"""Mid-run snapshot / resume of the grid's full execution state.

``repro.checkpoint.checkpoint`` stores a *model* (trainable tree + seed +
server optimizer state) — enough to warm-start a new run, not to continue
an interrupted one. This module snapshots everything an interrupted
``sim/grid.py`` run needs to pick up exactly where it died:

* the server model ``y`` and server-optimizer state,
* the async event heap (every in-flight client, with its computed delta),
  the carry-over buffer, the virtual clock and the insertion counter,
* the data / device / dynamics / fault RNG stream positions
  (``numpy.random.Generator.bit_generator.state`` round-trips exactly
  through JSON — Python ints are arbitrary precision),
* the FlushAccountant's RDP composition ledger,
* the selection policy's mutable state (rotation counters, observed-RTT
  EMAs, refit maps),
* the metrics registry (so end-of-run wire billing, which reads the
  scheduler counters, is exact),
* the history records so far.

The acceptance contract (tests/test_resume.py): kill a run at virtual
time T, restore its latest snapshot, continue — and the resumed run's
history, final ``y`` (bitwise on CPU) and privacy ledger match the
uninterrupted run's.

Snapshots are only taken at *flush boundaries* (async) or *round
boundaries* (sync): the one points where no lane work is pending, so
every in-flight completion event holds concrete arrays.

Format: one ``.npz`` holding the arrays plus a single JSON blob under
``__grid_meta__`` for everything scalar/structural. Legacy model
checkpoints (``__meta__`` key) are rejected with a pointer to
``checkpoint.load``.
"""
from __future__ import annotations

import glob
import heapq
import json
import os
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt_lib
from repro.nn import basic
from repro.sim import scheduler as sched_lib

GRID_STATE_VERSION = 1
META_KEY = "__grid_meta__"


# ---------------------------------------------------------------------------
# low-level helpers


def rng_state(gen: np.random.Generator) -> Dict[str, Any]:
    """A Generator's exact stream position (JSON-serializable: the PCG64
    state ints are Python ints, which json keeps at full precision)."""
    return gen.bit_generator.state


def set_rng_state(gen: np.random.Generator, state: Dict[str, Any]) -> None:
    gen.bit_generator.state = state


def _json_default(o):
    if isinstance(o, np.integer):
        return int(o)
    if isinstance(o, np.floating):
        return float(o)
    if isinstance(o, np.bool_):
        return bool(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(f"not JSON-serializable: {type(o).__name__}")


def checkpoint_path(directory: str, applied: int, mode: str) -> str:
    """Canonical snapshot filename: zero-padded so lexical sort ==
    chronological sort (what :func:`latest` relies on)."""
    return os.path.join(directory, f"grid_{mode}_{applied:06d}.npz")


def latest(directory: str) -> Optional[str]:
    paths = sorted(glob.glob(os.path.join(directory, "grid_*.npz")))
    return paths[-1] if paths else None


def save_state(path: str, meta: Dict[str, Any],
               arrays: Dict[str, np.ndarray]) -> str:
    path = ckpt_lib.with_suffix(path)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **{META_KEY: json.dumps(meta, default=_json_default)},
             **arrays)
    return path


def load_state(path: str) -> Tuple[Dict[str, Any], Dict[str, np.ndarray]]:
    """(meta, arrays) of a grid-state snapshot; raises on legacy model
    checkpoints and on version mismatch."""
    with np.load(ckpt_lib.with_suffix(path), allow_pickle=False) as z:
        if META_KEY not in z.files:
            raise ValueError(
                f"{path!r} is not a grid-state checkpoint (no "
                f"{META_KEY!r} entry) — legacy model checkpoints load "
                "via repro.checkpoint.checkpoint.load")
        meta = json.loads(str(z[META_KEY]))
        arrays = {k: z[k] for k in z.files if k != META_KEY}
    v = meta.get("grid_state_version")
    if v != GRID_STATE_VERSION:
        raise ValueError(f"grid-state version {v!r} is not supported "
                         f"(this build reads {GRID_STATE_VERSION})")
    return meta, arrays


def pack_tree(prefix: str, tree) -> Dict[str, np.ndarray]:
    return {f"{prefix}/{k}": np.asarray(v)
            for k, v in basic.flatten_params(tree)}


def unpack_tree(prefix: str, arrays: Dict[str, np.ndarray]):
    cut = len(prefix) + 1
    flat = {k[cut:]: arrays[k] for k in arrays
            if k.startswith(prefix + "/")}
    return jax.tree_util.tree_map(jnp.asarray, basic.unflatten_params(flat))


def pack_leaves(prefix: str, tree) -> Dict[str, np.ndarray]:
    return {f"{prefix}/{i}": np.asarray(l)
            for i, l in enumerate(jax.tree_util.tree_leaves(tree))}


def unpack_leaves(prefix: str, arrays: Dict[str, np.ndarray], template):
    leaves, treedef = jax.tree_util.tree_flatten(template)
    return jax.tree_util.tree_unflatten(
        treedef,
        [jnp.asarray(arrays[f"{prefix}/{i}"]) for i in range(len(leaves))])


# ---------------------------------------------------------------------------
# async snapshots


def _work_meta(work: Dict[str, Any]) -> Dict[str, Any]:
    m = {"weight": float(work["weight"]),
         "up_bytes": int(work["up_bytes"]),
         "cid": int(work["cid"]),
         "tier": None if work.get("tier") is None else int(work["tier"]),
         "lane": "cell" in work}
    if "fault" in work:
        m["fault"] = work["fault"]
    return m


def _work_arrays(work: Dict[str, Any]):
    """The concrete (delta, loss) of a completed client — snapshots only
    happen at flush boundaries, where every lane cell is resolved."""
    cell = work.get("cell")
    if cell is not None:
        delta, loss = cell.resolve()
        if delta is None:
            raise RuntimeError("unresolved lane cell at snapshot time — "
                               "snapshots must be taken at flush "
                               "boundaries only")
    else:
        delta, loss = work["delta"], work["loss"]
    return np.asarray(delta), np.asarray(loss)


def _restore_work(wm: Dict[str, Any], delta, loss,
                  make_cell) -> Dict[str, Any]:
    work = {"weight": wm["weight"], "up_bytes": wm["up_bytes"],
            "cid": wm["cid"], "tier": wm["tier"]}
    if wm["lane"]:
        if make_cell is None:
            raise ValueError("snapshot was taken with client lanes "
                             "(GridConfig.lanes > 0); resume with lanes "
                             "enabled too")
        cell = make_cell()
        cell.delta = jnp.asarray(delta)
        cell.loss = jnp.asarray(loss)
        work["cell"] = cell
    else:
        work["delta"] = jnp.asarray(delta)
        work["loss"] = jnp.asarray(loss)
    if "fault" in wm:
        work["fault"] = wm["fault"]
    return work


def _topology_meta(topo) -> Optional[Dict[str, int]]:
    return (None if topo is None
            else {"regions": int(topo.num_regions),
                  "clients": int(topo.num_clients)})


def _check_topology(meta: Dict[str, Any], topo, shocks) -> None:
    """A snapshot taken under a topology / shock model must resume under
    the same one: the region counters, hop ledger and shock RNG stream
    in the snapshot are meaningless otherwise."""
    tm = meta.get("topology")
    if (tm is not None) != (topo is not None) or (
            tm is not None and tm["regions"] != int(topo.num_regions)):
        raise ValueError(
            f"checkpointed topology {tm!r} does not match this run's "
            "GridConfig.topology — resume with the same region layout")
    if (meta.get("shocks") is not None) != (shocks is not None):
        raise ValueError(
            "checkpointed shock state does not match this run's "
            "DynamicsConfig.shocks — resume with the same shock model")
    if shocks is not None:
        shocks.load_state(meta["shocks"])


def encode_async(*, state: Dict[str, Any], sched, rngs, accountant,
                 policy, registry, shocks=None,
                 topo=None) -> Tuple[Dict[str, Any],
                                     Dict[str, np.ndarray]]:
    """Snapshot a BufferedAsyncScheduler run at a flush boundary.

    ``rngs`` maps stream names to the run's live Generators (data /
    device / dynamics / faults); the same names must be passed to
    :func:`decode_async`. The event heap is saved in raw list order and
    re-heapified on restore — the total (time, seq) order makes the pop
    sequence identical either way.
    """
    arrays: Dict[str, np.ndarray] = {}
    arrays.update(pack_tree("y", state["y"]))
    arrays.update(pack_leaves("s", state["sstate"]))
    events: List[Dict[str, Any]] = []
    for i, ev in enumerate(sched.q._heap):
        em: Dict[str, Any] = {"time": float(ev.time), "seq": int(ev.seq),
                              "kind": ev.kind}
        if ev.kind == "complete":
            em.update(cid=int(ev.payload["cid"]),
                      version=int(ev.payload["version"]),
                      tier=ev.payload.get("tier"),
                      rtt=float(ev.payload["rtt"]),
                      work=_work_meta(ev.payload["work"]))
            d, l = _work_arrays(ev.payload["work"])
            arrays[f"ev{i}/delta"] = d
            arrays[f"ev{i}/loss"] = l
        elif ev.kind == "failed":
            em.update(cid=int(ev.payload["cid"]),
                      tier=ev.payload.get("tier"),
                      cause=ev.payload.get("cause"))
        events.append(em)
    buffer: List[Dict[str, Any]] = []
    for i, e in enumerate(sched.buffer):
        buffer.append({"weight": float(e.weight),
                       "staleness": int(e.staleness),
                       "work": _work_meta(e.work)})
        d, l = _work_arrays(e.work)
        arrays[f"buf{i}/delta"] = d
        arrays[f"buf{i}/loss"] = l
    meta = {
        "grid_state_version": GRID_STATE_VERSION,
        "mode": "async",
        "applied": int(state["applied"]),
        "version": int(sched.version),
        "now": float(sched.q.now),
        "next_seq": int(sched.q._next_seq),
        "consecutive_retries": int(sched._consecutive_retries),
        "dark_since": sched._dark_since,
        "events": events,
        "buffer": buffer,
        "history": sched.records,
        "rng": {name: rng_state(g) for name, g in rngs.items()},
        "accountant": (accountant.state_dict()
                       if accountant is not None else None),
        "policy": policy.state_dict(),
        "metrics": registry.state_dict(),
        "topology": _topology_meta(topo),
        "shocks": shocks.state_dict() if shocks is not None else None,
    }
    return meta, arrays


def decode_async(meta: Dict[str, Any], arrays: Dict[str, np.ndarray], *,
                 state: Dict[str, Any], sched, sstate_template, rngs,
                 accountant, policy, registry, shocks=None, topo=None,
                 make_cell=None) -> List[Dict[str, Any]]:
    """Restore a snapshot into a freshly-constructed scheduler + state
    dict, before ``sched.run`` is called. Returns the restored history
    (``sched.records`` — run() appends to it until ``num_updates``)."""
    if meta["mode"] != "async":
        raise ValueError(f"cannot resume a {meta['mode']!r} snapshot in "
                         "async mode — GridConfig.mode must match")
    if (meta["accountant"] is not None) != (accountant is not None):
        raise ValueError("checkpointed DP state does not match this "
                         "run's dp_* settings — resume with the same "
                         "RoundConfig DP configuration")
    _check_topology(meta, topo, shocks)
    state["y"] = unpack_tree("y", arrays)
    state["sstate"] = unpack_leaves("s", arrays, sstate_template)
    state["applied"] = int(meta["applied"])
    sched.version = int(meta["version"])
    q = sched_lib.EventQueue()
    q.now = float(meta["now"])
    q._next_seq = int(meta["next_seq"])
    heap = []
    for i, em in enumerate(meta["events"]):
        payload: Dict[str, Any] = {}
        if em["kind"] == "complete":
            payload = {"cid": em["cid"], "version": em["version"],
                       "tier": em["tier"], "rtt": em["rtt"],
                       "work": _restore_work(em["work"],
                                             arrays[f"ev{i}/delta"],
                                             arrays[f"ev{i}/loss"],
                                             make_cell)}
        elif em["kind"] == "failed":
            payload = {"cid": em["cid"], "tier": em["tier"]}
            if em.get("cause") is not None:
                payload["cause"] = em["cause"]
        heap.append(sched_lib.Event(time=em["time"], seq=em["seq"],
                                    kind=em["kind"], payload=payload))
    heapq.heapify(heap)
    q._heap = heap
    sched.q = q
    sched.buffer = [
        sched_lib.BufferEntry(
            work=_restore_work(bm["work"], arrays[f"buf{i}/delta"],
                               arrays[f"buf{i}/loss"], make_cell),
            weight=float(bm["weight"]), staleness=int(bm["staleness"]))
        for i, bm in enumerate(meta["buffer"])]
    sched.records = list(meta["history"])
    sched._consecutive_retries = int(meta["consecutive_retries"])
    sched._dark_since = meta["dark_since"]
    for name, g in rngs.items():
        set_rng_state(g, meta["rng"][name])
    if accountant is not None:
        accountant.load_state(meta["accountant"])
    policy.load_state(meta["policy"])
    registry.load_state(meta["metrics"])
    # the snapshot was taken mid-event (inside the flush loop): replay
    # the interrupted event's tail — remaining full-buffer flushes and
    # the freed slot's redispatch — so run() picks up exactly where the
    # original run's event loop would have
    sched.finish_event(q.now)
    return sched.records


# ---------------------------------------------------------------------------
# sync snapshots


def encode_sync(*, y, sstate, round_idx: int, now: float, history, rngs,
                policy, registry, report, shocks=None,
                topo=None) -> Tuple[Dict[str, Any],
                                    Dict[str, np.ndarray]]:
    """Snapshot a sync run after round ``round_idx`` finished (the next
    round to run is ``round_idx + 1``). The comm ledger is billed per
    round in sync mode, so its measured totals ride along."""
    arrays: Dict[str, np.ndarray] = {}
    arrays.update(pack_tree("y", y))
    arrays.update(pack_leaves("s", sstate))
    meta = {
        "grid_state_version": GRID_STATE_VERSION,
        "mode": "sync",
        "round": int(round_idx),
        "now": float(now),
        "history": history,
        "rng": {name: rng_state(g) for name, g in rngs.items()},
        "policy": policy.state_dict(),
        "metrics": registry.state_dict(),
        "comm": {"measured_down_bytes": int(report.measured_down_bytes),
                 "measured_up_bytes": int(report.measured_up_bytes),
                 "transfers": int(report.transfers),
                 "tier_traffic": report.tier_traffic,
                 "hop_traffic": report.hop_traffic},
        "topology": _topology_meta(topo),
        "shocks": shocks.state_dict() if shocks is not None else None,
    }
    return meta, arrays


def decode_sync(meta: Dict[str, Any], arrays: Dict[str, np.ndarray], *,
                sstate_template, rngs, policy, registry, report,
                shocks=None, topo=None):
    """Returns (y, sstate, next_round, now, history) and restores the
    rng / policy / metrics / comm state in place."""
    if meta["mode"] != "sync":
        raise ValueError(f"cannot resume a {meta['mode']!r} snapshot in "
                         "sync mode — GridConfig.mode must match")
    _check_topology(meta, topo, shocks)
    y = unpack_tree("y", arrays)
    sstate = unpack_leaves("s", arrays, sstate_template)
    for name, g in rngs.items():
        set_rng_state(g, meta["rng"][name])
    policy.load_state(meta["policy"])
    registry.load_state(meta["metrics"])
    c = meta["comm"]
    report.measured_down_bytes = int(c["measured_down_bytes"])
    report.measured_up_bytes = int(c["measured_up_bytes"])
    report.transfers = int(c["transfers"])
    report.tier_traffic = {name: dict(rec)
                           for name, rec in c["tier_traffic"].items()}
    report.hop_traffic = {name: dict(rec)
                          for name, rec in c.get("hop_traffic",
                                                 {}).items()}
    return (y, sstate, int(meta["round"]) + 1, float(meta["now"]),
            list(meta["history"]))
