"""Checkpointing.

A FedPT checkpoint stores only the *trainable* tree, the scalar seed, the
freeze-spec and the server optimizer state — the frozen side regenerates
from the seed on restore, so checkpoints shrink by the frozen fraction
(the same 46x as the communication path, for the CIFAR-10 2.16% row).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.nn import basic


def _flat_np(tree):
    return {k: np.asarray(v) for k, v in basic.flatten_params(tree)}


def with_suffix(path: str) -> str:
    """Normalize a checkpoint path to carry the ``.npz`` suffix.

    ``np.savez`` silently appends ``.npz`` when the path lacks it, so
    ``save(p)`` followed by ``load(p)`` on the same suffix-less string
    used to raise FileNotFoundError. Both ends normalize through this
    so any spelling round-trips."""
    return path if path.endswith(".npz") else path + ".npz"


def save(path: str, trainable, seed: int, freeze_spec, server_state=None,
         round_num: int = 0, extra: Optional[Dict[str, Any]] = None):
    path = with_suffix(path)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays = {f"y/{k}": v for k, v in _flat_np(trainable).items()}
    if server_state is not None:
        leaves, treedef = jax.tree_util.tree_flatten(server_state)
        for i, l in enumerate(leaves):
            arrays[f"s/{i}"] = np.asarray(l)
        meta_state = str(treedef)
    else:
        meta_state = ""
    meta = {"seed": int(seed), "freeze_spec": list(freeze_spec),
            "round": int(round_num), "server_state_treedef": meta_state,
            "extra": extra or {}}
    np.savez(path, __meta__=json.dumps(meta), **arrays)


def load(path: str, server_state_template=None):
    """Returns (trainable, seed, freeze_spec, server_state, round, extra)."""
    with np.load(with_suffix(path), allow_pickle=False) as z:
        meta = json.loads(str(z["__meta__"]))
        flat = {k[2:]: z[k] for k in z.files if k.startswith("y/")}
        trainable = basic.unflatten_params(flat)
        server_state = None
        if server_state_template is not None:
            leaves, treedef = jax.tree_util.tree_flatten(server_state_template)
            loaded = [z[f"s/{i}"] for i in range(len(leaves))]
            server_state = jax.tree_util.tree_unflatten(treedef, loaded)
    return (trainable, meta["seed"], tuple(meta["freeze_spec"]),
            server_state, meta["round"], meta["extra"])


def restore_full_model(path: str, init_fn):
    """Restore the complete model: trainable from the file, frozen
    regenerated from the stored seed."""
    from repro.core import partition as part
    trainable, seed, freeze_spec, _, rnd, _ = load(path)
    frozen = part.partition(init_fn(seed), freeze_spec)[1]
    return part.merge(trainable, frozen), rnd
