"""Synthetic federated datasets.

The container has no EMNIST / CIFAR-10 / Stack Overflow, so we generate
*learnable* synthetic stand-ins with the exact tensor geometry of the
paper's tasks and the same federation structure:

* image tasks: each class has a Gaussian prototype image; client label
  distributions are drawn from a symmetric Dirichlet(alpha) as in
  Hsu et al. 2019 (the paper uses alpha=1 for CIFAR-10);
* language task: tokens follow per-client Markov chains mixed with a
  global chain, so next-word prediction has learnable structure and
  client heterogeneity.

Accuracy numbers on these are *trend-comparable*, not absolute-comparable,
with the paper (EXPERIMENTS.md §Validity).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# Image classification (EMNIST / CIFAR shaped)


@dataclasses.dataclass
class FederatedImages:
    client_images: List[np.ndarray]   # per client (n_i, H, W, C) float32
    client_labels: List[np.ndarray]   # per client (n_i,) int32
    test_images: np.ndarray
    test_labels: np.ndarray
    num_classes: int

    @property
    def num_clients(self) -> int:
        return len(self.client_images)


def make_federated_images(num_clients: int, examples_per_client: int,
                          shape: Tuple[int, int, int], num_classes: int,
                          alpha: float = 1.0, noise: float = 0.35,
                          test_examples: int = 1000, seed: int = 0):
    """Class prototypes + Gaussian noise; Dirichlet(alpha) label skew."""
    rng = np.random.default_rng(seed)
    protos = rng.normal(0.0, 1.0, (num_classes, *shape)).astype(np.float32)

    def sample(labels):
        x = protos[labels] + rng.normal(0, noise, (len(labels), *shape))
        return x.astype(np.float32)

    client_images, client_labels = [], []
    for _c in range(num_clients):
        p = rng.dirichlet(np.full(num_classes, alpha))
        labels = rng.choice(num_classes, size=examples_per_client, p=p)
        client_images.append(sample(labels))
        client_labels.append(labels.astype(np.int32))
    test_labels = rng.integers(0, num_classes, test_examples).astype(np.int32)
    return FederatedImages(client_images, client_labels,
                           sample(test_labels), test_labels, num_classes)


# ---------------------------------------------------------------------------
# Language (Stack Overflow NWP shaped)


@dataclasses.dataclass
class FederatedTokens:
    client_tokens: List[np.ndarray]   # per client (n_i, seq) int32
    test_tokens: np.ndarray
    vocab: int


def make_federated_tokens(num_clients: int, sentences_per_client: int,
                          seq_len: int = 20, vocab: int = 10004,
                          test_sentences: int = 512, mix: float = 0.7,
                          seed: int = 0) -> FederatedTokens:
    """Markov-chain text: a shared sparse transition table plus a
    client-specific one, mixed with weight `mix` on the shared table."""
    rng = np.random.default_rng(seed)
    branch = 8  # successors per token

    def make_table(r):
        return r.integers(0, vocab, (vocab, branch)).astype(np.int32)

    shared = make_table(rng)

    def gen(table_local, n, r):
        out = np.empty((n, seq_len), np.int32)
        tok = r.integers(0, vocab, n)
        for t in range(seq_len):
            out[:, t] = tok
            use_shared = r.random(n) < mix
            nxt_s = shared[tok, r.integers(0, branch, n)]
            nxt_l = table_local[tok, r.integers(0, branch, n)]
            tok = np.where(use_shared, nxt_s, nxt_l)
        return out

    client_tokens = []
    for c in range(num_clients):
        r = np.random.default_rng(seed + 1 + c)
        local = make_table(r)
        client_tokens.append(gen(local, sentences_per_client, r))
    r = np.random.default_rng(seed + 10_000)
    test = gen(make_table(r), test_sentences, r)
    return FederatedTokens(client_tokens, test, vocab)


# ---------------------------------------------------------------------------
# Cohort batching for the round engine


def sample_cohort(rng: np.random.Generator, num_clients: int, cohort: int):
    return rng.choice(num_clients, size=cohort, replace=False)


def client_batch_images(ds: FederatedImages, cid: int, tau: int, batch: int,
                        rng: np.random.Generator):
    """Returns ({'images': (tau,b,H,W,C), 'labels': (tau,b)}, weight)."""
    xs, ys = ds.client_images[cid], ds.client_labels[cid]
    idx = rng.integers(0, len(ys), (tau, batch))
    return {"images": xs[idx], "labels": ys[idx]}, float(len(ys))


def client_batch_tokens(ds: FederatedTokens, cid: int, tau: int, batch: int,
                        rng: np.random.Generator):
    xs = ds.client_tokens[cid]
    idx = rng.integers(0, len(xs), (tau, batch))
    return {"tokens": xs[idx]}, float(len(xs))


def cohort_batch(ds, cids, tau: int, batch: int, rng, kind: str = "images"):
    """Stack per-client batches into the round engine's
    (clients, tau, batch, ...) layout plus the weight vector p_i."""
    fn = client_batch_images if kind == "images" else client_batch_tokens
    batches, weights = [], []
    for cid in cids:
        b, w = fn(ds, int(cid), tau, batch, rng)
        batches.append(b)
        weights.append(w)
    out = {k: np.stack([b[k] for b in batches]) for k in batches[0]}
    return out, np.asarray(weights, np.float32)
