"""Minimal functional optimizers (optax-style (init, update) pairs).

Used both as ClientOpt (fresh state every round, per the generalized
FedAvg of Reddi et al. 2020) and as ServerOpt (persistent state across
rounds). The paper's experiments use: SGD / SGDM / Adam clients and
SGD / SGDM / Adam servers (Table 9).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]  # (params, grads, state) -> (params, state)
    name: str = ""


def sgd(lr: float) -> Optimizer:
    def init(_params):
        return ()

    def update(params, grads, state):
        new = jax.tree_util.tree_map(
            lambda p, g: p - lr * g.astype(p.dtype), params, grads)
        return new, state

    return Optimizer(init, update, f"sgd(lr={lr})")


def sgdm(lr: float, momentum: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return jax.tree_util.tree_map(lambda p: jnp.zeros_like(p), params)

    def update(params, grads, m):
        m = jax.tree_util.tree_map(
            lambda mm, g: momentum * mm + g.astype(mm.dtype), m, grads)
        if nesterov:
            step = jax.tree_util.tree_map(
                lambda mm, g: momentum * mm + g.astype(mm.dtype), m, grads)
        else:
            step = m
        new = jax.tree_util.tree_map(
            lambda p, s: p - lr * s.astype(p.dtype), params, step)
        return new, m

    return Optimizer(init, update, f"sgdm(lr={lr},m={momentum})")


def adam(lr: float, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8) -> Optimizer:
    def init(params):
        z = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"m": z, "v": jax.tree_util.tree_map(jnp.copy, z),
                "t": jnp.zeros((), jnp.int32)}

    def update(params, grads, state):
        t = state["t"] + 1
        m = jax.tree_util.tree_map(
            lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        new = jax.tree_util.tree_map(
            lambda p, mm, vv: p - (lr * (mm / bc1) /
                                   (jnp.sqrt(vv / bc2) + eps)).astype(p.dtype),
            params, m, v)
        return new, {"m": m, "v": v, "t": t}

    return Optimizer(init, update, f"adam(lr={lr})")


def get_optimizer(name: str, lr: float, **kw) -> Optimizer:
    return {"sgd": sgd, "sgdm": sgdm, "adam": adam}[name](lr, **kw)


# --- tree arithmetic helpers -------------------------------------------------


def tree_sub(a, b):
    return jax.tree_util.tree_map(lambda x, y: x - y, a, b)


def tree_add(a, b):
    return jax.tree_util.tree_map(lambda x, y: x + y, a, b)


def tree_scale(a, s):
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)
