"""Sharding rules: parameter-path regex -> PartitionSpec.

Conventions (Megatron-style TP on the "model" axis; clients/batch on
("pod", "data")):

* column-parallel: qkv / FFN-in / up projections shard their *output*
  dim on "model"; row-parallel: wo / FFN-out shard their *input* dim.
* MoE expert stacks shard the expert dim on "model" when divisible (and,
  for very large expert counts — DeepSeek's 160 — additionally the FFN
  dim, giving 2-D expert sharding so the 236B frozen bank fits HBM).
* embeddings/unembeddings shard the vocab dim (parallel-vocab with the
  log-softmax psum under GSPMD).
* norms, biases, gates, routers, small SSM tensors replicate.
* FROZEN leaves follow the same rules — they are inputs, never updated,
  and FedPT's aggregation collective excludes them entirely.

Every rule is divisibility-guarded: a dim that does not divide the axis
falls back to replication on that axis (e.g. whisper's 51866 vocab).
"""
from __future__ import annotations

import re
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch import mesh as mesh_lib
from repro.nn import basic


# (regex over path, spec template) — first match wins. Spec templates use
# NEGATIVE dim indices (relative to the trailing dims), so the same rule
# covers both a bare leaf and its scan-stacked (leading group dim) form.
_RULES = [
    # attention: column-parallel in, row-parallel out
    (r"/attn/w[qkv]/kernel$", {-1: "model"}),
    (r"/attn/w[qkv]/bias$", {-1: "model"}),
    (r"/attn/wo/kernel$", {-2: "model"}),
    (r"/cross_attn/w[qkv]/kernel$", {-1: "model"}),
    (r"/cross_attn/wo/kernel$", {-2: "model"}),
    # MLA
    (r"/attn/wq_b/kernel$", {-1: "model"}),
    (r"/attn/wk_b/kernel$", {-1: "model"}),
    (r"/attn/wv_b/kernel$", {-1: "model"}),
    # dense FFN
    (r"/ffn/wi(_gate|_up)?/kernel$", {-1: "model"}),
    (r"/ffn/wo/kernel$", {-2: "model"}),
    # MoE experts: stacked (E, d, ff) / (E, ff, d); expert dim on model
    (r"/moe/wi_(gate|up)$", {-3: "model"}),
    (r"/moe/wo$", {-3: "model"}),
    (r"/moe/shared/wi(_gate|_up)?/kernel$", {-1: "model"}),
    (r"/moe/shared/wo/kernel$", {-2: "model"}),
    # Mamba: in column-parallel, out row-parallel; channel tensors sharded
    (r"/mamba/in_proj/kernel$", {-1: "model"}),
    (r"/mamba/out_proj/kernel$", {-2: "model"}),
    (r"/mamba/x_proj/kernel$", {-2: "model"}),
    (r"/mamba/dt_proj/kernel$", {-1: "model"}),
    (r"/mamba/conv_w$", {-1: "model"}),
    (r"/mamba/conv_b$", {-1: "model"}),
    (r"/mamba/A_log$", {-2: "model"}),
    (r"/mamba/D$", {-1: "model"}),
    # xLSTM
    (r"/mlstm/up_proj/kernel$", {-1: "model"}),
    (r"/mlstm/down_proj/kernel$", {-2: "model"}),
    # embeddings: parallel-vocab
    (r"embed/embedding$", {-2: "model"}),
    (r"unembed/kernel$", {-1: "model"}),
]

# 2-D expert sharding for very large expert banks (DeepSeek-V2): expert
# dim on "data", FFN dim on "model" — 236B of frozen experts / 256 chips.
_RULES_2D_EXPERTS = [
    (r"/moe/wi_(gate|up)$", {-3: "data", -1: "model"}),
    (r"/moe/wo$", {-3: "data", -2: "model"}),
]

# When the expert count does not divide the model axis (Mixtral's 8 on a
# 16-wide axis), shard the expert FFN dim instead (intra-expert TP) —
# otherwise 45B of experts replicate per device.
_RULES_FFN_EXPERTS = [
    (r"/moe/wi_(gate|up)$", {-1: "model"}),
    (r"/moe/wo$", {-2: "model"}),
]

# 2-D expert sharding with the axes swapped (expert dim on "model", FFN
# dim on "data") — used by the grouped-dispatch perf variant, where the
# "data" axis is needed for the token groups.
_RULES_2D_EXPERTS_SWAPPED = [
    (r"/moe/wi_(gate|up)$", {-3: "model", -1: "data"}),
    (r"/moe/wo$", {-3: "model", -2: "data"}),
]


def _spec_for(path: str, shape, mesh, rules) -> P:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for pat, dims in rules:
        if re.search(pat, path):
            spec = [None] * len(shape)
            for d, ax in dims.items():
                di = d + len(shape) if d < 0 else d
                if 0 <= di < len(shape) and shape[di] % sizes.get(ax, 1) == 0 \
                        and shape[di] >= sizes.get(ax, 1):
                    spec[di] = ax
            return P(*spec)
    return P()


def param_shardings(params_struct, cfg: ModelConfig, mesh):
    """Tree of NamedShardings matching the (possibly stacked) param tree."""
    rules = list(_RULES)
    msize = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    mode = cfg.expert_shard
    if mode == "auto":
        mode = ("2d" if cfg.num_experts >= 64 else
                ("ffn" if cfg.num_experts and cfg.num_experts % msize else
                 "model"))
    if mode == "2d":
        rules = _RULES_2D_EXPERTS + rules
    elif mode == "2d_swapped":
        rules = _RULES_2D_EXPERTS_SWAPPED + rules
    elif mode == "ffn":
        rules = _RULES_FFN_EXPERTS + rules
    flat = dict(basic.flatten_params(params_struct))
    out = {}
    for path, leaf in flat.items():
        spec = _spec_for(path, leaf.shape, mesh, rules)
        out[path] = NamedSharding(mesh, spec)
    return basic.unflatten_params(out)


def replicated(tree, mesh):
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), tree)


def flat_constrainer(mesh):
    """``constrain_flat_fn(arr, clients: bool)`` for this mesh — the one
    sharding rule of the flat aggregation plane, shared by the dry-run
    specs (``launch/specs.py``) and the simulation grid
    (``sim/grid.py``) so the two cannot drift.

    The ``(C, size)`` client-delta buffer pins its client/lane axis to
    the data axes (``("pod", "data")`` when both exist) and its size
    axis to ``"model"`` (GSPMD pads uneven splits), so a tensor-parallel
    mesh never materializes C full-size fp32 vectors per data shard; the
    aggregated ``(size,)`` vector stays model-sharded until ``unflatten``
    reshards each leaf to its parameter layout. The weighted mean's
    client-axis reduction then lowers to the cross-data-axis collective
    directly on the sharded buffer — no gather of the K rows first."""
    dax = mesh_lib.data_axes(mesh)
    model = "model" if "model" in mesh.axis_names else None
    client_axes = dax if len(dax) > 1 else (dax[0] if dax else None)

    def constrain_flat(arr, clients: bool):
        spec = P(client_axes, model) if clients else P(model)
        return jax.lax.with_sharding_constraint(
            arr, NamedSharding(mesh, spec))

    return constrain_flat


def cohort_constrainer(mesh):
    """``constrain_batch_fn(tree)`` for SYNC-mode cohort inputs — the
    input-plane twin of :func:`flat_constrainer`'s rule: every batch
    leaf pins its leading (client/lane) axis to the data axes
    (``("pod", "data")`` when both exist), so the cohort's microbatches
    land data-parallel inside the jitted round instead of replicated
    per device. Divisibility-guarded per leaf (a cohort that does not
    divide the data axes replicates, exactly like
    :func:`batch_sharding`); trailing dims always replicate.

    Also applied to tier-grouped lane batches: the rule only names the
    leading axis, so tier-sliced shapes share it unchanged."""
    dax = mesh_lib.data_axes(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    total = 1
    for a in dax:
        total *= sizes[a]
    axes = dax if len(dax) > 1 else (dax[0] if dax else None)

    def constrain_batch(tree):
        def one(x):
            if axes is not None and x.ndim >= 1 and x.shape[0] % total == 0:
                spec = P(axes, *([None] * (x.ndim - 1)))
            else:
                spec = P()
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, spec))
        return jax.tree_util.tree_map(one, tree)

    return constrain_batch


def batch_sharding(tree_struct, mesh, batch_axes=("pod", "data"),
                   batch_dim: int = 0):
    """Shard the leading (client/batch) dim over the data axes."""
    axes = tuple(a for a in batch_axes if a in mesh.axis_names)

    def one(leaf):
        spec = [None] * len(leaf.shape)
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        total = 1
        for a in axes:
            total *= sizes[a]
        if leaf.shape[batch_dim] % total == 0:
            spec[batch_dim] = axes if len(axes) > 1 else axes[0]
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map(one, tree_struct)


def cache_shardings(cache_struct, cfg: ModelConfig, mesh, long_context: bool):
    """KV-cache / SSM-state shardings for serving.

    decode_32k: batch over ("pod","data"), cache seq over "model".
    long_500k (batch=1): cache seq over ("data","model"); SSM states shard
    their channel dim.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def one_path(path, leaf):
        shp = leaf.shape
        spec = [None] * len(shp)
        if path.endswith("cache_len"):
            return NamedSharding(mesh, P())
        is_seq_cache = any(path.endswith(s) for s in
                           ("/k", "/v", "/ckv", "/kpe"))
        if is_seq_cache:
            # (G, B, S, ...)
            if long_context:
                want = sizes.get("data", 1) * sizes.get("model", 1)
                if shp[2] % want == 0:
                    spec[2] = ("data", "model")
                elif shp[2] % sizes.get("model", 1) == 0:
                    spec[2] = "model"
            else:
                total = 1
                for a in dax:
                    total *= sizes[a]
                if shp[1] % total == 0:
                    spec[1] = dax if len(dax) > 1 else dax[0]
                if shp[2] % sizes.get("model", 1) == 0:
                    spec[2] = "model"
            return NamedSharding(mesh, P(*spec))
        # SSM states: (G, B, channels, ...) — shard the channel dim
        for d in range(2, len(shp)):
            if shp[d] % sizes.get("model", 1) == 0 and shp[d] >= sizes.get("model", 1):
                spec[d] = "model"
                break
        if not long_context:
            total = 1
            for a in dax:
                total *= sizes[a]
            if shp[1] % total == 0:
                spec[1] = dax if len(dax) > 1 else dax[0]
        return NamedSharding(mesh, P(*spec))

    flat = dict(basic.flatten_params(cache_struct))
    out = {p: one_path(p, l) for p, l in flat.items()}
    return basic.unflatten_params(out)
