import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("DRYRUN_DEVICES", "512")).strip()
"""Multi-pod dry-run entrypoint.

Lowers and compiles every (architecture x input-shape) pair against the
production mesh — (16,16) single-pod and (2,16,16) multi-pod — and
records memory_analysis / cost_analysis / collective statistics for the
roofline (EXPERIMENTS.md §Dry-run, §Roofline).

The two os.environ lines above MUST stay the first statements: jax locks
the device count on first init. Smoke tests and benchmarks never import
this module (they see 1 device).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
      --shape train_4k [--multi-pod] [--out results.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax

from repro.configs import load_all, ARCH_IDS
from repro.launch import mesh as mesh_lib
from repro.launch import specs as specs_lib

COLLECTIVE_RE = re.compile(
    r"(\w[\w.-]*)\s*=\s*((?:\(|)[a-z0-9_\[\],{}\s/]*(?:\)|))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)\(",
)
SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str):
    """Sum result-shape bytes per collective kind, with per-computation
    counts so the roofline can scale while-body occurrences by trip count."""
    stats = {}
    comp = "<entry>"
    while_bodies = set(re.findall(r"body=%?([\w.-]+)", hlo_text))
    for line in hlo_text.splitlines():
        m = re.match(r"\s*%?([\w.-]+)\s*\(", line)
        if line.startswith(("%", "ENTRY")) and "{" in line:
            nm = re.match(r"(?:ENTRY\s+)?%?([\w.-]+)", line)
            if nm:
                comp = nm.group(1)
        cm = COLLECTIVE_RE.search(line)
        if cm:
            kind = cm.group(3)
            by = _shape_bytes(line.split("=", 1)[1].split(kind)[0])
            rec = stats.setdefault(kind, {"count": 0, "bytes": 0,
                                          "in_loop_bytes": 0})
            rec["count"] += 1
            rec["bytes"] += by
            if comp in while_bodies:
                rec["in_loop_bytes"] += by
    return stats


def run_one(arch: str, shape: str, multi_pod: bool = False,
            mesh=None, verbose: bool = True, cfg_override=None):
    reason = specs_lib.skip_reason(arch, shape)
    if reason and cfg_override is None:
        return {"arch": arch, "shape": shape, "status": "skip",
                "reason": reason}
    if mesh is None:
        mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    # jax.set_mesh only exists on newer jax; entering the Mesh object is
    # the 0.4.x-compatible way to make it the ambient mesh
    set_mesh = getattr(jax, "set_mesh", None) or (lambda m: m)
    try:
        job = specs_lib.build_job(arch, shape, mesh,
                                  cfg_override=cfg_override)
        with set_mesh(mesh):
            jitted = jax.jit(job.fn, in_shardings=job.in_shardings)
            lowered = jitted.lower(*job.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            # older jax returns a one-element list of the per-device dict
            if isinstance(cost, (list, tuple)):
                cost = cost[0] if cost else {}
            hlo = compiled.as_text()
        coll = collective_stats(hlo)
        res = {
            "arch": arch, "shape": shape, "status": "ok",
            "mesh": list(mesh.devices.shape),
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                # peak_memory_in_bytes only exists on TPU backends; the
                # arg+out+temp sum is the CPU approximation
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None)
                or (getattr(mem, "argument_size_in_bytes", 0)
                    + getattr(mem, "output_size_in_bytes", 0)
                    + getattr(mem, "temp_size_in_bytes", 0)) or None,
            },
            "cost": {k: cost.get(k) for k in
                     ("flops", "bytes accessed", "transcendentals")
                     if isinstance(cost, dict) and k in cost},
            "collectives": coll,
            "clients": job.clients,
        }
        if verbose:
            print(f"[ok] {arch} x {shape} mesh={res['mesh']} "
                  f"lower={t_lower:.0f}s compile={t_compile:.0f}s "
                  f"flops={res['cost'].get('flops')}")
        return res
    except Exception as e:  # noqa: BLE001 — report, don't crash the matrix
        if verbose:
            traceback.print_exc()
        return {"arch": arch, "shape": shape, "status": "error",
                "error": f"{type(e).__name__}: {e}",
                "elapsed_s": round(time.time() - t0, 1)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    load_all()
    mesh = mesh_lib.make_production_mesh(multi_pod=args.multi_pod)
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"on {len(jax.devices())} host devices")

    results = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in specs_lib.SHAPES:
                results.append(run_one(arch, shape, mesh=mesh))
    else:
        results.append(run_one(args.arch, args.shape, mesh=mesh))

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    bad = [r for r in results if r["status"] == "error"]
    print(f"{len(results)} jobs: "
          f"{sum(r['status'] == 'ok' for r in results)} ok, "
          f"{sum(r['status'] == 'skip' for r in results)} skip, "
          f"{len(bad)} error")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
