"""Production mesh construction.

The target is a TPU v5e pod-slice: one pod = a (data=16, model=16) mesh
of 256 chips; the multi-pod configuration adds a leading pod axis
(2 x 16 x 16 = 512 chips). Client cohorts of the federated round shard
over ("pod", "data"); tensor/expert parallelism lives on "model".

This module never touches jax device state at import time — meshes are
built inside functions, and only the dry-run entrypoint forces the
512-device host platform.
"""
from __future__ import annotations

import math

import jax
import numpy as np

HW = {
    # TPU v5e per-chip constants used by the roofline (benchmarks/roofline.py)
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link
    "hbm_bytes": 16 * 1024 ** 3,
}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devs)}; run via "
            "launch/dryrun.py which forces 512 host devices")
    return jax.make_mesh(shape, axes, devices=devs[:n])


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for tests (requires forced host device count >= prod)."""
    n = math.prod(shape)
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


def make_single_device_mesh():
    """1x1 mesh so smoke tests exercise the pjit path on one CPU device."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])


def data_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# Named meshes the simulation grid accepts (``GridConfig.mesh``). Debug
# presets exist so the multi-device CI job (8 forced host devices) can
# exercise the sharded code paths without the 256-chip production shape.
MESH_PRESETS = {
    "single": make_single_device_mesh,
    "debug": make_debug_mesh,                            # (data=2, model=2)
    "debug-pod": lambda: make_debug_mesh(
        (2, 2, 2), ("pod", "data", "model")),            # 8 devices
    "production": make_production_mesh,
    "production-multipod": lambda: make_production_mesh(multi_pod=True),
}


def resolve_mesh(spec):
    """``None`` | preset name | mesh object -> mesh object (or ``None``).

    This is the one place grid/spec configs turn a *description* of a
    mesh into device state, so configs stay picklable and importing a
    config never touches jax devices."""
    if spec is None:
        return None
    if isinstance(spec, str):
        try:
            factory = MESH_PRESETS[spec]
        except KeyError:
            raise ValueError(f"unknown mesh preset {spec!r}; options: "
                             f"{sorted(MESH_PRESETS)}") from None
        return factory()
    return spec


def axis_size(mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)
