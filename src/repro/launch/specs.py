"""Input specifications and step builders for every (architecture x
input-shape) pair — the substrate of the multi-pod dry-run.

All inputs are ShapeDtypeStructs (no allocation); params come from
jax.eval_shape over the real init. The FROZEN tree is bf16 (read-only
weights), the TRAINABLE tree stays f32 (master copy) — the standard
mixed-precision split.

Shapes (assignment):
  train_4k     seq 4,096   global_batch 256   -> fedpt_round_step
  prefill_32k  seq 32,768  global_batch 32    -> prefill_step
  decode_32k   seq 32,768  global_batch 128   -> serve_step (1 token)
  long_500k    seq 524,288 global_batch 1     -> serve_step (1 token)

`long_500k` is only lowered for sub-quadratic-capable architectures
(SSM / hybrid / sliding-window); see SKIPS.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.partition as part
from repro.configs.base import ModelConfig, get_config
from repro.core import fedpt
from repro.launch import mesh as mesh_lib
from repro.launch import sharding as shard_lib
from repro.models import decoder_lm as dlm
from repro.nn import basic

F32 = jnp.float32
BF16 = jnp.bfloat16
I32 = jnp.int32

SHAPES = {
    "train_4k": dict(seq=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq=524288, global_batch=1, kind="decode"),
}

# Principled skips (DESIGN.md §shape-coverage): long_500k needs
# sub-quadratic attention. SWA archs get it natively; mistral-nemo gets
# our beyond-paper SWA serving variant; pure full-attention archs skip.
LONG_OK = {"mixtral-8x7b", "jamba-v0.1-52b", "xlstm-350m", "mistral-nemo-12b"}
# serving SWA window applied to nemo for long_500k only:
NEMO_SERVE_WINDOW = 8192
VISION_TOWER_DIM = 1152


def skip_reason(arch: str, shape: str) -> Optional[str]:
    if shape == "long_500k" and arch not in LONG_OK:
        return "pure full-attention arch: 500k decode excluded by design"
    return None


def serving_config(cfg: ModelConfig, shape: str) -> ModelConfig:
    if shape == "long_500k" and cfg.name == "mistral-nemo-12b":
        # beyond-paper serving adaptation: rolling-buffer SWA cache
        return cfg.with_(sliding_window=NEMO_SERVE_WINDOW)
    return cfg


# ---------------------------------------------------------------------------
# Parameter structs


def param_structs(cfg: ModelConfig, seed: int = 0):
    """eval_shape the init and split into (y_struct f32, frozen_struct bf16)."""
    full = jax.eval_shape(lambda: dlm.init_model(cfg, seed))
    y, z = part.partition(full, cfg.freeze_spec)
    z = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, BF16), z)
    return y, z


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


# ---------------------------------------------------------------------------
# Input specs per shape kind


def train_specs(cfg: ModelConfig, mesh, seq: int, global_batch: int,
                tau: int = 2):
    """(batch_struct, weights_struct, clients) for one federated round."""
    dax = mesh_lib.data_axes(mesh)
    clients = 1
    for a in dax:
        clients *= mesh_lib.axis_size(mesh, a)
    b = global_batch // (clients * tau)
    assert b >= 1, (cfg.name, global_batch, clients, tau)
    tok_seq = seq - cfg.num_prefix_tokens if cfg.family == "vlm" else seq
    batch = {
        "tokens": _sds((clients, tau, b, tok_seq), I32),
        "labels": _sds((clients, tau, b, tok_seq), I32),
    }
    if cfg.family == "vlm":
        batch["prefix_embeds"] = _sds(
            (clients, tau, b, cfg.num_prefix_tokens, VISION_TOWER_DIM), BF16)
    if cfg.is_encoder_decoder:
        batch["encoder_embeds"] = _sds(
            (clients, tau, b, cfg.encoder_seq_len, cfg.d_model), BF16)
    weights = _sds((clients,), F32)
    return batch, weights, clients


def prefill_specs(cfg: ModelConfig, seq: int, global_batch: int):
    tok_seq = seq - cfg.num_prefix_tokens if cfg.family == "vlm" else seq
    batch = {"tokens": _sds((global_batch, tok_seq), I32)}
    if cfg.family == "vlm":
        batch["prefix_embeds"] = _sds(
            (global_batch, cfg.num_prefix_tokens, VISION_TOWER_DIM), BF16)
    if cfg.is_encoder_decoder:
        batch["encoder_embeds"] = _sds(
            (global_batch, cfg.encoder_seq_len, cfg.d_model), BF16)
    return batch


def decode_specs(cfg: ModelConfig, seq: int, global_batch: int):
    cache = jax.eval_shape(
        lambda: dlm.init_cache(cfg, global_batch, seq, dtype=BF16))
    tokens = _sds((global_batch, 1), I32)
    return cache, tokens


# ---------------------------------------------------------------------------
# Step builders


def make_train_step(cfg: ModelConfig, mesh, y_struct):
    """FedPT round step for this architecture (client sgd, server sgdm)."""
    rc = fedpt.RoundConfig(clients_per_round=0, local_steps=2, local_batch=0,
                           client_opt="sgd", client_lr=0.02,
                           server_opt="sgdm", server_lr=0.5)

    shard_y = shard_lib.param_shardings(y_struct, cfg, mesh)
    dax = mesh_lib.data_axes(mesh)
    from jax.sharding import NamedSharding, PartitionSpec as P

    def constrain(tree, clients: bool):
        def one(x, ns):
            spec = ns.spec
            if clients:
                spec = P(dax if len(dax) > 1 else dax[0], *spec)
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, spec))
        return jax.tree_util.tree_map(one, tree, shard_y)

    # the flat delta buffer's and the cohort input batch's sharding
    # rules live in launch/sharding.py (shared with the simulation
    # grid's mesh execution path, so the two cannot drift)
    constrain_flat = shard_lib.flat_constrainer(mesh)
    constrain_batch = shard_lib.cohort_constrainer(mesh)

    def loss_fn(params, mb):
        return dlm.train_loss(params, cfg, mb)

    round_step, server_opt = fedpt.make_round_fn(
        loss_fn, rc, constrain_fn=constrain,
        constrain_flat_fn=constrain_flat,
        constrain_batch_fn=constrain_batch)

    def train_step(y, sstate, frozen, batch, weights, seed):
        rng = jax.random.key(seed[0])
        return round_step(y, sstate, frozen, batch, weights, rng)

    return train_step, server_opt


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(y, frozen, batch):
        params = part.merge(y, frozen)
        kw = {}
        if cfg.family == "vlm":
            kw["prefix_embeds"] = batch["prefix_embeds"]
        if cfg.is_encoder_decoder:
            kw["encoder_embeds"] = batch["encoder_embeds"]
        logits, metrics = dlm.forward(params, cfg, batch["tokens"], **kw)
        return logits

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def serve_step(y, frozen, cache, tokens):
        params = part.merge(y, frozen)
        return dlm.decode_step(params, cfg, cache, tokens)

    return serve_step


# ---------------------------------------------------------------------------
# Assembled lowering spec per (arch, shape, mesh)


@dataclasses.dataclass
class LoweringJob:
    arch: str
    shape: str
    fn: Callable
    args: tuple                 # ShapeDtypeStructs
    in_shardings: tuple
    cfg: ModelConfig
    clients: int = 0


def build_job(arch: str, shape: str, mesh, cfg_override=None) -> LoweringJob:
    base_cfg = cfg_override if cfg_override is not None else get_config(arch)
    info = SHAPES[shape]
    cfg = serving_config(base_cfg, shape)
    y_struct, z_struct = param_structs(cfg)
    shard_y = shard_lib.param_shardings(y_struct, cfg, mesh)
    shard_z = shard_lib.param_shardings(z_struct, cfg, mesh)
    from jax.sharding import NamedSharding, PartitionSpec as P
    rep = NamedSharding(mesh, P())

    if info["kind"] == "train":
        batch, weights, clients = train_specs(cfg, mesh, info["seq"],
                                              info["global_batch"])
        train_step, server_opt = make_train_step(cfg, mesh, y_struct)
        sstate_struct = jax.eval_shape(server_opt.init, y_struct)
        shard_ss = jax.tree_util.tree_map(
            lambda s: shard_lib.param_shardings(y_struct, cfg, mesh), ())
        # sgdm state mirrors y's structure -> same shardings
        shard_sstate = shard_lib.param_shardings(sstate_struct, cfg, mesh)
        shard_batch = shard_lib.batch_sharding(batch, mesh)
        seed = _sds((1,), I32)
        args = (y_struct, sstate_struct, z_struct, batch,
                _sds((clients,), F32), seed)
        inshard = (shard_y, shard_sstate, shard_z, shard_batch,
                   shard_lib.batch_sharding(_sds((clients,), F32), mesh), rep)
        return LoweringJob(arch, shape, train_step, args, inshard, cfg,
                           clients)

    if info["kind"] == "prefill":
        batch = prefill_specs(cfg, info["seq"], info["global_batch"])
        fn = make_prefill_step(cfg)
        args = (y_struct, z_struct, batch)
        inshard = (shard_y, shard_z, shard_lib.batch_sharding(batch, mesh))
        return LoweringJob(arch, shape, fn, args, inshard, cfg)

    # decode
    cache, tokens = decode_specs(cfg, info["seq"], info["global_batch"])
    fn = make_decode_step(cfg)
    long_ctx = shape == "long_500k"
    shard_cache = shard_lib.cache_shardings(cache, cfg, mesh, long_ctx)
    tok_shard = (shard_lib.batch_sharding(tokens, mesh)
                 if not long_ctx else rep)
    args = (y_struct, z_struct, cache, tokens)
    inshard = (shard_y, shard_z, shard_cache, tok_shard)
    return LoweringJob(arch, shape, fn, args, inshard, cfg)
