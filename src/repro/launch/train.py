"""Training launcher.

Two modes:

* paper tasks (CPU-runnable end-to-end): federated training of the
  paper's own models on synthetic federated data —
    PYTHONPATH=src python -m repro.launch.train --task emnist \
        --rounds 100 [--fully-trainable]
* assigned architectures (reduced variants for CPU; the full configs are
  exercised by the dry-run):
    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
        --reduced --rounds 10
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.partition as part
from repro.configs import load_all
from repro.configs.base import get_config
from repro.core import fedpt
from repro.data import synthetic as syn
from repro.fl import runtime
from repro.models import decoder_lm as dlm
from repro.models import paper_models as pm


def reduced_config(cfg, max_layers: int = 2, d_model: int = 256,
                   vocab: int = 512):
    """Smoke-scale variant of an assigned architecture (same family/wiring)."""
    slots, _ = __import__("repro.models.decoder_lm", fromlist=["layer_program"]
                          ).layer_program(cfg)
    period = len(slots)
    layers = max(period, (max_layers + period - 1) // period * period)
    d = min(cfg.d_model, d_model)
    heads = min(cfg.num_heads, max(1, d // 64))
    kvh = max(1, min(cfg.num_kv_heads, heads))
    while heads % kvh:
        kvh -= 1
    return cfg.with_(
        num_layers=layers, d_model=d, num_heads=heads, num_kv_heads=kvh,
        head_dim=d // heads if cfg.head_dim else 0,
        d_ff=min(cfg.d_ff, 4 * d) if cfg.d_ff else 0,
        moe_d_ff=min(cfg.expert_d_ff, 2 * d) if cfg.num_experts else 0,
        num_experts=min(cfg.num_experts, 4),
        num_experts_per_tok=min(cfg.num_experts_per_tok, 2),
        vocab_size=min(cfg.vocab_size, vocab),
        kv_lora_rank=min(cfg.kv_lora_rank, 64),
        q_lora_rank=min(cfg.q_lora_rank, 96),
        qk_nope_head_dim=32 if cfg.use_mla else cfg.qk_nope_head_dim,
        qk_rope_head_dim=16 if cfg.use_mla else cfg.qk_rope_head_dim,
        v_head_dim=32 if cfg.use_mla else cfg.v_head_dim,
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_seq_len=min(cfg.encoder_seq_len, 16) or 0,
        num_prefix_tokens=min(cfg.num_prefix_tokens, 8),
        compute_dtype="float32",
    )


def run_paper_task(task: str, rounds: int, fully_trainable: bool,
                   seed: int = 0, log: bool = True):
    if task == "emnist":
        ds = syn.make_federated_images(60, 60, (28, 28, 1), 62, seed=seed)
        init_fn = lambda s: pm.init_emnist_cnn(s)
        fwd = pm.emnist_cnn_forward
        spec = () if fully_trainable else pm.EMNIST_FREEZE
        rc = fedpt.RoundConfig(20, 2, 16, "sgd", 0.05, "sgd", 0.5)
        kind = "images"
        ev = runtime.accuracy_eval(fwd, ds.test_images, ds.test_labels)
    elif task == "cifar":
        ds = syn.make_federated_images(50, 100, (24, 24, 3), 10, seed=seed)
        init_fn = lambda s: pm.init_resnet18(s)
        fwd = pm.resnet18_forward
        spec = () if fully_trainable else pm.resnet18_freeze_spec((3,))
        rc = fedpt.RoundConfig(10, 2, 32, "sgdm", 10**-0.5, "sgdm", 0.1)
        kind = "images"
        ev = runtime.accuracy_eval(fwd, ds.test_images, ds.test_labels)
    elif task == "stackoverflow":
        ds = syn.make_federated_tokens(64, 64, vocab=2004, seed=seed)
        init_fn = lambda s: pm.init_so_transformer(s, vocab=2004)
        fwd = pm.so_transformer_forward
        spec = () if fully_trainable else pm.so_freeze_spec((0, 1, 2))
        rc = fedpt.RoundConfig(32, 2, 16, "adam", 0.1, "sgd", 0.03)
        kind = "tokens"
        ev = runtime.nwp_accuracy_eval(fwd, ds.test_tokens)
    else:
        raise ValueError(task)

    if kind == "images":
        def loss_fn(params, b):
            logits = fwd(params, b["images"])
            lp = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(
                lp, b["labels"][:, None], 1)), {}
    else:
        def loss_fn(params, b):
            logits = fwd(params, b["tokens"])
            return dlm.lm_loss(logits[:, :-1], b["tokens"][:, 1:]), {}

    res = runtime.run_federated(init_fn, loss_fn, ds, rc, rounds,
                                freeze_spec=spec, seed=seed, data_kind=kind,
                                eval_every=max(1, rounds // 4), eval_fn=ev,
                                log=log)
    return res


def run_reduced_arch(arch: str, rounds: int, seed: int = 0, log: bool = True):
    load_all()
    cfg = reduced_config(get_config(arch))
    ds = syn.make_federated_tokens(16, 32, seq_len=32, vocab=cfg.vocab_size,
                                   seed=seed)
    init_fn = lambda s: dlm.init_model(cfg, s)

    def loss_fn(params, b):
        batch = {"tokens": b["tokens"], "labels": b["tokens"]}
        if cfg.family == "vlm":
            batch["prefix_embeds"] = jnp.zeros(
                (b["tokens"].shape[0], cfg.num_prefix_tokens, 1152))
        if cfg.is_encoder_decoder:
            batch["encoder_embeds"] = jnp.zeros(
                (b["tokens"].shape[0], cfg.encoder_seq_len, cfg.d_model))
        return dlm.train_loss(params, cfg, batch)

    rc = fedpt.RoundConfig(4, 2, 4, "sgd", 0.1, "sgdm", 0.5)
    return runtime.run_federated(init_fn, loss_fn, ds, rc, rounds,
                                 freeze_spec=cfg.freeze_spec, seed=seed,
                                 data_kind="tokens", log=log), cfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", choices=["emnist", "cifar", "stackoverflow"])
    ap.add_argument("--arch", default=None)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--fully-trainable", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.task:
        res = run_paper_task(args.task, args.rounds, args.fully_trainable,
                             args.seed)
    else:
        res, cfg = run_reduced_arch(args.arch, args.rounds, args.seed)
        print(f"arch={cfg.name} trainable share: "
              f"{100 * res.comm.trainable_bytes / res.comm.full_bytes:.2f}%")
    print(f"final loss={res.history[-1]['loss']:.4f} "
          f"comm reduction={res.comm.reduction:.1f}x "
          f"sec/round={res.seconds_per_round:.2f}")


if __name__ == "__main__":
    main()
