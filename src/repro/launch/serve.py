"""Serving driver: batched autoregressive decoding with KV caches /
SSM states for any registered architecture (reduced variants run on CPU;
full configs are exercised via the dry-run serve_step lowering).

  PYTHONPATH=src python -m repro.launch.serve --arch xlstm-350m \
      --reduced --batch 4 --steps 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import load_all
from repro.configs.base import get_config
from repro.models import decoder_lm as dlm


def generate(params, cfg, prompt_tokens, steps: int, max_len: int = 0,
             temperature: float = 0.0, seed: int = 0):
    """Greedy / sampled generation. prompt_tokens: (B, P)."""
    B, P = prompt_tokens.shape
    max_len = max_len or (P + steps)
    cache = dlm.init_cache(cfg, B, max_len)
    step = jax.jit(lambda c, t: dlm.decode_step(params, cfg, c, t))
    # prefill by stepping the prompt (simple serving path; bulk prefill
    # uses forward(return_caches=True))
    logits = None
    for t in range(P):
        logits, cache = step(cache, prompt_tokens[:, t:t + 1])
    out = [prompt_tokens]
    key = jax.random.key(seed)
    tok = None
    for s in range(steps):
        if temperature > 0:
            key, k = jax.random.split(key)
            tok = jax.random.categorical(k, logits[:, -1] / temperature)[:, None]
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out.append(tok.astype(jnp.int32))
        logits, cache = step(cache, tok.astype(jnp.int32))
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    from repro.launch.train import reduced_config

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    load_all()
    cfg = reduced_config(get_config(args.arch))
    params = dlm.init_model(cfg, 0)
    prompt = jax.random.randint(jax.random.key(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    t0 = time.time()
    seqs = generate(params, cfg, prompt, args.steps,
                    temperature=args.temperature)
    dt = time.time() - t0
    print(f"arch={cfg.name} generated {seqs.shape} in {dt:.1f}s "
          f"({args.batch * args.steps / dt:.1f} tok/s)")
    print(np.asarray(seqs[0]))


if __name__ == "__main__":
    main()
