"""Fused DP clip-and-accumulate — Pallas TPU kernel.

The DP-FedAvg / DP-FTRL hot-spot: for every client update Δ_i (flattened
trainable vector, up to ~10^8 elements), compute ‖Δ_i‖₂, scale by
min(1, C/‖Δ_i‖), and accumulate into the aggregation buffer. Done naively
this is 3 HBM sweeps (square-reduce, scale, add); the kernel pair fuses
it into 2: a block-tiled sum-of-squares reduction, then a single
read-modify-write pass `acc += x * scale` with the scalar prefetched to
SMEM. The norm reduction accumulates across the 1-D block grid in an
SMEM scratch cell (TPU grid iterations are sequential, so scratch
accumulation is race-free).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 8 * 128 * 32  # 32768 f32 elements = 128 KiB per tile


def _sumsq_kernel(x_ref, o_ref, acc_ref):
    i = pl.program_id(0)
    n = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[0] = jnp.zeros((), jnp.float32)

    x = x_ref[...].astype(jnp.float32)
    acc_ref[0] = acc_ref[0] + jnp.sum(x * x)

    @pl.when(i == n - 1)
    def _out():
        o_ref[0] = acc_ref[0]


def _scale_add_kernel(scale_ref, x_ref, acc_ref, o_ref):
    # scale is a scalar-prefetch operand (SMEM)
    o_ref[...] = acc_ref[...] + x_ref[...].astype(jnp.float32) * scale_ref[0]


def _pad_to_block(x, block):
    n = x.shape[0]
    npad = (n + block - 1) // block * block - n
    if npad:
        x = jnp.pad(x, (0, npad))
    return x


def sumsq(x, block: int = BLOCK, interpret: bool = False):
    """Sum of squares of a 1-D vector via a grid-accumulated reduction."""
    xp = _pad_to_block(x, block)
    grid = (xp.shape[0] // block,)
    out = pl.pallas_call(
        _sumsq_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec(memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        scratch_shapes=[pltpu.SMEM((1,), jnp.float32)],
        interpret=interpret,
    )(xp)
    return out[0]


def _scale_kernel(scale_ref, x_ref, o_ref):
    o_ref[...] = x_ref[...].astype(jnp.float32) * scale_ref[0]


def clip_flat(x, clip_norm: float, block: int = BLOCK,
              interpret: bool = False):
    """x * min(1, clip_norm/||x||) over a flat f32 vector — the round
    engine's per-client clip (no accumulate target). Returns
    (clipped (N,), pre-clip norm). Two fused HBM passes.
    """
    n = x.shape[0]
    nrm = jnp.sqrt(sumsq(x, block=block, interpret=interpret))
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(nrm, 1e-12))
    xp = _pad_to_block(x, block)
    grid = (xp.shape[0] // block,)
    out = pl.pallas_call(
        _scale_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((block,), lambda i, s: (i,))],
            out_specs=pl.BlockSpec((block,), lambda i, s: (i,)),
        ),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0],), jnp.float32),
        interpret=interpret,
    )(scale.reshape(1), xp)
    return out[:n], nrm


def clip_accumulate(acc, x, clip_norm: float, block: int = BLOCK,
                    interpret: bool = False):
    """acc += x * min(1, clip_norm/||x||). acc, x: (N,) f32.

    Returns (new_acc, norm). Two fused HBM passes instead of three.
    """
    n = x.shape[0]
    ss = sumsq(x, block=block, interpret=interpret)
    nrm = jnp.sqrt(ss)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(nrm, 1e-12))
    xp = _pad_to_block(x, block)
    ap = _pad_to_block(acc.astype(jnp.float32), block)
    grid = (xp.shape[0] // block,)
    out = pl.pallas_call(
        functools.partial(_scale_add_kernel),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((block,), lambda i, s: (i,)),
                      pl.BlockSpec((block,), lambda i, s: (i,))],
            out_specs=pl.BlockSpec((block,), lambda i, s: (i,)),
        ),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0],), jnp.float32),
        interpret=interpret,
    )(scale.reshape(1), xp, ap)
    return out[:n], nrm
