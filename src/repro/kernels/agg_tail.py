"""Fused one-sweep server aggregation tail: stats -> pack -> apply.

The server tail (quarantine screen / int8 fake-quantize / L2 clip fold /
weighted mean / DP Gaussian noise) used to be five separate sweeps over
the (K, size) client-delta buffer. This module runs it as at most three
reads plus one (size,) write:

1. **stats** — per-(row, block) max-abs and sum-of-squares in one read.
   The max-abs feeds the per-leaf quantization scales AND the row
   finiteness flag (a row's max-abs is NaN iff the row holds a NaN, +Inf
   iff its largest magnitude is Inf); the sum-of-squares reduces to the
   raw row norms the quarantine screen needs — bitwise identical to
   ``core.sanitize.screen_rows``'s separate norm sweep, which the fused
   route therefore deletes.
2. **pack** — one read producing int8 codes (4x fewer bytes for the
   apply read) plus the quantized row sum-of-squares the clip stage
   folds into the aggregation weights.
3. **apply** — one read of the codes accumulating the weighted mean,
   with the pre-drawn (size,) DP noise vector as the accumulator's
   starting value, and one write of the update.

On TPU each stage is a Pallas kernel (grid over align-blocks, same
layout contract as kernels/quantize.py: leaves own whole blocks, so a
block never straddles leaves). On CPU each stage is a separately jitted
wrapper of the `kernels/ref.py` oracle, orchestrated from Python:
composing the stages into ONE XLA:CPU program costs +300-650ms at 10M
params x 16 clients (the fusion pass re-materializes producers across
stage boundaries), so the concrete-buffer path deliberately keeps the
stage boundaries at jit boundaries. Inside an outer trace (the round
engines under ``sim/grid.py``'s jit) the same composition is inlined
with the ref oracles.

Staged-vs-fused contract (test-enforced, see tests/test_kernels.py):

* plain / uniform / tier-masked means and quantize-only: **bitwise
  identical** to the staged ops on CPU — the apply runs as a
  column-chunked GEMV (chunking a GEMV along columns never reorders the
  K-axis accumulation) and the quantization scales come off an integer
  max, which no cross-program contraction can shift;
* clip fold and/or DP noise without quantization: within a couple of
  ulps on the concrete stage-jit path (XLA:CPU contracts the fold's
  multiply-adds differently across program boundaries); under an outer
  trace both paths inline into ONE program and stay bitwise — which is
  what the jitted round engines run;
* quantize + clip and/or noise: within fp round-off — the clip weights
  come from the quantized sum-of-squares fold (one int8 read instead of
  an f32 norm sweep) and the apply folds scale x clip x weight /
  denominator into one per-(row, block) coefficient.

Non-finite rows are excluded *inside* the sweep: their aggregation
weight is zeroed by the screen, and an int8 code of a NaN element is
finite garbage, so `0 * garbage` contributes exact zero — quarantine
without a dedicated zeroing sweep. (With the screen disabled entirely,
the fused quantized route assumes finite data; the unquantized routes
propagate NaN exactly like the staged ops.) The DP fixed denominator is
untouched: a quarantined row contributes the same zero as a padding
row, so sigma calibration and the epsilon ledger stay valid.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ref

BLOCK = 1024  # one f32 (8, 128) TPU tile; must equal the layout's align


# ---------------------------------------------------------------------------
# Pallas TPU kernels. Grid over align-blocks, one (K, block) tile per step;
# the sequential TPU grid makes SMEM scratch accumulation race-free (same
# trick as quantize.py / dp_clip.py).


def _stats_kernel(x_ref, bmax_ref, bsumsq_ref):
    x = x_ref[...].astype(jnp.float32)
    bmax_ref[...] = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    bsumsq_ref[...] = jnp.sum(x * x, axis=-1, keepdims=True)


def _pack_kernel(x_ref, s_ref, q_ref, qss_ref, acc_ref, *, qmax: float):
    i = pl.program_id(0)
    n = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    s = s_ref[...]                                       # (K, 1)
    q = jnp.clip(jnp.round(x / s), -qmax, qmax)
    q_ref[...] = q.astype(jnp.int8)[:, None]
    acc_ref[...] += jnp.sum(q * q, axis=-1) * (s[:, 0] * s[:, 0])

    @pl.when(i == n - 1)
    def _out():
        qss_ref[...] = acc_ref[...]


def _apply_kernel(q_ref, a_ref, noise_ref, o_ref):
    qf = q_ref[...][:, 0].astype(jnp.float32)            # (K, block)
    o_ref[...] = noise_ref[...] + jnp.sum(qf * a_ref[...], axis=0)


def block_stats(mat, block: int = BLOCK, interpret: bool = False):
    """(K, N) -> per-(row, block) (max-abs, sumsq), one HBM read."""
    K, N = mat.shape
    nb = N // block
    return pl.pallas_call(
        _stats_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((K, block), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((K, 1), lambda i: (0, i)),
                   pl.BlockSpec((K, 1), lambda i: (0, i))],
        out_shape=[jax.ShapeDtypeStruct((K, nb), jnp.float32),
                   jax.ShapeDtypeStruct((K, nb), jnp.float32)],
        interpret=interpret,
    )(mat)


def pack(mat, sblock, bits: int = 8, block: int = BLOCK,
         interpret: bool = False):
    """(K, N), (K, NB) scales -> ((K, NB, block) int8 codes, (K,)
    quantized row sumsq), one read + one int8 write."""
    qmax = 2.0 ** (bits - 1) - 1
    K, N = mat.shape
    nb = N // block
    q, qss = pl.pallas_call(
        functools.partial(_pack_kernel, qmax=qmax),
        grid=(nb,),
        in_specs=[pl.BlockSpec((K, block), lambda i: (0, i)),
                  pl.BlockSpec((K, 1), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((K, 1, block), lambda i: (0, i, 0)),
                   pl.BlockSpec(memory_space=pltpu.SMEM)],
        out_shape=[jax.ShapeDtypeStruct((K, nb, block), jnp.int8),
                   jax.ShapeDtypeStruct((K,), jnp.float32)],
        scratch_shapes=[pltpu.SMEM((K,), jnp.float32)],
        interpret=interpret,
    )(mat, sblock)
    return q, qss


def apply_coeff(q, coeff, noise, block: int = BLOCK,
                interpret: bool = False):
    """(K, NB, block) codes x (K, NB) coefficients -> (N,), starting the
    accumulator from ``noise`` — one codes read, one update write."""
    K, nb, _ = q.shape
    return pl.pallas_call(
        _apply_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((K, 1, block), lambda i: (0, i, 0)),
                  pl.BlockSpec((K, 1), lambda i: (0, i)),
                  pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb * block,), jnp.float32),
        interpret=interpret,
    )(q.reshape(K, nb, block), coeff, noise)


# ---------------------------------------------------------------------------
# CPU stage jits. One jit per stage — the stage boundaries ARE the
# performance model on XLA:CPU (see module docstring); the tiny (K,)-level
# glue between them runs eagerly at negligible cost.

_stats_j = jax.jit(ref.agg_block_stats_ref,
                   static_argnames=("block", "with_sumsq", "row_chunks"))
_rss_j = jax.jit(ref.row_sumsq_ref, static_argnames=("chunk",))
_scales_j = jax.jit(ref.agg_scales_ref, static_argnames=("bits", "n_leaves"))
_pack_j = jax.jit(ref.agg_pack_ref, static_argnames=("bits", "block"))
_qss_j = jax.jit(ref.agg_quant_sumsq_ref)
_apply_j = jax.jit(ref.agg_apply_ref, static_argnames=("block",))
_apply_exact_j = jax.jit(ref.agg_apply_exact_ref, static_argnames=("cols",))
_noise_j = jax.jit(
    lambda rng, sigma, size: sigma * jax.random.normal(
        rng, (size,), jnp.float32),
    static_argnames=("size",))


class _Stages:
    """Stage implementations for one engine: 'ref' (inline, traceable),
    'jit' (concrete CPU, python-orchestrated stage jits), 'tpu'
    (Pallas kernels; scales/exact-apply stay jnp)."""

    def __init__(self, engine: str, interpret: bool = False):
        self.engine = engine
        self.interpret = interpret

    def stats(self, mat, block, with_sumsq):
        if self.engine == "tpu":
            bmax, bss = block_stats(mat, block=block,
                                    interpret=self.interpret)
            return bmax, (bss if with_sumsq else None)
        if self.engine == "jit":
            return _stats_j(mat, block=block, with_sumsq=with_sumsq)
        return ref.agg_block_stats_ref(mat, block=block,
                                       with_sumsq=with_sumsq)

    def row_sumsq(self, mat, block):
        if self.engine == "jit":
            return _rss_j(mat, chunk=block)
        return ref.row_sumsq_ref(mat, chunk=block)

    def scales(self, bmax, block_leaf, bits, n_leaves):
        if self.engine == "jit":
            return _scales_j(bmax, jnp.asarray(block_leaf, jnp.int32),
                             bits=bits, n_leaves=n_leaves)
        return ref.agg_scales_ref(bmax, block_leaf, bits, n_leaves)

    def pack(self, mat, sblock, bits, block, need_qss):
        if self.engine == "tpu":
            return pack(mat, sblock, bits=bits, block=block,
                        interpret=self.interpret)
        if self.engine == "jit":
            q = _pack_j(mat, sblock, bits=bits, block=block)
            return q, (_qss_j(q, sblock) if need_qss else None)
        q = ref.agg_pack_ref(mat, sblock, bits=bits, block=block)
        return q, (ref.agg_quant_sumsq_ref(q, sblock) if need_qss else None)

    def apply_coeff(self, q, coeff, noise, block):
        if self.engine == "tpu":
            nb = coeff.shape[1]
            nvec = (noise if noise is not None
                    else jnp.zeros((nb * block,), jnp.float32))
            return apply_coeff(q, coeff, nvec, block=block,
                               interpret=self.interpret)
        if self.engine == "jit":
            return _apply_j(q, coeff, noise, block=block)
        return ref.agg_apply_ref(q, coeff, noise=noise, block=block)

    def apply_exact(self, x3, w, sblock, wsum, block_den, noise):
        if self.engine == "jit":
            return _apply_exact_j(x3, w, sblock=sblock, wsum=wsum,
                                  block_den=block_den, noise=noise)
        return ref.agg_apply_exact_ref(x3, w, sblock=sblock, wsum=wsum,
                                       block_den=block_den, noise=noise)


def compose(mat, weights, *, block_leaf, n_leaves: int, align: int = BLOCK,
            bits: int = 0, clip_norm: float = 0.0, uniform: bool = False,
            wsum_fixed: Optional[float] = None, sigma: float = 0.0,
            rng=None, bmask=None, remask_rows: bool = False,
            block_denom: bool = False, screen=None, constrain_fn=None,
            engine: str = "ref", interpret: bool = False):
    """The fused tail, generic over both round engines.

    Stage order matches the staged ops exactly: screen -> uniform weight
    transform -> denominator -> row re-mask (async tiers) -> quantize ->
    clip fold -> mean (per-block denominator for sync tiers) -> output
    constraint -> noise. Returns ``(update, info)``; ``info`` carries the
    quarantine masks/norms (screen on), per-row post-quantize norms
    (clip on) and the route taken.
    """
    from repro.core import flat as flat_lib          # lazy: layering
    from repro.core import sanitize as sanitize_lib

    K, size = mat.shape
    nb = size // align
    stages = _Stages(engine, interpret=interpret)
    info = {}

    # ---- stats read: everything screen/quantize need, one sweep --------
    need_max = bits > 0 or screen is not None
    need_raw = screen is not None or (clip_norm > 0 and bits == 0)
    bmax = raw_norms = None
    if need_max:
        bmax, bsumsq = stages.stats(mat, align, with_sumsq=need_raw)
        if need_raw:
            raw_norms = jnp.sqrt(
                jnp.matmul(bsumsq, jnp.ones((nb,), jnp.float32)))
    elif need_raw:
        raw_norms = jnp.sqrt(stages.row_sumsq(mat, align))

    # ---- quarantine screen from the stats (no extra sweep) -------------
    q_mask = None
    if screen is not None:
        row_finite = jnp.all(jnp.isfinite(bmax), axis=-1)
        weights, q_mask, sinfo = sanitize_lib.screen_from_stats(
            raw_norms, row_finite, weights, screen)
        info.update(sinfo)

    # ---- aggregation weights and denominator ---------------------------
    w = (weights > 0).astype(weights.dtype) if uniform else weights
    if wsum_fixed is not None:
        wsum = jnp.asarray(float(wsum_fixed), jnp.float32)
    else:
        wsum = jnp.maximum(jnp.sum(w), 1e-12)

    # ---- quantize: scales from stats, then the pack read ---------------
    sblock = q8 = None
    if bits > 0:
        sblock = stages.scales(bmax, block_leaf, bits, n_leaves)
        if q_mask is not None:
            # a quarantined NaN/Inf row has NaN/Inf scales; its weight is
            # zero, but 0 * NaN would still poison the coefficient fold —
            # neutralize the scales (the row's codes are garbage either
            # way and contribute exact zero through the zero weight)
            sblock = jnp.where(q_mask[:, None], 1.0, sblock)
        q8, qss = stages.pack(mat, sblock, bits, align,
                              need_qss=clip_norm > 0)

    # ---- clip fold: per-row scale into the weights ---------------------
    if clip_norm > 0:
        norms = jnp.sqrt(qss) if bits > 0 else raw_norms
        if q_mask is not None:
            # staged zeroes quarantined rows before the norm pass; mask
            # here so a NaN/outlier norm can't poison the fold (the row's
            # weight is already zero either way)
            norms = jnp.where(q_mask, 0.0, norms)
        w = w * jnp.minimum(1.0, clip_norm / jnp.maximum(norms, 1e-12))
        info["update_norms"] = norms

    noise = None
    if sigma > 0:
        if engine == "jit":
            noise = _noise_j(rng, sigma, size)
        else:
            noise = flat_lib.draw_noise(rng, size, sigma)

    # ---- apply: route on what was folded -------------------------------
    # quantize+clip/noise -> per-(row, block) coefficient accumulation
    # (fp-round-off contract); everything else -> column-chunked GEMV,
    # bitwise identical to weighted_mean / block_masked_mean.
    if bits > 0 and (clip_norm > 0 or sigma > 0):
        coeff = (w / wsum)[:, None] * sblock
        fold_noise = noise if constrain_fn is None else None
        out = stages.apply_coeff(q8, coeff, fold_noise, align)
        if constrain_fn is not None:
            out = constrain_fn(out)
            if noise is not None:
                out = out + noise
        info["route"] = f"fused/{engine}/coeff"
    else:
        if bits > 0:
            x3 = q8        # dequantized in-register by the exact apply
        else:
            x = mat
            if q_mask is not None:
                # bits==0 reads raw f32: a quarantined NaN row must be
                # zeroed (NaN * 0 = NaN in the GEMV); finite outlier
                # rows would be fine on weight alone, but matching the
                # staged zeroing keeps the contract exact
                x = jnp.where(q_mask[:, None], 0.0, x)
            if remask_rows:
                x = (x.reshape(K, nb, align)
                     * bmask[:, :, None]).reshape(K, size)
            x3 = x.reshape(K, nb, align)
        block_den = None
        mean_wsum = wsum
        if block_denom:
            block_den = jnp.maximum(
                jnp.matmul(w.astype(jnp.float32), bmask), 1e-12)
            mean_wsum = None
        out = stages.apply_exact(x3, w, sblock, mean_wsum, block_den, None)
        if constrain_fn is not None:
            out = constrain_fn(out)
        if noise is not None:
            out = out + noise
        info["route"] = f"fused/{engine}/exact"
    return out, info
