"""Sliding-window flash attention — Pallas TPU kernel.

TPU-native adaptation of the serving hot-spot behind the `long_500k`
shape: a flash-attention kernel whose grid *structurally skips* KV blocks
outside the sliding window (rather than masking them to -inf and still
paying the matmul, as the pure-jnp path does). Block shapes are
MXU-aligned (128x128 score tiles), the online-softmax running state
(m, l, acc) lives in VMEM scratch and persists across the KV-block grid
dimension.

Grid: (batch*heads, num_q_blocks, num_kv_blocks), KV innermost. For a
window of W tokens, each q block touches at most ceil(W/bk)+1 kv blocks;
out-of-range blocks exit via pl.when without touching the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _swa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                bq: int, bk: int, window: int, causal: bool, seq: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    q_start = qi * bq
    k_start = ki * bk

    # --- structural skip: this kv block intersects the window? ----------
    # visible kv positions for q block [q_start, q_start+bq):
    #   k <= q_end-1 (causal)  and  k > q_start - window (sliding window)
    in_causal = (k_start <= q_start + bq - 1) if causal else True
    in_window = (k_start + bk - 1 > q_start - window) if window > 0 else True
    live = jnp.logical_and(in_causal, in_window)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(live)
    def _body():
        q = q_ref[0].astype(jnp.float32)           # (bq, d)
        k = k_ref[0].astype(jnp.float32)           # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        d = q.shape[-1]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * (1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32)))

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < seq
        if causal:
            mask = jnp.logical_and(mask, qpos >= kpos)
        if window > 0:
            mask = jnp.logical_and(mask, qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def swa_attention(q, k, v, window: int = 0, causal: bool = True,
                  bq: int = 128, bk: int = 128, interpret: bool = False):
    """q, k, v: (B, H, S, D) -> (B, H, S, D).

    D should be a multiple of 128 for MXU alignment (the wrapper in
    ops.py pads when it is not). S is padded to a bq/bk multiple.
    """
    B, H, S, D = q.shape
    bq = min(bq, S)
    bk = min(bk, S)
    Sp = ((S + max(bq, bk) - 1) // max(bq, bk)) * max(bq, bk)
    if Sp != S:
        pad = Sp - S
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    qf = q.reshape(B * H, Sp, D)
    kf = k.reshape(B * H, Sp, D)
    vf = v.reshape(B * H, Sp, D)
    grid = (B * H, Sp // bq, Sp // bk)

    kernel = functools.partial(_swa_kernel, bq=bq, bk=bk, window=window,
                               causal=causal, seq=S)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, bk, D), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, bk, D), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max m
            pltpu.VMEM((bq, 1), jnp.float32),   # running sum l
            pltpu.VMEM((bq, D), jnp.float32),   # accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sp, D)[:, :, :S, :]
