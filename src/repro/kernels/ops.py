"""Jitted public wrappers around the Pallas kernels.

On this CPU container the wrappers run with interpret=True (the kernel
body executes in Python under the Pallas interpreter); on TPU they lower
to Mosaic. `use_pallas` flags let the model code swap the pure-jnp path
for the kernel path at config time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import agg_tail as _agg
from repro.kernels import dp_clip as _dp
from repro.kernels import quantize as _q
from repro.kernels import ref as _ref
from repro.kernels import seed_reconstruct as _sr
from repro.kernels import swa_attention as _swa

_ON_TPU = jax.default_backend() == "tpu"
_INTERPRET = not _ON_TPU

# agg_tail dispatcher: the fused stats/pack/apply path engages by
# default only when BOTH hold —
#   * quantization is on (bits > 0): that is where the staged tail
#     pays >= 4 sweeps (maxabs, Q->DQ write, norm, mean) and the fused
#     int8 pack/apply collapses them. The unquantized pipelines are
#     already minimal-sweep (mean: one GEMV; clip: norm + GEMV), so
#     the fused stage orchestration is pure overhead there (measured
#     0.1-0.9x on concrete CPU buffers);
#   * the buffer has at least this many elements (K * size): below it
#     the orchestration's fixed cost loses to one well-fused XLA
#     program even with quantization on. 4M elements puts the bench's
#     300k-param smoke shapes on the staged side and every
#     >= 1M x 8-client quantized cell on the fused side.
# An EXPLICIT threshold routes purely by size (0 forces fused, a huge
# value forces staged) — that is the test/bench override knob.
AGG_FUSE_THRESHOLD = 4 << 20


@functools.partial(jax.jit, static_argnames=("window", "causal", "bq", "bk"))
def swa_attention(q, k, v, window: int = 0, causal: bool = True,
                  bq: int = 128, bk: int = 128):
    """(B, H, S, D) sliding-window flash attention (see swa_attention.py)."""
    return _swa.swa_attention(q, k, v, window=window, causal=causal,
                              bq=bq, bk=bk, interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("clip_norm",))
def clip_accumulate(acc, x, clip_norm: float):
    """Fused DP clip-and-accumulate over flat f32 vectors."""
    return _dp.clip_accumulate(acc, x, clip_norm, interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("clip_norm",))
def flat_clip(x, clip_norm: float):
    """Per-vector L2 clip over a flat f32 delta: (clipped, pre-clip
    norm). Fused two-pass kernel on TPU, reshaped pure-jnp elsewhere."""
    if _ON_TPU:
        return _dp.clip_flat(x, clip_norm)
    return _ref.flat_clip_ref(x, clip_norm)


@functools.partial(jax.jit, static_argnames=("n_leaves", "bits", "block"))
def fake_quantize_flat(x, block_leaf, n_leaves: int = 0, bits: int = 8,
                       block: int = _q.BLOCK):
    """Fused per-leaf int8 fake-quantize of a block-aligned flat delta
    (see quantize.py). Kernel on TPU, segment-reduction ref elsewhere."""
    if _ON_TPU:
        return _q.fake_quantize_flat(x, block_leaf, n_leaves, bits=bits,
                                     block=block)
    return _ref.fake_quantize_flat_ref(x, block_leaf, bits=bits, block=block,
                                       n_leaves=n_leaves)


@functools.partial(jax.jit, static_argnames=("leaf_id", "shape", "stddev",
                                             "dtype"))
def seed_reconstruct(seed, leaf_id: int, shape, stddev: float,
                     dtype=jnp.float32):
    """Deterministic on-chip Gaussian tensor from (seed, leaf_id)."""
    return _sr.seed_reconstruct(seed, leaf_id, shape, stddev, dtype=dtype,
                                interpret=_INTERPRET)


# ---------------------------------------------------------------------------
# Fused server aggregation tail (kernels/agg_tail.py) behind a
# shape-aware dispatcher.


def _fake_quantize(mat, block_leaf, n_leaves, bits, align):
    # same dispatch as core.flat.fake_quantize, without needing a layout
    if _ON_TPU and bits == 8:
        return jax.lax.map(
            lambda row: fake_quantize_flat(row, block_leaf, n_leaves,
                                           block=align), mat)
    return _ref.fake_quantize_flat_ref(mat, block_leaf, bits=bits,
                                       block=align, n_leaves=n_leaves)


def _staged_tail(mat, weights, block_leaf, bmask, rng, *, n_leaves,
                 align, bits, clip_norm, uniform, wsum_fixed, sigma,
                 block_denom, remask_rows, screen, constrain_fn=None):
    """The historical op-by-op tail — what the round engines ran before
    the fused path existed, verbatim. Small shapes dispatch here (and it
    is the bit-exactness oracle the fused contract is tested against)."""
    from repro.core import flat as flat_lib       # lazy: layering
    from repro.core import sanitize as sanitize_lib

    # no "route" key here: this function runs under jit, and jit outputs
    # must be arrays — agg_tail stamps the route after the call
    info = {}
    if screen is not None:
        mat, weights, sinfo = sanitize_lib.screen_rows(
            mat, weights, screen, align)
        info.update(sinfo)
    w = (weights > 0).astype(weights.dtype) if uniform else weights
    if wsum_fixed is not None:
        wsum = jnp.asarray(float(wsum_fixed), jnp.float32)
    else:
        wsum = jnp.maximum(jnp.sum(w), 1e-12)
    if remask_rows:
        K = mat.shape[0]
        mat = (mat.reshape(K, -1, align) * bmask[:, :, None]).reshape(K, -1)
    if bits > 0:
        mat = _fake_quantize(mat, block_leaf, n_leaves, bits, align)
    if clip_norm > 0:
        norms = jnp.sqrt(_ref.row_sumsq_ref(mat, chunk=align))
        w = w * jnp.minimum(1.0, clip_norm / jnp.maximum(norms, 1e-12))
        info["update_norms"] = norms
    if block_denom:
        out = flat_lib.block_masked_mean(mat, w, bmask, align)
    else:
        out = flat_lib.weighted_mean(mat, w, wsum)
    if constrain_fn is not None:
        out = constrain_fn(out)
    if sigma > 0:
        out = flat_lib.add_noise(out, sigma, rng)
    return out, info


_staged_tail_jit = jax.jit(
    _staged_tail,
    static_argnames=("n_leaves", "align", "bits", "clip_norm", "uniform",
                     "wsum_fixed", "sigma", "block_denom", "remask_rows",
                     "screen"))


def agg_tail(mat, weights, *, block_leaf, n_leaves: int, align: int = 1024,
             bits: int = 0, clip_norm: float = 0.0, uniform: bool = False,
             wsum_fixed=None, sigma: float = 0.0, rng=None, bmask=None,
             remask_rows: bool = False, block_denom: bool = False,
             screen=None, constrain_fn=None, threshold=None):
    """One-sweep server aggregation tail with shape-aware dispatch.

    Computes the full post-training server pipeline over the (K, size)
    flat delta buffer — quarantine screen, per-leaf int-``bits``
    fake-quantize, per-row L2 clip folded into the weights, weighted /
    fixed-denominator mean (per-block denominator for trainability
    tiers), output sharding constraint, DP Gaussian noise — and returns
    ``(update, info)`` with the quarantine masks / norms the round
    engines report as metrics plus the dispatch ``route`` taken.

    Dispatch (shape- AND pipeline-aware): by default the fused
    stats/pack/apply path of ``kernels/agg_tail.py`` engages only for
    quantized pipelines (``bits > 0`` — where the staged tail pays its
    >= 4 sweeps) on buffers of at least :data:`AGG_FUSE_THRESHOLD`
    elements; everything else runs the staged op sequence,
    bit-identical to the historical tail. The fused path is Pallas
    kernels on TPU, python-orchestrated stage jits on concrete CPU
    buffers, the inlined ref composition under an outer trace. An
    explicit ``threshold`` routes purely by size: ``0`` forces fused,
    ``threshold > K*size`` forces staged.
    """
    kw = dict(n_leaves=n_leaves, align=align, bits=bits,
              clip_norm=clip_norm, uniform=uniform, wsum_fixed=wsum_fixed,
              sigma=sigma, block_denom=block_denom,
              remask_rows=remask_rows, screen=screen)
    K, size = mat.shape
    traced = isinstance(mat, jax.core.Tracer)
    if threshold is None:
        fuse = bits > 0 and K * size >= AGG_FUSE_THRESHOLD
    else:
        fuse = K * size >= threshold
    if not fuse:
        if traced or constrain_fn is not None:
            out, info = _staged_tail(mat, weights, block_leaf, bmask, rng,
                                     constrain_fn=constrain_fn, **kw)
        else:
            out, info = _staged_tail_jit(mat, weights,
                                         jnp.asarray(block_leaf, jnp.int32),
                                         bmask, rng, **kw)
        info["route"] = "staged"
        return out, info
    if _ON_TPU:
        engine = "tpu"
    elif traced:
        engine = "ref"
    else:
        engine = "jit"
    return _agg.compose(mat, weights, block_leaf=block_leaf, rng=rng,
                        bmask=bmask, constrain_fn=constrain_fn,
                        engine=engine, **kw)
