"""Jitted public wrappers around the Pallas kernels.

On this CPU container the wrappers run with interpret=True (the kernel
body executes in Python under the Pallas interpreter); on TPU they lower
to Mosaic. `use_pallas` flags let the model code swap the pure-jnp path
for the kernel path at config time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import dp_clip as _dp
from repro.kernels import quantize as _q
from repro.kernels import ref as _ref
from repro.kernels import seed_reconstruct as _sr
from repro.kernels import swa_attention as _swa

_ON_TPU = jax.default_backend() == "tpu"
_INTERPRET = not _ON_TPU


@functools.partial(jax.jit, static_argnames=("window", "causal", "bq", "bk"))
def swa_attention(q, k, v, window: int = 0, causal: bool = True,
                  bq: int = 128, bk: int = 128):
    """(B, H, S, D) sliding-window flash attention (see swa_attention.py)."""
    return _swa.swa_attention(q, k, v, window=window, causal=causal,
                              bq=bq, bk=bk, interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("clip_norm",))
def clip_accumulate(acc, x, clip_norm: float):
    """Fused DP clip-and-accumulate over flat f32 vectors."""
    return _dp.clip_accumulate(acc, x, clip_norm, interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("clip_norm",))
def flat_clip(x, clip_norm: float):
    """Per-vector L2 clip over a flat f32 delta: (clipped, pre-clip
    norm). Fused two-pass kernel on TPU, reshaped pure-jnp elsewhere."""
    if _ON_TPU:
        return _dp.clip_flat(x, clip_norm)
    return _ref.flat_clip_ref(x, clip_norm)


@functools.partial(jax.jit, static_argnames=("n_leaves", "bits", "block"))
def fake_quantize_flat(x, block_leaf, n_leaves: int = 0, bits: int = 8,
                       block: int = _q.BLOCK):
    """Fused per-leaf int8 fake-quantize of a block-aligned flat delta
    (see quantize.py). Kernel on TPU, segment-reduction ref elsewhere."""
    if _ON_TPU:
        return _q.fake_quantize_flat(x, block_leaf, n_leaves, bits=bits,
                                     block=block)
    return _ref.fake_quantize_flat_ref(x, block_leaf, bits=bits, block=block,
                                       n_leaves=n_leaves)


@functools.partial(jax.jit, static_argnames=("leaf_id", "shape", "stddev",
                                             "dtype"))
def seed_reconstruct(seed, leaf_id: int, shape, stddev: float,
                     dtype=jnp.float32):
    """Deterministic on-chip Gaussian tensor from (seed, leaf_id)."""
    return _sr.seed_reconstruct(seed, leaf_id, shape, stddev, dtype=dtype,
                                interpret=_INTERPRET)
