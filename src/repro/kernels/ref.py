"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references: each kernel's test sweeps shapes /
dtypes and asserts allclose (or, for the PRNG kernel, distributional and
determinism properties) against these functions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def swa_attention_ref(q, k, v, window: int, causal: bool = True):
    """Dense sliding-window attention oracle.

    q, k, v: (B, H, S, D). window: number of past positions visible
    (window <= 0 means full causal attention). Returns (B, H, S, D) f32.
    """
    B, H, S, D = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask = qpos >= kpos
    if window > 0:
        mask = mask & (qpos - kpos < window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))


def dp_clip_accumulate_ref(acc, x, clip_norm: float):
    """Oracle for the fused clip-and-accumulate: acc + x * min(1, C/||x||).

    acc, x: (N,) float32. Returns (new_acc (N,), norm scalar).
    """
    nrm = jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32))))
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(nrm, 1e-12))
    return acc + x.astype(jnp.float32) * scale, nrm


# ---------------------------------------------------------------------------
# Flat-buffer aggregation fallbacks (core/flat.py dispatches here off-TPU).
#
# Every reduction is expressed over a (rows, chunk) block view instead of
# a (C, N) row sweep: XLA:CPU lowers the former to a vectorized loop and
# the latter to a scalar one (~20x slower at N=10^7), and the block view
# is also exactly the layout the TPU kernels tile.


def _chunked(x, chunk: int):
    """(..., N) -> (..., N//chunk, chunk); N must divide (FlatLayout
    aligns it) — falls back to one chunk otherwise."""
    n = x.shape[-1]
    if chunk <= 1 or n == 0 or n % chunk:
        return x.reshape(x.shape[:-1] + (1, n))
    return x.reshape(x.shape[:-1] + (n // chunk, chunk))


# rows are processed in a few large independent slices: at >=10^7
# elements per operand XLA:CPU schedules the slices measurably better
# than one monolithic cascade (and it bounds intermediate live range)
_ROW_CHUNKS = 4
_CHUNK_MIN = 1 << 20


def _rowwise(x3, one_chunk):
    """Apply `one_chunk` ((rows, width) -> (rows,)) over the trailing
    axis of (..., width), slicing the flattened row dim into a few
    large independent chunks."""
    width = x3.shape[-1]
    rows = x3.reshape(-1, width)
    n = rows.shape[0]
    if n * width <= _CHUNK_MIN or n < _ROW_CHUNKS:
        return one_chunk(rows).reshape(x3.shape[:-1])
    step = -(-n // _ROW_CHUNKS)
    parts = [one_chunk(rows[i:i + step]) for i in range(0, n, step)]
    return jnp.concatenate(parts).reshape(x3.shape[:-1])


def _sumsq_chunk(rows):
    """sum(x^2) over each row, by log-halving: pairwise elementwise adds
    stream at memory bandwidth, where XLA:CPU's reduce op runs a ~5x
    slower scalar loop at these shapes. The first halving fuses the
    squaring (and any int8->f32 cast)."""
    h = rows.shape[-1] // 2
    if rows.shape[-1] % 2 or h == 0:
        rows = rows.astype(jnp.float32)
        return jnp.sum(rows * rows, axis=-1)
    a = rows[..., :h].astype(jnp.float32)
    b = rows[..., h:].astype(jnp.float32)
    y = a * a + b * b
    while y.shape[-1] > 1 and y.shape[-1] % 2 == 0:
        h = y.shape[-1] // 2
        y = y[..., :h] + y[..., h:]
    return jnp.sum(y, axis=-1)


def _maxabs_chunk(rows):
    """max|x| over each row, same log-halving trick."""
    h = rows.shape[-1] // 2
    if rows.shape[-1] % 2 or h == 0:
        return jnp.max(jnp.abs(rows), axis=-1)
    y = jnp.maximum(jnp.abs(rows[..., :h]), jnp.abs(rows[..., h:]))
    while y.shape[-1] > 1 and y.shape[-1] % 2 == 0:
        h = y.shape[-1] // 2
        y = jnp.maximum(y[..., :h], y[..., h:])
    return jnp.max(y, axis=-1)


def _last_axis_sumsq(x3):
    return _rowwise(x3, _sumsq_chunk)


def _last_axis_maxabs(x3):
    return _rowwise(x3, _maxabs_chunk)


def flat_sumsq_ref(x, chunk: int = 1024):
    """Sum of squares of a 1-D flat vector via a two-stage reduction."""
    return jnp.sum(_last_axis_sumsq(_chunked(x.astype(jnp.float32), chunk)))


def row_sumsq_ref(mat, chunk: int = 1024):
    """(C, N) -> (C,) per-row sum of squares, one fused pass."""
    part = _last_axis_sumsq(_chunked(mat.astype(jnp.float32), chunk))
    return jnp.matmul(part, jnp.ones((part.shape[-1],), jnp.float32))


def flat_clip_ref(x, clip_norm: float, chunk: int = 1024):
    """Oracle for the flat per-vector clip: x * min(1, C/||x||).
    Returns (clipped, pre-clip norm)."""
    nrm = jnp.sqrt(flat_sumsq_ref(x, chunk))
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(nrm, 1e-12))
    return x.astype(jnp.float32) * scale, nrm


def fake_quantize_flat_ref(mat, block_leaf, bits: int = 8,
                           block: int = 1024, n_leaves: int = 0):
    """Per-leaf symmetric int-k fake-quantize over a block-aligned flat
    buffer. ``mat``: (..., N) with N = len(block_leaf) * block; each
    block belongs to one leaf (block_leaf: (K,) int). Matches
    `compress.quantize_leaf` + `dequantize_leaf` exactly: scale is the
    leaf max-abs / qmax (zero padding never raises a max).

    ``n_leaves`` must be passed when ``block_leaf`` is a traced value
    (e.g. through a jitted wrapper); with a concrete map it is derived.
    """
    qmax = 2.0 ** (bits - 1) - 1
    if not n_leaves:
        n_leaves = int(np.max(np.asarray(block_leaf))) + 1 \
            if len(block_leaf) else 0
    block_leaf = jnp.asarray(block_leaf, jnp.int32)
    xc = _chunked(mat.astype(jnp.float32), block)      # (..., K, block)
    bmax = _last_axis_maxabs(xc)                       # (..., K)
    lmax = jax.ops.segment_max(jnp.moveaxis(bmax, -1, 0), block_leaf,
                               num_segments=n_leaves)  # (L, ...)
    scales = jnp.maximum(jnp.moveaxis(lmax, 0, -1), 1e-12) / qmax
    sblock = jnp.take(scales, block_leaf, axis=-1)     # (..., K)
    q = jnp.clip(jnp.round(xc / sblock[..., None]), -qmax, qmax)
    return (q * sblock[..., None]).reshape(mat.shape)


def seed_reconstruct_ref(seed: int, shape, stddev: float):
    """Distributional reference for the TPU-PRNG Gaussian generator.

    NOT bit-identical to the Pallas kernel (different PRNG); used for
    moment / independence checks. Determinism of the kernel itself is
    asserted kernel-vs-kernel.
    """
    return stddev * jax.random.normal(jax.random.key(seed), shape,
                                      jnp.float32)
