"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references: each kernel's test sweeps shapes /
dtypes and asserts allclose (or, for the PRNG kernel, distributional and
determinism properties) against these functions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def swa_attention_ref(q, k, v, window: int, causal: bool = True):
    """Dense sliding-window attention oracle.

    q, k, v: (B, H, S, D). window: number of past positions visible
    (window <= 0 means full causal attention). Returns (B, H, S, D) f32.
    """
    B, H, S, D = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask = qpos >= kpos
    if window > 0:
        mask = mask & (qpos - kpos < window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))


def dp_clip_accumulate_ref(acc, x, clip_norm: float):
    """Oracle for the fused clip-and-accumulate: acc + x * min(1, C/||x||).

    acc, x: (N,) float32. Returns (new_acc (N,), norm scalar).
    """
    nrm = jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32))))
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(nrm, 1e-12))
    return acc + x.astype(jnp.float32) * scale, nrm


def seed_reconstruct_ref(seed: int, shape, stddev: float):
    """Distributional reference for the TPU-PRNG Gaussian generator.

    NOT bit-identical to the Pallas kernel (different PRNG); used for
    moment / independence checks. Determinism of the kernel itself is
    asserted kernel-vs-kernel.
    """
    return stddev * jax.random.normal(jax.random.key(seed), shape,
                                      jnp.float32)
