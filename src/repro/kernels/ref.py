"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references: each kernel's test sweeps shapes /
dtypes and asserts allclose (or, for the PRNG kernel, distributional and
determinism properties) against these functions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def swa_attention_ref(q, k, v, window: int, causal: bool = True):
    """Dense sliding-window attention oracle.

    q, k, v: (B, H, S, D). window: number of past positions visible
    (window <= 0 means full causal attention). Returns (B, H, S, D) f32.
    """
    B, H, S, D = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask = qpos >= kpos
    if window > 0:
        mask = mask & (qpos - kpos < window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))


def dp_clip_accumulate_ref(acc, x, clip_norm: float):
    """Oracle for the fused clip-and-accumulate: acc + x * min(1, C/||x||).

    acc, x: (N,) float32. Returns (new_acc (N,), norm scalar).
    """
    nrm = jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32))))
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(nrm, 1e-12))
    return acc + x.astype(jnp.float32) * scale, nrm


# ---------------------------------------------------------------------------
# Flat-buffer aggregation fallbacks (core/flat.py dispatches here off-TPU).
#
# Every reduction is expressed over a (rows, chunk) block view instead of
# a (C, N) row sweep: XLA:CPU lowers the former to a vectorized loop and
# the latter to a scalar one (~20x slower at N=10^7), and the block view
# is also exactly the layout the TPU kernels tile.


def _chunked(x, chunk: int):
    """(..., N) -> (..., N//chunk, chunk); N must divide (FlatLayout
    aligns it) — falls back to one chunk otherwise."""
    n = x.shape[-1]
    if chunk <= 1 or n == 0 or n % chunk:
        return x.reshape(x.shape[:-1] + (1, n))
    return x.reshape(x.shape[:-1] + (n // chunk, chunk))


# rows are processed in a few large independent slices: at >=10^7
# elements per operand XLA:CPU schedules the slices measurably better
# than one monolithic cascade (and it bounds intermediate live range)
_ROW_CHUNKS = 4
_CHUNK_MIN = 1 << 20


def _rowwise(x3, one_chunk, nchunks: int = _ROW_CHUNKS):
    """Apply `one_chunk` ((rows, width) -> (rows,)) over the trailing
    axis of (..., width), slicing the flattened row dim into a few
    large independent chunks. The chunk count never changes the result
    (each row reduces independently); it only bounds the live range of
    the halving cascade's intermediates."""
    width = x3.shape[-1]
    rows = x3.reshape(-1, width)
    n = rows.shape[0]
    if n * width <= _CHUNK_MIN or n < nchunks:
        return one_chunk(rows).reshape(x3.shape[:-1])
    step = -(-n // nchunks)
    parts = [one_chunk(rows[i:i + step]) for i in range(0, n, step)]
    return jnp.concatenate(parts).reshape(x3.shape[:-1])


def _sumsq_chunk(rows):
    """sum(x^2) over each row, by log-halving: pairwise elementwise adds
    stream at memory bandwidth, where XLA:CPU's reduce op runs a ~5x
    slower scalar loop at these shapes. The first halving fuses the
    squaring (and any int8->f32 cast)."""
    h = rows.shape[-1] // 2
    if rows.shape[-1] % 2 or h == 0:
        rows = rows.astype(jnp.float32)
        return jnp.sum(rows * rows, axis=-1)
    a = rows[..., :h].astype(jnp.float32)
    b = rows[..., h:].astype(jnp.float32)
    y = a * a + b * b
    while y.shape[-1] > 1 and y.shape[-1] % 2 == 0:
        h = y.shape[-1] // 2
        y = y[..., :h] + y[..., h:]
    return jnp.sum(y, axis=-1)


_ABS_MASK_I32 = np.int32(0x7FFFFFFF)


def _maxabs_chunk(rows):
    """max|x| over each row, same log-halving trick.

    f32 rows run on the bitcast int32 view: clearing the sign bit of an
    IEEE f32 gives a pattern that orders exactly like |x| for finite
    values, and every NaN payload orders above +Inf, so integer max IS
    max|x| with NaN propagation intact (possibly a different NaN
    payload, never a lost NaN). XLA:CPU's integer max streams ~1.4x
    faster than the float cascade (no NaN-ordering blend per element).
    """
    h = rows.shape[-1] // 2
    if rows.shape[-1] % 2 or h == 0:
        return jnp.max(jnp.abs(rows), axis=-1)
    if rows.dtype == jnp.float32:
        z = jax.lax.bitcast_convert_type(rows, jnp.int32) & _ABS_MASK_I32
        while z.shape[-1] > 1 and z.shape[-1] % 2 == 0:
            h = z.shape[-1] // 2
            z = jnp.maximum(z[..., :h], z[..., h:])
        return jax.lax.bitcast_convert_type(jnp.max(z, axis=-1), jnp.float32)
    y = jnp.maximum(jnp.abs(rows[..., :h]), jnp.abs(rows[..., h:]))
    while y.shape[-1] > 1 and y.shape[-1] % 2 == 0:
        h = y.shape[-1] // 2
        y = jnp.maximum(y[..., :h], y[..., h:])
    return jnp.max(y, axis=-1)


def _last_axis_sumsq(x3):
    return _rowwise(x3, _sumsq_chunk)


def _last_axis_maxabs(x3):
    return _rowwise(x3, _maxabs_chunk)


def flat_sumsq_ref(x, chunk: int = 1024):
    """Sum of squares of a 1-D flat vector via a two-stage reduction."""
    return jnp.sum(_last_axis_sumsq(_chunked(x.astype(jnp.float32), chunk)))


def row_sumsq_ref(mat, chunk: int = 1024):
    """(C, N) -> (C,) per-row sum of squares, one fused pass."""
    part = _last_axis_sumsq(_chunked(mat.astype(jnp.float32), chunk))
    return jnp.matmul(part, jnp.ones((part.shape[-1],), jnp.float32))


def flat_clip_ref(x, clip_norm: float, chunk: int = 1024):
    """Oracle for the flat per-vector clip: x * min(1, C/||x||).
    Returns (clipped, pre-clip norm)."""
    nrm = jnp.sqrt(flat_sumsq_ref(x, chunk))
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(nrm, 1e-12))
    return x.astype(jnp.float32) * scale, nrm


def fake_quantize_flat_ref(mat, block_leaf, bits: int = 8,
                           block: int = 1024, n_leaves: int = 0):
    """Per-leaf symmetric int-k fake-quantize over a block-aligned flat
    buffer. ``mat``: (..., N) with N = len(block_leaf) * block; each
    block belongs to one leaf (block_leaf: (K,) int). Matches
    `compress.quantize_leaf` + `dequantize_leaf` exactly: scale is the
    leaf max-abs / qmax (zero padding never raises a max).

    ``n_leaves`` must be passed when ``block_leaf`` is a traced value
    (e.g. through a jitted wrapper); with a concrete map it is derived.
    """
    qmax = 2.0 ** (bits - 1) - 1
    if not n_leaves:
        n_leaves = int(np.max(np.asarray(block_leaf))) + 1 \
            if len(block_leaf) else 0
    block_leaf = jnp.asarray(block_leaf, jnp.int32)
    xc = _chunked(mat.astype(jnp.float32), block)      # (..., K, block)
    bmax = _last_axis_maxabs(xc)                       # (..., K)
    lmax = jax.ops.segment_max(jnp.moveaxis(bmax, -1, 0), block_leaf,
                               num_segments=n_leaves)  # (L, ...)
    scales = jnp.maximum(jnp.moveaxis(lmax, 0, -1), 1e-12) / qmax
    sblock = jnp.take(scales, block_leaf, axis=-1)     # (..., K)
    q = jnp.clip(jnp.round(xc / sblock[..., None]), -qmax, qmax)
    return (q * sblock[..., None]).reshape(mat.shape)


# ---------------------------------------------------------------------------
# Fused aggregation-tail stages (kernels/agg_tail.py's oracles).
#
# The server tail (screen / quantize / clip / mean / noise) fuses into at
# most three reads of the (K, size) buffer plus one (size,) write:
#
#   stats: one f32 read  -> per-(row, block) max-abs (+ sum-of-squares,
#          when the quarantine screen needs raw row norms);
#   pack:  one f32 read  -> int8 codes, plus the quantized row sumsq the
#          clip stage folds into the aggregation weights;
#   apply: one int8 (or f32) read -> weighted mean over rows, pre-drawn
#          DP noise added in the same pass, one (size,) write.
#
# Each stage is deliberately a SEPARATE function: composing them into one
# XLA:CPU program costs +300-650ms at 10M params x 16 clients (the fusion
# pass re-materializes producers across stage boundaries), so
# kernels/agg_tail.py jits the stages individually and orchestrates them
# from Python when handed concrete buffers.
#
# `apply` has two formulations with different contracts:
#   * agg_apply_exact_ref — a column-chunked GEMV, bitwise identical to
#     weighted_mean / block_masked_mean on the same operand (chunking a
#     GEMV along columns never reorders the K-axis accumulation);
#   * agg_apply_ref — a row-at-a-time accumulation starting from the
#     noise vector, ~2x faster from int8 codes but only fp-round-off
#     close to the GEMV. The dispatcher uses it exactly where the
#     staged-vs-fused contract is already fp-level (quantize + clip/DP).

# the stats/pack sweeps prefer finer row slices than the default
# _ROW_CHUNKS=4: at (K * num_blocks, 1024) granularity, ~L2-sized slices
# keep the halving cascade's intermediates cache-resident (~25% faster
# at 10M x 16 than 4 slices)
_STATS_ROW_CHUNKS = 128


def agg_block_stats_ref(mat, block: int = 1024, with_sumsq: bool = False,
                        row_chunks: int = _STATS_ROW_CHUNKS):
    """(K, N) -> per-(row, block) max-abs, optionally with per-(row,
    block) sum-of-squares, in one read of the buffer.

    ``bmax`` feeds the per-leaf quantization scales (segment-max over the
    block->leaf map) and the row-finiteness flag (a row's max-abs is NaN
    iff the row has a NaN, +Inf iff its largest magnitude is Inf).
    ``bsumsq @ ones`` equals ``row_sumsq_ref`` bitwise — per-block sums
    are row-local, so the fused screen's raw norms match
    ``core.sanitize.screen_rows``'s separate sweep exactly on finite
    rows."""
    x3 = _chunked(mat.astype(jnp.float32), block)
    bmax = _rowwise(x3, _maxabs_chunk, nchunks=row_chunks)
    if not with_sumsq:
        return bmax, None
    bsumsq = _rowwise(x3, _sumsq_chunk, nchunks=row_chunks)
    return bmax, bsumsq


def agg_scales_ref(bmax, block_leaf, bits: int, n_leaves: int):
    """Per-(row, block) quantization scales from the stats pass.

    Exactly `fake_quantize_flat_ref`'s scale rule (leaf max-abs / qmax
    with the 1e-12 floor), so packed codes dequantize bit-for-bit to the
    staged fake-quantize output."""
    qmax = 2.0 ** (bits - 1) - 1
    block_leaf = jnp.asarray(block_leaf, jnp.int32)
    lmax = jax.ops.segment_max(jnp.moveaxis(bmax, -1, 0), block_leaf,
                               num_segments=n_leaves)
    scales = jnp.maximum(jnp.moveaxis(lmax, 0, -1), 1e-12) / qmax
    return jnp.take(scales, block_leaf, axis=-1)          # (K, NB)


def agg_pack_ref(mat, sblock, bits: int, block: int = 1024):
    """Quantize to int8 codes: (K, N), (K, NB) -> (K, NB, block) int8.

    The int8-out store is the point: the apply stage then reads 4x fewer
    bytes, and ``codes * sblock[..., None]`` reconstructs the staged
    fake-quantize output bit-for-bit (same divide, same round, same
    clip)."""
    qmax = 2.0 ** (bits - 1) - 1
    x3 = _chunked(mat.astype(jnp.float32), block)
    return jnp.clip(jnp.round(x3 / sblock[..., None]),
                    -qmax, qmax).astype(jnp.int8)


def agg_quant_sumsq_ref(q, sblock):
    """Quantized per-row sum of squares from int8 codes: sum_b s_b^2 *
    sum(q_b^2). Equal in value to row_sumsq of the dequantized buffer
    (fp-round-off: the per-block scale factors out of the block sum), at
    int8 read cost instead of another f32 sweep."""
    return jnp.einsum("kb,kb->k", _last_axis_sumsq(q),
                      sblock.astype(jnp.float32) ** 2)


def agg_apply_ref(q, coeff, noise=None, block: int = 1024):
    """Weighted accumulation over rows, one read + one write.

    q: (K, NB, block) int8 codes or f32 blocks; coeff: (K, NB) per-(row,
    block) coefficients with everything folded in (dequantize scale x
    clip scale x weight / denominator); noise: optional pre-drawn (N,)
    vector the accumulator STARTS from, so DP noise costs no extra
    sweep. Row-at-a-time keeps one f32 accumulator hot instead of
    materializing a (K, NB, block) dequantized copy."""
    K, NB = coeff.shape
    if noise is not None:
        acc = noise.reshape(NB, block).astype(jnp.float32)
    else:
        acc = jnp.zeros((NB, block), jnp.float32)
    for k in range(K):
        acc = acc + q[k].astype(jnp.float32) * coeff[k][:, None]
    return acc.reshape(-1)


def agg_apply_exact_ref(x3, weights, sblock=None, wsum=None, block_den=None,
                        noise=None, cols: int = 1024):
    """Column-chunked weighted-mean GEMV, bitwise identical to the staged
    mean on the same operand.

    x3: (K, NB, block) f32 blocks or int8 codes (with ``sblock`` (K, NB)
    to dequantize each column chunk in registers — the reconstruction is
    bitwise the staged fake-quantize output). Each output element is the
    same K-length dot ``jnp.matmul(weights, mat)`` computes — chunking
    along columns never touches the K accumulation order — and the
    ``/wsum`` (or per-block ``/block_den``, repeated to elements) and
    ``+noise`` tails are elementwise, so chunk-then-divide equals
    divide-then-chunk bit for bit. This is the quantize-only route's
    bitwise staged-vs-fused contract (test-enforced)."""
    K, NB, block = x3.shape
    outs = []
    for i in range(0, NB, cols):
        part = x3[:, i:i + cols].astype(jnp.float32)
        if sblock is not None:
            part = part * sblock[:, i:i + cols, None]
        t = jnp.matmul(weights.astype(jnp.float32), part.reshape(K, -1))
        if block_den is not None:
            t = t / jnp.repeat(block_den[i:i + cols], block)
        elif wsum is not None:
            t = t / wsum
        outs.append(t)
    out = jnp.concatenate(outs) if len(outs) > 1 else outs[0]
    if noise is not None:
        out = out + noise
    return out


def seed_reconstruct_ref(seed: int, shape, stddev: float):
    """Distributional reference for the TPU-PRNG Gaussian generator.

    NOT bit-identical to the Pallas kernel (different PRNG); used for
    moment / independence checks. Determinism of the kernel itself is
    asserted kernel-vs-kernel.
    """
    return stddev * jax.random.normal(jax.random.key(seed), shape,
                                      jnp.float32)
