"""On-chip frozen-parameter regeneration — Pallas TPU kernel.

The FedPT reconstruction step (Algorithm 1 line 5) regenerates the frozen
Gaussians from the scalar seed. On a TPU pod this kernel removes the HBM
broadcast / checkpoint read entirely: each device fills its *local shard*
of the frozen tensor directly in VMEM and a Box-Muller transform turns
uniform bits into Gaussians.

Bit source: a **counter-based hash PRNG** (squirrel3-style avalanche over
the global element index mixed with (seed, leaf_id)). Counter-based
generation is the right primitive here — the value of element (i, j) is a
pure function of (seed, leaf, i, j), so the tensor is *identical no
matter how it is sharded, blocked, or which backend generates it*
(server CPU vs client TPU — exactly FedPT's requirement that server and
clients "share the same random number generator"). The TPU hardware PRNG
(pltpu.prng_seed / prng_random_bits) would be faster but is stateful and
backend-specific, and its interpret-mode emulation is a zero stub in
current JAX; we keep the counter-based path as the only path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TWO_PI = 6.283185307179586

# squirrel3 avalanche constants (python ints; cast at trace time inside
# the kernel so they are not captured as closure constants)
_C1 = 0xB5297A4D
_C2 = 0x68E31DA4
_C3 = 0x1B56C4E9


def _squirrel3(n, seed):
    """Vectorized integer hash; n, seed: uint32 arrays -> uint32 bits."""
    n = n * jnp.uint32(_C1)
    n = n + seed
    n = n ^ jnp.right_shift(n, jnp.uint32(8))
    n = n + jnp.uint32(_C2)
    n = n ^ jnp.left_shift(n, jnp.uint32(8))
    n = n * jnp.uint32(_C3)
    n = n ^ jnp.right_shift(n, jnp.uint32(8))
    return n


def _uniform(bits):
    """uint32 -> (0, 1): top 24 bits as mantissa, offset by half an ulp."""
    return (jnp.right_shift(bits, jnp.uint32(8)).astype(jnp.float32)
            + 0.5) * (1.0 / 16777216.0)


def _seed_kernel(seed_ref, o_ref, *, stddev: float, rows: int, cols: int,
                 block_rows: int):
    i = pl.program_id(0)
    br, cp = o_ref.shape
    # global element index (row-major over the LOGICAL cols, so padding
    # columns do not perturb the stream of real elements)
    r = i * block_rows + jax.lax.broadcasted_iota(jnp.int32, (br, cp), 0)
    c = jax.lax.broadcasted_iota(jnp.int32, (br, cp), 1)
    idx = (r * cols + c).astype(jnp.uint32)
    seed = seed_ref[0].astype(jnp.uint32) * jnp.uint32(0x9E3779B9) + \
        seed_ref[1].astype(jnp.uint32)
    b1 = _squirrel3(idx * jnp.uint32(2), seed)
    b2 = _squirrel3(idx * jnp.uint32(2) + jnp.uint32(1), seed)
    u1 = _uniform(b1)
    u2 = _uniform(b2)
    z = jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(TWO_PI * u2)
    valid = jnp.logical_and(r < rows, c < cols)
    z = jnp.where(valid, z, 0.0)
    o_ref[...] = (stddev * z).astype(o_ref.dtype)


def seed_reconstruct(seed, leaf_id: int, shape, stddev: float,
                     dtype=jnp.float32, block_rows: int = 256,
                     interpret: bool = False):
    """Generate the deterministic Gaussian tensor of `shape` on-chip.

    `shape` is flattened to (rows, cols) on the last dim; cols padded to
    the 128-lane boundary inside the kernel and sliced off after.
    """
    if len(shape) == 1:
        rows, cols = 1, int(shape[0])
    else:
        rows = 1
        for d in shape[:-1]:
            rows *= int(d)
        cols = int(shape[-1])
    cpad = (cols + 127) // 128 * 128
    br = min(block_rows, max(rows, 8))
    nblocks = (rows + br - 1) // br
    rpad = nblocks * br

    seeds = jnp.asarray([jnp.asarray(seed, jnp.int32),
                         jnp.asarray(leaf_id * 40503, jnp.int32)], jnp.int32)
    out = pl.pallas_call(
        functools.partial(_seed_kernel, stddev=float(stddev), rows=rows,
                          cols=cols, block_rows=br),
        grid=(nblocks,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM)],
        out_specs=pl.BlockSpec((br, cpad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rpad, cpad), dtype),
        interpret=interpret,
    )(seeds)
    return out[:rows, :cols].reshape(shape)
