"""Fused per-leaf int8 fake-quantize — Pallas TPU kernel pair.

The lossy-uplink hot-spot: quantize a client's flat trainable delta
(one contiguous fp32 vector, block-aligned per leaf by
``core.flat.FlatLayout``) with a symmetric per-leaf scale, then
dequantize in place — the in-graph Q->DQ the round engine applies when
``RoundConfig.uplink_bits > 0``. Done per leaf with tree ops this is
2 sweeps *per leaf* plus a dispatch per leaf; the kernel pair fuses it
into 2 total HBM sweeps over the whole buffer:

1. a block-tiled max-abs reduction accumulating per-LEAF maxima in an
   SMEM scratch vector, routed by a scalar-prefetched block->leaf map
   (TPU grid iterations are sequential, so scratch accumulation is
   race-free — same trick as dp_clip.py's sum-of-squares);
2. a single read-modify-write pass ``round/clip/rescale`` with the
   per-leaf scales prefetched to SMEM and indexed by the same map.

Because each leaf is padded to a whole number of blocks, a block never
straddles leaves and the zero padding can never raise a leaf's max.
Scales match ``core.compress.quantize_leaf`` exactly (max-abs/qmax with
the same 1e-12 floor), so kernel and tree path agree bit-for-bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 1024  # one f32 (8, 128) tile; must equal the layout's `align`


def _maxabs_kernel(block_leaf_ref, x_ref, o_ref, acc_ref):
    i = pl.program_id(0)
    n = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    lid = block_leaf_ref[i]
    m = jnp.max(jnp.abs(x_ref[...].astype(jnp.float32)))
    acc_ref[lid] = jnp.maximum(acc_ref[lid], m)

    @pl.when(i == n - 1)
    def _out():
        o_ref[...] = acc_ref[...]


def _qdq_kernel(block_leaf_ref, scales_ref, x_ref, o_ref, *, qmax: float):
    s = scales_ref[block_leaf_ref[pl.program_id(0)]]
    q = jnp.clip(jnp.round(x_ref[...].astype(jnp.float32) / s), -qmax, qmax)
    o_ref[...] = q * s


def leaf_maxabs(x, block_leaf, n_leaves: int, block: int = BLOCK,
                interpret: bool = False):
    """Per-leaf max-abs of a block-aligned flat vector.

    x: (N,) with N == len(block_leaf) * block; block_leaf: (K,) int32
    mapping each block to its leaf. Returns (n_leaves,) f32.
    """
    grid = (x.shape[0] // block,)
    return pl.pallas_call(
        _maxabs_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((block,), lambda i, m: (i,))],
            out_specs=pl.BlockSpec(memory_space=pltpu.SMEM),
            scratch_shapes=[pltpu.SMEM((n_leaves,), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((n_leaves,), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(block_leaf, jnp.int32), x)


def fake_quantize_flat(x, block_leaf, n_leaves: int, bits: int = 8,
                       block: int = BLOCK, interpret: bool = False):
    """Fused Q->DQ of a flat client delta with per-leaf symmetric scales.

    Semantics match `compress.fake_quantize_tree` on the unflattened
    tree. Two HBM sweeps total, independent of the leaf count.
    """
    qmax = 2.0 ** (bits - 1) - 1
    maxima = leaf_maxabs(x, block_leaf, n_leaves, block=block,
                         interpret=interpret)
    scales = jnp.maximum(maxima, 1e-12) / qmax
    grid = (x.shape[0] // block,)
    return pl.pallas_call(
        functools.partial(_qdq_kernel, qmax=qmax),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[pl.BlockSpec((block,), lambda i, m, s: (i,))],
            out_specs=pl.BlockSpec((block,), lambda i, m, s: (i,)),
        ),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=interpret,
    )(jnp.asarray(block_leaf, jnp.int32), scales, x)
