"""FedPT reproduction package.

One process-wide jax config knob lives here: sharding-invariant PRNG.
The simulation grid executes the same program on one device or over a
``launch/mesh.py`` mesh and promises histories that agree to fp32
round-off — which requires random draws (DP noise above all) whose
values do not depend on how the output array is partitioned. The legacy
threefry lowering is not partition-invariant; the partitionable
implementation is, at the cost of changing the raw stream (PRNG-derived
trajectories differ from pre-mesh versions of this repo, exactly like
PR 2's one-key-per-flat-buffer change did).
"""
import jax

jax.config.update("jax_threefry_partitionable", True)
