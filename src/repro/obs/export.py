"""Trace exporters: schema-versioned JSONL and Chrome/Perfetto JSON.

The Perfetto export renders the grid's *virtual* clock as trace_event
process/thread tracks, so a run opens directly in ``ui.perfetto.dev``
(or ``chrome://tracing``):

* process "server" — round spans, flush and ``checkpoint`` instants on
  one track, ``dp_flush`` accounting instants on a "privacy" track,
  ``tier_upload`` wire-billing instants on a "wire" track, injected
  ``fault`` firings, sanitize ``quarantine`` instants and correlated
  region ``shock`` firings on a "faults" track, ``edge_flush``
  pre-reduce instants on an "edges" track, parked-dispatch ``retry``
  instants alongside the rounds;
* process "clients" — one thread track per client id, carrying that
  client's ``dispatch`` round-trip spans and ``upload`` arrival
  instants.

Virtual seconds map to trace microseconds 1:1 (``ts = t * 1e6``), so
the timeline reads in simulated fleet time, not host wall-clock.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List

from repro.obs import schema as schema_lib

# server-process thread ids by event kind
_SERVER_PID = 0
_CLIENT_PID = 1
_SERVER_TIDS = {"round": 0, "flush": 0, "retry": 0, "checkpoint": 0,
                "dp_flush": 1, "tier_upload": 2,
                "fault": 3, "quarantine": 3, "shock": 3,
                "edge_flush": 4}
_SERVER_TID_NAMES = {0: "rounds", 1: "privacy", 2: "wire", 3: "faults",
                     4: "edges"}


def record_json(rec) -> Dict[str, Any]:
    """One TraceRecord -> its schema-versioned JSONL object."""
    out: Dict[str, Any] = {"v": schema_lib.SCHEMA_VERSION,
                           "kind": rec.kind, "t": rec.t}
    if rec.dur is not None:
        out["dur"] = rec.dur
    seq = getattr(rec, "seq", None)
    if seq is not None:
        out["seq"] = seq
    parent = getattr(rec, "parent", None)
    if parent is not None:
        out["parent"] = parent
    out.update(rec.payload)
    return out


def write_jsonl(records: Iterable, path: str) -> int:
    """Write one JSON object per record; returns the record count."""
    n = 0
    with open(path, "w") as f:
        for rec in records:
            f.write(json.dumps(record_json(rec)) + "\n")
            n += 1
    return n


def _us(t: float) -> float:
    return t * 1e6


def perfetto_trace(records: Iterable) -> Dict[str, Any]:
    """Chrome trace_event document for a record stream (see module
    docstring for the track layout). Events are stably sorted by
    ``(ts, seq)`` — the monotone v4 seq breaks ties between
    zero-duration instants sharing a virtual timestamp, so the export is
    deterministic regardless of dict/iterator quirks upstream. Records
    with a ``parent`` additionally emit a flow-event pair (``ph: s/f``)
    so causal chains render as arrows in ui.perfetto.dev."""
    keyed: List[tuple] = []           # (ts, tiebreak, event dict)
    coords: Dict[int, tuple] = {}     # seq -> (pid, tid, start_ts, end_ts)
    links: List[tuple] = []           # (child seq, parent seq)
    client_tids = set()
    for i, rec in enumerate(records):
        args = {k: v for k, v in rec.payload.items() if v is not None}
        seq = getattr(rec, "seq", None)
        parent = getattr(rec, "parent", None)
        if seq is not None:
            args["seq"] = seq
        if rec.kind in ("dispatch", "upload"):
            pid, tid = _CLIENT_PID, int(rec.payload["cid"])
            client_tids.add(tid)
        else:
            pid = _SERVER_PID
            tid = _SERVER_TIDS.get(rec.kind, 0)
        ts = _us(rec.t)
        if rec.dur is not None:
            ev = {"name": rec.kind, "cat": rec.kind, "ph": "X",
                  "ts": ts, "dur": _us(rec.dur),
                  "pid": pid, "tid": tid, "args": args}
            end_ts = ts + _us(rec.dur)
        else:
            # instants: flushes & co. render as global markers on the
            # server tracks, client arrivals as thread-scoped ticks
            scope = "t" if pid == _CLIENT_PID else "g"
            ev = {"name": rec.kind, "cat": rec.kind, "ph": "i",
                  "ts": ts, "s": scope,
                  "pid": pid, "tid": tid, "args": args}
            end_ts = ts
        keyed.append((ts, seq if seq is not None else i, ev))
        if seq is not None:
            coords[seq] = (pid, tid, ts, end_ts)
            if parent is not None:
                links.append((seq, parent))
    keyed.sort(key=lambda kv: (kv[0], kv[1]))
    events: List[Dict[str, Any]] = [ev for _, _, ev in keyed]
    # causal arrows: flow start at the parent's end, flow finish (with
    # binding point "enclosing slice start") at the child's start —
    # in child-seq order, so the export stays input-order independent
    for child, parent in sorted(links):
        if parent not in coords or child not in coords:
            continue                     # dangling ref (e.g. post-resume)
        ppid, ptid, _, pend = coords[parent]
        cpid, ctid, cstart, _ = coords[child]
        events.append({"name": "causal", "cat": "causal", "ph": "s",
                       "id": child, "ts": pend, "pid": ppid, "tid": ptid})
        events.append({"name": "causal", "cat": "causal", "ph": "f",
                       "bp": "e", "id": child, "ts": cstart,
                       "pid": cpid, "tid": ctid})
    meta = [
        {"name": "process_name", "ph": "M", "pid": _SERVER_PID,
         "args": {"name": "server"}},
        {"name": "process_name", "ph": "M", "pid": _CLIENT_PID,
         "args": {"name": "clients"}},
    ]
    for tid, name in _SERVER_TID_NAMES.items():
        meta.append({"name": "thread_name", "ph": "M", "pid": _SERVER_PID,
                     "tid": tid, "args": {"name": name}})
    for tid in sorted(client_tids):
        meta.append({"name": "thread_name", "ph": "M", "pid": _CLIENT_PID,
                     "tid": tid, "args": {"name": f"client {tid}"}})
    return {"traceEvents": meta + events, "displayTimeUnit": "ms",
            "otherData": {"clock": "virtual-seconds",
                          "schema_version": schema_lib.SCHEMA_VERSION}}


def write_perfetto(records: Iterable, path: str) -> int:
    doc = perfetto_trace(records)
    with open(path, "w") as f:
        json.dump(doc, f)
    return sum(1 for e in doc["traceEvents"]
               if e.get("ph") not in ("M", "s", "f"))
