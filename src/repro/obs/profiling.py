"""Optional ``jax.profiler`` annotation hooks for traced grid runs.

Virtual-time spans (obs/trace.py) say *when the simulated fleet* was
busy; a wall-time profile says where the *host* actually spent its
compute. With ``TelemetryConfig(profile=True)`` the grid wraps its two
jitted hot paths — the vmapped client lane step and the buffered-apply
server tail — in named ``jax.profiler.TraceAnnotation`` scopes, so a
profile captured around the run (``jax.profiler.trace(...)`` or
``start_trace``/``stop_trace``) shows ``grid/lane_step`` /
``grid/server_apply`` blocks that line up with the virtual-time flush
spans one-to-one.

Everything degrades to a plain call when profiling is off or the
installed jax lacks ``TraceAnnotation`` — the wrapper adds one function
frame, never a device sync.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Callable, Optional

try:  # jax >= 0.3; absent under exotic stubs — degrade to no-op
    from jax.profiler import TraceAnnotation as _TraceAnnotation
except Exception:  # pragma: no cover - depends on the installed jax
    _TraceAnnotation = None


def annotation(name: str):
    """Context manager marking a named region in the jax profiler
    timeline (no-op when TraceAnnotation is unavailable)."""
    if _TraceAnnotation is None:  # pragma: no cover
        return contextlib.nullcontext()
    return _TraceAnnotation(name)


def annotate(fn: Callable, name: str,
             enabled: bool = True) -> Callable:
    """Wrap ``fn`` so each call runs inside ``annotation(name)``.
    With ``enabled=False`` (telemetry off, or profile not requested)
    returns ``fn`` unchanged — zero added frames on the default path."""
    if not enabled or _TraceAnnotation is None:
        return fn

    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        with _TraceAnnotation(name):
            return fn(*args, **kwargs)

    return wrapped


def annotate_map(fns: dict, name: str, enabled: bool = True) -> dict:
    """``annotate`` over a dict of callables (the grid's per-tier lane
    step / client step tables), tagging each with its key."""
    if not enabled:
        return fns
    return {k: annotate(fn, f"{name}[{k}]") for k, fn in fns.items()}


def capture(path: Optional[str]):
    """Context manager: capture a jax wall-time profile into ``path``
    (a TensorBoard logdir) for the enclosed block; no-op when ``path``
    is None or the profiler is unavailable."""
    if path is None:
        return contextlib.nullcontext()
    try:
        import jax
        return jax.profiler.trace(path)
    except Exception:  # pragma: no cover - profiler backend missing
        return contextlib.nullcontext()
