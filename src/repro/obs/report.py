"""Markdown run reports over a grid telemetry trace (stdlib-only).

Renders one traced run — JSONL export or in-memory Tracer — as a
human-readable report: the per-phase critical-path table from
``obs/analyze.py``, straggler attribution, the tier wire ledger
(re-summed from the ``tier_upload`` billing instants the ``CommReport``
emitted, so it IS the ledger), the epsilon curve with burn rates, and
fault/quarantine/shock/checkpoint counts. With ``--metrics`` (a
``MetricsRegistry.snapshot()`` JSON) the report cross-checks the trace
against the registry's counters.

CLI (the CI ``telemetry`` job uploads the output as an artifact):

    python -m repro.obs.report run.jsonl --metrics snap.json -o report.md
"""
from __future__ import annotations

import json
from typing import Any, Iterable, List, Optional, Union

from repro.obs import analyze as analyze_lib

_MB = 1024 * 1024


def _fmt(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def _table(headers: List[str], rows: List[List[Any]]) -> List[str]:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "---|" * len(headers)]
    for r in rows:
        out.append("| " + " | ".join(_fmt(c) for c in r) + " |")
    out.append("")
    return out


def _counter(snap: Optional[dict], name: str) -> Optional[float]:
    if not snap:
        return None
    c = snap.get("counters", {}).get(name)
    return None if c is None else c.get("value")


def build_report(source: Union[str, Iterable],
                 metrics: Optional[dict] = None,
                 title: str = "Grid run report",
                 max_rows: int = 20) -> str:
    """The full markdown report for one trace source (JSONL path,
    Tracer, or record iterable). ``metrics`` is an optional decoded
    ``MetricsRegistry.snapshot()`` dict for cross-checks."""
    a = analyze_lib.analyze(source)
    lines: List[str] = [f"# {title}", ""]
    n_events = sum(a.counts["kinds"].values())
    unit = "rounds" if a.mode == "sync" else "flushes"
    lines += [f"- mode: **{a.mode}** · {len(a.breakdowns)} {unit} · "
              f"{n_events} trace events",
              f"- virtual wall time: **{a.virtual_seconds:.4g} s**", ""]

    # --- critical path ---------------------------------------------------
    wall = sum(b.span for b in a.breakdowns)
    lines += ["## Critical path", ""]
    if wall > 0:
        rows = [[k, f"{v:.4g}", f"{100.0 * v / wall:.1f}%"]
                for k, v in a.phase_totals.items()]
        lines += _table(["phase", "virtual s", "% of wall"], rows)
        ident = all(b.check_identity(1e-6) for b in a.breakdowns)
        lines += [f"- phase identity (phases sum to each {unit[:-2]}'s "
                  f"span): **{'holds' if ident else 'VIOLATED'}**", ""]
    else:
        lines += ["(no rounds/flushes in the trace)", ""]
    if a.breakdowns:
        shown = a.breakdowns[:max_rows]
        rows = []
        for b in shown:
            who = "—"
            if b.bounded_by is not None:
                who = f"cid {b.bounded_by['cid']}"
                if b.bounded_by.get("tier") is not None:
                    who += f" / tier {b.bounded_by['tier']}"
                if b.bounded_by.get("region") is not None:
                    who += f" / region {b.bounded_by['region']}"
            rows.append([b.index, f"{b.start:.4g}", f"{b.span:.4g}",
                         f"{b.phases['downlink']:.4g}",
                         f"{b.phases['compute']:.4g}",
                         f"{b.phases['uplink']:.4g}",
                         f"{b.phases['retry']:.4g}",
                         f"{b.phases['wait']:.4g}", who])
        lines += _table(["#", "start", "span", "down", "compute", "up",
                         "retry", "wait", "bounded by"], rows)
        if len(a.breakdowns) > max_rows:
            lines += [f"({len(a.breakdowns) - max_rows} more {unit} "
                      "not shown)", ""]

    # --- stragglers ------------------------------------------------------
    lines += ["## Straggler attribution", ""]
    any_strag = False
    for key, label in (("by_cid", "cid"), ("by_tier", "tier"),
                       ("by_region", "region")):
        slots = a.stragglers.get(key, {})
        if not slots:
            continue
        any_strag = True
        top = sorted(slots.items(), key=lambda kv: -kv[1]["seconds"])
        rows = [[k, v["count"], f"{v['seconds']:.4g}"]
                for k, v in top[:10]]
        lines += [f"**Bounded {unit} by {label}:**", ""]
        lines += _table([label, unit + " bounded", "virtual s"], rows)
    if a.stragglers.get("unattributed"):
        lines += [f"- {a.stragglers['unattributed']} {unit} unattributed "
                  "(deadline-bound, dark-window, or pre-v4 trace)", ""]
    if not any_strag and not a.stragglers.get("unattributed"):
        lines += ["(nothing bounded the clock — empty trace?)", ""]

    # --- wire ledger -----------------------------------------------------
    if a.wire:
        lines += ["## Wire ledger (per tier, from tier_upload billing)",
                  ""]
        rows = [[name, f"{rec['down_bytes'] / _MB:.3f}",
                 f"{rec['up_bytes'] / _MB:.3f}", rec["transfers"],
                 rec["uploads"]]
                for name, rec in sorted(a.wire.items())]
        lines += _table(["tier", "down MB", "up MB", "transfers",
                         "uploads"], rows)

    # --- metrics cross-check --------------------------------------------
    if metrics is not None:
        lines += ["## Metrics cross-check", ""]
        rows = []
        trace_uploads = a.counts["kinds"].get("upload", 0) \
            + a.counts["faults"].get("duplicate_upload", 0)
        reg_uploads = _counter(metrics, "uploads")
        if reg_uploads is not None:
            ok = "OK" if trace_uploads <= reg_uploads else "MISMATCH"
            rows.append(["uploads (trace incl. duplicates vs registry)",
                         trace_uploads, int(reg_uploads), ok])
        for kind, cname in (("dispatch", "dispatches"),
                            ("retry", "retries"),
                            ("quarantine", "quarantined"),
                            ("checkpoint", "checkpoints")):
            reg = _counter(metrics, cname)
            if reg is None:
                continue
            tr = a.counts["kinds"].get(kind, 0)
            rows.append([cname, tr, int(reg),
                         "OK" if tr == int(reg) else "MISMATCH"])
        if rows:
            lines += _table(["quantity", "trace", "registry", "check"],
                            rows)
        else:
            lines += ["(no comparable counters in the snapshot)", ""]

    # --- privacy ---------------------------------------------------------
    if a.privacy:
        lines += ["## Privacy budget", ""]
        rows = [[p["flush"], f"{p['t']:.4g}", f"{p['epsilon']:.4g}",
                 f"{p['burn_rate']:.4g}"] for p in a.privacy[:max_rows]]
        lines += _table(["flush", "t (s)", "epsilon", "burn (eps/s)"],
                        rows)
        lines += [f"- final epsilon: **{a.privacy[-1]['epsilon']:.4g}** "
                  f"after {len(a.privacy)} accounted flushes", ""]

    # --- events ----------------------------------------------------------
    lines += ["## Events", ""]
    rows = [[k, v] for k, v in sorted(a.counts["kinds"].items())]
    lines += _table(["kind", "count"], rows)
    if a.counts["faults"]:
        rows = [[k, v] for k, v in sorted(a.counts["faults"].items())]
        lines += ["**Injected faults:**", ""]
        lines += _table(["fault", "count"], rows)
    if a.counts["quarantine"]:
        rows = [[k, v] for k, v in sorted(a.counts["quarantine"].items())]
        lines += ["**Quarantined rows:**", ""]
        lines += _table(["cause", "count"], rows)
    return "\n".join(lines).rstrip() + "\n"


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        description="Render a grid telemetry JSONL trace as a markdown "
                    "run report (critical path, stragglers, wire ledger, "
                    "privacy curve, fault counts).")
    ap.add_argument("jsonl", help="JSONL trace file")
    ap.add_argument("--metrics", default=None, metavar="SNAPSHOT_JSON",
                    help="MetricsRegistry.snapshot() JSON to cross-check "
                         "the trace against")
    ap.add_argument("-o", "--out", default=None, metavar="MD",
                    help="write the report here (default: stdout)")
    ap.add_argument("--title", default="Grid run report")
    args = ap.parse_args(argv)
    metrics = None
    if args.metrics:
        with open(args.metrics) as f:
            metrics = json.load(f)
    text = build_report(args.jsonl, metrics=metrics, title=args.title)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
        print(f"wrote {args.out} ({len(text.splitlines())} lines)")
    else:
        print(text)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
