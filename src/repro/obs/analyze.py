"""Causal-graph analysis over a grid telemetry trace (stdlib-only).

Schema v4 gave every :class:`~repro.obs.trace.TraceRecord` a monotone
``seq`` and an optional ``parent`` id, so a trace is a forest: each
client round trip is a chain ``dispatch -> (fault|retry)* -> upload ->
flush/round -> dp_flush/tier_upload/edge_flush``. This module
reconstructs that graph — from the in-memory record list or from an
exported JSONL file, interchangeably — and computes what the flat event
stream could not answer:

* **Per-round critical paths** (:func:`round_breakdowns`): each sync
  ``round`` span / async ``flush`` window is split into phases —
  downlink transfer, client compute, uplink transfer, retry/backoff,
  server apply, and buffer/idle wait — by walking the round's causal
  chain back through its *bounding* upload (the arrival that closed it)
  to the dispatch span's v4 ``t_down``/``t_comp``/``t_up`` components
  and clipping each segment to the round's window. The phases sum to
  the round's virtual wall time exactly (``wait`` is defined as the
  unattributed remainder, and the chain segments are disjoint and
  clipped, so the remainder is non-negative up to float error) — the
  test-enforced identity the ISSUE asks for.
* **Straggler attribution**: which cid/tier/region bounded each round
  or flush, with counts and bounded virtual seconds.
* **Privacy burn rate**: the ``dp_flush`` stream as an
  (epsilon, d(epsilon)/dt) series over virtual time.
* **Wire ledger**: ``tier_upload`` billing instants re-summed per tier,
  cross-checkable against ``CommReport.tier_table()``.

Everything degrades gracefully on pre-v4 traces (no ids -> every round
is "unattributed": its whole window is ``wait``) and on dangling
parents (checkpoint/resume starts a fresh tracer).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Iterable, List, Optional, Tuple, Union

# phase keys, in report order; "apply" is identically 0.0 in the
# virtual clock (the server applies instantaneously at the flush/round
# boundary) but kept so live wall-clock traces (ROADMAP) reuse the keys
PHASES = ("downlink", "compute", "uplink", "retry", "apply", "wait")


@dataclasses.dataclass
class Node:
    """One normalized trace record inside the causal graph."""
    kind: str
    t: float
    dur: Optional[float]
    payload: Dict[str, Any]
    seq: Optional[int] = None
    parent: Optional[int] = None
    children: List[int] = dataclasses.field(default_factory=list)

    @property
    def end(self) -> float:
        return self.t + (self.dur or 0.0)


_TOP_LEVEL = ("v", "kind", "t", "dur", "seq", "parent")


def _normalize(rec: Any) -> Node:
    """TraceRecord or decoded JSONL dict -> Node (payload keys
    identical either way, so JSONL->analyze equals in-memory analyze)."""
    if isinstance(rec, dict):
        return Node(kind=rec["kind"], t=float(rec["t"]),
                    dur=None if rec.get("dur") is None
                    else float(rec["dur"]),
                    payload={k: v for k, v in rec.items()
                             if k not in _TOP_LEVEL},
                    seq=rec.get("seq"), parent=rec.get("parent"))
    return Node(kind=rec.kind, t=rec.t, dur=rec.dur,
                payload=dict(rec.payload),
                seq=getattr(rec, "seq", None),
                parent=getattr(rec, "parent", None))


def load_records(source: Union[str, Iterable]) -> List[Node]:
    """Normalize a trace source: a JSONL path, a Tracer, or an iterable
    of TraceRecords / decoded dicts."""
    if isinstance(source, str):
        out = []
        with open(source) as f:
            for line in f:
                line = line.strip()
                if line:
                    out.append(_normalize(json.loads(line)))
        return out
    events = getattr(source, "events", None)
    if events is not None and not isinstance(source, (list, tuple)):
        source = events                       # a Tracer
    return [_normalize(r) for r in source]


@dataclasses.dataclass
class TraceGraph:
    """The causal forest: normalized nodes + seq index + child lists."""
    nodes: List[Node]
    by_seq: Dict[int, Node]

    def of_kind(self, kind: str) -> List[Node]:
        return [n for n in self.nodes if n.kind == kind]

    def get(self, seq: Optional[int]) -> Optional[Node]:
        return None if seq is None else self.by_seq.get(seq)

    def children_of(self, node: Node) -> List[Node]:
        return [self.by_seq[s] for s in node.children]


def build_graph(source: Union[str, Iterable]) -> TraceGraph:
    nodes = load_records(source)
    by_seq = {n.seq: n for n in nodes if n.seq is not None}
    for n in nodes:
        p = by_seq.get(n.parent) if n.parent is not None else None
        if p is not None and n.seq is not None:
            p.children.append(n.seq)
    return TraceGraph(nodes=nodes, by_seq=by_seq)


# ---------------------------------------------------------------------------
# Critical-path phase attribution


def _clip(a: float, b: float, w0: float, w1: float) -> float:
    """Length of [a, b] ∩ [w0, w1]."""
    return max(0.0, min(b, w1) - max(a, w0))


@dataclasses.dataclass
class RoundBreakdown:
    index: int                    # round number / flush number
    kind: str                     # "round" (sync) or "flush" (async)
    start: float                  # window start, virtual seconds
    end: float                    # window end (= the round/flush time)
    phases: Dict[str, float]      # PHASES -> virtual seconds, sums to span
    bounded_by: Optional[Dict[str, Any]]  # cid/tier/region/rtt, or None

    @property
    def span(self) -> float:
        return self.end - self.start

    def check_identity(self, tol: float = 1e-9) -> bool:
        return abs(sum(self.phases.values()) - self.span) \
            <= tol * max(1.0, abs(self.span))


def _chain_phases(graph: TraceGraph, upload: Node, w0: float,
                  w1: float) -> Dict[str, float]:
    """Walk upload -> dispatch -> retry* and lay the chain's phase
    segments onto the window [w0, w1] (clipped — disjoint consecutive
    intervals, so their clipped sum never exceeds the window)."""
    phases = {k: 0.0 for k in PHASES}
    disp = graph.get(upload.parent)
    if disp is None or disp.kind != "dispatch":
        return phases
    p = disp.payload
    t_down = p.get("t_down")
    t_comp = p.get("t_comp")
    t_up = p.get("t_up")
    if t_down is not None and t_comp is not None and t_up is not None:
        a = disp.t
        for key, d in (("downlink", t_down), ("compute", t_comp),
                       ("uplink", t_up)):
            phases[key] += _clip(a, a + d, w0, w1)
            a += d
    elif disp.dur is not None:
        # pre-component trace: the whole round trip counts as uplink-
        # unattributed compute (best effort, identity still holds)
        phases["compute"] += _clip(disp.t, disp.t + disp.dur, w0, w1)
    # parked retries that preceded this dispatch slot: each covers
    # [retry.t, retry.t + backoff], ending where the next attempt starts
    node = graph.get(disp.parent)
    while node is not None and node.kind == "retry":
        b = node.payload.get("backoff") or 0.0
        phases["retry"] += _clip(node.t, node.t + b, w0, w1)
        node = graph.get(node.parent)
    return phases


def _bounded_by(upload: Node) -> Dict[str, Any]:
    p = upload.payload
    return {"cid": p.get("cid"), "tier": p.get("tier"),
            "region": p.get("region"), "rtt": p.get("rtt")}


def round_breakdowns(graph: TraceGraph) -> List[RoundBreakdown]:
    """Per-round critical-path phases. Sync ``round`` spans use their
    own [t, t+dur] window; async ``flush`` instants use the inter-flush
    window [previous flush t (or 0), flush t]. ``wait`` is the window
    time no chain segment claims — deadline tails, buffer idle, and
    everything in unattributed (pre-v4 / resumed) rounds."""
    out: List[RoundBreakdown] = []
    rounds = graph.of_kind("round")
    retries = graph.of_kind("retry")
    for n in rounds:
        w0, w1 = n.t, n.end
        upload = graph.get(n.parent)
        if upload is not None and upload.kind == "upload":
            phases = _chain_phases(graph, upload, w0, w1)
            bounded = _bounded_by(upload)
        else:
            # deadline-bound or dark-window round: no bounding upload.
            # Retry instants inside the window (the sync dark re-poll)
            # claim their backoff; the rest is wait.
            phases = {k: 0.0 for k in PHASES}
            for r in retries:
                if w0 <= r.t < w1 and r.parent is None:
                    b = r.payload.get("backoff") or 0.0
                    phases["retry"] += _clip(r.t, r.t + b, w0, w1)
            bounded = None
        phases["wait"] = (w1 - w0) - sum(
            v for k, v in phases.items() if k != "wait")
        out.append(RoundBreakdown(
            index=int(n.payload.get("round", len(out))), kind="round",
            start=w0, end=w1, phases=phases, bounded_by=bounded))
    if rounds:
        return out
    prev = 0.0
    for n in graph.of_kind("flush"):
        w0, w1 = prev, n.t
        prev = n.t
        upload = graph.get(n.parent)
        if upload is not None and upload.kind == "upload":
            phases = _chain_phases(graph, upload, w0, w1)
            bounded = _bounded_by(upload)
        else:
            phases = {k: 0.0 for k in PHASES}
            bounded = None
        phases["wait"] = (w1 - w0) - sum(
            v for k, v in phases.items() if k != "wait")
        out.append(RoundBreakdown(
            index=int(n.payload.get("version", len(out))), kind="flush",
            start=w0, end=w1, phases=phases, bounded_by=bounded))
    return out


# ---------------------------------------------------------------------------
# Straggler attribution, privacy burn, wire ledger, event counts


def straggler_attribution(breakdowns: List[RoundBreakdown]) -> Dict[str, Any]:
    """Who bounded the clock: counts and bounded virtual seconds keyed
    by cid / tier / region (the bounding upload's payload)."""
    out: Dict[str, Dict[Any, Dict[str, float]]] = {
        "by_cid": {}, "by_tier": {}, "by_region": {}}
    unattributed = 0
    for b in breakdowns:
        if b.bounded_by is None:
            unattributed += 1
            continue
        for key, field in (("by_cid", "cid"), ("by_tier", "tier"),
                           ("by_region", "region")):
            val = b.bounded_by.get(field)
            if val is None:
                continue
            slot = out[key].setdefault(val, {"count": 0, "seconds": 0.0})
            slot["count"] += 1
            slot["seconds"] += b.span
    return {**out, "unattributed": unattributed}


def privacy_series(graph: TraceGraph) -> List[Dict[str, float]]:
    """The dp_flush stream as an epsilon curve with per-step burn rate
    (d(epsilon)/d(virtual time); 0.0 when the clock did not move)."""
    out: List[Dict[str, float]] = []
    prev_t, prev_eps = 0.0, 0.0
    for n in graph.of_kind("dp_flush"):
        eps = n.payload.get("epsilon")
        if eps is None:
            continue
        dt = n.t - prev_t
        out.append({"t": n.t, "flush": n.payload.get("flush", len(out)),
                    "epsilon": float(eps),
                    "burn_rate": (float(eps) - prev_eps) / dt
                    if dt > 0 else 0.0})
        prev_t, prev_eps = n.t, float(eps)
    return out


def wire_ledger(graph: TraceGraph) -> Dict[str, Dict[str, int]]:
    """tier_upload billing instants re-summed per tier name."""
    out: Dict[str, Dict[str, int]] = {}
    for n in graph.of_kind("tier_upload"):
        p = n.payload
        rec = out.setdefault(p["tier_name"],
                             {"down_bytes": 0, "up_bytes": 0,
                              "transfers": 0, "uploads": 0})
        rec["down_bytes"] += int(p.get("down_bytes") or 0)
        rec["up_bytes"] += int(p.get("up_bytes") or 0)
        rec["transfers"] += int(p.get("transfers") or 0)
        rec["uploads"] += int(p.get("uploads") or 0)
    return out


def event_counts(graph: TraceGraph) -> Dict[str, Any]:
    counts: Dict[str, int] = {}
    for n in graph.nodes:
        counts[n.kind] = counts.get(n.kind, 0) + 1
    faults: Dict[str, int] = {}
    for n in graph.of_kind("fault"):
        f = n.payload.get("fault", "?")
        faults[f] = faults.get(f, 0) + 1
    quarantine: Dict[str, int] = {}
    for n in graph.of_kind("quarantine"):
        c = n.payload.get("cause", "?")
        quarantine[c] = quarantine.get(c, 0) + 1
    return {"kinds": counts, "faults": faults, "quarantine": quarantine}


# ---------------------------------------------------------------------------
# One-call rollup


@dataclasses.dataclass
class RunAnalysis:
    mode: str                                 # "sync" | "async" | "empty"
    breakdowns: List[RoundBreakdown]
    phase_totals: Dict[str, float]
    virtual_seconds: float
    stragglers: Dict[str, Any]
    privacy: List[Dict[str, float]]
    wire: Dict[str, Dict[str, int]]
    counts: Dict[str, Any]

    def to_json(self) -> Dict[str, Any]:
        return {
            "mode": self.mode,
            "virtual_seconds": self.virtual_seconds,
            "phase_totals": self.phase_totals,
            "rounds": [{"index": b.index, "kind": b.kind,
                        "start": b.start, "end": b.end,
                        "phases": b.phases, "bounded_by": b.bounded_by}
                       for b in self.breakdowns],
            "stragglers": self.stragglers,
            "privacy": self.privacy,
            "wire": self.wire,
            "counts": self.counts,
        }


def analyze(source: Union[str, Iterable]) -> RunAnalysis:
    """Full rollup for a trace source (JSONL path, Tracer, or record
    iterable): graph -> breakdowns -> totals/stragglers/privacy/wire."""
    graph = build_graph(source)
    breakdowns = round_breakdowns(graph)
    mode = ("empty" if not graph.nodes
            else "sync" if graph.of_kind("round") else "async")
    totals = {k: 0.0 for k in PHASES}
    for b in breakdowns:
        for k, v in b.phases.items():
            totals[k] += v
    vs = max((b.end for b in breakdowns), default=0.0)
    return RunAnalysis(
        mode=mode, breakdowns=breakdowns, phase_totals=totals,
        virtual_seconds=vs,
        stragglers=straggler_attribution(breakdowns),
        privacy=privacy_series(graph), wire=wire_ledger(graph),
        counts=event_counts(graph))
