"""Grid telemetry: structured event tracing, a metrics registry, and
trace exporters (schema-versioned JSONL + Chrome/Perfetto timelines).

The simulation stack (sim/scheduler.py, sim/grid.py, core/dp.py,
core/comm.py) threads one :class:`Tracer` and one
:class:`MetricsRegistry` through a run. ``GridConfig.telemetry=None``
(the default) routes tracing through :data:`NULL_TRACER` — a strict
no-op with bit-identical run histories — while the metrics registry is
always live and backs ``GridResult.scheduler_stats`` / ``tier_stats``
as its dict views.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               SNAPSHOT_VERSION)
from repro.obs.schema import (EVENT_SCHEMA, KINDS, SCHEMA_VERSION,
                              validate_jsonl, validate_perfetto,
                              validate_record, validate_records)
from repro.obs.trace import (NULL_TRACER, NullTracer, TelemetryConfig,
                             TraceRecord, Tracer, resolve_telemetry)
from repro.obs import export
