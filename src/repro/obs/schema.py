"""Event-record schema for the grid's telemetry stream (stdlib-only).

Every record a :class:`repro.obs.trace.Tracer` emits serializes to one
JSON object carrying the schema version, the event kind, its virtual-time
start ``t`` (seconds), an optional duration ``dur`` (seconds; ``null`` or
absent for instant events), and a kind-specific payload. This module is
the single source of truth for what those payloads look like: the JSONL
exporter writes records of this shape, the CI ``telemetry`` job validates
every emitted line against it, and the live-server path (ROADMAP) is
expected to reuse the same stream.

Deliberately dependency-free (``json`` + ``math`` only) so the validator
can run anywhere — including the CLI form the CI job uses:

    python -m repro.obs.schema trace.jsonl --perfetto trace.json \
        --require dispatch flush
"""
from __future__ import annotations

import json
import math
from typing import Any, Dict, Iterable, List, Optional, Tuple

SCHEMA_VERSION = 4
# schema v2 added the fault/quarantine/checkpoint kinds; v3 added the
# edge_flush/shock kinds and the optional region field on
# dispatch/upload (sim/topology.py); v4 added the top-level causal ids
# ``seq`` (monotone per-tracer emission id) / ``parent`` (seq of the
# causally-upstream record) and the optional ``t_down``/``t_comp``/
# ``t_up`` phase components on dispatch spans. Earlier streams are
# strict subsets and stay valid.
ACCEPTED_VERSIONS = (1, 2, 3, 4)

_NUM = (int, float)
_INT = (int,)
_STR = (str,)
_BOOL = (bool,)

# kind -> (required payload fields, optional payload fields); each field
# maps to the tuple of accepted Python types (post-json.loads). ``None``
# is accepted for any *optional* field — "measured but not applicable"
# is an explicit null, never a missing-vs-zero ambiguity.
EVENT_SCHEMA: Dict[str, Tuple[Dict[str, tuple], Dict[str, tuple]]] = {
    # one client round trip attempt, dispatch -> upload-complete (span;
    # dur is null when the client never finishes: sync dropout)
    "dispatch": ({"cid": _INT},
                 {"tier": _INT, "region": _INT, "down_bytes": _INT,
                  "up_bytes": _INT, "version": _INT, "outcome": _STR,
                  # v4: per-phase virtual-time components of the round
                  # trip (downlink transfer, client compute, uplink
                  # transfer), so analyze.py can split the span without
                  # re-deriving link models
                  "t_down": _NUM, "t_comp": _NUM, "t_up": _NUM}),
    # a delta arriving at the server (instant)
    "upload": ({"cid": _INT, "up_bytes": _INT},
               {"tier": _INT, "region": _INT, "staleness": _INT,
                "rtt": _NUM, "participant": _BOOL}),
    # a dispatch slot parked by a dark availability window (instant)
    "retry": ({}, {"backoff": _NUM}),
    # one buffered async server update (instant at apply time)
    "flush": ({"version": _INT, "buffer_fill": _NUM},
              {"staleness_mean": _NUM, "staleness_max": _NUM}),
    # one synchronous cohort round (span over the round's virtual time)
    "round": ({"round": _INT},
              {"participants": _NUM, "cohort": _INT, "loss": _NUM}),
    # one FlushAccountant composition step (instant)
    "dp_flush": ({"flush": _INT, "n_real": _INT, "multiplicity": _INT},
                 {"sigma": _NUM, "epsilon": _NUM, "delta": _NUM,
                  "padded": _BOOL}),
    # tier-sliced wire billing from the comm ledger (instant)
    "tier_upload": ({"tier_name": _STR, "down_bytes": _INT,
                     "up_bytes": _INT},
                    {"transfers": _INT, "uploads": _INT}),
    # --- schema v2 ---
    # one injected fault firing (sim/faults.py): crash_compute,
    # truncate_upload (frac/up_bytes = what arrived), corrupt_nan,
    # corrupt_bitflip, duplicate_upload (instant)
    "fault": ({"fault": _STR},
              {"cid": _INT, "tier": _INT, "frac": _NUM, "up_bytes": _INT}),
    # one row quarantined by the sanitize screen (core/sanitize.py)
    # before aggregation: cause is "nonfinite" or "norm-outlier"
    # (instant at the flush/round that screened it)
    "quarantine": ({"cause": _STR},
                   {"cid": _INT, "tier": _INT, "norm": _NUM,
                    "flush": _INT, "round": _INT}),
    # one grid-state snapshot written (checkpoint/grid_state.py)
    "checkpoint": ({"path": _STR},
                   {"applied": _INT, "round": _INT, "mode": _STR,
                    "buffer_fill": _NUM, "events_in_flight": _INT}),
    # --- schema v3 (sim/topology.py) ---
    # one edge aggregator forwarding its pre-reduced flat buffer
    # upstream (instant at the flush/round that drained it): fill = how
    # many client rows it reduced, up_bytes = the buffer's wire size
    "edge_flush": ({"region": _INT},
                   {"fill": _INT, "up_bytes": _INT, "norm": _NUM,
                    "round": _INT, "flush": _INT}),
    # one correlated region outage firing (sim/dynamics.RegionShocks):
    # the region's clients' availability is scaled by residual until
    # virtual time ``until`` (instant at the outage start)
    "shock": ({"region": _INT},
              {"duration": _NUM, "residual": _NUM, "until": _NUM}),
}

KINDS = tuple(EVENT_SCHEMA)


def _type_ok(value: Any, types: tuple) -> bool:
    # bool is an int subclass; never let a bool satisfy an int/num field
    if isinstance(value, bool):
        return bool in types or _BOOL == types
    if float in types and isinstance(value, _NUM):
        return True
    return isinstance(value, types)


def validate_record(rec: Any) -> List[str]:
    """Errors for one decoded JSONL record ([] = valid)."""
    if not isinstance(rec, dict):
        return [f"record is {type(rec).__name__}, not an object"]
    errs: List[str] = []
    v = rec.get("v")
    if v not in ACCEPTED_VERSIONS:
        errs.append(f"v={v!r} (expected one of {ACCEPTED_VERSIONS})")
    kind = rec.get("kind")
    if kind not in EVENT_SCHEMA:
        return errs + [f"unknown kind {kind!r}"]
    t = rec.get("t")
    if not (isinstance(t, _NUM) and not isinstance(t, bool)
            and math.isfinite(t) and t >= 0.0):
        errs.append(f"t={t!r} is not a finite non-negative number")
    dur = rec.get("dur")
    if dur is not None and not (isinstance(dur, _NUM)
                                and not isinstance(dur, bool)
                                and math.isfinite(dur) and dur >= 0.0):
        errs.append(f"dur={dur!r} is not null or a finite non-negative "
                    "number")
    # v4 causal ids are top-level (not payload) and optional — pre-v4
    # streams simply omit them.
    for name in ("seq", "parent"):
        val = rec.get(name)
        if val is not None and not (isinstance(val, int)
                                    and not isinstance(val, bool)
                                    and val >= 0):
            errs.append(f"{name}={val!r} is not null or a non-negative "
                        "integer")
    required, optional = EVENT_SCHEMA[kind]
    payload = {k: val for k, val in rec.items()
               if k not in ("v", "kind", "t", "dur", "seq", "parent")}
    for name, types in required.items():
        if name not in payload:
            errs.append(f"{kind}: missing required field {name!r}")
        elif payload[name] is None or not _type_ok(payload[name], types):
            errs.append(f"{kind}: field {name!r}={payload[name]!r} has "
                        "the wrong type")
    for name, val in payload.items():
        if name in required:
            continue
        if name not in optional:
            errs.append(f"{kind}: unexpected field {name!r}")
        elif val is not None and not _type_ok(val, optional[name]):
            errs.append(f"{kind}: field {name!r}={val!r} has the wrong "
                        "type")
    return errs


def validate_records(records: Iterable[Any]) -> List[str]:
    """All errors across a record stream, prefixed with the 1-based
    record index."""
    errs = []
    for i, rec in enumerate(records):
        errs.extend(f"record {i + 1}: {e}" for e in validate_record(rec))
    return errs


def validate_causal_ids(records: Iterable[Any]) -> List[str]:
    """v4 id-integrity errors for a decoded record stream: every record
    must carry a ``seq``, seqs must be strictly increasing (one tracer,
    emission order), every non-null ``parent`` must reference an
    already-emitted seq, and at least one parent link must exist (a
    stream with ids but no edges is a broken chain, not a graph)."""
    errs: List[str] = []
    seen: set = set()
    prev = -1
    any_parent = False
    for i, rec in enumerate(records):
        if not isinstance(rec, dict):
            continue
        seq = rec.get("seq")
        if not (isinstance(seq, int) and not isinstance(seq, bool)):
            errs.append(f"record {i + 1}: missing seq (ids required)")
            continue
        if seq <= prev:
            errs.append(f"record {i + 1}: seq={seq} not strictly "
                        f"increasing (previous {prev})")
        prev = max(prev, seq)
        parent = rec.get("parent")
        if parent is not None:
            any_parent = True
            if parent not in seen:
                errs.append(f"record {i + 1}: parent={parent} does not "
                            "reference an earlier seq")
        seen.add(seq)
    if prev >= 0 and not any_parent:
        errs.append("no parent link anywhere in the stream")
    return errs


def validate_jsonl(path: str) -> Tuple[int, List[str]]:
    """(record count, errors) for a JSONL trace file."""
    n = 0
    errs: List[str] = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            n += 1
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                errs.append(f"line {i + 1}: not valid JSON ({e})")
                continue
            errs.extend(f"line {i + 1}: {e}" for e in validate_record(rec))
    return n, errs


def validate_perfetto(path: str,
                      require: Iterable[str] = ()) -> Tuple[int, List[str]]:
    """(event count, errors) for a Chrome/Perfetto ``trace_event`` JSON
    export: the file must be loadable JSON with a ``traceEvents`` list,
    and must contain at least one non-metadata event named after each
    kind in ``require``."""
    errs: List[str] = []
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return 0, [f"not loadable JSON: {e}"]
    events = doc.get("traceEvents") if isinstance(doc, dict) else None
    if not isinstance(events, list):
        return 0, ["missing 'traceEvents' list"]
    # metadata ("M") and v4 causal flow-link pairs ("s"/"f") are derived
    # decoration, not records — the count must match the JSONL stream
    named = [e for e in events
             if isinstance(e, dict) and e.get("ph") not in ("M", "s", "f")]
    for e in named:
        ts = e.get("ts")
        if not (isinstance(ts, _NUM) and not isinstance(ts, bool)
                and math.isfinite(ts) and ts >= 0.0):
            errs.append(f"event {e.get('name')!r}: ts={ts!r} is not a "
                        "finite non-negative number")
    for kind in require:
        if not any(e.get("name") == kind for e in named):
            errs.append(f"no {kind!r} event in the trace")
    return len(named), errs


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        description="Validate a grid telemetry JSONL stream (and "
                    "optionally its Perfetto export) against the event "
                    "schema.")
    ap.add_argument("jsonl", help="JSONL trace file (one record per line)")
    ap.add_argument("--perfetto", default=None, metavar="JSON",
                    help="also validate a Chrome/Perfetto trace_event "
                         "export")
    ap.add_argument("--require", nargs="*", default=[], metavar="KIND",
                    help="event kinds that must appear in BOTH files")
    ap.add_argument("--require-ids", action="store_true",
                    help="require v4 causal ids: every record carries a "
                         "strictly-monotone seq, parents resolve, and at "
                         "least one parent link exists")
    args = ap.parse_args(argv)
    n, errs = validate_jsonl(args.jsonl)
    if n == 0:
        errs.append("no records in the JSONL stream")
    seen = set()
    decoded = []
    with open(args.jsonl) as f:
        for line in f:
            line = line.strip()
            if line:
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue
                decoded.append(rec)
                if isinstance(rec, dict):
                    seen.add(rec.get("kind"))
    for kind in args.require:
        if kind not in seen:
            errs.append(f"jsonl: no {kind!r} record in the stream")
    if args.require_ids:
        errs.extend(f"jsonl: {e}" for e in validate_causal_ids(decoded))
    print(f"{args.jsonl}: {n} records, {len(errs)} error(s)")
    if args.perfetto:
        pn, perrs = validate_perfetto(args.perfetto, require=args.require)
        print(f"{args.perfetto}: {pn} events, {len(perrs)} error(s)")
        errs.extend(perrs)
    for e in errs:
        print(f"  ERROR: {e}")
    return 1 if errs else 0


if __name__ == "__main__":
    raise SystemExit(main())
