"""Structured event tracing for the simulation grid.

A :class:`Tracer` collects typed span/event records (kinds defined in
``obs/schema.py``: ``dispatch``, ``upload``, ``retry``, ``flush``,
``round``, ``dp_flush``, ``tier_upload``) stamped in *virtual* seconds,
emitted from the scheduler, the grid driver, the per-flush DP
accountant, and the comm ledger's tier billing. Exporters
(``obs/export.py``) turn the stream into schema-versioned JSONL or a
Chrome/Perfetto timeline.

The whole layer is a no-op by default: ``GridConfig.telemetry=None``
routes every emission through the module-level :data:`NULL_TRACER`,
whose ``span``/``instant`` are empty methods — no record allocation, no
extra PRNG draws, and (test-enforced) bit-identical run histories. This
mirrors the repo's ``resolve_dynamics`` / one-tier-plan "trivial case is
exact" discipline: instrumentation you don't ask for costs nothing and
changes nothing.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

from repro.obs import export as export_lib
from repro.obs import metrics as metrics_lib
from repro.obs import schema as schema_lib

KINDS = schema_lib.KINDS


@dataclasses.dataclass
class TraceRecord:
    kind: str                       # one of schema.KINDS
    t: float                        # virtual-time start (seconds)
    dur: Optional[float]            # virtual duration; None = instant
    payload: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # --- schema v4 causal ids ---
    # seq: monotone per-Tracer emission id; parent: seq of the record
    # this one is causally downstream of (dispatch -> upload -> flush ->
    # dp_flush, ...). Both optional so positional construction and
    # pre-v4 streams stay valid.
    seq: Optional[int] = None
    parent: Optional[int] = None

    def to_json(self) -> Dict[str, Any]:
        return export_lib.record_json(self)


@dataclasses.dataclass
class TelemetryConfig:
    """What to do with the event stream a traced run produces.

    With both paths ``None`` the events just accumulate on
    ``Tracer.events`` (and ``GridResult.telemetry``) for in-process
    inspection/export. ``profile=True`` additionally wraps the jitted
    lane step and the server tail in ``jax.profiler`` annotations
    (``obs/profiling.py``) so a wall-time profile captured around the
    run lines up with the virtual-time spans."""
    jsonl_path: Optional[str] = None
    perfetto_path: Optional[str] = None
    profile: bool = False


class NullTracer:
    """The telemetry=None fast path: every emission is a no-op. A
    single shared instance (:data:`NULL_TRACER`) stands in everywhere a
    tracer is threaded, so call sites never branch."""

    enabled = False
    events: tuple = ()

    def span(self, kind: str, t: float, dur: Optional[float],
             parent: Optional[int] = None, **payload) -> None:
        pass

    def instant(self, kind: str, t: float,
                parent: Optional[int] = None, **payload) -> None:
        pass


NULL_TRACER = NullTracer()


class Tracer:
    """Collects TraceRecords in emission order (which is virtual-time
    order for the event-driven engines) and exports them on demand."""

    enabled = True

    def __init__(self, config: Optional[TelemetryConfig] = None,
                 metrics: Optional[metrics_lib.MetricsRegistry] = None):
        self.config = config or TelemetryConfig()
        self.metrics = metrics or metrics_lib.MetricsRegistry()
        self.events: List[TraceRecord] = []
        self._next_seq = 0

    def span(self, kind: str, t: float, dur: Optional[float],
             parent: Optional[int] = None, **payload) -> int:
        seq = self._next_seq
        self._next_seq = seq + 1
        self.events.append(TraceRecord(
            kind, float(t), None if dur is None else float(dur), payload,
            seq=seq, parent=parent))
        return seq

    def instant(self, kind: str, t: float,
                parent: Optional[int] = None, **payload) -> int:
        return self.span(kind, t, None, parent=parent, **payload)

    # --- inspection -----------------------------------------------------
    def kind_counts(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for rec in self.events:
            counts[rec.kind] = counts.get(rec.kind, 0) + 1
        return counts

    def of_kind(self, kind: str) -> List[TraceRecord]:
        return [rec for rec in self.events if rec.kind == kind]

    # --- export ---------------------------------------------------------
    def export_jsonl(self, path: str) -> int:
        return export_lib.write_jsonl(self.events, path)

    def export_perfetto(self, path: str) -> int:
        return export_lib.write_perfetto(self.events, path)

    def flush_outputs(self) -> None:
        """Write whatever the config asked for (called once at the end
        of a traced grid run)."""
        if self.config.jsonl_path:
            self.export_jsonl(self.config.jsonl_path)
        if self.config.perfetto_path:
            self.export_perfetto(self.config.perfetto_path)


def resolve_telemetry(spec: Any) -> Optional[TelemetryConfig]:
    """GridConfig.telemetry -> TelemetryConfig or None (= NULL_TRACER).

    Accepts ``None`` (off), a ``TelemetryConfig``, ``True`` / ``"on"`` /
    ``"memory"`` (trace in memory, export manually), or a dict of
    TelemetryConfig fields."""
    if spec is None:
        return None
    if isinstance(spec, TelemetryConfig):
        return spec
    if spec is True or spec in ("on", "memory"):
        return TelemetryConfig()
    if isinstance(spec, dict):
        return TelemetryConfig(**spec)
    raise ValueError(f"unknown telemetry spec {spec!r} (expected None, "
                     "a TelemetryConfig, True/'on'/'memory', or a dict "
                     "of TelemetryConfig fields)")
