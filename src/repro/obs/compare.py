"""Diff two grid runs — JSONL traces and/or metrics snapshots — as flat
scalar tables, with CI-gating thresholds (stdlib-only).

Each input is flattened to ``name -> number``:

* a ``MetricsRegistry.snapshot()`` JSON becomes ``counter.<name>`` (plus
  ``counter.<name>/<label>`` per label), ``gauge.<name>`` and
  ``hist.<name>.count|mean|min|max``;
* a telemetry JSONL trace is run through ``obs/analyze.py`` and becomes
  ``kind.<k>`` / ``fault.<k>`` / ``quarantine.<k>`` counts,
  ``phase.<k>`` critical-path totals, ``virtual_seconds``, ``rounds``,
  ``wire.<tier>.up_bytes|down_bytes|transfers|uploads`` and
  ``privacy.epsilon_final`` / ``privacy.flushes``.

The two sides need not be the same kind of file — any overlapping names
diff; one-sided names show as added/removed.

``--fail-on 'PAT[:RELTOL]'`` (repeatable, fnmatch globs) turns the diff
into a gate: exit 1 if any matching metric differs by more than RELTOL
relative (default 0 = must match exactly), or exists on only one side.

    python -m repro.obs.compare golden.json run.json \
        --fail-on 'counter.dispatches' --fail-on 'counter.tier_*' \
        --fail-on 'phase.*:0.05' -o diff.md
"""
from __future__ import annotations

import fnmatch
import json
from typing import Dict, List, Optional, Tuple

from repro.obs import analyze as analyze_lib


def flatten_snapshot(doc: dict) -> Dict[str, float]:
    """Flat scalars from a MetricsRegistry.snapshot() dict."""
    out: Dict[str, float] = {}
    for kind, prefix in (("counters", "counter"), ("gauges", "gauge")):
        for name, rec in doc.get(kind, {}).items():
            out[f"{prefix}.{name}"] = float(rec.get("value", 0.0))
            for label, v in (rec.get("labels") or {}).items():
                out[f"{prefix}.{name}/{label}"] = float(v)
    for name, summ in doc.get("histograms", {}).items():
        for stat in ("count", "mean", "min", "max"):
            if summ.get(stat) is not None:
                out[f"hist.{name}.{stat}"] = float(summ[stat])
    return out


def flatten_trace(path: str) -> Dict[str, float]:
    """Flat scalars from a telemetry JSONL trace via obs/analyze."""
    a = analyze_lib.analyze(path)
    out: Dict[str, float] = {"virtual_seconds": float(a.virtual_seconds),
                             "rounds": float(len(a.breakdowns))}
    for k, v in a.counts["kinds"].items():
        out[f"kind.{k}"] = float(v)
    for k, v in a.counts["faults"].items():
        out[f"fault.{k}"] = float(v)
    for k, v in a.counts["quarantine"].items():
        out[f"quarantine.{k}"] = float(v)
    for k, v in a.phase_totals.items():
        out[f"phase.{k}"] = float(v)
    for tier, rec in a.wire.items():
        for field, v in rec.items():
            out[f"wire.{tier}.{field}"] = float(v)
    if a.privacy:
        out["privacy.epsilon_final"] = float(a.privacy[-1]["epsilon"])
        out["privacy.flushes"] = float(len(a.privacy))
    return out


def flatten(path: str) -> Dict[str, float]:
    """Flatten one input file, sniffing its format: a JSON object with
    a ``counters``/``gauges`` key is a metrics snapshot, anything else
    is treated as a JSONL trace."""
    try:
        with open(path) as f:
            doc = json.load(f)
        if isinstance(doc, dict) and ("counters" in doc or "gauges" in doc):
            return flatten_snapshot(doc)
    except (json.JSONDecodeError, UnicodeDecodeError):
        pass  # multi-line JSONL (or not JSON at all): fall through
    return flatten_trace(path)


def parse_fail_on(patterns: List[str]) -> List[Tuple[str, float]]:
    """'PAT' or 'PAT:RELTOL' -> (glob, reltol). A bare PAT means exact
    match required (reltol 0)."""
    out = []
    for p in patterns:
        if ":" in p:
            pat, tol = p.rsplit(":", 1)
            out.append((pat, float(tol)))
        else:
            out.append((p, 0.0))
    return out


def diff(a: Dict[str, float], b: Dict[str, float],
         fail_on: Optional[List[Tuple[str, float]]] = None
         ) -> Tuple[List[dict], List[str]]:
    """Rows over the union of metric names, plus the list of gate
    violations (empty when nothing matched --fail-on or all matches
    were within tolerance)."""
    rows: List[dict] = []
    violations: List[str] = []
    for name in sorted(set(a) | set(b)):
        va, vb = a.get(name), b.get(name)
        if va is None or vb is None:
            delta = rel = float("nan")
        else:
            delta = vb - va
            rel = delta / max(abs(va), abs(vb), 1e-12)
        rows.append({"name": name, "a": va, "b": vb,
                     "delta": delta, "rel": rel})
        for pat, tol in (fail_on or []):
            if not fnmatch.fnmatch(name, pat):
                continue
            if va is None or vb is None:
                violations.append(
                    f"{name}: present on only one side "
                    f"(a={va!r}, b={vb!r}) [{pat}]")
            elif abs(rel) > tol:
                violations.append(
                    f"{name}: {va:g} -> {vb:g} "
                    f"(rel {rel:+.3%} > tol {tol:.3%}) [{pat}]")
            break  # first matching pattern wins
    return rows, violations


def render(rows: List[dict], label_a: str, label_b: str,
           violations: Optional[List[str]] = None,
           changed_only: bool = False) -> str:
    lines = [f"# Run diff: `{label_a}` vs `{label_b}`", ""]
    shown = [r for r in rows
             if not changed_only or r["delta"] != 0.0]
    n_same = len(rows) - len(shown)
    lines += [f"| metric | {label_a} | {label_b} | delta | rel |",
              "|---|---|---|---|---|"]
    for r in shown:
        fa = "—" if r["a"] is None else f"{r['a']:g}"
        fb = "—" if r["b"] is None else f"{r['b']:g}"
        if r["a"] is None or r["b"] is None:
            fd, fr = "—", "—"
        else:
            fd, fr = f"{r['delta']:+g}", f"{r['rel']:+.2%}"
        lines.append(f"| {r['name']} | {fa} | {fb} | {fd} | {fr} |")
    lines.append("")
    if changed_only and n_same:
        lines += [f"({n_same} unchanged metrics hidden)", ""]
    if violations:
        lines += ["## Gate violations", ""]
        lines += [f"- {v}" for v in violations]
        lines.append("")
    elif violations is not None:
        lines += ["All gated metrics within tolerance.", ""]
    return "\n".join(lines).rstrip() + "\n"


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        description="Diff two runs (telemetry JSONL traces and/or "
                    "metrics-snapshot JSON) as flat scalars; --fail-on "
                    "turns matching metrics into a CI gate.")
    ap.add_argument("a", help="baseline: trace JSONL or snapshot JSON")
    ap.add_argument("b", help="candidate: trace JSONL or snapshot JSON")
    ap.add_argument("--fail-on", action="append", default=[],
                    metavar="PAT[:RELTOL]",
                    help="fnmatch glob over metric names; exit 1 if a "
                         "matching metric differs by more than RELTOL "
                         "relative (default 0 = exact). Repeatable; "
                         "first matching pattern wins per metric.")
    ap.add_argument("--changed-only", action="store_true",
                    help="hide rows with zero delta")
    ap.add_argument("-o", "--out", default=None, metavar="MD",
                    help="write the diff table here (default: stdout)")
    args = ap.parse_args(argv)
    fa, fb = flatten(args.a), flatten(args.b)
    gates = parse_fail_on(args.fail_on) or None
    rows, violations = diff(fa, fb, gates)
    text = render(rows, args.a, args.b,
                  violations if gates else None,
                  changed_only=args.changed_only)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
        print(f"wrote {args.out} ({len(rows)} metrics, "
              f"{len(violations)} violations)")
    else:
        print(text)
    if violations:
        for v in violations:
            print(f"FAIL {v}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
