"""Metrics registry: counters, gauges and histograms with a snapshot API.

One :class:`MetricsRegistry` instance rides along with every grid run —
telemetry on or off — and is the single source of truth for the run's
scalar observables: the scheduler's dispatch/upload/dropout/retry
counters, the per-tier wire and timing accumulators, and the per-tier
compute gauges. ``GridResult.scheduler_stats`` / ``tier_stats`` are
*views* over it (the dict values are read back out of the registry), so
consumers can either keep using those dicts or take
``registry.snapshot()`` and get the same numbers plus everything else.

Metrics are plain Python accumulation (no JAX, no locks — the grid is
single-threaded), and each metric optionally splits by a hashable
``label`` (tier index, event kind, ...) on top of its global value.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Hashable, Optional

SNAPSHOT_VERSION = 1


class Counter:
    """Monotonic accumulator with an optional per-label breakdown."""

    __slots__ = ("name", "value", "labels")

    def __init__(self, name: str):
        self.name = name
        self.value = 0
        self.labels: Dict[Hashable, Any] = {}

    def inc(self, amount=1, label: Optional[Hashable] = None) -> None:
        self.value += amount
        if label is not None:
            self.labels[label] = self.labels.get(label, 0) + amount

    def get(self, label: Hashable, default=0):
        return self.labels.get(label, default)


class Gauge:
    """Last-written value (plus per-label last-written values)."""

    __slots__ = ("name", "value", "labels")

    def __init__(self, name: str):
        self.name = name
        self.value: Any = None
        self.labels: Dict[Hashable, Any] = {}

    def set(self, value, label: Optional[Hashable] = None) -> None:
        self.value = value
        if label is not None:
            self.labels[label] = value

    def get(self, label: Hashable, default=None):
        return self.labels.get(label, default)


class Histogram:
    """Streaming count/sum/min/max (mean is derived at snapshot time —
    enough for the grid's timing distributions without storing samples)."""

    __slots__ = ("name", "count", "total", "min", "max")

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        value = float(value)
        self.count += 1
        self.total += value
        self.min = min(self.min, value)
        self.max = max(self.max, value)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def summary(self) -> Dict[str, float]:
        return {"count": self.count, "sum": self.total, "mean": self.mean,
                "min": self.min if self.count else 0.0,
                "max": self.max if self.count else 0.0}


@dataclasses.dataclass
class MetricsRegistry:
    counters: Dict[str, Counter] = dataclasses.field(default_factory=dict)
    gauges: Dict[str, Gauge] = dataclasses.field(default_factory=dict)
    histograms: Dict[str, Histogram] = dataclasses.field(
        default_factory=dict)

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(name)
        return h

    def snapshot(self) -> Dict[str, Any]:
        """One JSON-serializable dict of every metric's current state.
        Labels are stringified (tier indices become "0", "1", ...) so
        the snapshot round-trips through json without surprises."""
        return {
            "v": SNAPSHOT_VERSION,
            "counters": {
                n: {"value": c.value,
                    "labels": {str(k): v for k, v in c.labels.items()}}
                for n, c in sorted(self.counters.items())},
            "gauges": {
                n: {"value": g.value,
                    "labels": {str(k): v for k, v in g.labels.items()}}
                for n, g in sorted(self.gauges.items())},
            "histograms": {n: h.summary()
                           for n, h in sorted(self.histograms.items())},
        }

    def state_dict(self) -> Dict[str, Any]:
        """Exact restorable state — unlike :meth:`snapshot`, labels are
        kept as ``[key, value]`` pairs so integer label keys (tier
        indices) survive a JSON round trip, and histograms keep their
        raw accumulators (min/max stored as ``None`` when empty)."""
        return {
            "counters": {
                n: [c.value, [[k, v] for k, v in c.labels.items()]]
                for n, c in self.counters.items()},
            "gauges": {
                n: [g.value, [[k, v] for k, v in g.labels.items()]]
                for n, g in self.gauges.items()},
            "histograms": {
                n: [h.count, h.total,
                    None if h.count == 0 else h.min,
                    None if h.count == 0 else h.max]
                for n, h in self.histograms.items()},
        }

    def load_state(self, state: Dict[str, Any]) -> None:
        """Restore in place from a :meth:`state_dict` blob (metrics not
        named in the blob are left untouched — a restored run registers
        the same names anyway)."""
        for n, (value, labels) in state.get("counters", {}).items():
            c = self.counter(n)
            c.value = value
            c.labels = {k: v for k, v in labels}
        for n, (value, labels) in state.get("gauges", {}).items():
            g = self.gauge(n)
            g.value = value
            g.labels = {k: v for k, v in labels}
        for n, (count, total, lo, hi) in state.get(
                "histograms", {}).items():
            h = self.histogram(n)
            h.count = int(count)
            h.total = float(total)
            h.min = math.inf if lo is None else float(lo)
            h.max = -math.inf if hi is None else float(hi)
