"""Mistral-Nemo 12B [hf:mistralai/Mistral-Nemo-Base-2407] — dense GQA,
head_dim 128 (not d_model/heads), 128k context."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mistral-nemo-12b", family="dense",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=131072,
    rope_theta=1e6, max_seq_len=131072,
    freeze_spec=(r"/ffn/(wi_gate|wi_up|wo)/kernel$",),
    source="hf:mistralai/Mistral-Nemo-Base-2407",
))
