"""PaliGemma-3B language backbone [arXiv:2407.07726] — Gemma decoder
(MQA kv=1, head_dim 256, GeGLU, tied embeddings) consuming 256 SigLIP
patch embeddings via a linear projector. The SigLIP vision tower is a
STUB per the assignment: input_specs() provides (B, 256, 1152) patch
embeddings; we implement the language/decoder transformer."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="paligemma-3b", family="vlm",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1,
    head_dim=256, d_ff=16384, vocab_size=257216,
    act="gelu", tie_embeddings=True,
    num_prefix_tokens=256,
    freeze_spec=(r"/ffn/(wi_gate|wi_up|wo)/kernel$",),
    source="arXiv:2407.07726",
))
