"""Whisper large-v3 transformer backbone [arXiv:2212.04356] — 32-layer
encoder + 32-layer decoder with cross-attention, LayerNorm, GELU,
sinusoidal positions, no gating. The mel-spectrogram + conv2 frontend is
a STUB per the assignment: input_specs() provides (B, 1500, 1280) frame
embeddings directly."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-large-v3", family="audio",
    num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
    d_ff=5120, vocab_size=51866,
    is_encoder_decoder=True, encoder_layers=32, encoder_seq_len=1500,
    norm_type="layernorm", act="gelu", gated_mlp=False, use_rope=False,
    # FedPT: freeze encoder FFNs — the paper's own Transformer experiment
    # (SO NWP, Table 11) freezes encoder FFN hidden layers.
    freeze_spec=(r"^enc_layers/.*/ffn/",),
    source="arXiv:2212.04356",
))
