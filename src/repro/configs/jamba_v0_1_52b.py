"""Jamba v0.1 52B [arXiv:2403.19887] — hybrid Mamba+attention 1:7
interleave (one attention layer per 8), MoE (16 experts top-2) on every
second layer."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=65536,
    num_experts=16, num_experts_per_tok=2, moe_period=2,
    router_aux_loss=0.02,
    attn_period=8,
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
    use_rope=False,  # Jamba uses no positional encoding (Mamba carries order)
    # FedPT: freeze experts + the large Mamba in/out projections; dt/A/D,
    # conv, gates, router, attention and norms stay trainable.
    freeze_spec=(r"/moe/(wi_gate|wi_up|wo)$",
                 r"/mamba/(in_proj|out_proj)/kernel$",
                 r"/ffn/(wi_gate|wi_up|wo)/kernel$"),
    source="arXiv:2403.19887",
))
