"""Mixtral 8x7B [arXiv:2401.04088] — 8-expert top-2 MoE with GQA and
sliding-window attention (window 4096, rolling-buffer KV cache)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=32000,
    num_experts=8, num_experts_per_tok=2, moe_period=1,
    router_aux_loss=0.02,
    sliding_window=4096, rope_theta=1e6,
    # FedPT: freeze the routed expert FFNs (the dominant parameter block);
    # router, attention and norms stay trainable (paper recipe #1).
    freeze_spec=(r"/moe/(wi_gate|wi_up|wo)$",),
    source="arXiv:2401.04088",
))
