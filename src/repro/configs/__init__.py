"""Architecture configs. ``load_all()`` imports every per-arch module so
the registry is populated; ``get_config(name)`` fetches one.
"""
import importlib

from repro.configs.base import ModelConfig, get_config, list_configs, register

_MODULES = (
    "mixtral_8x7b",
    "deepseek_v2_236b",
    "qwen2_5_3b",
    "jamba_v0_1_52b",
    "mistral_nemo_12b",
    "glm4_9b",
    "paligemma_3b",
    "xlstm_350m",
    "whisper_large_v3",
    "stablelm_1_6b",
)

_loaded = False


def load_all():
    global _loaded
    if _loaded:
        return
    for m in _MODULES:
        importlib.import_module(f"repro.configs.{m}")
    _loaded = True


# canonical arch-id (CLI --arch) -> module config name
ARCH_IDS = {
    "mixtral-8x7b": "mixtral-8x7b",
    "deepseek-v2-236b": "deepseek-v2-236b",
    "qwen2.5-3b": "qwen2.5-3b",
    "jamba-v0.1-52b": "jamba-v0.1-52b",
    "mistral-nemo-12b": "mistral-nemo-12b",
    "glm4-9b": "glm4-9b",
    "paligemma-3b": "paligemma-3b",
    "xlstm-350m": "xlstm-350m",
    "whisper-large-v3": "whisper-large-v3",
    "stablelm-1.6b": "stablelm-1.6b",
}
