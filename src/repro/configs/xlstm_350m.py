"""xLSTM-350M [arXiv:2405.04517] — mLSTM (matrix memory, chunkwise
parallel) blocks with an sLSTM (scalar memory) block every 4th layer.
d_ff=0: the cells carry their own up/down projections."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-350m", family="ssm",
    num_layers=24, d_model=1024, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    slstm_every=4, xlstm_proj_factor=2.0,
    use_rope=False, tie_embeddings=True,
    # FedPT: freezing the recurrent/projection kernels = the echo-state
    # regime the paper cites (Jaeger 2002); gates & norms stay trainable.
    freeze_spec=(r"/mlstm/(wq|wk|wv|up_proj|down_proj)/kernel$",
                 r"/slstm/(r_gates|up_gate|up_proj|down_proj)"),
    source="arXiv:2405.04517",
))
