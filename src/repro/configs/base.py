"""Model configuration system.

Every architecture (the paper's own three models and the ten assigned
architectures) is described by a single ``ModelConfig``. The FedPT freeze
specification is a first-class field: a tuple of regexes over parameter
paths (``layers/attn/wq`` style) that selects the *frozen* subset.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Optional

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Block kinds used by the hybrid / ssm stacks.
ATTN = "attn"
MAMBA = "mamba"
MLSTM = "mlstm"
SLSTM = "slstm"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Configuration of a transformer-family model.

    The same dataclass covers dense, MoE, hybrid (attention+Mamba), SSM
    (xLSTM), VLM and audio (encoder-decoder) architectures; the family
    field selects the stack wiring.
    """

    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0          # expert hidden dim (0 -> d_ff)
    router_aux_loss: float = 0.0
    moe_capacity_factor: float = 1.25
    # perf knobs (hillclimb variants; 0/auto = paper-faithful baseline)
    moe_dispatch_groups: int = 0   # >1: group-local sort dispatch
    expert_shard: str = "auto"     # auto | model | 2d | 2d_swapped
    decode_seq_parallel: bool = False  # flash-decoding style cache attn

    # --- MLA (DeepSeek-V2) ----------------------------------------------------
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # --- attention details ----------------------------------------------------
    qkv_bias: bool = False
    sliding_window: int = 0    # 0 = full attention
    rope_theta: float = 10000.0
    use_rope: bool = True
    attn_logit_softcap: float = 0.0

    # --- hybrid (Jamba) -------------------------------------------------------
    attn_period: int = 0       # one attention layer per `attn_period` layers
    moe_period: int = 1        # MoE FFN every `moe_period` layers (else dense)

    # --- Mamba ---------------------------------------------------------------
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # --- xLSTM ---------------------------------------------------------------
    slstm_every: int = 0       # an sLSTM block every k blocks (0 = none)
    xlstm_proj_factor: float = 2.0

    # --- encoder-decoder / multimodal ------------------------------------------
    encoder_layers: int = 0
    is_encoder_decoder: bool = False
    num_prefix_tokens: int = 0   # VLM patch / audio frame embeddings (stub frontend)
    encoder_seq_len: int = 0     # fixed encoder length (audio)

    # --- misc ------------------------------------------------------------------
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"            # silu | gelu | relu
    gated_mlp: bool = True
    tie_embeddings: bool = False
    max_seq_len: int = 32768
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # --- FedPT ------------------------------------------------------------------
    # regexes over parameter paths selecting the FROZEN subset.
    freeze_spec: tuple = ()
    # citation for the architecture numbers
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff if self.moe_d_ff else self.d_ff

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def block_kinds(self):
        """Sequence of block kinds (length num_layers)."""
        kinds = []
        for i in range(self.num_layers):
            if self.family == "hybrid" and self.attn_period:
                # Jamba: one attention layer per period, at the middle slot
                # of each period-group (arXiv:2403.19887 uses offset 4 of 8).
                kinds.append(ATTN if (i % self.attn_period) == self.attn_period // 2 else MAMBA)
            elif self.family == "ssm":
                if self.slstm_every and (i % self.slstm_every) == self.slstm_every - 1:
                    kinds.append(SLSTM)
                else:
                    kinds.append(MLSTM)
            else:
                kinds.append(ATTN)
        return kinds

    def layer_uses_moe(self, i: int) -> bool:
        if self.num_experts <= 0:
            return False
        return (i % self.moe_period) == (self.moe_period - 1)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry

_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # lazily import config modules
        from repro import configs as _c  # noqa: F401
        _c.load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown architecture {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs():
    from repro import configs as _c
    _c.load_all()
    return dict(_REGISTRY)


def match_freeze(path: str, freeze_spec) -> bool:
    """True if a parameter path is frozen under the spec."""
    return any(re.search(pat, path) for pat in freeze_spec)
