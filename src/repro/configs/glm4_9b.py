"""GLM-4 9B [hf:THUDM/glm-4-9b] — dense, GQA kv=2, RoPE, SwiGLU."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="glm4-9b", family="dense",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=2,
    d_ff=13696, vocab_size=151552,
    qkv_bias=True, rope_theta=1e6,
    freeze_spec=(r"/ffn/(wi_gate|wi_up|wo)/kernel$",),
    source="hf:THUDM/glm-4-9b",
))
