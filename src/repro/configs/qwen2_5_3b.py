"""Qwen2.5-3B-class dense model [hf:Qwen/Qwen2.5-0.5B family card] —
GQA (kv=2), QKV bias, tied embeddings."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2.5-3b", family="dense",
    num_layers=36, d_model=2048, num_heads=16, num_kv_heads=2,
    d_ff=11008, vocab_size=151936,
    qkv_bias=True, tie_embeddings=True, rope_theta=1e6,
    freeze_spec=(r"/ffn/(wi_gate|wi_up|wo)/kernel$",),
    source="hf:Qwen/Qwen2.5-0.5B",
))
