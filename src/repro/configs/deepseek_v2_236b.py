"""DeepSeek-V2 236B [arXiv:2405.04434] — MLA (kv_lora 512) + 160 routed
experts top-6 with 2 shared experts (expert FFN dim 1536)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-236b", family="moe",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
    d_ff=12288, vocab_size=102400,
    num_experts=160, num_experts_per_tok=6, num_shared_experts=2,
    moe_d_ff=1536, moe_period=1, router_aux_loss=0.003,
    use_mla=True, kv_lora_rank=512, q_lora_rank=1536,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    # NOTE (DESIGN.md deviation log): the real model keeps layer 0 dense;
    # we make all 60 layers MoE to keep the scan program homogeneous.
    freeze_spec=(r"/moe/(wi_gate|wi_up|wo)$",),
    source="arXiv:2405.04434",
))
