"""Adaptive (quantile-based) clipping for DP-FedAvg / DP-FTRL
(Andrew et al. 2021, "Differentially Private Learning with Adaptive
Clipping" — the production companion to the paper's fixed clip_norm 0.3).

The clip norm C_t tracks a target quantile gamma of client update norms
via geometric updates:  C_{t+1} = C_t * exp(-eta_C (b_t - gamma)), where
b_t is the (noised, for DP) fraction of clients whose update fit inside
C_t. With FedPT the norms live in the trainable subspace only, so the
estimator adapts to the reduced dimension automatically.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdaptiveClipConfig:
    initial_clip: float = 0.1
    target_quantile: float = 0.5
    lr: float = 0.2               # eta_C
    fraction_noise_std: float = 0.0  # sigma_b for DP on the count


def init_state(cfg: AdaptiveClipConfig):
    return {"clip": jnp.asarray(cfg.initial_clip, jnp.float32),
            "t": jnp.zeros((), jnp.int32)}


def update_state(cfg: AdaptiveClipConfig, state, norms, rng=None):
    """norms: (clients,) pre-clip update norms. Returns (new_state, clip)."""
    clip = state["clip"]
    b = jnp.mean((norms <= clip).astype(jnp.float32))
    if cfg.fraction_noise_std > 0 and rng is not None:
        b = b + cfg.fraction_noise_std * jax.random.normal(rng, ())
    new_clip = clip * jnp.exp(-cfg.lr * (b - cfg.target_quantile))
    return {"clip": new_clip, "t": state["t"] + 1}, clip


def clipped_mean(deltas, norms, clip):
    """Clip each client delta to `clip` and average (uniform weights)."""
    scale = jnp.minimum(1.0, clip / jnp.maximum(norms, 1e-12))
    return jax.tree_util.tree_map(
        lambda d: jnp.mean(d * scale.reshape((-1,) + (1,) * (d.ndim - 1)),
                           axis=0), deltas)
