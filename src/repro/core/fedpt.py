"""FedPT round engine — Algorithm 1 of the paper, as a single jitted
mesh program.

One federated round:
  1. server "sends" (y_t, z): under datacenter simulation the trainable
     tree y is broadcast along the client (data) mesh axis and the frozen
     tree is regenerated from the seed (never communicated);
  2. every sampled client runs tau local ClientOpt steps with gradients
     flowing only into y (the frozen side is a constant input -> XLA
     allocates no grad buffers or optimizer state for it);
  3. client deltas are clipped (optionally, for DP) and weighted-mean
     aggregated — on the mesh this is the cross-client psum whose payload
     FedPT shrinks by |frozen|/|full|;
  4. ServerOpt treats -delta as a pseudo-gradient.

The engine is model-agnostic: it takes any ``loss_fn(params, batch)``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

import repro.core.partition as part
from repro.core import flat as flat_lib
from repro.core import sanitize as sanitize_lib
from repro.kernels import ops as kernel_ops
from repro.optim import optimizers as opt_lib


@dataclasses.dataclass(frozen=True)
class RoundConfig:
    clients_per_round: int
    local_steps: int            # tau
    local_batch: int
    client_opt: str = "sgd"
    client_lr: float = 0.05
    server_opt: str = "sgd"
    server_lr: float = 1.0
    server_momentum: float = 0.9
    # DP (DP-FedAvg clip/noise; DP-FTRL lives in core/dp.py ServerOpt)
    dp_clip_norm: float = 0.0   # 0 = off
    dp_noise_multiplier: float = 0.0
    uniform_weights: bool = False  # DP requires fixed (uniform) weighting
    # lossy uplink compression of client deltas (0 = off); complementary
    # to FedPT per the paper's §2/§5
    uplink_bits: int = 0


def make_client_update(loss_fn: Callable, client_opt: opt_lib.Optimizer,
                       local_steps: int):
    """Returns f(y, frozen, client_batch[, grad_mask]) -> (delta, metrics).

    client_batch: pytree with leading axis tau (one microbatch per local
    step). Gradients are taken wrt y only. ``grad_mask`` (optional 0/1
    tree over y) zeroes the gradient of frozen-for-this-tier leaves each
    local step — exact freezing under SGD-family ClientOpts — and the
    final delta is masked again (belt & braces) so a tiered client's
    upload is structurally zero outside its tier.
    """

    def client_update(y0, frozen, client_batch, grad_mask=None):
        opt_state = client_opt.init(y0)

        def local_step(carry, mb):
            y, st = carry
            def loss_of_y(yy):
                full = part.merge(yy, jax.tree_util.tree_map(
                    jax.lax.stop_gradient, frozen))
                out = loss_fn(full, mb)
                return (out[0], out[1]) if isinstance(out, tuple) else (out, {})
            (loss, _aux), grads = jax.value_and_grad(loss_of_y,
                                                     has_aux=True)(y)
            if grad_mask is not None:
                grads = jax.tree_util.tree_map(
                    lambda g, m: g * m.astype(g.dtype), grads, grad_mask)
            y, st = client_opt.update(y, grads, st)
            return (y, st), loss

        (y_fin, _), losses = jax.lax.scan(local_step, (y0, opt_state),
                                          client_batch)
        delta = opt_lib.tree_sub(y_fin, y0)
        if grad_mask is not None:
            delta = jax.tree_util.tree_map(
                lambda d, m: d * m.astype(d.dtype), delta, grad_mask)
        return delta, {"client_loss": jnp.mean(losses)}

    return client_update


def clip_delta(delta, clip_norm: float):
    """Per-client L2 clipping: delta * min(1, C/||delta||).

    Runs over the flat buffer — the fused dp_clip.py kernel on TPU, the
    reshaped kernels/ref.py fallback on CPU — instead of a per-leaf
    tree sweep. Accepts and returns a tree (or a flat fp32 vector, in
    which case no unflatten round-trip is paid)."""
    if isinstance(delta, jnp.ndarray) and delta.ndim == 1:
        layout = None
        vec = delta
    else:
        layout = flat_lib.FlatLayout.of(delta)
        vec = layout.flatten(delta)
    clipped, nrm = flat_lib.clip(vec, clip_norm, layout)
    if layout is None:
        return clipped, nrm
    # match the old tree-path dtype behaviour: leaves keep their dtype
    return layout.unflatten(clipped), nrm


def resolve_server_opt(rc: RoundConfig) -> opt_lib.Optimizer:
    """The ServerOpt a RoundConfig names (shared by the sync round engine
    and the async grid, so the two can't drift)."""
    if rc.server_opt == "sgdm":
        return opt_lib.sgdm(rc.server_lr, rc.server_momentum)
    return opt_lib.get_optimizer(rc.server_opt, rc.server_lr)


def make_round_fn(loss_fn: Callable, rc: RoundConfig,
                  server_opt: Optional[opt_lib.Optimizer] = None,
                  donate: bool = True, constrain_fn: Optional[Callable] = None,
                  constrain_flat_fn: Optional[Callable] = None,
                  constrain_batch_fn: Optional[Callable] = None,
                  plan=None, sanitize=None, fused_threshold=None):
    """Builds round_step(y, server_state, frozen, batch, weights, rng) —
    or, under a non-trivial trainability ``plan``,
    round_step(y, server_state, frozen, batch, weights, tiers, rng).

    batch: pytree, leaves (clients, tau, local_batch, ...).
    weights: (clients,) float — e.g. #examples per client (paper's p_i).
    tiers: (clients,) int32 tier index per cohort slot (plan mode only).
    rng: PRNG key for DP noise (ignored when DP is off).
    constrain_fn(tree, clients: bool): optional sharding-constraint hook
    used on the mesh — pins the per-client trainable copies to the data
    axis so GSPMD never replicates C copies of y per device.
    constrain_flat_fn(arr, clients: bool): same, for the flat delta
    buffer ((C, size) when clients=True, (size,) when False).
    constrain_batch_fn(tree): same, for the cohort input batch — pins
    each leaf's leading (client) axis to the data mesh axes (see
    ``launch/sharding.cohort_constrainer``), so SYNC-mode inputs land
    data-parallel instead of replicated.
    plan: a ``core.plan.CompiledPlan``. Trivial plans (one tier, nothing
    extra frozen) take the exact single-spec path below — bit for bit.
    Non-trivial plans mask each client's gradients with its tier's leaf
    mask every local step (exact freezing under SGD-family ClientOpts),
    so frozen-for-this-tier blocks contribute zero delta; aggregation
    divides per block by the tier-mask-weighted participant sum, so
    those blocks also carry zero *weight*. Under DP the denominator
    stays the fixed ``clients_per_round`` — clipping the masked row
    bounds per-client sensitivity unchanged, so clip norms and sigma
    are tier-independent.

    The aggregation tail (quantize / clip / weighted mean / DP noise)
    runs over ``core.flat.FlatLayout`` buffers: client deltas are
    flattened *inside* the vmapped client step, so each per-client pass
    is one op over (C, size) instead of a tree_map per leaf. With DP
    and quantization off the result is bit-for-bit the old tree path
    (same dot_general over the client axis).

    ``sanitize`` (a ``core.sanitize.SanitizeConfig``) screens the (C,
    size) delta buffer FIRST — before quantization and clipping, since a
    NaN norm would poison the clip weights too: quarantined rows
    (non-finite / norm-outlier) are zeroed with zero weight, the
    quarantine masks land in the returned metrics, and under DP the
    fixed denominator is untouched (sigma stays calibrated). With clean
    data the screened aggregate is bit-identical to ``sanitize=None``.
    """
    client_opt = opt_lib.get_optimizer(rc.client_opt, rc.client_lr)
    if server_opt is None:
        server_opt = resolve_server_opt(rc)
    client_update = make_client_update(loss_fn, client_opt, rc.local_steps)
    tiered = plan is not None and not plan.trivial

    def _round_step(y, server_state, frozen, batch, weights, tiers, rng):
        layout = flat_lib.FlatLayout.of(y)   # static: shapes only
        if constrain_batch_fn is not None:
            batch = constrain_batch_fn(batch)
        if tiered:
            # (n_tiers,) per leaf, indexed by each client's runtime tier
            stacked_masks = jax.tree_util.tree_map(
                lambda *ms: jnp.stack(ms), *plan.leaf_masks())

        def flat_client(y0, cb, tier):
            if tiered:
                mask = jax.tree_util.tree_map(lambda s: s[tier],
                                              stacked_masks)
                delta, metrics = client_update(y0, frozen, cb, mask)
            else:
                delta, metrics = client_update(y0, frozen, cb)
            return layout.flatten(delta), metrics

        # --- local training on every sampled client (vmapped over the
        # client axis; under pjit that axis is sharded over `data`) -----
        if constrain_fn is not None:
            C = weights.shape[0]
            yb = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (C,) + a.shape), y)
            yb = constrain_fn(yb, clients=True)
            if tiered:
                deltas, metrics = jax.vmap(flat_client)(yb, batch, tiers)
            else:
                deltas, metrics = jax.vmap(
                    lambda yc, cb: flat_client(yc, cb, None))(yb, batch)
        elif tiered:
            deltas, metrics = jax.vmap(
                lambda cb, t: flat_client(y, cb, t))(batch, tiers)
        else:
            deltas, metrics = jax.vmap(
                lambda cb: flat_client(y, cb, None))(batch)
        if constrain_flat_fn is not None:
            deltas = constrain_flat_fn(deltas, clients=True)

        # --- the whole server tail — quarantine screen, lossy uplink
        # quantize, clip fold, weighted/fixed-denominator mean, output
        # constraint, DP Gaussian noise — as ONE dispatched op
        # (kernels/ops.agg_tail): staged per-op sequence for small
        # buffers (bit-identical to the historical tail), the fused
        # stats/pack/apply sweep above the dispatch threshold. Under DP
        # the denominator is the fixed clients_per_round (sigma is
        # calibrated to sensitivity C/n, so dropped zero-weight
        # participants shrink the numerator, never the denominator) ----
        noised = rc.dp_clip_norm > 0 and rc.dp_noise_multiplier > 0
        sigma = (rc.dp_noise_multiplier * rc.dp_clip_norm
                 / rc.clients_per_round) if noised else 0.0
        flat_delta, ainfo = kernel_ops.agg_tail(
            deltas, weights,
            block_leaf=layout.block_leaf(),
            n_leaves=len(layout.sizes),
            align=layout.align,
            bits=rc.uplink_bits or 0,
            clip_norm=rc.dp_clip_norm if rc.dp_clip_norm > 0 else 0.0,
            # uniform among *participants*: zero weights mark clients the
            # grid scheduler dropped and must stay excluded even under
            # DP's fixed weighting
            uniform=bool(rc.uniform_weights or rc.dp_clip_norm > 0),
            wsum_fixed=(float(rc.clients_per_round)
                        if rc.dp_clip_norm > 0 else None),
            sigma=sigma, rng=rng if noised else None,
            # per-block mask-weighted mean for tiers (blocks a tier froze
            # carry zero weight for its clients); under DP/clip the mean
            # keeps the fixed denominator instead
            bmask=(jnp.asarray(plan.block_masks())[tiers]
                   if tiered and rc.dp_clip_norm <= 0 else None),
            block_denom=tiered and rc.dp_clip_norm <= 0,
            screen=sanitize,
            constrain_fn=(None if constrain_flat_fn is None else
                          lambda v: constrain_flat_fn(v, clients=False)),
            threshold=fused_threshold)

        # --- ServerOpt on the pseudo-gradient ---------------------------
        delta = layout.unflatten(flat_delta, dtype=jnp.float32)
        neg = jax.tree_util.tree_map(lambda d: -d, delta)
        y_new, server_state = server_opt.update(y, neg, server_state)
        out_metrics = {"loss": jnp.mean(metrics["client_loss"]),
                       "delta_norm": opt_lib.tree_global_norm(delta)
                       if noised else jnp.sqrt(
                           flat_lib.sumsq(flat_delta, layout.align))}
        if "update_norms" in ainfo:
            out_metrics["update_norm"] = jnp.mean(ainfo["update_norms"])
        if sanitize is not None:
            out_metrics["quarantine_nonfinite"] = ainfo["nonfinite"]
            out_metrics["quarantine_outlier"] = ainfo["outlier"]
            out_metrics["quarantine_norms"] = ainfo["norms"]
        return y_new, server_state, out_metrics

    if tiered:
        round_step = _round_step     # (y, sstate, frozen, batch, w, tiers, rng)
    else:
        def round_step(y, server_state, frozen, batch, weights, rng):
            return _round_step(y, server_state, frozen, batch, weights,
                               None, rng)

    return round_step, server_opt


# ---------------------------------------------------------------------------
# Asynchronous (buffered) aggregation hooks — used by repro/sim/scheduler.py.
#
# FedBuff-style servers weight each buffered client delta by a function of
# its *staleness* s = (server version now) - (server version the client
# downloaded). The weighting is pluggable; the named defaults follow
# Nguyen et al. 2022 (polynomial, a=0.5) and Xie et al. 2019 (hinge).


def staleness_constant():
    """No down-weighting (plain buffered FedAvg)."""
    return lambda s: 1.0


def staleness_polynomial(power: float = 0.5):
    """w(s) = (1+s)^-a; a=0.5 is FedBuff's 1/sqrt(1+s)."""
    return lambda s: (1.0 + float(s)) ** (-power)


def staleness_hinge(delay: float = 4.0, slope: float = 0.5):
    """w(s) = 1 while s <= delay, then 1/(slope*(s-delay)+1)."""
    def fn(s):
        s = float(s)
        return 1.0 if s <= delay else 1.0 / (slope * (s - delay) + 1.0)
    return fn


STALENESS_FNS = {
    "constant": staleness_constant,
    "polynomial": staleness_polynomial,
    "hinge": staleness_hinge,
}


def get_staleness_fn(name="polynomial", **kw) -> Callable[[float], float]:
    """Resolve a staleness weighting: a callable passes through, a name
    looks up STALENESS_FNS (kw forwarded to the factory)."""
    if callable(name):
        return name
    try:
        return STALENESS_FNS[name](**kw)
    except KeyError:
        raise ValueError(f"unknown staleness_fn {name!r}; "
                         f"options: {sorted(STALENESS_FNS)}") from None


def make_client_step(loss_fn: Callable, rc: RoundConfig,
                     client_opt: Optional[opt_lib.Optimizer] = None,
                     tier=None, plan=None, scatter: bool = True):
    """Single-client step for the async grid: (y, frozen, client_batch) ->
    (flat_delta, metrics). The delta is born flat — flattened inside the
    jitted step onto the ``FlatLayout`` of ``y`` — and the same uplink
    quantization and DP clipping as the synchronous round engine are
    applied over the flat buffer, in the same order.

    ``tier`` (a ``core.plan.TierSlice``, with its ``plan`` the owning
    ``CompiledPlan``) builds the step for ONE trainability tier: ``y``
    is split structurally — the tier's extra-frozen leaves join the
    frozen side, so XLA allocates no grad buffers or optimizer state
    for them — and the delta is the tier's *contiguous* ``(tier_size,)``
    flat slice. Quantization scales and the DP clip norm computed on
    the slice equal those of the zero-scattered full row (absent blocks
    are exactly zero), so per-client DP sensitivity is unchanged by
    tiering. With ``scatter=True`` the step returns the slice scattered
    to global ``(size,)`` width; ``scatter=False`` returns the raw
    contiguous slice (the wire payload)."""
    if client_opt is None:
        client_opt = opt_lib.get_optimizer(rc.client_opt, rc.client_lr)
    client_update = make_client_update(loss_fn, client_opt, rc.local_steps)
    if tier is not None and plan is None:
        raise ValueError("a tiered client step needs the owning "
                         "CompiledPlan (plan=...)")

    def client_step(y, frozen, client_batch):
        if tier is None:
            layout = flat_lib.FlatLayout.of(y)
            delta, metrics = client_update(y, frozen, client_batch)
            flat_delta = layout.flatten(delta)
        else:
            y_t, extra = plan.split(y, tier)
            layout = flat_lib.FlatLayout.of(y_t)
            delta, metrics = client_update(y_t, part.merge(frozen, extra),
                                           client_batch)
            flat_delta = layout.flatten(delta)
        if rc.uplink_bits:
            flat_delta = flat_lib.fake_quantize(flat_delta, layout,
                                                rc.uplink_bits)
        if rc.dp_clip_norm > 0:
            flat_delta, nrm = flat_lib.clip(flat_delta, rc.dp_clip_norm,
                                            layout)
            metrics = dict(metrics, update_norm=nrm)
        if tier is not None and scatter:
            flat_delta = plan.scatter(flat_delta, tier)
        return flat_delta, metrics

    return client_step


def make_lane_step(loss_fn: Callable, rc: RoundConfig, lane: int,
                   client_opt: Optional[opt_lib.Optimizer] = None,
                   constrain_flat_fn: Optional[Callable] = None,
                   tier=None, plan=None):
    """Batched client step for the async grid's fixed-width lanes:
    (y, frozen, lane_batch) -> (flat_deltas (lane, size), losses (lane,)).

    One vmapped dispatch replaces `lane` sequential jit calls; under a
    launch/sharding.py mesh, pass ``constrain_flat_fn`` to pin the lane
    axis to the data mesh axes so clients execute data-parallel.

    With a ``tier``/``plan`` pair the lane is tier-homogeneous (the grid
    groups pending clients by tier, so each tier traces exactly once):
    the vmapped steps run at the tier's ``(lane, tier_size)`` width —
    grad buffers and the clip/quantize tail all shrink with the tier —
    and ONE static-index scatter widens the batch to the global
    ``(lane, size)`` buffer before the sharding constraint, so
    frozen-for-this-tier blocks enter the aggregation as exact zeros.
    """
    step = make_client_step(loss_fn, rc, client_opt, tier=tier, plan=plan,
                            scatter=False)

    def lane_step(y, frozen, lane_batch):
        flat_deltas, metrics = jax.vmap(
            lambda cb: step(y, frozen, cb))(lane_batch)
        if tier is not None:
            flat_deltas = plan.scatter(flat_deltas, tier)
        if constrain_flat_fn is not None:
            flat_deltas = constrain_flat_fn(flat_deltas, clients=True)
        return flat_deltas, metrics["client_loss"]

    return lane_step


def make_buffered_apply(server_opt: opt_lib.Optimizer,
                        flush_dp=None,
                        constrain_flat_fn: Optional[Callable] = None,
                        plan=None, sanitize=None, fused_threshold=None):
    """Server-side flush of an async buffer: apply(y, server_state,
    flat_deltas, weights[, rng]) with ``flat_deltas`` the (K, size) stack
    of flat client deltas and weights (K,) already including the
    staleness factor (w_i = staleness_fn(s_i) * p_i). Weighted-mean as
    one dot, then ServerOpt on the pseudo-gradient, mirroring the sync
    engine.

    ``plan`` (a non-trivial ``core.plan.CompiledPlan``) switches to the
    tiered signature apply(y, server_state, flat_deltas, weights,
    tier_ids[, rng]): ``tier_ids`` (K,) int32 names each row's tier, and
    the per-row tier block masks make frozen-for-this-tier blocks
    contribute zero delta (rows are re-masked, belt & braces — tiered
    client steps already scatter exact zeros there) and zero *weight*:
    without DP the mean divides per block by the mask-weighted
    participant sum (blocks nobody trained keep delta 0); with
    ``flush_dp`` the denominator stays the FIXED ``goal_count`` — the
    masked, clipped row still has sensitivity ``clip_norm/goal_count``,
    so sigma is tier-independent. Padding rows carry weight 0 and tier 0;
    both denominators ignore them.

    K is a fixed shape: short buffers (e.g. a drained final flush) are
    padded with zero-weight rows by the caller, which fall out of the
    weighted mean — so partial flushes never re-trace.

    ``flush_dp`` (a :class:`repro.core.dp.FlushDPConfig`) turns on
    per-flush DP: the mean uses the FIXED ``goal_count`` denominator —
    sigma is calibrated once per flush and zero-weight padding rows of a
    drained buffer change neither the denominator nor the noise scale —
    and ``rng`` (one key per flush) drives ONE Gaussian draw over the
    flat buffer. Client deltas must arrive clipped (``make_client_step``
    does this when ``rc.dp_clip_norm > 0``) with staleness weights
    <= 1, so per-flush sensitivity is ``clip_norm / goal_count``.

    ``constrain_flat_fn`` (see ``launch/sharding.flat_constrainer``)
    pins the buffer's K axis to the data mesh axes and its size axis to
    "model": the weighted mean then reduces the sharded buffer in place
    (a cross-data-axis collective) — the K rows are never gathered onto
    one device.

    ``sanitize`` (a ``core.sanitize.SanitizeConfig``) screens the (K,
    size) buffer FIRST: quarantined rows (non-finite / norm-outlier) are
    zeroed with zero weight — under ``flush_dp`` the FIXED goal_count
    denominator is untouched, so a quarantined row degrades to exactly a
    padding row and sigma / the epsilon ledger stay valid. The
    quarantine masks ride back on the metrics dict for the grid to turn
    into traced events. Clean buffers aggregate bit-identically to
    ``sanitize=None``.
    """

    tiered = plan is not None and not plan.trivial

    def _apply(y, server_state, flat_deltas, weights, tier_ids, rng):
        layout = flat_lib.FlatLayout.of(y)
        if constrain_flat_fn is not None:
            flat_deltas = constrain_flat_fn(flat_deltas, clients=True)
        noised = flush_dp is not None and flush_dp.noise_multiplier > 0
        if noised and rng is None:
            raise ValueError("flush DP noise needs a per-flush rng key")
        # screen -> tier row re-mask -> mean (fixed goal_count
        # denominator under flush DP, per-block mask-weighted otherwise
        # for tiers) -> constraint -> per-flush Gaussian, as ONE
        # dispatched op — staged per-op sequence below the threshold,
        # fused stats/apply sweep above it
        flat_delta, ainfo = kernel_ops.agg_tail(
            flat_deltas, weights,
            block_leaf=layout.block_leaf(),
            n_leaves=len(layout.sizes),
            align=layout.align,
            wsum_fixed=(float(flush_dp.goal_count)
                        if flush_dp is not None else None),
            sigma=flush_dp.sigma if noised else 0.0,
            rng=rng if noised else None,
            bmask=(jnp.asarray(plan.block_masks())[tier_ids]
                   if tiered else None),
            remask_rows=tiered,
            block_denom=tiered and flush_dp is None,
            screen=sanitize,
            constrain_fn=(None if constrain_flat_fn is None else
                          lambda v: constrain_flat_fn(v, clients=False)),
            threshold=fused_threshold)
        qinfo = ainfo if sanitize is not None else None
        delta = layout.unflatten(flat_delta, dtype=jnp.float32)
        neg = jax.tree_util.tree_map(lambda d: -d, delta)
        y_new, server_state = server_opt.update(y, neg, server_state)
        # with noise on pad slots, the flat vector's norm overstates the
        # model update — report the unflattened norm instead (sync engine
        # does the same)
        norm = (opt_lib.tree_global_norm(delta) if noised
                else jnp.sqrt(flat_lib.sumsq(flat_delta, layout.align)))
        out = {"delta_norm": norm}
        if qinfo is not None:
            out["quarantine_nonfinite"] = qinfo["nonfinite"]
            out["quarantine_outlier"] = qinfo["outlier"]
            out["quarantine_norms"] = qinfo["norms"]
        return y_new, server_state, out

    if tiered:
        def apply_fn(y, server_state, flat_deltas, weights, tier_ids,
                     rng=None):
            return _apply(y, server_state, flat_deltas, weights,
                          jnp.asarray(tier_ids, jnp.int32), rng)
    else:
        def apply_fn(y, server_state, flat_deltas, weights, rng=None):
            return _apply(y, server_state, flat_deltas, weights, None, rng)

    return apply_fn


def make_eval_fn(loss_fn: Callable):
    """Centralized eval of the merged model."""

    def eval_step(y, frozen, batch):
        out = loss_fn(part.merge(y, frozen), batch)
        return out[0] if isinstance(out, tuple) else out

    return jax.jit(eval_step)
