"""Differential privacy for FedPT.

Two mechanisms, as in the paper (§3.2, §4.2):

* **DP-FedAvg** (McMahan et al. 2017b): per-client clipping of the
  trainable update + central Gaussian noise — implemented inside the
  round engine (core/fedpt.py) via ``dp_clip_norm`` / ``dp_noise_multiplier``.

* **DP-FTRL** (Kairouz et al. 2021b): noise is drawn from a binary *tree
  aggregation* of the cumulative pseudo-gradient sum, giving formal
  (eps, delta)-DP without client sampling assumptions. Implemented here
  as a ServerOpt whose state carries the cumulative sum; tree-node noise
  is *regenerated deterministically* from (seed, level, index) with
  ``fold_in`` — the same trick FedPT uses for frozen weights — so the
  server never stores O(log T) noise buffers.

FedPT's benefit (the paper's Table 5): noise is added only to the
*trainable* coordinates, so for a fixed noise multiplier the total noise
energy is |y|/|x| smaller than for the fully-trainable model.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.obs import trace as trace_lib
from repro.optim import optimizers as opt_lib


# ---------------------------------------------------------------------------
# Per-flush DP for buffered-async (FedBuff) aggregation.
#
# The sync engine privatizes one *round*: sigma = z * C / clients_per_round
# with a fixed denominator so dropped clients shrink the numerator, never
# the noise scale. The async analogue privatizes one *flush*: the unit of
# composition is one buffered server update of ``goal_count`` client
# deltas. The same fixed-denominator discipline applies — a drained final
# buffer is padded to ``goal_count`` with zero-weight rows, and neither
# the mean's denominator nor sigma changes for it, so every flush of a
# run is the same Gaussian mechanism and composition stays a simple
# product over flushes.


@dataclasses.dataclass(frozen=True)
class FlushDPConfig:
    """Noise calibration for ONE async buffer flush.

    Per-client deltas arrive clipped to ``clip_norm`` (inside the flat
    client step) and are combined with weights in [0, 1] (staleness
    factor x uniform weight, or 0 for padding rows) over the FIXED
    denominator ``goal_count`` — so one client's contribution to the
    flushed mean has L2 norm at most ``clip_norm / goal_count``, and
    ``sigma = noise_multiplier * clip_norm / goal_count`` gives each
    flush the standard Gaussian mechanism with multiplier z.
    """
    clip_norm: float
    noise_multiplier: float
    goal_count: int

    def __post_init__(self):
        if self.clip_norm <= 0 or self.goal_count < 1:
            raise ValueError("flush DP needs clip_norm > 0 and "
                             "goal_count >= 1")

    @property
    def sensitivity(self) -> float:
        return self.clip_norm / self.goal_count

    @property
    def sigma(self) -> float:
        return self.noise_multiplier * self.sensitivity


class FlushAccountant:
    """Counts flushes and composes their Gaussian mechanisms via RDP.

    A flush where every buffered delta comes from a distinct client is
    one Gaussian mechanism with multiplier z. Async dispatch samples
    clients WITH replacement, though, so one client can own ``m >= 1``
    rows of the same flush — changing that client's data then moves the
    flushed mean by up to ``m * clip_norm / goal_count`` (each row is
    clipped and carries weight <= 1), an effective multiplier ``z / m``
    for that flush. The accountant therefore takes the observed
    per-flush multiplicity and composes
    ``RDP(alpha) = alpha / (2 z^2) * sum_t m_t^2``, giving
    ``eps(delta) = min_alpha RDP(alpha) + log(1/delta) / (alpha - 1)``.
    No client-sampling amplification is claimed (async dispatch is not
    a uniform subsample), so the bound is conservative.
    """

    _ALPHAS = tuple([1.0 + x / 10.0 for x in range(1, 100)]
                    + list(range(11, 64)) + [128, 256, 512])

    def __init__(self, cfg: FlushDPConfig,
                 tracer=trace_lib.NULL_TRACER):
        self.cfg = cfg
        self.tracer = tracer
        self.flushes = 0
        self.padded_flushes = 0
        self.max_multiplicity = 0
        self._sum_m2 = 0.0

    def record_flush(self, n_real: int, multiplicity: int = 1,
                     now: float = 0.0, parent=None) -> None:
        """One applied server update with ``n_real`` non-padding rows,
        of which at most ``multiplicity`` belong to the same client.
        Padding changes neither sigma nor the accounting — the mechanism
        is identical, a short flush just spends the same budget on fewer
        clients.

        ``now`` is the flush's virtual time, used only for the tracer's
        ``dp_flush`` instant (each composition step carries sigma and
        the epsilon spent SO FAR, so a timeline shows the budget curve)."""
        if multiplicity < 1:
            raise ValueError("multiplicity must be >= 1")
        self.flushes += 1
        self.max_multiplicity = max(self.max_multiplicity, multiplicity)
        self._sum_m2 += float(multiplicity) ** 2
        if n_real < self.cfg.goal_count:
            self.padded_flushes += 1
        if self.tracer.enabled:
            delta = 1e-5
            self.tracer.instant(
                "dp_flush", now, parent=parent, flush=self.flushes - 1,
                n_real=int(n_real), multiplicity=int(multiplicity),
                sigma=self.cfg.sigma, epsilon=self.epsilon(delta),
                delta=delta, padded=bool(n_real < self.cfg.goal_count))

    def state_dict(self) -> dict:
        """Restorable ledger state (the config is NOT serialized — a
        resumed run rebuilds it from GridConfig and :meth:`load_state`
        cross-checks the calibration)."""
        return {"flushes": self.flushes,
                "padded_flushes": self.padded_flushes,
                "max_multiplicity": self.max_multiplicity,
                "sum_m2": self._sum_m2,
                "sigma": self.cfg.sigma,
                "noise_multiplier": self.cfg.noise_multiplier,
                "goal_count": self.cfg.goal_count}

    def load_state(self, state: dict) -> None:
        """Restore the composition ledger in place. Raises if the saved
        calibration (sigma / z / goal_count) does not match this
        accountant's config — resuming under a different mechanism would
        silently misprice every pre-restore flush."""
        for field, have in (("sigma", self.cfg.sigma),
                            ("noise_multiplier", self.cfg.noise_multiplier),
                            ("goal_count", self.cfg.goal_count)):
            want = state.get(field)
            if want is not None and not math.isclose(
                    float(want), float(have),
                    rel_tol=1e-12, abs_tol=0.0):
                raise ValueError(
                    f"checkpointed DP calibration {field}={want!r} does "
                    f"not match this run's {field}={have!r} — resume "
                    "with the same dp_* GridConfig settings")
        self.flushes = int(state["flushes"])
        self.padded_flushes = int(state["padded_flushes"])
        self.max_multiplicity = int(state["max_multiplicity"])
        self._sum_m2 = float(state["sum_m2"])

    def epsilon(self, delta: float = 1e-5) -> float:
        z = self.cfg.noise_multiplier
        if z <= 0:
            return math.inf
        if self.flushes == 0:
            return 0.0
        return min(self._sum_m2 * a / (2.0 * z * z)
                   + math.log(1.0 / delta) / (a - 1.0)
                   for a in self._ALPHAS)

    def summary(self, delta: float = 1e-5) -> dict:
        return {"flushes": self.flushes,
                "padded_flushes": self.padded_flushes,
                "max_multiplicity": self.max_multiplicity,
                "sigma": self.cfg.sigma,
                "noise_multiplier": self.cfg.noise_multiplier,
                "epsilon": self.epsilon(delta), "delta": delta}


def tree_noise(rng_key, tree, sigma: float, t: int):
    """Noise of the binary-tree cumulative-sum estimator at step t
    (1-indexed): sum of one Gaussian per set bit of t, each keyed by the
    (level, index) of the corresponding tree node. Variance grows as
    popcount(t) * sigma^2 <= log2(T) * sigma^2."""

    t = jnp.asarray(t, jnp.int32)

    def leaf_noise(leaf, leaf_key):
        def level_term(level, acc):
            bit = (t >> level) & 1
            idx = t >> level
            k = jax.random.fold_in(jax.random.fold_in(leaf_key, level), idx)
            z = jax.random.normal(k, leaf.shape, jnp.float32)
            return acc + bit.astype(jnp.float32) * z

        acc = jnp.zeros(leaf.shape, jnp.float32)
        acc = jax.lax.fori_loop(0, 30, lambda l, a: level_term(l, a), acc)
        return sigma * acc

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(rng_key, len(leaves))
    return jax.tree_util.tree_unflatten(
        treedef, [leaf_noise(l, k) for l, k in zip(leaves, keys)])


@dataclasses.dataclass(frozen=True)
class DPFTRLConfig:
    lr: float
    noise_multiplier: float
    clip_norm: float
    clients_per_round: int
    momentum: float = 0.9
    seed: int = 1234


def dp_ftrl_server_opt(cfg: DPFTRLConfig) -> opt_lib.Optimizer:
    """ServerOpt implementing DP-FTRL(-M): the model is a function of the
    privatized cumulative sum S_t = sum_i delta_i + TreeNoise(t).

    state = {x0, cumsum, prev_priv_step?, momentum buffer, t}.
    The incoming "grads" are -delta (the round engine's pseudo-gradient
    convention), already clipped per client and averaged with uniform
    weights, so sensitivity per round is clip_norm / clients_per_round.
    """
    sigma = cfg.noise_multiplier * cfg.clip_norm / cfg.clients_per_round
    key = jax.random.key(cfg.seed)

    def init(params):
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {
            "x0": jax.tree_util.tree_map(jnp.copy, params),
            "cumsum": zeros,
            "prev_priv": jax.tree_util.tree_map(jnp.copy, zeros),
            "m": jax.tree_util.tree_map(jnp.copy, zeros),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(params, grads, state):
        t = state["t"] + 1
        # grads = -delta; cumulative sum of *descent* direction
        cumsum = jax.tree_util.tree_map(
            lambda c, g: c + g.astype(jnp.float32), state["cumsum"], grads)
        noise = tree_noise(key, cumsum, sigma, t)
        priv = opt_lib.tree_add(cumsum, noise)
        # momentum on the privatized increment
        inc = opt_lib.tree_sub(priv, state["prev_priv"])
        m = jax.tree_util.tree_map(
            lambda mm, ii: cfg.momentum * mm + ii, state["m"], inc)
        # momentum-SGD on the privatized increment stream: summed over
        # rounds this tracks x0 - lr * momentum-average(priv_t).
        new = jax.tree_util.tree_map(
            lambda p, mm: (p - cfg.lr * mm).astype(p.dtype), params, m)
        return new, {"x0": state["x0"], "cumsum": cumsum, "prev_priv": priv,
                     "m": m, "t": t}

    return opt_lib.Optimizer(init, update, f"dp-ftrl(lr={cfg.lr},z={cfg.noise_multiplier})")


# Noise-multiplier -> epsilon mapping quoted from the paper's Table 5
# (Kairouz et al. 2021b accountant; no offline accountant available here):
# noise 0 -> eps inf, 1.13 -> 19.74, 2.33 -> 8.50, 4.03 -> 5.66,
# 6.21 -> 2.95, 8.83 -> 2.04 (SO NWP, 1600 rounds, report goal 100).
NOISE_TO_EPS = {0.0: float("inf"), 1.13: 19.74, 2.33: 8.50,
                4.03: 5.66, 6.21: 2.95, 8.83: 2.04}
