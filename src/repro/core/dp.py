"""Differential privacy for FedPT.

Two mechanisms, as in the paper (§3.2, §4.2):

* **DP-FedAvg** (McMahan et al. 2017b): per-client clipping of the
  trainable update + central Gaussian noise — implemented inside the
  round engine (core/fedpt.py) via ``dp_clip_norm`` / ``dp_noise_multiplier``.

* **DP-FTRL** (Kairouz et al. 2021b): noise is drawn from a binary *tree
  aggregation* of the cumulative pseudo-gradient sum, giving formal
  (eps, delta)-DP without client sampling assumptions. Implemented here
  as a ServerOpt whose state carries the cumulative sum; tree-node noise
  is *regenerated deterministically* from (seed, level, index) with
  ``fold_in`` — the same trick FedPT uses for frozen weights — so the
  server never stores O(log T) noise buffers.

FedPT's benefit (the paper's Table 5): noise is added only to the
*trainable* coordinates, so for a fixed noise multiplier the total noise
energy is |y|/|x| smaller than for the fully-trainable model.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.optim import optimizers as opt_lib


def tree_noise(rng_key, tree, sigma: float, t: int):
    """Noise of the binary-tree cumulative-sum estimator at step t
    (1-indexed): sum of one Gaussian per set bit of t, each keyed by the
    (level, index) of the corresponding tree node. Variance grows as
    popcount(t) * sigma^2 <= log2(T) * sigma^2."""

    t = jnp.asarray(t, jnp.int32)

    def leaf_noise(leaf, leaf_key):
        def level_term(level, acc):
            bit = (t >> level) & 1
            idx = t >> level
            k = jax.random.fold_in(jax.random.fold_in(leaf_key, level), idx)
            z = jax.random.normal(k, leaf.shape, jnp.float32)
            return acc + bit.astype(jnp.float32) * z

        acc = jnp.zeros(leaf.shape, jnp.float32)
        acc = jax.lax.fori_loop(0, 30, lambda l, a: level_term(l, a), acc)
        return sigma * acc

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(rng_key, len(leaves))
    return jax.tree_util.tree_unflatten(
        treedef, [leaf_noise(l, k) for l, k in zip(leaves, keys)])


@dataclasses.dataclass(frozen=True)
class DPFTRLConfig:
    lr: float
    noise_multiplier: float
    clip_norm: float
    clients_per_round: int
    momentum: float = 0.9
    seed: int = 1234


def dp_ftrl_server_opt(cfg: DPFTRLConfig) -> opt_lib.Optimizer:
    """ServerOpt implementing DP-FTRL(-M): the model is a function of the
    privatized cumulative sum S_t = sum_i delta_i + TreeNoise(t).

    state = {x0, cumsum, prev_priv_step?, momentum buffer, t}.
    The incoming "grads" are -delta (the round engine's pseudo-gradient
    convention), already clipped per client and averaged with uniform
    weights, so sensitivity per round is clip_norm / clients_per_round.
    """
    sigma = cfg.noise_multiplier * cfg.clip_norm / cfg.clients_per_round
    key = jax.random.key(cfg.seed)

    def init(params):
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {
            "x0": jax.tree_util.tree_map(jnp.copy, params),
            "cumsum": zeros,
            "prev_priv": jax.tree_util.tree_map(jnp.copy, zeros),
            "m": jax.tree_util.tree_map(jnp.copy, zeros),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(params, grads, state):
        t = state["t"] + 1
        # grads = -delta; cumulative sum of *descent* direction
        cumsum = jax.tree_util.tree_map(
            lambda c, g: c + g.astype(jnp.float32), state["cumsum"], grads)
        noise = tree_noise(key, cumsum, sigma, t)
        priv = opt_lib.tree_add(cumsum, noise)
        # momentum on the privatized increment
        inc = opt_lib.tree_sub(priv, state["prev_priv"])
        m = jax.tree_util.tree_map(
            lambda mm, ii: cfg.momentum * mm + ii, state["m"], inc)
        # momentum-SGD on the privatized increment stream: summed over
        # rounds this tracks x0 - lr * momentum-average(priv_t).
        new = jax.tree_util.tree_map(
            lambda p, mm: (p - cfg.lr * mm).astype(p.dtype), params, m)
        return new, {"x0": state["x0"], "cumsum": cumsum, "prev_priv": priv,
                     "m": m, "t": t}

    return opt_lib.Optimizer(init, update, f"dp-ftrl(lr={cfg.lr},z={cfg.noise_multiplier})")


# Noise-multiplier -> epsilon mapping quoted from the paper's Table 5
# (Kairouz et al. 2021b accountant; no offline accountant available here):
# noise 0 -> eps inf, 1.13 -> 19.74, 2.33 -> 8.50, 4.03 -> 5.66,
# 6.21 -> 2.95, 8.83 -> 2.04 (SO NWP, 1600 rounds, report goal 100).
NOISE_TO_EPS = {0.0: float("inf"), 1.13: 19.74, 2.33: 8.50,
                4.03: 5.66, 6.21: 2.95, 8.83: 2.04}
