"""FedPT core: the paper's contribution as composable JAX modules —
parameter partitioning, seed reconstruction, the federated round engine,
DP mechanisms, and communication accounting.

NOTE: function names that would shadow their submodule (``partition``,
``reconstruct``) are exported with ``_params``/``_frozen`` suffixes; the
submodules remain importable as ``repro.core.partition`` etc.
"""
from repro.core.partition import (partition as partition_params,
                                  merge, summarize, summarize_plan,
                                  partition_plan, trainable_fraction)
from repro.core.reconstruct import (reconstruct as reconstruct_frozen,
                                    make_reconstructor, init_partitioned,
                                    verify_roundtrip)
from repro.core.fedpt import (RoundConfig, make_round_fn, make_client_update,
                              clip_delta, make_eval_fn)
from repro.core.flat import FlatLayout
from repro.core.plan import TrainPlan, Tier, CompiledPlan, compile_plan
from repro.core.dp import (DPFTRLConfig, dp_ftrl_server_opt, tree_noise,
                           NOISE_TO_EPS)
from repro.core.comm import CommReport, report_for

# restore submodule attributes clobbered by the re-exports above
from repro.core import (partition, reconstruct, fedpt, dp, comm,  # noqa: E402,F811
                        flat, plan)
