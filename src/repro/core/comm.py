"""Communication-cost accounting (the paper's Tables 1-3 'Reduction in
Communication' column).

Per round, generalized FedAvg moves:
  download:  full model                    -> FedPT: trainable y + 8B seed
  upload:    full model update             -> FedPT: trainable delta
so the per-round reduction is 2*|x| / (2*|y| + seed). The uplink-only
reduction (|x|/|y|) is also reported since uplink is the scarcer resource
(0.25MB/s vs 0.75MB/s; Wang et al. 2021b).

With uplink quantization on (RoundConfig.uplink_bits > 0) the uplink
payload is the int-k delta plus one f32 scale per leaf — the ledger uses
``compress.quantized_uplink_bytes`` for it, not fp32 trainable bytes.

The analytic columns above are *predictions*; the simulation grid
(repro/sim/wire.py) serializes real payloads and records the observed
totals in ``measured_down_bytes`` / ``measured_up_bytes`` so the two can
be cross-checked (they must agree exactly for fp32 payloads).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

from repro.core import compress
from repro.nn import basic
from repro.obs import trace as trace_lib

SEED_BYTES = 8

# Measured cross-device links (Wang et al. 2021b): download 0.75 MB/s,
# upload 0.25 MB/s. The "uniform" fleet preset in repro/sim/devices.py
# uses the same constants.
DOWNLINK_MBPS = 0.75
UPLINK_MBPS = 0.25


@dataclasses.dataclass
class CommReport:
    full_bytes: int
    trainable_bytes: int
    rounds: int = 1
    # uplink quantization (0 = fp32 uplink). When set, uploads cost
    # `quantized_trainable_bytes` per client-round instead of fp32 bytes.
    uplink_bits: int = 0
    quantized_trainable_bytes: int = 0
    # wire-level totals observed by the simulation grid (sum over every
    # client transfer actually performed); 0 until metered.
    measured_down_bytes: int = 0
    measured_up_bytes: int = 0
    transfers: int = 0
    # per-trainability-tier breakdown of the measured totals (filled by
    # the grid when a core/plan.py TrainPlan is active): tier name ->
    # {down_bytes, up_bytes, transfers, uploads}. Uplink is billed at
    # the tier's sliced payload; downlink is tier-invariant (every tier
    # downloads the full trainable tree — see core/plan.py).
    tier_traffic: Dict[str, Dict[str, int]] = dataclasses.field(
        default_factory=dict)
    # per-hop breakdown under a two-level topology (sim/topology.py):
    # hop name ("client_edge" / "edge_server") -> {down_bytes, up_bytes,
    # transfers, uploads}. The client_edge hop carries exactly the
    # transfers the legacy measured_* totals meter (hop == global totals
    # by construction); the edge_server hop is the *additional* traffic
    # hierarchical aggregation introduces — one pre-reduced flat buffer
    # up and one model payload down per active region per flush.
    hop_traffic: Dict[str, Dict[str, int]] = dataclasses.field(
        default_factory=dict)
    # set by the grid when a topology is active: every add_measured /
    # add_tier_measured call then mirrors into hop_traffic["client_edge"]
    # (one metering entry point, so the hop ledger can never drift from
    # the legacy totals). Plumbing, not ledger state.
    bill_hops: bool = dataclasses.field(default=False, repr=False,
                                        compare=False)
    # the telemetry tracer the grid threads through (obs/trace.py):
    # tier-sliced wire billing emits one ``tier_upload`` instant per
    # metered batch. NULL_TRACER (the default) emits nothing; never
    # part of equality/repr — it is plumbing, not ledger state.
    tracer: Any = dataclasses.field(default=trace_lib.NULL_TRACER,
                                    repr=False, compare=False)

    @property
    def download_full(self) -> int:
        return self.full_bytes * self.rounds

    @property
    def download_fedpt(self) -> int:
        return (self.trainable_bytes + SEED_BYTES) * self.rounds

    @property
    def upload_full(self) -> int:
        return self.full_bytes * self.rounds

    @property
    def upload_fedpt(self) -> int:
        per_round = (self.quantized_trainable_bytes
                     if self.uplink_bits and self.quantized_trainable_bytes
                     else self.trainable_bytes)
        return per_round * self.rounds

    @property
    def reduction(self) -> float:
        return (self.download_full + self.upload_full) / max(
            self.download_fedpt + self.upload_fedpt, 1)

    @property
    def uplink_reduction(self) -> float:
        return self.upload_full / max(self.upload_fedpt, 1)

    def per_client_round_mb(self) -> Dict[str, float]:
        mb = 1024.0 * 1024.0
        return {
            "full_down_mb": self.full_bytes / mb,
            "full_up_mb": self.full_bytes / mb,
            "fedpt_down_mb": (self.trainable_bytes + SEED_BYTES) / mb,
            "fedpt_up_mb": self.upload_fedpt / self.rounds / mb,
        }

    # estimated wall-clock on the measured cross-device links
    def transfer_seconds(self, fedpt: bool = True) -> float:
        mb = 1024.0 * 1024.0
        down = (self.download_fedpt if fedpt else self.download_full) / mb
        up = (self.upload_fedpt if fedpt else self.upload_full) / mb
        return down / DOWNLINK_MBPS + up / UPLINK_MBPS

    # --- wire-level metering (filled in by repro/sim) -------------------
    def add_measured(self, down_bytes: int, up_bytes: int,
                     transfers: int = 1) -> None:
        """Accumulate observed serialized payload sizes for `transfers`
        client round-trips."""
        self.measured_down_bytes += int(down_bytes)
        self.measured_up_bytes += int(up_bytes)
        self.transfers += int(transfers)
        if self.bill_hops:
            self.add_hop("client_edge", down_bytes=down_bytes,
                         up_bytes=up_bytes, transfers=transfers)

    def add_tier_measured(self, tier: str, down_bytes: int, up_bytes: int,
                          transfers: int = 1, uploads: int = 0,
                          now: float = 0.0, parent=None) -> None:
        """Accumulate observed bytes for one trainability tier AND the
        global totals (callers meter through one entry point — never
        call both this and ``add_measured`` for the same transfers).
        ``now`` stamps the tracer's ``tier_upload`` billing instant in
        virtual time, ``parent`` links it to the round/flush that billed
        it (both ignored with the default NULL_TRACER)."""
        rec = self.tier_traffic.setdefault(
            tier, {"down_bytes": 0, "up_bytes": 0, "transfers": 0,
                   "uploads": 0})
        rec["down_bytes"] += int(down_bytes)
        rec["up_bytes"] += int(up_bytes)
        rec["transfers"] += int(transfers)
        rec["uploads"] += int(uploads)
        self.add_measured(down_bytes, up_bytes, transfers)
        self.tracer.instant("tier_upload", now, parent=parent,
                            tier_name=tier,
                            down_bytes=int(down_bytes),
                            up_bytes=int(up_bytes),
                            transfers=int(transfers),
                            uploads=int(uploads))

    def add_hop(self, hop: str, down_bytes: int = 0, up_bytes: int = 0,
                transfers: int = 0, uploads: int = 0) -> None:
        """Accumulate observed bytes on one topology hop. The
        ``client_edge`` hop is fed automatically by ``add_measured`` when
        ``bill_hops`` is set; the grid calls this directly for the
        ``edge_server`` hop (edge flush buffers + per-region downlink
        fan-out), which the legacy single-hop totals do NOT include."""
        rec = self.hop_traffic.setdefault(
            hop, {"down_bytes": 0, "up_bytes": 0, "transfers": 0,
                  "uploads": 0})
        rec["down_bytes"] += int(down_bytes)
        rec["up_bytes"] += int(up_bytes)
        rec["transfers"] += int(transfers)
        rec["uploads"] += int(uploads)

    @property
    def measured_total_bytes(self) -> int:
        return self.measured_down_bytes + self.measured_up_bytes

    def tier_table(self) -> Dict[str, Dict[str, float]]:
        """Per-tier measured traffic with MB columns (README's tier
        table / the tiered example's report)."""
        mb = 1024.0 * 1024.0
        out = {}
        for name, rec in self.tier_traffic.items():
            out[name] = dict(rec)
            out[name]["down_mb"] = rec["down_bytes"] / mb
            out[name]["up_mb"] = rec["up_bytes"] / mb
            out[name]["up_bytes_per_upload"] = (
                rec["up_bytes"] / rec["uploads"] if rec["uploads"] else 0.0)
        return out

    def hop_table(self) -> Dict[str, Dict[str, float]]:
        """Per-hop measured traffic with MB columns (README's hop ledger
        table / the --regions example's report)."""
        mb = 1024.0 * 1024.0
        out = {}
        for name, rec in self.hop_traffic.items():
            out[name] = dict(rec)
            out[name]["down_mb"] = rec["down_bytes"] / mb
            out[name]["up_mb"] = rec["up_bytes"] / mb
        return out


def report_for(trainable, frozen, rounds: int = 1,
               uplink_bits: int = 0) -> CommReport:
    by = basic.tree_bytes(trainable)
    bz = basic.tree_bytes(frozen)
    qb = (compress.quantized_uplink_bytes(trainable, uplink_bits)
          if uplink_bits else 0)
    return CommReport(full_bytes=by + bz, trainable_bytes=by, rounds=rounds,
                      uplink_bits=uplink_bits, quantized_trainable_bytes=qb)
