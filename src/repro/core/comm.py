"""Communication-cost accounting (the paper's Tables 1-3 'Reduction in
Communication' column).

Per round, generalized FedAvg moves:
  download:  full model                    -> FedPT: trainable y + 8B seed
  upload:    full model update             -> FedPT: trainable delta
so the per-round reduction is 2*|x| / (2*|y| + seed). The uplink-only
reduction (|x|/|y|) is also reported since uplink is the scarcer resource
(0.25MB/s vs 0.75MB/s; Wang et al. 2021b).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.nn import basic

SEED_BYTES = 8


@dataclasses.dataclass
class CommReport:
    full_bytes: int
    trainable_bytes: int
    rounds: int = 1

    @property
    def download_full(self) -> int:
        return self.full_bytes * self.rounds

    @property
    def download_fedpt(self) -> int:
        return (self.trainable_bytes + SEED_BYTES) * self.rounds

    @property
    def upload_full(self) -> int:
        return self.full_bytes * self.rounds

    @property
    def upload_fedpt(self) -> int:
        return self.trainable_bytes * self.rounds

    @property
    def reduction(self) -> float:
        return (self.download_full + self.upload_full) / max(
            self.download_fedpt + self.upload_fedpt, 1)

    @property
    def uplink_reduction(self) -> float:
        return self.upload_full / max(self.upload_fedpt, 1)

    def per_client_round_mb(self) -> Dict[str, float]:
        mb = 1024.0 * 1024.0
        return {
            "full_down_mb": self.full_bytes / mb,
            "full_up_mb": self.full_bytes / mb,
            "fedpt_down_mb": (self.trainable_bytes + SEED_BYTES) / mb,
            "fedpt_up_mb": self.trainable_bytes / mb,
        }

    # estimated wall-clock on the measured cross-device links
    # (download 0.75 MB/s, upload 0.25 MB/s; Wang et al. 2021b)
    def transfer_seconds(self, fedpt: bool = True) -> float:
        mb = 1024.0 * 1024.0
        down = (self.download_fedpt if fedpt else self.download_full) / mb
        up = (self.upload_fedpt if fedpt else self.upload_full) / mb
        return down / 0.75 + up / 0.25


def report_for(trainable, frozen, rounds: int = 1) -> CommReport:
    by = basic.tree_bytes(trainable)
    bz = basic.tree_bytes(frozen)
    return CommReport(full_bytes=by + bz, trainable_bytes=by, rounds=rounds)
