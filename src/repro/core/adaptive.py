"""Adaptive (tiered) partial training — the paper's §5 future work:
"selectively freeze more parameters for devices with smaller bandwidth
and/or computational capacity, while training more parameters on devices
that do not suffer such limitations."

Design: tiers are ordered freeze specs (tier 0 = most capable = fewest
frozen blocks; higher tiers freeze supersets). The server keeps ONE
trainable tree y = the union (tier-0 trainable set). Each client gets a
per-leaf 0/1 mask for its tier; masked leaves receive zero local updates
(mask applied to the gradients each local step — exact freezing under
SGD-family ClientOpts) and are excluded from that client's upload.
Aggregation is per-leaf mask-weighted:  Δ[l] = Σ_i w_i m_i[l] Δ_i[l] /
Σ_i w_i m_i[l]  — leaves nobody trained this round keep Δ=0.

Communication: client i uploads only its tier's trainable bytes —
`tier_comm_report` gives the per-tier ledger.

NOTE: this module is the original leaf-level prototype (kept for its
tests and example). The production path is ``core/plan.py``: a
``TrainPlan`` compiles tiers into static block sub-layouts of the flat
aggregation buffer and threads them through the round engine, the async
lanes, the scheduler, and per-tier wire billing
(``sim.GridConfig.plan``).
"""
from __future__ import annotations

import re
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp

import repro.core.partition as part
from repro.core import comm, fedpt
from repro.nn import basic
from repro.optim import optimizers as opt_lib


def tier_masks(y_tree, tier_specs: Sequence[tuple]):
    """Per-tier 0/1 leaf masks over the union trainable tree.

    tier_specs[t] is the *additional* freeze spec of tier t relative to
    the union trainable set (tier 0 usually ()).
    """
    flat = dict(basic.flatten_params(y_tree))
    masks = []
    for spec in tier_specs:
        m = {p: jnp.asarray(0.0 if any(re.search(s, p) for s in spec)
                            else 1.0, jnp.float32)
             for p in flat}
        masks.append(basic.unflatten_params(m))
    return masks


def make_tiered_round_fn(loss_fn: Callable, rc: fedpt.RoundConfig,
                         tier_specs: Sequence[tuple],
                         server_opt: Optional[opt_lib.Optimizer] = None):
    """round_step(y, sstate, frozen, batch, weights, tiers, rng).

    tiers: (clients,) int32 — tier index per sampled client.
    """
    client_opt = opt_lib.get_optimizer(rc.client_opt, rc.client_lr)
    if server_opt is None:
        server_opt = opt_lib.get_optimizer(rc.server_opt, rc.server_lr)
    n_tiers = len(tier_specs)

    def round_step(y, server_state, frozen, batch, weights, tiers, rng):
        masks_all = tier_masks(y, tier_specs)
        # stack masks: leaf -> (n_tiers,)
        stacked = jax.tree_util.tree_map(
            lambda *ms: jnp.stack(ms), *masks_all)

        def client_update(client_batch, tier):
            mask = jax.tree_util.tree_map(lambda s: s[tier], stacked)
            opt_state = client_opt.init(y)

            def local_step(carry, mb):
                yy, st = carry
                def loss_of_y(yv):
                    full = part.merge(yv, jax.tree_util.tree_map(
                        jax.lax.stop_gradient, frozen))
                    out = loss_fn(full, mb)
                    return out[0] if not isinstance(out, tuple) else out[0]
                grads = jax.grad(loss_of_y)(yy)
                grads = jax.tree_util.tree_map(
                    lambda g, m: g * m.astype(g.dtype), grads, mask)
                yy, st = client_opt.update(yy, grads, st)
                return (yy, st), None

            (y_fin, _), _ = jax.lax.scan(local_step, (y, opt_state),
                                         client_batch)
            delta = opt_lib.tree_sub(y_fin, y)
            # belt & braces: mask the upload too
            delta = jax.tree_util.tree_map(
                lambda d, m: d * m.astype(d.dtype), delta, mask)
            return delta, mask

        deltas, masks = jax.vmap(client_update)(batch, tiers)
        w = weights.astype(jnp.float32)
        num = jax.tree_util.tree_map(
            lambda d, m: jnp.tensordot(w * m.astype(jnp.float32),
                                       d.astype(jnp.float32), axes=1),
            deltas, masks)
        den = jax.tree_util.tree_map(
            lambda m: jnp.maximum(jnp.sum(w * m.astype(jnp.float32)), 1e-12),
            masks)
        delta = jax.tree_util.tree_map(lambda n, d: n / d, num, den)
        neg = jax.tree_util.tree_map(lambda d: -d, delta)
        y_new, server_state = server_opt.update(y, neg, server_state)
        return y_new, server_state, {
            "delta_norm": opt_lib.tree_global_norm(delta)}

    return round_step, server_opt


def tier_comm_report(y_tree, frozen_tree, tier_specs) -> List[comm.CommReport]:
    """Per-tier communication ledger: tier t uploads only its unmasked
    leaves (plus the shared seed downstream)."""
    masks = tier_masks(y_tree, tier_specs)
    full_bytes = basic.tree_bytes(y_tree) + basic.tree_bytes(frozen_tree)
    reports = []
    for m in masks:
        flat_y = dict(basic.flatten_params(y_tree))
        flat_m = dict(basic.flatten_params(m))
        byt = sum(v.size * v.dtype.itemsize for p, v in flat_y.items()
                  if float(flat_m[p]) > 0)
        reports.append(comm.CommReport(full_bytes=full_bytes,
                                       trainable_bytes=byt))
    return reports
