"""Delta quarantine: screen the flat ``(K, size)`` buffer before it
touches aggregation.

DP clipping does **not** protect the server from corrupted uploads:
``NaN * scale`` is still NaN, so one poisoned row nukes the weighted
mean, the noise addition, and every server update after it. The screen
runs as the *first* stage of both round engines (sync cohort step and
async buffered apply), before quantization and clipping, and
quarantines two classes of row:

* **non-finite** — any NaN/±Inf element (the ``corrupt_nan`` fault, or
  a genuinely diverged client);
* **norm-outlier** — finite rows whose L2 norm exceeds
  ``norm_mult`` x the median live-row norm (the ``corrupt_bitflip``
  fault's signature: exponent-bit flips produce finite-but-astronomical
  values that ``isfinite`` alone misses).

Quarantined rows are zeroed *and* given zero weight — zero weight alone
is not enough, since ``NaN * 0 = NaN`` inside the weighted mean. The
fixed DP denominator is untouched: under per-flush DP the mean divides
by ``goal_count`` regardless of how many rows survive, so sigma
calibration and the ``FlushAccountant`` epsilon ledger stay valid — a
quarantined row simply contributes the same zero signal as a padding
row. Every quarantine emits a traced ``quarantine`` event at the call
sites (grid / scheduler), driven by the masks this module returns.

The screen is pure ``jnp`` and branch-free, so it jits into the
existing single-pass server tail; with clean data it computes
``where(False, ...)`` everywhere and the aggregate is bit-identical to
the unscreened path (test-enforced).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple, Union

import jax.numpy as jnp

from repro.core import flat as flat_lib


@dataclasses.dataclass(frozen=True)
class SanitizeConfig:
    """Quarantine screen knobs.

    ``nonfinite`` toggles the NaN/Inf row mask. ``norm_mult`` sets the
    outlier threshold as a multiple of the median norm over *live*
    rows (weight > 0, finite, norm > 0 — padding rows never vote);
    ``norm_mult <= 0`` disables the outlier screen."""

    nonfinite: bool = True
    norm_mult: float = 10.0

    @property
    def trivial(self) -> bool:
        return not self.nonfinite and self.norm_mult <= 0


def screen_from_stats(norms: jnp.ndarray, row_finite: jnp.ndarray,
                      weights: jnp.ndarray, cfg: SanitizeConfig
                      ) -> Tuple[jnp.ndarray, jnp.ndarray,
                                 Dict[str, jnp.ndarray]]:
    """Quarantine decisions from precomputed per-row stats.

    ``norms`` are the pre-screen L2 row norms and ``row_finite`` the
    all-elements-finite flags — :func:`screen_rows` computes both with
    its own sweeps; the fused aggregation tail
    (``kernels/agg_tail.py``) reads them off its stats pass so the
    screen costs no extra pass over the buffer. A row with
    ``row_finite`` False may carry a NaN/Inf ``norms`` entry: every use
    below is masked by ``row_finite``, so the value is never observed
    (the reported ``norms`` are zeroed there, matching the NaN-free
    view ``screen_rows`` reduces).

    Returns ``(clean_weights, quarantine_mask, info)``. Decisions are
    bitwise identical to :func:`screen_rows` on the same stats
    (test-enforced)."""
    if cfg.nonfinite:
        nonfinite_q = ~row_finite
    else:
        nonfinite_q = jnp.zeros_like(row_finite)

    if cfg.norm_mult > 0:
        live = (weights > 0) & row_finite & (norms > 0)
        med = jnp.nanmedian(jnp.where(live, norms, jnp.nan))
        # no live rows -> med is NaN -> comparisons are False (no
        # quarantine), which is the right degenerate answer
        outlier_q = live & (norms > cfg.norm_mult * med)
    else:
        outlier_q = jnp.zeros_like(row_finite)

    q = nonfinite_q | outlier_q
    clean_w = jnp.where(q, 0.0, weights)
    info = {"nonfinite": nonfinite_q, "outlier": outlier_q,
            "norms": jnp.where(row_finite, norms, 0.0)}
    return clean_w, q, info


def screen_rows(mat: jnp.ndarray, weights: jnp.ndarray, cfg: SanitizeConfig,
                align: int = flat_lib.ALIGN
                ) -> Tuple[jnp.ndarray, jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Screen a flat ``(K, size)`` delta buffer.

    Returns ``(clean_mat, clean_weights, info)`` where quarantined rows
    are zeroed in ``clean_mat`` and ``clean_weights``, and ``info``
    carries the ``nonfinite`` / ``outlier`` bool masks plus the
    pre-screen row ``norms`` (0 where a row had non-finite elements) —
    the call site turns these into traced events and counters."""
    finite = jnp.isfinite(mat)
    row_finite = jnp.all(finite, axis=1)
    # compute norms on a NaN-free view so a poisoned row cannot poison
    # the median either
    safe = jnp.where(finite, mat, 0.0)
    norms = jnp.sqrt(flat_lib.row_sumsq(safe, align))
    clean_w, q, info = screen_from_stats(norms, row_finite, weights, cfg)
    clean = jnp.where(q[:, None], 0.0, mat)
    return clean, clean_w, info


def resolve_sanitize(
        spec: Union[None, bool, str, dict, SanitizeConfig]
) -> Optional[SanitizeConfig]:
    """GridConfig.sanitize -> SanitizeConfig or None (screen off).

    ``None``/``False``/``"off"`` and a trivial config resolve to
    ``None`` — the round engines then build the exact unscreened
    aggregation. ``True``/``"on"`` gives the default screen; a dict
    builds a config from fields; a config passes through."""
    if spec is None or spec is False:
        return None
    if spec is True:
        cfg = SanitizeConfig()
    elif isinstance(spec, str):
        if spec == "off":
            return None
        if spec == "on":
            cfg = SanitizeConfig()
        else:
            raise ValueError(f"unknown sanitize spec {spec!r}; options: "
                             "'on', 'off'")
    elif isinstance(spec, dict):
        cfg = SanitizeConfig(**spec)
    elif isinstance(spec, SanitizeConfig):
        cfg = spec
    else:
        raise TypeError(f"sanitize must be None, bool, 'on'/'off', a dict or "
                        f"a SanitizeConfig, got {type(spec).__name__}")
    return None if cfg.trivial else cfg
