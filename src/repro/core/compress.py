"""Uplink delta compression — the paper (§2, §5) positions FedPT as
*complementary* to compression (Konecny et al. 2016): the trainable delta
can additionally be quantized before upload. We implement symmetric
per-leaf int8 quantization with a float32 scale; the comm ledger then
multiplies FedPT's reduction by ~4x on the uplink.

Quantization is applied per-client BEFORE aggregation (it models the
lossy uplink), so the server averages dequantized deltas — unbiased
under stochastic rounding; we use deterministic nearest rounding and
validate the end-to-end accuracy impact in tests.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.nn import basic

# every quantized leaf ships one float32 scale on the wire
# (sim/wire.py serializes it; quantized_uplink_bytes bills it)
SCALE_BYTES = 4


def quantize_leaf(x, bits: int = 8) -> Tuple[jnp.ndarray, jnp.ndarray]:
    qmax = 2.0 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-12) / qmax
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -qmax, qmax)
    return q.astype(jnp.int8 if bits == 8 else jnp.int32), scale


def dequantize_leaf(q, scale):
    return q.astype(jnp.float32) * scale


def quantize_tree(tree, bits: int = 8):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    qs, scales = zip(*[quantize_leaf(l, bits) for l in leaves])
    return (jax.tree_util.tree_unflatten(treedef, qs),
            jax.tree_util.tree_unflatten(treedef, scales))


def dequantize_tree(qtree, scales):
    return jax.tree_util.tree_map(dequantize_leaf, qtree, scales)


def fake_quantize_tree(tree, bits: int = 8):
    """Q->DQ in one pass (the in-graph uplink model used by the round
    engine when RoundConfig.uplink_bits > 0)."""
    def one(x):
        q, s = quantize_leaf(x, bits)
        return dequantize_leaf(q, s).astype(x.dtype)
    return jax.tree_util.tree_map(one, tree)


def quantized_uplink_bytes(tree, bits: int = 8) -> int:
    """int-k payload + one f32 scale per leaf — the exact size
    sim/wire.py serializes for bits=8."""
    n = basic.tree_size(tree)
    n_leaves = len(jax.tree_util.tree_leaves(tree))
    return n * bits // 8 + SCALE_BYTES * n_leaves
