"""FedPT parameter partitioning (Algorithm 1, line 1).

``partition`` splits a model parameter tree into the *trainable* part
``y`` and the *frozen* part by matching flattened parameter paths against
the config's ``freeze_spec`` regexes. ``merge`` reassembles the full tree
``x = Reconstruct(y, z)`` given the regenerated frozen side.

Both halves keep the nested-dict structure (with disjoint leaves), so
jit/pjit tracing, sharding rules and optimizers apply transparently.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Tuple

import jax
import numpy as np

from repro.nn import basic


def partition(params: Dict[str, Any], freeze_spec) -> Tuple[Dict, Dict]:
    """Returns (trainable, frozen) trees with disjoint leaves."""
    flat = dict(basic.flatten_params(params))
    train, frozen = {}, {}
    for path, leaf in flat.items():
        if any(re.search(p, path) for p in freeze_spec):
            frozen[path] = leaf
        else:
            train[path] = leaf
    return basic.unflatten_params(train), basic.unflatten_params(frozen)


def merge(trainable: Dict[str, Any], frozen: Dict[str, Any]) -> Dict[str, Any]:
    """Reassemble the full parameter tree from the two disjoint halves."""
    flat = dict(basic.flatten_params(trainable))
    flat.update(dict(basic.flatten_params(frozen)))
    return basic.unflatten_params(flat)


def stop_gradient_frozen(trainable, frozen):
    """Merge with an explicit stop_gradient on the frozen side (belt &
    braces: grads are only taken wrt the trainable arg anyway)."""
    return merge(trainable, jax.tree_util.tree_map(jax.lax.stop_gradient,
                                                   frozen))


def count_params(tree) -> int:
    return basic.tree_size(tree)


def trainable_fraction(params, freeze_spec) -> float:
    y, z = partition(params, freeze_spec)
    ny, nz = basic.tree_size(y), basic.tree_size(z)
    return ny / max(ny + nz, 1)


def summarize(params, freeze_spec) -> Dict[str, float]:
    """The paper's Table-1/2/3 row for an arbitrary model + freeze spec."""
    from repro.core import comm
    y, z = partition(params, freeze_spec)
    ny, nz = basic.tree_size(y), basic.tree_size(z)
    rep = comm.report_for(y, z)
    total = ny + nz
    return {
        "total_params": total,
        "trainable_params": ny,
        "frozen_params": nz,
        "trainable_pct": 100.0 * ny / total,
        # download (y + seed) + upload (delta y), vs 2x full model — the
        # single source of truth for this formula is comm.CommReport
        "comm_reduction": rep.reduction,
        "trainable_bytes": rep.trainable_bytes,
        "frozen_bytes": rep.full_bytes - rep.trainable_bytes,
    }
