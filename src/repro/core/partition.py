"""FedPT parameter partitioning (Algorithm 1, line 1).

``partition`` splits a model parameter tree into the *trainable* part
``y`` and the *frozen* part by matching flattened parameter paths against
the config's ``freeze_spec`` regexes. ``merge`` reassembles the full tree
``x = Reconstruct(y, z)`` given the regenerated frozen side.

Both halves keep the nested-dict structure (with disjoint leaves), so
jit/pjit tracing, sharding rules and optimizers apply transparently.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Tuple

import jax
import numpy as np

from repro.nn import basic


def partition(params: Dict[str, Any], freeze_spec) -> Tuple[Dict, Dict]:
    """Returns (trainable, frozen) trees with disjoint leaves."""
    flat = dict(basic.flatten_params(params))
    train, frozen = {}, {}
    for path, leaf in flat.items():
        if any(re.search(p, path) for p in freeze_spec):
            frozen[path] = leaf
        else:
            train[path] = leaf
    return basic.unflatten_params(train), basic.unflatten_params(frozen)


def merge(trainable: Dict[str, Any], frozen: Dict[str, Any]) -> Dict[str, Any]:
    """Reassemble the full parameter tree from the two disjoint halves."""
    flat = dict(basic.flatten_params(trainable))
    flat.update(dict(basic.flatten_params(frozen)))
    return basic.unflatten_params(flat)


def stop_gradient_frozen(trainable, frozen):
    """Merge with an explicit stop_gradient on the frozen side (belt &
    braces: grads are only taken wrt the trainable arg anyway)."""
    return merge(trainable, jax.tree_util.tree_map(jax.lax.stop_gradient,
                                                   frozen))


def count_params(tree) -> int:
    return basic.tree_size(tree)


def trainable_fraction(params, freeze_spec) -> float:
    y, z = partition(params, freeze_spec)
    ny, nz = basic.tree_size(y), basic.tree_size(z)
    return ny / max(ny + nz, 1)


def summarize(params, freeze_spec) -> Dict[str, float]:
    """The paper's Table-1/2/3 row for an arbitrary model + freeze spec
    — the one-tier special case of :func:`summarize_plan`."""
    from repro.core import plan as plan_lib
    row = dict(summarize_plan(params, freeze_spec,
                              plan_lib.TrainPlan.single())[0])
    row.pop("tier")
    return row


def partition_plan(params, freeze_spec, plan):
    """Per-tier (trainable, frozen) splits under a trainability plan.

    ``freeze_spec`` defines the *global* trainable tree (the union every
    tier shares); each tier's additive spec moves more of it to the
    frozen side. Returns ``(compiled_plan, [(train_t, frozen_t), ...])``
    — tier t's frozen tree is the global frozen tree plus the leaves the
    tier declines to train, so ``merge(train_t, frozen_t)`` is always
    the full model. A one-tier plan with no extra spec reproduces
    ``partition`` exactly.
    """
    from repro.core import plan as plan_lib
    y, z = partition(params, freeze_spec)
    cplan = plan_lib.compile_plan(plan, y)
    splits = []
    for t in cplan.tiers:
        y_t, extra = cplan.split(y, t)
        splits.append((y_t, merge(z, extra)))
    return cplan, splits


def summarize_plan(params, freeze_spec, plan) -> list:
    """Per-tier Table-1 rows: same columns as :func:`summarize` plus the
    tier name.

    These are the paper's *analytic* per-spec numbers — tier t's row is
    what Table 1 would print had the whole fleet used tier t's combined
    spec (downlink = tier trainable + seed). The simulation grid's
    *measured* ledger differs on the downlink: in a mixed fleet every
    tier must download the full global trainable tree (other tiers keep
    training the blocks this tier froze, so their current values cannot
    be regenerated from the seed); only the uplink is tier-sliced."""
    from repro.core import comm
    cplan, splits = partition_plan(params, freeze_spec, plan)
    rows = []
    for t, (y_t, z_t) in zip(cplan.tiers, splits):
        ny, nz = basic.tree_size(y_t), basic.tree_size(z_t)
        rep = comm.report_for(y_t, z_t)
        total = ny + nz
        rows.append({
            "tier": t.name,
            "total_params": total,
            "trainable_params": ny,
            "frozen_params": nz,
            "trainable_pct": 100.0 * ny / total,
            # download (y + seed) + upload (delta y), vs 2x full model —
            # the single source of truth is comm.CommReport
            "comm_reduction": rep.reduction,
            "trainable_bytes": rep.trainable_bytes,
            "frozen_bytes": rep.full_bytes - rep.trainable_bytes,
        })
    return rows
