"""Flat-buffer aggregation layout — the server hot path's data plane.

Every per-round server op (clip, fake-quantize, weighted mean, DP noise)
used to sweep the trainable tree leaf-by-leaf: N_leaves tiny XLA ops per
client per pass, each with its own dispatch and its own badly-shaped
reduction. :class:`FlatLayout` maps the trainable tree ``y`` onto ONE
contiguous fp32 vector with a static layout (offsets/shapes computed
once per freeze_spec at trace time), so the whole aggregation tail runs
as a handful of single-pass ops over ``(clients, size)``:

* client deltas are *born flat* — ``flatten`` runs inside the jitted
  client step, so the delta is written straight into the flat buffer
  instead of into per-leaf arrays and re-concatenated later;
* per-client L2 norms, per-leaf int8 quantization scales and the
  weighted mean are dot/segment ops over the flat buffer (Pallas
  kernels on TPU via ``repro.kernels.ops``; reshaped pure-JAX fallbacks
  from ``repro.kernels.ref`` on CPU — XLA:CPU's row-reductions over
  ``(C, 10^7)`` run ~20x slower than the same reduction expressed over
  ``(C*K, align)`` blocks, which is why every reduction here goes
  through the block view);
* leaves are padded to ``align``-element boundaries so each leaf owns
  whole blocks — block-local reductions never straddle leaves, and the
  TPU kernels get a static block->leaf map to prefetch.

Padding is zero-filled and inert: zeros contribute nothing to norms or
max-abs scales, survive quantization as zeros, and are sliced away by
``unflatten`` — DP noise may land on pad slots (``add_noise``) because
unflatten drops them before the server update.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# default block size: one f32 (8, 128) TPU tile, and a CPU reduction
# chunk small enough to vectorize.
ALIGN = 1024


def _ceil_to(n: int, align: int) -> int:
    return (n + align - 1) // align * align


@dataclasses.dataclass(frozen=True)
class FlatLayout:
    """Static mapping tree <-> one contiguous fp32 vector.

    Built once per (freeze_spec, model) from abstract shapes — safe to
    construct from tracers inside ``jit``. All fields are Python/numpy
    statics, so closing over a layout never adds jit arguments.
    """
    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    sizes: Tuple[int, ...]          # true leaf sizes
    padded: Tuple[int, ...]         # leaf sizes rounded up to `align`
    offsets: Tuple[int, ...]        # leaf start offsets in the flat vector
    size: int                       # total flat length (multiple of align)
    align: int

    @classmethod
    def of(cls, tree, align: int = ALIGN) -> "FlatLayout":
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        shapes = tuple(tuple(l.shape) for l in leaves)
        dtypes = tuple(jnp.result_type(l) for l in leaves)
        sizes = tuple(int(np.prod(s)) if s else 1 for s in shapes)
        padded = tuple(_ceil_to(max(n, 1), align) for n in sizes)
        offsets = tuple(int(o) for o in np.cumsum((0,) + padded[:-1]))
        return cls(treedef=treedef, shapes=shapes, dtypes=dtypes,
                   sizes=sizes, padded=padded, offsets=offsets,
                   size=int(sum(padded)) if leaves else 0, align=align)

    # -- static block metadata (numpy; fed to kernels as prefetch args) --

    @property
    def num_blocks(self) -> int:
        return self.size // self.align

    def block_leaf(self) -> np.ndarray:
        """(num_blocks,) int32: which leaf each align-block belongs to."""
        return np.repeat(np.arange(len(self.sizes), dtype=np.int32),
                         [p // self.align for p in self.padded])

    # -- tree <-> vector ------------------------------------------------

    def flatten(self, tree) -> jnp.ndarray:
        """Tree -> (size,) fp32. vmap-safe (use it inside the client step
        so deltas are written flat from birth)."""
        leaves = jax.tree_util.tree_leaves(tree)
        if not leaves:
            return jnp.zeros((0,), jnp.float32)
        parts = []
        for leaf, n, pad in zip(leaves, self.sizes, self.padded):
            v = jnp.ravel(leaf).astype(jnp.float32)
            if pad != n:
                v = jnp.pad(v, (0, pad - n))
            parts.append(v)
        return jnp.concatenate(parts) if len(parts) > 1 else parts[0]

    def unflatten(self, vec: jnp.ndarray, dtype: Optional[Any] = None):
        """(size,) vector -> tree. ``dtype=None`` restores each leaf's
        original dtype; pass e.g. ``jnp.float32`` to keep aggregation
        precision (the round engine's delta trees are fp32 regardless of
        the parameter dtype, matching the old tensordot path)."""
        leaves = []
        for shape, dt, n, off in zip(self.shapes, self.dtypes, self.sizes,
                                     self.offsets):
            piece = jax.lax.slice_in_dim(vec, off, off + n)
            leaves.append(piece.reshape(shape).astype(dtype or dt))
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    def zeros(self) -> jnp.ndarray:
        return jnp.zeros((self.size,), jnp.float32)

    # -- block sub-layouts (core/plan.py trainability tiers) -------------

    def leaf_blocks(self, leaf_on) -> np.ndarray:
        """(k,) int32 global block ids owned by the leaves ``leaf_on``
        selects (bool per leaf, layout order). Because every leaf owns
        whole ``align`` blocks, any per-leaf subset of the tree is a
        per-block subset of the flat vector — the static index map that
        makes a tier's payload a contiguous slice of its own."""
        if len(leaf_on) != len(self.sizes):
            raise ValueError(f"leaf_on has {len(leaf_on)} entries for "
                             f"{len(self.sizes)} leaves")
        per_leaf = self.block_leaf()
        keep = np.asarray(leaf_on, bool)[per_leaf]
        return np.nonzero(keep)[0].astype(np.int32)

    def block_mask(self, leaf_on) -> np.ndarray:
        """(num_blocks,) float32 0/1 mask over align-blocks for the
        leaves ``leaf_on`` selects."""
        mask = np.zeros((self.num_blocks,), np.float32)
        mask[self.leaf_blocks(leaf_on)] = 1.0
        return mask


def gather_blocks(vec: jnp.ndarray, block_ids: np.ndarray,
                  align: int = ALIGN) -> jnp.ndarray:
    """(size,) or (k, size) -> the selected blocks as ONE contiguous
    vector/matrix ((n*align,) or (k, n*align)). Static index map: the
    gather is a single XLA take over the block view."""
    ids = jnp.asarray(block_ids, jnp.int32)
    if vec.ndim == 1:
        return vec.reshape(-1, align)[ids].reshape(-1)
    k = vec.shape[0]
    return vec.reshape(k, -1, align)[:, ids].reshape(k, -1)


def scatter_blocks(sub: jnp.ndarray, block_ids: np.ndarray,
                   num_blocks: int, align: int = ALIGN) -> jnp.ndarray:
    """Inverse of :func:`gather_blocks`: place a contiguous block slice
    back into a zero-filled full-width vector ((size,) or (k, size)).
    Unselected blocks are exactly zero, so a scattered tier delta
    contributes nothing outside its tier's trainable blocks."""
    ids = jnp.asarray(block_ids, jnp.int32)
    if sub.ndim == 1:
        out = jnp.zeros((num_blocks, align), jnp.float32)
        return out.at[ids].set(sub.reshape(-1, align)).reshape(-1)
    k = sub.shape[0]
    out = jnp.zeros((k, num_blocks, align), jnp.float32)
    return out.at[:, ids].set(sub.reshape(k, -1, align)).reshape(k, -1)


def expand_block_mask(mask: jnp.ndarray, align: int = ALIGN) -> jnp.ndarray:
    """(num_blocks,) 0/1 -> (size,) elementwise mask (static repeat)."""
    return jnp.repeat(jnp.asarray(mask, jnp.float32), align)


# ---------------------------------------------------------------------------
# Flat ops used by the round engine. Each dispatches: fused Pallas kernel
# on TPU, reshaped pure-JAX fallback (kernels/ref.py) elsewhere.


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def sumsq(vec: jnp.ndarray, align: int = ALIGN) -> jnp.ndarray:
    """Sum of squares of a flat vector (scalar, fp32)."""
    from repro.kernels import ref
    if _on_tpu() and vec.ndim == 1 and vec.shape[0] % align == 0:
        from repro.kernels import dp_clip
        return dp_clip.sumsq(vec)
    return ref.flat_sumsq_ref(vec, chunk=align)


def row_sumsq(mat: jnp.ndarray, align: int = ALIGN) -> jnp.ndarray:
    """(C, size) -> (C,) per-row sum of squares, single pass."""
    from repro.kernels import ref
    return ref.row_sumsq_ref(mat, chunk=align)


def row_norms(mat: jnp.ndarray, align: int = ALIGN) -> jnp.ndarray:
    return jnp.sqrt(row_sumsq(mat, align))


def clip(vec: jnp.ndarray, clip_norm: float,
         layout: Optional[FlatLayout] = None):
    """Per-vector L2 clip: vec * min(1, C/||vec||). Returns (clipped,
    pre-clip norm). Fused two-pass kernel on TPU (kernels/dp_clip.py)."""
    align = layout.align if layout is not None else ALIGN
    if _on_tpu() and vec.shape[0] and vec.shape[0] % align == 0:
        from repro.kernels import ops
        return ops.flat_clip(vec, clip_norm)
    from repro.kernels import ref
    return ref.flat_clip_ref(vec, clip_norm, chunk=align)


def fake_quantize(mat: jnp.ndarray, layout: FlatLayout, bits: int = 8):
    """Per-leaf symmetric int-k fake-quantization of flat client deltas.

    ``mat`` is (C, size) or (size,). Scales are per (client, leaf) —
    exactly `compress.quantize_leaf`'s max-abs/qmax — computed from the
    block view, so the result matches the tree path bit-for-bit.
    """
    if layout.size == 0:
        return mat
    squeeze = mat.ndim == 1
    if squeeze:
        mat = mat[None]
    block_leaf = layout.block_leaf()
    if _on_tpu() and bits == 8:
        from repro.kernels import ops
        out = jax.lax.map(
            lambda row: ops.fake_quantize_flat(row, block_leaf,
                                               len(layout.sizes),
                                               block=layout.align), mat)
    else:
        from repro.kernels import ref
        out = ref.fake_quantize_flat_ref(mat, block_leaf, bits=bits,
                                         block=layout.align)
    return out[0] if squeeze else out


def weighted_mean(mat: jnp.ndarray, weights: jnp.ndarray,
                  wsum: jnp.ndarray) -> jnp.ndarray:
    """(C, size), (C,) -> (size,): sum_c w_c * mat_c / wsum as ONE dot.

    Bit-for-bit identical to the old per-leaf ``tensordot`` sweep (same
    dot_general reduction over the client axis, same fp32 division), so
    sync-mode histories are unchanged when DP/quantization are off.
    """
    return jnp.matmul(weights.astype(jnp.float32),
                      mat.astype(jnp.float32)) / wsum


def block_masked_mean(mat: jnp.ndarray, weights: jnp.ndarray,
                      block_masks: jnp.ndarray,
                      align: int = ALIGN) -> jnp.ndarray:
    """(C, size), (C,), (C, num_blocks) -> (size,): the trainability-tier
    aggregation rule, shared by the sync round engine and the async
    buffered apply so the two cannot drift numerically.

    Per block j: sum_c w_c mat_c[j] / max(sum_c w_c m_c[j], eps) — a
    client contributes zero weight on blocks its tier froze (its rows
    are already zero there), and blocks nobody trained keep delta 0.
    Reduces to :func:`weighted_mean` when every mask is all-ones."""
    w = weights.astype(jnp.float32)
    num = jnp.matmul(w, mat.astype(jnp.float32))
    den = jnp.repeat(jnp.maximum(jnp.matmul(w, block_masks), 1e-12), align)
    return num / den


def pad_rows(mat: jnp.ndarray, rows: int) -> jnp.ndarray:
    """Pad a (k, size) stack to (rows, size) with zero rows (k <= rows).

    The async grid's drained final flush uses this to keep the buffered
    apply at its fixed ``goal_count`` shape: padding rows carry zero
    weight, so they fall out of the weighted mean — and under per-flush
    DP the fixed-denominator mean and noise sigma are unchanged by them.
    """
    if mat.shape[0] > rows:
        raise ValueError(f"cannot pad {mat.shape[0]} rows down to {rows}")
    if mat.shape[0] == rows:
        return mat
    pad = jnp.zeros((rows - mat.shape[0],) + mat.shape[1:], mat.dtype)
    return jnp.concatenate([mat, pad])


def draw_noise(rng, size: int, sigma: float) -> jnp.ndarray:
    """Pre-draw the (size,) Gaussian :func:`add_noise` would add:
    ``add_noise(v, sigma, rng) == v + draw_noise(rng, v.size, sigma)``
    bit-for-bit (same single PRNG call, same scaling) — the invariance
    contract the fused aggregation tail relies on to start its
    accumulator from the noise vector instead of sweeping again."""
    return sigma * jax.random.normal(rng, (size,), jnp.float32)


def add_noise(vec: jnp.ndarray, sigma: float, rng) -> jnp.ndarray:
    """Add N(0, sigma^2) to the flat vector: ONE PRNG call instead of
    one per leaf. Pad slots receive noise too — ``unflatten`` discards
    them, so the model update is untouched; only flat-vector norms see
    the extra energy (callers that report a post-noise update norm
    compute it from the unflattened tree)."""
    return vec + sigma * jax.random.normal(rng, vec.shape, jnp.float32)
