"""Heterogeneous trainability tiers: per-client freeze plans.

FedPT's headline trade-off freezes ONE fixed portion of the model for
every client. Real fleets are heterogeneous — weak devices should train
*less* of the model than strong ones (the paper's §5 future work; FedPLT
and Partial Variable Training show this is where the scalability wins
live). A :class:`TrainPlan` promotes the single global ``freeze_spec``
into a first-class set of named **tiers**:

* the *global* trainable tree ``y`` stays what ``freeze_spec`` says it
  is — the union of everything any tier trains (tier 0's set);
* each tier adds an **additive** freeze spec over ``y``: regexes naming
  the leaves that tier does NOT train. Tier 0 is conventionally ``full``
  (no extra freezing); higher tiers freeze supersets and suit weaker
  devices;
* compiling a plan against ``y`` (:func:`compile_plan`) turns each tier
  into a static *sub-layout* of the global :class:`~repro.core.flat.FlatLayout`:
  a 0/1 block mask plus a gather/scatter index map, exploiting the
  layout's whole-block-per-leaf padding. A tier's delta is therefore a
  contiguous ``(tier_size,)`` slice that scatters into the global
  ``(K, size)`` aggregation buffer with one static-index op.

Aggregation semantics (mirroring ``core/adaptive.py``'s per-leaf rule,
now per block over the flat plane): a client contributes zero delta and
zero *weight* on blocks its tier froze, so

    delta[j] = sum_i w_i m_{t(i)}[j] delta_i[j] / sum_i w_i m_{t(i)}[j]

and blocks nobody trained this round/flush keep ``delta = 0``. Under DP
the denominator stays the FIXED cohort/goal count — clipping the masked
row bounds per-client sensitivity exactly as before, so clip norms and
noise calibration are unchanged by tiering.

Communication: tier t uploads only its own trainable blocks — the wire
(``sim/wire.py``) and the ledger (``core/comm.py``) bill each transfer
at tier-sliced byte counts. Downlink stays the full trainable tree plus
seed for every tier: frozen-for-this-tier blocks are still *trained by
other tiers*, so their current values cannot be regenerated from the
seed and must be downloaded for the forward pass.

A one-tier plan covering all clients is the pre-plan single-spec system,
bit for bit: :func:`compile_plan` marks it ``trivial`` and every
consumer routes trivial plans through the original code path.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flat as flat_lib
from repro.nn import basic


@dataclasses.dataclass(frozen=True)
class Tier:
    """One named trainability tier: ``freeze_spec`` regexes are ADDITIVE
    over the global trainable tree (paths the tier does not train)."""
    name: str
    freeze_spec: Tuple[str, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "freeze_spec", tuple(self.freeze_spec))


@dataclasses.dataclass(frozen=True)
class TrainPlan:
    """Ordered tiers, most capable first (tier 0 = fewest frozen leaves).

    Construct from a dict (``TrainPlan.of({"full": (), "lite": (r"^conv",)})``),
    a sequence of (name, spec) pairs, or pass ``Tier`` objects directly.
    """
    tiers: Tuple[Tier, ...]

    def __post_init__(self):
        object.__setattr__(self, "tiers", tuple(self.tiers))
        if not self.tiers:
            raise ValueError("a TrainPlan needs at least one tier")
        names = [t.name for t in self.tiers]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tier names: {names}")

    @classmethod
    def of(cls, spec: Union["TrainPlan", Dict[str, Sequence[str]],
                            Sequence]) -> "TrainPlan":
        if isinstance(spec, TrainPlan):
            return spec
        if isinstance(spec, dict):
            return cls(tuple(Tier(n, tuple(s)) for n, s in spec.items()))
        tiers = []
        for item in spec:
            if isinstance(item, Tier):
                tiers.append(item)
            else:
                name, fs = item
                tiers.append(Tier(name, tuple(fs)))
        return cls(tuple(tiers))

    @classmethod
    def single(cls, name: str = "full") -> "TrainPlan":
        """The pre-plan world: one tier, nothing extra frozen."""
        return cls((Tier(name, ()),))

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(t.name for t in self.tiers)

    def __len__(self) -> int:
        return len(self.tiers)


@dataclasses.dataclass(frozen=True)
class TierSlice:
    """A tier compiled against the global FlatLayout: static block mask
    and gather/scatter index map. All fields are Python/numpy statics —
    closing over a TierSlice adds no jit arguments."""
    name: str
    index: int
    freeze_spec: Tuple[str, ...]
    leaf_on: Tuple[bool, ...]     # per global-layout leaf: trained here?
    block_ids: np.ndarray         # (tier_blocks,) int32 global block ids
    size: int                     # tier_blocks * align (padded flat width)
    param_count: int              # true (unpadded) trainable params
    trainable_bytes: int          # true bytes — what the wire bills

    @property
    def num_blocks(self) -> int:
        return len(self.block_ids)


@dataclasses.dataclass(frozen=True)
class CompiledPlan:
    """A TrainPlan bound to one trainable tree ``y``.

    ``layout`` is the global flat layout; ``tiers[t]`` the per-tier
    sub-layout. ``trivial`` plans (one tier training every leaf) are the
    signal for consumers to keep the original single-spec code path —
    the acceptance contract is that a trivial plan reproduces it bit for
    bit.
    """
    plan: TrainPlan
    layout: flat_lib.FlatLayout
    paths: Tuple[str, ...]        # leaf paths, layout (tree_flatten) order
    tiers: Tuple[TierSlice, ...]

    @property
    def trivial(self) -> bool:
        return len(self.tiers) == 1 and all(self.tiers[0].leaf_on)

    @property
    def names(self) -> Tuple[str, ...]:
        return self.plan.names

    def block_masks(self) -> np.ndarray:
        """(n_tiers, num_blocks) float32 stacked 0/1 block masks — the
        per-row tier masks the round engine indexes with runtime tier
        ids."""
        return np.stack([self.layout.block_mask(t.leaf_on)
                         for t in self.tiers])

    def leaf_masks(self) -> List[Dict[str, Any]]:
        """Per-tier 0/1 leaf-mask trees over ``y`` (gradient masking in
        the mixed-tier sync engine)."""
        out = []
        for t in self.tiers:
            flat = {p: jnp.asarray(1.0 if on else 0.0, jnp.float32)
                    for p, on in zip(self.paths, t.leaf_on)}
            out.append(basic.unflatten_params(flat))
        return out

    # -- per-tier structural split (async lane steps) --------------------

    def split(self, y, tier: TierSlice):
        """(tier-trainable subtree, tier-extra-frozen subtree) of ``y``.
        Leaf order inside the subtree matches the global layout order, so
        the subtree's own FlatLayout is exactly the tier's contiguous
        block slice."""
        flat = dict(basic.flatten_params(y))
        train = {p: flat[p] for p, on in zip(self.paths, tier.leaf_on) if on}
        frozen = {p: flat[p] for p, on in zip(self.paths, tier.leaf_on)
                  if not on}
        return basic.unflatten_params(train), basic.unflatten_params(frozen)

    # -- gather / scatter over the flat plane ----------------------------

    def gather(self, vec: jnp.ndarray, tier: TierSlice) -> jnp.ndarray:
        """Global (size,)/(k, size) -> contiguous tier slice."""
        return flat_lib.gather_blocks(vec, tier.block_ids, self.layout.align)

    def scatter(self, sub: jnp.ndarray, tier: TierSlice) -> jnp.ndarray:
        """Contiguous (tier_size,)/(k, tier_size) slice -> zero-filled
        global width."""
        return flat_lib.scatter_blocks(sub, tier.block_ids,
                                       self.layout.num_blocks,
                                       self.layout.align)


def _tier_slice(plan: TrainPlan, layout: flat_lib.FlatLayout,
                paths: Sequence[str], sizes, dtypes, index: int) -> TierSlice:
    tier = plan.tiers[index]
    leaf_on = tuple(not any(re.search(p, path) for p in tier.freeze_spec)
                    for path in paths)
    block_ids = layout.leaf_blocks(leaf_on)
    pcount = sum(n for n, on in zip(sizes, leaf_on) if on)
    tbytes = sum(n * np.dtype(d).itemsize
                 for n, d, on in zip(sizes, dtypes, leaf_on) if on)
    return TierSlice(name=tier.name, index=index,
                     freeze_spec=tier.freeze_spec, leaf_on=leaf_on,
                     block_ids=block_ids,
                     size=len(block_ids) * layout.align,
                     param_count=int(pcount), trainable_bytes=int(tbytes))


def compile_plan(plan, y) -> CompiledPlan:
    """Bind a plan (TrainPlan / dict / sequence) to the trainable tree.

    Validates that every tier trains at least one leaf of a non-empty
    ``y`` — a tier that freezes all of it would dispatch clients that
    upload nothing and learn nothing, which is a fleet-configuration
    bug, not a tier. (An empty ``y`` — the global freeze_spec froze the
    whole model — compiles to zero-size tiers so analytic summaries
    still work; the grid rejects it elsewhere.)
    """
    plan = TrainPlan.of(plan)
    layout = flat_lib.FlatLayout.of(y)
    paths = tuple(p for p, _ in basic.flatten_params(y))
    if len(paths) != len(layout.sizes):
        raise ValueError("trainable tree has non-dict structure the "
                         "path-based plan cannot address")
    tiers = tuple(_tier_slice(plan, layout, paths, layout.sizes,
                              layout.dtypes, i) for i in range(len(plan)))
    for t in tiers:
        if paths and not any(t.leaf_on):
            raise ValueError(f"tier {t.name!r} freezes every trainable "
                             "leaf — it would train nothing")
    return CompiledPlan(plan=plan, layout=layout, paths=paths, tiers=tiers)
