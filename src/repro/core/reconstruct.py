"""Seed-based reconstruction of frozen parameters (Algorithm 1, line 5).

The server never ships frozen bytes: clients receive ``(y_t, z)`` where
``z`` is a scalar integer seed, and regenerate the frozen leaves locally.
Determinism comes from path-keyed initialization (nn/basic.py): every
leaf's PRNG key is ``fold_in(key(z), crc32(path))``, so any holder of
``z`` reproduces the exact same Gaussians.

``make_reconstructor`` returns a jitted function of *no arguments* whose
HLO contains only the frozen-leaf RNG ops — the trainable side of the
init is dead-code-eliminated by XLA. On TPU the same job is done by the
``seed_reconstruct`` Pallas kernel (kernels/seed_reconstruct.py) which
generates the Gaussians directly in VMEM tiles.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict

import jax

import repro.core.partition as part


def reconstruct(init_fn: Callable[[int], Dict[str, Any]], seed: int,
                freeze_spec) -> Dict[str, Any]:
    """Regenerate the frozen tree from the scalar seed."""
    return part.partition(init_fn(seed), freeze_spec)[1]


def make_reconstructor(init_fn, seed: int, freeze_spec):
    """Jitted zero-arg reconstructor; XLA DCEs the trainable-side init."""

    @jax.jit
    def _rec():
        return part.partition(init_fn(seed), freeze_spec)[1]

    return _rec


def init_partitioned(init_fn, seed: int, freeze_spec):
    """Server-side round-0 split: (y0, frozen, seed)."""
    full = init_fn(seed)
    y, z = part.partition(full, freeze_spec)
    return y, z


def verify_roundtrip(init_fn, seed: int, freeze_spec) -> bool:
    """Invariant: merge(partition(x)) == x and reconstruct is exact."""
    full = init_fn(seed)
    y, z = part.partition(full, freeze_spec)
    z2 = reconstruct(init_fn, seed, freeze_spec)
    ok = jax.tree_util.tree_all(jax.tree_util.tree_map(
        lambda a, b: (a == b).all(), z, z2))
    merged = part.merge(y, z)
    from repro.nn import basic
    fa = dict(basic.flatten_params(full))
    fb = dict(basic.flatten_params(merged))
    ok2 = set(fa) == set(fb) and all(
        bool((fa[k] == fb[k]).all()) for k in fa)
    return bool(ok) and ok2
