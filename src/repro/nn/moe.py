"""Mixture-of-Experts: top-k router + capacity-bounded sort-based dispatch.

TPU-native design: tokens are routed with a sort (XLA sort lowers well on
TPU), scattered into a dense (experts, capacity, d_model) buffer, expert
FFNs run as one batched einsum whose expert dimension is sharded over the
`model` mesh axis (expert parallelism — GSPMD inserts the all-to-all when
resharding token-sharded activations to expert-sharded buffers), and
combined back with the router weights. Overflowing tokens beyond capacity
are dropped (standard Switch/GShard semantics).

Shared experts (DeepSeek-V2) run densely on every token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import basic
from repro.configs.base import ModelConfig


def _maybe_constrain(x, spec):
    return basic.maybe_constrain(x, spec)


def init_moe(seed, path, cfg: ModelConfig, dtype):
    d, e = cfg.d_model, cfg.num_experts
    ff = cfg.expert_d_ff
    p = {
        "router": basic.init_dense(seed, f"{path}/router", d, e, dtype),
        # stacked expert weights: (E, d, ff) / (E, ff, d)
        "wi_gate": basic.normal_init(seed, f"{path}/wi_gate", (e, d, ff), dtype, fan_in=d),
        "wi_up": basic.normal_init(seed, f"{path}/wi_up", (e, d, ff), dtype, fan_in=d),
        "wo": basic.normal_init(seed, f"{path}/wo", (e, ff, d), dtype, fan_in=ff),
    }
    if cfg.num_shared_experts > 0:
        sff = cfg.expert_d_ff * cfg.num_shared_experts
        p["shared"] = basic.init_mlp(seed, f"{path}/shared", d, sff, dtype,
                                     gated=True)
    return p


def router_topk(x, p, cfg: ModelConfig):
    """Returns (weights (T,k), experts (T,k) int32, aux_loss scalar)."""
    logits = basic.dense(x, p["router"], jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    e = cfg.num_experts
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=1), axis=0)
    aux = e * jnp.sum(me * ce)
    return w.astype(x.dtype), idx.astype(jnp.int32), aux


def _sort_dispatch(x, w, idx, e: int, cap: int, cd):
    """Sort-based dispatch of (T, d) tokens into an (E, cap, d) buffer.
    Returns (buf, combine_meta) where combine_meta = (st, sw, keep, slot)."""
    T, d = x.shape
    k = idx.shape[1]
    flat_e = idx.reshape(-1)                        # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    flat_w = w.reshape(-1)

    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    # position of each routed token within its expert's buffer
    starts = jnp.searchsorted(se, jnp.arange(e, dtype=se.dtype))
    pos = jnp.arange(T * k, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    keep = pos < cap
    slot = jnp.where(keep, se.astype(jnp.int32) * cap + pos, e * cap)
    buf = jnp.zeros((e * cap + 1, d), cd)
    buf = buf.at[slot].set(x[st].astype(cd), mode="drop")
    return buf[: e * cap].reshape(e, cap, d), (st, sw, keep, slot)


def _combine_local(y_flat, meta, T: int, e: int, cap: int, cd):
    """Inverse of _sort_dispatch: weighted scatter back into (T, d)."""
    st, sw, keep, slot = meta
    d = y_flat.shape[-1]
    gathered = jnp.where(keep[:, None],
                         y_flat[jnp.clip(slot, 0, e * cap - 1)], 0.0)
    gathered = gathered * sw[:, None].astype(cd)
    return jnp.zeros((T, d), cd).at[st].add(gathered)


def moe_ffn(x, p, cfg: ModelConfig):
    """x: (T, d) flat tokens -> (T, d), plus aux loss.

    Sort-based dispatch with capacity = ceil(T*k/E * capacity_factor).
    With cfg.moe_dispatch_groups > 1 the sort/scatter runs group-LOCALLY
    (groups sharded over the data axis) so no global argsort / scatter
    collectives are emitted — only the expert-parallel all-to-all.
    """
    T, d = x.shape
    g = cfg.moe_dispatch_groups
    if g and g > 1 and T % g == 0 and T // g >= cfg.num_experts_per_tok:
        return _moe_ffn_grouped(x, p, cfg, g)
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    cap = int(max(1, round(T * k / e * cfg.moe_capacity_factor)))
    cd = cfg.cdtype

    w, idx, aux = router_topk(x, p, cfg)  # (T,k)

    # scatter tokens into (E*C+1, d); last row is the drop bucket.
    # Expert-dim sharding axis mirrors launch/sharding.py: "data" for huge
    # banks (2-D expert sharding), "model" when divisible, else intra-
    # expert TP (shard the FFN dim only).
    if cfg.num_experts >= 64:
        expert_axis, ff_axis = "data", "model"
    else:
        expert_axis, ff_axis = "model", None
    buf, meta = _sort_dispatch(x, w, idx, e, cap, cd)
    buf = _maybe_constrain(buf, (expert_axis, None, None))

    # expert FFN: batched over the (sharded) expert dim — this reshard is
    # the expert-parallel all-to-all under GSPMD
    g = jnp.einsum("ecd,edf->ecf", buf, p["wi_gate"].astype(cd))
    u = jnp.einsum("ecd,edf->ecf", buf, p["wi_up"].astype(cd))
    h = jax.nn.silu(g) * u
    h = _maybe_constrain(h, (expert_axis, None, ff_axis))
    y = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(cd))
    y = _maybe_constrain(y, (expert_axis, None, None))

    # combine: gather back to (T*k, d), weight, segment-sum into tokens
    out = _combine_local(y.reshape(e * cap, d), meta, T, e, cap, cd)

    if cfg.num_shared_experts > 0:
        out = out + basic.mlp(x, p["shared"], "silu", cd)
    return out, aux


def _moe_ffn_grouped(x, p, cfg: ModelConfig, g: int):
    """Group-local dispatch (perf variant, DESIGN.md §Perf/H1).

    Tokens reshape to (g, T/g, d) with the group dim pinned to the data
    axis; routing, sort, scatter and combine are all group-local (no
    cross-group collectives). Only the batched expert einsum crosses the
    mesh — the canonical expert-parallel all-to-all.
    """
    T, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    cd = cfg.cdtype
    Tl = T // g
    cap = int(max(1, round(Tl * k / e * cfg.moe_capacity_factor)))

    xg = _maybe_constrain(x.reshape(g, Tl, d), ("data", None, None))

    def local(xl):
        w, idx, aux = router_topk(xl, p, cfg)
        buf, meta = _sort_dispatch(xl, w, idx, e, cap, cd)
        return buf, meta, aux

    bufs, metas, auxs = jax.vmap(local)(xg)          # (g, E, cap, d)
    # Iteration 2 (EXPERIMENTS.md §Perf/H1): keep group dim on "data" AND
    # expert dim on "model" through the expert einsums — the per-shard
    # expert weights (O(100MB)) gather across their secondary axis instead
    # of the O(10GB) token buffers.
    e_ax = "model"
    bufs = _maybe_constrain(bufs, ("data", e_ax, None, None))

    gg = jnp.einsum("gecd,edf->gecf", bufs, p["wi_gate"].astype(cd))
    uu = jnp.einsum("gecd,edf->gecf", bufs, p["wi_up"].astype(cd))
    h = jax.nn.silu(gg) * uu
    h = _maybe_constrain(h, ("data", e_ax, None, None))
    y = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(cd))
    y = _maybe_constrain(y, ("data", None, None, None))

    out = jax.vmap(
        lambda yl, st, sw, keep, slot: _combine_local(
            yl.reshape(e * cap, d), (st, sw, keep, slot), Tl, e, cap, cd)
    )(y, *metas)
    out = out.reshape(T, d)
    if cfg.num_shared_experts > 0:
        out = out + basic.mlp(x, p["shared"], "silu", cd)
    return out, jnp.mean(auxs)


def moe_ffn_dense_fallback(x, p, cfg: ModelConfig):
    """Reference: run every expert on every token and mask (oracle for tests)."""
    T, d = x.shape
    cd = jnp.float32
    w, idx, aux = router_topk(x, p, cfg)
    g = jnp.einsum("td,edf->tef", x.astype(cd), p["wi_gate"].astype(cd))
    u = jnp.einsum("td,edf->tef", x.astype(cd), p["wi_up"].astype(cd))
    h = jax.nn.silu(g) * u
    y = jnp.einsum("tef,efd->ted", h, p["wo"].astype(cd))
    mask = jnp.zeros((T, cfg.num_experts), cd)
    mask = mask.at[jnp.arange(T)[:, None], idx].add(w.astype(cd))
    out = jnp.einsum("ted,te->td", y, mask)
    if cfg.num_shared_experts > 0:
        out = out + basic.mlp(x.astype(cd), p["shared"], "silu", cd)
    return out.astype(x.dtype), aux
