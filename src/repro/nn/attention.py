"""Attention: RoPE, GQA multi-head attention with chunked online-softmax
(flash-style, bounded memory at 32k+ sequence lengths), sliding windows,
MLA (DeepSeek-V2 multi-head latent attention), and single-token decode
against a KV cache (including sequence-sharded caches for 500k context).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn import basic
from repro.configs.base import ModelConfig

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE


def rope_freqs(head_dim: int, theta: float, positions):
    """positions: (..., seq) int32 -> cos/sin (..., seq, head_dim//2)."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., seq, heads, head_dim); cos/sin: (..., seq, head_dim//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(x.dtype)  # broadcast over heads
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ---------------------------------------------------------------------------
# GQA projections


def init_attention(seed, path, cfg: ModelConfig, dtype):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    b = cfg.qkv_bias
    return {
        "wq": basic.init_dense(seed, f"{path}/wq", d, h * hd, dtype, bias=b),
        "wk": basic.init_dense(seed, f"{path}/wk", d, kv * hd, dtype, bias=b),
        "wv": basic.init_dense(seed, f"{path}/wv", d, kv * hd, dtype, bias=b),
        "wo": basic.init_dense(seed, f"{path}/wo", h * hd, d, dtype, bias=False),
    }


def qkv_project(x, p, cfg: ModelConfig):
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    cd = cfg.cdtype
    q = basic.dense(x, p["wq"], cd).reshape(b, s, h, hd)
    k = basic.dense(x, p["wk"], cd).reshape(b, s, kv, hd)
    v = basic.dense(x, p["wv"], cd).reshape(b, s, kv, hd)
    return q, k, v


# ---------------------------------------------------------------------------
# Chunked (flash-style) causal attention.
#
# Memory stays O(seq * chunk) instead of O(seq^2): we scan over KV chunks
# carrying the online-softmax running (max, sum, acc). Sliding windows skip
# out-of-window chunks entirely via lax.cond-free masking (masked chunks
# contribute exp(-inf)=0; XLA still executes them, the Pallas kernel in
# kernels/swa_attention.py skips them structurally on TPU).


def _attend_chunk(q, k, v, qpos, kpos, window: int, softcap: float, scale,
                  causal: bool, prefix_len: int):
    """q:(b,h,sq,d) k,v:(b,h,sc,d) -> logits-masked scores (b,h,sq,sc)."""
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    if causal:
        mask = qpos[:, None] >= kpos[None, :]
        if prefix_len > 0:  # bidirectional prefix (PaliGemma-style)
            mask = mask | (kpos[None, :] < prefix_len)
        if window > 0:
            mask = mask & (qpos[:, None] - kpos[None, :] < window)
    else:
        mask = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    return jnp.where(mask[None, None], s, NEG_INF)


def flash_attention(q, k, v, cfg: ModelConfig, q_offset=0, chunk: int = 512,
                    causal: bool = True, prefix_len: int = 0):
    """Causal (optionally sliding-window) attention.

    q: (b, sq, h, hd);  k, v: (b, skv, kv_heads, hd_k); v may have a
    different per-head dim than q/k (MLA).
    q_offset: position of q[0] relative to k[0] (for prefill continuation).
    Returns (b, sq, h, dv).
    """
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    kvh = k.shape[2]
    dv = v.shape[3]
    rep = h // kvh
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    window = cfg.sliding_window

    qh = q.transpose(0, 2, 1, 3)  # b,h,sq,hd
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    if rep > 1:
        kh = jnp.repeat(kh, rep, axis=1)
        vh = jnp.repeat(vh, rep, axis=1)

    nchunks = max(1, (skv + chunk - 1) // chunk)
    pad = nchunks * chunk - skv
    if pad:
        kh = jnp.pad(kh, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kh = kh.reshape(b, h, nchunks, chunk, hd).transpose(2, 0, 1, 3, 4)
    vh = vh.reshape(b, h, nchunks, chunk, dv).transpose(2, 0, 1, 3, 4)

    qpos = q_offset + jnp.arange(sq)

    def body(carry, xs):
        m, l, acc = carry
        kc, vc, ci = xs
        kpos = ci * chunk + jnp.arange(chunk)
        valid = kpos < skv
        s = _attend_chunk(qh, kc, vc, qpos, kpos, window, cfg.attn_logit_softcap,
                          scale, causal, prefix_len)
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kh, vh, jnp.arange(nchunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, cfg: ModelConfig):
    """One-token decode: q (b, 1, h, hd) against caches (b, S, kvh, hd).

    cache_len: scalar or (b,) number of valid cache positions. Works with a
    sequence-sharded cache under GSPMD (the softmax is numerically global —
    computed via max/sum reductions XLA turns into cross-shard psums).
    """
    b, _, h, hd = q.shape
    S, kvh = k_cache.shape[1], k_cache.shape[2]
    rep = h // kvh
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    kh = k_cache
    vh = v_cache
    if rep > 1:
        kh = jnp.repeat(kh, rep, axis=2)
        vh = jnp.repeat(vh, rep, axis=2)
    if cfg.decode_seq_parallel:
        # flash-decoding layout (perf variant, DESIGN.md §Perf/H2): the
        # tiny q replicates across "model"; the huge cache stays
        # sequence-sharded; softmax/PV reduce over the sharded S axis
        # (GSPMD emits psum of (b,h,1,dv) partials instead of
        # all-gathering the cache).
        q = basic.maybe_constrain(q, (("pod", "data"), None, None, None))
        kh = basic.maybe_constrain(kh, (("pod", "data"), "model", None, None))
        vh = basic.maybe_constrain(vh, (("pod", "data"), "model", None, None))
    s = jnp.einsum("bqhd,bshd->bhqs", q, kh,
                   preferred_element_type=jnp.float32) * scale
    if cfg.decode_seq_parallel:
        s = basic.maybe_constrain(s, (("pod", "data"), None, None, "model"))
    if cfg.attn_logit_softcap > 0:
        s = cfg.attn_logit_softcap * jnp.tanh(s / cfg.attn_logit_softcap)
    pos = jnp.arange(S)
    cl = jnp.asarray(cache_len)
    cl = cl[:, None, None, None] if cl.ndim else cl
    mask = pos[None, None, None, :] < cl
    if cfg.sliding_window > 0:
        mask = mask & (pos[None, None, None, :] >= cl - cfg.sliding_window)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(vh.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", p, vh,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# MLA — DeepSeek-V2 multi-head latent attention (arXiv:2405.04434).
#
# KV is compressed to a kv_lora_rank latent c_kv plus a shared rope key
# k_pe; decode caches only (c_kv, k_pe) — 576 dims instead of
# 2*num_heads*head_dim — and uses the absorbed-matmul form.


def init_mla(seed, path, cfg: ModelConfig, dtype):
    d = cfg.d_model
    h = cfg.num_heads
    qn, qr, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    qlr = cfg.q_lora_rank
    p = {
        "wkv_a": basic.init_dense(seed, f"{path}/wkv_a", d, r + qr, dtype),
        "kv_norm": basic.init_norm(seed, f"{path}/kv_norm", r, dtype, "rmsnorm"),
        "wk_b": basic.init_dense(seed, f"{path}/wk_b", r, h * qn, dtype),
        "wv_b": basic.init_dense(seed, f"{path}/wv_b", r, h * vd, dtype),
        "wo": basic.init_dense(seed, f"{path}/wo", h * vd, d, dtype),
    }
    if qlr > 0:
        p["wq_a"] = basic.init_dense(seed, f"{path}/wq_a", d, qlr, dtype)
        p["q_norm"] = basic.init_norm(seed, f"{path}/q_norm", qlr, dtype, "rmsnorm")
        p["wq_b"] = basic.init_dense(seed, f"{path}/wq_b", qlr, h * (qn + qr), dtype)
    else:
        p["wq"] = basic.init_dense(seed, f"{path}/wq", d, h * (qn + qr), dtype)
    return p


def mla_qkv(x, p, cfg: ModelConfig, positions):
    """Full (non-absorbed) MLA for train/prefill.

    Returns q, k, v shaped (b, s, h, dim) with rope applied; k/v have
    per-head dims qn+qr and v_head_dim. Also returns the compressed
    (c_kv, k_pe) pair for cache write.
    """
    b, s, _ = x.shape
    h = cfg.num_heads
    qn, qr, vd, r = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                     cfg.v_head_dim, cfg.kv_lora_rank)
    cd = cfg.cdtype

    if "wq_a" in p:
        qc = basic.dense(x, p["wq_a"], cd)
        qc = basic.rmsnorm(qc, p["q_norm"]["scale"])
        q = basic.dense(qc, p["wq_b"], cd).reshape(b, s, h, qn + qr)
    else:
        q = basic.dense(x, p["wq"], cd).reshape(b, s, h, qn + qr)

    kv = basic.dense(x, p["wkv_a"], cd)
    c_kv, k_pe = kv[..., :r], kv[..., r:]
    c_kv = basic.rmsnorm(c_kv, p["kv_norm"]["scale"])
    k_nope = basic.dense(c_kv, p["wk_b"], cd).reshape(b, s, h, qn)
    v = basic.dense(c_kv, p["wv_b"], cd).reshape(b, s, h, vd)

    cos, sin = rope_freqs(qr, cfg.rope_theta, positions)
    q_nope, q_pe = q[..., :qn], q[..., qn:]
    q_pe = apply_rope(q_pe, cos, sin)
    k_pe_r = apply_rope(k_pe[..., None, :], cos, sin)  # single shared rope head
    k_pe_b = jnp.broadcast_to(k_pe_r, (b, s, h, qr))
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    k = jnp.concatenate([k_nope, k_pe_b], axis=-1)
    return q, k, v, (c_kv, k_pe_r[..., 0, :])


def mla_compress(x, p, cfg: ModelConfig, positions):
    """Compute only the compressed cache entries (c_kv, roped k_pe) for a
    new token. x: (b, s, d) -> ckv (b, s, r), kpe (b, s, qr)."""
    r, qr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    cd = cfg.cdtype
    kv = basic.dense(x, p["wkv_a"], cd)
    c_kv, k_pe = kv[..., :r], kv[..., r:]
    c_kv = basic.rmsnorm(c_kv, p["kv_norm"]["scale"])
    cos, sin = rope_freqs(qr, cfg.rope_theta, positions)
    k_pe = apply_rope(k_pe[..., None, :], cos, sin)[..., 0, :]
    return c_kv, k_pe


def mla_decode(x, p, cfg: ModelConfig, ckv_cache, kpe_cache, cache_len):
    """Absorbed-form decode: score via latent space, cache is (c_kv, k_pe).

    x: (b, 1, d).  ckv_cache: (b, S, r). kpe_cache: (b, S, qr).
    """
    b, _, _ = x.shape
    h = cfg.num_heads
    qn, qr, vd, r = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                     cfg.v_head_dim, cfg.kv_lora_rank)
    cd = cfg.cdtype
    S = ckv_cache.shape[1]

    if "wq_a" in p:
        qc = basic.dense(x, p["wq_a"], cd)
        qc = basic.rmsnorm(qc, p["q_norm"]["scale"])
        q = basic.dense(qc, p["wq_b"], cd).reshape(b, 1, h, qn + qr)
    else:
        q = basic.dense(x, p["wq"], cd).reshape(b, 1, h, qn + qr)
    cl = jnp.asarray(cache_len)
    pos = jnp.broadcast_to((cl - 1).reshape(-1, 1), (b, 1))
    cos, sin = rope_freqs(qr, cfg.rope_theta, pos)
    q_nope, q_pe = q[..., :qn], q[..., qn:]
    q_pe = apply_rope(q_pe, cos, sin)

    # absorb W_UK into q: q_lat (b,1,h,r) = q_nope @ W_kb^T (per head)
    wkb = p["wk_b"]["kernel"].astype(cd).reshape(r, h, qn)
    q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope, wkb)

    scale = 1.0 / jnp.sqrt(qn + qr).astype(jnp.float32)
    s_lat = jnp.einsum("bqhr,bsr->bhqs", q_lat, ckv_cache.astype(cd),
                       preferred_element_type=jnp.float32)
    s_pe = jnp.einsum("bqhr,bsr->bhqs", q_pe, kpe_cache.astype(cd),
                      preferred_element_type=jnp.float32)
    s = (s_lat + s_pe) * scale
    spos = jnp.arange(S)
    clb = cl if cl.ndim else cl[None]
    mask = spos[None, None, None, :] < clb[:, None, None, None]
    s = jnp.where(mask, s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)

    # attention over latents, then up-project with absorbed W_UV
    o_lat = jnp.einsum("bhqs,bsr->bqhr", pr.astype(cd), ckv_cache.astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)
    wvb = p["wv_b"]["kernel"].astype(cd).reshape(r, h, vd)
    o = jnp.einsum("bqhr,rhv->bqhv", o_lat, wvb)
    o = o.reshape(b, 1, h * vd)
    return basic.dense(o, p["wo"], cd)
