"""State-space / recurrent blocks: Mamba (Jamba's SSM layer) and the two
xLSTM cells (mLSTM with matrix memory — chunkwise-parallel for training,
recurrent for decode — and sLSTM with scalar memory).

TPU adaptation: the mLSTM training path uses a *chunkwise* formulation
(intra-chunk quadratic on the MXU, inter-chunk state carried by a scan)
instead of a per-timestep recurrence, so the backward pass only
checkpoints one matrix state per chunk rather than per step. Mamba uses a
time scan with a small carried state (the selective-scan recurrence), and
single-step functions serve decode.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.nn import basic
from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# Causal depthwise conv1d (shared by Mamba & xLSTM blocks)


def causal_conv1d(x, w, b=None):
    """x: (B, S, C), w: (K, C) depthwise kernel -> (B, S, C)."""
    K, C = w.shape
    y = jax.lax.conv_general_dilated(
        x, w[:, None, :],  # (K, 1, C) io-feature
        window_strides=(1,), padding=[(K - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"), feature_group_count=C)
    if b is not None:
        y = y + b
    return y


def conv1d_step(x_t, conv_state, w, b=None):
    """Single decode step. x_t: (B, C); conv_state: (B, K-1, C)."""
    K, C = w.shape
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", window, w)
    if b is not None:
        y = y + b
    return y, window[:, 1:, :]


# ---------------------------------------------------------------------------
# Mamba (selective SSM), as used by Jamba [arXiv:2403.19887]


def mamba_dims(cfg: ModelConfig):
    d_inner = cfg.mamba_expand * cfg.d_model
    dt_rank = math.ceil(cfg.d_model / 16)
    return d_inner, dt_rank


def init_mamba(seed, path, cfg: ModelConfig, dtype):
    d = cfg.d_model
    d_inner, dt_rank = mamba_dims(cfg)
    n = cfg.mamba_d_state
    K = cfg.mamba_d_conv
    p = {
        "in_proj": basic.init_dense(seed, f"{path}/in_proj", d, 2 * d_inner, dtype),
        "conv_w": basic.normal_init(seed, f"{path}/conv_w", (K, d_inner), dtype,
                                    fan_in=K),
        "conv_b": basic.zeros_init(seed, f"{path}/conv_b", (d_inner,), dtype),
        "x_proj": basic.init_dense(seed, f"{path}/x_proj", d_inner,
                                   dt_rank + 2 * n, dtype),
        "dt_proj": basic.init_dense(seed, f"{path}/dt_proj", dt_rank, d_inner,
                                    dtype, bias=True),
        # A_log init: log(1..n) broadcast (S4D-real)
        "A_log": jnp.broadcast_to(
            jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)), (d_inner, n)
        ).astype(dtype),
        "D": basic.ones_init(seed, f"{path}/D", (d_inner,), dtype),
        "out_proj": basic.init_dense(seed, f"{path}/out_proj", d_inner, d, dtype),
    }
    return p


def _mamba_scan_inputs(x, p, cfg: ModelConfig):
    d_inner, dt_rank = mamba_dims(cfg)
    n = cfg.mamba_d_state
    cd = cfg.cdtype
    xz = basic.dense(x, p["in_proj"], cd)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = causal_conv1d(xs, p["conv_w"].astype(cd), p["conv_b"].astype(cd))
    xs = jax.nn.silu(xs)
    dbc = basic.dense(xs, p["x_proj"], cd)
    dt, B, C = jnp.split(dbc, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(basic.dense(dt, p["dt_proj"], cd))  # (B,S,d_inner)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))             # (d_inner, n)
    return xs, z, dt, B, C, A


def mamba_forward(x, p, cfg: ModelConfig, h0=None):
    """x: (B, S, d) -> (B, S, d); returns (out, (h_final, conv_tail))."""
    Bsz, S, _ = x.shape
    d_inner, _ = mamba_dims(cfg)
    n = cfg.mamba_d_state
    cd = cfg.cdtype
    xs, z, dt, B, C, A = _mamba_scan_inputs(x, p, cfg)

    dA = jnp.exp(dt.astype(jnp.float32)[..., None] * A)        # (B,S,di,n)
    # dBx: (dt*x) (B,S,di) outer B (B,S,n) -> (B,S,di,n)
    dBx = (dt * xs).astype(jnp.float32)[..., None] * B.astype(jnp.float32)[:, :, None, :]

    h_init = jnp.zeros((Bsz, d_inner, n), jnp.float32) if h0 is None else h0

    def step(h, inp):
        dA_t, dBx_t, C_t = inp
        h = dA_t * h + dBx_t
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    (h_fin, ys) = jax.lax.scan(
        step, h_init,
        (dA.swapaxes(0, 1), dBx.swapaxes(0, 1),
         C.astype(jnp.float32).swapaxes(0, 1)))
    y = ys.swapaxes(0, 1).astype(cd)                           # (B,S,di)
    y = y + xs * p["D"].astype(cd)
    y = y * jax.nn.silu(z)
    out = basic.dense(y, p["out_proj"], cd)
    # conv tail for decode continuation
    K = cfg.mamba_d_conv
    xz = basic.dense(x, p["in_proj"], cd)
    conv_tail = jnp.split(xz, 2, axis=-1)[0][:, -(K - 1):, :]
    return out, (h_fin, conv_tail)


def mamba_step(x_t, p, cfg: ModelConfig, state):
    """Decode step. x_t: (B, d); state = (h (B,di,n), conv (B,K-1,di))."""
    h, conv_state = state
    d_inner, dt_rank = mamba_dims(cfg)
    n = cfg.mamba_d_state
    cd = cfg.cdtype
    xz = basic.dense(x_t, p["in_proj"], cd)
    xs, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = conv1d_step(xs, conv_state, p["conv_w"].astype(cd),
                                 p["conv_b"].astype(cd))
    xc = jax.nn.silu(xc)
    dbc = basic.dense(xc, p["x_proj"], cd)
    dt, B, C = jnp.split(dbc, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(basic.dense(dt, p["dt_proj"], cd)).astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt[..., None] * A)
    dBx = (dt * xc.astype(jnp.float32))[..., None] * B.astype(jnp.float32)[:, None, :]
    h = dA * h + dBx
    y = jnp.einsum("bdn,bn->bd", h, C.astype(jnp.float32)).astype(cd)
    y = y + xc * p["D"].astype(cd)
    y = y * jax.nn.silu(z)
    return basic.dense(y, p["out_proj"], cd), (h, conv_state)


# ---------------------------------------------------------------------------
# mLSTM (xLSTM, arXiv:2405.04517) — matrix memory with exponential gating.
#
# Chunkwise-parallel training form; per-head state (C: dh x dh, n: dh).


def xlstm_dims(cfg: ModelConfig):
    d_in = int(cfg.xlstm_proj_factor * cfg.d_model)
    nh = cfg.num_heads
    dh = d_in // nh
    return d_in, nh, dh


def init_mlstm(seed, path, cfg: ModelConfig, dtype):
    d = cfg.d_model
    d_in, nh, dh = xlstm_dims(cfg)
    K = 4
    return {
        "up_proj": basic.init_dense(seed, f"{path}/up_proj", d, 2 * d_in, dtype),
        "conv_w": basic.normal_init(seed, f"{path}/conv_w", (K, d_in), dtype, fan_in=K),
        "conv_b": basic.zeros_init(seed, f"{path}/conv_b", (d_in,), dtype),
        "wq": basic.init_dense(seed, f"{path}/wq", d_in, d_in, dtype, bias=True),
        "wk": basic.init_dense(seed, f"{path}/wk", d_in, d_in, dtype, bias=True),
        "wv": basic.init_dense(seed, f"{path}/wv", d_in, d_in, dtype, bias=True),
        "w_if": basic.init_dense(seed, f"{path}/w_if", d_in, 2 * nh, dtype, bias=True),
        "ogate_norm": basic.init_norm(seed, f"{path}/ogate_norm", d_in, dtype,
                                      "rmsnorm"),
        "down_proj": basic.init_dense(seed, f"{path}/down_proj", d_in, d, dtype),
    }


def _mlstm_qkvif(x, p, cfg: ModelConfig):
    d_in, nh, dh = xlstm_dims(cfg)
    cd = cfg.cdtype
    B, S, _ = x.shape
    up = basic.dense(x, p["up_proj"], cd)
    xm, z = jnp.split(up, 2, axis=-1)
    xc = causal_conv1d(xm, p["conv_w"].astype(cd), p["conv_b"].astype(cd))
    xc = jax.nn.silu(xc)
    q = basic.dense(xc, p["wq"], cd).reshape(B, S, nh, dh).transpose(0, 2, 1, 3)
    k = basic.dense(xc, p["wk"], cd).reshape(B, S, nh, dh).transpose(0, 2, 1, 3)
    v = basic.dense(xm, p["wv"], cd).reshape(B, S, nh, dh).transpose(0, 2, 1, 3)
    g = basic.dense(xc, p["w_if"], jnp.float32)
    log_i, f_pre = jnp.split(g, 2, axis=-1)                    # (B,S,nh)
    log_i = log_i.transpose(0, 2, 1)                            # exp input gate
    log_f = jax.nn.log_sigmoid(f_pre).transpose(0, 2, 1)        # sigmoid forget
    k = k / jnp.sqrt(jnp.asarray(dh, cd))
    return q, k, v, log_i, log_f, z


def mlstm_forward(x, p, cfg: ModelConfig, state=None, chunk: int = 128):
    """x: (B,S,d) -> (B,S,d). Chunkwise-parallel mLSTM."""
    B, S, _ = x.shape
    d_in, nh, dh = xlstm_dims(cfg)
    cd = cfg.cdtype
    q, k, v, log_i, log_f, z = _mlstm_qkvif(x, p, cfg)

    nchunks = max(1, (S + chunk - 1) // chunk)
    pad = nchunks * chunk - S
    if pad:
        q, k, v = (jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0))) for t in (q, k, v))
        log_i = jnp.pad(log_i, ((0, 0), (0, 0), (0, pad)), constant_values=-30.0)
        log_f = jnp.pad(log_f, ((0, 0), (0, 0), (0, pad)))
    L = chunk

    def split_chunks(t):
        return t.reshape(t.shape[0], t.shape[1], nchunks, L, *t.shape[3:]).swapaxes(0, 2).swapaxes(1, 2)

    qc, kc, vc = (split_chunks(t) for t in (q, k, v))           # (nc,B,nh,L,dh)
    lic, lfc = (split_chunks(t) for t in (log_i, log_f))        # (nc,B,nh,L)

    C0 = jnp.zeros((B, nh, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, nh, dh), jnp.float32)
    if state is not None:
        C0, n0 = state

    def chunk_step(carry, inp):
        C, n = carry
        q_, k_, v_, li_, lf_ = inp
        F = jnp.cumsum(lf_, axis=-1)                            # (B,nh,L)
        # decay matrix D_ts = exp(F_t - F_s + li_s), s <= t
        Dlog = F[..., :, None] - F[..., None, :] + li_[..., None, :]
        tri = jnp.tril(jnp.ones((L, L), bool))
        D = jnp.where(tri, jnp.exp(Dlog), 0.0)
        S_ = jnp.einsum("bhld,bhmd->bhlm", q_.astype(jnp.float32),
                        k_.astype(jnp.float32)) * D
        num = jnp.einsum("bhlm,bhmd->bhld", S_, v_.astype(jnp.float32))
        num = num + jnp.exp(F)[..., None] * jnp.einsum(
            "bhld,bhde->bhle", q_.astype(jnp.float32), C)
        den = jnp.sum(S_, axis=-1) + jnp.exp(F) * jnp.einsum(
            "bhld,bhd->bhl", q_.astype(jnp.float32), n)
        h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
        # state update to end of chunk
        decay_all = jnp.exp(F[..., -1:] - F + li_)              # (B,nh,L)
        C_new = jnp.exp(F[..., -1])[..., None, None] * C + jnp.einsum(
            "bhl,bhld,bhle->bhde", decay_all, k_.astype(jnp.float32),
            v_.astype(jnp.float32))
        n_new = jnp.exp(F[..., -1])[..., None] * n + jnp.einsum(
            "bhl,bhld->bhd", decay_all, k_.astype(jnp.float32))
        return (C_new, n_new), h.astype(cd)

    (Cf, nf), hs = jax.lax.scan(chunk_step, (C0, n0), (qc, kc, vc, lic, lfc))
    h = hs.swapaxes(1, 2).swapaxes(0, 2)                        # (B,nh,nc,L,dh)
    h = h.reshape(B, nh, nchunks * L, dh)[:, :, :S, :]
    h = h.transpose(0, 2, 1, 3).reshape(B, S, d_in)
    h = basic.rmsnorm(h, p["ogate_norm"]["scale"])
    h = h * jax.nn.silu(z)
    return basic.dense(h, p["down_proj"], cd), (Cf, nf)


def mlstm_step(x_t, p, cfg: ModelConfig, state):
    """Decode step. state = (C (B,nh,dh,dh), n (B,nh,dh), conv (B,3,d_in))."""
    C, n, conv_state = state
    d_in, nh, dh = xlstm_dims(cfg)
    cd = cfg.cdtype
    B = x_t.shape[0]
    up = basic.dense(x_t, p["up_proj"], cd)
    xm, z = jnp.split(up, 2, axis=-1)
    xc, conv_state = conv1d_step(xm, conv_state, p["conv_w"].astype(cd),
                                 p["conv_b"].astype(cd))
    xc = jax.nn.silu(xc)
    q = basic.dense(xc, p["wq"], cd).reshape(B, nh, dh)
    k = basic.dense(xc, p["wk"], cd).reshape(B, nh, dh) / jnp.sqrt(
        jnp.asarray(dh, cd))
    v = basic.dense(xm, p["wv"], cd).reshape(B, nh, dh)
    g = basic.dense(xc, p["w_if"], jnp.float32)
    log_i, f_pre = jnp.split(g, 2, axis=-1)
    i = jnp.exp(log_i)                                          # (B,nh)
    f = jax.nn.sigmoid(f_pre)
    C = f[..., None, None] * C + i[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", k.astype(jnp.float32), v.astype(jnp.float32))
    n = f[..., None] * n + i[..., None] * k.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), C)
    den = jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32), n)
    h = (num / jnp.maximum(jnp.abs(den), 1.0)[..., None]).astype(cd)
    h = h.reshape(B, d_in)
    h = basic.rmsnorm(h, p["ogate_norm"]["scale"])
    h = h * jax.nn.silu(z)
    return basic.dense(h, p["down_proj"], cd), (C, n, conv_state)


# ---------------------------------------------------------------------------
# sLSTM — scalar memory, exponential gating, per-head recurrence.


def init_slstm(seed, path, cfg: ModelConfig, dtype):
    d = cfg.d_model
    nh = cfg.num_heads
    dh = d // nh
    K = 4
    return {
        "conv_w": basic.normal_init(seed, f"{path}/conv_w", (K, d), dtype, fan_in=K),
        "conv_b": basic.zeros_init(seed, f"{path}/conv_b", (d,), dtype),
        "w_gates": basic.init_dense(seed, f"{path}/w_gates", d, 4 * d, dtype,
                                    bias=True),
        # block-diagonal recurrent weights per head: (nh, dh, 4*dh)
        "r_gates": basic.normal_init(seed, f"{path}/r_gates", (nh, dh, 4 * dh),
                                     dtype, fan_in=dh),
        "out_norm": basic.init_norm(seed, f"{path}/out_norm", d, dtype, "rmsnorm"),
        "up_gate": basic.init_dense(seed, f"{path}/up_gate", d,
                                    int(4 * d / 3) // 2 * 2, dtype),
        "up_proj": basic.init_dense(seed, f"{path}/up_proj", d,
                                    int(4 * d / 3) // 2 * 2, dtype),
        "down_proj": basic.init_dense(seed, f"{path}/down_proj",
                                      int(4 * d / 3) // 2 * 2, d, dtype),
    }


def _slstm_cell(w_t, r_gates, state, nh, dh):
    """w_t: (B, 4*d) input pre-activations; state=(c,n,h,m) each (B,nh,dh)."""
    c, n, h, m = state
    B = w_t.shape[0]
    rec = jnp.einsum("bhd,hdg->bhg", h, r_gates.astype(jnp.float32))
    pre = w_t.reshape(B, nh, 4 * dh).astype(jnp.float32) + rec
    z_pre, i_pre, f_pre, o_pre = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)
    i = jnp.exp(i_pre - m_new)
    f = jnp.exp(log_f + m - m_new)
    c = f * c + i * z
    n = f * n + i
    h_new = o * c / jnp.maximum(n, 1.0)
    return (c, n, h_new, m_new), h_new


def slstm_forward(x, p, cfg: ModelConfig, state=None):
    """x: (B,S,d) -> (B,S,d). Strict time recurrence (lax.scan)."""
    B, S, d = x.shape
    nh = cfg.num_heads
    dh = d // nh
    cd = cfg.cdtype
    xc = causal_conv1d(x.astype(cd), p["conv_w"].astype(cd),
                       p["conv_b"].astype(cd))
    xc = jax.nn.silu(xc)
    w = basic.dense(xc, p["w_gates"], cd)                       # (B,S,4d)
    if state is None:
        zeros = jnp.zeros((B, nh, dh), jnp.float32)
        state = (zeros, zeros, zeros, zeros - 30.0)

    def step(st, w_t):
        return _slstm_cell(w_t, p["r_gates"], st, nh, dh)

    state, hs = jax.lax.scan(step, state, w.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).reshape(B, S, d).astype(cd)
    h = basic.rmsnorm(h, p["out_norm"]["scale"])
    # gated FFN out (xLSTM post-up-projection block)
    u = jax.nn.silu(basic.dense(h, p["up_gate"], cd)) * basic.dense(
        h, p["up_proj"], cd)
    return basic.dense(u, p["down_proj"], cd), state


def slstm_step(x_t, p, cfg: ModelConfig, state):
    """Decode step. state = (cell_state(c,n,h,m), conv_state)."""
    cell, conv_state = state
    cd = cfg.cdtype
    d = x_t.shape[-1]
    nh = cfg.num_heads
    dh = d // nh
    xc, conv_state = conv1d_step(x_t.astype(cd), conv_state,
                                 p["conv_w"].astype(cd), p["conv_b"].astype(cd))
    xc = jax.nn.silu(xc)
    w = basic.dense(xc, p["w_gates"], cd)
    cell, h = _slstm_cell(w, p["r_gates"], cell, nh, dh)
    B = x_t.shape[0]
    h = h.reshape(B, d).astype(cd)
    h = basic.rmsnorm(h, p["out_norm"]["scale"])
    u = jax.nn.silu(basic.dense(h, p["up_gate"], cd)) * basic.dense(
        h, p["up_proj"], cd)
    return basic.dense(u, p["down_proj"], cd), (cell, conv_state)
