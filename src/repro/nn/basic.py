"""Functional NN primitives: deterministic path-keyed initialization,
norms, dense layers, embeddings, gated MLPs.

Parameters live in nested dicts ("param trees"). Every leaf is
initialized from a key derived *deterministically from the root seed and
the parameter path* — this is what lets FedPT regenerate frozen leaves
from a single scalar seed on every client (core/reconstruct.py).
"""
from __future__ import annotations

import zlib
from typing import Any, Dict, Iterable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Path-keyed deterministic PRNG


def path_key(root_seed, path: str):
    """Derive a PRNG key for a parameter path from an integer root seed.

    Stable across processes (crc32 of the path), so a client holding only
    the scalar seed can regenerate any frozen leaf.
    """
    k = jax.random.key(root_seed) if isinstance(root_seed, int) else root_seed
    return jax.random.fold_in(k, zlib.crc32(path.encode()) & 0x7FFFFFFF)


def normal_init(root_seed, path: str, shape, dtype, fan_in: int | None = None,
                stddev: float | None = None):
    """Gaussian init (the paper freezes 'parameters ... generated from
    Gaussian initializers'); default is LeCun-normal by fan-in."""
    if stddev is None:
        if fan_in is None:
            fan_in = shape[-2] if len(shape) >= 2 else max(shape[-1], 1)
        stddev = 1.0 / np.sqrt(max(fan_in, 1))
    k = path_key(root_seed, path)
    return (jax.random.normal(k, shape, jnp.float32) * stddev).astype(dtype)


def zeros_init(_root_seed, _path, shape, dtype, **_kw):
    return jnp.zeros(shape, dtype)


def ones_init(_root_seed, _path, shape, dtype, **_kw):
    return jnp.ones(shape, dtype)


# Initializer registry used by reconstruct: every leaf records how it was
# made so the frozen side can be regenerated without shipping bytes.
INITIALIZERS = {
    "normal": normal_init,
    "zeros": zeros_init,
    "ones": ones_init,
}


# ---------------------------------------------------------------------------
# Param tree utilities


def flatten_params(tree: Params, prefix: str = "") -> Iterable[Tuple[str, Any]]:
    for k in sorted(tree):
        v = tree[k]
        path = f"{prefix}/{k}" if prefix else k
        if isinstance(v, dict):
            yield from flatten_params(v, path)
        else:
            yield path, v


def unflatten_params(flat: Dict[str, Any]) -> Params:
    out: Params = {}
    for path, v in flat.items():
        parts = path.split("/")
        d = out
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return out


def tree_size(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


# ---------------------------------------------------------------------------
# Norms


def rmsnorm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32)) + bias.astype(jnp.float32)).astype(dt)


def groupnorm(x, scale, bias, num_groups: int, eps: float = 1e-5):
    """GroupNorm over channel-last input (N, H, W, C) or (N, C)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    c = x.shape[-1]
    g = num_groups
    xg = x.reshape(x.shape[:-1] + (g, c // g))
    axes = tuple(range(1, xg.ndim - 2)) + (xg.ndim - 1,)
    mu = jnp.mean(xg, axis=axes, keepdims=True)
    var = jnp.var(xg, axis=axes, keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    x = xg.reshape(x.shape)
    return (x * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def init_norm(seed, path, d, dtype, norm_type: str):
    if norm_type == "rmsnorm":
        return {"scale": zeros_init(seed, f"{path}/scale", (d,), dtype)}
    return {"scale": zeros_init(seed, f"{path}/scale", (d,), dtype),
            "bias": zeros_init(seed, f"{path}/bias", (d,), dtype)}


def apply_norm(x, p, norm_type: str):
    if norm_type == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


# ---------------------------------------------------------------------------
# Dense / embedding


def init_dense(seed, path, d_in, d_out, dtype, bias: bool = False):
    p = {"kernel": normal_init(seed, f"{path}/kernel", (d_in, d_out), dtype,
                               fan_in=d_in)}
    if bias:
        p["bias"] = zeros_init(seed, f"{path}/bias", (d_out,), dtype)
    return p


def dense(x, p, compute_dtype=None):
    k = p["kernel"]
    if compute_dtype is not None:
        x = x.astype(compute_dtype)
        k = k.astype(compute_dtype)
    y = x @ k
    if "bias" in p:
        y = y + p["bias"].astype(y.dtype)
    return y


def init_embedding(seed, path, vocab, d, dtype):
    return {"embedding": normal_init(seed, f"{path}/embedding", (vocab, d),
                                     dtype, stddev=0.02)}


def embed(ids, p, compute_dtype):
    return jnp.take(p["embedding"], ids, axis=0).astype(compute_dtype)


def unembed(x, p, compute_dtype):
    return x.astype(compute_dtype) @ p["embedding"].astype(compute_dtype).T


# ---------------------------------------------------------------------------
# Activations & MLP


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def init_mlp(seed, path, d_model, d_ff, dtype, gated: bool = True,
             bias: bool = False):
    if gated:
        return {
            "wi_gate": init_dense(seed, f"{path}/wi_gate", d_model, d_ff, dtype, bias),
            "wi_up": init_dense(seed, f"{path}/wi_up", d_model, d_ff, dtype, bias),
            "wo": init_dense(seed, f"{path}/wo", d_ff, d_model, dtype, bias),
        }
    return {
        "wi": init_dense(seed, f"{path}/wi", d_model, d_ff, dtype, bias),
        "wo": init_dense(seed, f"{path}/wo", d_ff, d_model, dtype, bias),
    }


def mlp(x, p, act: str, compute_dtype):
    f = activation(act)
    if "wi_gate" in p:
        g = dense(x, p["wi_gate"], compute_dtype)
        u = dense(x, p["wi_up"], compute_dtype)
        return dense(f(g) * u, p["wo"], compute_dtype)
    h = f(dense(x, p["wi"], compute_dtype))
    return dense(h, p["wo"], compute_dtype)


def maybe_constrain(x, spec):
    """Best-effort GSPMD sharding constraint.

    Filters the spec per-dimension: an axis that is absent from the
    ambient mesh, or that does not divide the dimension, degrades to None
    for THAT dim only (instead of dropping the whole constraint — see
    EXPERIMENTS.md §Perf H2/H3 iteration-1 lesson). No-ops entirely when
    no ambient mesh is set (single-device smoke tests).
    """
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return x
        sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
        filt = []
        for d, ax in enumerate(spec):
            if ax is None:
                filt.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            # keep the subset of axes that exist on the ambient mesh
            present = tuple(a for a in axes if a in sizes)
            total = 1
            for a in present:
                total *= sizes[a]
            if present and d < x.ndim and x.shape[d] % total == 0 \
                    and x.shape[d] >= total:
                filt.append(present if len(present) > 1 else present[0])
            else:
                filt.append(None)
        if all(f is None for f in filt):
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.PartitionSpec(*filt))
    except Exception:
        return x
