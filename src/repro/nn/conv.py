"""Convolutional primitives for the paper's own vision models
(EMNIST CNN of Table 6, ResNet-18 with GroupNorm for CIFAR-10).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn import basic


def init_conv(seed, path, k, c_in, c_out, dtype, bias: bool = True):
    p = {"kernel": basic.normal_init(seed, f"{path}/kernel",
                                     (k, k, c_in, c_out), dtype,
                                     fan_in=k * k * c_in)}
    if bias:
        p["bias"] = basic.zeros_init(seed, f"{path}/bias", (c_out,), dtype)
    return p


def conv2d(x, p, stride: int = 1, padding: str = "SAME"):
    y = jax.lax.conv_general_dilated(
        x, p["kernel"].astype(x.dtype), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if "bias" in p:
        y = y + p["bias"].astype(y.dtype)
    return y


def maxpool2d(x, window: int = 2, stride: int = 2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, window, window, 1),
        (1, stride, stride, 1), "VALID")


def avgpool_global(x):
    return jnp.mean(x, axis=(1, 2))


def init_groupnorm(seed, path, c, dtype):
    return {"scale": basic.ones_init(seed, f"{path}/scale", (c,), dtype),
            "bias": basic.zeros_init(seed, f"{path}/bias", (c,), dtype)}


def apply_groupnorm(x, p, groups: int = 32):
    g = min(groups, x.shape[-1])
    while x.shape[-1] % g:
        g -= 1
    return basic.groupnorm(x, p["scale"], p["bias"], g)
