"""Federated runtime: the server training loop driving the jitted round
engine over a federated dataset — the piece that examples/ and
benchmarks/ call.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.partition as part
from repro.core import fedpt, comm
from repro.data import synthetic as syn


@dataclasses.dataclass
class TrainResult:
    y: Any
    frozen: Any
    history: List[Dict[str, float]]
    comm: comm.CommReport
    seconds_per_round: float


def run_federated(init_fn: Callable[[int], Any], loss_fn: Callable,
                  dataset, rc: fedpt.RoundConfig, rounds: int,
                  freeze_spec=(), seed: int = 0, data_kind: str = "images",
                  eval_every: int = 0,
                  eval_fn: Optional[Callable[[Any], Dict[str, float]]] = None,
                  server_opt=None, log: bool = False) -> TrainResult:
    """Generic FedPT training driver (freeze_spec=() == fully trainable
    FedAvg — the paper's baseline)."""
    y, frozen = part.partition(init_fn(seed), freeze_spec)
    round_fn, sopt = fedpt.make_round_fn(loss_fn, rc, server_opt=server_opt)
    round_fn = jax.jit(round_fn, donate_argnums=(0, 1))
    sstate = sopt.init(y)
    rng = np.random.default_rng(seed + 77)
    history: List[Dict[str, float]] = []
    t0 = None
    for r in range(rounds):
        cids = syn.sample_cohort(rng, dataset_num_clients(dataset),
                                 rc.clients_per_round)
        batch, w = syn.cohort_batch(dataset, cids, rc.local_steps,
                                    rc.local_batch, rng, kind=data_kind)
        y, sstate, m = round_fn(y, sstate, frozen, batch, jnp.asarray(w),
                                jax.random.key(seed * 100_003 + r))
        if r == 0:
            jax.block_until_ready(y)
            t0 = time.time()  # exclude compile from the per-round timing
        rec = {"round": r, "loss": float(m["loss"])}
        if eval_fn and eval_every and (r + 1) % eval_every == 0:
            full = part.merge(y, frozen)
            rec.update(eval_fn(full))
        history.append(rec)
        if log and (r % max(1, rounds // 10) == 0):
            print(f"  round {r}: " + " ".join(
                f"{k}={v:.4f}" for k, v in rec.items() if k != "round"))
    jax.block_until_ready(y)
    spr = (time.time() - t0) / max(rounds - 1, 1) if t0 else float("nan")
    return TrainResult(y=y, frozen=frozen, history=history,
                       comm=comm.report_for(y, frozen),
                       seconds_per_round=spr)


def dataset_num_clients(ds) -> int:
    if hasattr(ds, "num_clients"):
        return ds.num_clients
    return len(ds.client_tokens)


def accuracy_eval(forward_fn, images, labels, batch: int = 256):
    """Classification accuracy evaluator factory."""

    def ev(params):
        correct = 0
        for i in range(0, len(labels), batch):
            logits = forward_fn(params, images[i:i + batch])
            correct += int(jnp.sum(jnp.argmax(logits, -1) == labels[i:i + batch]))
        return {"accuracy": correct / len(labels)}

    return ev


def nwp_accuracy_eval(forward_fn, tokens, batch: int = 128):
    """Next-word-prediction accuracy (the paper's SO NWP metric)."""

    def ev(params):
        correct = total = 0
        for i in range(0, len(tokens), batch):
            t = tokens[i:i + batch]
            logits = forward_fn(params, t)
            pred = jnp.argmax(logits[:, :-1, :], -1)
            correct += int(jnp.sum(pred == t[:, 1:]))
            total += pred.size
        return {"accuracy": correct / total}

    return ev
