"""Federated runtime: the server training loop driving the jitted round
engine over a federated dataset — the piece that examples/ and
benchmarks/ call.

``run_federated`` is the homogeneous-synchronous special case of the
simulation grid (``repro/sim/grid.py``): a uniform always-available
fleet, no straggler deadline, no over-selection. Heterogeneous fleets,
straggler handling and buffered async aggregation are reached by passing
a ``sim.GridConfig`` to ``sim.grid.run_grid`` directly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax.numpy as jnp

from repro.core import comm, fedpt
from repro.sim import grid as simgrid


@dataclasses.dataclass
class TrainResult:
    y: Any
    frozen: Any
    history: List[Dict[str, float]]
    comm: comm.CommReport
    seconds_per_round: float


def run_federated(init_fn: Callable[[int], Any], loss_fn: Callable,
                  dataset, rc: fedpt.RoundConfig, rounds: int,
                  freeze_spec=(), seed: int = 0, data_kind: str = "images",
                  eval_every: int = 0,
                  eval_fn: Optional[Callable[[Any], Dict[str, float]]] = None,
                  server_opt=None, log: bool = False) -> TrainResult:
    """Generic FedPT training driver (freeze_spec=() == fully trainable
    FedAvg — the paper's baseline). Delegates to the simulation grid in
    its homogeneous-synchronous configuration, which reproduces the
    original inline loop bit-for-bit (same RNG streams)."""
    res = simgrid.run_grid(init_fn, loss_fn, dataset, rc, rounds,
                           grid=simgrid.GridConfig(mode="sync",
                                                   fleet="uniform"),
                           freeze_spec=freeze_spec, seed=seed,
                           data_kind=data_kind, eval_every=eval_every,
                           eval_fn=eval_fn, server_opt=server_opt, log=log)
    return TrainResult(y=res.y, frozen=res.frozen, history=res.history,
                       comm=res.comm, seconds_per_round=res.seconds_per_round)


def dataset_num_clients(ds) -> int:
    return simgrid.num_clients(ds)


def accuracy_eval(forward_fn, images, labels, batch: int = 256):
    """Classification accuracy evaluator factory."""

    def ev(params):
        correct = 0
        for i in range(0, len(labels), batch):
            logits = forward_fn(params, images[i:i + batch])
            correct += int(jnp.sum(jnp.argmax(logits, -1) == labels[i:i + batch]))
        return {"accuracy": correct / len(labels)}

    return ev


def nwp_accuracy_eval(forward_fn, tokens, batch: int = 128):
    """Next-word-prediction accuracy (the paper's SO NWP metric)."""

    def ev(params):
        correct = total = 0
        for i in range(0, len(tokens), batch):
            t = tokens[i:i + batch]
            logits = forward_fn(params, t)
            pred = jnp.argmax(logits[:, :-1, :], -1)
            correct += int(jnp.sum(pred == t[:, 1:]))
            total += pred.size
        return {"accuracy": correct / total}

    return ev
