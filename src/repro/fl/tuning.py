"""Hyperparameter grid search — the paper's §C.1 protocol (grid over
client/server learning rates, best final accuracy reported), used by the
DP-FTRL experiments.
"""
from __future__ import annotations

import itertools
from typing import Callable, Dict, Iterable, List, Sequence


def grid(**axes: Sequence) -> List[Dict]:
    """grid(client_lr=[...], server_lr=[...]) -> list of dicts."""
    keys = sorted(axes)
    return [dict(zip(keys, vals))
            for vals in itertools.product(*(axes[k] for k in keys))]


PAPER_DP_GRID = grid(
    client_lr=[10 ** -1.5, 10 ** -1.0, 10 ** -0.5],
    server_lr=[10 ** -1.5, 10 ** -1.0, 10 ** -0.5, 10 ** 0.0, 10 ** 0.25],
)


def search(run_fn: Callable[[Dict], float], candidates: Iterable[Dict],
           maximize: bool = True, log: bool = False):
    """run_fn(point) -> score. Returns (best_point, best_score, history)."""
    best, best_score, hist = None, None, []
    for point in candidates:
        score = run_fn(point)
        hist.append({**point, "score": score})
        if log:
            print(f"  {point} -> {score:.4f}")
        if best_score is None or (score > best_score) == maximize:
            best, best_score = point, score
    return best, best_score, hist
