"""Client population model for the simulation grid.

Each client gets a :class:`DeviceProfile` — link bandwidths, a compute
multiplier (how much slower than the reference device its local steps
run), an availability probability (is it online when the server samples
it) and a mid-round dropout probability. Profiles are sampled from named
**fleet presets**:

``uniform``
    Every client identical, on the paper's measured cross-device links
    (download 0.75 MB/s, upload 0.25 MB/s; Wang et al. 2021b), always
    available, never dropping. The grid in this fleet + sync mode
    reproduces ``fl.runtime.run_federated`` bit-for-bit.

``pareto-mobile``
    Cross-device phones: heavy-tailed (Pareto) link speeds below the
    reference links, log-normal compute multipliers, 80% availability,
    10% mid-round dropout — the regime where straggler deadlines,
    over-selection and buffered async aggregation matter.

``pareto-mobile-diurnal``
    The same phones under device *dynamics* (``sim/dynamics.py``): every
    profile carries a stochastic :class:`~repro.sim.dynamics.LinkModel`
    (per-transfer log-normal jitter over its Pareto base bandwidth plus
    an RTT latency floor), and the grid defaults the fleet onto the
    ``diurnal`` availability trace — links jitter and the fleet follows
    online/offline cycles at virtual time.

``cross-silo``
    A handful of datacenter silos: ~1 Gb/s symmetric links, near-uniform
    compute, always available.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Union

import numpy as np

from repro.core import comm
from repro.sim import dynamics as dyn_lib

MB = 1024.0 * 1024.0


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    downlink_bps: float          # bytes/second the server->client link moves
    uplink_bps: float            # bytes/second client->server
    compute_multiplier: float    # local-step time multiplier (1.0 = reference)
    availability: float = 1.0    # P(online when sampled)
    dropout: float = 0.0         # P(drops mid-round after being dispatched)
    # per-device stochastic link (sim/dynamics.py): overrides the
    # DynamicsConfig's fleet-wide default for this client's transfers;
    # None = use the fleet default (static unless dynamics are on)
    link_model: Optional[dyn_lib.LinkModel] = None

    def round_trip_seconds(self, down_bytes: int, up_bytes: int,
                           compute_seconds: float) -> float:
        """Virtual time for one full client round trip: download the
        trainable payload, run local steps, upload the delta."""
        return (down_bytes / self.downlink_bps
                + compute_seconds * self.compute_multiplier
                + up_bytes / self.uplink_bps)


@dataclasses.dataclass
class Fleet:
    name: str
    profiles: List[DeviceProfile]

    def __len__(self) -> int:
        return len(self.profiles)

    def profile(self, cid: int) -> DeviceProfile:
        return self.profiles[int(cid)]

    def round_trip_seconds(self, cid: int, down_bytes: int, up_bytes: int,
                           compute_seconds: float) -> float:
        return self.profile(cid).round_trip_seconds(down_bytes, up_bytes,
                                                    compute_seconds)

    def summary(self) -> Dict[str, float]:
        dl = np.array([p.downlink_bps for p in self.profiles])
        ul = np.array([p.uplink_bps for p in self.profiles])
        cm = np.array([p.compute_multiplier for p in self.profiles])
        return {
            "clients": float(len(self.profiles)),
            "downlink_mbps_median": float(np.median(dl)) / MB,
            "uplink_mbps_median": float(np.median(ul)) / MB,
            "compute_mult_p90": float(np.quantile(cm, 0.9)),
            "availability_mean": float(np.mean(
                [p.availability for p in self.profiles])),
        }


# ---------------------------------------------------------------------------
# Presets


def _uniform(num_clients: int, rng: np.random.Generator) -> List[DeviceProfile]:
    p = DeviceProfile(downlink_bps=comm.DOWNLINK_MBPS * MB,
                      uplink_bps=comm.UPLINK_MBPS * MB,
                      compute_multiplier=1.0)
    return [p] * num_clients

def _pareto_mobile(num_clients: int,
                   rng: np.random.Generator) -> List[DeviceProfile]:
    # Pareto(alpha) slowdown factors >= 1 -> bandwidths at or below the
    # reference links, with a heavy tail of very slow phones.
    slow_dl = 1.0 + rng.pareto(2.5, num_clients)
    slow_ul = 1.0 + rng.pareto(2.5, num_clients)
    cmult = np.clip(rng.lognormal(0.25, 0.5, num_clients), 0.5, 10.0)
    return [DeviceProfile(downlink_bps=comm.DOWNLINK_MBPS * MB / slow_dl[i],
                          uplink_bps=comm.UPLINK_MBPS * MB / slow_ul[i],
                          compute_multiplier=float(cmult[i]),
                          availability=0.8, dropout=0.1)
            for i in range(num_clients)]

def _pareto_mobile_diurnal(num_clients: int,
                           rng: np.random.Generator) -> List[DeviceProfile]:
    # the pareto-mobile fleet, each phone with its own stochastic link:
    # jitter sigma drawn per device (flaky phones are flakier), one
    # shared 200ms latency floor. The grid pairs this preset with the
    # "diurnal" availability trace by default (dynamics.py).
    base = _pareto_mobile(num_clients, rng)
    sigmas = rng.uniform(0.1, 0.4, num_clients)
    return [dataclasses.replace(
        p, link_model=dyn_lib.LinkModel(jitter_sigma=float(sigmas[i]),
                                        rtt_seconds=0.2))
        for i, p in enumerate(base)]


def _cross_silo(num_clients: int,
                rng: np.random.Generator) -> List[DeviceProfile]:
    bw = 125.0 * MB  # ~1 Gb/s symmetric
    cmult = rng.uniform(0.8, 1.2, num_clients)
    return [DeviceProfile(downlink_bps=bw, uplink_bps=bw,
                          compute_multiplier=float(cmult[i]))
            for i in range(num_clients)]


# ---------------------------------------------------------------------------
# Capability -> trainability tier assignment (core/plan.py TrainPlan)


def capability_score(p: DeviceProfile) -> float:
    """Scalar capability of a device: geometric-mean link speed over the
    compute slowdown. Higher = more capable = lower (more-trainable)
    tier. Uplink dominates the FedPT round trip (0.25 vs 0.75 MB/s
    reference links), and slow compute delays the upload just the same,
    so both enter the score."""
    link = (p.downlink_bps * p.uplink_bps) ** 0.5
    return link / max(p.compute_multiplier, 1e-9)


def quantile_tiers(scores: np.ndarray, n_tiers: int) -> np.ndarray:
    """Quantile-split scalar capability scores (higher = more capable)
    into ``n_tiers`` equal buckets, tier 0 = most capable. Tier t's
    lower boundary sits at quantile ``1 - (t+1)/n_tiers``; the
    strictly-below comparison sends boundary ties upward, so a
    homogeneous score vector lands entirely in tier 0.

    Shared by the static profile split below and the online re-tiering
    of ``sim/selection.AdaptiveCapabilityPolicy`` (which feeds it
    ``1 / ema_observed_rtt`` instead of profile scores)."""
    scores = np.asarray(scores, np.float64)
    cuts = np.quantile(scores, [1.0 - (t + 1) / n_tiers
                                for t in range(n_tiers - 1)])
    return (scores[:, None] < cuts[None, :]).sum(1).astype(np.int32)


def assign_tiers(fleet: Fleet, n_tiers: int,
                 assignment="capability") -> np.ndarray:
    """(num_clients,) int32 tier index per client, tier 0 = most capable.

    ``assignment`` is ``"capability"`` (quantile-split the fleet's
    capability scores into ``n_tiers`` equal buckets; ties break toward
    the more capable tier, so a homogeneous fleet lands entirely in
    tier 0 — i.e. the plan's ``full`` tier), a callable
    ``profile -> tier index``, or an explicit per-client index sequence.
    """
    n = len(fleet)
    if callable(assignment):
        tiers = np.asarray([int(assignment(p)) for p in fleet.profiles],
                           np.int32)
    elif isinstance(assignment, str):
        if assignment != "capability":
            raise ValueError(f"unknown tier assignment {assignment!r}; "
                             "options: 'capability', a callable, or an "
                             "explicit per-client index array")
        tiers = quantile_tiers(
            np.asarray([capability_score(p) for p in fleet.profiles]),
            n_tiers)
    else:
        tiers = np.asarray(assignment, np.int32)
        if tiers.shape != (n,):
            raise ValueError(f"explicit tier assignment has shape "
                             f"{tiers.shape}, fleet has {n} clients")
    if tiers.size and (tiers.min() < 0 or tiers.max() >= n_tiers):
        raise ValueError(f"tier indices must be in [0, {n_tiers}); got "
                         f"range [{tiers.min()}, {tiers.max()}]")
    return tiers


FLEET_PRESETS: Dict[str, Callable[[int, np.random.Generator],
                                  List[DeviceProfile]]] = {
    "uniform": _uniform,
    "pareto-mobile": _pareto_mobile,
    "pareto-mobile-diurnal": _pareto_mobile_diurnal,
    "cross-silo": _cross_silo,
}


def make_fleet(num_clients: int, preset: Union[str, Fleet] = "uniform",
               seed: int = 0) -> Fleet:
    """Sample a client population from a named preset (a Fleet instance
    passes through unchanged)."""
    if isinstance(preset, Fleet):
        return preset
    try:
        builder = FLEET_PRESETS[preset]
    except KeyError:
        raise ValueError(f"unknown fleet preset {preset!r}; "
                         f"options: {sorted(FLEET_PRESETS)}") from None
    rng = np.random.default_rng(seed)
    return Fleet(name=preset, profiles=builder(num_clients, rng))
