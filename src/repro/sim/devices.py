"""Client population model for the simulation grid.

The fleet is stored as a :class:`FleetState` **struct-of-arrays**: one
numpy array per device attribute (link bandwidths, compute multiplier,
availability, dropout, per-device link-model parameters, tier id) rather
than one Python object per client. At 10^6 clients the arrays cost a few
MB and every fleet-wide query (cohort RTT estimates, capability scoring,
availability screens) is one vectorized op; :class:`DeviceProfile` is
kept as a **lazy per-index view** for callers that want one device.

Profiles are sampled from named **fleet presets**:

``uniform``
    Every client identical, on the paper's measured cross-device links
    (download 0.75 MB/s, upload 0.25 MB/s; Wang et al. 2021b), always
    available, never dropping. The grid in this fleet + sync mode
    reproduces ``fl.runtime.run_federated`` bit-for-bit.

``pareto-mobile``
    Cross-device phones: heavy-tailed (Pareto) link speeds below the
    reference links, log-normal compute multipliers, 80% availability,
    10% mid-round dropout — the regime where straggler deadlines,
    over-selection and buffered async aggregation matter.

``pareto-mobile-diurnal``
    The same phones under device *dynamics* (``sim/dynamics.py``): every
    profile carries a stochastic :class:`~repro.sim.dynamics.LinkModel`
    (per-transfer log-normal jitter over its Pareto base bandwidth plus
    an RTT latency floor), and the grid defaults the fleet onto the
    ``diurnal`` availability trace — links jitter and the fleet follows
    online/offline cycles at virtual time.

``cross-silo``
    A handful of datacenter silos: ~1 Gb/s symmetric links, near-uniform
    compute, always available.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core import comm
from repro.sim import dynamics as dyn_lib

MB = 1024.0 * 1024.0


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    downlink_bps: float          # bytes/second the server->client link moves
    uplink_bps: float            # bytes/second client->server
    compute_multiplier: float    # local-step time multiplier (1.0 = reference)
    availability: float = 1.0    # P(online when sampled)
    dropout: float = 0.0         # P(drops mid-round after being dispatched)
    # per-device stochastic link (sim/dynamics.py): overrides the
    # DynamicsConfig's fleet-wide default for this client's transfers;
    # None = use the fleet default (static unless dynamics are on)
    link_model: Optional[dyn_lib.LinkModel] = None

    def round_trip_seconds(self, down_bytes: int, up_bytes: int,
                           compute_seconds: float) -> float:
        """Virtual time for one full client round trip: download the
        trainable payload, run local steps, upload the delta."""
        return (down_bytes / self.downlink_bps
                + compute_seconds * self.compute_multiplier
                + up_bytes / self.uplink_bps)


@dataclasses.dataclass
class FleetState:
    """Struct-of-arrays device state, one ``(num_clients,)`` array per
    attribute. ``link_sigma``/``link_rtt`` hold the per-device
    :class:`~repro.sim.dynamics.LinkModel` parameters where ``has_link``
    is True (0.0 elsewhere); ``tier`` is filled in by
    :func:`assign_tiers` when a trainability plan is active."""

    downlink_bps: np.ndarray
    uplink_bps: np.ndarray
    compute_multiplier: np.ndarray
    availability: np.ndarray
    dropout: np.ndarray
    link_sigma: np.ndarray
    link_rtt: np.ndarray
    has_link: np.ndarray                 # bool: per-device link override?
    tier: Optional[np.ndarray] = None    # (num_clients,) int32 or None

    def __post_init__(self):
        n = len(self.downlink_bps)
        for name in ("downlink_bps", "uplink_bps", "compute_multiplier",
                     "availability", "dropout", "link_sigma", "link_rtt"):
            arr = np.ascontiguousarray(getattr(self, name), np.float64)
            if arr.shape != (n,):
                raise ValueError(f"FleetState.{name} has shape {arr.shape}, "
                                 f"expected ({n},)")
            setattr(self, name, arr)
        self.has_link = np.ascontiguousarray(self.has_link, bool)
        if self.has_link.shape != (n,):
            raise ValueError("FleetState.has_link shape mismatch")

    @classmethod
    def of(cls, num_clients: int, *, downlink_bps, uplink_bps,
           compute_multiplier=1.0, availability=1.0, dropout=0.0,
           link_sigma=0.0, link_rtt=0.0, has_link=False) -> "FleetState":
        """Build a state from scalars or arrays (scalars broadcast)."""
        n = int(num_clients)
        full = lambda v, dt=np.float64: np.full(n, v, dt) \
            if np.ndim(v) == 0 else np.asarray(v, dt)
        return cls(downlink_bps=full(downlink_bps),
                   uplink_bps=full(uplink_bps),
                   compute_multiplier=full(compute_multiplier),
                   availability=full(availability),
                   dropout=full(dropout),
                   link_sigma=full(link_sigma),
                   link_rtt=full(link_rtt),
                   has_link=full(has_link, bool))

    @classmethod
    def from_profiles(cls, profiles: Sequence[DeviceProfile]) -> "FleetState":
        links = [getattr(p, "link_model", None) for p in profiles]
        return cls(
            downlink_bps=np.array([p.downlink_bps for p in profiles],
                                  np.float64),
            uplink_bps=np.array([p.uplink_bps for p in profiles], np.float64),
            compute_multiplier=np.array(
                [p.compute_multiplier for p in profiles], np.float64),
            availability=np.array([p.availability for p in profiles],
                                  np.float64),
            dropout=np.array([p.dropout for p in profiles], np.float64),
            link_sigma=np.array([lm.jitter_sigma if lm else 0.0
                                 for lm in links], np.float64),
            link_rtt=np.array([lm.rtt_seconds if lm else 0.0
                               for lm in links], np.float64),
            has_link=np.array([lm is not None for lm in links], bool))

    def __len__(self) -> int:
        return len(self.downlink_bps)

    def profile(self, cid: int) -> DeviceProfile:
        """Lazy per-index view: materialize one DeviceProfile."""
        i = int(cid)
        lm = dyn_lib.LinkModel(jitter_sigma=float(self.link_sigma[i]),
                               rtt_seconds=float(self.link_rtt[i])) \
            if self.has_link[i] else None
        return DeviceProfile(downlink_bps=float(self.downlink_bps[i]),
                             uplink_bps=float(self.uplink_bps[i]),
                             compute_multiplier=float(
                                 self.compute_multiplier[i]),
                             availability=float(self.availability[i]),
                             dropout=float(self.dropout[i]),
                             link_model=lm)

    def round_trip_seconds(self, down_bytes, up_bytes, compute_seconds,
                           cids: Optional[np.ndarray] = None) -> np.ndarray:
        """Vectorized static round-trip times; any of the payload/compute
        args may be scalars or per-client arrays. Elementwise this is
        exactly ``DeviceProfile.round_trip_seconds`` (same float64 ops in
        the same association)."""
        if cids is None:
            dl, ul, cm = (self.downlink_bps, self.uplink_bps,
                          self.compute_multiplier)
        else:
            idx = np.asarray(cids)
            dl, ul, cm = (self.downlink_bps[idx], self.uplink_bps[idx],
                          self.compute_multiplier[idx])
        return (np.asarray(down_bytes, np.float64) / dl
                + np.asarray(compute_seconds, np.float64) * cm
                + np.asarray(up_bytes, np.float64) / ul)

    def capability_scores(self) -> np.ndarray:
        """Vectorized :func:`capability_score` over the whole fleet."""
        link = (self.downlink_bps * self.uplink_bps) ** 0.5
        return link / np.maximum(self.compute_multiplier, 1e-9)


class _ProfileView(Sequence):
    """Lazy sequence of DeviceProfile views over a FleetState — supports
    ``len``, indexing (int or slice) and iteration without ever holding
    N profile objects at once."""

    def __init__(self, state: FleetState):
        self._state = state

    def __len__(self) -> int:
        return len(self._state)

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self._state.profile(j)
                    for j in range(*i.indices(len(self._state)))]
        n = len(self._state)
        j = int(i)
        if j < 0:
            j += n
        if not 0 <= j < n:
            raise IndexError(i)
        return self._state.profile(j)


class Fleet:
    """A named client population. Construct from a ``FleetState``
    (preferred at scale) or from an explicit profile list (the pre-SoA
    API, kept for tests and hand-built fleets); ``.profiles`` is always
    a lazy per-index view over the arrays."""

    def __init__(self, name: str,
                 profiles: Optional[Sequence[DeviceProfile]] = None,
                 state: Optional[FleetState] = None):
        if (profiles is None) == (state is None):
            raise ValueError("Fleet needs exactly one of profiles= / state=")
        self.name = name
        self.state = state if state is not None \
            else FleetState.from_profiles(list(profiles))

    def __repr__(self) -> str:
        return f"Fleet(name={self.name!r}, clients={len(self)})"

    @property
    def profiles(self) -> _ProfileView:
        return _ProfileView(self.state)

    def __len__(self) -> int:
        return len(self.state)

    def profile(self, cid: int) -> DeviceProfile:
        return self.state.profile(cid)

    def round_trip_seconds(self, cid: int, down_bytes: int, up_bytes: int,
                           compute_seconds: float) -> float:
        return self.profile(cid).round_trip_seconds(down_bytes, up_bytes,
                                                    compute_seconds)

    def summary(self) -> Dict[str, float]:
        st = self.state
        return {
            "clients": float(len(st)),
            "downlink_mbps_median": float(np.median(st.downlink_bps)) / MB,
            "uplink_mbps_median": float(np.median(st.uplink_bps)) / MB,
            "compute_mult_p90": float(np.quantile(st.compute_multiplier,
                                                  0.9)),
            "availability_mean": float(np.mean(st.availability)),
        }


# ---------------------------------------------------------------------------
# Presets (each builds a FleetState directly — no per-client objects;
# the RNG call sequences are byte-identical to the old per-object
# builders, so seeded fleets are unchanged)


def _uniform(num_clients: int, rng: np.random.Generator) -> FleetState:
    return FleetState.of(num_clients,
                         downlink_bps=comm.DOWNLINK_MBPS * MB,
                         uplink_bps=comm.UPLINK_MBPS * MB,
                         compute_multiplier=1.0)


def _pareto_mobile(num_clients: int, rng: np.random.Generator) -> FleetState:
    # Pareto(alpha) slowdown factors >= 1 -> bandwidths at or below the
    # reference links, with a heavy tail of very slow phones.
    slow_dl = 1.0 + rng.pareto(2.5, num_clients)
    slow_ul = 1.0 + rng.pareto(2.5, num_clients)
    cmult = np.clip(rng.lognormal(0.25, 0.5, num_clients), 0.5, 10.0)
    return FleetState.of(num_clients,
                         downlink_bps=comm.DOWNLINK_MBPS * MB / slow_dl,
                         uplink_bps=comm.UPLINK_MBPS * MB / slow_ul,
                         compute_multiplier=cmult,
                         availability=0.8, dropout=0.1)


def _pareto_mobile_diurnal(num_clients: int,
                           rng: np.random.Generator) -> FleetState:
    # the pareto-mobile fleet, each phone with its own stochastic link:
    # jitter sigma drawn per device (flaky phones are flakier), one
    # shared 200ms latency floor. The grid pairs this preset with the
    # "diurnal" availability trace by default (dynamics.py).
    base = _pareto_mobile(num_clients, rng)
    sigmas = rng.uniform(0.1, 0.4, num_clients)
    return dataclasses.replace(base, link_sigma=sigmas,
                               link_rtt=np.full(num_clients, 0.2),
                               has_link=np.ones(num_clients, bool))


def _cross_silo(num_clients: int, rng: np.random.Generator) -> FleetState:
    bw = 125.0 * MB  # ~1 Gb/s symmetric
    cmult = rng.uniform(0.8, 1.2, num_clients)
    return FleetState.of(num_clients, downlink_bps=bw, uplink_bps=bw,
                         compute_multiplier=cmult)


# ---------------------------------------------------------------------------
# Capability -> trainability tier assignment (core/plan.py TrainPlan)


def capability_score(p: DeviceProfile) -> float:
    """Scalar capability of a device: geometric-mean link speed over the
    compute slowdown. Higher = more capable = lower (more-trainable)
    tier. Uplink dominates the FedPT round trip (0.25 vs 0.75 MB/s
    reference links), and slow compute delays the upload just the same,
    so both enter the score. The fleet-wide version is the vectorized
    :meth:`FleetState.capability_scores`."""
    link = (p.downlink_bps * p.uplink_bps) ** 0.5
    return link / max(p.compute_multiplier, 1e-9)


def quantile_tiers(scores: np.ndarray, n_tiers: int) -> np.ndarray:
    """Quantile-split scalar capability scores (higher = more capable)
    into ``n_tiers`` equal buckets, tier 0 = most capable. Tier t's
    lower boundary sits at quantile ``1 - (t+1)/n_tiers``; the
    strictly-below comparison sends boundary ties upward, so a
    homogeneous score vector lands entirely in tier 0.

    Shared by the static profile split below and the online re-tiering
    of ``sim/selection.AdaptiveCapabilityPolicy`` (which feeds it
    ``1 / ema_observed_rtt`` instead of profile scores)."""
    scores = np.asarray(scores, np.float64)
    cuts = np.quantile(scores, [1.0 - (t + 1) / n_tiers
                                for t in range(n_tiers - 1)])
    return (scores[:, None] < cuts[None, :]).sum(1).astype(np.int32)


def assign_tiers(fleet: Fleet, n_tiers: int,
                 assignment="capability") -> np.ndarray:
    """(num_clients,) int32 tier index per client, tier 0 = most capable.

    ``assignment`` is ``"capability"`` (quantile-split the fleet's
    capability scores into ``n_tiers`` equal buckets; ties break toward
    the more capable tier, so a homogeneous fleet lands entirely in
    tier 0 — i.e. the plan's ``full`` tier), a callable
    ``profile -> tier index``, or an explicit per-client index sequence.
    The result is also recorded on ``fleet.state.tier``.
    """
    n = len(fleet)
    if callable(assignment):
        tiers = np.asarray([int(assignment(p)) for p in fleet.profiles],
                           np.int32)
    elif isinstance(assignment, str):
        if assignment != "capability":
            raise ValueError(f"unknown tier assignment {assignment!r}; "
                             "options: 'capability', a callable, or an "
                             "explicit per-client index array")
        tiers = quantile_tiers(fleet.state.capability_scores(), n_tiers)
    else:
        tiers = np.asarray(assignment, np.int32)
        if tiers.shape != (n,):
            raise ValueError(f"explicit tier assignment has shape "
                             f"{tiers.shape}, fleet has {n} clients")
    if tiers.size and (tiers.min() < 0 or tiers.max() >= n_tiers):
        raise ValueError(f"tier indices must be in [0, {n_tiers}); got "
                         f"range [{tiers.min()}, {tiers.max()}]")
    fleet.state.tier = tiers
    return tiers


FLEET_PRESETS: Dict[str, Callable[[int, np.random.Generator],
                                  FleetState]] = {
    "uniform": _uniform,
    "pareto-mobile": _pareto_mobile,
    "pareto-mobile-diurnal": _pareto_mobile_diurnal,
    "cross-silo": _cross_silo,
}


def make_fleet(num_clients: int, preset: Union[str, Fleet] = "uniform",
               seed: int = 0) -> Fleet:
    """Sample a client population from a named preset (a Fleet instance
    passes through unchanged)."""
    if isinstance(preset, Fleet):
        return preset
    try:
        builder = FLEET_PRESETS[preset]
    except KeyError:
        raise ValueError(f"unknown fleet preset {preset!r}; "
                         f"options: {sorted(FLEET_PRESETS)}") from None
    rng = np.random.default_rng(seed)
    return Fleet(name=preset, state=builder(num_clients, rng))
