"""Event-driven virtual-clock scheduler for the simulation grid.

Two scheduling regimes over a heterogeneous :class:`~repro.sim.devices.Fleet`:

* **Synchronous cohorts** (:func:`plan_sync_round`): the server dispatches
  an (optionally over-selected) cohort, waits for the first
  ``clients_needed`` arrivals, and drops stragglers that miss the round
  deadline. Offline clients (availability draw) never start; dispatched
  clients may drop out mid-round (they consume downlink but never upload).

* **Buffered asynchronous** (:class:`BufferedAsyncScheduler`): FedBuff-style.
  The server keeps ``concurrency`` clients in flight; each completion
  lands in a buffer with its staleness (server version now minus version
  it trained on); once ``goal_count`` deltas are buffered the server
  applies one update and bumps its version. Staleness down-weighting is
  pluggable via ``core.fedpt.get_staleness_fn``.

All time is *virtual* seconds derived from device profiles and measured
wire bytes — the simulation runs as fast as the hardware allows while
reporting cross-device wall-clock.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.obs import metrics as metrics_lib
from repro.obs import trace as trace_lib
from repro.sim import devices as dev_lib
from repro.sim import faults as faults_lib


@dataclasses.dataclass(order=True)
class Event:
    time: float
    seq: int
    kind: str = dataclasses.field(compare=False)
    payload: Dict[str, Any] = dataclasses.field(compare=False,
                                                default_factory=dict)


class EventQueue:
    """Min-heap of events keyed by (virtual time, insertion order) — ties
    resolve in dispatch order, which is what makes the homogeneous sync
    fleet reproduce the plain cohort ordering exactly."""

    def __init__(self):
        self._heap: List[Event] = []
        # a plain int (not itertools.count) so a grid-state snapshot can
        # save and restore the insertion counter exactly
        self._next_seq = 0
        self.now = 0.0

    def push(self, time: float, kind: str, **payload) -> Event:
        ev = Event(time=float(time), seq=self._next_seq, kind=kind,
                   payload=payload)
        self._next_seq += 1
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        ev = heapq.heappop(self._heap)
        self.now = ev.time
        return ev

    def __len__(self) -> int:
        return len(self._heap)


# ---------------------------------------------------------------------------
# Synchronous cohorts


@dataclasses.dataclass
class SyncRoundPlan:
    cids: np.ndarray              # over-selected cohort, dispatch order
    dispatched: np.ndarray        # bool: passed the availability draw
    completed: np.ndarray         # bool: uploaded before the deadline
    participant: np.ndarray       # bool: among the first clients_needed arrivals
    arrival: np.ndarray           # float: upload-complete time (inf if never)
    round_seconds: float          # when the server closed the round
    offline: int                  # failed availability draw
    dropouts: int                 # dropped mid-round after dispatch
    deadline_drops: int           # upload arrives past the deadline
    excess: int                   # on time, but the quota was already filled
    # dark-window re-polls: 1 when nobody dispatched and the deadline-less
    # server advanced the clock by the redispatch backoff (the sync
    # analogue of the async engine's parked-dispatch retries)
    retries: int = 0
    # injected crash-mid-compute faults (sim/faults.py): dispatched,
    # consumed downlink + partial compute, never uploads
    crashes: int = 0
    # trace seq of the upload that closed the round (the slowest counted
    # arrival) — the grid parents its "round" span on it so analyze.py
    # can walk round -> bounding upload -> dispatch. None when the round
    # was deadline-bound (or untraced).
    bound_seq: Optional[int] = None

    def participant_cids(self) -> np.ndarray:
        """Participants in arrival order (dispatch order on ties)."""
        order = np.lexsort((np.arange(len(self.cids)), self.arrival))
        return self.cids[order[self.participant[order]]]


def plan_sync_round(fleet: dev_lib.Fleet, cids: Sequence[int],
                    down_bytes: int, up_bytes, compute_seconds,
                    clients_needed: int, rng: np.random.Generator,
                    deadline: float = math.inf, dynamics=None,
                    dyn_rng: Optional[np.random.Generator] = None,
                    now: float = 0.0,
                    tracer=trace_lib.NULL_TRACER,
                    tiers=None, faults=None,
                    shocks=None, regions=None) -> SyncRoundPlan:
    """Simulate one synchronous round over the cohort `cids` (possibly
    over-selected: len(cids) >= clients_needed) and decide who counts.

    ``up_bytes`` is a scalar, or a per-cohort-member array when clients
    upload tier-sliced payloads of different sizes (core/plan.py): a
    lite-tier phone's smaller delta clears the uplink sooner, and the
    virtual clock sees it. ``compute_seconds`` broadcasts the same way
    (per-tier compute: a lite tier's backward pass is cheaper).

    ``dynamics`` (a ``sim/dynamics.BoundDynamics``) makes the round
    stochastic: the availability trace is queried at ``now`` (the
    round's virtual start time) and multiplied into each profile's base
    availability, and transfer times come from each client's link model
    with per-transfer jitter drawn from ``dyn_rng`` — a child stream
    independent of ``rng``, whose fixed-count availability/dropout
    draws above stay byte-identical whether dynamics are on or off.

    ``tracer`` (an ``obs/trace.Tracer``) records one ``dispatch`` span
    per dispatched member (virtual start ``now``, duration = its round
    trip; dropouts get a null duration — they never finish) and one
    ``upload`` instant per completed upload; ``tiers`` optionally
    supplies the per-member tier indices for those payloads. The
    default NULL_TRACER emits nothing and costs nothing.

    ``faults`` (a ``sim/faults.BoundFaults``) injects crash-mid-compute:
    a fixed-count vector of crash draws from the *fault* stream (zero
    draws of ``rng``/``dyn_rng``, so ``faults=None`` rounds are
    bit-identical) marks cohort members that consume their downlink and
    part of their compute but never upload. Payload faults (truncation,
    corruption, duplicates) are async-only — the sync engine computes
    deltas inside one jitted cohort step and has no per-client wire
    payload to damage — and the grid rejects them before calling here.

    ``shocks`` (a ``sim/dynamics.BoundShocks``) + ``regions`` (the
    cohort members' edge-region indices, from ``sim/topology.py``)
    multiply correlated region-outage factors into the availability
    screen — one whole edge's clients go dark together.

    The round is fully vectorized: one RNG call per draw *kind* per
    cohort and array ops for arrivals/selection — no per-client Python
    objects or events (the arrival-order selection below reproduces the
    old per-member event heap exactly: events were pushed in member
    order, so (time, push-order) heap order == lexsort(arrival, index))."""
    cids = np.asarray(cids, np.int64)
    m = len(cids)
    st = fleet.state
    up_arr = np.broadcast_to(np.asarray(up_bytes, np.int64), (m,))
    comp_arr = np.broadcast_to(np.asarray(compute_seconds, np.float64), (m,))
    # fixed-count rng draws so the stream is deterministic regardless of
    # outcomes (and entirely separate from the data-sampling stream)
    avail_u = rng.random(m)
    drop_u = rng.random(m)
    # fixed-count crash draws from the independent fault stream
    crash = (faults.crash_draws(m) if faults is not None
             else np.zeros(m, bool))
    if dynamics is not None:
        # fixed-count N(0,1) draws from the dynamics stream: one per
        # potential transfer, consumed even for members that never
        # dispatch, so the stream position is outcome-independent
        z_down = dyn_rng.standard_normal(m)
        z_up = dyn_rng.standard_normal(m)

    avail = st.availability[cids]
    if dynamics is not None:
        avail = avail * dynamics.prob_batch(cids, now)
    if shocks is not None:
        avail = avail * shocks.factor(regions, now)
    dispatched = avail_u < avail
    dropped = dispatched & (drop_u < st.dropout[cids])
    crashed = dispatched & ~dropped & crash
    will_complete = dispatched & ~dropped & ~crash
    if dynamics is None:
        t = st.round_trip_seconds(down_bytes, up_arr, comp_arr, cids=cids)
    else:
        t = dynamics.round_trip_seconds_batch(st, cids, down_bytes, up_arr,
                                              comp_arr, z_down, z_up)
    arrival = np.where(will_complete, t, math.inf)

    # the first clients_needed arrivals at or before the deadline, in
    # (arrival, dispatch-order) order — the old event-heap pop loop
    participant = np.zeros(m, bool)
    order = np.lexsort((np.arange(m), arrival))
    comp_order = order[will_complete[order]]
    arr_sorted = arrival[comp_order]
    n_eligible = int(np.searchsorted(arr_sorted, deadline, side="right"))
    taken = min(int(clients_needed), n_eligible)
    participant[comp_order[:taken]] = True
    round_seconds = float(arr_sorted[taken - 1]) if taken else 0.0
    retried = 0
    if taken < clients_needed and math.isfinite(deadline):
        round_seconds = deadline           # server waited the round out
    elif taken == 0 and dynamics is not None:
        # deadline-less server under a dark availability window: nobody
        # even dispatched, so without a clock advance the trace would be
        # re-queried at the same virtual time forever. The server
        # re-polls after the redispatch backoff (the async engine's
        # retry semantics).
        round_seconds = dynamics.redispatch_backoff
        retried = 1
    completed = will_complete & (arrival <= deadline)
    bound_seq = None
    if tracer.enabled:
        # per-phase components for the v4 dispatch spans — recomputed
        # from the already-drawn z values, zero extra PRNG draws
        if dynamics is None:
            t_down = np.asarray(down_bytes, np.float64) \
                / st.downlink_bps[cids]
            t_comp = comp_arr * st.compute_multiplier[cids]
            t_up = up_arr / st.uplink_bps[cids]
        else:
            t_down, t_comp, t_up = dynamics.round_trip_components_batch(
                st, cids, down_bytes, up_arr, comp_arr, z_down, z_up)
        upload_seq = {}               # member index -> upload seq
        for i in range(m):
            if not dispatched[i]:
                continue
            dur = float(arrival[i]) if math.isfinite(arrival[i]) else None
            outcome = ("ok" if will_complete[i]
                       else "crash" if crashed[i] else "dropout")
            dseq = tracer.span(
                "dispatch", now, dur, cid=int(cids[i]),
                tier=None if tiers is None else int(tiers[i]),
                region=None if regions is None else int(regions[i]),
                down_bytes=int(down_bytes),
                up_bytes=int(up_arr[i]), outcome=outcome,
                t_down=float(t_down[i]), t_comp=float(t_comp[i]),
                t_up=float(t_up[i]))
            if crashed[i]:
                tracer.instant(
                    "fault", now, parent=dseq, fault="crash_compute",
                    cid=int(cids[i]),
                    tier=None if tiers is None else int(tiers[i]))
            if completed[i]:
                upload_seq[i] = tracer.instant(
                    "upload", now + float(arrival[i]), parent=dseq,
                    cid=int(cids[i]),
                    tier=None if tiers is None else int(tiers[i]),
                    region=None if regions is None else int(regions[i]),
                    up_bytes=int(up_arr[i]), rtt=float(arrival[i]),
                    participant=bool(participant[i]))
        if retried:
            tracer.instant("retry", now,
                           backoff=float(dynamics.redispatch_backoff))
        if taken and round_seconds == float(arr_sorted[taken - 1]):
            # the round closed on its slowest counted arrival (a full
            # cohort, or every eligible client under an infinite
            # deadline): that upload bounds the round's virtual wall
            # time. Deadline-stretched rounds keep bound_seq=None — the
            # server, not any client, held the clock.
            bound_seq = upload_seq.get(int(comp_order[taken - 1]))
    return SyncRoundPlan(
        cids=cids, dispatched=dispatched, completed=completed,
        participant=participant, arrival=arrival,
        round_seconds=float(round_seconds),
        offline=int(np.sum(~dispatched)),
        dropouts=int(np.sum(dispatched & ~will_complete & ~crashed)),
        deadline_drops=int(np.sum(will_complete & (arrival > deadline))),
        excess=int(np.sum(completed & ~participant)), retries=retried,
        crashes=int(np.sum(crashed)), bound_seq=bound_seq)


# ---------------------------------------------------------------------------
# Buffered asynchronous aggregation (FedBuff)


@dataclasses.dataclass
class BufferEntry:
    work: Dict[str, Any]          # run_client's result (opaque here; the
                                  # delta/loss may be lazy lane handles)
    weight: float                 # staleness_fn(s) * p_i
    staleness: int
    # trace seq of the upload instant that buffered this entry (None
    # when untraced or restored from a snapshot — grid-state whitelists
    # drop it, and the resumed run starts a fresh tracer anyway)
    seq: Optional[int] = None


class BufferedAsyncScheduler:
    """Drives the async grid. The caller provides three closures so the
    scheduler stays free of JAX and dataset specifics:

    ``sample_cid(rng) -> int``
        propose a client to dispatch (the scheduler redraws on failed
        availability checks);
    ``run_client(cid, version) -> dict``
        start local training against the *current* server model (correct
        because events are processed in virtual-time order, so the model
        at dispatch time is the model the client downloads); must return
        ``{"weight", "up_bytes", ...}`` — any further entries (delta,
        loss, lane handles) are opaque to the scheduler and simply
        carried to ``apply_update``, so the grid can defer the actual
        device work into batched client lanes and keep losses on-device
        (no per-client host sync here);
    ``apply_update(entries, now, version) -> dict``
        flush the buffer into one server update and return metrics
        (e.g. ``loss``/``delta_norm``), which are merged into the
        per-update history record.

    ``down_bytes`` and ``compute_seconds`` are constants of the round
    configuration (payload sizes are shape-determined).

    ``tier_of(cid) -> int`` (optional) names each client's trainability
    tier (core/plan.py): the tier is recorded on every dispatch — the
    payload of the queued event carries it, and the per-tier counters
    (``tier_dispatches``/``tier_uploads``/``tier_up_bytes``) let the
    grid bill wire traffic tier by tier, mid-round dropouts included
    (they consumed a tier-invariant downlink but never upload).

    ``compute_of(cid) -> seconds`` (optional) overrides the constant
    ``compute_seconds`` per dispatch — per-tier compute: a lite tier's
    backward pass is cheaper, scaled by its trainable fraction.

    ``dynamics`` (a ``sim/dynamics.BoundDynamics``) + ``dyn_rng`` make
    links stochastic and availability trace-driven, queried at each
    dispatch's virtual time. When the trace has the whole fleet dark the
    dispatch parks as a ``retry`` event ``redispatch_backoff`` virtual
    seconds later instead of raising — the run keeps draining events, so
    a zero-availability *window* stalls the clock, not the process, and
    a run with a ``deadline`` always terminates.

    ``observe(cid, rtt_seconds)`` (optional) is called for every upload
    the server receives with that transfer's realized round-trip time —
    the feedback loop ``sim/selection.py`` policies adapt on.

    ``tracer`` (an ``obs/trace.Tracer``) records every dispatch as a
    virtual-time span (start = dispatch time, duration = realized round
    trip; mid-round dropouts end at their failure time), every arriving
    upload and parked-dispatch retry as instants, and every buffer
    flush as an instant carrying its fill/staleness stats. The default
    NULL_TRACER emits nothing. ``metrics`` (an
    ``obs/metrics.MetricsRegistry``) backs ALL of the scheduler's
    counters — the legacy attributes (``dispatches``, ``tier_uploads``,
    ...) are read-only views over it.

    ``faults`` (a ``sim/faults.BoundFaults``) injects the failure model:
    exactly two fault-stream draws per dispatch (zero draws of ``rng``/
    ``dyn_rng``, so ``faults=None`` runs are bit-identical and a
    corruption-only config keeps the exact dispatch timeline) decide a
    crash-mid-compute, an upload truncation (partial bytes billed, delta
    dropped), a payload corruption (NaN/bitflip — carried on the work
    dict for the apply stage to materialize), a duplicate delivery (the
    entry buffers and bills twice), or nothing. When the virtual clock
    crosses ``faults.kill_at`` the run raises
    :class:`~repro.sim.faults.ServerKilled`.

    ``checkpoint_hook(scheduler, now)`` (optional) is called after every
    full-buffer flush — the one boundary where no lane work is pending
    and every in-flight completion holds concrete arrays, i.e. where
    ``checkpoint/grid_state.py`` can snapshot the whole execution state.

    Run state (event heap, carry-over buffer, history records) lives on
    the instance (``self.q``/``self.buffer``/``self.records``) so a
    snapshot can serialize it and a restore can pre-seed it before
    calling :meth:`run`.
    """

    def __init__(self, fleet: dev_lib.Fleet, concurrency: int,
                 goal_count: int, staleness_fn: Callable[[float], float],
                 sample_cid: Callable, run_client: Callable,
                 apply_update: Callable, down_bytes: int,
                 compute_seconds: float, rng: np.random.Generator,
                 tier_of: Optional[Callable[[int], int]] = None,
                 compute_of: Optional[Callable[[int], float]] = None,
                 region_of: Optional[Callable[[int], int]] = None,
                 shocks=None,
                 dynamics=None,
                 dyn_rng: Optional[np.random.Generator] = None,
                 observe: Optional[Callable[[int, float], None]] = None,
                 tracer=trace_lib.NULL_TRACER,
                 metrics: Optional[metrics_lib.MetricsRegistry] = None,
                 faults=None,
                 checkpoint_hook: Optional[Callable] = None):
        if goal_count < 1:
            raise ValueError("goal_count must be >= 1")
        self.fleet = fleet
        self.concurrency = max(1, int(concurrency))
        self.goal_count = int(goal_count)
        self.staleness_fn = staleness_fn
        self.sample_cid = sample_cid
        self.run_client = run_client
        self.apply_update = apply_update
        self.down_bytes = int(down_bytes)
        self.compute_seconds = float(compute_seconds)
        self.rng = rng
        self.tier_of = tier_of
        self.compute_of = compute_of
        # two-level topology (sim/topology.py): region_of names each
        # client's edge region — dispatch/upload events route through it
        # (payloads + per-region counters), and correlated region shocks
        # (sim/dynamics.BoundShocks) gate availability region-wide
        self.region_of = region_of
        self.shocks = shocks
        self.dynamics = dynamics
        self.dyn_rng = dyn_rng
        self.observe = observe
        self.tracer = tracer
        # ALL counters live in the metrics registry (read by the grid
        # for the comm ledger and GridResult.scheduler_stats)
        self.metrics = metrics if metrics is not None \
            else metrics_lib.MetricsRegistry()
        self.faults = faults
        self.kill_at = faults.kill_at if faults is not None else math.inf
        self.checkpoint_hook = checkpoint_hook
        self._consecutive_retries = 0
        # virtual time when the current dark window started (None = the
        # fleet is not dark): backs the retry budget below
        self._dark_since: Optional[float] = None
        # trace seq of the most recent flush instant — the grid's
        # apply_update closure parents its dp_flush/quarantine/
        # edge_flush/checkpoint instants on it (set by _flush *before*
        # apply_update runs; None when untraced)
        self.last_flush_seq: Optional[int] = None
        self.version = 0
        # run state, on the instance so grid-state snapshots can
        # serialize it and restores can pre-seed it (run() initializes
        # fresh when untouched)
        self.q: Optional[EventQueue] = None
        self.buffer: List[BufferEntry] = []
        self.records: List[Dict[str, float]] = []

    # legacy counter attributes, now read-only views over the registry
    @property
    def dispatches(self) -> int:
        return int(self.metrics.counter("dispatches").value)

    @property
    def dropouts(self) -> int:
        return int(self.metrics.counter("dropouts").value)

    @property
    def completions(self) -> int:
        return int(self.metrics.counter("uploads").value)

    @property
    def retries(self) -> int:
        return int(self.metrics.counter("retries").value)

    @property
    def up_bytes_total(self) -> int:
        return int(self.metrics.counter("up_bytes").value)

    @property
    def tier_dispatches(self) -> Dict[int, int]:
        return self.metrics.counter("tier_dispatches").labels

    @property
    def tier_uploads(self) -> Dict[int, int]:
        return self.metrics.counter("tier_uploads").labels

    @property
    def tier_up_bytes(self) -> Dict[int, int]:
        return self.metrics.counter("tier_up_bytes").labels

    @property
    def tier_rtt_sum(self) -> Dict[int, float]:
        return self.metrics.counter("tier_rtt_sum").labels

    def _dispatch(self, q: EventQueue, now: float,
                  parent: Optional[int] = None) -> None:
        # ``parent`` is the trace seq of whatever freed this dispatch
        # slot (a failed/completed round trip, or the previous parked
        # retry) — threaded onto the span/instant this dispatch emits so
        # the causal chain survives redispatches. None when untraced.
        # redraw until the availability check passes (bounded, so a fleet
        # of mostly-offline phones can't spin forever)
        for _ in range(1000):
            cid = int(self.sample_cid(self.rng))
            p = self.fleet.profile(cid)
            region = (int(self.region_of(cid))
                      if self.region_of is not None else None)
            avail = p.availability
            if self.dynamics is not None:
                avail = avail * self.dynamics.prob(cid, now)
            if self.shocks is not None:
                # correlated region outage: the whole edge's clients are
                # gated together (zero extra draws at query time)
                avail = avail * self.shocks.factor_one(region, now)
            if self.rng.random() < avail:
                break
        else:
            if self.dynamics is not None:
                # the trace has (essentially) everyone offline right now:
                # park this dispatch slot and retry when the clock moves.
                # Backoff escalates exponentially (capped, with
                # deterministic jitter so parked slots don't thundering-
                # herd on the same instant) and a *virtual-time* retry
                # budget bounds how long a dark window may stall the run.
                if self._dark_since is None:
                    self._dark_since = now
                dark = now - self._dark_since
                if dark > self.dynamics.retry_budget:
                    raise RuntimeError(
                        f"availability trace kept the whole fleet offline "
                        f"for {dark:.0f} consecutive virtual seconds, "
                        f"past the retry budget of "
                        f"{self.dynamics.retry_budget:.0f}s — set "
                        "GridConfig.async_deadline, fix the trace, or "
                        "raise DynamicsConfig.retry_budget")
                backoff = self.dynamics.backoff_seconds(
                    self._consecutive_retries)
                self._consecutive_retries += 1
                self.metrics.counter("retries").inc()
                rseq = self.tracer.instant("retry", now, parent=parent,
                                           backoff=float(backoff))
                q.push(now + backoff, "retry", seq=rseq)
                return
            raise RuntimeError("no available client after 1000 draws")
        self._consecutive_retries = 0
        self._dark_since = None
        fault = self.faults.draw() if self.faults is not None else None
        self.metrics.counter("dispatches").inc()
        comp = (self.compute_of(cid) if self.compute_of is not None
                else self.compute_seconds)
        if self.dynamics is not None:
            # two N(0,1) draws per dispatch (down + up), consumed even on
            # the dropout path so the stream is outcome-independent
            z_down, z_up = self.dyn_rng.standard_normal(2)
            lm = self.dynamics.link_for(cid)
        tier = int(self.tier_of(cid)) if self.tier_of is not None else None
        if tier is not None:
            self.metrics.counter("tier_dispatches").inc(label=tier)
        if region is not None:
            self.metrics.counter("region_dispatches").inc(label=region)
        if self.rng.random() < p.dropout:
            # dies after download + local work, before upload
            if self.dynamics is None:
                dl = self.down_bytes / p.downlink_bps
            else:
                dl = lm.transfer_seconds(self.down_bytes, p.downlink_bps,
                                         z_down)
            comp_t = comp * p.compute_multiplier
            t = now + (dl + comp_t)
            dseq = self.tracer.span(
                "dispatch", now, t - now, parent=parent, cid=cid,
                tier=tier, region=region, down_bytes=self.down_bytes,
                version=self.version, outcome="dropout",
                t_down=float(dl), t_comp=float(comp_t))
            q.push(t, "failed", cid=cid, tier=tier, region=region,
                   seq=dseq)
            return
        if fault is not None and fault["kind"] == "crash":
            # injected crash-mid-compute: downlink + crash_frac of the
            # local work, then silence — the server redispatches on the
            # failure event, like a dropout but counted separately
            if self.dynamics is None:
                dl = self.down_bytes / p.downlink_bps
            else:
                dl = lm.transfer_seconds(self.down_bytes, p.downlink_bps,
                                         z_down)
            comp_t = (self.faults.cfg.crash_frac * comp
                      * p.compute_multiplier)
            t = now + dl + comp_t
            dseq = self.tracer.span(
                "dispatch", now, t - now, parent=parent, cid=cid,
                tier=tier, region=region, down_bytes=self.down_bytes,
                version=self.version, outcome="crash",
                t_down=float(dl), t_comp=float(comp_t))
            self.tracer.instant("fault", t, parent=dseq,
                                fault="crash_compute", cid=cid, tier=tier)
            q.push(t, "failed", cid=cid, tier=tier, region=region,
                   cause="crash", seq=dseq)
            return
        work = self.run_client(cid, self.version)
        if fault is not None:
            # a payload fault (truncate/nan/bitflip/duplicate) rides on
            # the work dict to the arrival/apply stages
            work["fault"] = fault
        up_bytes = int(work["up_bytes"])
        if self.dynamics is None:
            rtt = p.round_trip_seconds(self.down_bytes, up_bytes, comp)
        else:
            rtt = self.dynamics.round_trip_seconds(
                p, self.down_bytes, up_bytes, comp, cid, z_down, z_up)
        if self.tracer.enabled:
            # the span's phase components, recomputed from the same
            # already-drawn z values — zero extra PRNG draws
            if self.dynamics is None:
                dl = self.down_bytes / p.downlink_bps
                ul = up_bytes / p.uplink_bps
            else:
                dl = lm.transfer_seconds(self.down_bytes, p.downlink_bps,
                                         z_down)
                ul = lm.transfer_seconds(up_bytes, p.uplink_bps, z_up)
            dseq = self.tracer.span(
                "dispatch", now, rtt, parent=parent, cid=cid, tier=tier,
                region=region, down_bytes=self.down_bytes,
                up_bytes=up_bytes, version=self.version, outcome="ok",
                t_down=float(dl),
                t_comp=float(comp * p.compute_multiplier),
                t_up=float(ul))
        else:
            dseq = None
        q.push(now + rtt, "complete", cid=cid, version=self.version,
               work=work, tier=tier, rtt=rtt, region=region, seq=dseq)

    def _flush(self, buffer, now: float, records) -> None:
        stale = np.array([e.staleness for e in buffer], np.float64)
        # the flush instant is emitted *before* apply_update so the
        # accountant/ledger instants the apply emits (dp_flush,
        # quarantine, edge_flush) can parent on it via last_flush_seq.
        # Its parent is the buffered upload with the largest seq — seqs
        # are emission-(= virtual-time-)monotone, so that is the last
        # arrival, the one that actually triggered this flush.
        parent = None
        if self.tracer.enabled:
            seqs = [e.seq for e in buffer if e.seq is not None]
            parent = max(seqs) if seqs else None
        self.last_flush_seq = self.tracer.instant(
            "flush", now, parent=parent, version=self.version,
            buffer_fill=float(len(buffer)),
            staleness_mean=float(stale.mean()),
            staleness_max=float(stale.max()))
        metrics = self.apply_update(buffer, now, self.version)
        # buffer_fill < goal_count only for the deadline-drained final
        # flush (the consumer pads it back to the fixed apply shape);
        # recorded so DP audits and tests can see the padding happened
        rec = {"round": len(records),
               "virtual_seconds": now,
               "buffer_fill": float(len(buffer)),
               "staleness_mean": float(stale.mean()),
               "staleness_max": float(stale.max())}
        rec.update(metrics or {})
        records.append(rec)
        self.version += 1

    def finish_event(self, now: float) -> None:
        """Replay the tail of the complete-branch a snapshot interrupted.

        The checkpoint hook fires *inside* the flush loop — before any
        further full-buffer flushes of the same event and before the
        freed slot's redispatch (both of which the original run then
        performed). A restore must replay exactly that tail, from the
        restored RNG positions, or the resumed timeline shifts by one
        dispatch. Checkpoint hooks are NOT re-fired here: the replayed
        flushes would just rewrite the snapshots the original run
        already wrote."""
        while len(self.buffer) >= self.goal_count:
            batch = self.buffer[:self.goal_count]
            del self.buffer[:self.goal_count]
            self._flush(batch, now, self.records)
        self._dispatch(self.q, now)

    def run(self, num_updates: int,
            deadline: float = math.inf) -> List[Dict[str, float]]:
        """Run until `num_updates` server updates have been applied.
        Returns one record per update (virtual time, staleness stats,
        plus whatever apply_update reports).

        ``deadline`` is a *virtual-seconds* budget: at the first event
        past it the run stops, flushing the partially-filled buffer as
        one final short update (the consumer pads it to ``goal_count``
        with zero weights, so the apply shape never changes).

        A restored grid-state snapshot pre-seeds ``self.q`` / ``self.
        buffer`` / ``self.records`` / ``self.version`` before calling
        this; a fresh run initializes them and primes ``concurrency``
        dispatches at t=0."""
        if self.q is None:
            self.q = EventQueue()
            for _ in range(self.concurrency):
                self._dispatch(self.q, 0.0)
        q, records = self.q, self.records
        while len(records) < num_updates:
            if not len(q):
                raise RuntimeError("async scheduler starved: no in-flight "
                                   "clients and buffer below goal_count")
            ev = q.pop()
            if ev.time > self.kill_at:
                # injected server kill: die exactly at the virtual time
                # the fault plan asked for (resume via grid_state)
                raise faults_lib.ServerKilled(at=ev.time,
                                              applied=self.version)
            if ev.time > deadline:
                # out of virtual time: drain the partial buffer as the
                # final (padded) server update
                if self.buffer:
                    self._flush(self.buffer, deadline, records)
                    self.buffer = []
                break
            if ev.kind == "retry":
                # a dispatch slot parked by a dark availability window:
                # try again now that the clock moved (chained to the
                # parked retry instant, so escalating backoffs link up)
                self._dispatch(q, ev.time, parent=ev.payload.get("seq"))
                continue
            if ev.kind == "failed":
                if ev.payload.get("cause") == "crash":
                    self.metrics.counter("crashes").inc()
                else:
                    self.metrics.counter("dropouts").inc()
                self._dispatch(q, ev.time, parent=ev.payload.get("seq"))
                continue
            work = ev.payload["work"]
            fault = work.get("fault")
            cid = int(ev.payload["cid"])
            tier = ev.payload.get("tier")
            region = ev.payload.get("region")
            dseq = ev.payload.get("seq")
            if fault is not None and fault["kind"] == "truncate":
                # the upload died partway: the wire carried (and bills)
                # a fraction of the bytes; the server detects the length
                # mismatch and drops the delta before buffering
                arrived = int(work["up_bytes"] * fault["frac"])
                self.metrics.counter("truncated").inc()
                self.metrics.counter("up_bytes").inc(arrived)
                if tier is not None:
                    self.metrics.counter("tier_up_bytes").inc(arrived,
                                                              label=tier)
                if region is not None:
                    self.metrics.counter("region_up_bytes").inc(
                        arrived, label=region)
                self.tracer.instant("fault", ev.time, parent=dseq,
                                    fault="truncate_upload", cid=cid,
                                    tier=tier, frac=float(fault["frac"]),
                                    up_bytes=arrived)
                self._dispatch(q, ev.time, parent=dseq)
                continue
            s = self.version - ev.payload["version"]
            self.metrics.counter("uploads").inc()
            self.metrics.counter("up_bytes").inc(int(work["up_bytes"]))
            if self.observe is not None:
                self.observe(cid, ev.payload["rtt"])
            useq = self.tracer.instant("upload", ev.time, parent=dseq,
                                       cid=cid, tier=tier,
                                       region=region,
                                       up_bytes=int(work["up_bytes"]),
                                       staleness=int(s),
                                       rtt=float(ev.payload["rtt"]))
            if region is not None:
                self.metrics.counter("region_uploads").inc(label=region)
                self.metrics.counter("region_up_bytes").inc(
                    int(work["up_bytes"]), label=region)
            if tier is not None:
                self.metrics.counter("tier_uploads").inc(label=tier)
                self.metrics.counter("tier_up_bytes").inc(
                    int(work["up_bytes"]), label=tier)
                self.metrics.counter("tier_rtt_sum").inc(
                    float(ev.payload["rtt"]), label=tier)
                self.metrics.counter("tier_rtt_n").inc(label=tier)
            entry = BufferEntry(
                work=work,
                weight=float(self.staleness_fn(s)) * float(work["weight"]),
                staleness=int(s), seq=useq)
            self.buffer.append(entry)
            if fault is not None and fault["kind"] in ("nan", "bitflip"):
                # the corrupted payload buffers normally — the apply
                # stage materializes the damage; the sanitize screen
                # (core/sanitize.py) is what should catch it
                self.metrics.counter("corrupted").inc()
                self.tracer.instant("fault", ev.time, parent=useq,
                                    fault="corrupt_" + fault["kind"],
                                    cid=cid, tier=tier)
            elif fault is not None and fault["kind"] == "duplicate":
                # retransmit after a lost ack: the same delta buffers
                # (and bills) twice
                self.metrics.counter("duplicates").inc()
                self.metrics.counter("uploads").inc()
                self.metrics.counter("up_bytes").inc(int(work["up_bytes"]))
                if tier is not None:
                    self.metrics.counter("tier_uploads").inc(label=tier)
                    self.metrics.counter("tier_up_bytes").inc(
                        int(work["up_bytes"]), label=tier)
                if region is not None:
                    self.metrics.counter("region_uploads").inc(label=region)
                    self.metrics.counter("region_up_bytes").inc(
                        int(work["up_bytes"]), label=region)
                self.tracer.instant("fault", ev.time, parent=useq,
                                    fault="duplicate_upload", cid=cid,
                                    tier=tier)
                self.buffer.append(BufferEntry(work=work,
                                               weight=entry.weight,
                                               staleness=entry.staleness,
                                               seq=useq))
            # duplicates can leave the buffer past goal_count: flush in
            # exact goal_count batches and carry the remainder (when
            # faults are off the buffer never exceeds goal_count, so
            # this is the old flush-everything behavior, bit for bit)
            while len(self.buffer) >= self.goal_count:
                batch = self.buffer[:self.goal_count]
                del self.buffer[:self.goal_count]
                self._flush(batch, ev.time, records)
                if self.checkpoint_hook is not None:
                    # flush boundaries are the one point where no lane
                    # work is pending: snapshot-safe
                    self.checkpoint_hook(self, ev.time)
            self._dispatch(q, ev.time, parent=useq)
        return records
