"""Federated simulation grid: heterogeneity-aware client populations,
device dynamics (stochastic links, trace-driven availability), an
event-driven virtual-clock scheduler (synchronous cohorts with
straggler deadlines / over-selection, and FedBuff-style buffered async
aggregation), pluggable tier-aware cohort-selection policies, and
wire-level communication metering.

``fl.runtime.run_federated`` is the homogeneous-synchronous special case
of ``sim.grid.run_grid``.
"""
from repro.sim.devices import (DeviceProfile, Fleet, FleetState, make_fleet,
                               FLEET_PRESETS, assign_tiers,
                               capability_score, quantile_tiers)
from repro.sim.dynamics import (LinkModel, AvailabilityTrace, AlwaysOn,
                                DiurnalTrace, StepTrace, DynamicsConfig,
                                RegionShocks, DYNAMICS_PRESETS,
                                resolve_dynamics)
from repro.sim.topology import (TopologyConfig, Topology, resolve_topology,
                                edge_reduce)
from repro.obs.trace import TelemetryConfig
from repro.sim.grid import GridConfig, GridResult, run_grid
from repro.sim.scheduler import (EventQueue, SyncRoundPlan, plan_sync_round,
                                 BufferedAsyncScheduler)
from repro.sim.selection import (SelectionPolicy, UniformPolicy,
                                 BandwidthAwarePolicy, TierRotationPolicy,
                                 AdaptiveCapabilityPolicy, POLICIES,
                                 resolve_policy)
from repro.sim import wire
