"""Two-level aggregation topology: clients -> edge aggregators -> server.

At fleet scale (the survey's — Le et al., PAPERS.md — headline answer to
communication practicality) clients do not talk to the server directly:
they are partitioned into **regions**, each owning an edge aggregator
that pre-reduces its cohort's flat deltas into one ``(size,)`` buffer
(the same flat layout ``core/flat.py`` gives every client row) and
forwards that single buffer upstream. The wire then has two hops —
client→edge and edge→server — billed separately in
``CommReport.hop_traffic`` (``core/comm.py``).

The flat grid is the one-region special case: ``resolve_topology(None)``
keeps every pre-topology code path untouched, and a *one-region*
topology runs the full hierarchical machinery (edge buffers, hop
ledger, ``edge_flush`` events) while staying bit-identical to the flat
grid on every model/metric path — the authoritative server reduce is
unchanged; the edge pre-reduce is the billing/verification view of the
same rows (test-enforced).

Region partition schemes:

``contiguous``
    clients ``[k*N/R, (k+1)*N/R)`` belong to region ``k`` — the
    geographic-block idiom, and what the presets mean by "region";
``strided``
    client ``c`` belongs to region ``c % R`` — maximally interleaved,
    useful to decorrelate region shocks from data skew in experiments;
explicit array
    any ``(num_clients,)`` int map.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Union

import numpy as np


@dataclasses.dataclass(frozen=True)
class TopologyConfig:
    """``GridConfig.topology``: region count + partition scheme."""

    regions: int = 1
    assignment: Union[str, np.ndarray] = "contiguous"

    def __post_init__(self):
        if self.regions < 1:
            raise ValueError("topology needs >= 1 region")


class Topology:
    """A bound topology: ``region_of`` is the ``(num_clients,)`` int32
    client→region map; per-region member lists are precomputed once."""

    def __init__(self, num_clients: int, region_of: np.ndarray):
        region_of = np.ascontiguousarray(region_of, np.int32)
        if region_of.shape != (num_clients,):
            raise ValueError(f"region map has shape {region_of.shape}, "
                             f"fleet has {num_clients} clients")
        if num_clients and region_of.min() < 0:
            raise ValueError("region indices must be >= 0")
        self.num_clients = int(num_clients)
        self.region_of = region_of
        self.num_regions = int(region_of.max()) + 1 if num_clients else 1
        self._members: Optional[Dict[int, np.ndarray]] = None

    @classmethod
    def build(cls, num_clients: int,
              spec: Union[TopologyConfig, int, np.ndarray]) -> "Topology":
        if isinstance(spec, int):
            spec = TopologyConfig(regions=spec)
        if isinstance(spec, TopologyConfig):
            r = spec.regions
            if r > max(num_clients, 1):
                raise ValueError(f"{r} regions over {num_clients} clients: "
                                 "every region needs at least one client")
            if isinstance(spec.assignment, str):
                if spec.assignment == "contiguous":
                    # equal-size contiguous blocks (first N % R regions
                    # get the extra client)
                    region_of = (np.arange(num_clients, dtype=np.int64)
                                 * r // max(num_clients, 1)).astype(np.int32)
                elif spec.assignment == "strided":
                    region_of = (np.arange(num_clients) % r).astype(np.int32)
                else:
                    raise ValueError(
                        f"unknown region assignment {spec.assignment!r}; "
                        "options: 'contiguous', 'strided', or an explicit "
                        "per-client index array")
            else:
                region_of = np.asarray(spec.assignment, np.int32)
                if region_of.size and region_of.max() >= r:
                    raise ValueError(f"explicit region map uses region "
                                     f"{region_of.max()}, config has {r}")
            topo = cls(num_clients, region_of)
            topo.num_regions = int(r)
            return topo
        return cls(num_clients, np.asarray(spec, np.int32))

    def members(self, region: int) -> np.ndarray:
        """Client ids in one region (cached)."""
        if self._members is None:
            order = np.argsort(self.region_of, kind="stable")
            bounds = np.searchsorted(self.region_of[order],
                                     np.arange(self.num_regions + 1))
            self._members = {
                k: order[bounds[k]:bounds[k + 1]]
                for k in range(self.num_regions)}
        return self._members[int(region)]

    def region_name(self, region: int) -> str:
        return f"edge{int(region)}"

    def summary(self) -> Dict[str, float]:
        sizes = np.bincount(self.region_of, minlength=self.num_regions)
        return {"regions": float(self.num_regions),
                "clients": float(self.num_clients),
                "region_size_min": float(sizes.min()),
                "region_size_max": float(sizes.max())}


def resolve_topology(spec, num_clients: int) -> Optional[Topology]:
    """``GridConfig.topology`` -> bound Topology or None (flat grid).

    ``None`` keeps the flat single-hop grid (no hierarchical machinery
    at all); an int is a region count with the ``contiguous`` partition;
    a :class:`TopologyConfig` or explicit per-client array binds as
    given. Note a one-*region* topology is NOT folded to None: it runs
    the full edge machinery (bit-identical to flat, test-enforced), so
    the hierarchy can be A/B'd against the flat grid."""
    if spec is None:
        return None
    return Topology.build(num_clients, spec)


def edge_reduce(rows: np.ndarray, weights: np.ndarray,
                regions: np.ndarray,
                num_regions: int) -> np.ndarray:
    """Pre-reduce client delta rows into per-region edge buffers.

    ``rows`` is the flush's ``(K, size)`` flat delta stack (one
    ``core/flat.py`` layout row per upload), ``weights`` its ``(K,)``
    aggregation weights and ``regions`` the uploader's region per row.
    Returns the ``(num_regions, size)`` edge buffers — region ``k``'s
    aggregator forwards row ``k`` (its members' weighted sum) upstream,
    so ``out.sum(0)`` re-associates the server's weighted reduce. The
    authoritative update keeps the fused single-reduce path; these
    buffers are what the edge *transmits* (billed per hop) and what the
    parity tests check against the flat reduce."""
    rows = np.asarray(rows)
    weights = np.asarray(weights, rows.dtype)
    regions = np.asarray(regions, np.int64)
    if rows.ndim != 2 or len(weights) != len(rows) \
            or len(regions) != len(rows):
        raise ValueError(f"edge_reduce shape mismatch: rows {rows.shape}, "
                         f"weights {weights.shape}, regions {regions.shape}")
    out = np.zeros((int(num_regions), rows.shape[1]), rows.dtype)
    np.add.at(out, regions, rows * weights[:, None])
    return out
