"""Device dynamics for the simulation grid: stochastic links and
trace-driven availability.

PR 1's fleet was a *static* snapshot: every transfer moved at exactly the
profile's base bandwidth and availability was one Bernoulli probability,
frozen for the whole run. Real phone fleets are nothing like that — links
jitter transfer to transfer, every transfer pays a latency floor, and
devices follow diurnal online/offline cycles (charging overnight, dark
during the commute). This module models both, queried at *virtual time*
so async flushes see the clock move:

* :class:`LinkModel` — per-transfer multiplicative **log-normal jitter**
  on top of the profile's base bandwidth, plus a fixed **RTT latency
  floor** per transfer. The jitter is mean-preserving
  (``exp(sigma*z - sigma^2/2)`` with ``z ~ N(0,1)``), so enabling it
  changes variance, not the expected transfer time; ``sigma=0`` maps
  ``z`` to exactly ``1.0`` and the transfer time is bit-for-bit the
  static ``bytes/bps`` (plus the floor, itself 0 by default).

* :class:`AvailabilityTrace` — ``prob(cid, t)`` in ``[0, 1]``,
  *multiplied* into the profile's base availability at dispatch time:
  :class:`AlwaysOn` (trivial, the pre-dynamics behavior),
  :class:`DiurnalTrace` (sinusoid with per-client phase, the diurnal
  preset) and :class:`StepTrace` (arbitrary per-client step functions —
  e.g. a maintenance window where the whole fleet goes dark).

* :class:`DynamicsConfig` — the pair, plus the async scheduler's
  redispatch backoff (how long to wait, in virtual seconds, before
  re-trying dispatch when the trace has everyone offline). ``bind``-ing
  a config to a fleet resolves per-profile ``link_model`` overrides and
  draws the per-client trace phases — from the grid's *dynamics* RNG
  stream, an independent child spawned off ``device_seed``, so enabling
  dynamics never perturbs the scheduler's fixed-count
  availability/dropout draws (the trivial-case bit-for-bit contract).

The trivial config (static links, always-on) resolves to ``None`` in the
grid and the schedulers take their exact pre-dynamics paths.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Union

import numpy as np


# ---------------------------------------------------------------------------
# Stochastic links


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """Per-transfer stochastic model over a profile's base bandwidth.

    ``transfer_seconds`` takes a standard-normal draw ``z`` (drawn by the
    caller from the dynamics stream, one per transfer) and returns

        rtt_seconds + (nbytes / bps) * exp(jitter_sigma*z - jitter_sigma^2/2)

    The log-normal factor has mean exactly 1, so the *expected* transfer
    time is the static time plus the RTT floor; ``jitter_sigma=0`` gives
    the static time bit-for-bit (``exp(0.0) == 1.0``).
    """
    jitter_sigma: float = 0.0     # log-normal sigma on the transfer time
    rtt_seconds: float = 0.0      # fixed latency floor per transfer

    @property
    def trivial(self) -> bool:
        return self.jitter_sigma == 0.0 and self.rtt_seconds == 0.0

    def jitter(self, z: float) -> float:
        """Mean-1 multiplicative jitter factor from a N(0,1) draw."""
        s = self.jitter_sigma
        return math.exp(s * float(z) - 0.5 * s * s)

    def transfer_seconds(self, nbytes: float, bps: float, z: float) -> float:
        return self.rtt_seconds + (nbytes / bps) * self.jitter(z)


# ---------------------------------------------------------------------------
# Availability traces (queried at virtual time)


class AvailabilityTrace:
    """``prob(cid, t) in [0, 1]``, multiplied into the profile's base
    availability at dispatch time. ``bind(num_clients, rng)`` resolves
    any per-client randomness (e.g. diurnal phases) from the dynamics
    stream and returns the bound trace."""

    trivial = False

    def bind(self, num_clients: int,
             rng: np.random.Generator) -> "AvailabilityTrace":
        return self

    def prob(self, cid: int, t: float) -> float:
        raise NotImplementedError


class AlwaysOn(AvailabilityTrace):
    """The pre-dynamics behavior: the trace never gates anyone."""

    trivial = True

    def prob(self, cid: int, t: float) -> float:
        return 1.0


@dataclasses.dataclass
class DiurnalTrace(AvailabilityTrace):
    """Sinusoidal online/offline cycle: availability swings between
    ``low`` and ``high`` over ``period`` virtual seconds. Each client
    gets a phase in ``[0, phase_spread)`` drawn at bind time from the
    dynamics stream (``phase_spread=0`` puts the whole fleet on one
    clock — the classic correlated diurnal dip)."""

    period: float = 86_400.0
    low: float = 0.1
    high: float = 1.0
    phase_spread: float = 1.0
    phases: Optional[np.ndarray] = None   # (num_clients,) in [0, 1)

    def __post_init__(self):
        if not 0.0 <= self.low <= self.high <= 1.0:
            raise ValueError(f"need 0 <= low <= high <= 1, got "
                             f"[{self.low}, {self.high}]")
        if self.period <= 0:
            raise ValueError("period must be positive")

    def bind(self, num_clients: int,
             rng: np.random.Generator) -> "DiurnalTrace":
        if self.phases is not None:
            if len(self.phases) != num_clients:
                raise ValueError(f"explicit phases have length "
                                 f"{len(self.phases)}, fleet has "
                                 f"{num_clients} clients")
            return self
        return dataclasses.replace(
            self, phases=rng.random(num_clients) * self.phase_spread)

    def prob(self, cid: int, t: float) -> float:
        ph = float(self.phases[cid]) if self.phases is not None else 0.0
        s = math.sin(2.0 * math.pi * (t / self.period + ph))
        return self.low + (self.high - self.low) * 0.5 * (1.0 + s)


@dataclasses.dataclass
class StepTrace(AvailabilityTrace):
    """Piecewise-constant availability: ``values[..., k]`` holds on
    ``[times[k], times[k+1])``. ``times`` must start at 0 and ascend;
    ``values`` is ``(T,)`` (shared by the fleet) or ``(num_clients, T)``
    (per-client traces). The last value holds forever."""

    times: np.ndarray
    values: np.ndarray

    def __post_init__(self):
        self.times = np.asarray(self.times, np.float64)
        self.values = np.asarray(self.values, np.float64)
        if self.times.ndim != 1 or self.times[0] != 0.0 \
                or np.any(np.diff(self.times) <= 0):
            raise ValueError("times must be 1-D, start at 0 and be "
                             "strictly increasing")
        if self.values.shape[-1] != len(self.times):
            raise ValueError(f"values' last axis ({self.values.shape[-1]}) "
                             f"must match times ({len(self.times)})")
        if np.any(self.values < 0) or np.any(self.values > 1):
            raise ValueError("availability values must lie in [0, 1]")

    def bind(self, num_clients: int,
             rng: np.random.Generator) -> "StepTrace":
        if self.values.ndim == 2 and self.values.shape[0] != num_clients:
            raise ValueError(f"per-client trace has {self.values.shape[0]} "
                             f"rows, fleet has {num_clients} clients")
        return self

    def prob(self, cid: int, t: float) -> float:
        k = int(np.searchsorted(self.times, t, side="right")) - 1
        k = max(k, 0)
        if self.values.ndim == 2:
            return float(self.values[cid, k])
        return float(self.values[k])


# ---------------------------------------------------------------------------
# The config the grid consumes


@dataclasses.dataclass
class DynamicsConfig:
    """Fleet-wide device dynamics: the default link model (per-profile
    ``DeviceProfile.link_model`` overrides it client by client), the
    availability trace, and the async scheduler's redispatch backoff."""

    link: LinkModel = dataclasses.field(default_factory=LinkModel)
    availability: AvailabilityTrace = dataclasses.field(
        default_factory=AlwaysOn)
    # async: base virtual seconds to wait before re-trying dispatch when
    # no sampled client passes the availability check (the trace has the
    # fleet dark); sync rounds just close empty at their deadline. The
    # async wait escalates exponentially per consecutive retry
    # (base * growth^k, capped, with deterministic jitter — see
    # BoundDynamics.backoff_seconds); the sync dark-window re-poll uses
    # the flat base.
    redispatch_backoff: float = 30.0
    backoff_growth: float = 2.0           # escalation per consecutive retry
    backoff_cap: float = 1_920.0          # ceiling on one backoff wait
    # async: virtual-seconds budget for one *continuous* dark window —
    # past it the scheduler raises instead of retrying forever (replaces
    # the old raw 100k-consecutive-retry guard)
    retry_budget: float = 1e7

    @property
    def trivial(self) -> bool:
        return self.link.trivial and self.availability.trivial

    def bind(self, fleet, rng: np.random.Generator) -> "BoundDynamics":
        links = tuple(getattr(p, "link_model", None) or self.link
                      for p in fleet.profiles)
        return BoundDynamics(
            links=links,
            trace=self.availability.bind(len(fleet), rng),
            redispatch_backoff=float(self.redispatch_backoff),
            backoff_growth=float(self.backoff_growth),
            backoff_cap=float(self.backoff_cap),
            retry_budget=float(self.retry_budget))


@dataclasses.dataclass(frozen=True)
class BoundDynamics:
    """A DynamicsConfig resolved against one fleet: per-client link
    models (profile override or the config default) and a bound trace.
    This is what the schedulers consume."""

    links: tuple
    trace: AvailabilityTrace
    redispatch_backoff: float
    backoff_growth: float = 2.0
    backoff_cap: float = 1_920.0
    retry_budget: float = 1e7

    # jitter the k-th consecutive backoff by a *deterministic* factor in
    # [0.75, 1.25): the golden-ratio low-discrepancy sequence de-phases
    # parked dispatch slots without consuming a single PRNG draw (the
    # zero-draw hygiene rule — backoffs must not move any stream)
    _JITTER_STEP = 0.6180339887498949

    def backoff_seconds(self, k: int) -> float:
        """Virtual seconds to park the k-th consecutive failed dispatch:
        capped exponential escalation with deterministic jitter."""
        base = min(self.redispatch_backoff * self.backoff_growth ** k,
                   self.backoff_cap)
        return base * (0.75 + 0.5 * ((k * self._JITTER_STEP) % 1.0))

    def link_for(self, cid: int) -> LinkModel:
        return self.links[int(cid)]

    def prob(self, cid: int, t: float) -> float:
        return self.trace.prob(cid, t)

    def round_trip_seconds(self, profile, down_bytes: int, up_bytes: int,
                           compute_seconds: float, cid: int,
                           z_down: float, z_up: float) -> float:
        """One full client round trip under the stochastic link: jittered
        download + compute + jittered upload. ``z_down``/``z_up`` are the
        caller's N(0,1) draws from the dynamics stream."""
        lm = self.link_for(cid)
        return (lm.transfer_seconds(down_bytes, profile.downlink_bps, z_down)
                + compute_seconds * profile.compute_multiplier
                + lm.transfer_seconds(up_bytes, profile.uplink_bps, z_up))


# ---------------------------------------------------------------------------
# Presets + resolution


def _preset_diurnal() -> DynamicsConfig:
    # mobile links jitter ~25% transfer to transfer with a 200ms floor;
    # availability swings 10%..100% over a (virtual) 4000-second day —
    # short enough that example/test runs see several cycles
    return DynamicsConfig(
        link=LinkModel(jitter_sigma=0.25, rtt_seconds=0.2),
        availability=DiurnalTrace(period=4_000.0, low=0.1, high=1.0))


def _preset_jitter() -> DynamicsConfig:
    return DynamicsConfig(link=LinkModel(jitter_sigma=0.25, rtt_seconds=0.2))


# "static" is NOT an entry here: it is intercepted by resolve_dynamics
# as the hard off-switch (None even over profile link models) — a dict
# entry would carry the wrong semantics if ever reached via
# FLEET_DEFAULT_DYNAMICS indirection
DYNAMICS_PRESETS: Dict[str, callable] = {
    "jitter": _preset_jitter,
    "diurnal": _preset_diurnal,
}

# fleet presets that imply a dynamics preset when GridConfig.dynamics is
# left at None (the new preset names opt in; existing fleets stay static)
FLEET_DEFAULT_DYNAMICS: Dict[str, str] = {
    "pareto-mobile-diurnal": "diurnal",
}


def resolve_dynamics(spec: Union[None, str, DynamicsConfig],
                     fleet) -> Optional[DynamicsConfig]:
    """GridConfig.dynamics -> DynamicsConfig or None (trivial).

    ``None`` defers to the fleet preset's default (static for every
    pre-dynamics preset); a name looks up :data:`DYNAMICS_PRESETS`; a
    config passes through. A config that is trivial AND rides a fleet
    with no per-profile link models resolves to ``None`` — the signal
    for the schedulers to take the exact pre-dynamics code paths.

    ``"static"`` is a hard off-switch: it resolves to ``None`` even on
    fleets whose profiles carry link models, so it is always the true
    static-link/always-on A/B control (to keep per-profile jitter while
    dropping the trace, pass a ``DynamicsConfig`` explicitly — an
    explicit config honors profile link models).
    """
    if spec == "static":
        return None
    if spec is None:
        name = FLEET_DEFAULT_DYNAMICS.get(getattr(fleet, "name", None))
        cfg = DYNAMICS_PRESETS[name]() if name else None
    elif isinstance(spec, str):
        try:
            cfg = DYNAMICS_PRESETS[spec]()
        except KeyError:
            raise ValueError(
                f"unknown dynamics preset {spec!r}; options: "
                f"{sorted(DYNAMICS_PRESETS) + ['static']}") from None
    elif isinstance(spec, DynamicsConfig):
        cfg = spec
    else:
        raise TypeError(f"dynamics must be None, a preset name or a "
                        f"DynamicsConfig, got {type(spec).__name__}")
    has_profile_links = any(getattr(p, "link_model", None) is not None
                            for p in fleet.profiles)
    if cfg is None and not has_profile_links:
        return None
    if cfg is None:
        cfg = DynamicsConfig()
    if cfg.trivial and not has_profile_links:
        return None
    return cfg
