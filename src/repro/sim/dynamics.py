"""Device dynamics for the simulation grid: stochastic links,
trace-driven availability, and correlated region-level shocks.

PR 1's fleet was a *static* snapshot: every transfer moved at exactly the
profile's base bandwidth and availability was one Bernoulli probability,
frozen for the whole run. Real phone fleets are nothing like that — links
jitter transfer to transfer, every transfer pays a latency floor, and
devices follow diurnal online/offline cycles (charging overnight, dark
during the commute). This module models both, queried at *virtual time*
so async flushes see the clock move:

* :class:`LinkModel` — per-transfer multiplicative **log-normal jitter**
  on top of the profile's base bandwidth, plus a fixed **RTT latency
  floor** per transfer. The jitter is mean-preserving
  (``exp(sigma*z - sigma^2/2)`` with ``z ~ N(0,1)``), so enabling it
  changes variance, not the expected transfer time; ``sigma=0`` maps
  ``z`` to exactly ``1.0`` and the transfer time is bit-for-bit the
  static ``bytes/bps`` (plus the floor, itself 0 by default).

* :class:`AvailabilityTrace` — ``prob(cid, t)`` in ``[0, 1]``,
  *multiplied* into the profile's base availability at dispatch time:
  :class:`AlwaysOn` (trivial, the pre-dynamics behavior),
  :class:`DiurnalTrace` (sinusoid with per-client phase, the diurnal
  preset) and :class:`StepTrace` (arbitrary per-client step functions —
  e.g. a maintenance window where the whole fleet goes dark). Every
  trace also answers ``prob_batch(cids, t)`` — one vectorized query per
  cohort, which is how the sync engine consumes it.

* :class:`RegionShocks` — **correlated** availability shocks over the
  two-level topology (``sim/topology.py``): a Poisson process of
  outages, each downing *one whole edge region* (a cell-tower outage
  takes out its geographic client group together) for ``duration``
  virtual seconds, scaling every member's availability by ``residual``.
  Bound to its own spawned RNG stream (zero draws of any other stream),
  advanced lazily at monotone virtual time, snapshot/restorable.

* :class:`DynamicsConfig` — link + trace + shocks, plus the async
  scheduler's redispatch backoff (how long to wait, in virtual seconds,
  before re-trying dispatch when the trace has everyone offline).
  ``bind``-ing a config to a fleet resolves per-profile ``link_model``
  overrides into per-client sigma/RTT *arrays* (no N-tuple of link
  objects) and draws the per-client trace phases — from the grid's
  *dynamics* RNG stream, an independent child spawned off
  ``device_seed``, so enabling dynamics never perturbs the scheduler's
  fixed-count availability/dropout draws (the trivial-case bit-for-bit
  contract).

The trivial config (static links, always-on, no shocks) resolves to
``None`` in the grid and the schedulers take their exact pre-dynamics
paths.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Union

import numpy as np


# ---------------------------------------------------------------------------
# Stochastic links


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """Per-transfer stochastic model over a profile's base bandwidth.

    ``transfer_seconds`` takes a standard-normal draw ``z`` (drawn by the
    caller from the dynamics stream, one per transfer) and returns

        rtt_seconds + (nbytes / bps) * exp(jitter_sigma*z - jitter_sigma^2/2)

    The log-normal factor has mean exactly 1, so the *expected* transfer
    time is the static time plus the RTT floor; ``jitter_sigma=0`` gives
    the static time bit-for-bit (``exp(0.0) == 1.0``).
    """
    jitter_sigma: float = 0.0     # log-normal sigma on the transfer time
    rtt_seconds: float = 0.0      # fixed latency floor per transfer

    @property
    def trivial(self) -> bool:
        return self.jitter_sigma == 0.0 and self.rtt_seconds == 0.0

    def jitter(self, z: float) -> float:
        """Mean-1 multiplicative jitter factor from a N(0,1) draw."""
        s = self.jitter_sigma
        return math.exp(s * float(z) - 0.5 * s * s)

    def transfer_seconds(self, nbytes: float, bps: float, z: float) -> float:
        return self.rtt_seconds + (nbytes / bps) * self.jitter(z)


# ---------------------------------------------------------------------------
# Availability traces (queried at virtual time)


class AvailabilityTrace:
    """``prob(cid, t) in [0, 1]``, multiplied into the profile's base
    availability at dispatch time. ``bind(num_clients, rng)`` resolves
    any per-client randomness (e.g. diurnal phases) from the dynamics
    stream and returns the bound trace. ``prob_batch(cids, t)`` is the
    vectorized form — subclasses should override it with one array op
    (the base-class fallback loops)."""

    trivial = False

    def bind(self, num_clients: int,
             rng: np.random.Generator) -> "AvailabilityTrace":
        return self

    def prob(self, cid: int, t: float) -> float:
        raise NotImplementedError

    def prob_batch(self, cids: np.ndarray, t: float) -> np.ndarray:
        return np.array([self.prob(int(c), t) for c in np.asarray(cids)],
                        np.float64)


class AlwaysOn(AvailabilityTrace):
    """The pre-dynamics behavior: the trace never gates anyone."""

    trivial = True

    def prob(self, cid: int, t: float) -> float:
        return 1.0

    def prob_batch(self, cids: np.ndarray, t: float) -> np.ndarray:
        return np.ones(len(np.asarray(cids)), np.float64)


@dataclasses.dataclass
class DiurnalTrace(AvailabilityTrace):
    """Sinusoidal online/offline cycle: availability swings between
    ``low`` and ``high`` over ``period`` virtual seconds. Each client
    gets a phase in ``[0, phase_spread)`` drawn at bind time from the
    dynamics stream (``phase_spread=0`` puts the whole fleet on one
    clock — the classic correlated diurnal dip)."""

    period: float = 86_400.0
    low: float = 0.1
    high: float = 1.0
    phase_spread: float = 1.0
    phases: Optional[np.ndarray] = None   # (num_clients,) in [0, 1)

    def __post_init__(self):
        if not 0.0 <= self.low <= self.high <= 1.0:
            raise ValueError(f"need 0 <= low <= high <= 1, got "
                             f"[{self.low}, {self.high}]")
        if self.period <= 0:
            raise ValueError("period must be positive")

    def bind(self, num_clients: int,
             rng: np.random.Generator) -> "DiurnalTrace":
        if self.phases is not None:
            if len(self.phases) != num_clients:
                raise ValueError(f"explicit phases have length "
                                 f"{len(self.phases)}, fleet has "
                                 f"{num_clients} clients")
            return self
        return dataclasses.replace(
            self, phases=rng.random(num_clients) * self.phase_spread)

    def prob(self, cid: int, t: float) -> float:
        ph = float(self.phases[cid]) if self.phases is not None else 0.0
        s = math.sin(2.0 * math.pi * (t / self.period + ph))
        return self.low + (self.high - self.low) * 0.5 * (1.0 + s)

    def prob_batch(self, cids: np.ndarray, t: float) -> np.ndarray:
        cids = np.asarray(cids)
        ph = self.phases[cids] if self.phases is not None \
            else np.zeros(len(cids))
        s = np.sin(2.0 * np.pi * (t / self.period + ph))
        return self.low + (self.high - self.low) * 0.5 * (1.0 + s)


@dataclasses.dataclass
class StepTrace(AvailabilityTrace):
    """Piecewise-constant availability: ``values[..., k]`` holds on
    ``[times[k], times[k+1])``. ``times`` must start at 0 and ascend;
    ``values`` is ``(T,)`` (shared by the fleet) or ``(num_clients, T)``
    (per-client traces). The last value holds forever."""

    times: np.ndarray
    values: np.ndarray

    def __post_init__(self):
        self.times = np.asarray(self.times, np.float64)
        self.values = np.asarray(self.values, np.float64)
        if self.times.ndim != 1 or self.times[0] != 0.0 \
                or np.any(np.diff(self.times) <= 0):
            raise ValueError("times must be 1-D, start at 0 and be "
                             "strictly increasing")
        if self.values.shape[-1] != len(self.times):
            raise ValueError(f"values' last axis ({self.values.shape[-1]}) "
                             f"must match times ({len(self.times)})")
        if np.any(self.values < 0) or np.any(self.values > 1):
            raise ValueError("availability values must lie in [0, 1]")

    def bind(self, num_clients: int,
             rng: np.random.Generator) -> "StepTrace":
        if self.values.ndim == 2 and self.values.shape[0] != num_clients:
            raise ValueError(f"per-client trace has {self.values.shape[0]} "
                             f"rows, fleet has {num_clients} clients")
        return self

    def prob(self, cid: int, t: float) -> float:
        k = int(np.searchsorted(self.times, t, side="right")) - 1
        k = max(k, 0)
        if self.values.ndim == 2:
            return float(self.values[cid, k])
        return float(self.values[k])

    def prob_batch(self, cids: np.ndarray, t: float) -> np.ndarray:
        cids = np.asarray(cids)
        k = max(int(np.searchsorted(self.times, t, side="right")) - 1, 0)
        if self.values.ndim == 2:
            return self.values[cids, k]
        return np.full(len(cids), self.values[k])


# ---------------------------------------------------------------------------
# Correlated region shocks (the topology-aware failure mode)


@dataclasses.dataclass(frozen=True)
class RegionShocks:
    """Poisson process of correlated edge-region outages.

    Inter-arrival times are exponential with mean ``every`` virtual
    seconds; each shock picks one region uniformly and scales every
    member's availability by ``residual`` for ``duration`` seconds
    (``residual=0`` is a full cell-tower outage). Requires a topology
    (``GridConfig.topology``) — a flat grid has no regions to down."""

    every: float = 2_000.0
    duration: float = 300.0
    residual: float = 0.0

    def __post_init__(self):
        if self.every <= 0 or self.duration <= 0:
            raise ValueError("RegionShocks.every/duration must be positive")
        if not 0.0 <= self.residual <= 1.0:
            raise ValueError(f"residual={self.residual} must lie in [0, 1]")

    def bind(self, num_regions: int, rng: np.random.Generator,
             tracer=None) -> "BoundShocks":
        return BoundShocks(self, num_regions, rng, tracer=tracer)


class BoundShocks:
    """A RegionShocks config bound to its own RNG stream (a spawn child
    of the device stream — zero parent draws, like ``sim/faults.py``).

    The outage process is advanced *lazily* at monotone virtual time:
    each shock consumes exactly two draws (a uniform region pick and the
    next exponential gap; the first gap is drawn at bind), so the stream
    position depends only on how far the clock has advanced — never on
    cohort outcomes — and a snapshot (``state_dict``/``load_state``)
    restores the process bit-exactly."""

    def __init__(self, cfg: RegionShocks, num_regions: int,
                 rng: np.random.Generator, tracer=None):
        if num_regions < 1:
            raise ValueError("shocks need >= 1 region")
        self.cfg = cfg
        self.num_regions = int(num_regions)
        self.rng = rng
        self.tracer = tracer
        self.fired = 0
        # every outage ever fired, as [region, start, end] — kept whole
        # (runs are finite) so tests and ops can audit the shock history
        self.outages: List[List[float]] = []
        # the still-live subset, pruned as the (monotone) clock advances
        # — factor queries scan only this, so dense shock schedules stay
        # O(active), not O(history)
        self._active: List[List[float]] = []
        self._t_last = 0.0
        self.next_t = float(rng.exponential(cfg.every))

    def _advance(self, t: float) -> None:
        while self.next_t <= t:
            start = self.next_t
            region = int(self.rng.integers(0, self.num_regions))
            outage = [float(region), start, start + self.cfg.duration]
            self.outages.append(outage)
            self._active.append(outage)
            self.fired += 1
            if self.tracer is not None and self.tracer.enabled:
                self.tracer.instant("shock", start, region=region,
                                    duration=float(self.cfg.duration),
                                    residual=float(self.cfg.residual),
                                    until=start + self.cfg.duration)
            self.next_t = start + float(self.rng.exponential(self.cfg.every))
        if t > self._t_last:
            self._t_last = t
            if self._active:
                self._active = [o for o in self._active if o[2] > t]

    def factor(self, regions: np.ndarray, t: float) -> np.ndarray:
        """Per-member availability multipliers for a cohort whose members
        live in ``regions`` (int array), queried at virtual time ``t``."""
        self._advance(t)
        regions = np.asarray(regions)
        f = np.ones(len(regions), np.float64)
        for r, start, end in self._active:
            if start <= t < end:
                f[regions == int(r)] *= self.cfg.residual
        return f

    def factor_one(self, region: int, t: float) -> float:
        """Scalar form for the async scheduler's per-dispatch check."""
        self._advance(t)
        f = 1.0
        for r, start, end in self._active:
            if int(r) == int(region) and start <= t < end:
                f *= self.cfg.residual
        return f

    def state_dict(self) -> Dict[str, Any]:
        return {"rng": self.rng.bit_generator.state,
                "next_t": float(self.next_t),
                "fired": int(self.fired),
                "t_last": float(self._t_last),
                "outages": [list(o) for o in self.outages]}

    def load_state(self, state: Dict[str, Any]) -> None:
        self.rng.bit_generator.state = state["rng"]
        self.next_t = float(state["next_t"])
        self.fired = int(state["fired"])
        self._t_last = float(state.get("t_last", 0.0))
        self.outages = [list(o) for o in state["outages"]]
        self._active = [o for o in self.outages if o[2] > self._t_last]


# ---------------------------------------------------------------------------
# The config the grid consumes


@dataclasses.dataclass
class DynamicsConfig:
    """Fleet-wide device dynamics: the default link model (per-profile
    ``DeviceProfile.link_model`` overrides it client by client), the
    availability trace, correlated region shocks (needs a topology), and
    the async scheduler's redispatch backoff."""

    link: LinkModel = dataclasses.field(default_factory=LinkModel)
    availability: AvailabilityTrace = dataclasses.field(
        default_factory=AlwaysOn)
    # correlated edge-region outages (sim/topology.py must be active);
    # bound by the grid against the topology with its own spawned stream
    shocks: Optional[RegionShocks] = None
    # async: base virtual seconds to wait before re-trying dispatch when
    # no sampled client passes the availability check (the trace has the
    # fleet dark); sync rounds just close empty at their deadline. The
    # async wait escalates exponentially per consecutive retry
    # (base * growth^k, capped, with deterministic jitter — see
    # BoundDynamics.backoff_seconds); the sync dark-window re-poll uses
    # the flat base.
    redispatch_backoff: float = 30.0
    backoff_growth: float = 2.0           # escalation per consecutive retry
    backoff_cap: float = 1_920.0          # ceiling on one backoff wait
    # async: virtual-seconds budget for one *continuous* dark window —
    # past it the scheduler raises instead of retrying forever (replaces
    # the old raw 100k-consecutive-retry guard)
    retry_budget: float = 1e7

    @property
    def trivial(self) -> bool:
        return (self.link.trivial and self.availability.trivial
                and self.shocks is None)

    def bind(self, fleet, rng: np.random.Generator) -> "BoundDynamics":
        st = fleet.state
        link_sigma = np.where(st.has_link, st.link_sigma,
                              self.link.jitter_sigma)
        link_rtt = np.where(st.has_link, st.link_rtt,
                            self.link.rtt_seconds)
        return BoundDynamics(
            link_sigma=link_sigma, link_rtt=link_rtt,
            trace=self.availability.bind(len(fleet), rng),
            redispatch_backoff=float(self.redispatch_backoff),
            backoff_growth=float(self.backoff_growth),
            backoff_cap=float(self.backoff_cap),
            retry_budget=float(self.retry_budget))


@dataclasses.dataclass(frozen=True, eq=False)
class BoundDynamics:
    """A DynamicsConfig resolved against one fleet: per-client link
    parameters as ``(num_clients,)`` arrays (profile override or the
    config default — no per-client link objects) and a bound trace.
    This is what the schedulers consume."""

    link_sigma: np.ndarray
    link_rtt: np.ndarray
    trace: AvailabilityTrace
    redispatch_backoff: float
    backoff_growth: float = 2.0
    backoff_cap: float = 1_920.0
    retry_budget: float = 1e7

    # jitter the k-th consecutive backoff by a *deterministic* factor in
    # [0.75, 1.25): the golden-ratio low-discrepancy sequence de-phases
    # parked dispatch slots without consuming a single PRNG draw (the
    # zero-draw hygiene rule — backoffs must not move any stream)
    _JITTER_STEP = 0.6180339887498949

    def backoff_seconds(self, k: int) -> float:
        """Virtual seconds to park the k-th consecutive failed dispatch:
        capped exponential escalation with deterministic jitter."""
        base = min(self.redispatch_backoff * self.backoff_growth ** k,
                   self.backoff_cap)
        return base * (0.75 + 0.5 * ((k * self._JITTER_STEP) % 1.0))

    def link_for(self, cid: int) -> LinkModel:
        """Lazy per-client view over the link-parameter arrays."""
        i = int(cid)
        return LinkModel(jitter_sigma=float(self.link_sigma[i]),
                         rtt_seconds=float(self.link_rtt[i]))

    def prob(self, cid: int, t: float) -> float:
        return self.trace.prob(cid, t)

    def prob_batch(self, cids: np.ndarray, t: float) -> np.ndarray:
        return self.trace.prob_batch(cids, t)

    def round_trip_seconds(self, profile, down_bytes: int, up_bytes: int,
                           compute_seconds: float, cid: int,
                           z_down: float, z_up: float) -> float:
        """One full client round trip under the stochastic link: jittered
        download + compute + jittered upload. ``z_down``/``z_up`` are the
        caller's N(0,1) draws from the dynamics stream."""
        lm = self.link_for(cid)
        return (lm.transfer_seconds(down_bytes, profile.downlink_bps, z_down)
                + compute_seconds * profile.compute_multiplier
                + lm.transfer_seconds(up_bytes, profile.uplink_bps, z_up))

    def round_trip_components_batch(self, st, cids: np.ndarray, down_bytes,
                                    up_bytes, compute_seconds,
                                    z_down: np.ndarray, z_up: np.ndarray):
        """The three phase terms of :meth:`round_trip_seconds_batch` —
        ``(down, comp, up)`` arrays whose left-to-right sum is exactly
        the round-trip time. The tracer records them on dispatch spans
        (schema v4 ``t_down``/``t_comp``/``t_up``) so ``obs/analyze.py``
        can split a span into phases without re-deriving link models.
        Consumes zero RNG draws: ``z_down``/``z_up`` are the caller's
        already-drawn N(0,1) values."""
        cids = np.asarray(cids)
        sig = self.link_sigma[cids]
        rtt = self.link_rtt[cids]
        down = (rtt + (np.asarray(down_bytes, np.float64)
                       / st.downlink_bps[cids])
                * np.exp(sig * z_down - 0.5 * sig * sig))
        up = (rtt + (np.asarray(up_bytes, np.float64) / st.uplink_bps[cids])
              * np.exp(sig * z_up - 0.5 * sig * sig))
        comp = (np.asarray(compute_seconds, np.float64)
                * st.compute_multiplier[cids])
        return down, comp, up

    def round_trip_seconds_batch(self, st, cids: np.ndarray, down_bytes,
                                 up_bytes, compute_seconds,
                                 z_down: np.ndarray,
                                 z_up: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`round_trip_seconds` over a cohort — one
        array op per round instead of one LinkModel call per member.
        ``st`` is the fleet's :class:`~repro.sim.devices.FleetState`;
        the float64 expression matches the scalar path's association
        elementwise."""
        down, comp, up = self.round_trip_components_batch(
            st, cids, down_bytes, up_bytes, compute_seconds, z_down, z_up)
        return down + comp + up


# ---------------------------------------------------------------------------
# Presets + resolution


def _preset_diurnal() -> DynamicsConfig:
    # mobile links jitter ~25% transfer to transfer with a 200ms floor;
    # availability swings 10%..100% over a (virtual) 4000-second day —
    # short enough that example/test runs see several cycles
    return DynamicsConfig(
        link=LinkModel(jitter_sigma=0.25, rtt_seconds=0.2),
        availability=DiurnalTrace(period=4_000.0, low=0.1, high=1.0))


def _preset_jitter() -> DynamicsConfig:
    return DynamicsConfig(link=LinkModel(jitter_sigma=0.25, rtt_seconds=0.2))


# "static" is NOT an entry here: it is intercepted by resolve_dynamics
# as the hard off-switch (None even over profile link models) — a dict
# entry would carry the wrong semantics if ever reached via
# FLEET_DEFAULT_DYNAMICS indirection
DYNAMICS_PRESETS: Dict[str, callable] = {
    "jitter": _preset_jitter,
    "diurnal": _preset_diurnal,
}

# fleet presets that imply a dynamics preset when GridConfig.dynamics is
# left at None (the new preset names opt in; existing fleets stay static)
FLEET_DEFAULT_DYNAMICS: Dict[str, str] = {
    "pareto-mobile-diurnal": "diurnal",
}


def resolve_dynamics(spec: Union[None, str, DynamicsConfig],
                     fleet) -> Optional[DynamicsConfig]:
    """GridConfig.dynamics -> DynamicsConfig or None (trivial).

    ``None`` defers to the fleet preset's default (static for every
    pre-dynamics preset); a name looks up :data:`DYNAMICS_PRESETS`; a
    config passes through. A config that is trivial AND rides a fleet
    with no per-profile link models resolves to ``None`` — the signal
    for the schedulers to take the exact pre-dynamics code paths.

    ``"static"`` is a hard off-switch: it resolves to ``None`` even on
    fleets whose profiles carry link models, so it is always the true
    static-link/always-on A/B control (to keep per-profile jitter while
    dropping the trace, pass a ``DynamicsConfig`` explicitly — an
    explicit config honors profile link models).
    """
    if spec == "static":
        return None
    if spec is None:
        name = FLEET_DEFAULT_DYNAMICS.get(getattr(fleet, "name", None))
        cfg = DYNAMICS_PRESETS[name]() if name else None
    elif isinstance(spec, str):
        try:
            cfg = DYNAMICS_PRESETS[spec]()
        except KeyError:
            raise ValueError(
                f"unknown dynamics preset {spec!r}; options: "
                f"{sorted(DYNAMICS_PRESETS) + ['static']}") from None
    elif isinstance(spec, DynamicsConfig):
        cfg = spec
    else:
        raise TypeError(f"dynamics must be None, a preset name or a "
                        f"DynamicsConfig, got {type(spec).__name__}")
    state = getattr(fleet, "state", None)
    if state is not None:
        has_profile_links = bool(np.any(state.has_link))
    else:
        has_profile_links = any(getattr(p, "link_model", None) is not None
                                for p in fleet.profiles)
    if cfg is None and not has_profile_links:
        return None
    if cfg is None:
        cfg = DynamicsConfig()
    if cfg.trivial and not has_profile_links:
        return None
    return cfg
