"""The simulation-grid driver.

``run_grid`` trains a FedPT model over a heterogeneous client fleet under
either scheduling regime and reports *measured* wire bytes plus simulated
cross-device wall-clock. ``fl.runtime.run_federated`` delegates here with
``GridConfig()`` defaults (uniform fleet, synchronous, no deadline) and is
reproduced **bit-for-bit**: the grid consumes the data-sampling RNG stream
(``seed + 77``) and the per-round DP keys (``seed*100_003 + r``) in
exactly the same order, and routes all device/availability randomness
through a separate stream (and all *dynamics* randomness — link jitter,
trace phases — through an independent child of that stream).

``GridConfig.dynamics`` (sim/dynamics.py) makes links stochastic and
availability trace-driven at virtual time; ``GridConfig.selection``
(sim/selection.py) makes cohort choice a policy — bandwidth-aware
sampling with importance weights, FedPLT-style tier rotation, or online
re-tiering from observed round trips. The trivial corner (static links,
always-on, uniform selection) routes through the exact pre-dynamics
code paths.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import Counter
from typing import Any, Callable, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.partition as part
from repro.checkpoint import grid_state as gstate_lib
from repro.core import comm, dp as dp_lib, fedpt
from repro.core import flat as flat_lib
from repro.core import plan as plan_lib
from repro.core import sanitize as sanitize_lib
from repro.data import synthetic as syn
from repro.launch import mesh as mesh_lib
from repro.launch import sharding as shard_lib
from repro.obs import metrics as metrics_lib
from repro.obs import profiling as prof_lib
from repro.obs import trace as trace_lib
from repro.sim import devices as dev_lib
from repro.sim import dynamics as dyn_lib
from repro.sim import faults as faults_lib
from repro.sim import scheduler as sched_lib
from repro.sim import selection as sel_lib
from repro.sim import topology as topo_lib
from repro.sim import wire


@dataclasses.dataclass
class GridConfig:
    mode: str = "sync"                      # "sync" | "async"
    fleet: Union[str, dev_lib.Fleet] = "uniform"
    # virtual seconds one local step takes on the reference device; each
    # client scales it by its profile's compute_multiplier
    base_step_time: float = 0.01
    # --- sync knobs ---
    over_selection: float = 1.0             # dispatch ceil(f*C), keep first C
    straggler_deadline: float = math.inf    # virtual seconds per round
    # --- async (FedBuff) knobs ---
    concurrency: int = 10                   # clients kept in flight
    goal_count: int = 5                     # buffer size K per server update
    staleness: Any = "polynomial"           # name or callable (core.fedpt)
    staleness_kw: Dict[str, float] = dataclasses.field(default_factory=dict)
    # fixed-width client lanes: in-flight client steps are deferred and
    # executed as one vmapped (lane, ...) batch per flush instead of one
    # jit dispatch per client. None = auto (lane width == goal_count);
    # 0 = the sequential per-client reference engine. Virtual-clock
    # history is identical either way (execution timing never feeds the
    # event clock); only device dispatch granularity changes.
    lanes: Optional[int] = None
    # virtual-seconds budget for the whole async run: the first event
    # past it ends the run, flushing the partial buffer as one final
    # short update (padded to goal_count with zero weights)
    async_deadline: float = math.inf
    # --- mesh execution ---
    # None = single-device dispatch. A launch/mesh.py preset name
    # ("single", "debug", "debug-pod", "production", ...) or a mesh
    # object shards the grid's device work end-to-end: lane-batched
    # client steps run data-parallel with the lane axis on the mesh's
    # ("pod", "data") axes and the flat delta's size axis on "model",
    # and the buffered apply reduces the sharded (K, size) buffer in
    # place (no gather). The virtual clock, staleness bookkeeping and
    # wire metering are mesh-independent; histories match the
    # single-device run to fp32 round-off.
    mesh: Any = None
    # --- trainability tiers (core/plan.py) ---
    # None = every client trains the full freeze_spec trainable tree
    # (the pre-plan system, bit for bit — as is a one-tier plan). A
    # TrainPlan / {name: extra_freeze_spec} dict / (name, spec) sequence
    # assigns each client a tier: weak devices train (and upload) less.
    plan: Any = None
    # "capability" (quantile-split devices.capability_score, most
    # capable -> tier 0), an explicit per-client tier-index array, or a
    # callable DeviceProfile -> tier index
    tier_assignment: Any = "capability"
    # --- device dynamics (sim/dynamics.py) ---
    # None = the fleet preset's default (static for every pre-dynamics
    # preset; "pareto-mobile-diurnal" implies the "diurnal" preset). A
    # preset name ("static", "jitter", "diurnal") or a DynamicsConfig
    # turns on stochastic links (per-transfer log-normal jitter + RTT
    # floor) and trace-driven availability queried at virtual time.
    # Trivial dynamics resolve to the exact pre-dynamics code paths.
    dynamics: Any = None
    # --- cohort selection (sim/selection.py) ---
    # "uniform" (exact pre-selection behavior), "bandwidth-aware",
    # "tier-rotation", "adaptive-capability", or a SelectionPolicy
    # instance
    selection: Any = "uniform"
    # --- two-level aggregation topology (sim/topology.py) ---
    # None = the flat single-hop grid (no hierarchical machinery at
    # all). An int region count, a TopologyConfig or an explicit
    # per-client region array partitions the fleet into edge regions:
    # each edge pre-reduces its members' flat deltas into one (size,)
    # buffer per flush, the wire bills the client->edge and
    # edge->server hops separately (CommReport.hop_traffic), and
    # correlated region shocks (DynamicsConfig.shocks) can down a
    # whole edge at once. A one-region topology runs the full edge
    # machinery and stays bit-identical to the flat grid
    # (test-enforced), so hierarchy can be A/B'd against flat.
    topology: Any = None
    # --- telemetry (repro/obs) ---
    # None = the NULL tracer: no event records, no extra PRNG draws,
    # bit-identical histories (test-enforced). A TelemetryConfig (or
    # True/"on", or a dict of its fields) records typed span/event
    # traces in virtual time — dispatches, uploads, retries, flushes,
    # rounds, dp_flush accounting, tier wire billing — inspectable on
    # GridResult.telemetry and exportable as schema-versioned JSONL or
    # a Chrome/Perfetto timeline. The metrics registry backing
    # GridResult.scheduler_stats/tier_stats is always on either way.
    telemetry: Any = None
    # --- fault injection (sim/faults.py) ---
    # None = no failure model: zero extra PRNG draws, bit-identical
    # histories (test-enforced). A preset name ("chaos"), a FaultConfig
    # or a dict of its fields injects client crash-mid-compute, upload
    # truncation, payload corruption (NaN / bit-flip), duplicate
    # deliveries and a server kill at virtual time T — all drawn from an
    # independent spawned fault stream. Sync mode supports crashes and
    # the kill only (payload faults need a per-client wire payload).
    faults: Any = None
    # --- delta quarantine (core/sanitize.py) ---
    # None/False = off (clean-data aggregation is bit-identical either
    # way). True / a SanitizeConfig / a dict screens the delta buffer
    # before aggregation: non-finite rows and norm outliers are zeroed
    # with zero weight (under DP the fixed denominator is untouched);
    # every quarantined row emits a traced "quarantine" event.
    sanitize: Any = None
    # --- fused aggregation tail (kernels/ops.agg_tail) ---
    # None = the shape- and pipeline-aware default: quantized delta
    # buffers (uplink_bits > 0) with at least
    # kernels.ops.AGG_FUSE_THRESHOLD elements (K x size) take the fused
    # stats/pack/apply sweep, everything else the staged per-op tail
    # (bit-identical to the historical sequence). An int overrides it
    # and routes purely by size: 0 forces fused everywhere, a huge
    # value forces staged everywhere — both round engines (sync rounds
    # and async buffered flushes) thread it through.
    agg_tail_threshold: Optional[int] = None
    # --- mid-run checkpoint / resume (checkpoint/grid_state.py) ---
    # checkpoint_every > 0 snapshots the full execution state into
    # checkpoint_dir every N server updates (async: at flush
    # boundaries; sync: at round boundaries). resume_from restores a
    # snapshot and continues — the resumed run reproduces the
    # uninterrupted run's history exactly (bitwise on CPU).
    checkpoint_every: int = 0
    checkpoint_dir: Optional[str] = None
    resume_from: Optional[str] = None
    # --- rng plumbing ---
    fleet_seed: int = 0                     # profile sampling
    device_seed: int = 13                   # availability/dropout/latency
    # (dynamics draws — jitter, trace phases — come from an independent
    # child stream spawned off [seed, device_seed], so enabling dynamics
    # never moves the availability/dropout stream above; fault draws
    # come from a SECOND spawned child, created only when faults are on)


@dataclasses.dataclass
class GridResult:
    y: Any
    frozen: Any
    history: List[Dict[str, float]]
    comm: comm.CommReport
    seconds_per_round: float                # real wall-clock
    virtual_seconds: float                  # simulated cross-device time
    fleet: dev_lib.Fleet
    mode: str
    scheduler_stats: Dict[str, int]
    # per-flush DP accounting (async mode with dp_noise_multiplier > 0):
    # flushes, padded_flushes, sigma, noise_multiplier, epsilon, delta
    dp: Optional[Dict[str, float]] = None
    # trainability-tier breakdown (GridConfig.plan set): tier name ->
    # {clients, down_bytes, up_bytes, transfers, uploads, ...}; the
    # same per-tier traffic also lives in comm.tier_traffic
    tier_stats: Optional[Dict[str, Dict[str, float]]] = None
    # the CompiledPlan the run used (None without a plan)
    plan: Any = None
    # the bound SelectionPolicy the run used (inspect e.g. .refits or
    # .current_tiers() after an adaptive run)
    policy: Any = None
    # the BoundDynamics the run used (None = static links, always-on)
    dynamics: Any = None
    # the bound Topology the run used (None = flat single-hop grid);
    # per-hop wire traffic lives in comm.hop_traffic
    topology: Any = None
    # the run's MetricsRegistry (always present): scheduler_stats and
    # tier_stats above are dict views over it — metrics.snapshot() is
    # the superset
    metrics: Any = None
    # the Tracer when GridConfig.telemetry was set (else None):`.events`
    # holds the virtual-time records, `.export_jsonl`/`.export_perfetto`
    # write them out
    telemetry: Any = None
    # fault-injection summary (GridConfig.faults set): the run's fired
    # fault counters — crashes, truncated, corrupted, duplicates — plus
    # quarantined rows (None when no failure model was active)
    faults: Optional[Dict[str, int]] = None

    @property
    def stats(self) -> Dict[str, int]:
        """Alias for ``scheduler_stats`` (the normalized per-run
        scheduler counters; same key set in both modes)."""
        return self.scheduler_stats


def num_clients(ds) -> int:
    if hasattr(ds, "num_clients"):
        return ds.num_clients
    return len(ds.client_tokens)


def _uplink_bytes(tree, bits: int) -> int:
    """Measured (serialized) uplink size when the wire format supports
    the payload (fp32 / int8); analytic int-k estimate otherwise, so
    sub-byte quantization configs keep running."""
    if bits in (0, 8):
        return wire.uplink_bytes(tree, bits=bits)
    from repro.core import compress
    return compress.quantized_uplink_bytes(tree, bits)


def run_grid(init_fn: Callable[[int], Any], loss_fn: Callable, dataset,
             rc: fedpt.RoundConfig, rounds: int,
             grid: Optional[GridConfig] = None, freeze_spec=(),
             seed: int = 0, data_kind: str = "images", eval_every: int = 0,
             eval_fn: Optional[Callable[[Any], Dict[str, float]]] = None,
             server_opt=None, log: bool = False) -> GridResult:
    """Train for `rounds` server updates on the simulated fleet. In sync
    mode a "round" is one cohort; in async mode it is one buffered server
    update (goal_count client deltas)."""
    grid = grid or GridConfig()
    N = num_clients(dataset)
    if rc.clients_per_round > N:
        raise ValueError(f"clients_per_round={rc.clients_per_round} exceeds "
                         f"the dataset's {N} clients")
    fleet = dev_lib.make_fleet(N, grid.fleet, seed=grid.fleet_seed)
    y, frozen = part.partition(init_fn(seed), freeze_spec)

    # telemetry: the metrics registry is ALWAYS live (it backs
    # scheduler_stats/tier_stats); the tracer is the NULL no-op unless
    # GridConfig.telemetry asks for event records
    registry = metrics_lib.MetricsRegistry()
    tel_cfg = trace_lib.resolve_telemetry(grid.telemetry)
    tracer = (trace_lib.Tracer(tel_cfg, registry) if tel_cfg is not None
              else trace_lib.NULL_TRACER)
    profile = bool(tel_cfg and tel_cfg.profile)

    report = comm.report_for(y, frozen, uplink_bits=rc.uplink_bits)
    report.tracer = tracer                       # tier_upload billing
    # two-level aggregation topology: None keeps the flat single-hop
    # grid untouched; otherwise every add_measured call mirrors into
    # the client_edge hop ledger and the grid bills the edge_server
    # hop (pre-reduced flat buffers) separately per flush
    topo = topo_lib.resolve_topology(grid.topology, N)
    if topo is not None:
        report.bill_hops = True
    down_bytes = wire.downlink_bytes(y)          # y + 8-byte seed, measured
    up_bytes = _uplink_bytes(y, rc.uplink_bits)  # shape-determined
    compute_seconds = rc.local_steps * grid.base_step_time
    registry.gauge("payload_down_bytes").set(int(down_bytes))
    registry.gauge("payload_up_bytes").set(int(up_bytes))
    registry.gauge("compute_seconds").set(float(compute_seconds))

    # trainability plan: capability->tier per client, tier-sliced uplink
    # payloads (downlink stays the full y + seed for every tier — other
    # tiers keep training the blocks a tier froze, so their current
    # values cannot be regenerated from the seed). The virtual clock
    # also charges per-tier compute: a tier's local step scales with its
    # trainable fraction (a lite tier's backward pass is cheaper); the
    # full tier's fraction is exactly 1.0, so one-tier plans keep the
    # pre-plan clock bit for bit.
    if grid.plan is not None:
        cplan = plan_lib.compile_plan(grid.plan, y)
        tier_of_client = dev_lib.assign_tiers(fleet, len(cplan.tiers),
                                              grid.tier_assignment)
        tier_up = np.asarray(
            [p["up"] for p in
             wire.tier_payloads(y, cplan, rc.uplink_bits).values()],
            np.int64)
        total_params = sum(cplan.layout.sizes)
        tier_compute = np.asarray(
            [compute_seconds * (t.param_count / total_params
                                if total_params else 1.0)
             for t in cplan.tiers], np.float64)
        for t in cplan.tiers:
            # per-tier virtual compute charge, the registry's copy (the
            # tier_stats view and the benchmarks read it from here)
            registry.gauge("tier_compute").set(float(tier_compute[t.index]),
                                               label=t.index)
    else:
        cplan = None
        tier_of_client = None
        tier_up = None
        tier_compute = None

    data_rng = np.random.default_rng(seed + 77)  # == run_federated's stream
    dev_rng = np.random.default_rng([seed, grid.device_seed])
    # the dynamics stream: an independent child of [seed, device_seed].
    # Spawning advances no draws of dev_rng, so the scheduler's
    # fixed-count availability/dropout streams are byte-identical with
    # dynamics on or off (tests pin this).
    dyn_rng = dev_rng.spawn(1)[0]
    dyn_cfg = dyn_lib.resolve_dynamics(grid.dynamics, fleet)
    dyn = dyn_cfg.bind(fleet, dyn_rng) if dyn_cfg is not None else None

    # the fault stream: a SECOND independent child, spawned ONLY when a
    # failure model is active — spawning advances no dev_rng draws and
    # the fault stream's own draws never touch the other streams, so
    # faults=None runs are bit-identical (test-enforced)
    faults_cfg = faults_lib.resolve_faults(grid.faults)
    if faults_cfg is not None and grid.mode == "sync" \
            and faults_cfg.payload_prob > 0:
        raise ValueError(
            "sync mode supports only crash_compute and server_kill_at "
            "faults — payload faults (truncate/corrupt/duplicate) need "
            "the async per-client wire path")
    bfaults = (faults_cfg.bind(dev_rng.spawn(1)[0])
               if faults_cfg is not None else None)
    # the shock stream: a THIRD independent child, spawned ONLY when
    # correlated region shocks are configured — same hygiene as the
    # fault stream, so shock-free runs see identical streams everywhere
    shocks_cfg = dyn_cfg.shocks if dyn_cfg is not None else None
    if shocks_cfg is not None and topo is None:
        raise ValueError(
            "DynamicsConfig.shocks needs a topology (GridConfig."
            "topology): shocks down whole edge regions, and the flat "
            "grid has none")
    bshocks = (shocks_cfg.bind(topo.num_regions, dev_rng.spawn(1)[0],
                               tracer=tracer)
               if shocks_cfg is not None else None)
    san = sanitize_lib.resolve_sanitize(grid.sanitize)
    if grid.checkpoint_every > 0 and not grid.checkpoint_dir:
        raise ValueError("checkpoint_every > 0 needs a checkpoint_dir")

    # cohort-selection policy: estimates feed bandwidth-aware inclusion
    # probabilities and seed the adaptive policy's observed-RTT EMA
    policy = sel_lib.resolve_policy(grid.selection)
    est_up = (tier_up[tier_of_client] if cplan is not None
              else np.full(N, up_bytes, np.int64))
    est_comp = (tier_compute[tier_of_client] if cplan is not None
                else np.full(N, compute_seconds, np.float64))
    # one array op over the FleetState struct-of-arrays — the former
    # per-profile listcomp was O(N) Python objects per run and dominated
    # startup at 10^5+ clients (benchmarks/fleet_bench.py)
    rtt_estimate = np.asarray(
        fleet.state.round_trip_seconds(down_bytes, est_up, est_comp),
        np.float64)
    policy.bind(fleet=fleet, num_clients=N, cplan=cplan,
                tiers=tier_of_client, rtt_estimate=rtt_estimate)

    common = dict(fleet=fleet, report=report, down_bytes=down_bytes,
                  up_bytes=up_bytes, compute_seconds=compute_seconds,
                  data_rng=data_rng, dev_rng=dev_rng, seed=seed,
                  data_kind=data_kind, eval_every=eval_every,
                  eval_fn=eval_fn, log=log, cplan=cplan,
                  tier_of_client=tier_of_client, tier_up=tier_up,
                  tier_compute=tier_compute, dyn=dyn, dyn_rng=dyn_rng,
                  policy=policy, registry=registry, tracer=tracer,
                  profile=profile, bfaults=bfaults, san=san,
                  topo=topo, bshocks=bshocks)
    if grid.mode == "sync":
        return _run_sync(y, frozen, loss_fn, dataset, rc, rounds, grid,
                         server_opt, **common)
    if grid.mode == "async":
        return _run_async(y, frozen, loss_fn, dataset, rc, rounds, grid,
                          server_opt, **common)
    raise ValueError(f"unknown grid mode {grid.mode!r} "
                     "(expected 'sync' or 'async')")


# ---------------------------------------------------------------------------
# Synchronous cohorts


# the normalized scheduler-stats schema: BOTH modes emit every key,
# with explicit zeros where a counter cannot fire (sync never retries
# in-flight dispatches; async has no over-selection excess and no
# availability-draw offline stage; sync supports only the crash fault)
# — regression-tested
STAT_KEYS = ("dispatches", "uploads", "offline", "dropouts",
             "deadline_drops", "excess", "retries",
             "crashes", "truncated", "corrupted", "duplicates",
             "quarantined")


def _stats_view(registry: metrics_lib.MetricsRegistry) -> Dict[str, int]:
    """GridResult.scheduler_stats as a dict view over the metrics
    registry — the registry is the one source of truth, this is its
    stable-schema rendering."""
    return {k: int(registry.counter(k).value) for k in STAT_KEYS}


def _tier_stats(report, cplan, tier_of_client,
                registry: metrics_lib.MetricsRegistry):
    """GridResult.tier_stats: the comm ledger's per-tier traffic plus
    the fleet census (how many clients each tier owns — the run's final
    tier map, which rotation/adaptive policies move over time), the
    tier's compute charge per local run, and the mean observed
    round-trip of its uploads. Timing/compute columns are read from the
    metrics registry (labels = tier indices), the wire columns from the
    comm ledger."""
    if cplan is None:
        return None
    rtt_sum = registry.counter("tier_rtt_sum")
    rtt_n = registry.counter("tier_rtt_n")
    compute = registry.gauge("tier_compute")
    out = {}
    for t in cplan.tiers:
        rec = dict(report.tier_traffic.get(
            t.name, {"down_bytes": 0, "up_bytes": 0, "transfers": 0,
                     "uploads": 0}))
        rec["clients"] = int(np.sum(tier_of_client == t.index))
        # measured wire cost per upload (int8-aware), matching
        # CommReport.tier_table(); the analytic fp32 slice size keeps
        # its own key
        rec["up_bytes_per_upload"] = (rec["up_bytes"] / rec["uploads"]
                                      if rec["uploads"] else 0.0)
        rec["trainable_bytes"] = t.trainable_bytes
        # per-tier virtual compute charge (reference device, one
        # dispatch): base compute scaled by the trainable fraction
        rec["compute_seconds"] = float(compute.get(t.index, 0.0))
        n = rtt_n.get(t.index, 0)
        rec["rtt_mean"] = (rtt_sum.get(t.index, 0.0) / n) if n else 0.0
        out[t.name] = rec
    return out


def _run_sync(y, frozen, loss_fn, dataset, rc, rounds, grid, server_opt, *,
              fleet, report, down_bytes, up_bytes, compute_seconds,
              data_rng, dev_rng, seed, data_kind, eval_every, eval_fn, log,
              cplan, tier_of_client, tier_up, tier_compute, dyn, dyn_rng,
              policy, registry, tracer, profile, bfaults, san, topo,
              bshocks):
    mesh = mesh_lib.resolve_mesh(grid.mesh)
    constrain_flat = shard_lib.flat_constrainer(mesh) if mesh else None
    constrain_batch = shard_lib.cohort_constrainer(mesh) if mesh else None
    # a trivial (one-tier, nothing-extra-frozen) plan routes through the
    # exact pre-plan engine: same trace, same history, bit for bit
    tiered = cplan is not None and not cplan.trivial
    round_fn, sopt = fedpt.make_round_fn(loss_fn, rc, server_opt=server_opt,
                                         constrain_flat_fn=constrain_flat,
                                         constrain_batch_fn=constrain_batch,
                                         plan=cplan, sanitize=san,
                                         fused_threshold=grid.agg_tail_threshold)
    round_fn = prof_lib.annotate(jax.jit(round_fn, donate_argnums=(0, 1)),
                                 "grid/round_fn", enabled=profile)
    sstate = sopt.init(y)
    N = num_clients(dataset)
    C = rc.clients_per_round
    m = min(N, max(C, int(math.ceil(C * grid.over_selection))))
    # one pre-reduced fp32 flat buffer per active edge per round
    # (shape-determined, so measured once)
    edge_bytes = wire.edge_flush_bytes(y) if topo is not None else 0

    # every live RNG stream a snapshot must capture (the fault stream
    # only exists when a failure model is active)
    rngs = {"data": data_rng, "dev": dev_rng, "dyn": dyn_rng}
    if bfaults is not None:
        rngs["fault"] = bfaults.rng

    history: List[Dict[str, float]] = []
    mc = registry.counter
    vt = 0.0
    start_round = 0
    last_ckpt: Optional[str] = None
    if grid.resume_from:
        meta, arrays = gstate_lib.load_state(grid.resume_from)
        y, sstate, start_round, vt, history = gstate_lib.decode_sync(
            meta, arrays, sstate_template=sstate, rngs=rngs,
            policy=policy, registry=registry, report=report,
            shocks=bshocks, topo=topo)
        last_ckpt = grid.resume_from
    t0 = None
    for r in range(start_round, rounds):
        if bfaults is not None and vt > bfaults.kill_at:
            raise faults_lib.ServerKilled(at=vt, applied=r,
                                          checkpoint=last_ckpt)
        # the policy's tier map can move between rounds (tier-rotation,
        # adaptive-capability); static policies return the bound map
        tiers_now = policy.current_tiers() if cplan is not None else None
        cids = policy.select_cohort(data_rng, m)
        # tier-sliced uplink payloads + per-tier compute feed the
        # virtual clock: a lite client's smaller delta clears the
        # 0.25 MB/s uplink sooner AND its backward pass is cheaper
        cohort_up = (tier_up[tiers_now[cids]] if cplan is not None
                     else up_bytes)
        cohort_comp = (tier_compute[tiers_now[cids]] if cplan is not None
                       else compute_seconds)
        cohort_regions = topo.region_of[cids] if topo is not None else None
        plan = sched_lib.plan_sync_round(
            fleet, cids, down_bytes, cohort_up, cohort_comp, C, dev_rng,
            deadline=grid.straggler_deadline, dynamics=dyn,
            dyn_rng=dyn_rng, now=vt, tracer=tracer,
            tiers=tiers_now[cids] if cplan is not None else None,
            faults=bfaults, shocks=bshocks, regions=cohort_regions)
        # the C slots the compiled round engine sees: participants in
        # arrival order, padded (weight 0) with the remaining cohort in
        # dispatch order when drops leave the round short
        kept_cids = plan.participant_cids()
        pad = plan.cids[~plan.participant][:C - len(kept_cids)]
        sel = np.concatenate([kept_cids, pad]).astype(np.int64)
        kept = np.arange(C) < len(kept_cids)

        batch, w = syn.cohort_batch(dataset, sel, rc.local_steps,
                                    rc.local_batch, data_rng, kind=data_kind)
        w = np.where(kept, w, 0.0).astype(np.float32)
        if not policy.trivial and not (rc.uniform_weights
                                       or rc.dp_clip_norm > 0):
            # importance-unbiased selection weights multiply into the
            # aggregation weights; under DP the engine forces uniform
            # weighting with a fixed denominator (sigma calibration),
            # so the correction is dropped there by design
            iw = policy.cohort_weights(sel)
            if iw is not None:
                w = (w * iw).astype(np.float32)
        args = (y, sstate, frozen, batch, jnp.asarray(w))
        if tiered:
            args += (jnp.asarray(tiers_now[sel], jnp.int32),)
        y, sstate, rmetrics = round_fn(*args,
                                       jax.random.key(seed * 100_003 + r))
        if t0 is None:
            jax.block_until_ready(y)
            t0 = time.time()  # exclude compile from the per-round timing
        vt0, vt = vt, vt + plan.round_seconds
        # the round span goes out as soon as its wall time is known —
        # before the quarantine/billing/edge instants it causally
        # precedes, so they can parent on it. Its own parent is the
        # upload that closed the round (plan.bound_seq), which links
        # round -> bounding upload -> dispatch for analyze.py's
        # critical-path walk.
        rseq = tracer.span("round", vt0, plan.round_seconds,
                           parent=plan.bound_seq, round=r,
                           participants=float(len(kept_cids)),
                           cohort=int(m), loss=float(rmetrics["loss"]))
        if san is not None:
            # quarantined cohort rows -> traced events + counter (the
            # masks are tiny (C,) vectors; one host sync per round)
            nonf = np.asarray(rmetrics["quarantine_nonfinite"])
            outl = np.asarray(rmetrics["quarantine_outlier"])
            norms = np.asarray(rmetrics["quarantine_norms"])
            for i in np.nonzero(nonf | outl)[0]:
                mc("quarantined").inc()
                tracer.instant(
                    "quarantine", vt0, parent=rseq,
                    cause="nonfinite" if nonf[i] else "norm-outlier",
                    cid=int(sel[i]),
                    tier=(int(tiers_now[sel[i]]) if cplan is not None
                          else None),
                    norm=float(norms[i]), round=r)
        registry.histogram("round_seconds").observe(plan.round_seconds)
        n_dispatched = int(np.sum(plan.dispatched))
        n_uploads = n_dispatched - plan.dropouts
        # observed round trips flow back to the policy (adaptive
        # re-tiering) and into the per-tier timing stats
        for i in np.nonzero(plan.completed)[0]:
            rtt = float(plan.arrival[i])
            policy.observe(int(plan.cids[i]), rtt)
            registry.histogram("upload_rtt").observe(rtt)
            if cplan is not None:
                t_idx = int(tiers_now[plan.cids[i]])
                mc("tier_rtt_sum").inc(rtt, label=t_idx)
                mc("tier_rtt_n").inc(label=t_idx)
        if cplan is not None:
            # bill per tier: dispatches pay the (tier-invariant)
            # downlink, uploads pay the tier-sliced uplink
            cohort_tiers = tiers_now[plan.cids]
            uploaded = np.isfinite(plan.arrival)
            for t in cplan.tiers:
                sel_t = cohort_tiers == t.index
                nd = int(np.sum(plan.dispatched & sel_t))
                nu = int(np.sum(uploaded & sel_t))
                if nd or nu:
                    report.add_tier_measured(
                        t.name, down_bytes * nd, int(tier_up[t.index]) * nu,
                        transfers=nd, uploads=nu, now=vt, parent=rseq)
        else:
            report.add_measured(down_bytes * n_dispatched,
                                up_bytes * n_uploads,
                                transfers=n_dispatched)
        if topo is not None:
            # hierarchical hop billing: every region with a dispatch
            # downloads one model payload server->edge (the edge fans it
            # out on the client hop); every region with a completed
            # upload pre-reduces its members' deltas and flushes one
            # flat buffer upstream
            disp_counts = np.bincount(cohort_regions[plan.dispatched],
                                      minlength=topo.num_regions)
            up_counts = np.bincount(cohort_regions[plan.completed],
                                    minlength=topo.num_regions)
            for k in np.nonzero(disp_counts)[0]:
                mc("region_dispatches").inc(int(disp_counts[k]),
                                            label=int(k))
            active = np.nonzero(up_counts)[0]
            for k in active:
                mc("region_uploads").inc(int(up_counts[k]), label=int(k))
                mc("edge_flushes").inc(label=int(k))
                mc("edge_up_bytes").inc(edge_bytes, label=int(k))
                tracer.instant("edge_flush", vt, parent=rseq,
                               region=int(k), fill=int(up_counts[k]),
                               up_bytes=edge_bytes, round=r)
            n_down = int(np.sum(disp_counts > 0))
            report.add_hop("edge_server", down_bytes=down_bytes * n_down,
                           up_bytes=edge_bytes * len(active),
                           transfers=n_down, uploads=len(active))
        mc("dispatches").inc(n_dispatched)
        mc("uploads").inc(n_uploads)
        mc("offline").inc(plan.offline)
        mc("dropouts").inc(plan.dropouts)
        mc("deadline_drops").inc(plan.deadline_drops)
        mc("excess").inc(plan.excess)
        mc("retries").inc(plan.retries)
        mc("crashes").inc(plan.crashes)

        rec = {"round": r, "loss": float(rmetrics["loss"])}
        if eval_fn and eval_every and (r + 1) % eval_every == 0:
            rec.update(eval_fn(part.merge(y, frozen)))
        rec["virtual_seconds"] = vt
        rec["participants"] = float(len(kept_cids))
        history.append(rec)
        policy.end_round(r)
        if grid.checkpoint_every > 0 \
                and (r + 1) % grid.checkpoint_every == 0:
            meta, arrays = gstate_lib.encode_sync(
                y=y, sstate=sstate, round_idx=r, now=vt, history=history,
                rngs=rngs, policy=policy, registry=registry, report=report,
                shocks=bshocks, topo=topo)
            last_ckpt = gstate_lib.save_state(
                gstate_lib.checkpoint_path(grid.checkpoint_dir, r + 1,
                                           "sync"), meta, arrays)
            mc("checkpoints").inc()
            tracer.instant("checkpoint", vt, parent=rseq, path=last_ckpt,
                           round=r, mode="sync")
        if log and (r % max(1, rounds // 10) == 0):
            print(f"  round {r}: " + " ".join(
                f"{k}={v:.4f}" for k, v in rec.items() if k != "round"))
    jax.block_until_ready(y)
    spr = (time.time() - t0) / max(rounds - start_round - 1, 1) \
        if t0 else float("nan")
    final_tiers = (policy.current_tiers() if cplan is not None
                   else tier_of_client)
    if tracer.enabled:
        tracer.flush_outputs()
    return GridResult(y=y, frozen=frozen, history=history, comm=report,
                      seconds_per_round=spr, virtual_seconds=vt,
                      fleet=fleet, mode="sync",
                      scheduler_stats=_stats_view(registry),
                      tier_stats=_tier_stats(report, cplan, final_tiers,
                                             registry),
                      plan=cplan, policy=policy, dynamics=dyn,
                      topology=topo, metrics=registry,
                      telemetry=tracer if tracer.enabled else None,
                      faults=_faults_view(registry, bfaults))


def _faults_view(registry: metrics_lib.MetricsRegistry,
                 bfaults) -> Optional[Dict[str, int]]:
    """GridResult.faults: the fired-fault counters, when a failure model
    was active (quarantined rows ride along — they are the sanitize
    screen's answer to the corruption faults)."""
    if bfaults is None:
        return None
    return {k: int(registry.counter(k).value)
            for k in ("crashes", "truncated", "corrupted", "duplicates",
                      "quarantined")}


# ---------------------------------------------------------------------------
# Buffered async (FedBuff)


class _LaneCell:
    """Handle for a client step deferred into a lane batch: filled with
    this client's own (delta row, loss) when the lane executes — rows
    are sliced out at fill time so a straggler entry keeps one (size,)
    row alive, not the whole (lane, size) batch."""
    __slots__ = ("delta", "loss")

    def __init__(self):
        self.delta = None

    def resolve(self):
        return self.delta, self.loss


def _run_async(y, frozen, loss_fn, dataset, rc, rounds, grid, server_opt, *,
               fleet, report, down_bytes, up_bytes, compute_seconds,
               data_rng, dev_rng, seed, data_kind, eval_every, eval_fn, log,
               cplan, tier_of_client, tier_up, tier_compute, dyn, dyn_rng,
               policy, registry, tracer, profile, bfaults, san, topo,
               bshocks):
    if server_opt is None:
        server_opt = fedpt.resolve_server_opt(rc)
    # trivial plans keep the pre-plan engine (lane-exact acceptance);
    # per-tier metering still runs off the scheduler's tier counters
    tiered = cplan is not None and not cplan.trivial
    # per-flush DP: the flush (goal_count buffered deltas, fixed
    # denominator) is the unit of composition — see core/dp.py
    flush_dp = accountant = None
    if rc.dp_noise_multiplier > 0:
        if rc.dp_clip_norm <= 0:
            raise ValueError("async DP noise needs dp_clip_norm > 0 "
                             "(per-client clipping bounds the flush "
                             "sensitivity)")
        flush_dp = dp_lib.FlushDPConfig(
            clip_norm=rc.dp_clip_norm,
            noise_multiplier=rc.dp_noise_multiplier,
            goal_count=grid.goal_count)
        accountant = dp_lib.FlushAccountant(flush_dp, tracer=tracer)
    mesh = mesh_lib.resolve_mesh(grid.mesh)
    constrain_flat = shard_lib.flat_constrainer(mesh) if mesh else None
    lane = grid.goal_count if grid.lanes is None else int(grid.lanes)
    # one engine per tier: lanes are tier-homogeneous (pending clients
    # group by tier below), so each tier's lane step traces exactly once
    # at its own (lane, tier_size) width
    tier_keys = [t.index for t in cplan.tiers] if tiered else [None]
    if lane > 0:
        lane_steps = {
            k: jax.jit(fedpt.make_lane_step(
                loss_fn, rc, lane, constrain_flat_fn=constrain_flat,
                tier=None if k is None else cplan.tiers[k],
                plan=None if k is None else cplan))
            for k in tier_keys}
        # jax.profiler annotations around the jitted hot paths so a
        # wall-time profile lines up with the virtual-time spans
        lane_steps = prof_lib.annotate_map(lane_steps, "grid/lane_step",
                                           enabled=profile)
    else:
        client_steps = {
            k: jax.jit(fedpt.make_client_step(
                loss_fn, rc,
                tier=None if k is None else cplan.tiers[k],
                plan=None if k is None else cplan))
            for k in tier_keys}
        client_steps = prof_lib.annotate_map(client_steps,
                                             "grid/client_step",
                                             enabled=profile)
    apply_fn = prof_lib.annotate(
        jax.jit(fedpt.make_buffered_apply(
            server_opt, flush_dp=flush_dp, constrain_flat_fn=constrain_flat,
            plan=cplan, sanitize=san,
            fused_threshold=grid.agg_tail_threshold), donate_argnums=(0, 1)),
        "grid/server_apply", enabled=profile)
    staleness_fn = fedpt.get_staleness_fn(grid.staleness, **grid.staleness_kw)
    if flush_dp is not None:
        # the per-flush sensitivity bound (clip_norm / goal_count)
        # assumes aggregation weights in [0, 1]; a custom staleness fn
        # exceeding 1 would silently invalidate the reported epsilon
        inner_staleness = staleness_fn

        def staleness_fn(s):
            w = inner_staleness(s)
            if not 0.0 <= w <= 1.0:
                raise ValueError(
                    f"staleness weight {w} for staleness {s} is outside "
                    "[0, 1]: per-flush DP calibrates sigma for weights "
                    "<= 1 (use a non-amplifying staleness_fn with DP)")
            return w
    N = num_clients(dataset)
    batch_fn = (syn.client_batch_images if data_kind == "images"
                else syn.client_batch_tokens)
    # one pre-reduced fp32 flat buffer per active edge per flush
    # (shape-determined, so measured once)
    edge_bytes = wire.edge_flush_bytes(y) if topo is not None else 0

    # mutable server state shared with the scheduler callbacks; events are
    # processed in virtual-time order, so "the model right now" is exactly
    # what a client dispatched at the current event time downloads
    state = {"y": y, "sstate": server_opt.init(y), "applied": 0}
    # lane mode: client steps dispatched since the last flush, grouped
    # by trainability tier (each group is one lane batch at its tier's
    # width). They all trained on the model of the CURRENT server
    # version (y only changes at flushes), so deferring them until the
    # next flush and running them as (lane, ...) batches is exactly the
    # sequential semantics — their completion times never depend on when
    # the compute runs.
    pending: Dict[Any, List] = {k: [] for k in tier_keys}

    def run_pending():
        for key, queue in pending.items():
            while queue:
                chunk = queue[:lane]
                del queue[:len(chunk)]
                n = len(chunk)
                # pad short lanes with a repeat of the last real batch:
                # one fixed (lane, ...) shape -> lane_step never re-traces
                stacked = {k: np.stack([b[k] for b, _ in chunk]
                                       + [chunk[-1][0][k]] * (lane - n))
                           for k in chunk[0][0]}
                deltas, losses = lane_steps[key](state["y"], frozen, stacked)
                for i, (_, cell) in enumerate(chunk):
                    cell.delta, cell.loss = deltas[i], losses[i]

    def sample_cid(rng):
        return policy.sample_cid(rng)

    def tier_of(cid):
        # the policy's map, queried at dispatch time (rotation/adaptive
        # policies move it between server updates)
        return (int(policy.current_tiers()[cid]) if cplan is not None
                else None)

    def run_client(cid, version):
        b, w = batch_fn(dataset, cid, rc.local_steps, rc.local_batch,
                        data_rng)
        if rc.uniform_weights or rc.dp_clip_norm > 0:
            w = 1.0  # DP / uniform weighting, as in the sync engine
        elif not policy.trivial:
            # importance-unbiased selection weight (dropped under DP —
            # the fixed-denominator uniform weighting calibrates sigma)
            w = w * policy.client_weight(cid)
        # payload size is shape-determined: reuse the once-measured
        # (tier-sliced, when a plan is active) value instead of
        # serializing every delta just to count its bytes
        t = tier_of(cid)
        up = int(tier_up[t]) if cplan is not None else up_bytes
        key = t if tiered else None
        if lane > 0:
            cell = _LaneCell()
            pending[key].append((b, cell))
            return {"cell": cell, "weight": w, "up_bytes": up,
                    "cid": cid, "tier": t}
        delta, metrics = client_steps[key](state["y"], frozen, b)
        # loss stays a device scalar: converted once per flush, not per
        # client (a float() here would force a host round-trip per client)
        return {"delta": delta, "loss": metrics["client_loss"],
                "weight": w, "up_bytes": up, "cid": cid, "tier": t}

    def entry_arrays(e):
        cell = e.work.get("cell")
        if cell is not None:
            return cell.resolve()
        return e.work["delta"], e.work["loss"]

    def apply_update(entries, now, version):
        if lane > 0:
            run_pending()
        rows, losses = [], []
        for e in entries:
            d, l = entry_arrays(e)
            f = e.work.get("fault")
            if f is not None and f["kind"] in ("nan", "bitflip"):
                # materialize the wire corruption from the per-event
                # seed (duplicate rows share the work dict and damage
                # identically; the client's reported loss predates the
                # wire, so it stays intact)
                d = jnp.asarray(faults_lib.corrupt_row(
                    np.asarray(d), f["kind"], f["seed"], bfaults.cfg))
            rows.append(d)
            losses.append(l)
        wts = [e.weight for e in entries]
        # pad a short (drained) flush to the fixed goal_count shape with
        # zero-weight rows, so apply_fn never re-traces — and under DP
        # the fixed-denominator mean and per-flush sigma never change
        flat_deltas = flat_lib.pad_rows(jnp.stack(rows), grid.goal_count)
        wts = wts + [0.0] * (grid.goal_count - len(entries))
        args = (state["y"], state["sstate"], flat_deltas,
                jnp.asarray(wts, jnp.float32))
        if tiered:
            # per-row tier ids drive the apply's block masks; padding
            # rows carry tier 0 + weight 0 and fall out of both means
            tids = ([e.work["tier"] for e in entries]
                    + [0] * (grid.goal_count - len(entries)))
            args += (jnp.asarray(tids, jnp.int32),)
        if flush_dp is not None:
            # one PRNG key per flush, from the same stream family as the
            # sync engine's per-round keys
            args += (jax.random.key(seed * 100_003 + state["applied"]),)
            # dispatch samples clients WITH replacement, so one client
            # may own several rows of this flush; the accountant scales
            # that flush's sensitivity by the observed multiplicity
            counts = Counter(e.work["cid"] for e in entries)
            # sched is assigned before run() ever calls this closure;
            # last_flush_seq is the flush instant the scheduler emitted
            # just before invoking us, i.e. this very flush
            accountant.record_flush(len(entries),
                                    multiplicity=max(counts.values()),
                                    now=now,
                                    parent=sched.last_flush_seq)
        y_new, ss, m = apply_fn(*args)
        state["y"], state["sstate"] = y_new, ss
        # ONE host sync per flush for the buffered losses
        out = {"loss": float(jnp.mean(jnp.stack(losses))),
               "delta_norm": float(m["delta_norm"])}
        applied = state["applied"]
        if san is not None:
            # quarantined buffer rows -> traced events + counter (the
            # masks are tiny (K,) vectors, synced with the losses above)
            nonf = np.asarray(m["quarantine_nonfinite"])
            outl = np.asarray(m["quarantine_outlier"])
            norms = np.asarray(m["quarantine_norms"])
            for i in np.nonzero((nonf | outl)[:len(entries)])[0]:
                registry.counter("quarantined").inc()
                w = entries[i].work
                tracer.instant(
                    "quarantine", now, parent=sched.last_flush_seq,
                    cause="nonfinite" if nonf[i] else "norm-outlier",
                    cid=int(w["cid"]),
                    tier=None if w.get("tier") is None else int(w["tier"]),
                    norm=float(norms[i]), flush=applied)
        if topo is not None and entries:
            # edge pre-reduce: this flush's rows grouped by uploader
            # region — each edge's (size,) buffer is what it transmits
            # upstream (billed on the edge_server hop at end of run).
            # The authoritative server reduce above consumed the same
            # rows fused, so the model path is topology-invariant.
            regs = topo.region_of[[int(e.work["cid"]) for e in entries]]
            ebuf = topo_lib.edge_reduce(
                np.asarray(flat_deltas)[:len(entries)],
                np.asarray(wts[:len(entries)], np.float32),
                regs, topo.num_regions)
            counts = np.bincount(regs, minlength=topo.num_regions)
            for k in np.nonzero(counts)[0]:
                registry.counter("edge_flushes").inc(label=int(k))
                registry.counter("edge_up_bytes").inc(edge_bytes,
                                                      label=int(k))
                registry.counter("edge_down_bytes").inc(down_bytes,
                                                        label=int(k))
                tracer.instant("edge_flush", now,
                               parent=sched.last_flush_seq, region=int(k),
                               fill=int(counts[k]), up_bytes=edge_bytes,
                               norm=float(np.linalg.norm(ebuf[k])),
                               flush=applied)
        state["applied"] = applied + 1
        if eval_fn and eval_every and state["applied"] % eval_every == 0:
            out.update(eval_fn(part.merge(y_new, frozen)))
        # a flush is the async "round": rotation/adaptive policies step
        # their tier maps here
        policy.end_round(applied)
        return out

    # every live RNG stream a snapshot must capture (the fault stream
    # only exists when a failure model is active)
    rngs = {"data": data_rng, "dev": dev_rng, "dyn": dyn_rng}
    if bfaults is not None:
        rngs["fault"] = bfaults.rng
    last_ckpt = {"path": None}

    def checkpoint_hook(s, now):
        # called by the scheduler after every full-buffer flush — the
        # one boundary where run_pending() has resolved every lane cell
        if state["applied"] % grid.checkpoint_every != 0:
            return
        meta, arrays = gstate_lib.encode_async(
            state=state, sched=s, rngs=rngs, accountant=accountant,
            policy=policy, registry=registry, shocks=bshocks, topo=topo)
        path = gstate_lib.save_state(
            gstate_lib.checkpoint_path(grid.checkpoint_dir,
                                       state["applied"], "async"),
            meta, arrays)
        last_ckpt["path"] = path
        registry.counter("checkpoints").inc()
        tracer.instant("checkpoint", now, parent=s.last_flush_seq,
                       path=path,
                       applied=state["applied"], mode="async",
                       buffer_fill=float(len(s.buffer)),
                       events_in_flight=len(s.q))

    sched = sched_lib.BufferedAsyncScheduler(
        fleet=fleet, concurrency=min(grid.concurrency, N),
        goal_count=grid.goal_count, staleness_fn=staleness_fn,
        sample_cid=sample_cid, run_client=run_client,
        apply_update=apply_update, down_bytes=down_bytes,
        compute_seconds=compute_seconds, rng=dev_rng,
        tier_of=tier_of if cplan is not None else None,
        compute_of=((lambda cid: float(tier_compute[tier_of(cid)]))
                    if cplan is not None else None),
        region_of=((lambda cid: int(topo.region_of[cid]))
                   if topo is not None else None),
        shocks=bshocks,
        dynamics=dyn, dyn_rng=dyn_rng, observe=policy.observe,
        tracer=tracer, metrics=registry, faults=bfaults,
        checkpoint_hook=(checkpoint_hook if grid.checkpoint_every > 0
                         else None))
    if grid.resume_from:
        gstate_lib.decode_async(
            *gstate_lib.load_state(grid.resume_from), state=state,
            sched=sched, sstate_template=state["sstate"], rngs=rngs,
            accountant=accountant, policy=policy, registry=registry,
            shocks=bshocks, topo=topo,
            make_cell=_LaneCell if lane > 0 else None)
        last_ckpt["path"] = grid.resume_from
    t_wall = time.time()
    try:
        history = sched.run(rounds, deadline=grid.async_deadline)
    except faults_lib.ServerKilled as e:
        # annotate the kill with the latest snapshot so callers can
        # resume (None when checkpointing was off)
        e.checkpoint = last_ckpt["path"]
        raise
    spr = (time.time() - t_wall) / max(rounds, 1)
    if log:
        for rec in history[:: max(1, rounds // 10)]:
            print(f"  update {rec['round']}: " + " ".join(
                f"{k}={v:.4f}" for k, v in rec.items() if k != "round"))

    vt = history[-1]["virtual_seconds"] if history else 0.0
    if cplan is not None:
        for t in cplan.tiers:
            nd = sched.tier_dispatches.get(t.index, 0)
            if nd or sched.tier_uploads.get(t.index, 0):
                report.add_tier_measured(
                    t.name, down_bytes * nd,
                    sched.tier_up_bytes.get(t.index, 0), transfers=nd,
                    uploads=sched.tier_uploads.get(t.index, 0), now=vt,
                    parent=sched.last_flush_seq)
    else:
        report.add_measured(down_bytes * sched.dispatches,
                            sched.up_bytes_total,
                            transfers=sched.dispatches)
    if topo is not None:
        # edge_server hop, billed from the registry's per-region edge
        # counters — the registry is snapshotted/restored with the run,
        # so a resumed run bills this hop exactly
        n_flush = int(registry.counter("edge_flushes").value)
        report.add_hop(
            "edge_server",
            down_bytes=int(registry.counter("edge_down_bytes").value),
            up_bytes=int(registry.counter("edge_up_bytes").value),
            transfers=n_flush, uploads=n_flush)
    final_tiers = (policy.current_tiers() if cplan is not None
                   else tier_of_client)
    if tracer.enabled:
        tracer.flush_outputs()
    return GridResult(y=state["y"], frozen=frozen, history=history,
                      comm=report, seconds_per_round=spr,
                      virtual_seconds=vt, fleet=fleet, mode="async",
                      scheduler_stats=_stats_view(registry),
                      dp=accountant.summary() if accountant else None,
                      tier_stats=_tier_stats(report, cplan, final_tiers,
                                             registry),
                      plan=cplan, policy=policy, dynamics=dyn,
                      topology=topo, metrics=registry,
                      telemetry=tracer if tracer.enabled else None,
                      faults=_faults_view(registry, bfaults))
