"""Wire-level payload serialization for the simulation grid.

The comm ledger (`core/comm.py`) *predicts* payload sizes analytically;
this module actually serializes the FedPT payloads and meters the bytes,
so the grid reports **measured** communication:

* downlink: the trainable tree ``y`` as raw little-endian leaf bytes in
  flatten order, followed by the 8-byte frozen-side seed — everything a
  FedPT client needs (the frozen side is regenerated from the seed);
* uplink: the trainable delta, either raw fp32/native-dtype leaf bytes,
  or (``bits=8``) symmetric int8 quantization via ``core/compress.py`` —
  per leaf, the int8 payload followed by its f32 scale.

For fp32 payloads the measured sizes equal ``CommReport.download_fedpt``
/ ``upload_fedpt`` exactly; for int8 they equal
``compress.quantized_uplink_bytes``. Tests enforce both.
"""
from __future__ import annotations

import dataclasses
import struct
from typing import Any, List, Tuple

import jax
import numpy as np

from repro.core import comm, compress

SEED_BYTES = comm.SEED_BYTES
_SEED_FMT = "<q"   # int64 little-endian == 8 bytes
_SCALE_FMT = "<f"  # one f32 scale per quantized leaf
assert struct.calcsize(_SEED_FMT) == SEED_BYTES
assert struct.calcsize(_SCALE_FMT) == compress.SCALE_BYTES


@dataclasses.dataclass(frozen=True)
class TreeSpec:
    """Shape/dtype template both endpoints share out-of-band (it is part
    of the model architecture, not of any per-round payload)."""
    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[np.dtype, ...]

    @classmethod
    def of(cls, tree) -> "TreeSpec":
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        return cls(treedef=treedef,
                   shapes=tuple(tuple(l.shape) for l in leaves),
                   dtypes=tuple(np.dtype(l.dtype) for l in leaves))

    def unflatten(self, leaves: List[np.ndarray]):
        return jax.tree_util.tree_unflatten(self.treedef, leaves)


def _np_leaves(tree) -> List[np.ndarray]:
    return [np.asarray(l) for l in jax.tree_util.tree_leaves(tree)]


# ---------------------------------------------------------------------------
# Downlink: trainable y + seed


def encode_downlink(y, seed: int) -> bytes:
    parts = [l.tobytes() for l in _np_leaves(y)]
    parts.append(struct.pack(_SEED_FMT, int(seed)))
    return b"".join(parts)


def decode_downlink(buf: bytes, spec: TreeSpec):
    """Returns (y, seed)."""
    leaves, off = [], 0
    for shape, dtype in zip(spec.shapes, spec.dtypes):
        n = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
        leaves.append(np.frombuffer(buf, dtype, count=int(np.prod(
            shape, dtype=np.int64)), offset=off).reshape(shape))
        off += n
    (seed,) = struct.unpack_from(_SEED_FMT, buf, off)
    off += SEED_BYTES
    if off != len(buf):
        raise ValueError(f"trailing bytes in downlink payload: "
                         f"{len(buf) - off}")
    return spec.unflatten(leaves), int(seed)


# ---------------------------------------------------------------------------
# Uplink: trainable delta, raw or int8-quantized


def encode_uplink(delta, bits: int = 0) -> bytes:
    if bits == 0:
        return b"".join(l.tobytes() for l in _np_leaves(delta))
    if bits != 8:
        raise ValueError("wire serialization supports fp32 (bits=0) or "
                         f"int8 (bits=8) uplinks, got bits={bits}")
    parts = []
    for leaf in jax.tree_util.tree_leaves(delta):
        q, scale = compress.quantize_leaf(leaf, bits)
        parts.append(np.asarray(q).tobytes())
        parts.append(struct.pack(_SCALE_FMT, float(scale)))
    return b"".join(parts)


def decode_uplink(buf: bytes, spec: TreeSpec, bits: int = 0):
    """Inverse of encode_uplink; int8 payloads come back dequantized to
    float32 (the server aggregates in f32 anyway)."""
    leaves, off = [], 0
    for shape, dtype in zip(spec.shapes, spec.dtypes):
        n_elems = int(np.prod(shape, dtype=np.int64))
        if bits == 0:
            leaves.append(np.frombuffer(buf, dtype, count=n_elems,
                                        offset=off).reshape(shape))
            off += n_elems * dtype.itemsize
        else:
            q = np.frombuffer(buf, np.int8, count=n_elems,
                              offset=off).reshape(shape)
            off += n_elems
            (scale,) = struct.unpack_from(_SCALE_FMT, buf, off)
            off += compress.SCALE_BYTES
            leaves.append(q.astype(np.float32) * scale)
    if off != len(buf):
        raise ValueError(f"trailing bytes in uplink payload: "
                         f"{len(buf) - off}")
    return spec.unflatten(leaves)


# ---------------------------------------------------------------------------
# Metering


def downlink_bytes(y) -> int:
    """Measured downlink payload size (serializes once; the size is
    value-independent, so callers may cache per round shape)."""
    return len(encode_downlink(y, 0))


def uplink_bytes(delta, bits: int = 0) -> int:
    return len(encode_uplink(delta, bits))


def edge_flush_bytes(y) -> int:
    """Edge->server payload under a two-level topology
    (``sim/topology.py``): one region's pre-reduced flat delta buffer,
    serialized fp32 (edges aggregate dequantized rows, so the int8
    client-hop compression never rides this hop) — no seed, the server
    already has the architecture out-of-band."""
    return len(encode_uplink(y, bits=0))


def tier_payloads(y, cplan, bits: int = 0) -> dict:
    """Per-tier wire payload sizes under a trainability plan:
    ``{tier name: {"down": bytes, "up": bytes}}``.

    Uplink is the tier's *sliced* delta — only the leaves the tier
    trains are serialized (measured for fp32/int8, analytic int-k
    otherwise). Downlink is tier-invariant: every tier downloads the
    full trainable tree + seed, because blocks a tier froze are still
    trained by other tiers and cannot be regenerated from the seed.
    """
    down = downlink_bytes(y)
    out = {}
    for t in cplan.tiers:
        y_t, _ = cplan.split(y, t)
        if bits in (0, 8):
            up = uplink_bytes(y_t, bits=bits)
        else:
            up = compress.quantized_uplink_bytes(y_t, bits)
        out[t.name] = {"down": down, "up": up}
    return out


def assert_matches_analytic(y, frozen, uplink_bits: int = 0) -> None:
    """Cross-check: measured wire bytes == the analytic ledger. Raises
    AssertionError on drift (used by tests and the grid's paranoia mode)."""
    rep = comm.report_for(y, frozen, uplink_bits=uplink_bits)
    down = downlink_bytes(y)
    up = uplink_bytes(y, bits=uplink_bits)
    if down != rep.download_fedpt:
        raise AssertionError(f"downlink measured {down} != analytic "
                             f"{rep.download_fedpt}")
    if up != rep.upload_fedpt:
        raise AssertionError(f"uplink measured {up} != analytic "
                             f"{rep.upload_fedpt}")
