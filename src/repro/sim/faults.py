"""Deterministic fault injection for the simulation grid.

The grid's failure model so far was *clean*: a dispatched client either
uploads a well-formed delta or drops out silently. Real cross-device
fleets fail messier — clients die mid-compute, uploads truncate on a
dropped link, payloads arrive corrupted (bad flash, bad RAM, bad actors),
retransmits deliver the same delta twice, and the *server* restarts
mid-run. This module injects all of those, deterministically:

* :class:`FaultConfig` — per-dispatch fault probabilities (crash mid-
  compute, upload truncation, NaN/Inf corruption, bit-flipped segments,
  duplicate delivery) plus a server kill at virtual time T.
* :class:`BoundFaults` — the config bound to its own RNG stream. The
  stream is a ``spawn`` child of the device stream (PR 5's hygiene
  rule): spawning advances **zero** draws of the parent, and each
  dispatch consumes a *fixed count* of fault-stream draws, so
  ``faults=None`` is bit-identical to the pre-fault grid and a
  corruption-only config never moves the dispatch clock (test-enforced).
* :func:`corrupt_row` — applies a drawn payload corruption to one flat
  delta row, re-seeded from the per-event corruption seed so a restored
  checkpoint replays the exact same damage.
* :class:`ServerKilled` — raised when the virtual clock crosses
  ``server_kill_at``; the grid annotates it with the last grid-state
  checkpoint path so callers can resume.

Payload corruptions (truncate/NaN/bitflip/duplicate) act on the async
path's materialized flat rows; the sync engine computes deltas inside
one jitted cohort step and has no per-client wire payload to damage, so
sync supports crash + server-kill only and rejects payload faults
loudly.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Union

import numpy as np


class ServerKilled(RuntimeError):
    """The virtual clock crossed ``FaultConfig.server_kill_at``.

    ``at`` is the virtual time of the event that crossed the kill line,
    ``applied`` the number of server updates applied before death, and
    ``checkpoint`` (set by the grid) the latest grid-state snapshot to
    resume from (``None`` when no checkpoint was ever written)."""

    def __init__(self, at: float, applied: int,
                 checkpoint: Optional[str] = None):
        self.at = float(at)
        self.applied = int(applied)
        self.checkpoint = checkpoint
        super().__init__(
            f"server killed at virtual t={self.at:.1f}s after "
            f"{self.applied} applied updates"
            + (f" (resume from {checkpoint})" if checkpoint else ""))


# the async upload-time fault kinds, in cumulative-probability order (one
# uniform per dispatch is partitioned over these edges)
_KINDS = ("crash", "truncate", "nan", "bitflip", "duplicate")


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Per-dispatch fault probabilities and the server-kill time.

    At most one fault fires per dispatch (the five probabilities
    partition one uniform draw, so they must sum to <= 1):

    ``crash_compute``
        the client dies after the download + ``crash_frac`` of its
        local compute — it consumed downlink and battery but never
        uploads (both modes);
    ``truncate_upload``
        the upload cuts off partway: the server receives (and bills) a
        fraction of the bytes, detects the length mismatch and drops
        the delta before buffering (async only);
    ``corrupt_nan``
        a random subset of ``nan_frac`` of the row's elements arrives
        as NaN/±Inf (async only);
    ``corrupt_bitflip``
        the top exponent bit of a contiguous ``bitflip_frac`` segment
        is flipped — finite-but-astronomical values that pure
        ``isfinite`` screens miss (async only);
    ``duplicate_upload``
        the delta is delivered twice (retransmit after a lost ack);
        both copies buffer and both bill uplink bytes (async only).

    ``server_kill_at`` kills the *server* at that virtual time by
    raising :class:`ServerKilled` — the crash-recovery half of the
    fault model (pair with ``GridConfig.checkpoint_every``).
    """

    crash_compute: float = 0.0
    truncate_upload: float = 0.0
    corrupt_nan: float = 0.0
    corrupt_bitflip: float = 0.0
    duplicate_upload: float = 0.0
    server_kill_at: float = math.inf
    # corruption shape knobs
    nan_frac: float = 0.02        # fraction of elements poisoned (nan)
    bitflip_frac: float = 0.01    # fraction of elements bit-flipped
    crash_frac: float = 0.5       # fraction of compute done before a crash
    min_truncate_frac: float = 0.1  # at least this fraction of bytes arrive

    def __post_init__(self):
        for name in ("crash_compute", "truncate_upload", "corrupt_nan",
                     "corrupt_bitflip", "duplicate_upload"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name}={v} is not a probability")
        if self.prob_total > 1.0:
            raise ValueError(f"fault probabilities sum to "
                             f"{self.prob_total} > 1 (at most one fault "
                             "fires per dispatch)")
        if self.server_kill_at <= 0:
            raise ValueError("server_kill_at must be a positive virtual "
                             "time (inf = never)")
        for name in ("nan_frac", "bitflip_frac", "crash_frac",
                     "min_truncate_frac"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name}={v} must lie in [0, 1]")

    @property
    def prob_total(self) -> float:
        return (self.crash_compute + self.truncate_upload + self.corrupt_nan
                + self.corrupt_bitflip + self.duplicate_upload)

    @property
    def payload_prob(self) -> float:
        """Probability mass on upload-payload faults (async only)."""
        return (self.truncate_upload + self.corrupt_nan
                + self.corrupt_bitflip + self.duplicate_upload)

    @property
    def trivial(self) -> bool:
        return self.prob_total == 0.0 and math.isinf(self.server_kill_at)

    def bind(self, rng: np.random.Generator) -> "BoundFaults":
        return BoundFaults(self, rng)


class BoundFaults:
    """A FaultConfig bound to its own RNG stream (a spawn child of the
    device stream — zero parent draws). ``draw()`` consumes exactly two
    fault-stream draws per async dispatch; ``crash_draws(m)`` consumes
    exactly ``m`` per sync round — fixed counts, so the stream position
    is outcome-independent and checkpoint/resume replays it exactly."""

    def __init__(self, cfg: FaultConfig, rng: np.random.Generator):
        self.cfg = cfg
        self.rng = rng
        c = cfg
        self._edges = np.cumsum([c.crash_compute, c.truncate_upload,
                                 c.corrupt_nan, c.corrupt_bitflip,
                                 c.duplicate_upload])

    @property
    def kill_at(self) -> float:
        return self.cfg.server_kill_at

    def draw(self) -> Optional[Dict[str, Any]]:
        """One per-dispatch fault decision: ``None`` (no fault) or
        ``{"kind", "seed"[, "frac"]}``. Always two draws — a uniform for
        the kind and a 63-bit per-event corruption seed — regardless of
        the outcome."""
        u = self.rng.random()
        seed = int(self.rng.integers(0, 2**63 - 1))
        k = int(np.searchsorted(self._edges, u, side="right"))
        if k >= len(_KINDS) or u >= self._edges[-1]:
            return None
        kind = _KINDS[k]
        fault: Dict[str, Any] = {"kind": kind, "seed": seed}
        if kind == "truncate":
            # derive the arriving fraction from the event seed (no
            # further parent-stream draws)
            r = np.random.default_rng(seed)
            lo = self.cfg.min_truncate_frac
            fault["frac"] = float(lo + (0.9 - lo) * r.random())
        return fault

    def crash_draws(self, m: int) -> np.ndarray:
        """Fixed-count sync-round draws: ``crashed[i]`` for each cohort
        member (the only fault kind the sync engine supports)."""
        return self.rng.random(m) < self.cfg.crash_compute


def corrupt_row(row: np.ndarray, kind: str, seed: int,
                cfg: FaultConfig) -> np.ndarray:
    """Apply a drawn payload corruption to one flat fp32 delta row.

    Deterministic in ``seed`` (the per-event corruption seed), so a
    resumed run replays byte-identical damage. ``nan`` scatters NaN/±Inf
    over a random ``nan_frac`` subset; ``bitflip`` XORs the top exponent
    bit of a contiguous ``bitflip_frac`` segment — for |x| < 2 that
    sends the value to ~1e38/Inf territory, the norm-outlier screen's
    clientele."""
    out = np.array(row, np.float32, copy=True)
    n = out.size
    if n == 0:
        return out
    r = np.random.default_rng(seed)
    if kind == "nan":
        k = min(n, max(1, int(cfg.nan_frac * n)))
        idx = r.choice(n, size=k, replace=False)
        vals = r.random(k)
        out[idx] = np.where(vals < 0.5, np.float32(np.nan),
                            np.where(vals < 0.75, np.float32(np.inf),
                                     np.float32(-np.inf)))
    elif kind == "bitflip":
        k = min(n, max(1, int(cfg.bitflip_frac * n)))
        start = int(r.integers(0, n))
        idx = (start + np.arange(k)) % n
        bits = out.view(np.uint32)
        bits[idx] ^= np.uint32(1 << 30)   # top exponent bit
    else:
        raise ValueError(f"not a payload-corruption kind: {kind!r}")
    return out


# ---------------------------------------------------------------------------
# Presets + resolution


def _preset_chaos() -> FaultConfig:
    # every fault kind live at once: the example's corrupted-cohort demo
    # and the CI chaos job run on this
    return FaultConfig(crash_compute=0.05, truncate_upload=0.05,
                       corrupt_nan=0.08, corrupt_bitflip=0.08,
                       duplicate_upload=0.05)


FAULT_PRESETS = {
    "chaos": _preset_chaos,
}


def resolve_faults(
        spec: Union[None, str, dict, FaultConfig]) -> Optional[FaultConfig]:
    """GridConfig.faults -> FaultConfig or None (trivial).

    ``None`` and an all-zero config resolve to ``None`` — the signal for
    the schedulers to take the exact pre-fault code paths (no fault
    stream is even spawned). A name looks up :data:`FAULT_PRESETS`; a
    dict builds a config from fields; a config passes through."""
    if spec is None:
        return None
    if isinstance(spec, str):
        try:
            cfg = FAULT_PRESETS[spec]()
        except KeyError:
            raise ValueError(f"unknown fault preset {spec!r}; options: "
                             f"{sorted(FAULT_PRESETS)}") from None
    elif isinstance(spec, dict):
        cfg = FaultConfig(**spec)
    elif isinstance(spec, FaultConfig):
        cfg = spec
    else:
        raise TypeError(f"faults must be None, a preset name, a dict or a "
                        f"FaultConfig, got {type(spec).__name__}")
    return None if cfg.trivial else cfg
