"""Cohort-selection policies for the simulation grid.

PR 1 hard-coded *who trains*: sync cohorts were a uniform
``syn.sample_cohort`` draw and async dispatch a uniform
``rng.integers``. At cross-device scale the server's choice of cohort is
a first-class control knob (the FL communication-practicality survey
names client sampling under dynamic availability as the gap between
simulated and deployed comm savings; FedPLT makes heterogeneity-aware
client/layer assignment the core mechanism). This module makes the
choice pluggable:

``uniform``
    The exact pre-PR behavior — byte-identical RNG consumption, so the
    default grid reproduces the pre-selection traces bit for bit.

``bandwidth-aware``
    Inclusion probability proportional to the *inverse* estimated round
    trip (fast phones train more often), with first-order
    Horvitz-Thompson importance weights ``(1/N) / p_i`` fed into the
    existing aggregation weights so the aggregate stays an unbiased
    estimate of the uniform-cohort update. Under DP the round engine
    forces uniform-among-participants weighting with a fixed
    denominator (that is what calibrates sigma), so the correction is
    dropped there — selection bias under DP is documented, not
    silently corrected (see README).

``tier-rotation``
    FedPLT-style coverage rotation over a ``core/plan.py`` TrainPlan:
    each round the tier->client assignment rotates by one, so every
    client group cycles through every tier's block-group and no block
    is starved of its stragglers' data distribution. Sampling stays
    uniform; only the per-round tier map changes.

``adaptive-capability``
    Closes the ROADMAP item: re-runs the capability->tier split online
    from an EMA of *observed* round-trip times (the scheduler reports
    every completed upload's RTT back via ``observe``), re-tiering the
    fleet every ``refit_every`` rounds with
    ``sim/devices.quantile_tiers`` — devices whose links degraded get
    demoted to lighter tiers even if their static profile looked fast.

A policy is bound to one run (``bind`` resets all state); the grid
resolves names through :func:`resolve_policy` and threads the policy
through both scheduling modes — sync cohorts, async dispatch, the
per-round tier map, aggregation-weight corrections, and observed-RTT
feedback.
"""
from __future__ import annotations

from typing import Optional, Union

import numpy as np

from repro.data import synthetic as syn
from repro.sim import devices as dev_lib


class SelectionPolicy:
    """Base policy == ``uniform``: the exact pre-selection behavior.

    The grid calls, in order:

    * ``bind(...)`` once per run (fleet, compiled plan, static tier
      map, per-client RTT estimates);
    * sync: ``select_cohort(data_rng, m)`` per round, then
      ``cohort_weights(sel)`` for the kept cohort slots;
    * async: ``sample_cid(dev_rng)`` per dispatch, ``client_weight``
      per completed client;
    * ``current_tiers()`` whenever a tier map is needed (rotation and
      adaptive policies return a map that changes over rounds);
    * ``observe(cid, rtt)`` for every upload the server actually saw;
    * ``end_round(r)`` after each server update (sync round or async
      flush).

    RNG discipline: ``select_cohort`` draws from the grid's data stream
    and ``sample_cid`` from the device stream, exactly like the pre-PR
    inlined calls — the uniform policy consumes both streams
    byte-identically.
    """

    name = "uniform"
    # trivial policies are skipped for weight corrections entirely, so
    # the default path multiplies nothing into the pre-PR weights
    trivial = True

    def bind(self, *, fleet: dev_lib.Fleet, num_clients: int, cplan=None,
             tiers: Optional[np.ndarray] = None,
             rtt_estimate: Optional[np.ndarray] = None) -> None:
        self.fleet = fleet
        self.num_clients = int(num_clients)
        self.cplan = cplan
        self._tiers = tiers
        self.rtt_estimate = rtt_estimate

    # -- sampling ---------------------------------------------------------

    def select_cohort(self, rng: np.random.Generator, m: int) -> np.ndarray:
        return syn.sample_cohort(rng, self.num_clients, m)

    def sample_cid(self, rng: np.random.Generator) -> int:
        return int(rng.integers(0, self.num_clients))

    # -- importance weights ----------------------------------------------

    def cohort_weights(self, cids: np.ndarray) -> Optional[np.ndarray]:
        """Per-cohort-slot multiplier into the aggregation weights
        (None = uniform, multiply nothing)."""
        return None

    def client_weight(self, cid: int) -> float:
        return 1.0

    # -- feedback ---------------------------------------------------------

    def observe(self, cid: int, rtt_seconds: float) -> None:
        pass

    def end_round(self, round_idx: int) -> None:
        pass

    # -- tier map ---------------------------------------------------------

    def current_tiers(self) -> Optional[np.ndarray]:
        return self._tiers

    # -- checkpointing -----------------------------------------------------

    def state_dict(self) -> dict:
        """JSON-serializable mutable state for mid-run checkpoints.
        Uniform and bandwidth-aware policies carry no mutable state
        beyond what ``bind`` derives, so the base blob is just the
        policy name (used as a resume-time consistency check)."""
        return {"name": self.name}

    def load_state(self, state: dict) -> None:
        if state.get("name") != self.name:
            raise ValueError(
                f"checkpointed selection policy {state.get('name')!r} "
                f"does not match this run's {self.name!r} — resume with "
                "the same GridConfig.selection")


class UniformPolicy(SelectionPolicy):
    pass


class BandwidthAwarePolicy(SelectionPolicy):
    """Inclusion probability proportional to ``(1/rtt_est)^temperature``,
    with slow scores floored at ``1/max_tilt`` of the fastest so the
    total inclusion spread stays bounded — a heavy-tailed fleet cannot
    starve its slow decile entirely, and one pathological straggler
    cannot collapse the tilt among the healthy phones (flooring the
    slow end preserves the fast end's relative differences; capping
    against the slowest would flatten everyone toward uniform).
    Importance weights are the first-order Horvitz-Thompson correction
    ``(1/N) / p_i`` (unit mean under the sampling distribution): a fast
    phone sampled 4x as often counts 1/4 as much per appearance,
    keeping the aggregate unbiased for the uniform-cohort update."""

    name = "bandwidth-aware"
    trivial = False

    def __init__(self, temperature: float = 1.0, max_tilt: float = 10.0):
        if temperature <= 0 or max_tilt < 1.0:
            raise ValueError("need temperature > 0 and max_tilt >= 1")
        self.temperature = float(temperature)
        self.max_tilt = float(max_tilt)

    def bind(self, **kw) -> None:
        super().bind(**kw)
        if self.rtt_estimate is None:
            raise ValueError("bandwidth-aware selection needs per-client "
                             "round-trip estimates")
        score = (1.0 / np.maximum(self.rtt_estimate, 1e-12)
                 ) ** self.temperature
        score = np.maximum(score, score.max() / self.max_tilt)
        self.probs = score / score.sum()
        # first-order HT weight: uniform inclusion is 1/N, ours is p_i
        self.weights = (1.0 / self.num_clients) / self.probs
        # inverse-CDF sampling: async dispatch (and its availability
        # redraw loop) draws per event — keep it O(log N), not the
        # O(N) rng.choice path
        self._cdf = np.cumsum(self.probs)
        self._cdf[-1] = 1.0

    def select_cohort(self, rng: np.random.Generator, m: int) -> np.ndarray:
        return rng.choice(self.num_clients, size=m, replace=False,
                          p=self.probs)

    def sample_cid(self, rng: np.random.Generator) -> int:
        return int(np.searchsorted(self._cdf, rng.random(), side="right"))

    def cohort_weights(self, cids: np.ndarray) -> np.ndarray:
        return self.weights[np.asarray(cids, np.int64)]

    def client_weight(self, cid: int) -> float:
        return float(self.weights[int(cid)])


class TierRotationPolicy(SelectionPolicy):
    """Rotate the tier->client assignment every ``every`` server updates:
    at update ``r`` client ``c`` trains tier
    ``(base[c] + r // every) % n_tiers``. Over ``n_tiers`` rotations
    every client group trains every tier's block-group (FedPLT-style
    coverage), composed against the plan's existing compiled
    sub-layouts — nothing re-traces, only the runtime tier ids move."""

    name = "tier-rotation"
    trivial = False

    def __init__(self, every: int = 1):
        if every < 1:
            raise ValueError("rotation period must be >= 1 round")
        self.every = int(every)
        self.rotation = 0

    def bind(self, **kw) -> None:
        super().bind(**kw)
        if self.cplan is None or self._tiers is None:
            raise ValueError("tier-rotation needs a trainability plan "
                             "(GridConfig.plan)")
        self.n_tiers = len(self.cplan.tiers)
        self.base = np.asarray(self._tiers, np.int32)
        self.rotation = 0
        self._map = self.base

    def current_tiers(self) -> np.ndarray:
        # cached: the async path queries per dispatch (tier id + compute),
        # the map only moves in end_round
        return self._map

    def end_round(self, round_idx: int) -> None:
        rotation = (round_idx + 1) // self.every
        if rotation != self.rotation:
            self.rotation = rotation
            self._map = (self.base + rotation) % self.n_tiers

    def state_dict(self) -> dict:
        state = super().state_dict()
        state["rotation"] = int(self.rotation)
        return state

    def load_state(self, state: dict) -> None:
        super().load_state(state)
        self.rotation = int(state["rotation"])
        self._map = (self.base + self.rotation) % self.n_tiers


class AdaptiveCapabilityPolicy(SelectionPolicy):
    """Re-tier the fleet online from observed round-trip times.

    The static capability split (``sim/devices.assign_tiers``) trusts
    the profile; this policy trusts the wire. Every completed upload
    updates an EMA of that client's observed RTT (initialized from the
    profile estimate, so unobserved clients keep their static rank);
    every ``refit_every`` server updates the fleet is re-split into
    ``n_tiers`` quantile buckets of ``1/ema_rtt`` — the same rule
    ``assign_tiers`` applies to static capability scores, now fed by
    measurements. Sampling stays uniform."""

    name = "adaptive-capability"
    trivial = False

    def __init__(self, refit_every: int = 5, ema: float = 0.3):
        if not 0.0 < ema <= 1.0:
            raise ValueError("ema weight must be in (0, 1]")
        if refit_every < 1:
            raise ValueError("refit_every must be >= 1 round")
        self.refit_every = int(refit_every)
        self.ema = float(ema)

    def bind(self, **kw) -> None:
        super().bind(**kw)
        if self.cplan is None or self._tiers is None:
            raise ValueError("adaptive-capability needs a trainability "
                             "plan (GridConfig.plan)")
        if self.rtt_estimate is None:
            raise ValueError("adaptive-capability needs per-client "
                             "round-trip estimates to seed the EMA")
        self.n_tiers = len(self.cplan.tiers)
        self.ema_rtt = np.asarray(self.rtt_estimate, np.float64).copy()
        self.observed = np.zeros(self.num_clients, bool)
        self._map = np.asarray(self._tiers, np.int32)
        self.refits = 0
        # EMA snapshot at the last refit: what the current map was
        # actually computed from (observations keep arriving between
        # refits, so ema_rtt itself runs ahead of the map)
        self.refit_ema = self.ema_rtt.copy()

    def observe(self, cid: int, rtt_seconds: float) -> None:
        cid = int(cid)
        self.ema_rtt[cid] = ((1.0 - self.ema) * self.ema_rtt[cid]
                             + self.ema * float(rtt_seconds))
        self.observed[cid] = True

    def current_tiers(self) -> np.ndarray:
        return self._map

    def end_round(self, round_idx: int) -> None:
        if (round_idx + 1) % self.refit_every:
            return
        self._map = dev_lib.quantile_tiers(
            1.0 / np.maximum(self.ema_rtt, 1e-12), self.n_tiers)
        self.refit_ema = self.ema_rtt.copy()
        self.refits += 1

    def state_dict(self) -> dict:
        state = super().state_dict()
        state.update(
            ema_rtt=[float(x) for x in self.ema_rtt],
            observed=[bool(x) for x in self.observed],
            tier_map=[int(x) for x in self._map],
            refits=int(self.refits),
            refit_ema=[float(x) for x in self.refit_ema])
        return state

    def load_state(self, state: dict) -> None:
        super().load_state(state)
        self.ema_rtt = np.asarray(state["ema_rtt"], np.float64)
        self.observed = np.asarray(state["observed"], bool)
        self._map = np.asarray(state["tier_map"], np.int32)
        self.refits = int(state["refits"])
        self.refit_ema = np.asarray(state["refit_ema"], np.float64)


POLICIES = {
    "uniform": UniformPolicy,
    "bandwidth-aware": BandwidthAwarePolicy,
    "tier-rotation": TierRotationPolicy,
    "adaptive-capability": AdaptiveCapabilityPolicy,
}


def resolve_policy(spec: Union[str, SelectionPolicy]) -> SelectionPolicy:
    """GridConfig.selection -> a fresh policy instance (named policies)
    or the caller's instance (assumed un-bound / reusable via bind)."""
    if isinstance(spec, SelectionPolicy):
        return spec
    try:
        return POLICIES[spec]()
    except KeyError:
        raise ValueError(f"unknown selection policy {spec!r}; options: "
                         f"{sorted(POLICIES)}") from None
