"""Composable decoder-only LM covering the dense / MoE / hybrid / SSM /
VLM families of the assigned architectures.

Layer stacks are built as a *periodic program*: the layer sequence is
grouped into `num_layers / period` identical groups, each containing
`period` slots of fixed kind (attention / Mamba / mLSTM / sLSTM, with a
dense or MoE FFN). The stack is executed with `lax.scan` over groups —
this keeps the HLO (and CPU compile time for 512-device dry-runs) bounded
for 60-layer models, and the roofline accounting multiplies scan-body
costs by the trip count.

KV caches: full-length buffers for global attention, ring buffers of
`sliding_window` size for SWA architectures (Mistral-style rolling
cache) — the latter is what makes `long_500k` decode feasible.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ATTN, MAMBA, MLSTM, SLSTM
from repro.nn import attention as attn_lib
from repro.nn import basic, moe as moe_lib, ssm as ssm_lib


# ---------------------------------------------------------------------------
# Layer program


class Slot(NamedTuple):
    kind: str       # attn | mamba | mlstm | slstm
    use_moe: bool
    cross_attn: bool = False


def layer_program(cfg: ModelConfig) -> Tuple[Tuple[Slot, ...], int]:
    """Returns (slots-per-group, n_groups)."""
    kinds = cfg.block_kinds()
    period = 1
    if cfg.family == "hybrid" and cfg.attn_period:
        period = cfg.attn_period
    if cfg.family == "ssm" and cfg.slstm_every:
        period = cfg.slstm_every
    if cfg.num_experts > 0 and cfg.moe_period > 1:
        period = math.lcm(period, cfg.moe_period)
    assert cfg.num_layers % period == 0, (cfg.name, cfg.num_layers, period)
    slots = tuple(
        Slot(kind=kinds[i], use_moe=cfg.layer_uses_moe(i))
        for i in range(period))
    return slots, cfg.num_layers // period


# ---------------------------------------------------------------------------
# Init


def _init_slot(key, cfg: ModelConfig, slot: Slot, si: int, decoder_cross: bool):
    dt = cfg.pdtype
    path = f"layers/slot{si}"
    p: Dict[str, Any] = {"ln1": basic.init_norm(key, f"{path}/ln1", cfg.d_model,
                                                dt, cfg.norm_type)}
    if slot.kind == ATTN:
        if cfg.use_mla:
            p["attn"] = attn_lib.init_mla(key, f"{path}/attn", cfg, dt)
        else:
            p["attn"] = attn_lib.init_attention(key, f"{path}/attn", cfg, dt)
    elif slot.kind == MAMBA:
        p["mamba"] = ssm_lib.init_mamba(key, f"{path}/mamba", cfg, dt)
    elif slot.kind == MLSTM:
        p["mlstm"] = ssm_lib.init_mlstm(key, f"{path}/mlstm", cfg, dt)
    elif slot.kind == SLSTM:
        p["slstm"] = ssm_lib.init_slstm(key, f"{path}/slstm", cfg, dt)
    if decoder_cross and slot.kind == ATTN:
        p["ln_cross"] = basic.init_norm(key, f"{path}/ln_cross", cfg.d_model,
                                        dt, cfg.norm_type)
        p["cross_attn"] = attn_lib.init_attention(key, f"{path}/cross_attn",
                                                  cfg, dt)
    if slot.kind in (ATTN, MAMBA):  # blocks with a separate FFN
        p["ln2"] = basic.init_norm(key, f"{path}/ln2", cfg.d_model, dt,
                                   cfg.norm_type)
        if slot.use_moe:
            p["moe"] = moe_lib.init_moe(key, f"{path}/moe", cfg, dt)
        else:
            p["ffn"] = basic.init_mlp(key, f"{path}/ffn", cfg.d_model, cfg.d_ff,
                                      dt, gated=cfg.gated_mlp)
    return p


def _init_stack(seed, cfg: ModelConfig, decoder_cross: bool = False):
    slots, n_groups = layer_program(cfg)
    root = basic.path_key(seed, f"{cfg.name}/stack" + ("/dec" if decoder_cross else ""))
    keys = jax.vmap(lambda g: jax.random.fold_in(root, g))(jnp.arange(n_groups))
    stacked = {}
    for si, slot in enumerate(slots):
        stacked[f"slot{si}"] = jax.vmap(
            lambda k, si=si, slot=slot: _init_slot(k, cfg, slot, si,
                                                   decoder_cross))(keys)
    return stacked


def init_model(cfg: ModelConfig, seed: int) -> Dict[str, Any]:
    dt = cfg.pdtype
    p: Dict[str, Any] = {
        "embed": basic.init_embedding(seed, "embed", cfg.vocab_size,
                                      cfg.d_model, dt),
        "final_norm": basic.init_norm(seed, "final_norm", cfg.d_model, dt,
                                      cfg.norm_type),
        "layers": _init_stack(seed, cfg),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = {"kernel": basic.normal_init(
            seed, "unembed/kernel", (cfg.d_model, cfg.vocab_size), dt,
            fan_in=cfg.d_model)}
    if cfg.family == "vlm":
        # projector from the (stubbed) vision tower dim to d_model
        p["mm_proj"] = basic.init_dense(seed, "mm_proj", 1152, cfg.d_model, dt,
                                        bias=True)
    if cfg.is_encoder_decoder:
        p["enc_layers"] = _init_stack(seed, cfg.with_(
            num_layers=cfg.encoder_layers or cfg.num_layers,
            sliding_window=0), decoder_cross=False)
        p["enc_norm"] = basic.init_norm(seed, "enc_norm", cfg.d_model, dt,
                                        cfg.norm_type)
        # decoder stack gets cross-attention
        p["layers"] = _init_stack(seed, cfg, decoder_cross=True)
    return p


# ---------------------------------------------------------------------------
# Forward (training / prefill)


def sinusoid_pos(positions, d_model, dtype):
    """Classic sinusoidal position embedding: positions (..., S) -> (..., S, d)."""
    half = d_model // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (jnp.log(10000.0) / max(half - 1, 1)))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _apply_slot(x, sp, cfg: ModelConfig, slot: Slot, positions, aux,
                encoder_out=None, prefix_len=0, causal=True):
    """One residual block. Returns (x, aux, cache_entry)."""
    cd = cfg.cdtype
    h = basic.apply_norm(x, sp["ln1"], cfg.norm_type)
    cache = ()
    if slot.kind == ATTN:
        if cfg.use_mla:
            q, k, v, (ckv, kpe) = attn_lib.mla_qkv(h, sp["attn"], cfg, positions)
            o = attn_lib.flash_attention(q, k, v, cfg.with_(sliding_window=0),
                                         causal=causal, prefix_len=prefix_len)
            o = o.reshape(o.shape[0], o.shape[1], -1)
            o = basic.dense(o, sp["attn"]["wo"], cd)
            cache = (ckv, kpe)
        else:
            q, k, v = attn_lib.qkv_project(h, sp["attn"], cfg)
            if cfg.use_rope:
                cos, sin = attn_lib.rope_freqs(cfg.resolved_head_dim,
                                               cfg.rope_theta, positions)
                q = attn_lib.apply_rope(q, cos, sin)
                k = attn_lib.apply_rope(k, cos, sin)
            o = attn_lib.flash_attention(q, k, v, cfg, causal=causal,
                                         prefix_len=prefix_len)
            o = o.reshape(o.shape[0], o.shape[1], -1)
            o = basic.dense(o, sp["attn"]["wo"], cd)
            cache = (k, v)
        x = x + o
        if "cross_attn" in sp and encoder_out is not None:
            hc = basic.apply_norm(x, sp["ln_cross"], cfg.norm_type)
            qc, _, _ = attn_lib.qkv_project(hc, sp["cross_attn"], cfg)
            _, kc, vc = attn_lib.qkv_project(encoder_out, sp["cross_attn"], cfg)
            oc = attn_lib.flash_attention(
                qc, kc, vc, cfg.with_(sliding_window=0), causal=False)
            oc = oc.reshape(oc.shape[0], oc.shape[1], -1)
            x = x + basic.dense(oc, sp["cross_attn"]["wo"], cd)
    elif slot.kind == MAMBA:
        o, st = ssm_lib.mamba_forward(h, sp["mamba"], cfg)
        x = x + o
        cache = st
    elif slot.kind == MLSTM:
        o, st = ssm_lib.mlstm_forward(h, sp["mlstm"], cfg)
        return x + o, aux, st
    elif slot.kind == SLSTM:
        o, st = ssm_lib.slstm_forward(h, sp["slstm"], cfg)
        return x + o, aux, st

    h2 = basic.apply_norm(x, sp["ln2"], cfg.norm_type)
    if slot.use_moe:
        B, S, D = h2.shape
        y, aux_l = moe_lib.moe_ffn(h2.reshape(B * S, D), sp["moe"], cfg)
        y = y.reshape(B, S, D)
        aux = aux + aux_l
    else:
        y = basic.mlp(h2, sp["ffn"], cfg.act, cd)
    return x + y, aux, cache


def forward(params, cfg: ModelConfig, tokens, prefix_embeds=None,
            encoder_embeds=None, return_caches: bool = False):
    """tokens: (B, S) int32. prefix_embeds: (B, P, 1152) VLM stub input.
    encoder_embeds: (B, E, d_model) audio stub input (enc-dec only).

    Returns (logits, metrics[, caches]).
    """
    cd = cfg.cdtype
    slots, n_groups = layer_program(cfg)
    x = basic.embed(tokens, params["embed"], cd)
    prefix_len = 0
    if cfg.family == "vlm" and prefix_embeds is not None:
        pe = basic.dense(prefix_embeds.astype(cd), params["mm_proj"], cd)
        x = jnp.concatenate([pe, x], axis=1)
        prefix_len = pe.shape[1]
    positions = jnp.arange(x.shape[1])[None, :]
    if not cfg.use_rope:
        x = x + sinusoid_pos(positions, cfg.d_model, cd)

    encoder_out = None
    if cfg.is_encoder_decoder and encoder_embeds is not None:
        enc_pos = jnp.arange(encoder_embeds.shape[1])[None, :]
        enc_x = encoder_embeds.astype(cd)
        if not cfg.use_rope:
            enc_x = enc_x + sinusoid_pos(enc_pos, cfg.d_model, cd)
        encoder_out = _run_stack(params["enc_layers"],
                                 cfg.with_(num_layers=cfg.encoder_layers or
                                           cfg.num_layers, sliding_window=0),
                                 enc_x, enc_pos, noncausal=True)[0]
        encoder_out = basic.apply_norm(encoder_out, params["enc_norm"],
                                       cfg.norm_type)

    x, aux, caches = _run_stack(params["layers"], cfg, x, positions,
                                encoder_out=encoder_out,
                                prefix_len=prefix_len,
                                collect_caches=return_caches)[0:3]

    x = basic.apply_norm(x, params["final_norm"], cfg.norm_type)
    if cfg.tie_embeddings:
        logits = basic.unembed(x, params["embed"], cd)
    else:
        logits = x @ params["unembed"]["kernel"].astype(cd)
    metrics = {"moe_aux_loss": aux}
    if return_caches:
        return logits, metrics, caches
    return logits, metrics


def _run_stack(stack_params, cfg: ModelConfig, x, positions, noncausal=False,
               encoder_out=None, prefix_len=0, collect_caches=False):
    slots, n_groups = layer_program(cfg)

    def group_body(carry, group_params):
        x, aux = carry
        caches = []
        for si, slot in enumerate(slots):
            x, aux, c = _apply_slot(x, group_params[f"slot{si}"], cfg, slot,
                                    positions, aux, encoder_out=encoder_out,
                                    prefix_len=prefix_len,
                                    causal=not noncausal)
            caches.append(c)
        out = tuple(caches) if collect_caches else ()
        return (x, aux), out

    (x, aux), caches = jax.lax.scan(group_body,
                                    (x, jnp.zeros((), jnp.float32)),
                                    stack_params)
    return x, aux, caches


# ---------------------------------------------------------------------------
# Loss


def lm_loss(logits, labels, mask=None):
    """Cross-entropy; labels: (B, S) int32, mask 1.0 where counted."""
    v = logits.shape[-1]
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(lp, labels[..., None].astype(jnp.int32),
                             axis=-1)[..., 0]
    if mask is None:
        mask = jnp.ones_like(ll)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def train_loss(params, cfg: ModelConfig, batch):
    kw = {}
    if cfg.family == "vlm":
        kw["prefix_embeds"] = batch["prefix_embeds"]
    if cfg.is_encoder_decoder:
        kw["encoder_embeds"] = batch["encoder_embeds"]
    logits, metrics = forward(params, cfg, batch["tokens"], **kw)
    # VLM: logits cover prefix+text; align to text labels only
    if cfg.family == "vlm" and "prefix_embeds" in kw:
        P = kw["prefix_embeds"].shape[1]
        logits = logits[:, P:, :]
    mask = batch.get("mask", None)
    if mask is not None:
        mask = mask[:, 1:].astype(jnp.float32)
    loss = lm_loss(logits[:, :-1, :], batch["labels"][:, 1:], mask)
    if cfg.router_aux_loss and cfg.num_experts:
        loss = loss + cfg.router_aux_loss * metrics["moe_aux_loss"]
    return loss, metrics


# ---------------------------------------------------------------------------
# Decode (serving): single-token step against per-layer caches.
#
# Attention layers use a full-length cache, or a Mistral-style ring buffer
# of `sliding_window` entries for SWA architectures (RoPE is applied at
# absolute positions on write, so relative geometry survives the ring).
# SSM layers carry constant-size recurrent states.


def cache_capacity(cfg: ModelConfig, max_len: int) -> int:
    if cfg.sliding_window and cfg.sliding_window < max_len:
        return cfg.sliding_window
    return max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    """Zero caches for decoding up to max_len tokens. Returns a pytree with
    a per-slot entry stacked over groups plus a scalar cache_len."""
    cd = dtype or cfg.cdtype
    slots, G = layer_program(cfg)
    S = cache_capacity(cfg, max_len)
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    d_in_x, nh_x, dh_x = ssm_lib.xlstm_dims(cfg)
    di, _ = ssm_lib.mamba_dims(cfg)
    K = cfg.mamba_d_conv
    entries = []
    for slot in slots:
        if slot.kind == ATTN and cfg.use_mla:
            e = {"ckv": jnp.zeros((G, batch, S, cfg.kv_lora_rank), cd),
                 "kpe": jnp.zeros((G, batch, S, cfg.qk_rope_head_dim), cd)}
        elif slot.kind == ATTN:
            e = {"k": jnp.zeros((G, batch, S, kvh, hd), cd),
                 "v": jnp.zeros((G, batch, S, kvh, hd), cd)}
        elif slot.kind == MAMBA:
            e = {"h": jnp.zeros((G, batch, di, cfg.mamba_d_state), jnp.float32),
                 "conv": jnp.zeros((G, batch, K - 1, di), cd)}
        elif slot.kind == MLSTM:
            e = {"C": jnp.zeros((G, batch, nh_x, dh_x, dh_x), jnp.float32),
                 "n": jnp.zeros((G, batch, nh_x, dh_x), jnp.float32),
                 "conv": jnp.zeros((G, batch, 3, d_in_x), cd)}
        elif slot.kind == SLSTM:
            dh_s = cfg.d_model // cfg.num_heads
            z = jnp.zeros((G, batch, cfg.num_heads, dh_s), jnp.float32)
            e = {"c": z, "n": z, "h": z, "m": z - 30.0,
                 "conv": jnp.zeros((G, batch, 3, cfg.d_model), cd)}
        entries.append(e)
    cache = {"slots": {f"slot{i}": e for i, e in enumerate(entries)},
             "cache_len": jnp.zeros((), jnp.int32)}
    if cfg.is_encoder_decoder:
        E = cfg.encoder_seq_len
        cache["cross"] = {
            f"slot{i}": {"k": jnp.zeros((G, batch, E, kvh, hd), cd),
                         "v": jnp.zeros((G, batch, E, kvh, hd), cd)}
            for i, slot in enumerate(slots) if slot.kind == ATTN}
    return cache


def build_cross_cache(params, cfg: ModelConfig, encoder_embeds):
    """Precompute encoder K/V for every decoder cross-attention slot."""
    cd = cfg.cdtype
    slots, G = layer_program(cfg)
    enc_pos = jnp.arange(encoder_embeds.shape[1])[None, :]
    enc_x = encoder_embeds.astype(cd)
    if not cfg.use_rope:
        enc_x = enc_x + sinusoid_pos(enc_pos, cfg.d_model, cd)
    enc_cfg = cfg.with_(num_layers=cfg.encoder_layers or cfg.num_layers,
                        sliding_window=0)
    enc = _run_stack(params["enc_layers"], enc_cfg, enc_x, enc_pos,
                     noncausal=True)[0]
    enc = basic.apply_norm(enc, params["enc_norm"], cfg.norm_type)

    def per_group(gp):
        out = {}
        for i, slot in enumerate(slots):
            if slot.kind != ATTN:
                continue
            sp = gp[f"slot{i}"]
            _, kc, vc = attn_lib.qkv_project(enc, sp["cross_attn"], cfg)
            out[f"slot{i}"] = {"k": kc, "v": vc}
        return out

    return jax.vmap(per_group, in_axes=0, out_axes=0)(params["layers"])


def _decode_slot(x, sp, cfg: ModelConfig, slot: Slot, cache, cross,
                 cache_len, pos):
    """x: (B,1,d). Returns (x, new_cache)."""
    cd = cfg.cdtype
    h = basic.apply_norm(x, sp["ln1"], cfg.norm_type)
    if slot.kind == ATTN:
        S = cache["k"].shape[1] if "k" in cache else cache["ckv"].shape[1]
        widx = jnp.mod(cache_len, S)                      # ring write index
        cl_eff = jnp.minimum(cache_len + 1, S)
        if cfg.use_mla:
            ckv, kpe = attn_lib.mla_compress(h, sp["attn"], cfg, pos[None, :])
            new_ckv = jax.lax.dynamic_update_slice(
                cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, widx, 0))
            new_kpe = jax.lax.dynamic_update_slice(
                cache["kpe"], kpe.astype(cache["kpe"].dtype), (0, widx, 0))
            o = attn_lib.mla_decode(h, sp["attn"], cfg, new_ckv, new_kpe,
                                    cl_eff)
            cache = {"ckv": new_ckv, "kpe": new_kpe}
        else:
            q, k, v = attn_lib.qkv_project(h, sp["attn"], cfg)
            if cfg.use_rope:
                cos, sin = attn_lib.rope_freqs(cfg.resolved_head_dim,
                                               cfg.rope_theta, pos[None, :])
                q = attn_lib.apply_rope(q, cos, sin)
                k = attn_lib.apply_rope(k, cos, sin)
            new_k = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, widx, 0, 0))
            new_v = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, widx, 0, 0))
            o = attn_lib.decode_attention(q, new_k, new_v, cl_eff,
                                          cfg.with_(sliding_window=0))
            o = basic.dense(o.reshape(o.shape[0], 1, -1), sp["attn"]["wo"], cd)
            cache = {"k": new_k, "v": new_v}
        x = x + o
        if cross is not None and "cross_attn" in sp:
            hc = basic.apply_norm(x, sp["ln_cross"], cfg.norm_type)
            qc, _, _ = attn_lib.qkv_project(hc, sp["cross_attn"], cfg)
            oc = attn_lib.decode_attention(
                qc, cross["k"], cross["v"], cross["k"].shape[1],
                cfg.with_(sliding_window=0))
            x = x + basic.dense(oc.reshape(oc.shape[0], 1, -1),
                                sp["cross_attn"]["wo"], cd)
    elif slot.kind == MAMBA:
        o, (hh, conv) = ssm_lib.mamba_step(h[:, 0, :], sp["mamba"], cfg,
                                           (cache["h"], cache["conv"]))
        x = x + o[:, None, :]
        cache = {"h": hh, "conv": conv}
    elif slot.kind == MLSTM:
        o, (C, n, conv) = ssm_lib.mlstm_step(
            h[:, 0, :], sp["mlstm"], cfg, (cache["C"], cache["n"], cache["conv"]))
        return x + o[:, None, :], {"C": C, "n": n, "conv": conv}
    elif slot.kind == SLSTM:
        cell = (cache["c"], cache["n"], cache["h"], cache["m"])
        o, (cell, conv) = ssm_lib.slstm_step(h[:, 0, :], sp["slstm"], cfg,
                                             (cell, cache["conv"]))
        return x + o[:, None, :], {"c": cell[0], "n": cell[1], "h": cell[2],
                                   "m": cell[3], "conv": conv}

    h2 = basic.apply_norm(x, sp["ln2"], cfg.norm_type)
    if slot.use_moe:
        B = h2.shape[0]
        y, _ = moe_lib.moe_ffn(h2.reshape(B, -1), sp["moe"], cfg)
        y = y.reshape(B, 1, -1)
    else:
        y = basic.mlp(h2, sp["ffn"], cfg.act, cd)
    return x + y, cache


def decode_step(params, cfg: ModelConfig, cache, tokens):
    """tokens: (B, 1) int32 -> (logits (B, 1, V), new cache)."""
    cd = cfg.cdtype
    slots, G = layer_program(cfg)
    cache_len = cache["cache_len"]
    pos = cache_len[None]  # absolute position of this token
    x = basic.embed(tokens, params["embed"], cd)
    if not cfg.use_rope:
        x = x + sinusoid_pos(pos[None, :], cfg.d_model, cd)

    def group_body(x, xs):
        gp, gc, gcross = xs
        new_caches = {}
        for si, slot in enumerate(slots):
            key = f"slot{si}"
            cr = gcross.get(key) if gcross else None
            x, nc = _decode_slot(x, gp[key], cfg, slot, gc[key], cr,
                                 cache_len, pos)
            new_caches[key] = nc
        return x, new_caches

    cross = cache.get("cross")
    (x, new_slots) = jax.lax.scan(
        group_body, x, (params["layers"], cache["slots"], cross))

    x = basic.apply_norm(x, params["final_norm"], cfg.norm_type)
    if cfg.tie_embeddings:
        logits = basic.unembed(x, params["embed"], cd)
    else:
        logits = x @ params["unembed"]["kernel"].astype(cd)
    new_cache = dict(cache)
    new_cache["slots"] = new_slots
    new_cache["cache_len"] = cache_len + 1
    return logits, new_cache
