"""The paper's own three experiment models, reproduced exactly:

* EMNIST CNN (Table 6): conv(5x5,32) -> maxpool -> conv(5x5,64) -> GN ->
  maxpool -> dense(512) -> dense(62). 1,690,174 params; freezing the
  first dense layer leaves 4.97% trainable (Table 1, 20x comm saving).
* ResNet-18 with GroupNorm for CIFAR-10 (Table 2): frozen conv *stages*
  0..3 in increasing order give 26.25 / 8.07 / 3.47 / 2.16 % trainable.
* Stack Overflow NWP Transformer (Table 3): 3 encoder layers, d=96,
  d_ff=2048, 8 heads x 12-dim, vocab 10k; freezing the first FFN dense
  of encoder blocks 2 / 1,2 / 0,1,2 gives 91.3 / 82.6 / 73.8 %.

These are *not* ShapeDtypeStruct stubs — they train end-to-end on the
synthetic federated datasets in benchmarks/ and examples/.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.nn import basic, conv as conv_lib
from repro.nn import attention as attn_lib


# ---------------------------------------------------------------------------
# EMNIST CNN (Table 6)


def init_emnist_cnn(seed: int, dtype=jnp.float32) -> Dict[str, Any]:
    return {
        "conv1": conv_lib.init_conv(seed, "conv1", 5, 1, 32, dtype),
        "conv2": conv_lib.init_conv(seed, "conv2", 5, 32, 64, dtype),
        "gn": conv_lib.init_groupnorm(seed, "gn", 64, dtype),
        "dense1": basic.init_dense(seed, "dense1", 3136, 512, dtype, bias=True),
        "dense2": basic.init_dense(seed, "dense2", 512, 62, dtype, bias=True),
    }


def emnist_cnn_forward(params, images):
    """images: (B, 28, 28, 1) -> logits (B, 62)."""
    x = conv_lib.conv2d(images, params["conv1"])
    x = jax.nn.relu(x)
    x = conv_lib.maxpool2d(x)
    x = conv_lib.conv2d(x, params["conv2"])
    x = conv_lib.apply_groupnorm(x, params["gn"], groups=2)
    x = jax.nn.relu(x)
    x = conv_lib.maxpool2d(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(basic.dense(x, params["dense1"]))
    return basic.dense(x, params["dense2"])


# FedPT freeze spec from the paper: the first dense layer (95.03% of params)
EMNIST_FREEZE = (r"^dense1/",)


# ---------------------------------------------------------------------------
# ResNet-18 with GroupNorm (CIFAR-10)

_STAGES = ((64, 1), (128, 2), (256, 2), (512, 2))  # (channels, first stride)


def init_resnet18(seed: int, num_classes: int = 10, dtype=jnp.float32):
    p: Dict[str, Any] = {
        "stem": conv_lib.init_conv(seed, "stem", 3, 3, 64, dtype, bias=False),
        "stem_gn": conv_lib.init_groupnorm(seed, "stem_gn", 64, dtype),
        "fc": basic.init_dense(seed, "fc", 512, num_classes, dtype, bias=True),
    }
    c_in = 64
    for si, (c, _stride) in enumerate(_STAGES):
        for bi in range(2):
            path = f"stage{si}/block{bi}"
            blk = {
                "conv1": conv_lib.init_conv(seed, f"{path}/conv1", 3,
                                            c_in if bi == 0 else c, c, dtype,
                                            bias=False),
                "gn1": conv_lib.init_groupnorm(seed, f"{path}/gn1", c, dtype),
                "conv2": conv_lib.init_conv(seed, f"{path}/conv2", 3, c, c,
                                            dtype, bias=False),
                "gn2": conv_lib.init_groupnorm(seed, f"{path}/gn2", c, dtype),
            }
            if bi == 0 and c_in != c:
                blk["proj"] = conv_lib.init_conv(seed, f"{path}/proj", 1, c_in,
                                                 c, dtype, bias=False)
            p[f"stage{si}_block{bi}"] = blk
        c_in = c
    return p


def resnet18_forward(params, images):
    """images: (B, H, W, 3) -> logits."""
    x = conv_lib.conv2d(images, params["stem"])
    x = jax.nn.relu(conv_lib.apply_groupnorm(x, params["stem_gn"]))
    for si, (c, stride) in enumerate(_STAGES):
        for bi in range(2):
            blk = params[f"stage{si}_block{bi}"]
            st = stride if bi == 0 else 1
            h = conv_lib.conv2d(x, blk["conv1"], stride=st)
            h = jax.nn.relu(conv_lib.apply_groupnorm(h, blk["gn1"]))
            h = conv_lib.conv2d(h, blk["conv2"])
            h = conv_lib.apply_groupnorm(h, blk["gn2"])
            sc = x
            if "proj" in blk:
                sc = conv_lib.conv2d(x, blk["proj"], stride=st)
            elif st != 1:
                sc = x[:, ::st, ::st, :]
            x = jax.nn.relu(h + sc)
    x = conv_lib.avgpool_global(x)
    return basic.dense(x, params["fc"])


def resnet18_freeze_spec(frozen_stages):
    """Paper Table 10: freeze the conv layers of residual stages, never the
    norms. Matching the paper's trainable-percentages requires freezing the
    *largest* (deepest) stage first — Table 10's "block 1" is the
    512-channel stage (73.75% of params), "block 0" the 256-channel one,
    etc. Downsample projections stay trainable (best match to the paper's
    26.25/8.07/3.47/2.16% schedule; exact per-block identity is not
    published)."""
    return tuple(rf"^stage{s}_block\d/(conv1|conv2)/" for s in frozen_stages)


# Table 2 rows, largest-first freeze schedule (decreasing stage index).
RESNET_FREEZE_SCHEDULE = {
    26.25: (3,),
    8.07: (3, 2),
    3.47: (3, 2, 1),
    2.16: (3, 2, 1, 0),
}


# ---------------------------------------------------------------------------
# Stack Overflow NWP Transformer (3 layers, d=96, ff=2048, 8 heads x 12)


def init_so_transformer(seed: int, vocab: int = 10004, seq: int = 20,
                        dtype=jnp.float32):
    d, ff, h, hd, L = 96, 2048, 8, 12, 3
    p: Dict[str, Any] = {
        "embed": basic.init_embedding(seed, "embed", vocab, d, dtype),
        "pos": basic.normal_init(seed, "pos", (seq, d), dtype, stddev=0.02),
    }
    for li in range(L):
        path = f"layer{li}"
        p[path] = {
            "ln1": {"scale": jnp.zeros((d,), dtype), "bias": jnp.zeros((d,), dtype)},
            "wq": basic.init_dense(seed, f"{path}/wq", d, h * hd, dtype, bias=True),
            "wk": basic.init_dense(seed, f"{path}/wk", d, h * hd, dtype, bias=True),
            "wv": basic.init_dense(seed, f"{path}/wv", d, h * hd, dtype, bias=True),
            "wo": basic.init_dense(seed, f"{path}/wo", h * hd, d, dtype, bias=True),
            "ln2": {"scale": jnp.zeros((d,), dtype), "bias": jnp.zeros((d,), dtype)},
            "ffn1": basic.init_dense(seed, f"{path}/ffn1", d, ff, dtype, bias=True),
            "ffn2": basic.init_dense(seed, f"{path}/ffn2", ff, d, dtype, bias=True),
        }
    p["final_ln"] = {"scale": jnp.zeros((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    return p


def so_transformer_forward(params, tokens):
    """tokens: (B, S) -> logits (B, S, vocab). Causal decoder-style mask
    (next-word prediction), tied input/output embeddings."""
    d, h, hd = 96, 8, 12
    B, S = tokens.shape
    x = basic.embed(tokens, params["embed"], jnp.float32)
    x = x + params["pos"][None, :S, :]
    li = 0
    while f"layer{li}" in params:
        lp = params[f"layer{li}"]
        hx = basic.layernorm(x, lp["ln1"]["scale"], lp["ln1"]["bias"])
        q = basic.dense(hx, lp["wq"]).reshape(B, S, h, hd)
        k = basic.dense(hx, lp["wk"]).reshape(B, S, h, hd)
        v = basic.dense(hx, lp["wv"]).reshape(B, S, h, hd)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(hd))
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", a, v).reshape(B, S, h * hd)
        x = x + basic.dense(o, lp["wo"])
        hx = basic.layernorm(x, lp["ln2"]["scale"], lp["ln2"]["bias"])
        hx = jax.nn.relu(basic.dense(hx, lp["ffn1"]))
        x = x + basic.dense(hx, lp["ffn2"])
        li += 1
    x = basic.layernorm(x, params["final_ln"]["scale"], params["final_ln"]["bias"])
    return basic.unembed(x, params["embed"], jnp.float32)


def so_freeze_spec(frozen_blocks):
    """Paper Table 11: freeze the first FFN dense of the given encoder blocks."""
    return tuple(rf"^layer{b}/ffn1/" for b in frozen_blocks)
