"""Summarize results/benchmarks.json into the EXPERIMENTS.md §Tables
section (run after `python -m benchmarks.run`)."""
import json
import sys


def main(path="results/benchmarks.json"):
    rows = json.load(open(path))
    tables = {}
    for r in rows:
        t = r.get("table")
        if t:
            tables.setdefault(t, []).append(r)
    out = ["\n## §Tables — paper-table reproductions (synthetic data)\n"]
    for t in sorted(tables):
        out.append(f"### Table {t}\n")
        keys = [k for k in tables[t][0] if k != "table"]
        out.append("| " + " | ".join(keys) + " |")
        out.append("|" + "---|" * len(keys))
        for r in tables[t]:
            out.append("| " + " | ".join(
                f"{r.get(k):.4f}" if isinstance(r.get(k), float)
                else str(r.get(k)) for k in keys) + " |")
        out.append("")
    text = "\n".join(out)
    with open("EXPERIMENTS.md", "a") as f:
        f.write(text)
    print(text)


if __name__ == "__main__":
    main(*sys.argv[1:])
