"""Summarize results/benchmarks.json into the EXPERIMENTS.md §Tables
section (run after `python -m benchmarks.run`), or render a grid metrics
snapshot (`benchmarks.grid_sweep --policy all --metrics-out snap.json`)
as markdown tables:

    python -m benchmarks.summarize                      # EXPERIMENTS.md
    python -m benchmarks.summarize --metrics snap.json  # stdout tables
"""
import argparse
import json
import sys


def render_snapshot(snap: dict) -> str:
    """Markdown tables for one ``MetricsRegistry.snapshot()`` dict."""
    out = []
    counters = snap.get("counters", {})
    if counters:
        out.append("| counter | value | by label |")
        out.append("|---|---|---|")
        for name, c in counters.items():
            by = " ".join(f"{k}={v}" for k, v in c["labels"].items())
            out.append(f"| {name} | {c['value']} | {by} |")
        out.append("")
    gauges = snap.get("gauges", {})
    if gauges:
        out.append("| gauge | value | by label |")
        out.append("|---|---|---|")
        for name, g in gauges.items():
            by = " ".join(f"{k}={v}" for k, v in g["labels"].items())
            out.append(f"| {name} | {g['value']} | {by} |")
        out.append("")
    hists = snap.get("histograms", {})
    if hists:
        out.append("| histogram | count | mean | min | max |")
        out.append("|---|---|---|---|---|")
        for name, h in hists.items():
            out.append(f"| {name} | {h['count']} | {h['mean']:.4g} "
                       f"| {h['min']:.4g} | {h['max']:.4g} |")
        out.append("")
    return "\n".join(out)


def summarize_metrics(path: str) -> None:
    with open(path) as f:
        doc = json.load(f)
    # either one snapshot, or grid_sweep's {cell name -> snapshot} dump
    if "v" in doc and ("counters" in doc or "gauges" in doc):
        doc = {"run": doc}
    for name, snap in doc.items():
        print(f"### {name}\n")
        print(render_snapshot(snap))


def summarize_tables(path: str) -> None:
    rows = json.load(open(path))
    tables = {}
    for r in rows:
        t = r.get("table")
        if t:
            tables.setdefault(t, []).append(r)
    out = ["\n## §Tables — paper-table reproductions (synthetic data)\n"]
    for t in sorted(tables):
        out.append(f"### Table {t}\n")
        keys = [k for k in tables[t][0] if k != "table"]
        out.append("| " + " | ".join(keys) + " |")
        out.append("|" + "---|" * len(keys))
        for r in tables[t]:
            out.append("| " + " | ".join(
                f"{r.get(k):.4f}" if isinstance(r.get(k), float)
                else str(r.get(k)) for k in keys) + " |")
        out.append("")
    text = "\n".join(out)
    with open("EXPERIMENTS.md", "a") as f:
        f.write(text)
    print(text)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", nargs="?", default="results/benchmarks.json",
                    help="benchmark rows to fold into EXPERIMENTS.md")
    ap.add_argument("--metrics", default=None, metavar="SNAPSHOT_JSON",
                    help="render a metrics snapshot (or grid_sweep's "
                         "--metrics-out dump) as tables instead")
    args = ap.parse_args(argv)
    if args.metrics:
        summarize_metrics(args.metrics)
    else:
        summarize_tables(args.path)


if __name__ == "__main__":
    main(sys.argv[1:])
