"""Summarize results/benchmarks.json into the EXPERIMENTS.md §Tables
section (run after `python -m benchmarks.run`), or render a grid metrics
snapshot (`benchmarks.grid_sweep --policy all --metrics-out snap.json`)
as markdown tables:

    python -m benchmarks.summarize                      # EXPERIMENTS.md
    python -m benchmarks.summarize --metrics snap.json  # stdout tables
    python -m benchmarks.summarize --bench              # BENCH_*.json
"""
import argparse
import json
import os
import sys


def render_snapshot(snap: dict) -> str:
    """Markdown tables for one ``MetricsRegistry.snapshot()`` dict."""
    out = []
    counters = snap.get("counters", {})
    if counters:
        out.append("| counter | value | by label |")
        out.append("|---|---|---|")
        for name, c in counters.items():
            by = " ".join(f"{k}={v}" for k, v in c["labels"].items())
            out.append(f"| {name} | {c['value']} | {by} |")
        out.append("")
    gauges = snap.get("gauges", {})
    if gauges:
        out.append("| gauge | value | by label |")
        out.append("|---|---|---|")
        for name, g in gauges.items():
            by = " ".join(f"{k}={v}" for k, v in g["labels"].items())
            out.append(f"| {name} | {g['value']} | {by} |")
        out.append("")
    hists = snap.get("histograms", {})
    if hists:
        out.append("| histogram | count | mean | min | max |")
        out.append("|---|---|---|---|---|")
        for name, h in hists.items():
            out.append(f"| {name} | {h['count']} | {h['mean']:.4g} "
                       f"| {h['min']:.4g} | {h['max']:.4g} |")
        out.append("")
    return "\n".join(out)


def summarize_metrics(path: str) -> None:
    with open(path) as f:
        doc = json.load(f)
    # either one snapshot, or grid_sweep's {cell name -> snapshot} dump
    if "v" in doc and ("counters" in doc or "gauges" in doc):
        doc = {"run": doc}
    for name, snap in doc.items():
        print(f"### {name}\n")
        print(render_snapshot(snap))


BENCH_FILES = ("BENCH_agg.json", "BENCH_fleet.json", "BENCH_grid.json")


def render_bench(docs: dict) -> str:
    """One markdown digest over the committed BENCH_*.json results
    (agg_bench / fleet_bench / grid_sweep), with the headline speedup
    columns side by side."""
    out = ["## Benchmark digest\n"]
    agg = docs.get("BENCH_agg.json")
    if agg:
        h = agg.get("headline", {})
        out.append(f"### Server aggregation (agg_bench, "
                   f"backend={agg.get('backend')})\n")
        if h:
            out.append(f"headline: pipeline={h.get('pipeline')} "
                       f"params={h.get('params')} clients={h.get('clients')} "
                       f"— flat-vs-tree speedup **{h.get('speedup', 0):.2f}x**, "
                       f"fused speedup **{h.get('fused_speedup', 0):.2f}x**\n")
        rows = agg.get("smoke", [])
        if rows:
            out.append("| pipeline | params | clients | tree us | flat us "
                       "| speedup | fused us | fused speedup | route |")
            out.append("|---|---|---|---|---|---|---|---|---|")
            for r in rows:
                out.append(
                    f"| {r['pipeline']} | {r['params']} | {r['clients']} "
                    f"| {r['tree_us']:.0f} | {r['flat_us']:.0f} "
                    f"| {r['speedup']:.2f}x | {r.get('fused_us', 0):.0f} "
                    f"| {r.get('fused_speedup', 0):.2f}x "
                    f"| {r.get('route', '-')} |")
            out.append("")
    fleet = docs.get("BENCH_fleet.json")
    if fleet:
        h = fleet.get("headline", {})
        out.append(f"### Fleet state (fleet_bench, "
                   f"preset={fleet.get('preset')})\n")
        if h:
            out.append(f"headline: {h.get('cell')} @ {h.get('clients')} "
                       f"clients — vectorized speedup "
                       f"**{h.get('speedup', 0):.1f}x**\n")
        rows = fleet.get("cells", [])
        if rows:
            out.append("| cell | clients | object us | vector us | speedup |")
            out.append("|---|---|---|---|---|")
            for r in rows:
                out.append(f"| {r['cell']} | {r['clients']} "
                           f"| {r['object_us']:.0f} | {r['vector_us']:.0f} "
                           f"| {r['speedup']:.1f}x |")
            out.append("")
    grid = docs.get("BENCH_grid.json")
    if grid:
        out.append(f"### Selection-policy sweep (grid_sweep, "
                   f"fleet={grid.get('fleet')}, "
                   f"target loss {grid.get('target')})\n")
        rows = grid.get("policy_cells", [])
        if rows:
            base = rows[0].get("vt_to_target_s") or 0.0
            out.append("| policy | vt to target (s) | vs uniform | hit "
                       "| final loss | virtual s | wire MB | uploads |")
            out.append("|---|---|---|---|---|---|---|---|")
            for r in rows:
                vt = r.get("vt_to_target_s")
                rel = (f"{base / vt:.2f}x" if vt else "-")
                out.append(
                    f"| {r['policy']} | {vt:.2f} | {rel} | {r['hit']} "
                    f"| {r['loss']:.4g} | {r['virtual_s']:.2f} "
                    f"| {r['wire_mb']:.4f} | {r['uploads']} |")
            out.append("")
    if len(out) == 1:
        out.append("(no BENCH_*.json files found)\n")
    return "\n".join(out)


def summarize_bench(root: str = ".") -> None:
    docs = {}
    for name in BENCH_FILES:
        p = os.path.join(root, name)
        if os.path.exists(p):
            with open(p) as f:
                docs[name] = json.load(f)
    print(render_bench(docs))


def summarize_tables(path: str) -> None:
    rows = json.load(open(path))
    tables = {}
    for r in rows:
        t = r.get("table")
        if t:
            tables.setdefault(t, []).append(r)
    out = ["\n## §Tables — paper-table reproductions (synthetic data)\n"]
    for t in sorted(tables):
        out.append(f"### Table {t}\n")
        keys = [k for k in tables[t][0] if k != "table"]
        out.append("| " + " | ".join(keys) + " |")
        out.append("|" + "---|" * len(keys))
        for r in tables[t]:
            out.append("| " + " | ".join(
                f"{r.get(k):.4f}" if isinstance(r.get(k), float)
                else str(r.get(k)) for k in keys) + " |")
        out.append("")
    text = "\n".join(out)
    with open("EXPERIMENTS.md", "a") as f:
        f.write(text)
    print(text)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", nargs="?", default="results/benchmarks.json",
                    help="benchmark rows to fold into EXPERIMENTS.md")
    ap.add_argument("--metrics", default=None, metavar="SNAPSHOT_JSON",
                    help="render a metrics snapshot (or grid_sweep's "
                         "--metrics-out dump) as tables instead")
    ap.add_argument("--bench", action="store_true",
                    help="render the committed BENCH_agg/fleet/grid.json "
                         "results as one digest with headline speedups")
    ap.add_argument("--bench-root", default=".",
                    help="directory holding the BENCH_*.json files")
    args = ap.parse_args(argv)
    if args.bench:
        summarize_bench(args.bench_root)
    elif args.metrics:
        summarize_metrics(args.metrics)
    else:
        summarize_tables(args.path)


if __name__ == "__main__":
    main(sys.argv[1:])
