"""Aggregation-tail bench: old per-leaf tree path vs the flat-buffer path.

Times ONE server aggregation (the per-round hot path the flat subsystem
replaces) over a clients x params grid, for four pipeline flavours:

* ``mean``  — weighted mean only (DP and quantization off);
* ``clip``  — per-client L2 clip + weighted fixed-denominator mean;
* ``dp``    — clip + mean + central Gaussian noise (DP-FedAvg tail);
* ``full``  — int8 fake-quantized uplink + clip + mean + noise (the
  paper's §5 composition — quantization on top of FedPT, under DP).

The *tree* path is the pre-flat engine verbatim: a tree_map sweep per
stage per leaf (vmapped per-client quantize/clip, per-leaf tensordot,
per-leaf noise keys). The *flat* path is what `core.fedpt.make_round_fn`
ships now: deltas are born flat, so each stage is a single op over the
(clients, size) buffer and clipping folds into the aggregation weights.
Both are jitted whole; inputs sit in each path's native layout (the
tree path never pays a flatten, the flat path never pays an unflatten
back — the engine unflattens once per round in both worlds).

Each cell also times the *fused* one-sweep tail
(``kernels.ops.agg_tail`` with the fused path forced) and the
*dispatcher* (``ops.agg_tail`` with its shape- and pipeline-aware
default: fused only for quantized pipelines of at least
:data:`~repro.kernels.ops.AGG_FUSE_THRESHOLD` elements — unquantized
tails are already minimal-sweep, so everything else stays staged),
checks fused-vs-staged parity (bitwise for mean/clip/dp, fp
round-off for full — the int8 coeff route reassociates the dequant
multiply), and reports a bytes-moved / TPU-HBM-roofline column for the
fused sweep (three reads of the client buffer + one output write).

Emits the harness's ``name,us_per_call,derived`` CSV rows and writes
``BENCH_agg.json`` next to the repo root. ``--smoke`` runs a tiny cell
per pipeline, asserts tree/flat agreement AND times it; with ``--gate
BENCH_agg.json`` the smoke timings become a CI regression gate — each
pipeline's flat_us must stay within ``--gate-tolerance`` (default 3x,
generous on purpose: it catches order-of-magnitude regressions, not
shared-runner noise) of the committed baseline's ``smoke`` section,
AND the dispatcher must not lose more than 10% to the staged path at
smoke shapes (the 0.9x no-lose floor: small buffers must keep routing
to the staged program, never the fused stage orchestration).
``--fresh-out`` writes the fresh smoke numbers as JSON (uploaded as a
workflow artifact by CI).

    PYTHONPATH=src python -m benchmarks.agg_bench [--reps 5]
    PYTHONPATH=src python -m benchmarks.agg_bench --smoke \
        [--gate BENCH_agg.json] [--gate-tolerance 3.0] [--fresh-out f.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import roofline
from repro.core import compress, flat as flat_lib
from repro.kernels import ops as kernel_ops
from repro.optim import optimizers as opt_lib

CLIP = 1.0
SIGMA = 0.01


def make_leaf_sizes(target_params: int):
    """Transformer-shaped leaf mix: embedding + unembedding plus decoder
    blocks of [wq, wk, wv, wo, ffn-in, ffn-out, 2 norms, 2 biases] —
    the leaf-count/size distribution the round engine actually sees
    (e.g. the paper's SO NWP model), not a handful of giant arrays."""
    if target_params >= 6_000_000:
        d, vocab = 256, 10_004
    elif target_params >= 2_000_000:
        d, vocab = 128, 10_004
    else:
        d, vocab = 96, 1_004
    block = [d * d, d * d, d * d, d * d,          # attention projections
             d * 4 * d, 4 * d * d,                # FFN
             d, d, d, 4 * d]                      # norms + biases
    sizes = [vocab * d]                           # embedding
    total = sizes[0]
    while total < target_params - vocab * d:
        sizes.extend(block)
        total += sum(block)
    sizes.append(vocab * d)                       # unembedding
    total += sizes[-1]
    return sizes, total


def make_deltas(sizes, clients: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return {f"leaf{i:03d}": jnp.asarray(
        rng.normal(0, 0.05, (clients, s)).astype(np.float32))
        for i, s in enumerate(sizes)}


# ---------------------------------------------------------------------------
# The two aggregation tails. `pipeline` in {"mean", "clip", "dp", "full"}.


def tree_tail(pipeline: str, clients: int, noise: bool = True):
    """The pre-flat engine: per-leaf tree_map sweeps."""

    def run(deltas, w, rng):
        if pipeline == "full":
            deltas = jax.vmap(
                lambda d: compress.fake_quantize_tree(d, 8))(deltas)
        if pipeline != "mean":
            def clip_one(d):
                nrm = opt_lib.tree_global_norm(d)
                s = jnp.minimum(1.0, CLIP / jnp.maximum(nrm, 1e-12))
                return jax.tree_util.tree_map(lambda x: x * s, d), nrm
            deltas, _norms = jax.vmap(clip_one)(deltas)
            wsum = jnp.asarray(float(clients), jnp.float32)
        else:
            wsum = jnp.maximum(jnp.sum(w), 1e-12)
        delta = jax.tree_util.tree_map(
            lambda d: jnp.tensordot(w.astype(jnp.float32),
                                    d.astype(jnp.float32), axes=1) / wsum,
            deltas)
        if noise and pipeline in ("dp", "full"):
            leaves, treedef = jax.tree_util.tree_flatten(delta)
            keys = jax.random.split(rng, len(leaves))
            delta = jax.tree_util.tree_unflatten(treedef, [
                l + SIGMA * jax.random.normal(k, l.shape, jnp.float32)
                for l, k in zip(leaves, keys)])
        return delta

    return run


def flat_tail(pipeline: str, clients: int, layout: flat_lib.FlatLayout,
              noise: bool = True):
    """The flat-buffer engine: single-pass ops over (clients, size)."""

    def run(mat, w, rng):
        if pipeline == "full":
            mat = flat_lib.fake_quantize(mat, layout, 8)
        if pipeline != "mean":
            norms = flat_lib.row_norms(mat, layout.align)
            w = w * jnp.minimum(1.0, CLIP / jnp.maximum(norms, 1e-12))
            wsum = jnp.asarray(float(clients), jnp.float32)
        else:
            wsum = jnp.maximum(jnp.sum(w), 1e-12)
        delta = flat_lib.weighted_mean(mat, w, wsum)
        if noise and pipeline in ("dp", "full"):
            delta = flat_lib.add_noise(delta, SIGMA, rng)
        return delta

    return run


def fused_tail(pipeline: str, clients: int, layout: flat_lib.FlatLayout,
               noise: bool = True, threshold=None):
    """The shipped tail: ``ops.agg_tail``. ``threshold=0`` forces the
    fused one-sweep path, ``threshold=None`` exercises the shape-aware
    dispatcher (what the round engines run). Not wrapped in jax.jit:
    on concrete CPU buffers the fused path orchestrates separately
    jitted stages from Python on purpose (one whole-tail XLA program
    pays a large composition penalty at 10M x 16 — see
    kernels/agg_tail.py)."""
    bl = jnp.asarray(layout.block_leaf(), jnp.int32)
    kw = dict(block_leaf=bl, n_leaves=len(layout.sizes),
              align=layout.align, threshold=threshold)
    if pipeline == "full":
        kw["bits"] = 8
    if pipeline != "mean":
        kw.update(clip_norm=CLIP, uniform=True, wsum_fixed=float(clients))
    noised = noise and pipeline in ("dp", "full")
    if noised:
        kw["sigma"] = SIGMA

    def run(mat, w, rng):
        out, info = kernel_ops.agg_tail(mat, w,
                                        rng=rng if noised else None, **kw)
        return out, info

    return run


def agg_bytes_moved(pipeline: str, params: int, clients: int) -> int:
    """HBM traffic model for the fused sweep over the (clients, params)
    f32 buffer: mean = one GEMV read; clip/dp = stats read + GEMV read;
    full (int8) = stats read + pack read/write(int8) + apply read(int8);
    every pipeline writes the (params,) update once, dp/full also read
    the pre-drawn noise vector."""
    kxs = clients * params
    if pipeline == "mean":
        b = kxs * 4
    elif pipeline in ("clip", "dp"):
        b = kxs * 4 * 2
    else:  # full: f32 stats + f32 pack-read + int8 pack-write + int8 apply
        b = kxs * (4 + 4 + 1 + 1)
    b += params * 4                       # update write
    if pipeline in ("dp", "full"):
        b += params * 4                   # pre-drawn noise read
    return b


def _time(fn, args, reps: int) -> float:
    jax.block_until_ready(fn(*args))          # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def run_cell(pipeline: str, params: int, clients: int, reps: int,
             check: bool = False):
    sizes, total = make_leaf_sizes(params)
    deltas = make_deltas(sizes, clients)
    layout = flat_lib.FlatLayout.of(
        jax.tree_util.tree_map(lambda a: a[0], deltas))
    mat = jnp.stack([layout.flatten(
        jax.tree_util.tree_map(lambda a: a[c], deltas))
        for c in range(clients)])
    w = jnp.asarray(np.linspace(1.0, 2.0, clients), jnp.float32)
    rng = jax.random.key(7)

    tfn = jax.jit(tree_tail(pipeline, clients))
    ffn = jax.jit(flat_tail(pipeline, clients, layout))
    fused = fused_tail(pipeline, clients, layout, threshold=0)
    dispatch = fused_tail(pipeline, clients, layout)
    if check:
        # compare the deterministic part: the two paths draw their DP
        # noise differently by design (one key vs one key per leaf)
        got = layout.unflatten(
            jax.jit(flat_tail(pipeline, clients, layout,
                              noise=False))(mat, w, rng),
            dtype=jnp.float32)
        want = jax.jit(tree_tail(pipeline, clients,
                                 noise=False))(deltas, w, rng)
        tol = 0 if pipeline == "mean" else 1e-5
        for (ka, va), (kb, vb) in zip(
                sorted(got.items()), sorted(want.items())):
            assert ka == kb
            err = float(jnp.max(jnp.abs(va - vb.reshape(va.shape))))
            rel = err / max(float(jnp.max(jnp.abs(vb))), 1e-12)
            assert rel <= tol, (pipeline, ka, rel)
        # fused-vs-staged parity, noise ON (both draw the identical
        # pre-drawn vector): bits==0 pipelines take the exact chunked
        # GEMV route (bitwise contract); full takes the int8 coeff
        # route (fp round-off: the dequant scale folds into the
        # aggregation weight instead of multiplying post-sum)
        f_out, _ = fused(mat, w, rng)
        s_out, _ = fused_tail(pipeline, clients, layout,
                              threshold=1 << 60)(mat, w, rng)
        if pipeline == "full":
            assert np.allclose(np.asarray(f_out), np.asarray(s_out),
                               rtol=1e-4, atol=1e-5), pipeline
        else:
            assert np.array_equal(np.asarray(f_out),
                                  np.asarray(s_out)), pipeline
    t_tree = _time(tfn, (deltas, w, rng), reps)
    t_flat = _time(ffn, (mat, w, rng), reps)
    t_fused = _time(lambda *a: fused(*a)[0], (mat, w, rng), reps)
    t_agg = _time(lambda *a: dispatch(*a)[0], (mat, w, rng), reps)
    route = dispatch(mat, w, rng)[1]["route"]
    nbytes = agg_bytes_moved(pipeline, layout.size, clients)
    return {"pipeline": pipeline, "params": total, "clients": clients,
            "leaves": len(sizes), "tree_us": t_tree * 1e6,
            "flat_us": t_flat * 1e6, "speedup": t_tree / t_flat,
            "fused_us": t_fused * 1e6, "fused_speedup": t_tree / t_fused,
            "agg_us": t_agg * 1e6, "route": route,
            "bytes_moved": nbytes,
            "tpu_roofline_us": nbytes / roofline.HBM * 1e6}


def run_smoke(reps: int):
    cells = []
    for pipeline in ("mean", "clip", "dp", "full"):
        cell = run_cell(pipeline, 300_000, 4, reps=reps, check=True)
        cells.append(cell)
        print(f"agg/smoke/{pipeline},{cell['flat_us']:.0f},"
              f"speedup={cell['speedup']:.2f};leaves={cell['leaves']}"
              f";agg_us={cell['agg_us']:.0f};route={cell['route']}")
        sys.stdout.flush()
    print("smoke OK: flat == tree and fused == staged on every pipeline")
    return cells


def gate_smoke(cells, baseline_path: str, tolerance: float,
               floor_us: float = 20_000.0) -> int:
    """Regression gate: fresh smoke flat_us vs the committed baseline.
    Returns the number of violations (0 = pass).

    The limit is ``max(tolerance * baseline, floor_us)``: smoke cells
    run ~1-20ms, where shared-runner scheduling noise alone spans a few
    x — the absolute floor keeps sub-floor jitter from flaking the gate
    while an order-of-magnitude regression (e.g. a path that silently
    falls back to per-leaf sweeps) still blows through it.

    A second, baseline-free check enforces the dispatcher's no-lose
    floor within the fresh run itself: at smoke shapes ``agg_tail``
    must route to the staged program and cost at most 1/0.9 of the
    plain staged tail (plus the same absolute noise floor) — the
    small-shape clip regression the fused path used to cause can never
    come back silently."""
    with open(baseline_path) as f:
        base = json.load(f)
    ref = {c["pipeline"]: c for c in base.get("smoke", [])}
    if not ref:
        raise SystemExit(
            f"bench gate ERROR: {baseline_path} has no 'smoke' section — "
            "not a performance regression; regenerate the baseline with "
            "--smoke --fresh-out (or the full bench) and commit it")
    bad = 0
    for c in cells:
        b = ref.get(c["pipeline"])
        if b is None:
            raise SystemExit(
                f"bench gate ERROR: baseline {baseline_path} is missing "
                f"pipeline {c['pipeline']!r} — not a performance "
                "regression; regenerate and commit the baseline")
        limit = max(tolerance * b["flat_us"], floor_us)
        verdict = "ok" if c["flat_us"] <= limit else "REGRESSION"
        print(f"gate/{c['pipeline']}: flat {c['flat_us']:.0f}us vs "
              f"baseline {b['flat_us']:.0f}us (limit {limit:.0f}us = "
              f"max({tolerance:g}x, {floor_us:.0f}us floor)) -> {verdict}")
        if c["flat_us"] > limit:
            bad += 1
        # dispatcher no-lose floor (fresh-run-relative, no baseline
        # needed): the shape-aware dispatch must keep small buffers on
        # the staged path, within 0.9x of running it directly
        if "agg_us" in c:
            nl_limit = max(c["flat_us"] / 0.9, floor_us)
            nl_ok = c["agg_us"] <= nl_limit and c["route"] == "staged"
            print(f"gate/{c['pipeline']}/dispatch: agg_tail "
                  f"{c['agg_us']:.0f}us route={c['route']} vs staged "
                  f"{c['flat_us']:.0f}us (limit {nl_limit:.0f}us) -> "
                  f"{'ok' if nl_ok else 'REGRESSION'}")
            if not nl_ok:
                bad += 1
    return bad


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny cells: correctness asserts + quick timings")
    ap.add_argument("--reps", type=int, default=4)
    ap.add_argument("--gate", default=None, metavar="BASELINE_JSON",
                    help="with --smoke: fail if any pipeline's flat_us "
                         "exceeds gate-tolerance x the baseline's smoke "
                         "timing")
    ap.add_argument("--gate-tolerance", type=float, default=3.0)
    ap.add_argument("--gate-floor-us", type=float, default=20_000.0,
                    help="absolute per-cell limit floor (container noise)")
    ap.add_argument("--fresh-out", default=None, metavar="JSON",
                    help="with --smoke: write the fresh smoke cells here")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_agg.json"))
    args = ap.parse_args(argv)

    if args.smoke:
        cells = run_smoke(reps=max(1, min(args.reps, 3)))
        if args.fresh_out:
            with open(args.fresh_out, "w") as f:
                json.dump({"backend": jax.default_backend(),
                           "devices": jax.device_count(),
                           "smoke": cells}, f, indent=1)
            print(f"wrote {args.fresh_out}")
        if args.gate:
            bad = gate_smoke(cells, args.gate, args.gate_tolerance,
                             floor_us=args.gate_floor_us)
            if bad:
                sys.exit(f"bench gate FAILED: {bad} pipeline(s) regressed "
                         f"past {args.gate_tolerance:g}x baseline")
            print("bench gate passed")
        return

    # the full bench also records the smoke cells, so a regenerated
    # BENCH_agg.json always carries the baseline the CI gate compares to
    smoke_cells = run_smoke(reps=args.reps)
    cells = []
    for params in (1_000_000, 4_000_000, 10_000_000):
        for clients in (8, 16):
            for pipeline in ("mean", "clip", "dp", "full"):
                cell = run_cell(pipeline, params, clients, reps=args.reps,
                                check=(params <= 1_000_000))
                cells.append(cell)
                print(f"agg/{pipeline}/p{params // 1_000_000}M/c{clients},"
                      f"{cell['flat_us']:.0f},"
                      f"tree_us={cell['tree_us']:.0f}"
                      f";speedup={cell['speedup']:.2f}"
                      f";fused_us={cell['fused_us']:.0f}"
                      f";fused_speedup={cell['fused_speedup']:.2f}"
                      f";route={cell['route']}"
                      f";roofline_us={cell['tpu_roofline_us']:.0f}"
                      f";leaves={cell['leaves']}")
                sys.stdout.flush()

    def _head(cs):
        c = cs[-1]
        return {"pipeline": c["pipeline"], "params": c["params"],
                "clients": c["clients"], "tree_us": c["tree_us"],
                "flat_us": c["flat_us"], "speedup": c["speedup"],
                "fused_us": c["fused_us"],
                "fused_speedup": c["fused_speedup"]}

    # headline: the paper's full composition at the largest cell, plus
    # the same composition at the paper's own model scale (SO NWP ~4M)
    head = _head([c for c in cells if c["pipeline"] == "full"
                  and c["params"] >= 10_000_000 and c["clients"] == 16])
    paper = _head([c for c in cells if c["pipeline"] == "full"
                   and 2_000_000 <= c["params"] < 10_000_000
                   and c["clients"] == 16])
    best = max((c for c in cells if c["params"] >= 10_000_000
                and c["clients"] == 16), key=lambda c: c["speedup"])
    head_cell = [c for c in cells if c["pipeline"] == "full"
                 and c["params"] >= 10_000_000 and c["clients"] == 16][-1]
    out = {"backend": jax.default_backend(),
           "devices": jax.device_count(),
           "clip": CLIP, "sigma": SIGMA,
           "smoke": smoke_cells,
           "headline": head,
           "paper_scale": paper,
           "best_10M_16c": _head([best]),
           "fused": {"threshold": kernel_ops.AGG_FUSE_THRESHOLD,
                     "headline_fused_speedup": head["fused_speedup"],
                     "headline_fused_us": head["fused_us"],
                     "bytes_moved": head_cell["bytes_moved"],
                     "tpu_roofline_us": head_cell["tpu_roofline_us"]},
           "cells": cells}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"# full @10M/16c: flat {head['speedup']:.2f}x, "
          f"fused {head['fused_speedup']:.2f}x "
          f"({head['tree_us']:.0f}us -> {head['fused_us']:.0f}us); "
          f"full @4M/16c: {paper['speedup']:.2f}x; "
          f"best 10M/16c cell: {best['pipeline']} {best['speedup']:.2f}x; "
          f"wrote {args.out}")


if __name__ == "__main__":
    main()
