"""Reproduction of the paper's Tables 1-5 on synthetic federated data.

Validation contract (EXPERIMENTS.md §Validity):
* communication reductions and trainable-percentages: EXACT parameter
  counting — must match the paper to rounding;
* accuracies: TREND validation (FedPT slightly below fully-trainable,
  gap shrinking as fewer blocks are frozen) — absolute numbers differ
  because the datasets are synthetic stand-ins;
* runtimes: relative per-round CPU times, full vs partial;
* Table 4 peak memory: compiled memory_analysis of the client update —
  the datacenter-simulation analogue of the paper's profiler numbers.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.partition as part
from repro.core import comm, dp, fedpt
from repro.data import synthetic as syn
from repro.fl import runtime
from repro.models import decoder_lm as dlm
from repro.models import paper_models as pm
from repro.optim import optimizers as opt_lib

ROUNDS = {"emnist": 15, "cifar": 4, "so": 25, "dp": 20}
jax.config.update("jax_platform_name", "cpu")


def _img_loss(fwd):
    def loss_fn(params, b):
        logits = fwd(params, b["images"])
        lp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(lp, b["labels"][:, None], 1)), {}
    return loss_fn


def _tok_loss(fwd):
    def loss_fn(params, b):
        logits = fwd(params, b["tokens"])
        return dlm.lm_loss(logits[:, :-1], b["tokens"][:, 1:]), {}
    return loss_fn


def table1_emnist(rounds=ROUNDS["emnist"], seed=0) -> List[Dict]:
    """EMNIST CNN: 4.97% trainable vs 100%."""
    ds = syn.make_federated_images(40, 50, (28, 28, 1), 62, seed=seed,
                                   test_examples=600)
    rows = []
    for spec, label in [(pm.EMNIST_FREEZE, "FedPT(4.97%)"), ((), "FT(100%)")]:
        rc = fedpt.RoundConfig(10, 2, 16, "sgd", 0.05, "sgd", 0.5)
        ev = runtime.accuracy_eval(pm.emnist_cnn_forward, ds.test_images,
                                   ds.test_labels)
        res = runtime.run_federated(lambda s: pm.init_emnist_cnn(s),
                                    _img_loss(pm.emnist_cnn_forward), ds, rc,
                                    rounds, freeze_spec=spec, seed=seed,
                                    eval_every=rounds, eval_fn=ev)
        s = part.summarize(part.merge(res.y, res.frozen), spec)
        rows.append({
            "table": "1-emnist", "variant": label,
            "trainable_pct": round(s["trainable_pct"], 2),
            "comm_reduction": round(res.comm.reduction, 1),
            "accuracy": res.history[-1].get("accuracy"),
            "final_loss": res.history[-1]["loss"],
            "sec_per_round": round(res.seconds_per_round, 3),
        })
    return rows


def table2_cifar(rounds=ROUNDS["cifar"], seed=0) -> List[Dict]:
    """ResNet-18-GN: frozen-stage sweep (largest stage first, Table 10).

    NOTE: cohort/batch are scaled down for the 1-core CPU container —
    the table's exact claims (trainable %, comm reduction) are parameter
    counts and unaffected; accuracy/runtime are trend columns.
    """
    ds = syn.make_federated_images(30, 32, (24, 24, 3), 10, seed=seed,
                                   test_examples=100)
    rows = []
    variants = [((3, 2, 1, 0), "PT(~2%)"), ((3, 2), "PT(~8%)"),
                ((3,), "PT(~26%)"), ((), "FT(100%)")]
    for stages, label in variants:
        spec = pm.resnet18_freeze_spec(stages) if stages else ()
        rc = fedpt.RoundConfig(2, 1, 8, "sgdm", 10 ** -0.5, "sgdm", 0.1)
        ev = runtime.accuracy_eval(pm.resnet18_forward, ds.test_images,
                                   ds.test_labels)
        res = runtime.run_federated(lambda s: pm.init_resnet18(s),
                                    _img_loss(pm.resnet18_forward), ds, rc,
                                    rounds, freeze_spec=spec, seed=seed,
                                    eval_every=rounds, eval_fn=ev)
        s = part.summarize(part.merge(res.y, res.frozen), spec)
        rows.append({
            "table": "2-cifar", "variant": label,
            "trainable_pct": round(s["trainable_pct"], 2),
            "comm_reduction": round(res.comm.reduction, 1),
            "accuracy": res.history[-1].get("accuracy"),
            "final_loss": res.history[-1]["loss"],
            "sec_per_round": round(res.seconds_per_round, 3),
        })
    return rows


def table3_stackoverflow(rounds=ROUNDS["so"], seed=0) -> List[Dict]:
    """SO NWP transformer: FFN freeze sweep (Table 11)."""
    vocab = 2004  # reduced vocab keeps CPU rounds fast; structure identical
    ds = syn.make_federated_tokens(48, 64, vocab=vocab, seed=seed)
    fwd = pm.so_transformer_forward
    rows = []
    for blocks, label in [((0, 1, 2), "PT(~74%)"), ((1, 2), "PT(~83%)"),
                          ((2,), "PT(~91%)"), ((), "FT(100%)")]:
        spec = pm.so_freeze_spec(blocks) if blocks else ()
        rc = fedpt.RoundConfig(16, 2, 16, "adam", 0.1, "sgd", 0.03)
        ev = runtime.nwp_accuracy_eval(fwd, ds.test_tokens[:128])
        res = runtime.run_federated(lambda s: pm.init_so_transformer(s, vocab),
                                    _tok_loss(fwd), ds, rc, rounds,
                                    freeze_spec=spec, seed=seed,
                                    data_kind="tokens",
                                    eval_every=rounds, eval_fn=ev)
        s = part.summarize(part.merge(res.y, res.frozen), spec)
        rows.append({
            "table": "3-stackoverflow", "variant": label,
            "trainable_pct": round(s["trainable_pct"], 2),
            "comm_reduction": round(res.comm.reduction, 2),
            "accuracy": res.history[-1].get("accuracy"),
            "final_loss": res.history[-1]["loss"],
            "sec_per_round": round(res.seconds_per_round, 3),
        })
    return rows


def table4_memory() -> List[Dict]:
    """Peak client-update memory by trainable percentage (ResNet/CIFAR):
    compiled memory_analysis of one client's local training step."""
    rows = []
    for stages, label in [((3, 2, 1, 0), "PT(~2%)"), ((3, 2, 1), "PT(~3%)"),
                          ((3, 2), "PT(~8%)"), ((3,), "PT(~26%)"),
                          ((), "FT(100%)")]:
        spec = pm.resnet18_freeze_spec(stages) if stages else ()
        y, z = part.partition(pm.init_resnet18(0), spec)
        cu = fedpt.make_client_update(_img_loss(pm.resnet18_forward),
                                      opt_lib.sgdm(0.1), 2)
        batch = {"images": jnp.zeros((2, 128, 24, 24, 3)),
                 "labels": jnp.zeros((2, 128), jnp.int32)}
        compiled = jax.jit(cu).lower(y, z, batch).compile()
        mem = compiled.memory_analysis()
        peak = getattr(mem, "peak_memory_in_bytes", None) or \
            getattr(mem, "temp_size_in_bytes", 0)
        s = part.summarize(part.merge(y, z), spec)
        rows.append({"table": "4-memory", "variant": label,
                     "trainable_pct": round(s["trainable_pct"], 2),
                     "peak_mib": round(peak / 2 ** 20, 1)})
    return rows


def table5_dp(rounds=ROUNDS["dp"], seed=0,
              noises=(0.0, 2.33, 8.83)) -> List[Dict]:
    """DP-FTRL on SO NWP: fully vs partially trainable under growing
    noise. The paper's claim: PT degrades less at high noise."""
    vocab = 2004
    ds = syn.make_federated_tokens(48, 64, vocab=vocab, seed=seed)
    fwd = pm.so_transformer_forward
    rows = []
    for blocks, label in [((), "FT"), ((0, 1, 2), "PT")]:
        spec = pm.so_freeze_spec(blocks) if blocks else ()
        for z in noises:
            cfgd = dp.DPFTRLConfig(lr=0.3, noise_multiplier=z, clip_norm=0.3,
                                   clients_per_round=16, momentum=0.9,
                                   seed=seed)
            sopt = dp.dp_ftrl_server_opt(cfgd)
            rc = fedpt.RoundConfig(16, 2, 16, "sgd", 10 ** -0.5, "sgd", 1.0,
                                   dp_clip_norm=0.3, uniform_weights=True)
            ev = runtime.nwp_accuracy_eval(fwd, ds.test_tokens[:128])
            res = runtime.run_federated(
                lambda s: pm.init_so_transformer(s, vocab), _tok_loss(fwd),
                ds, rc, rounds, freeze_spec=spec, seed=seed,
                data_kind="tokens", eval_every=rounds, eval_fn=ev,
                server_opt=sopt)
            rows.append({"table": "5-dp", "variant": label,
                         "noise": z, "epsilon": dp.NOISE_TO_EPS.get(z),
                         "accuracy": res.history[-1].get("accuracy"),
                         "final_loss": res.history[-1]["loss"]})
    return rows
