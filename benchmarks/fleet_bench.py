"""Fleet-scale bench: per-object DeviceProfile paths vs FleetState arrays.

Times the two per-run fleet hot paths the struct-of-arrays refactor
(``sim/devices.FleetState``) replaces, at 10^3 / 10^4 / 10^5 clients:

* ``build``  — fleet construction. The *object* path materializes one
  ``DeviceProfile`` dataclass per client (the pre-FleetState world; the
  lazy ``fleet.profiles`` view makes it reproducible here), the
  *vector* path builds the preset's ``(N,)`` arrays only.
* ``cohort`` — one over-selected synchronous cohort draw (10% of the
  fleet): availability/dropout screens, per-member round trips and
  arrival-order participant selection. The *object* path is the old
  per-member event-heap loop verbatim (one ``fleet.profile(c)`` +
  scalar arithmetic + heap push per member); the *vector* path is
  ``sim/scheduler.plan_sync_round`` — one RNG call per draw kind and
  array ops end to end. The two consume identical RNG streams and agree
  bitwise (asserted in --smoke).

Emits the harness's ``name,us_per_call,derived`` CSV rows and writes
``BENCH_fleet.json`` next to the repo root. ``--smoke`` runs one tiny
cell per kind, asserts object/vector agreement AND times it; with
``--gate BENCH_fleet.json`` the smoke timings become a CI regression
gate — each cell's vector_us must stay within ``--gate-tolerance``
(default 3x, generous on purpose) of the committed baseline, with an
absolute ``--gate-floor-us`` under which jitter never flakes the gate.
``--scale`` is the CI scale smoke: build a 100k-client FleetState, draw
10 cohorts through the vectorized planner, then run 2 hierarchical
rounds (4 edge regions + a region shock) on the probe model, all under
a hard wall-clock budget.

    PYTHONPATH=src python -m benchmarks.fleet_bench [--reps 5]
    PYTHONPATH=src python -m benchmarks.fleet_bench --smoke \
        [--gate BENCH_fleet.json] [--gate-tolerance 3.0] [--fresh-out f.json]
    PYTHONPATH=src python -m benchmarks.fleet_bench --scale \
        [--budget-seconds 300]
"""
from __future__ import annotations

import argparse
import heapq
import json
import math
import os
import sys
import time

import numpy as np

from repro.sim import devices as dev_lib
from repro.sim import scheduler as sched_lib

PRESET = "pareto-mobile"
DOWN_BYTES = 120_008
UP_BYTES = 120_000
COMPUTE_SECONDS = 0.2


def per_object_plan(fleet, cids, clients_needed: int,
                    rng: np.random.Generator, deadline: float = math.inf):
    """The pre-vectorization sync-round planner, verbatim semantics: one
    DeviceProfile materialization + scalar arithmetic + event-heap push
    per cohort member. Consumes the same fixed-count RNG vectors as
    ``plan_sync_round``, so the two agree bitwise."""
    cids = np.asarray(cids, np.int64)
    m = len(cids)
    avail_u = rng.random(m)
    drop_u = rng.random(m)
    arrival = np.full(m, math.inf)
    heap = []
    for i in range(m):
        p = fleet.profile(int(cids[i]))
        if not avail_u[i] < p.availability:
            continue
        if drop_u[i] < p.dropout:
            continue
        t = p.round_trip_seconds(DOWN_BYTES, UP_BYTES, COMPUTE_SECONDS)
        arrival[i] = t
        heapq.heappush(heap, (t, i))
    participant = np.zeros(m, bool)
    round_seconds, taken = 0.0, 0
    while heap and taken < clients_needed:
        t, i = heapq.heappop(heap)
        if t > deadline:
            break
        participant[i] = True
        round_seconds = t
        taken += 1
    return participant, arrival, float(round_seconds)


def _time(fn, reps: int) -> float:
    fn()                                      # warm (allocators, caches)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run_build_cell(clients: int, reps: int):
    def vector():
        return dev_lib.make_fleet(clients, PRESET, seed=0)

    def obj():
        # the pre-FleetState world: preset draws PLUS one DeviceProfile
        # dataclass per client
        return list(dev_lib.make_fleet(clients, PRESET, seed=0).profiles)

    t_obj = _time(obj, reps)
    t_vec = _time(vector, reps)
    return {"cell": "build", "clients": clients, "object_us": t_obj * 1e6,
            "vector_us": t_vec * 1e6, "speedup": t_obj / t_vec}


def run_cohort_cell(clients: int, reps: int, check: bool = False):
    fleet = dev_lib.make_fleet(clients, PRESET, seed=0)
    m = max(64, clients // 10)
    needed = max(1, m // 2)
    cohort_rng = np.random.default_rng(7)
    cids = cohort_rng.integers(0, clients, m)

    def vector():
        return sched_lib.plan_sync_round(
            fleet, cids, DOWN_BYTES, UP_BYTES, COMPUTE_SECONDS, needed,
            np.random.default_rng(11))

    def obj():
        return per_object_plan(fleet, cids, needed,
                               np.random.default_rng(11))

    if check:
        plan = vector()
        participant, arrival, round_seconds = obj()
        assert np.array_equal(plan.participant, participant), \
            "vectorized participant set diverged from the per-object loop"
        assert np.array_equal(plan.arrival, arrival), \
            "vectorized arrivals diverged from the per-object loop"
        assert plan.round_seconds == round_seconds, \
            (plan.round_seconds, round_seconds)
    t_obj = _time(obj, reps)
    t_vec = _time(vector, reps)
    return {"cell": "cohort", "clients": clients, "cohort": m,
            "object_us": t_obj * 1e6, "vector_us": t_vec * 1e6,
            "speedup": t_obj / t_vec}


def run_smoke(reps: int):
    cells = [run_build_cell(2_000, reps),
             run_cohort_cell(2_000, reps, check=True)]
    for c in cells:
        print(f"fleet/smoke/{c['cell']},{c['vector_us']:.0f},"
              f"object_us={c['object_us']:.0f};speedup={c['speedup']:.2f}")
        sys.stdout.flush()
    print("smoke OK: vectorized cohort plan == per-object loop, bitwise")
    return cells


def gate_smoke(cells, baseline_path: str, tolerance: float,
               floor_us: float = 20_000.0) -> int:
    """Regression gate: fresh smoke vector_us vs the committed baseline
    (same idiom as agg_bench: limit = max(tolerance * baseline,
    floor_us), so shared-runner jitter under the floor never flakes the
    gate while an order-of-magnitude regression still fails)."""
    with open(baseline_path) as f:
        base = json.load(f)
    ref = {c["cell"]: c for c in base.get("smoke", [])}
    if not ref:
        raise SystemExit(
            f"bench gate ERROR: {baseline_path} has no 'smoke' section — "
            "not a performance regression; regenerate the baseline with "
            "--smoke --fresh-out (or the full bench) and commit it")
    bad = 0
    for c in cells:
        b = ref.get(c["cell"])
        if b is None:
            raise SystemExit(
                f"bench gate ERROR: baseline {baseline_path} is missing "
                f"cell {c['cell']!r} — not a performance regression; "
                "regenerate and commit the baseline")
        limit = max(tolerance * b["vector_us"], floor_us)
        verdict = "ok" if c["vector_us"] <= limit else "REGRESSION"
        print(f"gate/{c['cell']}: vector {c['vector_us']:.0f}us vs "
              f"baseline {b['vector_us']:.0f}us (limit {limit:.0f}us = "
              f"max({tolerance:g}x, {floor_us:.0f}us floor)) -> {verdict}")
        if c["vector_us"] > limit:
            bad += 1
    return bad


def run_scale(budget_seconds: float) -> None:
    """The CI scale smoke: 100k-client FleetState + 10 vectorized cohort
    draws, then 2 hierarchical rounds on the probe model — all under one
    hard wall-clock budget. (The dataset stays small: the federated
    image sets materialize per-client arrays eagerly, so the 100k part
    exercises fleet/scheduler scale and the grid part exercises the
    topology machinery.)"""
    import jax
    import jax.numpy as jnp
    from repro.core import fedpt
    from repro.data import synthetic as syn
    from repro.nn import basic
    from repro.sim import grid as grid_lib
    from repro.sim.dynamics import DynamicsConfig, RegionShocks

    t0 = time.perf_counter()
    N = 100_000
    fleet = dev_lib.make_fleet(N, PRESET, seed=0)
    assert len(fleet) == N and fleet.state.downlink_bps.shape == (N,)
    t_build = time.perf_counter() - t0
    print(f"scale/build_100k,{t_build * 1e6:.0f},clients={N}")

    rng = np.random.default_rng(3)
    t1 = time.perf_counter()
    total_participants = 0
    for _ in range(10):
        cids = rng.integers(0, N, 10_000)
        plan = sched_lib.plan_sync_round(
            fleet, cids, DOWN_BYTES, UP_BYTES, COMPUTE_SECONDS, 5_000, rng)
        total_participants += int(np.sum(plan.participant))
    t_draws = time.perf_counter() - t1
    assert total_participants == 50_000, total_participants
    print(f"scale/cohort_draws_10x10k,{t_draws * 1e6:.0f},"
          f"participants={total_participants}")

    def init_fn(seed):
        return {"dense": basic.init_dense(seed, "dense", 64, 4,
                                          jnp.float32, bias=True)}

    def loss_fn(params, b):
        x = b["images"].reshape(b["images"].shape[0], -1)
        lp = jax.nn.log_softmax(basic.dense(x, params["dense"]))
        return -jnp.mean(jnp.take_along_axis(lp, b["labels"][:, None],
                                             1)), {}

    t2 = time.perf_counter()
    ds = syn.make_federated_images(32, 24, (8, 8, 1), 4, seed=0)
    rc = fedpt.RoundConfig(8, 2, 8, "sgd", 0.1, "sgd", 1.0)
    res = grid_lib.run_grid(
        init_fn, loss_fn, ds, rc, 2,
        grid_lib.GridConfig(
            mode="sync", fleet=PRESET, topology=4,
            dynamics=DynamicsConfig(shocks=RegionShocks(
                every=0.5, duration=0.4, residual=0.0))),
        seed=0)
    t_grid = time.perf_counter() - t2
    assert len(res.history) == 2
    ce = res.comm.hop_traffic["client_edge"]
    assert ce["down_bytes"] == res.comm.measured_down_bytes
    assert ce["up_bytes"] == res.comm.measured_up_bytes
    assert "edge_server" in res.comm.hop_traffic
    print(f"scale/hierarchical_2rounds,{t_grid * 1e6:.0f},"
          f"regions=4;hop_up_mb="
          f"{res.comm.hop_table()['edge_server']['up_mb']:.3f}")

    elapsed = time.perf_counter() - t0
    print(f"scale smoke: {elapsed:.1f}s (budget {budget_seconds:.0f}s)")
    if elapsed > budget_seconds:
        sys.exit(f"scale smoke BLEW ITS BUDGET: {elapsed:.1f}s > "
                 f"{budget_seconds:.0f}s wall-clock")
    print("scale smoke passed")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny cells: correctness asserts + quick timings")
    ap.add_argument("--scale", action="store_true",
                    help="100k-client scale smoke under a wall-clock "
                         "budget (the CI scale job)")
    ap.add_argument("--budget-seconds", type=float, default=300.0)
    ap.add_argument("--reps", type=int, default=4)
    ap.add_argument("--gate", default=None, metavar="BASELINE_JSON",
                    help="with --smoke: fail if any cell's vector_us "
                         "exceeds gate-tolerance x the baseline's smoke "
                         "timing")
    ap.add_argument("--gate-tolerance", type=float, default=3.0)
    ap.add_argument("--gate-floor-us", type=float, default=20_000.0,
                    help="absolute per-cell limit floor (container noise)")
    ap.add_argument("--fresh-out", default=None, metavar="JSON",
                    help="with --smoke: write the fresh smoke cells here")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_fleet.json"))
    args = ap.parse_args(argv)

    if args.scale:
        run_scale(args.budget_seconds)
        return

    if args.smoke:
        cells = run_smoke(reps=max(1, min(args.reps, 3)))
        if args.fresh_out:
            with open(args.fresh_out, "w") as f:
                json.dump({"smoke": cells}, f, indent=1)
            print(f"wrote {args.fresh_out}")
        if args.gate:
            bad = gate_smoke(cells, args.gate, args.gate_tolerance,
                             floor_us=args.gate_floor_us)
            if bad:
                sys.exit(f"bench gate FAILED: {bad} cell(s) regressed "
                         f"past {args.gate_tolerance:g}x baseline")
            print("bench gate passed")
        return

    # the full bench also records the smoke cells, so a regenerated
    # BENCH_fleet.json always carries the baseline the CI gate reads
    smoke_cells = run_smoke(reps=args.reps)
    cells = []
    for clients in (1_000, 10_000, 100_000):
        for kind, runner in (("build", run_build_cell),
                             ("cohort", run_cohort_cell)):
            cell = runner(clients, args.reps)
            cells.append(cell)
            print(f"fleet/{kind}/c{clients},{cell['vector_us']:.0f},"
                  f"object_us={cell['object_us']:.0f}"
                  f";speedup={cell['speedup']:.2f}")
            sys.stdout.flush()

    head = next(c for c in cells
                if c["cell"] == "cohort" and c["clients"] == 100_000)
    if head["speedup"] < 10.0:
        sys.exit(f"headline FAILED: cohort draw at 100k clients is only "
                 f"{head['speedup']:.1f}x over the per-object path "
                 "(needs >= 10x)")
    out = {"preset": PRESET,
           "down_bytes": DOWN_BYTES, "up_bytes": UP_BYTES,
           "compute_seconds": COMPUTE_SECONDS,
           "smoke": smoke_cells,
           "headline": head,
           "cells": cells}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"# cohort @100k: {head['speedup']:.1f}x "
          f"({head['object_us']:.0f}us -> {head['vector_us']:.0f}us); "
          f"wrote {args.out}")


if __name__ == "__main__":
    main()
