"""Benchmark aggregator — one function per paper table plus the roofline.

Prints ``name,us_per_call,derived`` CSV rows per the harness contract:
* name: table row identifier
* us_per_call: per-round (train tables) or per-step time in microseconds
* derived: the table's own headline metric(s)

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [--tables 1,2,...]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _emit(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer rounds per table")
    ap.add_argument("--tables", default="1,2,3,4,5,roofline")
    ap.add_argument("--out", default="results/benchmarks.json")
    args = ap.parse_args(argv)

    from benchmarks import tables
    if args.quick:
        tables.ROUNDS.update({"emnist": 6, "cifar": 3, "so": 8, "dp": 6})

    want = set(args.tables.split(","))
    all_rows = []

    def run_table(key, fn):
        if key not in want:
            return
        t0 = time.time()
        rows = fn()
        all_rows.extend(rows)
        for r in rows:
            us = float(r.get("sec_per_round", 0.0)) * 1e6
            derived = ";".join(
                f"{k}={v}" for k, v in r.items()
                if k not in ("table", "variant", "sec_per_round"))
            _emit(f"table{r['table']}/{r['variant']}", us, derived)
        print(f"# table {key} done in {time.time()-t0:.0f}s", file=sys.stderr)

    run_table("1", tables.table1_emnist)
    run_table("2", tables.table2_cifar)
    run_table("3", tables.table3_stackoverflow)
    run_table("4", tables.table4_memory)
    run_table("5", tables.table5_dp)

    if "roofline" in want:
        dry = "results/dryrun_single_pod.json"
        if os.path.exists(dry):
            from benchmarks import roofline
            rows = roofline.build_table(dry)
            all_rows.extend(rows)
            for r in rows:
                if "compute_s" in r:
                    _emit(f"roofline/{r['arch']}/{r['shape']}",
                          max(r['compute_s'], r['memory_s'],
                              r['collective_s']) * 1e6,
                          f"dominant={r['dominant']};"
                          f"useful={r['useful_fraction']:.2f};"
                          f"peak_gib={r['peak_gib']:.2f}")
                else:
                    _emit(f"roofline/{r['arch']}/{r['shape']}", 0.0,
                          f"status={r.get('status')}")
        else:
            print(f"# {dry} missing — run launch/dryrun.py first",
                  file=sys.stderr)

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(all_rows, f, indent=1, default=str)
    print(f"# wrote {args.out}", file=sys.stderr)


if __name__ == "__main__":
    main()
