"""Grid sweep: fleet preset x scheduling mode x freeze spec, plus a
selection-policy axis over the dynamic phone fleet.

For each cell the sweep trains the EMNIST CNN on the simulation grid and
reports **simulated wall-clock to a target loss** — the scenario metric
the analytic ledger cannot produce: it folds together per-device link
speeds and compute, straggler deadlines / buffered async scheduling, and
the measured (serialized) payload bytes that FedPT and int8 uplink
quantization shrink.

Emits the harness's ``name,us_per_call,derived`` CSV rows, where
us_per_call is *virtual* microseconds to the target loss (inf -> the
budget's total virtual time is reported and hit=0 flagged).

    PYTHONPATH=src python -m benchmarks.grid_sweep [--quick] [--target 1.0]

``--policy all`` (or a single policy name) sweeps the
``sim/selection.py`` cohort-selection policies on the
``pareto-mobile-diurnal`` fleet (stochastic links + diurnal
availability) instead of the fleet grid. The policy cells use the
*compact probe model* (one dense layer, the same config the acceptance
test in tests/test_selection.py pins) rather than the EMNIST CNN: at
EMNIST scale over CI-affordable update counts the loss trajectory is
noisy enough that the target-crossing round flips run to run, and —
measured honestly — an active trainability plan's per-tier compute
scaling already equalizes round trips so selection adds little on top
(see README). The probe cells converge in seconds with a stable
1.1-2.1x bandwidth-aware-over-uniform signal across seeds.
``uniform``/``bandwidth-aware`` cells run without a plan (pure
selection effect); ``tier-rotation``/``adaptive-capability`` need one
and carry a 2-tier plan. ``--baseline-out BENCH_grid.json`` writes the
cells as the committed baseline; ``--gate BENCH_grid.json`` turns a
fresh run into a CI regression gate — each policy's virtual time to
target must stay within ``--gate-tolerance`` (default 2x: virtual time
is seed-pinned, but the crossing round can shift with cross-platform
float drift) of the baseline, hit flags must not regress, and
``bandwidth-aware`` must not fall behind ``uniform``.

    PYTHONPATH=src python -m benchmarks.grid_sweep --policy all \
        [--gate BENCH_grid.json] [--baseline-out BENCH_grid.json]

Cell counters (uploads, retries, drops) are read from each run's
metrics snapshot (``GridResult.metrics`` — the registry
``scheduler_stats`` views), not hand-plumbed dicts; ``--metrics-out``
dumps every cell's full snapshot for ``benchmarks.summarize
--metrics``.
"""
from __future__ import annotations

import argparse
import json
import math
import sys

import jax
import jax.numpy as jnp

from repro.core import fedpt
from repro.data import synthetic as syn
from repro.models import paper_models as pm
from repro.sim import GridConfig, run_grid

MB = 1024.0 * 1024.0

FLEETS = ["uniform", "pareto-mobile", "cross-silo"]
SPECS = {"fedpt5pct": pm.EMNIST_FREEZE, "full": ()}

POLICIES = ["uniform", "bandwidth-aware", "tier-rotation",
            "adaptive-capability"]
POLICY_FLEET = "pareto-mobile-diurnal"
# tier policies need a plan; the sampling policies run without one so
# the cell isolates the selection effect (per-tier compute scaling
# otherwise equalizes round trips — see the module docstring)
POLICY_PLAN = {"full": (), "lite": (r"/kernel$",)}
POLICY_NEEDS_PLAN = {"tier-rotation", "adaptive-capability"}


def _loss_fn(params, batch):
    logits = pm.emnist_cnn_forward(params, batch["images"])
    lp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(lp, batch["labels"][:, None], 1)), {}


def _grid_config(mode: str, fleet: str, rounds: int) -> GridConfig:
    if mode == "sync":
        # deadline only bites on the heterogeneous mobile fleet
        deadline = 120.0 if fleet == "pareto-mobile" else math.inf
        return GridConfig(mode="sync", fleet=fleet, over_selection=1.3,
                          straggler_deadline=deadline)
    return GridConfig(mode="async", fleet=fleet, concurrency=12,
                      goal_count=6, staleness="polynomial")


def time_to_target(history, target: float):
    """First virtual time at which the running-min loss crosses target."""
    best = math.inf
    for rec in history:
        best = min(best, rec["loss"])
        if best <= target:
            return rec["virtual_seconds"], True
    return history[-1]["virtual_seconds"] if history else 0.0, False


def _probe_init(seed):
    from repro.nn import basic
    return {"dense": basic.init_dense(seed, "dense", 64, 4, jnp.float32,
                                      bias=True)}


def _probe_loss(params, b):
    from repro.nn import basic
    x = b["images"].reshape(b["images"].shape[0], -1)
    logits = basic.dense(x, params["dense"])
    lp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(lp, b["labels"][:, None], 1)), {}


def run_policy_cells(policies, rounds: int, target: float):
    """One async cell per selection policy on the dynamic phone fleet,
    over the compact probe model (see the module docstring)."""
    ds = syn.make_federated_images(24, 30, (8, 8, 1), 4, seed=0,
                                   test_examples=64)
    rc = fedpt.RoundConfig(4, 2, 8, "sgd", 0.1, "sgd", 1.0)
    cells = []
    snapshots = {}
    for policy in policies:
        gc = GridConfig(mode="async", fleet=POLICY_FLEET, concurrency=8,
                        goal_count=4, staleness="polynomial",
                        plan=(POLICY_PLAN if policy in POLICY_NEEDS_PLAN
                              else None),
                        selection=policy, base_step_time=1.0)
        res = run_grid(_probe_init, _probe_loss, ds, rc, rounds, grid=gc,
                       seed=0)
        vt, hit = time_to_target(res.history, target)
        # the counters come from the run's metrics snapshot — the same
        # registry GridResult.scheduler_stats views, so the committed
        # BENCH_grid.json values are unchanged
        snap = res.metrics.snapshot()
        snapshots[policy] = snap
        counters = snap["counters"]
        cell = {"policy": policy, "vt_to_target_s": vt, "hit": int(hit),
                "loss": res.history[-1]["loss"],
                "virtual_s": res.virtual_seconds,
                "wire_mb": res.comm.measured_total_bytes / MB,
                "uploads": counters["uploads"]["value"],
                "retries": counters["retries"]["value"]}
        cells.append(cell)
        print(f"grid/policy/{policy},{vt * 1e6:.0f},"
              f"hit={cell['hit']};loss={cell['loss']:.3f}"
              f";virt_s={cell['virtual_s']:.0f}"
              f";wire_mb={cell['wire_mb']:.1f}"
              f";uploads={cell['uploads']}"
              f";retries={cell['retries']}")
        sys.stdout.flush()
    return cells, snapshots


def gate_policy_cells(cells, baseline_path: str, tolerance: float,
                      target: float, rounds: int) -> int:
    """Regression gate for the policy axis: fresh virtual time to target
    vs the committed baseline. Returns the number of violations.

    Virtual time is seed-pinned and host-independent up to float drift
    in the loss trajectory (the crossing round can shift by one), so the
    tolerance is generous — the gate catches structural breaks (a
    policy silently falling back to uniform, the dynamics clock
    collapsing), not jitter."""
    with open(baseline_path) as f:
        base = json.load(f)
    # refuse apples-to-oranges comparisons: the baseline records the
    # config it was measured at
    for key, fresh in (("target", target), ("rounds", rounds),
                       ("fleet", POLICY_FLEET)):
        if key in base and base[key] != fresh:
            raise SystemExit(
                f"bench gate ERROR: baseline {baseline_path} was measured "
                f"at {key}={base[key]!r}, this run uses {fresh!r} — not a "
                "performance regression; regenerate the baseline with "
                "--policy all --baseline-out and commit it")
    ref = {c["policy"]: c for c in base.get("policy_cells", [])}
    if not ref:
        raise SystemExit(
            f"bench gate ERROR: {baseline_path} has no 'policy_cells' "
            "section — not a performance regression; regenerate with "
            "--policy all --baseline-out and commit it")
    bad = 0
    for c in cells:
        b = ref.get(c["policy"])
        if b is None:
            raise SystemExit(
                f"bench gate ERROR: baseline {baseline_path} is missing "
                f"policy {c['policy']!r} — regenerate and commit it")
        limit = tolerance * b["vt_to_target_s"]
        ok = c["vt_to_target_s"] <= limit and c["hit"] >= b["hit"]
        print(f"gate/policy/{c['policy']}: vt {c['vt_to_target_s']:.1f}s "
              f"vs baseline {b['vt_to_target_s']:.1f}s (limit "
              f"{limit:.1f}s), hit {c['hit']} (baseline {b['hit']}) -> "
              f"{'ok' if ok else 'REGRESSION'}")
        bad += 0 if ok else 1
    by = {c["policy"]: c for c in cells}
    if "uniform" in by and "bandwidth-aware" in by:
        # the headline structural claim the subsystem exists to make;
        # 15% slack so a one-flush crossing shift from cross-platform
        # float drift cannot flip a genuine win into a gate failure
        limit = 1.15 * by["uniform"]["vt_to_target_s"]
        if by["bandwidth-aware"]["vt_to_target_s"] > limit:
            print("gate/policy/order: bandwidth-aware slower than "
                  "1.15x uniform -> REGRESSION")
            bad += 1
        else:
            print("gate/policy/order: bandwidth-aware <= 1.15x uniform "
                  "-> ok")
    return bad


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--target", type=float, default=1.0,
                    help="client-loss target (initial loss ~ln(62)=4.1)")
    ap.add_argument("--rounds", type=int, default=0,
                    help="server updates per cell (0 = default)")
    ap.add_argument("--policy", default=None, metavar="NAME|all",
                    help="sweep selection policies on the "
                         f"{POLICY_FLEET} fleet instead of the fleet grid")
    ap.add_argument("--policy-target", type=float, default=0.2,
                    help="loss target for the policy cells (probe-model "
                         "initial loss ~ln(4)=1.39; 0.2 is crossed "
                         "within a few updates by every policy)")
    ap.add_argument("--baseline-out", default=None, metavar="JSON",
                    help="with --policy: write the cells as the "
                         "committed BENCH_grid.json baseline")
    ap.add_argument("--metrics-out", default=None, metavar="JSON",
                    help="with --policy: dump each cell's full metrics "
                         "snapshot (render with "
                         "benchmarks.summarize --metrics)")
    ap.add_argument("--gate", default=None, metavar="BASELINE_JSON",
                    help="with --policy: fail if any policy's virtual "
                         "time to target regresses past gate-tolerance "
                         "x the baseline")
    ap.add_argument("--gate-tolerance", type=float, default=2.0)
    args = ap.parse_args(argv)
    rounds = args.rounds or (8 if args.quick else 20)

    if args.policy:
        policies = POLICIES if args.policy == "all" else [args.policy]
        cells, snapshots = run_policy_cells(policies, args.rounds or 15,
                                            args.policy_target)
        if args.metrics_out:
            with open(args.metrics_out, "w") as f:
                json.dump(snapshots, f, indent=1)
            print(f"wrote {args.metrics_out}")
        if args.baseline_out:
            out = {"backend": jax.default_backend(),
                   "fleet": POLICY_FLEET, "target": args.policy_target,
                   "rounds": args.rounds or 15, "seed": 0,
                   "policy_cells": cells}
            with open(args.baseline_out, "w") as f:
                json.dump(out, f, indent=1)
            print(f"wrote {args.baseline_out}")
        if args.gate:
            bad = gate_policy_cells(cells, args.gate, args.gate_tolerance,
                                    args.policy_target, args.rounds or 15)
            if bad:
                sys.exit(f"bench gate FAILED: {bad} policy cell(s) "
                         f"regressed past {args.gate_tolerance:g}x "
                         "baseline")
            print("bench gate passed")
        return

    ds = syn.make_federated_images(40, 50, (28, 28, 1), 62, alpha=1.0)
    rc = fedpt.RoundConfig(10, 2, 16, "sgd", 0.05, "sgd", 0.5,
                           uplink_bits=8)
    for fleet in FLEETS:
        for mode in (["sync"] if args.quick else ["sync", "async"]):
            for spec_name, spec in SPECS.items():
                gc = _grid_config(mode, fleet, rounds)
                res = run_grid(lambda s: pm.init_emnist_cnn(s), _loss_fn,
                               ds, rc, rounds, grid=gc, freeze_spec=spec,
                               seed=0)
                vt, hit = time_to_target(res.history, args.target)
                # both modes emit the same counter schema (explicit
                # zeros for counters that cannot fire), so one snapshot
                # read covers sync and async cells alike
                ctr = res.metrics.snapshot()["counters"]
                drops = (ctr["dropouts"]["value"]
                         + ctr["deadline_drops"]["value"])
                derived = (f"hit={int(hit)}"
                           f";loss={res.history[-1]['loss']:.3f}"
                           f";virt_s={res.virtual_seconds:.0f}"
                           f";wire_mb={res.comm.measured_total_bytes/MB:.1f}"
                           f";uploads={ctr['uploads']['value']}"
                           f";drops={drops}"
                           f";reduction={res.comm.reduction:.1f}x")
                print(f"grid/{fleet}/{mode}/{spec_name},{vt*1e6:.0f},"
                      f"{derived}")
                sys.stdout.flush()


if __name__ == "__main__":
    main()
