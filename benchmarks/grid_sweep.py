"""Grid sweep: fleet preset x scheduling mode x freeze spec.

For each cell the sweep trains the EMNIST CNN on the simulation grid and
reports **simulated wall-clock to a target loss** — the scenario metric
the analytic ledger cannot produce: it folds together per-device link
speeds and compute, straggler deadlines / buffered async scheduling, and
the measured (serialized) payload bytes that FedPT and int8 uplink
quantization shrink.

Emits the harness's ``name,us_per_call,derived`` CSV rows, where
us_per_call is *virtual* microseconds to the target loss (inf -> the
budget's total virtual time is reported and hit=0 flagged).

    PYTHONPATH=src python -m benchmarks.grid_sweep [--quick] [--target 1.0]
"""
from __future__ import annotations

import argparse
import math
import sys

import jax
import jax.numpy as jnp

from repro.core import fedpt
from repro.data import synthetic as syn
from repro.models import paper_models as pm
from repro.sim import GridConfig, run_grid

MB = 1024.0 * 1024.0

FLEETS = ["uniform", "pareto-mobile", "cross-silo"]
SPECS = {"fedpt5pct": pm.EMNIST_FREEZE, "full": ()}


def _loss_fn(params, batch):
    logits = pm.emnist_cnn_forward(params, batch["images"])
    lp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(lp, batch["labels"][:, None], 1)), {}


def _grid_config(mode: str, fleet: str, rounds: int) -> GridConfig:
    if mode == "sync":
        # deadline only bites on the heterogeneous mobile fleet
        deadline = 120.0 if fleet == "pareto-mobile" else math.inf
        return GridConfig(mode="sync", fleet=fleet, over_selection=1.3,
                          straggler_deadline=deadline)
    return GridConfig(mode="async", fleet=fleet, concurrency=12,
                      goal_count=6, staleness="polynomial")


def time_to_target(history, target: float):
    """First virtual time at which the running-min loss crosses target."""
    best = math.inf
    for rec in history:
        best = min(best, rec["loss"])
        if best <= target:
            return rec["virtual_seconds"], True
    return history[-1]["virtual_seconds"] if history else 0.0, False


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--target", type=float, default=1.0,
                    help="client-loss target (initial loss ~ln(62)=4.1)")
    ap.add_argument("--rounds", type=int, default=0,
                    help="server updates per cell (0 = default)")
    args = ap.parse_args(argv)
    rounds = args.rounds or (8 if args.quick else 20)

    ds = syn.make_federated_images(40, 50, (28, 28, 1), 62, alpha=1.0)
    rc = fedpt.RoundConfig(10, 2, 16, "sgd", 0.05, "sgd", 0.5,
                           uplink_bits=8)
    for fleet in FLEETS:
        for mode in (["sync"] if args.quick else ["sync", "async"]):
            for spec_name, spec in SPECS.items():
                gc = _grid_config(mode, fleet, rounds)
                res = run_grid(lambda s: pm.init_emnist_cnn(s), _loss_fn,
                               ds, rc, rounds, grid=gc, freeze_spec=spec,
                               seed=0)
                vt, hit = time_to_target(res.history, args.target)
                st = res.scheduler_stats
                derived = (f"hit={int(hit)}"
                           f";loss={res.history[-1]['loss']:.3f}"
                           f";virt_s={res.virtual_seconds:.0f}"
                           f";wire_mb={res.comm.measured_total_bytes/MB:.1f}"
                           f";uploads={st['uploads']}"
                           f";drops={st['dropouts']+st['deadline_drops']}"
                           f";reduction={res.comm.reduction:.1f}x")
                print(f"grid/{fleet}/{mode}/{spec_name},{vt*1e6:.0f},"
                      f"{derived}")
                sys.stdout.flush()


if __name__ == "__main__":
    main()
