"""Analytic FLOP / byte models per (architecture x shape).

XLA's cost_analysis counts while-loop bodies ONCE (scan-over-layers and
scan-over-time are both loops), so the compiled numbers systematically
undercount deep stacks and SSM time scans. The roofline therefore uses
these closed-form models as the primary compute/memory terms and reports
the measured HLO numbers alongside (benchmarks/roofline.py corrects them
by probe extrapolation).

Conventions: FLOPs are global (whole step, all devices); bytes are
per-device per step, bf16 weights/caches unless stated.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ModelConfig
from repro.models import decoder_lm as dlm
from repro.nn import basic
import jax
import jax.numpy as jnp


def param_counts(cfg: ModelConfig) -> Dict[str, int]:
    """Exact parameter counts from the real init (eval_shape, no alloc)."""
    full = jax.eval_shape(lambda: dlm.init_model(cfg, 0))
    import repro.core.partition as part
    y, z = part.partition(full, cfg.freeze_spec)
    n_all = basic.tree_size(full)
    n_y = basic.tree_size(y)
    return {"total": n_all, "trainable": n_y, "frozen": n_all - n_y}


def active_params(cfg: ModelConfig, counts) -> float:
    """Parameters touched per token (MoE: top-k + shared of each bank)."""
    if cfg.num_experts <= 0:
        return counts["total"]
    full = jax.eval_shape(lambda: dlm.init_model(cfg, 0))
    flat = dict(basic.flatten_params(full))
    expert_leaves = {k: v for k, v in flat.items()
                     if "/moe/wi_" in k or k.endswith("/moe/wo")}
    n_experts_params = sum(int(jnp.prod(jnp.asarray(v.shape)))
                           for v in expert_leaves.values())
    frac = cfg.num_experts_per_tok / cfg.num_experts
    return counts["total"] - n_experts_params * (1.0 - frac)


def attention_flops(cfg: ModelConfig, seq: int, batch: int,
                    cache_len: int = 0, decode: bool = False) -> float:
    """Score+PV flops for all attention layers (excl. projections, which
    live in 2*N*D)."""
    slots, G = dlm.layer_program(cfg)
    n_attn = sum(s.kind == "attn" for s in slots) * G
    hd = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
          if cfg.use_mla else cfg.resolved_head_dim)
    vd = cfg.v_head_dim if cfg.use_mla else cfg.resolved_head_dim
    h = cfg.num_heads
    if decode:
        kv = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
        return 2.0 * batch * h * (hd + vd) * kv * n_attn
    if cfg.sliding_window and cfg.sliding_window < seq:
        pairs = seq * cfg.sliding_window - cfg.sliding_window ** 2 / 2
    else:
        pairs = seq * seq / 2
    return 2.0 * batch * h * (hd + vd) * pairs * n_attn


def ssm_flops(cfg: ModelConfig, seq: int, batch: int) -> float:
    """Recurrent-state update flops for Mamba / mLSTM / sLSTM layers."""
    slots, G = dlm.layer_program(cfg)
    total = 0.0
    import repro.nn.ssm as ssm_lib
    n_mamba = sum(s.kind == "mamba" for s in slots) * G
    if n_mamba:
        di, _ = ssm_lib.mamba_dims(cfg)
        total += 6.0 * batch * seq * di * cfg.mamba_d_state * n_mamba
    n_mlstm = sum(s.kind == "mlstm" for s in slots) * G
    if n_mlstm:
        d_in, nh, dh = ssm_lib.xlstm_dims(cfg)
        # chunkwise: intra-chunk quadratic + state outer products
        chunk = 128
        total += (2.0 * batch * seq * nh * (chunk * dh * 2 + dh * dh * 2)
                  * n_mlstm)
    n_slstm = sum(s.kind == "slstm" for s in slots) * G
    if n_slstm:
        nh = cfg.num_heads
        dh = cfg.d_model // nh
        total += 2.0 * batch * seq * nh * dh * 4 * dh * n_slstm
    return total


@dataclasses.dataclass
class StepModel:
    flops_global: float        # total useful flops for the step
    model_flops: float         # 6*N(_active)*D convention
    bytes_per_device: float    # HBM traffic estimate per device
    coll_hint: str = ""


def analytic_step(cfg: ModelConfig, shape: str, mesh_devices: int = 256,
                  model_axis: int = 16) -> StepModel:
    from repro.launch.specs import SHAPES, serving_config
    info = SHAPES[shape]
    cfg = serving_config(cfg, shape)
    seq, gb = info["seq"], info["global_batch"]
    counts = param_counts(cfg)
    n_act = active_params(cfg, counts)
    pb = counts["total"] * 2.0  # bf16 weight bytes (global)

    if info["kind"] == "train":
        tokens = gb * seq
        mf = 6.0 * n_act * tokens
        fl = mf + 3.0 * attention_flops(cfg, seq, gb) + 3.0 * ssm_flops(cfg, seq, gb)
        # fwd+bwd reads weights ~3x; trainable also written; activations ~
        # 2 bytes x tokens x d x layers x ~12 tensors, sharded over devices
        act = 12.0 * 2.0 * tokens * cfg.d_model * cfg.num_layers / mesh_devices
        by = 3.0 * pb / model_axis + act
        return StepModel(fl, mf, by)

    if info["kind"] == "prefill":
        tokens = gb * seq
        mf = 2.0 * n_act * tokens
        fl = mf + attention_flops(cfg, seq, gb) + ssm_flops(cfg, seq, gb)
        act = 2.0 * 2.0 * tokens * cfg.d_model * cfg.num_layers / mesh_devices
        by = pb / model_axis + act
        return StepModel(fl, mf, by)

    # decode: one token against the cache
    cache_struct = jax.eval_shape(
        lambda: dlm.init_cache(cfg, gb, seq, dtype=jnp.bfloat16))
    cache_bytes = basic.tree_bytes(cache_struct["slots"])
    mf = 2.0 * n_act * gb
    fl = mf + attention_flops(cfg, seq, gb, cache_len=seq, decode=True)
    by = pb / model_axis + cache_bytes / mesh_devices
    return StepModel(fl, mf, by)
