"""Roofline analysis (deliverable g).

For every (arch x shape) on the single-pod mesh, derive:
  compute term    = FLOPs / (chips x 197 TFLOP/s bf16)
  memory term     = HBM bytes / (chips x 819 GB/s)
  collective term = collective bytes / (chips x 50 GB/s/link)

Sources and corrections:
* analytic FLOPs / bytes from benchmarks/analytic.py (primary — XLA's
  cost_analysis counts while-loop bodies once, undercounting scanned
  stacks; see the probe study in EXPERIMENTS.md §Roofline-method);
* collective bytes from the compiled HLO of the dry-run, with while-body
  occurrences scaled by the layer-scan trip count via a 1-group vs
  2-group probe pair (per-group collective bytes = probe difference).

Usage: PYTHONPATH=src python -m benchmarks.roofline \
    --dryrun results/dryrun_single_pod.json --out results/roofline.json
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys

CHIPS = 256
PEAK = 197e12
HBM = 819e9
ICI = 50e9


def roofline_terms(arch: str, shape: str, dry: dict, analytic) -> dict:
    coll = dry.get("collectives") or {}
    base = sum(v["bytes"] - v["in_loop_bytes"] for v in coll.values())
    in_loop = sum(v["in_loop_bytes"] for v in coll.values())
    # trip-count scaling for loop collectives
    from repro.configs.base import get_config
    from repro.models.decoder_lm import layer_program
    from repro.launch.specs import SHAPES, serving_config
    cfg = serving_config(get_config(arch), shape)
    _, G = layer_program(cfg)
    tau = 2 if SHAPES[shape]["kind"] == "train" else 1
    coll_bytes = base + in_loop * G * tau
    t_comp = analytic.flops_global / (CHIPS * PEAK)
    t_mem = analytic.bytes_per_device / HBM
    t_coll = coll_bytes / ICI  # HLO shapes are already per-device shards
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    mf_ratio = (analytic.model_flops / analytic.flops_global
                if analytic.flops_global else 0.0)
    return {
        "arch": arch, "shape": shape, **terms,
        "dominant": dom.replace("_s", ""),
        "model_flops": analytic.model_flops,
        "hlo_flops_per_device": (dry.get("cost") or {}).get("flops"),
        "analytic_flops_global": analytic.flops_global,
        "useful_fraction": mf_ratio,
        "collective_bytes": coll_bytes,
        "peak_gib": (dry.get("memory") or {}).get("peak_bytes", 0) / 2**30,
        "status": dry.get("status"),
    }


def build_table(dryrun_path: str):
    from repro.configs import load_all, ARCH_IDS
    from repro.configs.base import get_config
    from repro.launch.specs import SHAPES, skip_reason
    from benchmarks import analytic as ana

    load_all()
    dry = {(r["arch"], r["shape"]): r
           for r in json.load(open(dryrun_path))}
    rows = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            d = dry.get((arch, shape), {"status": "missing"})
            if d.get("status") == "skip":
                rows.append({"arch": arch, "shape": shape, "status": "skip",
                             "reason": d.get("reason")})
                continue
            if d.get("status") != "ok":
                rows.append({"arch": arch, "shape": shape,
                             "status": d.get("status")})
                continue
            step = ana.analytic_step(get_config(arch), shape)
            rows.append(roofline_terms(arch, shape, d, step))
    return rows


def format_table(rows) -> str:
    out = ["arch,shape,compute_s,memory_s,collective_s,dominant,"
           "useful_frac,peak_gib"]
    for r in rows:
        if r.get("status") != "ok" and "compute_s" not in r:
            out.append(f"{r['arch']},{r['shape']},,,,SKIP,,")
            continue
        out.append(
            f"{r['arch']},{r['shape']},{r['compute_s']:.3e},"
            f"{r['memory_s']:.3e},{r['collective_s']:.3e},{r['dominant']},"
            f"{r['useful_fraction']:.2f},{r['peak_gib']:.2f}")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun_single_pod.json")
    ap.add_argument("--out", default="results/roofline.json")
    args = ap.parse_args(argv)
    rows = build_table(args.dryrun)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(format_table(rows))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
