"""Serving example: autoregressive decoding with the framework's cache
machinery — ring-buffer sliding-window KV cache (Mixtral-style) and
constant-state SSM decode (xLSTM), the mechanisms behind the `long_500k`
dry-run shape.

    PYTHONPATH=src python examples/serve_longcontext.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import load_all
from repro.configs.base import get_config
from repro.launch.serve import generate
from repro.launch.train import reduced_config
from repro.models import decoder_lm as dlm

load_all()

for arch in ["mixtral-8x7b", "xlstm-350m"]:
    cfg = reduced_config(get_config(arch))
    if cfg.sliding_window:
        cfg = cfg.with_(sliding_window=16)  # exercise the ring buffer
    params = dlm.init_model(cfg, 0)
    prompt = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab_size)
    t0 = time.time()
    seqs = generate(params, cfg, prompt, steps=48, max_len=64)
    dt = time.time() - t0
    cache = dlm.init_cache(cfg, 2, 64)
    n_state = sum(x.size for x in jax.tree_util.tree_leaves(cache))
    kind = (f"ring KV cache (window={cfg.sliding_window})"
            if cfg.sliding_window else "constant SSM state")
    print(f"{arch:14s} [{cfg.family}] decoded {seqs.shape[1]-8} tokens/seq "
          f"in {dt:.1f}s via {kind}; cache elements/seq: {n_state//2:,}")
