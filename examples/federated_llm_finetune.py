"""FedPT beyond the paper: federated fine-tuning of a modern LLM family.

Applies the paper's freeze-the-big-blocks recipe to a (reduced) assigned
architecture — e.g. Mixtral-style MoE, where the routed experts freeze
and only router/attention/norms train federated. On the full config this
is the dry-run's train_4k lowering; here a reduced variant trains for
real on CPU.

    PYTHONPATH=src python examples/federated_llm_finetune.py \
        --arch mixtral-8x7b --rounds 8
"""
import argparse

from repro.configs import load_all
from repro.launch.train import run_reduced_arch

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="mixtral-8x7b")
ap.add_argument("--rounds", type=int, default=8)
args = ap.parse_args()

load_all()
res, cfg = run_reduced_arch(args.arch, args.rounds, log=True)
first, last = res.history[0]["loss"], res.history[-1]["loss"]
print(f"\narch={cfg.name} (reduced: {cfg.num_layers}L d={cfg.d_model})")
print(f"loss {first:.3f} -> {last:.3f} over {args.rounds} rounds")
print(f"trainable bytes: {res.comm.trainable_bytes:,} "
      f"({100*res.comm.trainable_bytes/res.comm.full_bytes:.1f}% of model); "
      f"comm reduction {res.comm.reduction:.1f}x")
assert last < first, "federated loss should decrease"
