"""Quickstart: FedPT in ~40 lines.

Trains the paper's EMNIST CNN federated with 95% of parameters frozen
(regenerated from a seed on every client), and shows the communication
ledger — the paper's Table 1 row.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

import repro.core.partition as part
from repro.core import comm, fedpt
from repro.data import synthetic as syn
from repro.models import paper_models as pm

# 1. a federated dataset: 40 clients, Dirichlet(1) label skew
ds = syn.make_federated_images(num_clients=40, examples_per_client=50,
                               shape=(28, 28, 1), num_classes=62, alpha=1.0)

# 2. split the model: trainable y + frozen-from-seed z  (Algorithm 1, l.1)
SEED = 0
y, frozen = part.partition(pm.init_emnist_cnn(SEED), pm.EMNIST_FREEZE)
ledger = comm.report_for(y, frozen)
print(f"trainable: {100 * part.count_params(y) / 1_690_174:.2f}% of params")
print(f"per-round communication reduction: {ledger.reduction:.1f}x "
      f"(paper: 20x)")


# 3. the task loss
def loss_fn(params, batch):
    logits = pm.emnist_cnn_forward(params, batch["images"])
    lp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(lp, batch["labels"][:, None], 1)), {}


# 4. a FedPT round: 10 clients x 2 local SGD steps, server SGD on the
#    aggregated pseudo-gradient (generalized FedAvg)
rc = fedpt.RoundConfig(clients_per_round=10, local_steps=2, local_batch=16,
                       client_opt="sgd", client_lr=0.05,
                       server_opt="sgd", server_lr=0.5)
round_fn, server_opt = fedpt.make_round_fn(loss_fn, rc)
round_fn = jax.jit(round_fn)
sstate = server_opt.init(y)

rng = np.random.default_rng(0)
for r in range(10):
    cids = syn.sample_cohort(rng, ds.num_clients, rc.clients_per_round)
    batch, w = syn.cohort_batch(ds, cids, rc.local_steps, rc.local_batch, rng)
    y, sstate, m = round_fn(y, sstate, frozen, batch, jnp.asarray(w),
                            jax.random.key(r))
    print(f"round {r}: client loss {float(m['loss']):.3f}")

# 5. evaluate the merged model
full = part.merge(y, frozen)
acc = float(jnp.mean(jnp.argmax(pm.emnist_cnn_forward(
    full, ds.test_images), -1) == ds.test_labels))
print(f"test accuracy after 10 rounds: {acc:.3f} (chance {1/62:.3f})")
