"""Asynchronous FedPT on a heterogeneous phone fleet.

The paper's communication reductions (Tables 1-3) matter most where
clients are slow, flaky and bandwidth-bound. This example trains the
EMNIST CNN with 95% of parameters frozen on the "pareto-mobile" fleet —
heavy-tailed link speeds, 80% availability, 10% mid-round dropout —
under FedBuff-style buffered async aggregation (goal count K, staleness
down-weighting), and compares against a synchronous cohort run with a
straggler deadline on the same fleet. Communication is *measured* at the
wire (serialized payload bytes), not estimated.

    PYTHONPATH=src python examples/async_heterogeneous.py

With ``--tiers`` the fleet additionally gets a three-tier trainability
plan (core/plan.py): capable phones train the full trainable tree,
mid-tier phones freeze conv2, weak phones train only the norm + head.
The run is compared against the same fleet all-`full`, with per-tier
wire traffic from the CommReport ledger — the mixed fleet must bill
strictly fewer uplink bytes.

    PYTHONPATH=src python examples/async_heterogeneous.py --tiers

``--trace out.json`` records the async run's full event stream
(obs/trace.py) and writes a Chrome/Perfetto timeline — open it in
https://ui.perfetto.dev to see every client's dispatch->upload round
trip as a span on its own track, with server flushes as instant
markers, all in the grid's *virtual* clock. ``--trace-jsonl out.jsonl``
additionally writes the raw schema-versioned event records (one JSON
object per line; validate with ``python -m repro.obs.schema``).

    PYTHONPATH=src python examples/async_heterogeneous.py \
        --trace trace.json --trace-jsonl trace.jsonl

``--chaos`` turns the fleet hostile (sim/faults.py "chaos" preset:
client crashes, truncated uploads, NaN / bit-flip payload corruption,
duplicate deliveries) and runs it twice: once unscreened — the corrupted
deltas NaN-poison the server model within a few flushes — and once with
the delta-quarantine screen (core/sanitize.py) and periodic grid-state
checkpoints on, which keeps training finite. It then kills the server
mid-run at a virtual time T and resumes from the latest snapshot
(checkpoint/grid_state.py), asserting the resumed history matches the
uninterrupted run exactly.

    PYTHONPATH=src python examples/async_heterogeneous.py --chaos

``--regions`` runs the two-level aggregation topology (sim/topology.py):
clients -> edge aggregators -> server. First a *one-region* hierarchical
run is asserted bit-for-bit identical to the flat grid (the edge
machinery is a billing/verification view; the server reduce is
unchanged), then a 4-region fleet with correlated region shocks
(sim/dynamics.RegionShocks — whole edges go dark together) prints the
per-hop wire ledger: the edge->server hop carries one pre-reduced
buffer per flush per active region instead of one delta per client.
Works with ``--trace``: the timeline gains ``edge_flush`` markers on
the server's "edges" track and ``shock`` markers on "faults".

    PYTHONPATH=src python examples/async_heterogeneous.py --regions

``--chaos --regions`` together run ONE hostile hierarchical fleet
(chaos faults + quarantine + 4-region topology) — the CI telemetry job
uses this as its traced demo. The per-mode assertions (kill/resume,
flat-vs-one-region) are skipped; the combined run just has to stay
finite and produce a consistent trace.

``--report out.md`` renders the traced run as a markdown run report
(obs/report.py: critical path, stragglers, wire ledger, privacy curve,
fault counts — memory telemetry is enabled automatically), and
``--metrics-out snap.json`` dumps the run's MetricsRegistry snapshot
for ``python -m repro.obs.compare`` CI gating.

    PYTHONPATH=src python examples/async_heterogeneous.py \
        --chaos --regions --report report.md --metrics-out snap.json
"""
import argparse
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fedpt
from repro.core.plan import TrainPlan
from repro.data import synthetic as syn
from repro.models import paper_models as pm
from repro.nn import basic
from repro.obs.trace import TelemetryConfig
from repro.sim import GridConfig, run_grid
from repro.sim import faults as faults_lib

MB = 1024.0 * 1024.0

parser = argparse.ArgumentParser(description=__doc__)
parser.add_argument("--tiers", action="store_true",
                    help="mixed-tier trainability plan vs all-full")
parser.add_argument("--chaos", action="store_true",
                    help="fault-injected fleet: unscreened vs quarantined, "
                         "plus a kill/checkpoint/resume demo")
parser.add_argument("--regions", action="store_true",
                    help="hierarchical client->edge->server aggregation: "
                         "one-region vs flat bit-for-bit, then a 4-region "
                         "fleet with correlated region shocks and the "
                         "per-hop wire ledger")
parser.add_argument("--rounds", type=int, default=12,
                    help="server updates per run (CI smoke uses fewer)")
parser.add_argument("--trace", default=None, metavar="JSON",
                    help="write a Perfetto timeline of the async run "
                         "(open in ui.perfetto.dev)")
parser.add_argument("--trace-jsonl", default=None, metavar="JSONL",
                    help="also write the raw schema-versioned event "
                         "stream as JSONL")
parser.add_argument("--report", default=None, metavar="MD",
                    help="write a markdown run report of the traced run "
                         "(obs/report.py; enables memory telemetry)")
parser.add_argument("--metrics-out", default=None, metavar="JSON",
                    help="dump the traced run's MetricsRegistry snapshot "
                         "as JSON (for repro.obs.compare)")
args = parser.parse_args()

ds = syn.make_federated_images(num_clients=40, examples_per_client=50,
                               shape=(28, 28, 1), num_classes=62, alpha=1.0)


def loss_fn(params, batch):
    logits = pm.emnist_cnn_forward(params, batch["images"])
    lp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(lp, batch["labels"][:, None], 1)), {}


# int8-quantized uplink on top of FedPT (the paper's §5: complementary)
rc = fedpt.RoundConfig(clients_per_round=10, local_steps=2, local_batch=16,
                       client_opt="sgd", client_lr=0.05,
                       server_opt="sgd", server_lr=0.5, uplink_bits=8)

# tier 0 trains the whole (dense1-frozen) trainable tree; weaker tiers
# freeze progressively more of it and upload progressively less
TIERS = TrainPlan.of({
    "full": (),
    "mid": (r"^conv2/",),
    "lite": (r"^conv1/", r"^conv2/"),
})

CKPT_DIR = None
if args.chaos and args.regions:
    # the combined hostile-hierarchical demo the CI telemetry job traces:
    # chaos faults + quarantine screen + 4-region edge topology in one
    # run; the per-mode assertions below are skipped
    ASYNC = dict(mode="async", fleet="pareto-mobile", concurrency=12,
                 goal_count=6, staleness="polynomial")
    RUNS = {
        "async chaos + regions": GridConfig(**ASYNC, faults="chaos",
                                            sanitize=True, topology=4),
    }
elif args.chaos:
    CKPT_DIR = tempfile.mkdtemp(prefix="chaos_ckpt_")
    ASYNC = dict(mode="async", fleet="pareto-mobile", concurrency=12,
                 goal_count=6, staleness="polynomial")
    RUNS = {
        "async unscreened": GridConfig(**ASYNC,
                                       faults={"corrupt_nan": 0.5}),
        "async chaos + quarantine": GridConfig(**ASYNC, faults="chaos",
                                               sanitize=True,
                                               checkpoint_every=2,
                                               checkpoint_dir=CKPT_DIR),
    }
elif args.regions:
    from repro.sim import DynamicsConfig, RegionShocks
    ASYNC = dict(mode="async", fleet="pareto-mobile", concurrency=12,
                 goal_count=6, staleness="polynomial")
    RUNS = {
        "async flat": GridConfig(**ASYNC),
        "async one-region": GridConfig(**ASYNC, topology=1),
        "async 4 regions + shocks": GridConfig(
            **ASYNC, topology=4,
            # the toy fleet's whole run spans a few virtual seconds, so
            # the outage process is scaled to match (real deployments:
            # think hours between shocks, minutes of darkness)
            dynamics=DynamicsConfig(shocks=RegionShocks(
                every=0.8, duration=1.2, residual=0.0))),
    }
elif args.tiers:
    RUNS = {
        "async all-full": GridConfig(mode="async", fleet="pareto-mobile",
                                     concurrency=12, goal_count=6,
                                     staleness="polynomial"),
        "async tiered": GridConfig(mode="async", fleet="pareto-mobile",
                                   concurrency=12, goal_count=6,
                                   staleness="polynomial", plan=TIERS),
    }
else:
    RUNS = {
        "sync + deadline": GridConfig(mode="sync", fleet="pareto-mobile",
                                      over_selection=1.3,
                                      straggler_deadline=120.0),
        "async (FedBuff)": GridConfig(mode="async", fleet="pareto-mobile",
                                      concurrency=12, goal_count=6,
                                      staleness="polynomial"),
    }

if args.trace or args.trace_jsonl or args.report:
    # trace the last (async) run: with --tiers that is the tiered fleet,
    # otherwise the FedBuff run; --report only needs memory retention
    traced = list(RUNS)[-1]
    RUNS[traced] = dataclasses.replace(
        RUNS[traced], telemetry=TelemetryConfig(
            jsonl_path=args.trace_jsonl, perfetto_path=args.trace))

results = {}
for name, gc in RUNS.items():
    res = run_grid(lambda s: pm.init_emnist_cnn(s), loss_fn, ds, rc,
                   rounds=args.rounds, grid=gc,
                   freeze_spec=pm.EMNIST_FREEZE, seed=0)
    results[name] = res
    st = res.scheduler_stats
    print(f"\n== {name} on fleet '{res.fleet.name}' ==")
    print(f"  loss {res.history[0]['loss']:.3f} -> "
          f"{res.history[-1]['loss']:.3f} over {len(res.history)} updates")
    print(f"  simulated wall-clock: {res.virtual_seconds:,.0f} s "
          f"({res.virtual_seconds / max(len(res.history), 1):.0f} s/update)")
    print(f"  dispatches {st['dispatches']}, uploads {st['uploads']}, "
          f"dropouts {st['dropouts']}, offline {st['offline']}, "
          f"deadline drops {st['deadline_drops']}")
    if res.mode == "async":
        stale = [h["staleness_max"] for h in res.history]
        print(f"  staleness max seen: {max(stale):.0f} "
              f"(down-weighted 1/sqrt(1+s))")
    print(f"  measured wire traffic: "
          f"{res.comm.measured_down_bytes / MB:.2f} MB down, "
          f"{res.comm.measured_up_bytes / MB:.2f} MB up "
          f"across {res.comm.transfers} transfers")
    print(f"  analytic ledger: {res.comm.reduction:.1f}x reduction vs "
          f"full-model FedAvg (uplink alone {res.comm.uplink_reduction:.1f}x)")
    if res.telemetry is not None:
        counts = res.telemetry.kind_counts()
        print("  telemetry: " + " ".join(
            f"{k}={counts[k]}" for k in sorted(counts)))
        if args.trace:
            print(f"  wrote Perfetto timeline -> {args.trace} "
                  "(open in ui.perfetto.dev)")
        if args.trace_jsonl:
            print(f"  wrote event stream -> {args.trace_jsonl}")
    if res.faults is not None:
        print("  faults: " + " ".join(
            f"{k}={v}" for k, v in res.faults.items()))
    if res.tier_stats:
        print("  tier      clients  dispatches  uploads      up KiB  "
              "KiB/upload")
        for tname, rec in res.tier_stats.items():
            per = rec["up_bytes_per_upload"] / 1024.0
            print(f"  {tname:<9s} {rec['clients']:>7d} {rec['transfers']:>11d}"
                  f" {rec['uploads']:>8d} {rec['up_bytes'] / 1024.0:>11.1f}"
                  f" {per:>11.2f}")

if args.tiers:
    full = results["async all-full"].comm.measured_up_bytes
    mixed = results["async tiered"].comm.measured_up_bytes
    print(f"\nmixed-tier uplink: {mixed / MB:.2f} MB vs all-full "
          f"{full / MB:.2f} MB "
          f"({(1.0 - mixed / max(full, 1)) * 100.0:.0f}% less)")
    assert mixed < full, "tiered fleet must bill fewer uplink bytes"

if args.regions and not args.chaos:
    def _flat_y(y):
        return np.concatenate([np.asarray(v).ravel()
                               for _, v in basic.flatten_params(y)])

    flat, one = results["async flat"], results["async one-region"]
    # the one-region hierarchy is the flat grid, bit for bit: same
    # history, same final model, same schedule — only the billing view
    # (the hop ledger) is new
    assert [h["loss"] for h in flat.history] \
        == [h["loss"] for h in one.history], \
        "one-region history must match the flat grid exactly"
    assert [h["virtual_seconds"] for h in flat.history] \
        == [h["virtual_seconds"] for h in one.history]
    assert np.array_equal(_flat_y(flat.y), _flat_y(one.y)), \
        "one-region model must match the flat grid bitwise"
    assert flat.scheduler_stats == one.scheduler_stats
    assert flat.comm.measured_up_bytes == one.comm.measured_up_bytes
    print("\none-region hierarchy == flat grid, bit for bit "
          f"({len(one.history)} updates, "
          f"{one.comm.hop_traffic['edge_server']['uploads']} edge flushes)")

    sh = results["async 4 regions + shocks"]
    ce = sh.comm.hop_traffic["client_edge"]
    assert ce["down_bytes"] == sh.comm.measured_down_bytes
    assert ce["up_bytes"] == sh.comm.measured_up_bytes
    es = sh.comm.hop_traffic["edge_server"]
    assert es["uploads"] > 0
    print("\nper-hop wire ledger (4 regions, correlated shocks):")
    print("  hop           down MB     up MB  transfers  uploads")
    for hop, rec in sh.comm.hop_table().items():
        print(f"  {hop:<12s} {rec['down_mb']:>8.2f}  {rec['up_mb']:>8.2f}"
              f"  {rec['transfers']:>9d}  {rec['uploads']:>7d}")
    reg_up = sh.metrics.counter("region_uploads").labels
    print("  uploads by region: " + " ".join(
        f"edge{k}={v}" for k, v in sorted(reg_up.items())))
    print(f"  edge->server carries {es['uploads']} pre-reduced buffers "
          f"vs {ce['uploads'] or sh.scheduler_stats['uploads']} client "
          "deltas on the first hop")

if args.chaos and not args.regions:
    def _flat(y):
        return np.concatenate([np.asarray(v).ravel()
                               for _, v in basic.flatten_params(y)])

    poisoned = results["async unscreened"]
    screened = results["async chaos + quarantine"]
    assert not np.all(np.isfinite(_flat(poisoned.y))), \
        "unscreened corrupt uploads should NaN-poison the model"
    assert np.all(np.isfinite(_flat(screened.y))), \
        "the quarantine screen must keep the model finite"
    assert screened.faults["quarantined"] > 0
    print(f"\nunscreened model is NaN-poisoned; quarantine zeroed "
          f"{screened.faults['quarantined']} corrupt rows and kept "
          f"training finite (final loss "
          f"{screened.history[-1]['loss']:.3f})")

    # kill the server mid-run, restore the latest snapshot, continue:
    # the resumed run must reproduce the uninterrupted one exactly
    h = screened.history
    T = 0.5 * (h[-2]["virtual_seconds"] + h[-1]["virtual_seconds"])
    killed_gc = dataclasses.replace(
        RUNS["async chaos + quarantine"],
        faults=dataclasses.replace(faults_lib.resolve_faults("chaos"),
                                   server_kill_at=T),
        telemetry=None)
    try:
        run_grid(lambda s: pm.init_emnist_cnn(s), loss_fn, ds, rc,
                 rounds=args.rounds, grid=killed_gc,
                 freeze_spec=pm.EMNIST_FREEZE, seed=0)
        raise AssertionError("server_kill_at should have fired")
    except faults_lib.ServerKilled as e:
        print(f"server killed at t={e.at:,.0f}s after {e.applied} "
              f"updates; resuming from {e.checkpoint}")
        resumed_gc = dataclasses.replace(
            RUNS["async chaos + quarantine"], telemetry=None,
            resume_from=e.checkpoint)
        resumed = run_grid(lambda s: pm.init_emnist_cnn(s), loss_fn, ds, rc,
                           rounds=args.rounds, grid=resumed_gc,
                           freeze_spec=pm.EMNIST_FREEZE, seed=0)
    assert [r["loss"] for r in resumed.history] == \
        [r["loss"] for r in screened.history], \
        "resumed history must match the uninterrupted run"
    assert np.array_equal(_flat(resumed.y), _flat(screened.y)), \
        "resumed model must match the uninterrupted run bitwise"
    print(f"resume OK: {len(resumed.history)} updates, history and final "
          "model match the uninterrupted run exactly")

if args.chaos and args.regions:
    combined = results["async chaos + regions"]
    flat_y = np.concatenate([np.asarray(v).ravel()
                             for _, v in basic.flatten_params(combined.y)])
    assert np.all(np.isfinite(flat_y)), \
        "quarantine must keep the hostile hierarchical run finite"
    es = combined.comm.hop_traffic["edge_server"]
    print(f"\nchaos+regions: quarantined "
          f"{combined.faults['quarantined']} corrupt rows, "
          f"{es['uploads']} edge->server buffers, final loss "
          f"{combined.history[-1]['loss']:.3f}")

if args.report or args.metrics_out:
    import json

    traced_res = results[list(RUNS)[-1]]
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(traced_res.metrics.snapshot(), f, indent=1)
        print(f"\nwrote metrics snapshot -> {args.metrics_out}")
    if args.report:
        from repro.obs import report as report_lib

        text = report_lib.build_report(
            traced_res.telemetry, metrics=traced_res.metrics.snapshot())
        with open(args.report, "w") as f:
            f.write(text)
        print(f"wrote run report -> {args.report} "
              f"({len(text.splitlines())} lines)")
