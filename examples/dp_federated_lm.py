"""Differentially-private FedPT on the Stack Overflow NWP transformer —
the paper's §4.2 experiment: DP-FTRL server with per-client clipping, on
the partially trainable model (FFN hidden layers of all 3 encoder blocks
frozen, 73.8% trainable).

    PYTHONPATH=src python examples/dp_federated_lm.py [--noise 2.33]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.partition as part
from repro.core import dp, fedpt
from repro.data import synthetic as syn
from repro.fl import runtime
from repro.models import decoder_lm as dlm
from repro.models import paper_models as pm

ap = argparse.ArgumentParser()
ap.add_argument("--noise", type=float, default=2.33)
ap.add_argument("--rounds", type=int, default=20)
ap.add_argument("--fully-trainable", action="store_true")
args = ap.parse_args()

VOCAB = 2004
ds = syn.make_federated_tokens(48, 64, vocab=VOCAB, seed=0)
spec = () if args.fully_trainable else pm.so_freeze_spec((0, 1, 2))


def loss_fn(params, b):
    logits = pm.so_transformer_forward(params, b["tokens"])
    return dlm.lm_loss(logits[:, :-1], b["tokens"][:, 1:]), {}


# DP-FTRL server optimizer: privatized cumulative sums via tree noise
dcfg = dp.DPFTRLConfig(lr=0.3, noise_multiplier=args.noise, clip_norm=0.3,
                       clients_per_round=16, momentum=0.9)
sopt = dp.dp_ftrl_server_opt(dcfg)
rc = fedpt.RoundConfig(16, 2, 16, "sgd", 10 ** -0.5, "sgd", 1.0,
                       dp_clip_norm=0.3, uniform_weights=True)

res = runtime.run_federated(
    lambda s: pm.init_so_transformer(s, VOCAB), loss_fn, ds, rc, args.rounds,
    freeze_spec=spec, data_kind="tokens", server_opt=sopt, log=True,
    eval_every=args.rounds,
    eval_fn=runtime.nwp_accuracy_eval(pm.so_transformer_forward,
                                      ds.test_tokens[:128]))

eps = dp.NOISE_TO_EPS.get(args.noise, "n/a")
label = "FT" if args.fully_trainable else "PT(73.8%)"
print(f"\n{label}  noise={args.noise} (paper eps~{eps}): "
      f"acc={res.history[-1].get('accuracy'):.4f} "
      f"loss={res.history[-1]['loss']:.3f} "
      f"comm reduction={res.comm.reduction:.2f}x")
