"""Adaptive tiered freezing on a live fleet (the paper's §5 future work).

Weak devices should train less of the model than strong ones — but
*which* devices are weak is something the server can only learn from the
wire. This demo runs the ``adaptive-capability`` selection policy
(``sim/selection.py``) on the ``pareto-mobile-diurnal`` fleet: phones
with heavy-tailed link speeds, per-device stochastic link jitter + RTT
floors, and a diurnal availability cycle (``sim/dynamics.py``). The
policy starts from the static capability->tier split, then re-tiers the
fleet every few server updates from an EMA of *observed* round-trip
times — devices whose links turn out slower than their profile promised
get demoted to lighter tiers (smaller uploads, cheaper local compute),
and the per-tier clock + wire ledger show the effect.

    PYTHONPATH=src python examples/adaptive_tiers.py [--rounds N]

(For the static-tier grid — capability assignment frozen for the run —
see examples/async_heterogeneous.py --tiers.)
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fedpt
from repro.core.plan import TrainPlan
from repro.data import synthetic as syn
from repro.models import paper_models as pm
from repro.sim import GridConfig, run_grid
from repro.sim.selection import AdaptiveCapabilityPolicy

parser = argparse.ArgumentParser(description=__doc__)
parser.add_argument("--rounds", type=int, default=16,
                    help="async server updates (CI smoke uses fewer)")
parser.add_argument("--refit-every", type=int, default=4,
                    help="re-tier the fleet every N server updates")
args = parser.parse_args()

ds = syn.make_federated_images(num_clients=40, examples_per_client=50,
                               shape=(28, 28, 1), num_classes=62, alpha=1.0)


def loss_fn(params, batch):
    logits = pm.emnist_cnn_forward(params, batch["images"])
    lp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(lp, batch["labels"][:, None], 1)), {}


rc = fedpt.RoundConfig(clients_per_round=10, local_steps=2, local_batch=16,
                       client_opt="sgd", client_lr=0.05,
                       server_opt="sgd", server_lr=0.5, uplink_bits=8)

TIERS = TrainPlan.of({
    "full": (),
    "mid": (r"^conv2/",),
    "lite": (r"^conv1/", r"^conv2/"),
})

policy = AdaptiveCapabilityPolicy(refit_every=args.refit_every, ema=0.4)
gc = GridConfig(mode="async", fleet="pareto-mobile-diurnal",
                concurrency=12, goal_count=6, staleness="polynomial",
                plan=TIERS, selection=policy)

res = run_grid(lambda s: pm.init_emnist_cnn(s), loss_fn, ds, rc,
               rounds=args.rounds, grid=gc, freeze_spec=pm.EMNIST_FREEZE,
               seed=0)

static_map = np.asarray(policy._tiers)
final_map = np.asarray(policy.current_tiers())
moved = int(np.sum(static_map != final_map))
names = list(TIERS.names)

print(f"== adaptive-capability on fleet '{res.fleet.name}' ==")
print(f"  loss {res.history[0]['loss']:.3f} -> "
      f"{res.history[-1]['loss']:.3f} over {len(res.history)} updates, "
      f"{res.virtual_seconds:,.0f} virtual seconds")
st = res.scheduler_stats
print(f"  dispatches {st['dispatches']}, uploads {st['uploads']}, "
      f"dropouts {st['dropouts']}, dark-window retries {st['retries']}")
print(f"  re-tiered {policy.refits}x from observed RTTs: {moved}/"
      f"{len(final_map)} clients moved tier")
print("  census static  -> "
      f"{dict(zip(names, map(int, np.bincount(static_map, minlength=3))))}")
print("  census adapted -> "
      f"{dict(zip(names, map(int, np.bincount(final_map, minlength=3))))}")
print("  tier   clients  uploads  up KiB/upload  compute s  rtt mean s")
for tname, rec in res.tier_stats.items():
    print(f"  {tname:<6s} {rec['clients']:>7d} {rec['uploads']:>8d}"
          f" {rec['up_bytes_per_upload'] / 1024.0:>14.2f}"
          f" {rec['compute_seconds']:>10.4f} {rec['rtt_mean']:>11.2f}")

# the feedback loop must actually be live: the scheduler reported real
# round trips back (observe() fired) and they moved the EMA off its
# profile-seeded estimates — comparing final_map buckets against
# ema_rtt alone would be vacuous, since the map IS the quantile split
# of that array
assert policy.observed.any(), "no upload ever reached observe()"
seed_est = np.asarray(policy.rtt_estimate, np.float64)
assert not np.allclose(policy.ema_rtt[policy.observed],
                       seed_est[policy.observed]), \
    "observed EMAs never moved off the static profile estimates"
# and the split consumed those measurements: the final map is the
# quantile split of the EMAs as of the LAST refit (observations after
# it keep moving ema_rtt, so compare against the policy's snapshot)
from repro.sim.devices import quantile_tiers  # noqa: E402
np.testing.assert_array_equal(final_map,
                              quantile_tiers(1.0 / policy.refit_ema, 3))
print("OK: adaptive re-tiering follows observed round-trip times")
