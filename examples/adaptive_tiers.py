"""Beyond-paper: adaptive tiered freezing (the paper's §5 future work).

Three device tiers share one federated model: powerful clients train all
non-frozen blocks, constrained clients freeze progressively more. The
per-leaf mask-weighted aggregation keeps every block learning from the
clients that can afford it, and each tier pays only its own uplink.

    PYTHONPATH=src python examples/adaptive_tiers.py

(This drives the original leaf-level prototype in core/adaptive.py on a
hand-rolled loop. For tiers over the full simulation grid — capability
-> tier assignment, tier-grouped lanes, per-tier wire billing — see
`GridConfig.plan` and examples/async_heterogeneous.py --tiers.)
"""
import jax
import jax.numpy as jnp
import numpy as np

import repro.core.partition as part
from repro.core import adaptive, fedpt
from repro.data import synthetic as syn
from repro.models import paper_models as pm

TIERS = [(), (r"^dense2/",), (r"^dense2/", r"^conv2/")]
TIER_NAMES = ["full", "mid (dense2 frozen)", "low (+conv2 frozen)"]

ds = syn.make_federated_images(30, 50, (28, 28, 1), 62, seed=0)
y, frozen = part.partition(pm.init_emnist_cnn(0), pm.EMNIST_FREEZE)

for name, rep in zip(TIER_NAMES,
                     adaptive.tier_comm_report(y, frozen, TIERS)):
    print(f"tier {name:24s} uplink {rep.upload_fedpt/1024:8.1f} KiB/round "
          f"(total reduction {rep.reduction:.1f}x)")


def loss_fn(params, b):
    logits = pm.emnist_cnn_forward(params, b["images"])
    lp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(lp, b["labels"][:, None], 1)), {}


rc = fedpt.RoundConfig(9, 2, 16, "sgd", 0.05, "sgd", 0.5)
round_fn, sopt = adaptive.make_tiered_round_fn(loss_fn, rc, TIERS)
round_fn = jax.jit(round_fn)
ss = sopt.init(y)
rng = np.random.default_rng(0)
tier_of_client = rng.integers(0, 3, ds.num_clients)  # device census

for r in range(8):
    cids = syn.sample_cohort(rng, ds.num_clients, 9)
    batch, w = syn.cohort_batch(ds, cids, 2, 16, rng)
    tiers = jnp.asarray(tier_of_client[cids], jnp.int32)
    y, ss, m = round_fn(y, ss, frozen, batch, jnp.asarray(w), tiers,
                        jax.random.key(r))
    print(f"round {r}: cohort tiers {np.bincount(tiers, minlength=3)} "
          f"delta_norm={float(m['delta_norm']):.4f}")

acc = float(jnp.mean(jnp.argmax(pm.emnist_cnn_forward(
    part.merge(y, frozen), ds.test_images), -1) == ds.test_labels))
print(f"test accuracy: {acc:.3f} (chance {1/62:.3f})")
