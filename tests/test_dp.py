"""Differential-privacy mechanisms: clipping invariants (hypothesis),
tree-noise determinism and popcount variance scaling, DP-FTRL server
behaviour, and the FedPT dimension-reduction effect on noise energy.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import dp, fedpt
from repro.optim import optimizers as opt_lib


@given(st.floats(0.01, 100.0), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_clip_bounds_norm(clip, seed):
    tree = {"a": jax.random.normal(jax.random.key(seed % 997), (37,)) * 5,
            "b": {"c": jax.random.normal(jax.random.key(seed % 991), (5, 7))}}
    clipped, nrm = fedpt.clip_delta(tree, clip)
    n2 = opt_lib.tree_global_norm(clipped)
    assert float(n2) <= clip * (1 + 1e-5)
    if float(nrm) <= clip:  # no-op when inside the ball
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(clipped)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6)


def test_tree_noise_deterministic_and_popcount_variance():
    key = jax.random.key(0)
    tree = {"w": jnp.zeros((4096,))}
    n1 = dp.tree_noise(key, tree, sigma=1.0, t=5)
    n2 = dp.tree_noise(key, tree, sigma=1.0, t=5)
    assert bool((n1["w"] == n2["w"]).all())
    # popcount scaling: var(t) ~ popcount(t) * sigma^2
    for t, pc in [(1, 1), (3, 2), (7, 3), (8, 1), (15, 4)]:
        n = dp.tree_noise(key, tree, sigma=1.0, t=t)
        var = float(jnp.var(n["w"]))
        assert abs(var - pc) < 0.35 * pc + 0.1, (t, pc, var)


def test_dp_ftrl_noise_free_matches_momentum_descent():
    cfg = dp.DPFTRLConfig(lr=0.1, noise_multiplier=0.0, clip_norm=1.0,
                          clients_per_round=10, momentum=0.0)
    opt = dp.dp_ftrl_server_opt(cfg)
    params = {"w": jnp.ones((4,))}
    state = opt.init(params)
    g = {"w": jnp.full((4,), 0.5)}
    p1, state = opt.update(params, g, state)
    np.testing.assert_allclose(np.asarray(p1["w"]), 1.0 - 0.1 * 0.5, rtol=1e-6)
    p2, state = opt.update(p1, g, state)
    np.testing.assert_allclose(np.asarray(p2["w"]), 1.0 - 2 * 0.1 * 0.5,
                               rtol=1e-6)


def test_dp_round_noise_only_touches_trainable():
    """FedPT's Table-5 mechanism: noise lands on y only — the frozen side
    has no aggregation path at all."""

    def loss(params, b):
        return jnp.sum(params["y"]["w"] ** 2) * 0.0, {}

    rc = fedpt.RoundConfig(4, 1, 1, "sgd", 0.1, "sgd", 1.0,
                           dp_clip_norm=1.0, dp_noise_multiplier=1.0)
    round_fn, sopt = fedpt.make_round_fn(loss, rc)
    y = {"y": {"w": jnp.zeros((16,))}}
    frozen = {"z": jnp.zeros((16,))}
    batch = {"x": jnp.zeros((4, 1, 1))}
    y2, _, _ = jax.jit(round_fn)(y, sopt.init(y), frozen, batch,
                                 jnp.ones((4,)), jax.random.key(0))
    # zero gradient -> update is pure noise, and it is non-zero on y
    assert float(jnp.abs(y2["y"]["w"]).sum()) > 0


def test_noise_energy_scales_with_trainable_dim():
    """Same multiplier, fewer coordinates -> less total noise energy:
    the quantitative core of the paper's DP claim."""
    key = jax.random.key(1)
    sigma = 1.0
    full = {"a": jnp.zeros((1000,)), "b": jnp.zeros((9000,))}
    pt = {"a": jnp.zeros((1000,))}
    nf = dp.tree_noise(key, full, sigma, t=3)
    np_ = dp.tree_noise(key, pt, sigma, t=3)
    ef = sum(float(jnp.sum(x ** 2)) for x in jax.tree_util.tree_leaves(nf))
    ep = sum(float(jnp.sum(x ** 2)) for x in jax.tree_util.tree_leaves(np_))
    assert ep < ef / 5.0


# ---------------------------------------------------------------------------
# Per-flush async DP (FlushDPConfig / FlushAccountant)


def test_flush_dp_config_sigma():
    cfg = dp.FlushDPConfig(clip_norm=0.5, noise_multiplier=2.0,
                           goal_count=10)
    assert cfg.sensitivity == pytest.approx(0.05)
    assert cfg.sigma == pytest.approx(0.1)
    with pytest.raises(ValueError):
        dp.FlushDPConfig(clip_norm=0.0, noise_multiplier=1.0, goal_count=5)
    with pytest.raises(ValueError):
        dp.FlushDPConfig(clip_norm=1.0, noise_multiplier=1.0, goal_count=0)


def test_flush_accountant_composition():
    cfg = dp.FlushDPConfig(clip_norm=1.0, noise_multiplier=1.13,
                           goal_count=5)
    acc = dp.FlushAccountant(cfg)
    assert acc.epsilon() == 0.0
    eps = []
    for t in range(1, 21):
        acc.record_flush(n_real=5)
        eps.append(acc.epsilon(1e-5))
    # epsilon grows monotonically with flushes, sublinearly (RDP)
    assert all(a < b for a, b in zip(eps, eps[1:]))
    assert eps[-1] < 20 * eps[0]
    # more noise -> less epsilon for the same T
    quiet = dp.FlushAccountant(dp.FlushDPConfig(1.0, 4.0, 5))
    for _ in range(20):
        quiet.record_flush(5)
    assert quiet.epsilon(1e-5) < eps[-1]
    # z = 0 is unbounded
    loud = dp.FlushAccountant(dp.FlushDPConfig(1.0, 0.0, 5))
    loud.record_flush(5)
    assert loud.epsilon() == float("inf")


def test_flush_accountant_multiplicity_scales_sensitivity():
    """A client owning m rows of one flush moves the mean by m x the
    single-row sensitivity: the accountant composes m^2 in RDP, so the
    reported epsilon must exceed the distinct-contributors bound."""
    cfg = dp.FlushDPConfig(clip_norm=1.0, noise_multiplier=2.0,
                           goal_count=8)
    distinct, repeated = dp.FlushAccountant(cfg), dp.FlushAccountant(cfg)
    for _ in range(10):
        distinct.record_flush(8, multiplicity=1)
        repeated.record_flush(8, multiplicity=2)
    assert repeated.epsilon(1e-5) > distinct.epsilon(1e-5)
    assert repeated.max_multiplicity == 2
    with pytest.raises(ValueError):
        distinct.record_flush(8, multiplicity=0)


def test_flush_accountant_multiplicity_sensitivity_is_quadratic():
    """Multiplicity m composes as m^2 in RDP: epsilon grows monotonically
    in m, and one m=2 flush costs exactly what four m=1 flushes cost
    (2^2 = 4 in the sum), matching the m * clip/goal_count sensitivity."""
    cfg = dp.FlushDPConfig(clip_norm=1.0, noise_multiplier=2.0,
                           goal_count=8)
    eps = []
    for m in (1, 2, 3, 4):
        acc = dp.FlushAccountant(cfg)
        for _ in range(6):
            acc.record_flush(8, multiplicity=m)
        eps.append(acc.epsilon(1e-5))
    assert eps[0] < eps[1] < eps[2] < eps[3]
    one_m2 = dp.FlushAccountant(cfg)
    one_m2.record_flush(8, multiplicity=2)
    four_m1 = dp.FlushAccountant(cfg)
    for _ in range(4):
        four_m1.record_flush(8, multiplicity=1)
    assert one_m2.epsilon(1e-5) == pytest.approx(four_m1.epsilon(1e-5))
    assert one_m2.flushes == 1 and four_m1.flushes == 4


def test_flush_accountant_repeated_client_stream():
    """A realistic repeated-client stream: flushes record the observed
    per-flush multiplicity as they come; the summary reports the max and
    the epsilon reflects the whole stream, not only the worst flush."""
    cfg = dp.FlushDPConfig(clip_norm=0.5, noise_multiplier=1.5,
                           goal_count=4)
    acc = dp.FlushAccountant(cfg)
    for m in (1, 1, 2, 1, 3, 1):
        acc.record_flush(4, multiplicity=m)
    s = acc.summary(1e-5)
    assert s["flushes"] == 6 and s["max_multiplicity"] == 3
    # strictly between the all-m=1 and all-m=3 compositions
    lo, hi = dp.FlushAccountant(cfg), dp.FlushAccountant(cfg)
    for _ in range(6):
        lo.record_flush(4, multiplicity=1)
        hi.record_flush(4, multiplicity=3)
    assert lo.epsilon(1e-5) < s["epsilon"] < hi.epsilon(1e-5)


def test_flush_accountant_padding_spends_same_budget():
    """A padded (drained) flush is the SAME mechanism — sigma and the
    per-flush epsilon cost do not depend on the fill."""
    cfg = dp.FlushDPConfig(clip_norm=1.0, noise_multiplier=2.0,
                           goal_count=8)
    a, b = dp.FlushAccountant(cfg), dp.FlushAccountant(cfg)
    for _ in range(4):
        a.record_flush(n_real=8)
        b.record_flush(n_real=2)         # heavily padded flushes
    assert a.epsilon(1e-5) == b.epsilon(1e-5)
    assert b.padded_flushes == 4 and a.padded_flushes == 0
    assert a.summary()["sigma"] == b.summary()["sigma"]


def test_buffered_apply_fixed_denominator_and_noise():
    """make_buffered_apply under flush DP: mean divides by goal_count
    regardless of weights, and the noise is one sigma-scaled draw."""
    from repro.core import flat as flat_lib
    y = {"w": jnp.zeros((300,), jnp.float32)}
    layout = flat_lib.FlatLayout.of(y)
    K = 4
    cfg = dp.FlushDPConfig(clip_norm=1.0, noise_multiplier=0.5,
                           goal_count=K)
    apply_fn = fedpt.make_buffered_apply(opt_lib.sgd(1.0), flush_dp=cfg)
    rows = jnp.stack([layout.flatten({"w": jnp.full((300,), float(i + 1))})
                      for i in range(K)])
    w = jnp.asarray([1.0, 1.0, 0.0, 0.0])
    rng = jax.random.key(0)
    y2, _, _ = apply_fn(y, opt_lib.sgd(1.0).init(y), rows, w, rng)
    # mean = (1*1 + 1*2 + 0 + 0) / K = 0.75 on every true slot
    noise = flat_lib.add_noise(layout.zeros(), cfg.sigma, rng)
    want = 0.75 + layout.unflatten(noise, jnp.float32)["w"]
    np.testing.assert_allclose(np.asarray(y2["w"]), np.asarray(want),
                               rtol=1e-6, atol=1e-7)
    # rng is required when noise is on
    with pytest.raises(ValueError, match="rng"):
        apply_fn(y, opt_lib.sgd(1.0).init(y), rows, w)
