"""FedPT core invariants: partition/merge round-trip, seed reconstruction,
aggregation equivalence with a sequential reference, frozen-param
immutability, and communication accounting against the paper's tables.
"""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.core.partition as part
import repro.core.reconstruct as rec
from repro.core import comm, fedpt
from repro.models import paper_models as pm
from repro.nn import basic
from repro.optim import optimizers as opt_lib

INIT = lambda s: pm.init_emnist_cnn(s)


# ---------------------------------------------------------------------------
# partition / merge


@given(st.integers(0, 2**31 - 1),
       st.sets(st.sampled_from(["conv1", "conv2", "dense1", "dense2", "gn"]),
               max_size=4))
@settings(max_examples=10, deadline=None)
def test_partition_merge_roundtrip(seed, frozen_names):
    spec = tuple(rf"^{n}/" for n in sorted(frozen_names))
    full = INIT(seed % 1000)
    y, z = part.partition(full, spec)
    merged = part.merge(y, z)
    fa = dict(basic.flatten_params(full))
    fb = dict(basic.flatten_params(merged))
    assert set(fa) == set(fb)
    for k in fa:
        assert bool((fa[k] == fb[k]).all())
    # disjointness
    ky = set(dict(basic.flatten_params(y)))
    kz = set(dict(basic.flatten_params(z)))
    assert not (ky & kz)
    assert all(any(re.search(p, k) for p in spec) for k in kz)


def test_reconstruct_is_exact_and_dce_friendly():
    assert rec.verify_roundtrip(INIT, 7, pm.EMNIST_FREEZE)
    r1 = rec.reconstruct(INIT, 7, pm.EMNIST_FREEZE)
    # the jitted reconstructor is bit-stable across calls (what clients
    # rely on); jit-vs-eager may differ by an ulp (fma fusion), so the
    # cross-path check is allclose.
    recon = rec.make_reconstructor(INIT, 7, pm.EMNIST_FREEZE)
    r2a, r2b = recon(), recon()
    assert jax.tree_util.tree_all(jax.tree_util.tree_map(
        lambda a, b: bool((a == b).all()), r2a, r2b))
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                                rtol=1e-6, atol=1e-8), r1, r2a)
    r3 = rec.reconstruct(INIT, 8, pm.EMNIST_FREEZE)
    assert not jax.tree_util.tree_all(jax.tree_util.tree_map(
        lambda a, b: bool((a == b).all()), r1, r3))


# ---------------------------------------------------------------------------
# round engine vs sequential reference


def _quadratic_loss(params, batch):
    # simple strongly-convex loss so local SGD has closed behaviour
    flat = dict(basic.flatten_params(params))
    loss = 0.0
    for k, v in flat.items():
        loss = loss + jnp.sum((v - batch["target"]) ** 2)
    return loss, {}


def test_round_matches_sequential_reference():
    spec = (r"^dense1/",)
    y, z = part.partition(INIT(0), spec)
    rc = fedpt.RoundConfig(3, 2, 1, "sgd", 0.01, "sgd", 1.0)
    round_fn, sopt = fedpt.make_round_fn(_quadratic_loss, rc)
    C, tau = 3, 2
    batch = {"target": jnp.arange(C * tau, dtype=jnp.float32).reshape(
        C, tau, 1) / 10.0}
    w = jnp.asarray([1.0, 2.0, 3.0])
    y2, _, _ = jax.jit(round_fn)(y, sopt.init(y), z, batch, w,
                                 jax.random.key(0))

    # sequential reference
    cu = fedpt.make_client_update(_quadratic_loss, opt_lib.sgd(0.01), tau)
    deltas = [cu(y, z, {"target": batch["target"][i]})[0] for i in range(C)]
    agg = jax.tree_util.tree_map(
        lambda *ds: sum(wi * d for wi, d in zip(w, ds)) / float(jnp.sum(w)),
        *deltas)
    y_ref = jax.tree_util.tree_map(lambda a, d: a + d, y, agg)
    for (ka, va), (kb, vb) in zip(basic.flatten_params(y2),
                                  basic.flatten_params(y_ref)):
        assert ka == kb
        np.testing.assert_allclose(np.asarray(va), np.asarray(vb),
                                   rtol=2e-5, atol=2e-6)


def test_frozen_never_updated_end_to_end():
    from repro.data import synthetic as syn
    ds = syn.make_federated_images(8, 20, (28, 28, 1), 62, seed=1)

    def loss_fn(params, b):
        logits = pm.emnist_cnn_forward(params, b["images"])
        lp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(lp, b["labels"][:, None], 1)), {}

    y, z = part.partition(INIT(0), pm.EMNIST_FREEZE)
    z0 = jax.tree_util.tree_map(lambda a: a.copy(), z)
    rc = fedpt.RoundConfig(4, 2, 8, "sgd", 0.05, "sgd", 0.5)
    round_fn, sopt = fedpt.make_round_fn(loss_fn, rc)
    ss = sopt.init(y)
    rngnp = np.random.default_rng(0)
    for r in range(3):
        cids = syn.sample_cohort(rngnp, 8, 4)
        batch, w = syn.cohort_batch(ds, cids, 2, 8, rngnp)
        y, ss, m = jax.jit(round_fn)(y, ss, z, batch, jnp.asarray(w),
                                     jax.random.key(r))
        assert np.isfinite(float(m["loss"]))
    assert jax.tree_util.tree_all(jax.tree_util.tree_map(
        lambda a, b: bool((a == b).all()), z, z0))


# ---------------------------------------------------------------------------
# communication accounting — the paper's exact numbers


def test_comm_reduction_matches_paper_tables():
    # Table 1: EMNIST 4.97% trainable, 20x
    y, z = part.partition(INIT(0), pm.EMNIST_FREEZE)
    s = part.summarize(part.merge(y, z), pm.EMNIST_FREEZE)
    assert s["total_params"] == 1_690_174
    assert abs(s["trainable_pct"] - 4.97) < 0.01
    assert abs(comm.report_for(y, z).reduction - 20.1) < 0.2

    # Table 3: SO NWP 91.3 / 82.6 / 73.8 % trainable
    sop = pm.init_so_transformer(0)
    for blocks, want in [((2,), 91.3), ((1, 2), 82.6), ((0, 1, 2), 73.8)]:
        s = part.summarize(sop, pm.so_freeze_spec(blocks))
        assert abs(s["trainable_pct"] - want) < 0.45, (blocks, s)

    # Table 2 schedule is monotone decreasing in trainable share
    rn = pm.init_resnet18(0)
    pcts = [part.summarize(rn, pm.resnet18_freeze_spec(st))["trainable_pct"]
            for st in [(3,), (3, 2), (3, 2, 1), (3, 2, 1, 0)]]
    assert all(a > b for a, b in zip(pcts, pcts[1:]))
    assert abs(pcts[0] - 26.25) < 1.0 and pcts[-1] < 3.0


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import checkpoint as ckpt
    y, z = part.partition(INIT(3), pm.EMNIST_FREEZE)
    sopt = opt_lib.sgdm(0.5)
    ss = sopt.init(y)
    p = str(tmp_path / "ck.npz")
    ckpt.save(p, y, seed=3, freeze_spec=pm.EMNIST_FREEZE, server_state=ss,
              round_num=11)
    y2, seed, spec, ss2, rnd, _ = ckpt.load(p, server_state_template=ss)
    assert rnd == 11 and seed == 3 and tuple(spec) == pm.EMNIST_FREEZE
    for (ka, va), (kb, vb) in zip(basic.flatten_params(y),
                                  basic.flatten_params(y2)):
        assert ka == kb and bool((np.asarray(va) == np.asarray(vb)).all())
    full, rnd2 = ckpt.restore_full_model(p, INIT)
    fa = dict(basic.flatten_params(INIT(3)))
    fb = dict(basic.flatten_params(full))
    for k in fa:
        ok = bool((np.asarray(fa[k]) == np.asarray(fb[k])).all())
        if any(re.search(s, k) for s in pm.EMNIST_FREEZE):
            assert ok, f"frozen leaf {k} must regenerate exactly"
