"""Cohort-selection policies (sim/selection.py): uniform bit-for-bit
equivalence, bandwidth-aware sampling + importance weights, FedPLT-style
tier rotation, adaptive re-tiering from observed round trips, and the
per-tier compute charge in the virtual clock."""
import dataclasses
import math

import numpy as np
import pytest

from repro.core import fedpt
from repro.data import synthetic as syn
from repro.sim import devices as dev_lib
from repro.sim import dynamics as dyn_lib
from repro.sim import grid as simgrid
from repro.sim import selection as sel_lib


# the probe model is OWNED by the policy bench and imported here, so the
# acceptance test below and the committed BENCH_grid.json baseline can
# never silently validate different models
from benchmarks.grid_sweep import _probe_init as init_fn  # noqa: E402
from benchmarks.grid_sweep import _probe_loss as loss_fn  # noqa: E402


def make_ds(n_clients=12, seed=0):
    return syn.make_federated_images(n_clients, 30, (8, 8, 1), 4, seed=seed,
                                     test_examples=64)


RC = fedpt.RoundConfig(4, 2, 8, "sgd", 0.1, "sgd", 1.0)
MB = 1024.0 * 1024.0
TIER_PLAN = {"full": (), "mid": (r"/bias$",), "lite": (r"/kernel$",)}


def _fleet(mults, **kw):
    return dev_lib.Fleet(name="test", profiles=[
        dev_lib.DeviceProfile(downlink_bps=MB, uplink_bps=MB,
                              compute_multiplier=m, **kw) for m in mults])


def _bind(policy, fleet, cplan=None, tiers=None, rtt=None):
    policy.bind(fleet=fleet, num_clients=len(fleet), cplan=cplan,
                tiers=tiers, rtt_estimate=rtt)
    return policy


def time_to_target(history, target):
    best = math.inf
    for h in history:
        best = min(best, h["loss"])
        if best <= target:
            return h["virtual_seconds"], True
    return (history[-1]["virtual_seconds"] if history else 0.0), False


# ---------------------------------------------------------------------------
# Resolution + the uniform acceptance contract


def test_resolve_policy():
    for name, cls in sel_lib.POLICIES.items():
        p = sel_lib.resolve_policy(name)
        assert isinstance(p, cls) and p.name == name
    inst = sel_lib.BandwidthAwarePolicy(temperature=2.0)
    assert sel_lib.resolve_policy(inst) is inst
    with pytest.raises(ValueError, match="unknown selection policy"):
        sel_lib.resolve_policy("galaxy-brain")
    # fresh instance per resolution: no state leaks across runs
    assert sel_lib.resolve_policy("uniform") \
        is not sel_lib.resolve_policy("uniform")


def test_uniform_policy_consumes_streams_identically():
    fleet = _fleet([1.0] * 10)
    pol = _bind(sel_lib.UniformPolicy(), fleet)
    r1, r2 = np.random.default_rng(3), np.random.default_rng(3)
    np.testing.assert_array_equal(pol.select_cohort(r1, 6),
                                  syn.sample_cohort(r2, 10, 6))
    assert pol.sample_cid(r1) == int(r2.integers(0, 10))
    assert pol.cohort_weights(np.arange(6)) is None
    assert pol.client_weight(3) == 1.0 and pol.trivial


# ---------------------------------------------------------------------------
# Bandwidth-aware


def test_bandwidth_aware_probs_and_weights():
    fleet = _fleet([1.0] * 4)
    rtt = np.array([1.0, 2.0, 4.0, 8.0])
    pol = _bind(sel_lib.BandwidthAwarePolicy(), fleet, rtt=rtt)
    assert pol.probs.sum() == pytest.approx(1.0)
    # monotone: faster client, higher inclusion probability
    assert np.all(np.diff(pol.probs) < 0)
    assert pol.probs[0] / pol.probs[3] == pytest.approx(8.0)
    # first-order HT correction: expected weight under the sampling
    # distribution is 1 (sum_i p_i * (1/N)/p_i), keeping the aggregate
    # unbiased for the uniform-cohort update
    assert np.sum(pol.probs * pol.weights) == pytest.approx(1.0)
    assert pol.client_weight(0) < 1.0 < pol.client_weight(3)
    # tilt cap: a pathological outlier cannot monopolize the cohort
    capped = _bind(sel_lib.BandwidthAwarePolicy(max_tilt=4.0), fleet,
                   rtt=np.array([1e-6, 1.0, 1.0, 1.0]))
    assert capped.probs.max() / capped.probs.min() <= 4.0 + 1e-9
    with pytest.raises(ValueError):
        sel_lib.BandwidthAwarePolicy(temperature=0.0)
    with pytest.raises(ValueError, match="round-trip estimates"):
        _bind(sel_lib.BandwidthAwarePolicy(), fleet, rtt=None)


@pytest.mark.dynamics
def test_bandwidth_aware_prefers_fast_clients():
    fleet = _fleet([1.0] * 6)
    rtt = np.array([1.0, 1.0, 1.0, 20.0, 20.0, 20.0])
    pol = _bind(sel_lib.BandwidthAwarePolicy(), fleet, rtt=rtt)
    rng = np.random.default_rng(0)
    draws = np.array([pol.sample_cid(rng) for _ in range(3000)])
    fast = np.isin(draws, [0, 1, 2]).mean()
    assert fast > 0.9   # 20x rtt gap -> ~95% of dispatches go fast


@pytest.mark.dynamics
def test_bandwidth_aware_beats_uniform_time_to_target():
    """Acceptance: on the pareto-mobile-diurnal fleet, bandwidth-aware
    selection reaches the target loss in measurably less virtual time
    than uniform (fixed seeds; the README reports the magnitude range
    across seeds honestly)."""
    ds = make_ds(n_clients=24)
    target = 0.2
    vts = {}
    for pol in ("uniform", "bandwidth-aware"):
        gc = simgrid.GridConfig(mode="async", fleet="pareto-mobile-diurnal",
                                base_step_time=1.0, concurrency=8,
                                goal_count=4, selection=pol)
        res = simgrid.run_grid(init_fn, loss_fn, ds, RC, 15, grid=gc, seed=0)
        vt, hit = time_to_target(res.history, target)
        assert hit, pol
        vts[pol] = vt
    assert vts["bandwidth-aware"] < vts["uniform"]


@pytest.mark.dynamics
def test_bandwidth_aware_importance_weights_reach_the_aggregate():
    """The HT correction must actually enter the weighted mean: the same
    run with the policy's weights forced to 1 diverges."""
    ds = make_ds(n_clients=12)
    gc = simgrid.GridConfig(mode="async", fleet="pareto-mobile",
                            dynamics="jitter", concurrency=6, goal_count=3,
                            selection="bandwidth-aware")
    a = simgrid.run_grid(init_fn, loss_fn, ds, RC, 6, grid=gc, seed=4)

    class FlatWeights(sel_lib.BandwidthAwarePolicy):
        def client_weight(self, cid):
            return 1.0

    b = simgrid.run_grid(init_fn, loss_fn, ds, RC, 6, seed=4,
                         grid=dataclasses.replace(gc,
                                                  selection=FlatWeights()))
    # identical sampling stream (same probs), different aggregation
    assert a.scheduler_stats == b.scheduler_stats
    assert [h["loss"] for h in a.history] != [h["loss"] for h in b.history]


@pytest.mark.dynamics
def test_bandwidth_aware_under_dp_keeps_sigma():
    """Under DP the engine forces uniform-among-participants weighting
    with the fixed denominator; selection must not touch sigma or the
    accountant (the HT correction is documented as dropped)."""
    ds = make_ds(n_clients=10)
    rc = fedpt.RoundConfig(4, 2, 8, "sgd", 0.1, "sgd", 1.0,
                           dp_clip_norm=0.5, dp_noise_multiplier=0.4)
    gc = simgrid.GridConfig(mode="async", concurrency=5, goal_count=3,
                            fleet="pareto-mobile", dynamics="jitter",
                            selection="bandwidth-aware")
    res = simgrid.run_grid(init_fn, loss_fn, ds, rc, 5, grid=gc, seed=4)
    assert res.dp["sigma"] == pytest.approx(0.4 * 0.5 / 3)
    assert res.dp["flushes"] == 5


# ---------------------------------------------------------------------------
# Tier rotation


def test_tier_rotation_requires_plan():
    fleet = _fleet([1.0] * 4)
    with pytest.raises(ValueError, match="trainability plan"):
        _bind(sel_lib.TierRotationPolicy(), fleet)


@pytest.mark.dynamics
def test_tier_rotation_cycles_every_group_through_every_tier():
    from repro.core import plan as plan_lib
    ds = make_ds(n_clients=9)
    # base census all-full: WITHOUT rotation, mid and lite would never
    # see a single upload; rotation must feed all three tiers
    gc = simgrid.GridConfig(plan=TIER_PLAN, tier_assignment=[0] * 9,
                            selection="tier-rotation")
    res = simgrid.run_grid(init_fn, loss_fn, ds, RC, 6, grid=gc, seed=1)
    assert res.history[-1]["loss"] < res.history[0]["loss"]
    pol = res.policy
    # 6 rounds of rotate-by-one over 3 tiers: map returned to base twice
    assert pol.rotation == 6
    np.testing.assert_array_equal(
        pol.current_tiers(), (pol.base + 6) % 3)
    # every tier saw uploads from rotation (with a static all-X census a
    # 3-tier plan would starve two tiers; rotation feeds all three)
    st = res.tier_stats
    assert all(st[k]["uploads"] > 0 for k in ("full", "mid", "lite"))
    # unit: the map actually moves round to round
    unit = sel_lib.TierRotationPolicy(every=2)
    unit.bind(fleet=_fleet([1.0] * 3), num_clients=3,
              cplan=plan_lib.compile_plan(TIER_PLAN, init_fn(0)),
              tiers=np.array([0, 1, 2], np.int32),
              rtt_estimate=np.ones(3))
    m0 = unit.current_tiers().copy()
    unit.end_round(0)
    np.testing.assert_array_equal(unit.current_tiers(), m0)  # every=2
    unit.end_round(1)
    np.testing.assert_array_equal(unit.current_tiers(), (m0 + 1) % 3)


# ---------------------------------------------------------------------------
# Adaptive capability


def test_quantile_tiers_matches_assign_tiers():
    fleet = dev_lib.make_fleet(32, "pareto-mobile", seed=3)
    scores = np.asarray([dev_lib.capability_score(p)
                         for p in fleet.profiles])
    np.testing.assert_array_equal(
        dev_lib.quantile_tiers(scores, 3),
        dev_lib.assign_tiers(fleet, 3, "capability"))
    # homogeneous scores: ties break upward, everyone tier 0
    assert dev_lib.quantile_tiers(np.ones(8), 4).max() == 0


@pytest.mark.dynamics
def test_adaptive_capability_retiers_from_observed_rtt():
    """Profiles lie, the wire doesn't: a fleet whose static profiles are
    identical (static capability split -> everyone tier 0/full) but
    where half the devices carry a crippling per-profile link model must
    end up split by *observed* round trips after re-tiering."""
    n = 12
    slow_ids = list(range(6, 12))
    profiles = []
    for c in range(n):
        lm = (dyn_lib.LinkModel(rtt_seconds=300.0, jitter_sigma=0.1)
              if c in slow_ids else
              dyn_lib.LinkModel(rtt_seconds=0.0, jitter_sigma=0.1))
        profiles.append(dev_lib.DeviceProfile(
            downlink_bps=MB, uplink_bps=MB, compute_multiplier=1.0,
            link_model=lm))
    fleet = dev_lib.Fleet(name="liars", profiles=profiles)
    ds = make_ds(n_clients=n)
    pol = sel_lib.AdaptiveCapabilityPolicy(refit_every=3, ema=0.5)
    gc = simgrid.GridConfig(mode="async", fleet=fleet,
                            plan={"full": (), "lite": (r"/kernel$",)},
                            concurrency=6, goal_count=3, selection=pol)
    res = simgrid.run_grid(init_fn, loss_fn, ds, RC, 12, grid=gc, seed=2)
    assert res.policy is pol and pol.refits >= 1
    # the static split called everyone full-tier...
    assert (pol._tiers == 0).all()
    final = pol.current_tiers()
    # ... the observed split demotes every slow client the wire exposed
    # (a quantile split keeps ~half the fleet in tier 0, so the map
    # stays non-degenerate; unobserved clients keep their static rank)
    observed_slow = [c for c in slow_ids if pol.observed[c]]
    assert observed_slow, "no slow client ever completed"
    assert all(final[c] == 1 for c in observed_slow)
    assert (final == 0).any()
    assert not np.array_equal(final, pol._tiers)
    # the EMA actually separated the groups it saw
    seen_fast = [c for c in range(6) if pol.observed[c]]
    if seen_fast:
        assert max(pol.ema_rtt[c] for c in seen_fast) \
            < min(pol.ema_rtt[c] for c in observed_slow)


def test_adaptive_capability_unit_ema_and_refit():
    from repro.core import plan as plan_lib
    fleet = _fleet([1.0] * 4)
    pol = sel_lib.AdaptiveCapabilityPolicy(refit_every=2, ema=0.5)
    pol.bind(fleet=fleet, num_clients=4,
             cplan=plan_lib.compile_plan({"full": (), "lite": (r"/bias$",)},
                                         init_fn(0)),
             tiers=np.zeros(4, np.int32),
             rtt_estimate=np.array([1.0, 1.0, 1.0, 1.0]))
    pol.observe(3, 9.0)
    assert pol.ema_rtt[3] == pytest.approx(5.0)      # 0.5*1 + 0.5*9
    pol.end_round(0)                                  # not yet (every=2)
    assert pol.refits == 0
    pol.end_round(1)
    assert pol.refits == 1
    assert pol.current_tiers()[3] == 1               # slowest demoted
    assert pol.current_tiers()[:3].max() == 0
    with pytest.raises(ValueError):
        sel_lib.AdaptiveCapabilityPolicy(ema=0.0)


# ---------------------------------------------------------------------------
# Per-tier compute in the virtual clock (acceptance)


@pytest.mark.dynamics
def test_lite_tier_heavy_fleet_finishes_rounds_faster():
    """Acceptance: per-tier compute_seconds — a lite-tier-heavy fleet
    finishes rounds in less virtual time than all-full, and the per-tier
    timing shows up in GridResult.tier_stats."""
    ds = make_ds(n_clients=12)
    plan = {"full": (), "lite": (r"/kernel$",)}
    base = dict(plan=plan, base_step_time=10.0)
    full = simgrid.run_grid(
        init_fn, loss_fn, ds, RC, 3, seed=0,
        grid=simgrid.GridConfig(tier_assignment=[0] * 12, **base))
    lite = simgrid.run_grid(
        init_fn, loss_fn, ds, RC, 3, seed=0,
        grid=simgrid.GridConfig(tier_assignment=[1] * 12, **base))
    assert lite.virtual_seconds < full.virtual_seconds
    # the tier's compute charge is the base scaled by trainable fraction
    cs_full = full.tier_stats["full"]["compute_seconds"]
    cs_lite = lite.tier_stats["lite"]["compute_seconds"]
    assert cs_full == pytest.approx(RC.local_steps * 10.0)
    frac = lite.plan.tiers[1].param_count / sum(lite.plan.layout.sizes)
    assert cs_lite == pytest.approx(cs_full * frac)
    assert 0 < frac < 1
    # observed mean round trips surface per tier
    assert lite.tier_stats["lite"]["rtt_mean"] > 0
    assert lite.tier_stats["lite"]["rtt_mean"] \
        < full.tier_stats["full"]["rtt_mean"]
