import os
import sys

# src/ layout import path (tests also work without installing the package)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root: tests share the policy-bench probe model (benchmarks/)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import pytest

# hypothesis is a pinned CI dep but not guaranteed in every container;
# skip the property-test modules (not the whole collection) without it
try:
    import hypothesis  # noqa: F401
    collect_ignore = []
except ImportError:
    collect_ignore = ["test_attention.py", "test_dp.py",
                      "test_fedpt_core.py", "test_kernels.py",
                      "test_optim_data.py"]


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
