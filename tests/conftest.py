import os
import sys

# src/ layout import path (tests also work without installing the package)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
