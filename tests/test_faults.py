"""Fault injection (sim/faults.py), the delta-quarantine screen
(core/sanitize.py), and their grid wiring: the faults=None zero-draw
contract, corruption-only timeline invariance, NaN-poisoning with and
without the sanitize screen, fault counters/traces, the sync crash path,
the server kill, and the escalating-backoff retry machinery."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fedpt
from repro.core import sanitize as sanitize_lib
from repro.data import synthetic as syn
from repro.nn import basic
from repro.sim import dynamics as dyn_lib
from repro.sim import faults as faults_lib
from repro.sim import grid as simgrid

pytestmark = pytest.mark.chaos


def init_fn(seed):
    return {"dense": basic.init_dense(seed, "dense", 64, 4, jnp.float32,
                                      bias=True)}


def loss_fn(params, b):
    x = b["images"].reshape(b["images"].shape[0], -1)
    logits = basic.dense(x, params["dense"])
    lp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(lp, b["labels"][:, None], 1)), {}


def make_ds(n_clients=12, seed=0):
    return syn.make_federated_images(n_clients, 30, (8, 8, 1), 4, seed=seed,
                                     test_examples=64)


RC = fedpt.RoundConfig(4, 2, 8, "sgd", 0.1, "sgd", 1.0)

CHAOS = dict(crash_compute=0.05, truncate_upload=0.05, corrupt_nan=0.08,
             corrupt_bitflip=0.08, duplicate_upload=0.05)


def _flat(y):
    return np.concatenate([np.asarray(v).ravel()
                           for _, v in basic.flatten_params(y)])


def _same_history(a, b):
    assert [h["loss"] for h in a.history] == [h["loss"] for h in b.history]
    for ha, hb in zip(a.history, b.history):
        assert ha["virtual_seconds"] == hb["virtual_seconds"]


# ---------------------------------------------------------------------------
# resolution & config validation


def test_resolve_faults_trivial_is_none():
    assert faults_lib.resolve_faults(None) is None
    assert faults_lib.resolve_faults(faults_lib.FaultConfig()) is None
    assert faults_lib.resolve_faults({}) is None
    assert faults_lib.resolve_faults({"crash_compute": 0.0}) is None


def test_resolve_faults_variants():
    cfg = faults_lib.resolve_faults("chaos")
    assert cfg is not None and cfg.prob_total > 0
    cfg2 = faults_lib.resolve_faults({"corrupt_nan": 0.5})
    assert cfg2.corrupt_nan == 0.5
    assert faults_lib.resolve_faults(cfg) is cfg
    with pytest.raises(ValueError, match="unknown fault preset"):
        faults_lib.resolve_faults("nope")
    with pytest.raises(TypeError):
        faults_lib.resolve_faults(42)


def test_fault_config_validation():
    with pytest.raises(ValueError, match="probabilit"):
        faults_lib.FaultConfig(crash_compute=1.5)
    with pytest.raises(ValueError, match="sum"):
        faults_lib.FaultConfig(crash_compute=0.6, corrupt_nan=0.6)
    with pytest.raises(ValueError, match="server_kill_at"):
        faults_lib.FaultConfig(server_kill_at=0.0)


def test_resolve_sanitize_variants():
    assert sanitize_lib.resolve_sanitize(None) is None
    assert sanitize_lib.resolve_sanitize(False) is None
    assert sanitize_lib.resolve_sanitize("off") is None
    assert sanitize_lib.resolve_sanitize(True) is not None
    got = sanitize_lib.resolve_sanitize({"norm_mult": 5.0})
    assert got.norm_mult == 5.0
    # a config that screens nothing resolves to None (trivial-is-exact)
    assert sanitize_lib.resolve_sanitize(
        sanitize_lib.SanitizeConfig(nonfinite=False, norm_mult=0.0)) is None


# ---------------------------------------------------------------------------
# fault-stream hygiene & corruption primitives


def test_fault_draw_consumes_exactly_two_stream_draws():
    cfg = faults_lib.FaultConfig(corrupt_nan=0.5)
    a, b = np.random.default_rng(3), np.random.default_rng(3)
    bf = cfg.bind(a)
    for _ in range(7):
        bf.draw()
    b.random()  # 7 x (uniform + 63-bit integer)
    b.integers(0, 2 ** 63 - 1)
    for _ in range(6):
        b.random()
        b.integers(0, 2 ** 63 - 1)
    assert a.bit_generator.state == b.bit_generator.state


def test_corrupt_row_deterministic_and_damaging():
    cfg = faults_lib.FaultConfig(corrupt_nan=0.1, corrupt_bitflip=0.1)
    row = np.linspace(-1.0, 1.0, 256).astype(np.float32)
    a = faults_lib.corrupt_row(row, "nan", 12345, cfg)
    b = faults_lib.corrupt_row(row, "nan", 12345, cfg)
    np.testing.assert_array_equal(a, b)
    assert np.sum(~np.isfinite(a)) >= 1
    # the original row is untouched
    assert np.all(np.isfinite(row))
    c = faults_lib.corrupt_row(row, "bitflip", 999, cfg)
    # bit 30 flips the top exponent bit: |x| < 2 becomes huge
    assert np.max(np.abs(c[np.isfinite(c)]), initial=0.0) > 1e9 \
        or np.any(~np.isfinite(c))


# ---------------------------------------------------------------------------
# sanitize screen unit behavior


def test_screen_rows_quarantines_nonfinite_and_outliers():
    mat = np.ones((5, 8), np.float32)
    mat[1, 3] = np.nan
    mat[2, 0] = np.inf
    mat[3] *= 1e6                      # norm outlier vs the ones rows
    w = np.ones(5, np.float32)
    clean, cw, info = sanitize_lib.screen_rows(
        jnp.asarray(mat), jnp.asarray(w), sanitize_lib.SanitizeConfig())
    nonf = np.asarray(info["nonfinite"])
    outl = np.asarray(info["outlier"])
    assert list(nonf) == [False, True, True, False, False]
    assert list(outl) == [False, False, False, True, False]
    cw = np.asarray(cw)
    assert list(cw) == [1.0, 0.0, 0.0, 0.0, 1.0]
    clean = np.asarray(clean)
    assert np.all(np.isfinite(clean))
    assert np.all(clean[1] == 0.0) and np.all(clean[3] == 0.0)


def test_screen_rows_clean_data_bitwise_noop():
    rng = np.random.default_rng(0)
    mat = jnp.asarray(rng.standard_normal((4, 16)).astype(np.float32))
    w = jnp.asarray(np.ones(4, np.float32))
    clean, cw, _ = sanitize_lib.screen_rows(
        mat, w, sanitize_lib.SanitizeConfig())
    assert bool(jnp.all(clean == mat)) and bool(jnp.all(cw == w))


# ---------------------------------------------------------------------------
# grid wiring: zero-draw contract & timeline invariance


def test_trivial_faults_config_bit_identical_to_none():
    ds = make_ds()
    a = simgrid.run_grid(init_fn, loss_fn, ds, RC, 5,
                         grid=simgrid.GridConfig(mode="async"), seed=3)
    b = simgrid.run_grid(
        init_fn, loss_fn, ds, RC, 5,
        grid=simgrid.GridConfig(mode="async",
                                faults={"crash_compute": 0.0}), seed=3)
    _same_history(a, b)
    assert a.faults is None and b.faults is None


def test_corruption_only_faults_keep_dispatch_timeline():
    """Payload corruption never touches the dev/dyn streams or the event
    clock: a corrupt-everything run has the exact virtual timeline and
    dispatch counts of the faults=None run — only the payloads differ."""
    ds = make_ds()
    off = simgrid.run_grid(init_fn, loss_fn, ds, RC, 5,
                           grid=simgrid.GridConfig(mode="async"), seed=3)
    on = simgrid.run_grid(
        init_fn, loss_fn, ds, RC, 5,
        grid=simgrid.GridConfig(mode="async", faults={"corrupt_nan": 1.0},
                                sanitize=True), seed=3)
    for ha, hb in zip(off.history, on.history):
        assert ha["virtual_seconds"] == hb["virtual_seconds"]
    assert on.scheduler_stats["dispatches"] == \
        off.scheduler_stats["dispatches"]
    assert on.scheduler_stats["uploads"] == off.scheduler_stats["uploads"]
    # every buffered row was corrupted -> every row quarantined, and the
    # sanitized model stays finite
    assert on.faults["corrupted"] == on.scheduler_stats["uploads"]
    assert on.faults["quarantined"] == 5 * simgrid.GridConfig().goal_count
    assert np.all(np.isfinite(_flat(on.y)))


# ---------------------------------------------------------------------------
# acceptance: poisoned cohort with/without the screen


def test_nan_poison_without_sanitize_poisons_model():
    ds = make_ds()
    r = simgrid.run_grid(
        init_fn, loss_fn, ds, RC, 5,
        grid=simgrid.GridConfig(mode="async", faults={"corrupt_nan": 1.0}),
        seed=3)
    assert not np.all(np.isfinite(_flat(r.y)))
    assert r.faults["corrupted"] > 0 and r.faults["quarantined"] == 0


def test_nan_poison_with_sanitize_stays_finite_and_traces():
    ds = make_ds()
    r = simgrid.run_grid(
        init_fn, loss_fn, ds, RC, 5,
        grid=simgrid.GridConfig(mode="async", faults={"corrupt_nan": 1.0},
                                sanitize=True, telemetry="memory"), seed=3)
    assert np.all(np.isfinite(_flat(r.y)))
    assert all(math.isfinite(h["loss"]) for h in r.history)
    quars = r.telemetry.of_kind("quarantine")
    assert len(quars) == r.faults["quarantined"] > 0
    assert all(q.payload["cause"] == "nonfinite" for q in quars)
    faults = r.telemetry.of_kind("fault")
    assert all(f.payload["fault"] == "corrupt_nan" for f in faults)


def test_bitflip_quarantined():
    ds = make_ds()
    r = simgrid.run_grid(
        init_fn, loss_fn, ds, RC, 5,
        grid=simgrid.GridConfig(mode="async",
                                faults={"corrupt_bitflip": 1.0},
                                sanitize=True, telemetry="memory"), seed=3)
    assert np.all(np.isfinite(_flat(r.y)))
    quars = r.telemetry.of_kind("quarantine")
    assert len(quars) > 0
    assert {q.payload["cause"] for q in quars} <= \
        {"nonfinite", "norm-outlier"}


# ---------------------------------------------------------------------------
# the remaining async fault kinds


def test_chaos_counters_and_traces():
    ds = make_ds()
    r = simgrid.run_grid(
        init_fn, loss_fn, ds, RC, 12,
        grid=simgrid.GridConfig(mode="async", faults="chaos",
                                sanitize=True, telemetry="memory"), seed=3)
    f = r.faults
    assert f["crashes"] > 0 and f["truncated"] > 0 and f["corrupted"] > 0
    assert f == {k: r.scheduler_stats[k] for k in f}
    kinds = {e.payload["fault"] for e in r.telemetry.of_kind("fault")}
    assert "crash_compute" in kinds and "truncate_upload" in kinds


def test_duplicate_upload_bills_twice_and_raises_dp_multiplicity():
    ds = make_ds()
    rc = dataclasses.replace(RC, dp_clip_norm=1.0, dp_noise_multiplier=0.5)
    base = simgrid.run_grid(init_fn, loss_fn, ds, rc, 6,
                            grid=simgrid.GridConfig(mode="async"), seed=3)
    dup = simgrid.run_grid(
        init_fn, loss_fn, ds, rc, 6,
        grid=simgrid.GridConfig(mode="async",
                                faults={"duplicate_upload": 1.0}), seed=3)
    assert dup.faults["duplicates"] > 0
    # both copies bill uplink: two billed uploads per dispatched client,
    # so the buffer fills in half the dispatches of the clean run
    assert dup.scheduler_stats["uploads"] == 2 * dup.faults["duplicates"]
    assert dup.comm.measured_up_bytes == \
        dup.scheduler_stats["uploads"] * dup.comm.trainable_bytes
    assert dup.scheduler_stats["dispatches"] < \
        base.scheduler_stats["dispatches"]
    # a duplicated client owns >= 2 rows of its flush: the accountant
    # sees it and the conservative epsilon grows
    assert dup.dp["max_multiplicity"] >= 2
    assert dup.dp["epsilon"] > base.dp["epsilon"]


def test_truncated_upload_drops_delta_but_bills_partial_bytes():
    ds = make_ds()
    r = simgrid.run_grid(
        init_fn, loss_fn, ds, RC, 4,
        grid=simgrid.GridConfig(mode="async",
                                faults={"truncate_upload": 0.5},
                                telemetry="memory"), seed=3)
    assert r.faults["truncated"] > 0
    truncs = [e for e in r.telemetry.of_kind("fault")
              if e.payload["fault"] == "truncate_upload"]
    assert truncs
    full = r.metrics.gauge("payload_up_bytes").value
    for e in truncs:
        assert 0 <= e.payload["up_bytes"] < full
        assert 0.1 <= e.payload["frac"] < 0.9 + 1e-9


# ---------------------------------------------------------------------------
# sync mode: crash faults only


def test_sync_crash_faults_counted():
    ds = make_ds()
    r = simgrid.run_grid(
        init_fn, loss_fn, ds, RC, 6,
        grid=simgrid.GridConfig(mode="sync",
                                faults={"crash_compute": 0.3},
                                telemetry="memory"), seed=3)
    assert r.faults["crashes"] > 0
    assert r.scheduler_stats["crashes"] == r.faults["crashes"]
    kinds = [e.payload["fault"] for e in r.telemetry.of_kind("fault")]
    assert kinds.count("crash_compute") == r.faults["crashes"]


def test_sync_rejects_payload_faults():
    ds = make_ds()
    with pytest.raises(ValueError, match="async"):
        simgrid.run_grid(
            init_fn, loss_fn, ds, RC, 2,
            grid=simgrid.GridConfig(mode="sync",
                                    faults={"corrupt_nan": 0.5}), seed=3)


def test_sync_trivial_faults_bit_identical():
    ds = make_ds()
    a = simgrid.run_grid(init_fn, loss_fn, ds, RC, 4, seed=3)
    b = simgrid.run_grid(init_fn, loss_fn, ds, RC, 4,
                         grid=simgrid.GridConfig(faults=None, sanitize=None),
                         seed=3)
    _same_history(a, b)


# ---------------------------------------------------------------------------
# server kill


def test_server_kill_raises_with_position():
    ds = make_ds()
    with pytest.raises(faults_lib.ServerKilled) as ei:
        simgrid.run_grid(
            init_fn, loss_fn, ds, RC, 50,
            grid=simgrid.GridConfig(mode="async",
                                    faults={"server_kill_at": 0.5}), seed=3)
    assert ei.value.at > 0.5 and ei.value.applied >= 0
    assert ei.value.checkpoint is None  # checkpointing was off


# ---------------------------------------------------------------------------
# schema v2: the new event kinds validate and export


def test_fault_events_validate_against_schema(tmp_path):
    from repro.obs import schema as schema_lib

    ds = make_ds()
    jsonl = str(tmp_path / "chaos.jsonl")
    perfetto = str(tmp_path / "chaos.json")
    r = simgrid.run_grid(
        init_fn, loss_fn, ds, RC, 8,
        grid=simgrid.GridConfig(
            mode="async", faults="chaos", sanitize=True,
            checkpoint_every=4, checkpoint_dir=str(tmp_path / "ckpt"),
            telemetry={"jsonl_path": jsonl, "perfetto_path": perfetto}),
        seed=3)
    kinds = {e.kind for e in r.telemetry.events}
    assert {"fault", "checkpoint"} <= kinds
    assert not schema_lib.validate_records(
        [e.to_json() for e in r.telemetry.events])
    n, errs = schema_lib.validate_jsonl(jsonl)
    assert not errs and n == len(r.telemetry.events)
    got, perrs = schema_lib.validate_perfetto(
        perfetto, require=["fault", "checkpoint"])
    assert not perrs and got >= 2


# ---------------------------------------------------------------------------
# escalating backoff & retry budget


def test_backoff_escalates_capped_with_deterministic_jitter():
    cfg = dyn_lib.DynamicsConfig(redispatch_backoff=10.0,
                                 backoff_growth=2.0, backoff_cap=100.0)
    fleet = simgrid.dev_lib.make_fleet(4, "uniform", seed=0)
    bd = cfg.bind(fleet, np.random.default_rng(0))
    seq = [bd.backoff_seconds(k) for k in range(8)]
    # deterministic: same k -> same backoff, no rng involved
    assert seq == [bd.backoff_seconds(k) for k in range(8)]
    # jitter keeps each backoff within [0.75, 1.25) of its base
    for k, s in enumerate(seq):
        base = min(10.0 * 2.0 ** k, 100.0)
        assert 0.75 * base <= s < 1.25 * base
    # escalation reaches (and never exceeds) the jittered cap
    assert max(seq) <= 1.25 * 100.0
    assert seq[5] > seq[0]


def test_dark_window_retry_budget_raises():
    ds = make_ds(4)
    dark = dyn_lib.StepTrace(times=[0.0], values=[0.0])   # fleet never up
    dyn = dyn_lib.DynamicsConfig(availability=dark, retry_budget=5_000.0)
    gc = simgrid.GridConfig(mode="async", dynamics=dyn)
    with pytest.raises(RuntimeError, match="retry budget"):
        simgrid.run_grid(init_fn, loss_fn, ds, RC, 3, grid=gc, seed=3)
