"""Uplink compression: quantization error bounds, payload accounting,
structure preservation, and the comm-ledger integration (a quantized
uplink must be billed at int8 bytes, not fp32)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comm, compress
from repro.nn import basic


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(0, 0.3, (3, 4)).astype(np.float32)),
        "b": {"c": jnp.asarray(rng.normal(0, 2.0, (5,)).astype(np.float32))},
    }


@pytest.mark.parametrize("bits", [4, 8])
def test_quantize_roundtrip_error_bound(bits):
    """Symmetric nearest-rounding quantization: per-element error is at
    most half a quantization step (scale/2)."""
    x = jnp.asarray(np.random.default_rng(1).normal(0, 1.5, (64,))
                    .astype(np.float32))
    q, scale = compress.quantize_leaf(x, bits)
    dq = compress.dequantize_leaf(q, scale)
    step = float(scale)
    assert float(jnp.max(jnp.abs(dq - x))) <= step / 2 + 1e-7
    # more bits -> finer grid: the step shrinks by 2^(bits difference)
    qmax = 2.0 ** (bits - 1) - 1
    assert step == pytest.approx(float(jnp.max(jnp.abs(x))) / qmax, rel=1e-6)


def test_more_bits_less_error():
    x = jnp.asarray(np.random.default_rng(2).normal(0, 1, (256,))
                    .astype(np.float32))
    errs = []
    for bits in (4, 8):
        q, s = compress.quantize_leaf(x, bits)
        errs.append(float(jnp.max(jnp.abs(compress.dequantize_leaf(q, s) - x))))
    assert errs[1] < errs[0] / 8  # 4 extra bits -> 16x finer grid


def test_quantized_uplink_bytes_accounting():
    t = _tree()
    n = basic.tree_size(t)            # 12 + 5 = 17 elements
    n_leaves = len(jax.tree_util.tree_leaves(t))
    assert n == 17 and n_leaves == 2
    # int8: one byte per element + one f32 scale per leaf
    assert compress.quantized_uplink_bytes(t, 8) == n + 4 * n_leaves
    # int4 packs two elements per byte (floor, as bit-packing would)
    assert compress.quantized_uplink_bytes(t, 4) == n * 4 // 8 + 4 * n_leaves


def test_fake_quantize_preserves_dtypes_and_treedef():
    t = _tree()
    t["b"]["half"] = jnp.ones((2, 2), jnp.bfloat16) * 0.37
    out = compress.fake_quantize_tree(t, 8)
    assert (jax.tree_util.tree_structure(out)
            == jax.tree_util.tree_structure(t))
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(out)):
        assert a.dtype == b.dtype and a.shape == b.shape
    # and it is actually lossy-but-close
    da = jax.tree_util.tree_leaves(t)[0] - jax.tree_util.tree_leaves(out)[0]
    assert 0 < float(jnp.max(jnp.abs(da))) < 0.01


def test_quantize_tree_structure():
    t = _tree()
    q, scales = compress.quantize_tree(t, 8)
    dq = compress.dequantize_tree(q, scales)
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(dq)):
        assert b.dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0.02)


def test_comm_report_uses_quantized_uplink_bytes():
    """Satellite fix: with uplink_bits=8 the ledger bills the uplink at
    int8 payload + scales — previously it overstated the cost 4x."""
    y, z = _tree(3), {"frozen": jnp.zeros((100,), jnp.float32)}
    fp32 = comm.report_for(y, z)
    q8 = comm.report_for(y, z, uplink_bits=8)
    assert fp32.upload_fedpt == basic.tree_bytes(y)
    assert q8.upload_fedpt == compress.quantized_uplink_bytes(y, 8)
    assert q8.upload_fedpt < fp32.upload_fedpt
    # download is unchanged (quantization is uplink-only)
    assert q8.download_fedpt == fp32.download_fedpt
    assert q8.reduction > fp32.reduction
    assert q8.uplink_reduction == pytest.approx(
        q8.upload_full / q8.upload_fedpt)
