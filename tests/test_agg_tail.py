"""Fused one-sweep aggregation tail (kernels/agg_tail.py) vs the staged
op sequence, and the shape-aware dispatcher in kernels/ops.agg_tail.

The contract (module docstring of kernels/agg_tail.py):

* any pipeline without quantization, and quantize-only, are **bitwise**
  identical to the staged tail on CPU (the fused apply is a
  column-chunked GEMV — chunking never reorders the K accumulation);
* quantize + clip and/or noise agree within fp round-off (the clip
  weights fold the quantized sum-of-squares and the apply folds
  scale x clip x weight / denominator into one coefficient).

Quarantine *decisions* must be identical on both routes (the fused path
reads the screen off its stats pass instead of `screen_rows`' own
sweep); the reported norms may differ by reassociation ulps only.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flat as flat_lib
from repro.core import sanitize as sanitize_lib
from repro.kernels import agg_tail as agg_tail_lib
from repro.kernels import ops as kernel_ops
from repro.kernels import ref

ALIGN = 256           # small blocks: every test compiles in well under 1s
BL = np.asarray([0, 0, 0, 1, 2, 2, 3, 3], np.int32)     # 4 leaves, 8 blocks
NB = len(BL)
SIZE = NB * ALIGN
K = 6

STAGED = 1 << 60      # threshold above any K*size: forces the staged path
FUSED = 0             # forces the fused path


def make_mat(seed=0, k=K, nan_row=None, inf_row=None, outlier_row=None):
    rng = np.random.default_rng(seed)
    mat = rng.normal(0, 0.5, (k, SIZE)).astype(np.float32)
    if nan_row is not None:
        mat[nan_row, 17] = np.nan
    if inf_row is not None:
        mat[inf_row, SIZE // 2 + 1] = np.inf
    if outlier_row is not None:
        mat[outlier_row] *= 1e6
    return jnp.asarray(mat)


def make_weights(seed=1, k=K, zero=()):
    w = np.random.default_rng(seed).uniform(0.5, 2.0, (k,)).astype(np.float32)
    for i in zero:
        w[i] = 0.0
    return jnp.asarray(w)


def tier_bmask(k=K):
    """Two tiers: even rows train every block, odd rows only leaves 0/3
    (a contiguous-sub-layout stand-in: tier-sliced widths)."""
    masks = np.ones((k, NB), np.float32)
    masks[1::2] = (BL == 0) | (BL == 3)
    return jnp.asarray(masks)


def run_both(mat, w, rng=None, **kw):
    kw.setdefault("block_leaf", BL)
    kw.setdefault("n_leaves", 4)
    kw.setdefault("align", ALIGN)
    s_out, s_info = kernel_ops.agg_tail(mat, w, rng=rng, threshold=STAGED,
                                        **kw)
    f_out, f_info = kernel_ops.agg_tail(mat, w, rng=rng, threshold=FUSED,
                                        **kw)
    assert s_info["route"] == "staged"
    assert f_info["route"].startswith("fused/")
    return (np.asarray(s_out), s_info), (np.asarray(f_out), f_info)


# ---------------------------------------------------------------------------
# Bitwise contract: every pipeline without quantize+clip/noise folding


BITWISE_CASES = {
    "mean": dict(),
    "uniform_mean": dict(uniform=True),
    "quant_only": dict(bits=8),
    "tiered_sync": dict(block_denom=True),
    "tiered_async": dict(remask_rows=True, block_denom=True),
    "tiered_quant": dict(bits=8, block_denom=True),
}

# clip fold / noise add: the stage-jit path computes the fold in a
# different XLA program than the staged tail, and XLA:CPU contracts the
# multiply-adds (FMA) differently across program boundaries — a couple
# of ulps, never more (measured ~1e-7 relative)
ULP_CASES = {
    "clip": dict(clip_norm=0.5, uniform=True, wsum_fixed=float(K)),
    "dp_no_quant": dict(clip_norm=0.5, uniform=True, wsum_fixed=float(K),
                        sigma=0.01),
    "noise_only": dict(wsum_fixed=float(K), sigma=0.02),
    "tiered_async_dp": dict(remask_rows=True, wsum_fixed=float(K),
                            sigma=0.02),
}


def _fill_tiers(kw):
    if kw.pop("block_denom", False) or kw.get("remask_rows"):
        kw["bmask"] = tier_bmask()
        kw["block_denom"] = "wsum_fixed" not in kw
    return kw


@pytest.mark.parametrize("name", sorted(BITWISE_CASES))
def test_fused_matches_staged_bitwise(name):
    kw = _fill_tiers(dict(BITWISE_CASES[name]))
    mat, w = make_mat(seed=hash(name) % 997), make_weights()
    (s_out, _), (f_out, _) = run_both(mat, w, **kw)
    assert np.array_equal(s_out, f_out), name


@pytest.mark.parametrize("name", sorted(ULP_CASES))
def test_fused_matches_staged_ulp(name):
    kw = _fill_tiers(dict(ULP_CASES[name]))
    rng = jax.random.key(7) if kw.get("sigma") else None
    mat, w = make_mat(seed=hash(name) % 997), make_weights()
    (s_out, _), (f_out, _) = run_both(mat, w, rng=rng, **kw)
    assert np.allclose(s_out, f_out, rtol=1e-5, atol=1e-7), name


def test_fused_matches_staged_zero_weight_padding_rows():
    """Zero-weight rows (scheduler-dropped / flush padding) contribute
    exact zero on both routes — bitwise, quantized and not."""
    mat, w = make_mat(seed=5), make_weights(zero=(2, 5))
    for kw in (dict(), dict(bits=8), dict(uniform=True)):
        (s_out, _), (f_out, _) = run_both(mat, w, **kw)
        assert np.array_equal(s_out, f_out), kw
    # and the padding rows genuinely don't contribute: zeroing their
    # data changes nothing
    mat0 = mat.at[2].set(1e9).at[5].set(-1e9)
    (s_out, _), _ = run_both(mat, w)
    (s_out0, _), _ = run_both(mat0, w)
    assert np.array_equal(s_out, s_out0)


def test_fused_matches_staged_full_pipeline_fp():
    """int8 + clip + noise: the coeff route folds dequantize scale x
    clip x weight / denominator — fp round-off, not bitwise."""
    mat, w = make_mat(seed=9), make_weights()
    rng = jax.random.key(3)
    (s_out, s_info), (f_out, f_info) = run_both(
        mat, w, rng=rng, bits=8, clip_norm=0.5, uniform=True,
        wsum_fixed=float(K), sigma=0.01)
    assert np.allclose(s_out, f_out, rtol=1e-4, atol=1e-5)
    assert np.allclose(np.asarray(s_info["update_norms"]),
                       np.asarray(f_info["update_norms"]), rtol=1e-3)
    assert f_info["route"].endswith("/coeff")


# ---------------------------------------------------------------------------
# Quarantine screen folded into the stats sweep


def test_screen_quarantine_decisions_identical_both_routes():
    """NaN row, Inf row, outlier-norm row, clean rows: the fused route
    reads the screen off its stats pass — decisions must match
    screen_rows' standalone sweep exactly (norms up to reassociation)."""
    cfg = sanitize_lib.SanitizeConfig(nonfinite=True, norm_mult=10.0)
    mat = make_mat(seed=11, nan_row=1, inf_row=4, outlier_row=2)
    w = make_weights()
    for kw in (dict(), dict(bits=8),
               dict(bits=8, clip_norm=0.5, uniform=True,
                    wsum_fixed=float(K), sigma=0.01)):
        rng = jax.random.key(1) if kw.get("sigma") else None
        (s_out, s_info), (f_out, f_info) = run_both(mat, w, rng=rng,
                                                    screen=cfg, **kw)
        for key in ("nonfinite", "outlier"):
            assert np.array_equal(np.asarray(s_info[key]),
                                  np.asarray(f_info[key])), (kw, key)
        assert bool(np.asarray(f_info["nonfinite"])[1])
        assert bool(np.asarray(f_info["nonfinite"])[4])
        assert bool(np.asarray(f_info["outlier"])[2])
        assert np.asarray(f_info["nonfinite"]).sum() == 2
        assert np.asarray(f_info["outlier"]).sum() == 1
        # reported norms: zeroed on non-finite rows, reassociation-close
        assert np.allclose(np.asarray(s_info["norms"]),
                           np.asarray(f_info["norms"]), rtol=1e-5), kw
        assert np.all(np.isfinite(f_out)), kw
        tol = 1e-4 if kw.get("bits") and (kw.get("clip_norm")
                                          or kw.get("sigma")) else 0.0
        assert np.allclose(s_out, f_out, rtol=tol, atol=tol), kw


def test_screen_from_stats_matches_screen_rows():
    """Regression for the folded sweep: screen_from_stats fed the fused
    path's raw stats (NaN norms on non-finite rows and all) must decide
    exactly like screen_rows' own NaN-free-view sweep."""
    cfg = sanitize_lib.SanitizeConfig(nonfinite=True, norm_mult=8.0)
    mat = make_mat(seed=13, nan_row=0, inf_row=3, outlier_row=5)
    w = make_weights(zero=(4,))
    _, w_rows, info_rows = sanitize_lib.screen_rows(mat, w, cfg, ALIGN)
    # the fused path's stats: raw norms (NaN/Inf on poisoned rows),
    # finiteness off the block max-abs
    bmax, bsumsq = ref.agg_block_stats_ref(mat, block=ALIGN,
                                           with_sumsq=True)
    raw_norms = jnp.sqrt(bsumsq @ jnp.ones((NB,), jnp.float32))
    row_finite = jnp.all(jnp.isfinite(bmax), axis=-1)
    w_stats, q, info_stats = sanitize_lib.screen_from_stats(
        raw_norms, row_finite, w, cfg)
    assert np.array_equal(np.asarray(info_rows["nonfinite"]),
                          np.asarray(info_stats["nonfinite"]))
    assert np.array_equal(np.asarray(info_rows["outlier"]),
                          np.asarray(info_stats["outlier"]))
    assert np.array_equal(np.asarray(w_rows), np.asarray(w_stats))
    assert np.array_equal(np.asarray(q), np.asarray(
        info_stats["nonfinite"] | info_stats["outlier"]))
    assert np.allclose(np.asarray(info_rows["norms"]),
                       np.asarray(info_stats["norms"]), rtol=1e-6)


# ---------------------------------------------------------------------------
# Pre-drawn noise invariance and the stats oracle


def test_draw_noise_matches_add_noise_bitwise():
    rng = jax.random.key(42)
    v = jnp.asarray(np.random.default_rng(0).normal(size=SIZE), jnp.float32)
    want = flat_lib.add_noise(v, 0.25, rng)
    got = v + flat_lib.draw_noise(rng, SIZE, 0.25)
    assert np.array_equal(np.asarray(want), np.asarray(got))


def test_block_stats_match_standalone_sweeps():
    """bmax == blockwise max|x| (NaN-propagating), bsumsq @ ones ==
    row_sumsq bitwise — the deleted standalone screen sweep's values."""
    mat = make_mat(seed=17, nan_row=2)
    bmax, bsumsq = ref.agg_block_stats_ref(mat, block=ALIGN,
                                           with_sumsq=True)
    x3 = np.asarray(mat).reshape(K, NB, ALIGN)
    want_max = np.max(np.abs(x3), axis=-1)
    got = np.asarray(bmax)
    assert np.array_equal(got[np.isfinite(want_max)],
                          want_max[np.isfinite(want_max)])
    assert np.isnan(got[2, 0]) and np.isnan(want_max[2, 0])
    rss = np.asarray(jnp.matmul(bsumsq, jnp.ones((NB,), jnp.float32)))
    want_rss = np.asarray(ref.row_sumsq_ref(mat, chunk=ALIGN))
    finite = np.isfinite(want_rss)
    assert np.array_equal(rss[finite], want_rss[finite])


def test_maxabs_chunk_int32_bitcast_matches_float():
    x = jnp.asarray(np.random.default_rng(3).normal(
        size=(32, 512)), jnp.float32)
    got = ref._maxabs_chunk(x)
    want = jnp.max(jnp.abs(x), axis=-1)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    xn = x.at[7, 100].set(jnp.nan)
    assert np.isnan(np.asarray(ref._maxabs_chunk(xn))[7])


# ---------------------------------------------------------------------------
# Shape-aware dispatch


def test_dispatcher_routes_small_shapes_staged():
    mat, w = make_mat(), make_weights()
    assert K * SIZE < kernel_ops.AGG_FUSE_THRESHOLD
    _, info = kernel_ops.agg_tail(mat, w, block_leaf=BL, n_leaves=4,
                                  align=ALIGN, bits=8)
    assert info["route"] == "staged"
    _, info = kernel_ops.agg_tail(mat, w, block_leaf=BL, n_leaves=4,
                                  align=ALIGN, bits=8, threshold=0)
    assert info["route"] == "fused/jit/exact"


def test_dispatcher_default_is_pipeline_aware():
    """Above the size threshold the default dispatch fuses only
    quantized pipelines — unquantized ones are already minimal-sweep
    and the stage orchestration measurably loses on them."""
    k, nb = 4, kernel_ops.AGG_FUSE_THRESHOLD // (4 * ALIGN)
    big_bl = np.zeros(nb, np.int32)
    size = nb * ALIGN
    assert k * size >= kernel_ops.AGG_FUSE_THRESHOLD
    mat = jnp.asarray(np.random.default_rng(0).normal(
        0, 0.5, (k, size)).astype(np.float32))
    w = jnp.ones((k,), jnp.float32)
    _, info = kernel_ops.agg_tail(mat, w, block_leaf=big_bl, n_leaves=1,
                                  align=ALIGN)
    assert info["route"] == "staged"          # bits=0: nothing to fuse
    _, info = kernel_ops.agg_tail(mat, w, block_leaf=big_bl, n_leaves=1,
                                  align=ALIGN, bits=8)
    assert info["route"] == "fused/jit/exact"  # quantized: fuse
    _, info = kernel_ops.agg_tail(mat, w, block_leaf=big_bl, n_leaves=1,
                                  align=ALIGN, threshold=0)
    assert info["route"].startswith("fused/")  # explicit: size only


def test_dispatcher_traced_uses_inline_ref_engine():
    """Under an outer jit (the round engines) the fused path must inline
    the ref composition — no nested stage jits, no concrete dispatch."""
    mat, w = make_mat(), make_weights()
    routes = []

    def f(mat, w, rng):
        out, info = kernel_ops.agg_tail(
            mat, w, block_leaf=BL, n_leaves=4, align=ALIGN, bits=8,
            clip_norm=0.5, uniform=True, wsum_fixed=float(K), sigma=0.01,
            rng=rng, threshold=0)
        routes.append(info["route"])
        return out

    rng = jax.random.key(0)
    traced = np.asarray(jax.jit(f)(mat, w, rng))
    assert routes == ["fused/ref/coeff"]
    concrete = np.asarray(f(mat, w, rng))
    assert routes[-1] == "fused/jit/coeff"
    assert np.allclose(traced, concrete, rtol=1e-5, atol=1e-6)


def test_dispatcher_traced_small_goes_staged():
    mat, w = make_mat(), make_weights()
    routes = []

    def f(mat, w):
        out, info = kernel_ops.agg_tail(mat, w, block_leaf=BL, n_leaves=4,
                                        align=ALIGN)
        routes.append(info["route"])
        return out

    a = np.asarray(jax.jit(f)(mat, w))
    assert routes == ["staged"]
    b = np.asarray(f(mat, w))
    assert np.array_equal(a, b)


# ---------------------------------------------------------------------------
# Grid-level acceptance: a DP async run is unchanged by the fused path


def test_async_dp_grid_history_unchanged_by_fused_path():
    """Forcing every flush through the fused tail must not change the
    run: same history, same model, same FlushAccountant epsilon — the
    DP guarantee is route-independent."""
    import dataclasses

    from repro.core import fedpt
    from repro.data import synthetic as syn
    from repro.nn import basic
    from repro.sim import grid as simgrid

    def init_fn(seed):
        return {"dense": basic.init_dense(seed, "dense", 64, 4, jnp.float32,
                                          bias=True)}

    def loss_fn(params, b):
        x = b["images"].reshape(b["images"].shape[0], -1)
        logits = basic.dense(x, params["dense"])
        lp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(lp, b["labels"][:, None], 1)), {}

    ds = syn.make_federated_images(10, 30, (8, 8, 1), 4, seed=0,
                                   test_examples=64)
    rc = fedpt.RoundConfig(4, 2, 8, "sgd", 0.1, "sgd", 1.0,
                           dp_clip_norm=0.5, dp_noise_multiplier=0.4)
    gc = simgrid.GridConfig(mode="async", concurrency=5, goal_count=3,
                            sanitize=True,
                            agg_tail_threshold=STAGED)
    staged = simgrid.run_grid(init_fn, loss_fn, ds, rc, 6, grid=gc, seed=4)
    fused = simgrid.run_grid(init_fn, loss_fn, ds, rc, 6,
                             grid=dataclasses.replace(
                                 gc, agg_tail_threshold=FUSED), seed=4)
    # bits=0 + flush DP takes the exact apply route: bitwise, not just
    # close — history, model, and the epsilon ledger all identical
    assert [h["loss"] for h in staged.history] \
        == [h["loss"] for h in fused.history]
    assert [h["delta_norm"] for h in staged.history] \
        == [h["delta_norm"] for h in fused.history]
    for (pa, la), (pb, lb) in zip(basic.flatten_params(staged.y),
                                  basic.flatten_params(fused.y)):
        assert bool(jnp.all(la == lb)), pa
    assert staged.dp == fused.dp
    assert staged.dp["epsilon"] == fused.dp["epsilon"]
