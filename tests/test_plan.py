"""Trainability plans (core/plan.py): compilation to block sub-layouts,
gather/scatter index maps, capability->tier assignment, per-tier
summaries and tier-sliced wire payloads."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.partition as part
from repro.core import comm, flat as flat_lib, plan as plan_lib
from repro.nn import basic
from repro.sim import devices as dev_lib
from repro.sim import wire


def init_fn(seed):
    return {"enc": basic.init_dense(seed, "enc", 48, 16, jnp.float32,
                                    bias=True),
            "head": basic.init_dense(seed + 1, "head", 16, 4, jnp.float32,
                                     bias=True)}


PLAN = {"full": (), "mid": (r"^head/",), "lite": (r"^head/", r"/bias$")}


def test_train_plan_construction():
    p = plan_lib.TrainPlan.of(PLAN)
    assert p.names == ("full", "mid", "lite")
    assert plan_lib.TrainPlan.of(p) is p
    q = plan_lib.TrainPlan.of([("a", ()), plan_lib.Tier("b", (r"x",))])
    assert q.names == ("a", "b")
    assert len(plan_lib.TrainPlan.single()) == 1
    with pytest.raises(ValueError, match="duplicate"):
        plan_lib.TrainPlan.of([("a", ()), ("a", ())])
    with pytest.raises(ValueError, match="at least one"):
        plan_lib.TrainPlan(())


def test_compile_plan_block_sublayouts():
    y, _ = part.partition(init_fn(0), ())
    cp = plan_lib.compile_plan(PLAN, y)
    assert not cp.trivial
    assert cp.layout.size == sum(cp.layout.padded)
    full, mid, lite = cp.tiers
    # full trains everything; mid drops the head; lite also drops biases
    assert all(full.leaf_on)
    assert full.size == cp.layout.size
    assert mid.size < full.size and lite.size < mid.size
    assert lite.param_count == 48 * 16  # enc kernel only
    # block ids are whole-block selections in ascending order
    for t in cp.tiers:
        assert t.size == len(t.block_ids) * cp.layout.align
        assert np.all(np.diff(t.block_ids) > 0) or len(t.block_ids) <= 1
    # stacked masks match per-tier masks
    bm = cp.block_masks()
    assert bm.shape == (3, cp.layout.num_blocks)
    assert np.all(bm[0] == 1.0)
    with pytest.raises(ValueError, match="train nothing|trains? nothing"
                                         "|every trainable"):
        plan_lib.compile_plan({"dead": (r".",)}, y)


def test_trivial_detection():
    y, _ = part.partition(init_fn(0), ())
    assert plan_lib.compile_plan(plan_lib.TrainPlan.single(), y).trivial
    # a one-tier plan that freezes something is NOT trivial
    assert not plan_lib.compile_plan({"only": (r"/bias$",)}, y).trivial
    # a two-tier plan is never trivial, even if tier 1 freezes nothing
    assert not plan_lib.compile_plan({"a": (), "b": ()}, y).trivial


def test_gather_scatter_roundtrip():
    y, _ = part.partition(init_fn(1), ())
    cp = plan_lib.compile_plan(PLAN, y)
    vec = jnp.arange(cp.layout.size, dtype=jnp.float32) + 1.0
    for t in cp.tiers:
        sub = cp.gather(vec, t)
        assert sub.shape == (t.size,)
        back = cp.scatter(sub, t)
        mask = flat_lib.expand_block_mask(cp.layout.block_mask(t.leaf_on),
                                          cp.layout.align)
        np.testing.assert_array_equal(np.asarray(back),
                                      np.asarray(vec * mask))
        # row-batched forms agree with the vector forms
        mat = jnp.stack([vec, 2.0 * vec])
        np.testing.assert_array_equal(np.asarray(cp.gather(mat, t)[1]),
                                      np.asarray(cp.gather(2.0 * vec, t)))
        np.testing.assert_array_equal(
            np.asarray(cp.scatter(cp.gather(mat, t), t)[0]),
            np.asarray(back))


def test_split_matches_gather():
    """The tier subtree's own FlatLayout IS the contiguous block slice:
    flatten(split) == gather(flatten(full)) — the property that lets a
    tier's delta scatter straight into the global buffer."""
    y, _ = part.partition(init_fn(2), ())
    cp = plan_lib.compile_plan(PLAN, y)
    gvec = cp.layout.flatten(y)
    for t in cp.tiers:
        y_t, extra = cp.split(y, t)
        lt = flat_lib.FlatLayout.of(y_t)
        assert lt.size == t.size
        np.testing.assert_array_equal(np.asarray(lt.flatten(y_t)),
                                      np.asarray(cp.gather(gvec, t)))
        # split halves reassemble the full tree
        merged = part.merge(y_t, extra)
        for (pa, la), (pb, lb) in zip(basic.flatten_params(y),
                                      basic.flatten_params(merged)):
            assert pa == pb and bool(jnp.all(la == lb))


def test_summarize_plan_rows_and_delegation():
    params = init_fn(0)
    rows = part.summarize_plan(params, (), PLAN)
    assert [r["tier"] for r in rows] == ["full", "mid", "lite"]
    # monotone: freezing more raises the comm reduction, shrinks uplink
    assert rows[0]["comm_reduction"] < rows[1]["comm_reduction"] \
        < rows[2]["comm_reduction"]
    assert rows[0]["trainable_bytes"] > rows[1]["trainable_bytes"] \
        > rows[2]["trainable_bytes"]
    for r in rows:
        assert r["total_params"] == rows[0]["total_params"]
    # the one-tier path IS summarize (old API as a one-tier plan)
    s = part.summarize(params, (r"/bias$",))
    row = part.summarize_plan(params, (r"/bias$",),
                              plan_lib.TrainPlan.single())[0]
    assert {k: v for k, v in row.items() if k != "tier"} == s


def test_summarize_survives_all_frozen_spec():
    """summarize() must keep working when the global freeze_spec freezes
    the whole model (trainable_params == 0), as freeze-fraction sweeps
    do — compile_plan only rejects dead TIERS of a non-empty tree."""
    params = init_fn(0)
    s = part.summarize(params, (r".",))
    assert s["trainable_params"] == 0 and s["trainable_pct"] == 0.0
    assert s["total_params"] == part.summarize(params, ())["total_params"]
    cp = plan_lib.compile_plan(plan_lib.TrainPlan.single(), {})
    assert cp.trivial and cp.tiers[0].size == 0


def test_wire_tier_payloads():
    y, _ = part.partition(init_fn(0), ())
    cp = plan_lib.compile_plan(PLAN, y)
    pay = wire.tier_payloads(y, cp)
    # full tier == the global payloads; downlink is tier-invariant
    assert pay["full"]["up"] == wire.uplink_bytes(y)
    down = wire.downlink_bytes(y)
    assert all(p["down"] == down for p in pay.values())
    assert pay["lite"]["up"] < pay["mid"]["up"] < pay["full"]["up"]
    # true bytes, not padded: lite uplink = enc kernel fp32 bytes
    assert pay["lite"]["up"] == 48 * 16 * 4
    # int8 slicing goes through the measured wire format
    pay8 = wire.tier_payloads(y, cp, bits=8)
    y_lite, _ = cp.split(y, cp.tiers[2])
    assert pay8["lite"]["up"] == wire.uplink_bytes(y_lite, bits=8)


def test_assign_tiers_capability():
    uni = dev_lib.make_fleet(8, "uniform")
    # homogeneous fleet: ties break toward the most capable tier -> all
    # clients land in tier 0 (the plan's "full")
    np.testing.assert_array_equal(dev_lib.assign_tiers(uni, 3),
                                  np.zeros(8, np.int32))
    par = dev_lib.make_fleet(60, "pareto-mobile", seed=3)
    tiers = dev_lib.assign_tiers(par, 3)
    counts = np.bincount(tiers, minlength=3)
    assert counts.sum() == 60 and all(c > 0 for c in counts)
    # roughly equal quantile buckets
    assert counts.max() - counts.min() <= 6
    # more capable clients get lower tiers
    scores = np.array([dev_lib.capability_score(p) for p in par.profiles])
    assert scores[tiers == 0].min() >= scores[tiers == 2].max()


def test_assign_tiers_explicit_and_callable():
    fleet = dev_lib.make_fleet(4, "uniform")
    np.testing.assert_array_equal(
        dev_lib.assign_tiers(fleet, 2, [0, 1, 0, 1]), [0, 1, 0, 1])
    by_compute = dev_lib.assign_tiers(
        fleet, 2, lambda p: 0 if p.compute_multiplier <= 1.0 else 1)
    np.testing.assert_array_equal(by_compute, [0, 0, 0, 0])
    with pytest.raises(ValueError, match="shape"):
        dev_lib.assign_tiers(fleet, 2, [0, 1])
    with pytest.raises(ValueError, match="tier indices"):
        dev_lib.assign_tiers(fleet, 2, [0, 1, 2, 0])
    with pytest.raises(ValueError, match="unknown tier assignment"):
        dev_lib.assign_tiers(fleet, 2, "galaxy-brain")


def test_tier_comm_report_ledger():
    rep = comm.CommReport(full_bytes=1000, trainable_bytes=100)
    rep.add_tier_measured("full", 100, 50, transfers=2, uploads=2)
    rep.add_tier_measured("lite", 100, 5, transfers=1, uploads=1)
    rep.add_tier_measured("full", 50, 25, transfers=1, uploads=1)
    assert rep.measured_down_bytes == 250
    assert rep.measured_up_bytes == 80
    assert rep.transfers == 4
    assert rep.tier_traffic["full"] == {"down_bytes": 150, "up_bytes": 75,
                                        "transfers": 3, "uploads": 3}
    tbl = rep.tier_table()
    assert tbl["lite"]["up_bytes_per_upload"] == 5.0
