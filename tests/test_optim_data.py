"""Optimizers vs closed-form references; synthetic-data federation
properties (Dirichlet skew, Markov learnability); comm ledger estimates.
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import comm
from repro.data import synthetic as syn
from repro.optim import optimizers as opt_lib


def test_sgd_matches_closed_form():
    opt = opt_lib.sgd(0.1)
    p = {"w": jnp.ones((3,))}
    g = {"w": jnp.full((3,), 2.0)}
    p2, _ = opt.update(p, g, opt.init(p))
    np.testing.assert_allclose(np.asarray(p2["w"]), 0.8, rtol=1e-6)


def test_sgdm_accumulates_momentum():
    opt = opt_lib.sgdm(1.0, momentum=0.5)
    p = {"w": jnp.zeros(())}
    st_ = opt.init(p)
    g = {"w": jnp.asarray(1.0)}
    p, st_ = opt.update(p, g, st_)   # m=1, p=-1
    p, st_ = opt.update(p, g, st_)   # m=1.5, p=-2.5
    np.testing.assert_allclose(float(p["w"]), -2.5, rtol=1e-6)


def test_adam_first_step_is_lr_sized():
    opt = opt_lib.adam(0.01)
    p = {"w": jnp.zeros((4,))}
    g = {"w": jnp.asarray([1.0, -1.0, 5.0, -0.1])}
    p2, _ = opt.update(p, g, opt.init(p))
    # bias-corrected first Adam step is ~lr * sign(g)
    np.testing.assert_allclose(np.asarray(p2["w"]),
                               -0.01 * np.sign(g["w"]), rtol=1e-3)


@given(st.floats(0.05, 50.0))
@settings(max_examples=8, deadline=None)
def test_dirichlet_skew_controls_heterogeneity(alpha):
    ds = syn.make_federated_images(12, 60, (4, 4, 1), 10, alpha=alpha, seed=3)
    # per-client label entropy grows with alpha
    ents = []
    for lab in ds.client_labels:
        p = np.bincount(lab, minlength=10) / len(lab)
        ents.append(-np.sum(p[p > 0] * np.log(p[p > 0])))
    assert 0 <= np.mean(ents) <= np.log(10) + 1e-6


def test_markov_tokens_are_learnable_structure():
    ds = syn.make_federated_tokens(4, 32, seq_len=20, vocab=100, seed=1)
    toks = np.concatenate(ds.client_tokens)
    # successors of a token concentrate on few values (branch factor 8)
    t0 = toks[:, 0]
    succ = toks[:, 1][t0 == t0[0]]
    assert len(np.unique(succ)) <= 16  # 8 local + 8 shared successors max


def test_comm_transfer_time_uses_uplink_downlink_asymmetry():
    r = comm.CommReport(full_bytes=10 * 2 ** 20, trainable_bytes=2 ** 20)
    # fedpt moves ~1MiB each way; full moves 10MiB each way
    assert r.transfer_seconds(fedpt=True) < r.transfer_seconds(fedpt=False)
    np.testing.assert_allclose(r.transfer_seconds(fedpt=False),
                               10 / 0.75 + 10 / 0.25, rtol=1e-2)
