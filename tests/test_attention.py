"""Attention-layer unit properties: RoPE algebra, flash-vs-dense oracle,
GQA head grouping, MLA compressed-cache equivalence.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig
from repro.nn import attention as att

CFG = ModelConfig(name="a", family="dense", num_layers=1, d_model=32,
                  num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=8,
                  compute_dtype="float32")


def test_rope_preserves_norm_and_relative_positions():
    x = jax.random.normal(jax.random.key(0), (1, 6, 2, 16))
    pos = jnp.arange(6)[None, :]
    cos, sin = att.rope_freqs(16, 1e4, pos)
    xr = att.apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(xr), axis=-1),
                               rtol=1e-5)
    # relative property: <q_m, k_n> depends only on m-n
    q = jax.random.normal(jax.random.key(1), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.key(2), (1, 1, 1, 16))

    def dot_at(m, n):
        cm, sm = att.rope_freqs(16, 1e4, jnp.asarray([[m]]))
        cn, sn = att.rope_freqs(16, 1e4, jnp.asarray([[n]]))
        qm = att.apply_rope(q, cm, sm)
        kn = att.apply_rope(k, cn, sn)
        return float(jnp.sum(qm * kn))

    assert abs(dot_at(5, 3) - dot_at(9, 7)) < 1e-4
    assert abs(dot_at(5, 3) - dot_at(5, 2)) > 1e-6  # but changes with gap


@given(st.integers(1, 2), st.sampled_from([17, 64, 130]),
       st.sampled_from([0, 8]))
@settings(max_examples=8, deadline=None)
def test_flash_matches_dense_softmax(b, s, window):
    cfg = CFG.with_(sliding_window=window)
    h, hd = 2, 16
    ks = jax.random.split(jax.random.key(s * 7 + b), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    out = att.flash_attention(q, k, v, cfg, chunk=32)
    # dense reference
    sc = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(hd))
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = qpos >= kpos
    if window:
        mask = mask & (qpos - kpos < window)
    sc = jnp.where(mask[None, None], sc, -1e30)
    want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(sc, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_gqa_grouping_matches_explicit_repeat():
    """h=4 queries on kvh=2: heads (0,1)->kv0, (2,3)->kv1."""
    b, s, hd = 1, 5, 16
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (b, s, 4, hd))
    k = jax.random.normal(ks[1], (b, s, 2, hd))
    v = jax.random.normal(ks[2], (b, s, 2, hd))
    out = att.flash_attention(q, k, v, CFG)
    krep = jnp.repeat(k, 2, axis=2)
    vrep = jnp.repeat(v, 2, axis=2)
    want = att.flash_attention(q, krep, vrep, CFG)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-6)


def test_mla_decode_matches_full_form():
    """Absorbed-matmul decode over the compressed (c_kv, k_pe) cache must
    equal full-form attention over up-projected K/V."""
    cfg = CFG.with_(use_mla=True, kv_lora_rank=24, q_lora_rank=0,
                    qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16)
    p = att.init_mla(0, "attn", cfg, jnp.float32)
    B, T = 2, 5
    x = jax.random.normal(jax.random.key(5), (B, T, cfg.d_model))
    pos = jnp.arange(T)[None, :]
    q, k, v, (ckv, kpe) = att.mla_qkv(x, p, cfg, pos)
    full = att.flash_attention(q, k, v, cfg)
    full = full.reshape(B, T, -1)
    from repro.nn import basic
    full_o = basic.dense(full, p["wo"], jnp.float32)

    # decode the last token against the compressed cache
    got = att.mla_decode(x[:, T - 1:T], p, cfg, ckv, kpe, T)
    np.testing.assert_allclose(np.asarray(got[:, 0]),
                               np.asarray(full_o[:, T - 1]),
                               atol=3e-5, rtol=3e-5)


def test_decode_attention_ignores_unwritten_slots():
    b, S, h, hd = 1, 8, 2, 16
    ks = jax.random.split(jax.random.key(6), 3)
    q = jax.random.normal(ks[0], (b, 1, h, hd))
    k = jax.random.normal(ks[1], (b, S, h, hd))
    v = jax.random.normal(ks[2], (b, S, h, hd))
    o1 = att.decode_attention(q, k, v, 3, CFG.with_(num_kv_heads=2, num_heads=2))
    k2 = k.at[:, 3:].set(99.0)
    v2 = v.at[:, 3:].set(-99.0)
    o2 = att.decode_attention(q, k2, v2, 3, CFG.with_(num_kv_heads=2, num_heads=2))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)
