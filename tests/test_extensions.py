"""Beyond-paper extensions: adaptive tiered freezing (paper §5 future
work) and quantized uplink (complementary compression).
"""
import jax
import jax.numpy as jnp
import numpy as np

import repro.core.partition as part
from repro.core import adaptive, compress, fedpt
from repro.models import paper_models as pm
from repro.nn import basic


def _loss(params, b):
    logits = pm.emnist_cnn_forward(params, b["images"])
    lp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(lp, b["labels"][:, None], 1)), {}


TIERS = [(), (r"^dense2/",), (r"^dense2/", r"^conv2/")]


def test_tier_masks_are_nested_and_leafwise():
    y, z = part.partition(pm.init_emnist_cnn(0), (r"^dense1/",))
    masks = adaptive.tier_masks(y, TIERS)
    flat = [dict(basic.flatten_params(m)) for m in masks]
    # tier 0 trains everything in the union
    assert all(float(v) == 1.0 for v in flat[0].values())
    # higher tiers freeze supersets
    for a, b in zip(flat, flat[1:]):
        assert all(float(b[k]) <= float(a[k]) for k in a)
    assert float(flat[1]["dense2/kernel"]) == 0.0


def test_tiered_round_respects_masks_and_learns():
    y0, z = part.partition(pm.init_emnist_cnn(0), (r"^dense1/",))
    rc = fedpt.RoundConfig(3, 2, 8, "sgd", 0.05, "sgd", 1.0)
    round_fn, sopt = adaptive.make_tiered_round_fn(_loss, rc, TIERS)
    round_fn = jax.jit(round_fn)
    B = {"images": jax.random.normal(jax.random.key(0), (3, 2, 8, 28, 28, 1)),
         "labels": jax.random.randint(jax.random.key(1), (3, 2, 8), 0, 62)}
    tiers = jnp.asarray([0, 1, 2], jnp.int32)
    w = jnp.ones((3,))
    y1, _, m = round_fn(y0, sopt.init(y0), z, B, w, tiers,
                        jax.random.key(0))
    f0 = dict(basic.flatten_params(y0))
    f1 = dict(basic.flatten_params(y1))
    # dense2 trained only by tier-0 client -> still updated
    assert float(jnp.abs(f1["dense2/kernel"] - f0["dense2/kernel"]).sum()) > 0
    # conv1 trained by all -> updated
    assert float(jnp.abs(f1["conv1/kernel"] - f0["conv1/kernel"]).sum()) > 0
    assert np.isfinite(float(m["delta_norm"]))


def test_tiered_aggregation_excludes_masked_clients():
    """A leaf frozen for tiers 1,2 must equal the tier-0-only average."""
    y0, z = part.partition(pm.init_emnist_cnn(0), (r"^dense1/",))
    rc = fedpt.RoundConfig(2, 1, 4, "sgd", 0.1, "sgd", 1.0)
    round_fn, sopt = adaptive.make_tiered_round_fn(_loss, rc, TIERS)
    B = {"images": jax.random.normal(jax.random.key(0), (2, 1, 4, 28, 28, 1)),
         "labels": jax.random.randint(jax.random.key(1), (2, 1, 4), 0, 62)}
    w = jnp.asarray([1.0, 100.0])   # heavy weight on the masked client
    # client 1 in tier 1 (dense2 frozen): its huge weight must NOT dilute
    # the dense2 update of client 0
    y1, _, _ = jax.jit(round_fn)(y0, sopt.init(y0), z, B, w,
                                 jnp.asarray([0, 1], jnp.int32),
                                 jax.random.key(0))
    # reference: client 0 alone
    y_ref, _, _ = jax.jit(round_fn)(
        y0, sopt.init(y0), z,
        jax.tree_util.tree_map(lambda a: a[:1], B), w[:1],
        jnp.asarray([0], jnp.int32), jax.random.key(0))
    a = dict(basic.flatten_params(y1))["dense2/kernel"]
    b = dict(basic.flatten_params(y_ref))["dense2/kernel"]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-7)


def test_tier_comm_ledger_monotone():
    y, z = part.partition(pm.init_emnist_cnn(0), (r"^dense1/",))
    reps = adaptive.tier_comm_report(y, z, TIERS)
    ups = [r.upload_fedpt for r in reps]
    assert ups[0] > ups[1] > ups[2] > 0
    assert all(r.reduction > 19 for r in reps)  # all tiers beat 20x-ish


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.key(0), (1000,)) * 0.3
    q, s = compress.quantize_leaf(x, 8)
    err = jnp.max(jnp.abs(compress.dequantize_leaf(q, s) - x))
    assert float(err) <= float(s) / 2 + 1e-9
    assert q.dtype == jnp.int8


def test_quantized_uplink_round_still_descends():
    y0, z = part.partition(pm.init_emnist_cnn(0), pm.EMNIST_FREEZE)
    rc = fedpt.RoundConfig(4, 2, 8, "sgd", 0.05, "sgd", 0.5, uplink_bits=8)
    round_fn, sopt = fedpt.make_round_fn(_loss, rc)
    round_fn = jax.jit(round_fn)
    from repro.data import synthetic as syn
    ds = syn.make_federated_images(8, 30, (28, 28, 1), 62, seed=2)
    rng = np.random.default_rng(0)
    ss = sopt.init(y0)
    y = y0
    losses = []
    for r in range(4):
        cids = syn.sample_cohort(rng, 8, 4)
        batch, w = syn.cohort_batch(ds, cids, 2, 8, rng)
        y, ss, m = round_fn(y, ss, z, batch, jnp.asarray(w),
                            jax.random.key(r))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    # uplink ledger: int8 payload is ~4x smaller than f32
    n = compress.quantized_uplink_bytes(y, 8)
    assert n < basic.tree_bytes(y) / 3.5
