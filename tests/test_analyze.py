"""Causal trace graph + critical-path attribution (src/repro/obs/
analyze.py) and the report/compare CLIs over it.

The load-bearing acceptance: on a traced run — sync or async, calm or
hostile — each round/flush window's phase breakdown (downlink, compute,
uplink, retry, apply, wait) sums to its virtual wall time *exactly*,
and the v4 seq/parent chain links every server update back through its
bounding upload to the dispatch that caused it."""
import json

import jax
import jax.numpy as jnp
import pytest

from repro.core import fedpt
from repro.data import synthetic as syn
from repro.nn import basic
from repro.obs import analyze as analyze_lib
from repro.obs import compare as compare_lib
from repro.obs import export as export_lib
from repro.obs import report as report_lib
from repro.obs import schema as schema_lib
from repro.obs import trace as trace_lib
from repro.sim import grid as simgrid


def init_fn(seed):
    return {"dense": basic.init_dense(seed, "dense", 64, 4, jnp.float32,
                                      bias=True)}


def loss_fn(params, b):
    x = b["images"].reshape(b["images"].shape[0], -1)
    logits = basic.dense(x, params["dense"])
    lp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(lp, b["labels"][:, None], 1)), {}


def make_ds(n_clients=10, seed=0):
    return syn.make_federated_images(n_clients, 30, (8, 8, 1), 4, seed=seed,
                                     test_examples=64)


RC = fedpt.RoundConfig(4, 2, 8, "sgd", 0.1, "sgd", 1.0)

DP_RC = fedpt.RoundConfig(4, 2, 8, "sgd", 0.1, "sgd", 1.0,
                          dp_clip_norm=0.5, dp_noise_multiplier=0.4)


def _run(gc, rc=RC, rounds=4, seed=3, n_clients=10):
    return simgrid.run_grid(init_fn, loss_fn, make_ds(n_clients), rc,
                            rounds, grid=gc, seed=seed)


# ---------------------------------------------------------------------------
# The identity: phases sum to the round's virtual wall time


def test_sync_identity_bounded_and_attributed():
    res = _run(simgrid.GridConfig(fleet="pareto-mobile",
                                  telemetry="memory"))
    a = analyze_lib.analyze(res.telemetry)
    assert a.mode == "sync"
    assert len(a.breakdowns) == len(res.history)
    for b in a.breakdowns:
        assert b.check_identity(), b
        assert all(v >= 0.0 for v in b.phases.values()), b.phases
        # no deadline and no over-selection: every round is closed by
        # its slowest counted arrival, so attribution always lands
        assert b.bounded_by is not None and b.bounded_by["cid"] is not None
        # something real happened inside the window
        assert b.phases["compute"] > 0.0 or b.phases["uplink"] > 0.0
    assert a.virtual_seconds == pytest.approx(res.virtual_seconds)
    st = a.stragglers
    assert st["unattributed"] == 0
    assert sum(s["count"] for s in st["by_cid"].values()) \
        == len(a.breakdowns)
    assert sum(s["seconds"] for s in st["by_cid"].values()) \
        == pytest.approx(sum(b.span for b in a.breakdowns))


def test_sync_deadline_bound_rounds_are_wait():
    """Deadline-closed rounds have no bounding upload: the window is
    unattributed wait — and the identity must STILL hold."""
    res = _run(simgrid.GridConfig(fleet="pareto-mobile",
                                  over_selection=1.3,
                                  straggler_deadline=0.02,
                                  telemetry="memory"))
    a = analyze_lib.analyze(res.telemetry)
    assert a.mode == "sync"
    deadline_bound = [b for b in a.breakdowns if b.bounded_by is None]
    assert deadline_bound, "a 20ms deadline on pareto-mobile must bind"
    for b in a.breakdowns:
        assert b.check_identity(), b
    for b in deadline_bound:
        assert b.phases["wait"] == pytest.approx(b.span)
    assert a.stragglers["unattributed"] == len(deadline_bound)


@pytest.mark.chaos
def test_async_chaos_regions_dp_identity():
    """The ISSUE's acceptance run: hostile fleet (chaos faults +
    quarantine) on a 4-region topology with per-flush DP — every
    inter-flush window's phases sum to its span, each flush is
    attributed to the arrival that filled the buffer, and the dp_flush
    chain reproduces the reported budget."""
    gc = simgrid.GridConfig(mode="async", fleet="pareto-mobile",
                            concurrency=5, goal_count=3,
                            telemetry="memory", faults="chaos",
                            sanitize=True, topology=4)
    res = _run(gc, rc=DP_RC, rounds=6, seed=0)
    a = analyze_lib.analyze(res.telemetry)
    assert a.mode == "async"
    assert len(a.breakdowns) == len(res.history)
    for b in a.breakdowns:
        assert b.check_identity(), b
        assert all(v >= -1e-12 for v in b.phases.values()), b.phases
        assert b.bounded_by is not None
        assert b.bounded_by["region"] is not None
    # back-to-back windows tile [0, virtual_seconds of the last flush]
    assert a.breakdowns[0].start == 0.0
    for prev, nxt in zip(a.breakdowns, a.breakdowns[1:]):
        assert prev.end == nxt.start
    # privacy curve == the accountant's own summary
    assert len(a.privacy) == res.dp["flushes"]
    assert a.privacy[-1]["epsilon"] == pytest.approx(res.dp["epsilon"])
    eps = [p["epsilon"] for p in a.privacy]
    assert eps == sorted(eps)
    assert all(p["burn_rate"] >= 0.0 for p in a.privacy)
    # the hostile fleet left fingerprints
    assert a.counts["faults"], "chaos run must record faults"
    assert sum(a.counts["quarantine"].values()) \
        == res.faults["quarantined"]


def test_chain_integrity_async():
    """v4 causal ids: upload -> dispatch, flush -> upload, dp_flush /
    edge_flush -> flush, and seqs strictly increase."""
    gc = simgrid.GridConfig(mode="async", fleet="pareto-mobile",
                            concurrency=5, goal_count=3,
                            telemetry="memory", topology=2)
    res = _run(gc, rc=DP_RC, rounds=5, seed=1)
    recs = [r.to_json() for r in res.telemetry.events]
    assert schema_lib.validate_causal_ids(recs) == []
    g = analyze_lib.build_graph(res.telemetry)
    for u in g.of_kind("upload"):
        assert g.get(u.parent).kind == "dispatch", u
    flush_seqs = set()
    for f in g.of_kind("flush"):
        flush_seqs.add(f.seq)
        assert g.get(f.parent).kind == "upload", f
        # the bounding upload is the LATEST buffered arrival: monotone
        # seqs make it the max over the flushed batch
        assert f.parent < f.seq
    for d in g.of_kind("dp_flush"):
        assert d.parent in flush_seqs, d
    for e in g.of_kind("edge_flush"):
        assert e.parent in flush_seqs, e
    for t in g.of_kind("tier_upload"):
        assert t.parent in flush_seqs, t


def test_sync_chain_integrity():
    res = _run(simgrid.GridConfig(fleet="pareto-mobile",
                                  telemetry="memory"))
    g = analyze_lib.build_graph(res.telemetry)
    round_seqs = set()
    for r in g.of_kind("round"):
        round_seqs.add(r.seq)
        up = g.get(r.parent)
        assert up is not None and up.kind == "upload", r
        assert g.get(up.parent).kind == "dispatch"
        # the bounding upload lands exactly at the round's end
        assert up.t == pytest.approx(r.end)
    for t in g.of_kind("tier_upload"):
        assert t.parent in round_seqs


def test_jsonl_roundtrip_equals_memory(tmp_path):
    gc = simgrid.GridConfig(mode="async", fleet="pareto-mobile",
                            concurrency=5, goal_count=3,
                            telemetry="memory", faults="chaos",
                            sanitize=True)
    res = _run(gc, rounds=5, seed=2)
    p = str(tmp_path / "run.jsonl")
    export_lib.write_jsonl(res.telemetry.events, p)
    via_file = analyze_lib.analyze(p).to_json()
    in_memory = analyze_lib.analyze(res.telemetry).to_json()
    assert json.dumps(via_file, sort_keys=True) \
        == json.dumps(in_memory, sort_keys=True)


# ---------------------------------------------------------------------------
# Degradation: pre-v4 traces, empty traces, dur=None spans


def test_pre_v4_trace_degrades_to_wait():
    """v1-v3 records (no seq/parent) still build a graph and still
    satisfy the identity — every round is just unattributed wait."""
    recs = [
        {"v": 1, "kind": "dispatch", "t": 0.0, "dur": 2.0, "cid": 1},
        {"v": 2, "kind": "upload", "t": 2.0, "cid": 1, "up_bytes": 10},
        {"v": 3, "kind": "round", "t": 0.0, "dur": 4.0, "round": 0},
        {"v": 1, "kind": "dispatch", "t": 4.0, "dur": None, "cid": 2,
         "outcome": "dropout"},
    ]
    assert schema_lib.validate_records(recs) == []
    a = analyze_lib.analyze(recs)
    assert a.mode == "sync"
    (b,) = a.breakdowns
    assert b.check_identity()
    assert b.bounded_by is None
    assert b.phases["wait"] == pytest.approx(4.0)
    assert a.stragglers["unattributed"] == 1
    # ...but the causal-id contract rightly rejects such a stream
    assert schema_lib.validate_causal_ids(recs) != []


def test_empty_trace_everything_is_empty():
    a = analyze_lib.analyze([])
    assert a.mode == "empty"
    assert a.breakdowns == [] and a.virtual_seconds == 0.0
    assert a.privacy == [] and a.wire == {}
    assert a.stragglers["unattributed"] == 0
    doc = export_lib.perfetto_trace([])
    assert [e for e in doc["traceEvents"] if e.get("ph") not in ("M",)] \
        == []
    text = report_lib.build_report([])
    assert "no rounds/flushes" in text


def test_validate_causal_ids_contract():
    ok = [
        {"v": 4, "kind": "dispatch", "t": 0.0, "dur": 1.0, "seq": 0},
        {"v": 4, "kind": "upload", "t": 1.0, "up_bytes": 5, "cid": 1,
         "seq": 1, "parent": 0},
    ]
    assert schema_lib.validate_causal_ids(ok) == []
    missing = [dict(ok[0]), dict(ok[1])]
    del missing[1]["seq"]
    assert any("seq" in e for e in schema_lib.validate_causal_ids(missing))
    decreasing = [dict(ok[0], seq=5), dict(ok[1], seq=3, parent=None)]
    assert schema_lib.validate_causal_ids(decreasing) != []
    dangling = [dict(ok[0]), dict(ok[1], parent=99)]
    assert any("parent" in e
               for e in schema_lib.validate_causal_ids(dangling))
    no_links = [dict(ok[0]), dict(ok[1], parent=None)]
    assert any("no parent link" in e
               for e in schema_lib.validate_causal_ids(no_links))


def test_perfetto_flow_events_and_stable_sort():
    """Same-timestamp events sort by seq (deterministic output order
    regardless of emission order), and parent links become Perfetto
    flow ("s"/"f") pairs that ui.perfetto.dev draws as arrows."""
    recs = [
        trace_lib.TraceRecord("dispatch", 0.0, 2.0, {"cid": 1}, 0, None),
        # two instants at the SAME t, listed in reverse seq order
        trace_lib.TraceRecord("flush", 2.0, None, {"version": 0}, 2, 1),
        trace_lib.TraceRecord("upload", 2.0, None,
                              {"cid": 1, "up_bytes": 5}, 1, 0),
        # dangling parent (resumed run): no flow, no crash
        trace_lib.TraceRecord("dp_flush", 2.0, None, {"flush": 0}, 3, 99),
    ]
    doc = export_lib.perfetto_trace(recs)
    named = [e for e in doc["traceEvents"]
             if e.get("ph") not in ("M", "s", "f")]
    same_t = [e["name"] for e in named if e["ts"] == 2.0e6]
    assert same_t == ["upload", "flush", "dp_flush"]   # seq order, not input
    flows = [e for e in doc["traceEvents"] if e.get("ph") in ("s", "f")]
    # two real links (0->1, 1->2); seq 3's parent 99 is dangling
    assert {(e["ph"], e["id"]) for e in flows} \
        == {("s", 1), ("f", 1), ("s", 2), ("f", 2)}
    for e in flows:
        assert e["cat"] == "causal"
    # flow starts sit at the parent's coordinates, ends at the child's
    start1 = next(e for e in flows if e["ph"] == "s" and e["id"] == 1)
    assert start1["ts"] == 2.0e6                      # dispatch end
    # reversing input order must not change the export
    doc2 = export_lib.perfetto_trace(list(reversed(recs)))
    assert json.dumps(doc, sort_keys=True) \
        == json.dumps(doc2, sort_keys=True)


# ---------------------------------------------------------------------------
# Report + compare CLIs


def _traced_run_files(tmp_path, seed=7):
    gc = simgrid.GridConfig(mode="async", fleet="pareto-mobile",
                            concurrency=5, goal_count=3,
                            telemetry="memory", faults="chaos",
                            sanitize=True, topology=2)
    res = _run(gc, rc=DP_RC, rounds=4, seed=seed)
    jsonl = str(tmp_path / f"run{seed}.jsonl")
    export_lib.write_jsonl(res.telemetry.events, jsonl)
    snap = str(tmp_path / f"snap{seed}.json")
    with open(snap, "w") as f:
        json.dump(res.metrics.snapshot(), f)
    return res, jsonl, snap


def test_report_cli_renders_and_cross_checks(tmp_path):
    res, jsonl, snap = _traced_run_files(tmp_path)
    out = str(tmp_path / "report.md")
    assert report_lib.main([jsonl, "--metrics", snap, "-o", out]) == 0
    text = open(out).read()
    assert "## Critical path" in text
    assert "identity" in text and "holds" in text and "VIOLATED" not in text
    assert "## Straggler attribution" in text
    assert "## Privacy budget" in text
    assert f"{res.dp['epsilon']:.4g}" in text
    assert "## Metrics cross-check" in text and "MISMATCH" not in text
    assert "## Events" in text


def test_compare_cli_gates(tmp_path, capsys):
    _, jsonl_a, snap_a = _traced_run_files(tmp_path, seed=7)
    _, jsonl_b, snap_b = _traced_run_files(tmp_path, seed=8)
    # identical inputs: the strictest gate passes
    assert compare_lib.main([snap_a, snap_a, "--fail-on", "*"]) == 0
    # different seeds: counter totals differ -> exact gate trips...
    assert compare_lib.main([snap_a, snap_b,
                             "--fail-on", "counter.up_bytes"]) == 1
    out = capsys.readouterr().out
    assert "FAIL counter.up_bytes" in out
    # ...a generous relative tolerance lets the same pair through
    assert compare_lib.main([snap_a, snap_b,
                             "--fail-on", "counter.up_bytes:10.0"]) == 0
    # traces flatten too, and diff against each other
    diff_md = str(tmp_path / "diff.md")
    assert compare_lib.main([jsonl_a, jsonl_b, "--changed-only",
                             "-o", diff_md]) == 0
    text = open(diff_md).read()
    assert "Run diff" in text and "virtual_seconds" in text
    # a trace/snapshot pair shares no names: gating one errors out
    assert compare_lib.main([jsonl_a, snap_a,
                             "--fail-on", "kind.flush"]) == 1


def test_compare_flatten_shapes(tmp_path):
    res, jsonl, snap = _traced_run_files(tmp_path, seed=9)
    flat_t = compare_lib.flatten(jsonl)
    assert flat_t["kind.flush"] == len(res.history)
    assert flat_t["privacy.epsilon_final"] \
        == pytest.approx(res.dp["epsilon"])
    assert flat_t["virtual_seconds"] > 0
    flat_s = compare_lib.flatten(snap)
    assert flat_s["counter.uploads"] \
        == res.scheduler_stats["uploads"]
    # labeled counters flatten per label
    assert any(k.startswith("counter.region_uploads/")
               for k in flat_s)


def test_summarize_bench_digest(capsys):
    import benchmarks.summarize as summ

    summ.main(["--bench"])
    out = capsys.readouterr().out
    assert "Benchmark digest" in out
    assert "Server aggregation" in out and "fused speedup" in out
    assert "Fleet state" in out and "vectorized speedup" in out
    assert "Selection-policy sweep" in out and "vs uniform" in out
