"""Per-architecture smoke tests (assignment requirement): a REDUCED
variant of each assigned architecture's family (<=2 effective layer
groups, d_model <= 512, <= 4 experts) runs one forward and one federated
train step on CPU; output shapes and finiteness are asserted. The FULL
configs are exercised by the dry-run only.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import load_all, ARCH_IDS
from repro.configs.base import get_config
from repro.core import fedpt
import repro.core.partition as part
from repro.launch.train import reduced_config
from repro.models import decoder_lm as dlm

load_all()
ARCHS = list(ARCH_IDS)


def make_batch(cfg, clients=2, tau=1, b=2, seq=16):
    key = jax.random.key(0)
    batch = {
        "tokens": jax.random.randint(key, (clients, tau, b, seq), 0,
                                     cfg.vocab_size),
    }
    batch["labels"] = batch["tokens"]
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jnp.zeros(
            (clients, tau, b, cfg.num_prefix_tokens, 1152), jnp.float32)
    if cfg.is_encoder_decoder:
        batch["encoder_embeds"] = jnp.zeros(
            (clients, tau, b, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced_config(get_config(arch))
    assert cfg.d_model <= 512 and cfg.num_experts <= 4
    params = dlm.init_model(cfg, 0)
    b, s = 2, 16
    toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab_size)
    kw = {}
    exp_s = s
    if cfg.family == "vlm":
        kw["prefix_embeds"] = jnp.zeros((b, cfg.num_prefix_tokens, 1152))
        exp_s = s + cfg.num_prefix_tokens
    if cfg.is_encoder_decoder:
        kw["encoder_embeds"] = jnp.zeros((b, cfg.encoder_seq_len, cfg.d_model))
    logits, metrics = dlm.forward(params, cfg, toks, **kw)
    assert logits.shape == (b, exp_s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_one_federated_train_step(arch):
    cfg = reduced_config(get_config(arch))
    init_fn = lambda s: dlm.init_model(cfg, s)
    y, frozen = part.partition(init_fn(0), cfg.freeze_spec)
    assert part.count_params(frozen) > 0, "freeze spec must bind"

    def loss_fn(params, mb):
        return dlm.train_loss(params, cfg, mb)

    rc = fedpt.RoundConfig(2, 1, 2, "sgd", 0.05, "sgd", 1.0)
    round_fn, sopt = fedpt.make_round_fn(loss_fn, rc)
    sstate = sopt.init(y)
    batch = make_batch(cfg)
    w = jnp.ones((2,), jnp.float32)
    y2, sstate, m = jax.jit(round_fn)(y, sstate, frozen, batch, w,
                                      jax.random.key(0))
    assert np.isfinite(float(m["loss"]))
    # trainable moved, frozen untouched by construction
    moved = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.sum(jnp.abs(l))),
        jax.tree_util.tree_map(lambda a, b: a - b, y2, y), 0.0)
    assert moved > 0.0


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "xlstm-350m",
                                  "deepseek-v2-236b", "whisper-large-v3",
                                  "jamba-v0.1-52b"])
def test_one_decode_step(arch):
    cfg = reduced_config(get_config(arch))
    params = dlm.init_model(cfg, 0)
    cache = dlm.init_cache(cfg, 2, 8)
    if cfg.is_encoder_decoder:
        cache["cross"] = dlm.build_cross_cache(
            params, cfg, jnp.zeros((2, cfg.encoder_seq_len, cfg.d_model)))
    toks = jnp.ones((2, 1), jnp.int32)
    logits, cache = dlm.decode_step(params, cfg, cache, toks)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert int(cache["cache_len"]) == 1
