"""Simulation grid: bit-for-bit equivalence with the plain federated
loop, byte-exact wire metering, straggler/dropout handling, and buffered
async aggregation with staleness weighting."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.partition as part
from repro.core import comm, fedpt
from repro.data import synthetic as syn
from repro.fl import runtime
from repro.nn import basic
from repro.sim import devices as dev_lib
from repro.sim import grid as simgrid
from repro.sim import scheduler as sched_lib
from repro.sim import wire


# ---------------------------------------------------------------------------
# A tiny linear model so each test compiles in well under a second.


def init_fn(seed):
    return {"dense": basic.init_dense(seed, "dense", 64, 4, jnp.float32,
                                      bias=True)}


def loss_fn(params, b):
    x = b["images"].reshape(b["images"].shape[0], -1)
    logits = basic.dense(x, params["dense"])
    lp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(lp, b["labels"][:, None], 1)), {}


def make_ds(n_clients=12, seed=0):
    return syn.make_federated_images(n_clients, 30, (8, 8, 1), 4, seed=seed,
                                     test_examples=64)


RC = fedpt.RoundConfig(4, 2, 8, "sgd", 0.1, "sgd", 1.0)


# ---------------------------------------------------------------------------
# Acceptance: homogeneous sync grid == the plain loop, bit for bit


def test_sync_grid_reproduces_plain_loop_bit_for_bit():
    ds = make_ds()
    seed, rounds = 3, 5
    # reference: the pre-grid run_federated loop, inlined
    y, frozen = part.partition(init_fn(seed), ())
    round_fn, sopt = fedpt.make_round_fn(loss_fn, RC)
    round_fn = jax.jit(round_fn, donate_argnums=(0, 1))
    ss = sopt.init(y)
    rng = np.random.default_rng(seed + 77)
    ref_losses = []
    for r in range(rounds):
        cids = syn.sample_cohort(rng, ds.num_clients, RC.clients_per_round)
        batch, w = syn.cohort_batch(ds, cids, RC.local_steps, RC.local_batch,
                                    rng)
        y, ss, m = round_fn(y, ss, frozen, batch, jnp.asarray(w),
                            jax.random.key(seed * 100_003 + r))
        ref_losses.append(float(m["loss"]))

    res = runtime.run_federated(init_fn, loss_fn, ds, RC, rounds, seed=seed)
    assert [h["loss"] for h in res.history] == ref_losses
    for (p1, l1), (p2, l2) in zip(basic.flatten_params(y),
                                  basic.flatten_params(res.y)):
        assert p1 == p2
        assert bool(jnp.all(l1 == l2)), p1


# ---------------------------------------------------------------------------
# Acceptance: measured wire bytes == analytic ledger (fp32 exactly)


def test_wire_bytes_match_analytic():
    y, frozen = part.partition(init_fn(0), (r"bias",))
    wire.assert_matches_analytic(y, frozen, uplink_bits=0)
    wire.assert_matches_analytic(y, frozen, uplink_bits=8)
    rep = comm.report_for(y, frozen)
    assert wire.downlink_bytes(y) == rep.download_fedpt \
        == basic.tree_bytes(y) + comm.SEED_BYTES
    assert wire.uplink_bytes(y) == rep.upload_fedpt == basic.tree_bytes(y)


def test_wire_roundtrip():
    y, _ = part.partition(init_fn(1), ())
    spec = wire.TreeSpec.of(y)
    buf = wire.encode_downlink(y, seed=42)
    y2, seed = wire.decode_downlink(buf, spec)
    assert seed == 42
    for a, b in zip(jax.tree_util.tree_leaves(y),
                    jax.tree_util.tree_leaves(y2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # fp32 uplink is lossless
    delta = jax.tree_util.tree_map(lambda l: l * 0.1, y)
    d2 = wire.decode_uplink(wire.encode_uplink(delta), spec)
    for a, b in zip(jax.tree_util.tree_leaves(delta),
                    jax.tree_util.tree_leaves(d2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # int8 uplink is lossy but within half a quantization step per leaf
    buf8 = wire.encode_uplink(delta, bits=8)
    from repro.core import compress
    assert len(buf8) == compress.quantized_uplink_bytes(delta, 8)
    d8 = wire.decode_uplink(buf8, spec, bits=8)
    for a, b in zip(jax.tree_util.tree_leaves(delta),
                    jax.tree_util.tree_leaves(d8)):
        step = float(jnp.max(jnp.abs(a))) / 127.0
        assert float(jnp.max(jnp.abs(a - b))) <= step / 2 + 1e-7


def test_grid_meters_every_transfer():
    ds = make_ds()
    res = simgrid.run_grid(init_fn, loss_fn, ds, RC, 4, seed=0)
    rep = comm.report_for(res.y, res.frozen)
    n = res.comm.transfers
    assert n == 4 * RC.clients_per_round
    assert res.comm.measured_down_bytes == rep.download_fedpt * n
    assert res.comm.measured_up_bytes == rep.upload_fedpt * n


# ---------------------------------------------------------------------------
# Scheduler: straggler deadlines, over-selection, dropout


def _fleet(mults, **kw):
    mb = 1024.0 * 1024.0
    return dev_lib.Fleet(name="test", profiles=[
        dev_lib.DeviceProfile(downlink_bps=mb, uplink_bps=mb,
                              compute_multiplier=m, **kw) for m in mults])


def test_sync_plan_straggler_deadline_drop():
    # compute_seconds=1.0, no wire bytes: finish times == multipliers
    fleet = _fleet([1.0, 2.0, 3.0, 50.0])
    plan = sched_lib.plan_sync_round(fleet, [0, 1, 2, 3], 0, 0, 1.0,
                                     clients_needed=4,
                                     rng=np.random.default_rng(0),
                                     deadline=10.0)
    assert plan.deadline_drops == 1
    assert list(plan.participant) == [True, True, True, False]
    assert plan.round_seconds == 10.0  # server waited the deadline out
    np.testing.assert_array_equal(plan.participant_cids(), [0, 1, 2])


def test_sync_plan_over_selection_takes_first_arrivals():
    fleet = _fleet([5.0, 1.0, 3.0, 2.0])
    plan = sched_lib.plan_sync_round(fleet, [0, 1, 2, 3], 0, 0, 1.0,
                                     clients_needed=2,
                                     rng=np.random.default_rng(0))
    # fastest two finish at t=1 (cid 1) and t=2 (cid 3)
    np.testing.assert_array_equal(plan.participant_cids(), [1, 3])
    assert plan.round_seconds == 2.0
    # over-selected losers arrived on time but past the quota: counted as
    # excess, NOT as deadline drops (there is no deadline here)
    assert plan.excess == 2 and plan.deadline_drops == 0


def test_sync_plan_dropout_and_offline():
    fleet = _fleet([1.0, 1.0, 1.0], dropout=1.0)     # everyone drops
    plan = sched_lib.plan_sync_round(fleet, [0, 1, 2], 0, 0, 1.0, 3,
                                     np.random.default_rng(0), deadline=5.0)
    assert plan.dropouts == 3 and not plan.participant.any()
    assert plan.round_seconds == 5.0
    off = _fleet([1.0, 1.0], availability=0.0)       # everyone offline
    plan = sched_lib.plan_sync_round(off, [0, 1], 0, 0, 1.0, 2,
                                     np.random.default_rng(0), deadline=5.0)
    assert plan.offline == 2 and plan.dropouts == 0


def test_sync_grid_drops_straggler_weight():
    """A client that can never finish by the deadline must not influence
    the aggregate: its round-engine weight is zeroed."""
    ds = make_ds(n_clients=4)
    fleet = _fleet([1.0, 1.0, 1.0, 500.0])
    rc = fedpt.RoundConfig(4, 2, 8, "sgd", 0.1, "sgd", 1.0)
    gc = simgrid.GridConfig(mode="sync", fleet=fleet, straggler_deadline=10.0,
                            base_step_time=1.0)
    res = simgrid.run_grid(init_fn, loss_fn, ds, rc, 3, grid=gc, seed=0)
    assert res.scheduler_stats["deadline_drops"] == 3  # slow client, 3 rounds
    assert all(h["participants"] == 3.0 for h in res.history)
    assert res.virtual_seconds == pytest.approx(30.0)


# ---------------------------------------------------------------------------
# Buffered async scheduler (unit, no JAX)


def test_async_goal_count_and_staleness_weighting():
    # cid 0 finishes in 1s, cid 1 in 5.5s; no wire time
    fleet = _fleet([1.0, 5.5])
    samples = iter([0, 1] + [0] * 50)
    applied = []

    def run_client(cid, version):
        return {"delta": cid, "weight": 1.0, "loss": 0.5, "up_bytes": 0}

    def apply_update(entries, now, version):
        applied.append((now, version, [(e.staleness, e.weight) for e in entries]))
        return {}

    sched = sched_lib.BufferedAsyncScheduler(
        fleet=fleet, concurrency=2, goal_count=2,
        staleness_fn=fedpt.get_staleness_fn("polynomial", power=0.5),
        sample_cid=lambda rng: next(samples), run_client=run_client,
        apply_update=apply_update, down_bytes=0, compute_seconds=1.0,
        rng=np.random.default_rng(0))
    records = sched.run(3)

    assert len(records) == 3
    assert all(len(entries) == 2 for _, _, entries in applied)  # goal count K
    # updates 1 and 2 are pure fast-client buffers (staleness 0)
    assert applied[0][2] == [(0, 1.0), (0, 1.0)]
    assert applied[1][2] == [(0, 1.0), (0, 1.0)]
    # the slow client dispatched at t=0 lands at t=5.5, after 2 server
    # updates: staleness 2, weight (1+2)^-0.5
    stale = dict(applied[2][2])
    assert 2 in stale
    assert stale[2] == pytest.approx((1.0 + 2.0) ** -0.5)
    assert records[2]["staleness_max"] == 2.0
    assert records[-1]["virtual_seconds"] >= records[0]["virtual_seconds"]


def test_staleness_fns():
    poly = fedpt.get_staleness_fn("polynomial", power=0.5)
    assert poly(0) == 1.0 and poly(3) == pytest.approx(0.5)
    const = fedpt.get_staleness_fn("constant")
    assert const(100) == 1.0
    hinge = fedpt.get_staleness_fn("hinge", delay=2.0, slope=1.0)
    assert hinge(2) == 1.0 and hinge(4) == pytest.approx(1.0 / 3.0)
    assert fedpt.get_staleness_fn(lambda s: 7.0)(1) == 7.0
    with pytest.raises(ValueError):
        fedpt.get_staleness_fn("nope")


# ---------------------------------------------------------------------------
# Async grid end-to-end (heterogeneous fleet + quantized uplink)


def test_async_grid_end_to_end():
    ds = make_ds(n_clients=20, seed=0)
    rc = fedpt.RoundConfig(4, 2, 8, "sgd", 0.1, "sgd", 1.0, uplink_bits=8)
    gc = simgrid.GridConfig(mode="async", fleet="pareto-mobile",
                            concurrency=6, goal_count=3,
                            staleness="polynomial")
    res = simgrid.run_grid(init_fn, loss_fn, ds, rc, 12, grid=gc, seed=1)
    assert len(res.history) == 12
    assert res.history[-1]["loss"] < res.history[0]["loss"]
    assert res.virtual_seconds > 0
    assert any(h["staleness_max"] > 0 for h in res.history)
    # every upload was metered at the measured int8 payload size
    per_up = wire.uplink_bytes(res.y, bits=8)
    assert res.comm.measured_up_bytes == per_up * res.scheduler_stats["uploads"]
    assert res.comm.measured_down_bytes == (wire.downlink_bytes(res.y)
                                            * res.scheduler_stats["dispatches"])
    assert res.comm.upload_fedpt == per_up  # analytic agrees with the wire


def test_async_grid_dp_per_flush():
    """Async DP composes per flush: noise is drawn once per buffered
    server update with the fixed goal_count denominator, the run is
    replay-deterministic, and the accountant reports the composition."""
    ds = make_ds(n_clients=10)
    rc = fedpt.RoundConfig(4, 2, 8, "sgd", 0.1, "sgd", 1.0,
                           dp_clip_norm=0.5, dp_noise_multiplier=0.4)
    gc = simgrid.GridConfig(mode="async", concurrency=5, goal_count=3)
    a = simgrid.run_grid(init_fn, loss_fn, ds, rc, 6, grid=gc, seed=4)
    b = simgrid.run_grid(init_fn, loss_fn, ds, rc, 6, grid=gc, seed=4)
    # deterministic: per-flush keys come from the seed stream
    assert [h["loss"] for h in a.history] == [h["loss"] for h in b.history]
    for (pa, la), (pb, lb) in zip(basic.flatten_params(a.y),
                                  basic.flatten_params(b.y)):
        assert bool(jnp.all(la == lb)), pa
    assert a.dp == b.dp
    assert a.dp["flushes"] == 6 and a.dp["padded_flushes"] == 0
    assert a.dp["sigma"] == pytest.approx(0.4 * 0.5 / 3)
    assert a.dp["max_multiplicity"] >= 1   # with-replacement dispatch
    assert 0 < a.dp["epsilon"] < math.inf
    # the noise path actually fires: same config with z=0 diverges
    rc0 = fedpt.RoundConfig(4, 2, 8, "sgd", 0.1, "sgd", 1.0,
                            dp_clip_norm=0.5)
    c = simgrid.run_grid(init_fn, loss_fn, ds, rc0, 6, grid=gc, seed=4)
    assert c.dp is None
    assert any(h["loss"] != hc["loss"] or h["delta_norm"] != hc["delta_norm"]
               for h, hc in zip(a.history, c.history))
    # ... but the virtual clock / staleness bookkeeping is unaffected
    for h, hc in zip(a.history, c.history):
        assert h["virtual_seconds"] == hc["virtual_seconds"]
        assert h["staleness_mean"] == hc["staleness_mean"]


def test_async_grid_dp_noise_requires_clip():
    ds = make_ds(n_clients=6)
    rc = fedpt.RoundConfig(4, 2, 8, dp_noise_multiplier=0.5)
    with pytest.raises(ValueError, match="dp_clip_norm"):
        simgrid.run_grid(init_fn, loss_fn, ds, rc, 1,
                         grid=simgrid.GridConfig(mode="async"))


def test_async_grid_dp_drained_flush_keeps_noise_scale():
    """The deadline-drained final buffer is padded to goal_count with
    zero weights: same fixed denominator, same sigma, and the accountant
    records it as one (padded) flush."""
    ds = make_ds(n_clients=10)
    rc = fedpt.RoundConfig(4, 2, 8, "sgd", 0.1, "sgd", 1.0,
                           dp_clip_norm=0.5, dp_noise_multiplier=0.4)
    gc = simgrid.GridConfig(mode="async", concurrency=4, goal_count=3)
    full = simgrid.run_grid(init_fn, loss_fn, ds, rc, 6, grid=gc, seed=2)
    cut = (full.history[1]["virtual_seconds"]
           + full.history[2]["virtual_seconds"]) / 2.0
    gcd = dataclasses.replace(gc, async_deadline=cut)
    res = simgrid.run_grid(init_fn, loss_fn, ds, rc, 6, grid=gcd, seed=2)
    assert res.history[-1]["buffer_fill"] < gc.goal_count
    assert res.dp["flushes"] == len(res.history)
    assert res.dp["padded_flushes"] == 1
    assert res.dp["sigma"] == full.dp["sigma"]
    # the un-cut prefix replays the unconstrained run exactly (identical
    # per-flush keys and fixed denominator)
    for a, b in zip(full.history[:2], res.history[:2]):
        assert a["loss"] == b["loss"]
        assert a["delta_norm"] == b["delta_norm"]


# ---------------------------------------------------------------------------
# Trainability tiers (core/plan.py) in the grid

TIER_PLAN = {"full": (), "mid": (r"/bias$",), "lite": (r"/kernel$",)}


def _assert_same_run(a, b):
    assert [h["loss"] for h in a.history] == [h["loss"] for h in b.history]
    for ha, hb in zip(a.history, b.history):
        assert ha["virtual_seconds"] == hb["virtual_seconds"]
    for (pa, la), (pb, lb) in zip(basic.flatten_params(a.y),
                                  basic.flatten_params(b.y)):
        assert pa == pb and bool(jnp.all(la == lb)), pa
    assert a.comm.measured_down_bytes == b.comm.measured_down_bytes
    assert a.comm.measured_up_bytes == b.comm.measured_up_bytes
    assert a.scheduler_stats == b.scheduler_stats


def test_sync_grid_one_tier_plan_bit_for_bit():
    """Acceptance: a one-tier plan covering all clients IS the pre-plan
    single-spec system — same history, params, clock and wire bytes."""
    from repro.core import plan as plan_lib
    ds = make_ds()
    ref = simgrid.run_grid(init_fn, loss_fn, ds, RC, 4, seed=3)
    gc = simgrid.GridConfig(plan=plan_lib.TrainPlan.single())
    got = simgrid.run_grid(init_fn, loss_fn, ds, RC, 4, grid=gc, seed=3)
    _assert_same_run(ref, got)
    # ... and the whole ledger lands on the single tier
    assert set(got.tier_stats) == {"full"}
    assert got.tier_stats["full"]["up_bytes"] == ref.comm.measured_up_bytes
    assert got.tier_stats["full"]["clients"] == ds.num_clients


def test_async_grid_one_tier_plan_lane_exact():
    """Acceptance: the async lane engine under a one-tier plan replays
    the pre-plan run exactly (virtual clock, staleness, params)."""
    from repro.core import plan as plan_lib
    ds = make_ds(n_clients=16)
    gc = simgrid.GridConfig(mode="async", fleet="pareto-mobile",
                            concurrency=6, goal_count=3)
    ref = simgrid.run_grid(init_fn, loss_fn, ds, RC, 8, grid=gc, seed=2)
    got = simgrid.run_grid(
        init_fn, loss_fn, ds, RC, 8, seed=2,
        grid=dataclasses.replace(gc, plan=plan_lib.TrainPlan.single()))
    _assert_same_run(ref, got)
    for ha, hb in zip(ref.history, got.history):
        assert ha["staleness_mean"] == hb["staleness_mean"]


def test_async_grid_mixed_tiers_bills_fewer_uplink():
    """Acceptance: a mixed-tier fleet bills strictly fewer uplink bytes
    than the all-`full` run, with per-tier byte counts reported."""
    ds = make_ds(n_clients=12)
    gc = simgrid.GridConfig(mode="async", fleet="pareto-mobile",
                            concurrency=6, goal_count=3)
    full = simgrid.run_grid(init_fn, loss_fn, ds, RC, 10, grid=gc, seed=5)
    mixed = simgrid.run_grid(
        init_fn, loss_fn, ds, RC, 10, seed=5,
        grid=dataclasses.replace(gc, plan=TIER_PLAN))
    assert mixed.history[-1]["loss"] < mixed.history[0]["loss"]
    st = mixed.tier_stats
    assert set(st) == {"full", "mid", "lite"}
    assert sum(r["clients"] for r in st.values()) == ds.num_clients
    # per-tier bytes are reported and sum to the ledger totals
    assert sum(r["up_bytes"] for r in st.values()) \
        == mixed.comm.measured_up_bytes
    assert sum(r["down_bytes"] for r in st.values()) \
        == mixed.comm.measured_down_bytes
    # every mid/lite upload is strictly smaller than a full upload, so
    # with any non-full participation the mixed fleet pays less uplink
    # per upload on average
    per_up_mixed = mixed.comm.measured_up_bytes / max(
        mixed.scheduler_stats["uploads"], 1)
    per_up_full = full.comm.measured_up_bytes / max(
        full.scheduler_stats["uploads"], 1)
    assert sum(r["uploads"] for r in st.values() if r["uploads"]) > 0
    assert any(r["uploads"] > 0 for k, r in st.items() if k != "full")
    assert per_up_mixed < per_up_full
    # tier uplink is billed at the measured sliced payload, and
    # tier_stats' per-upload figure matches the measured ledger
    y_mid, _ = mixed.plan.split(mixed.y, mixed.plan.tiers[1])
    assert st["mid"]["up_bytes"] == wire.uplink_bytes(y_mid) \
        * st["mid"]["uploads"]
    for name, rec in st.items():
        want = rec["up_bytes"] / rec["uploads"] if rec["uploads"] else 0.0
        assert rec["up_bytes_per_upload"] == want, name
        assert rec["up_bytes_per_upload"] \
            == mixed.comm.tier_table()[name]["up_bytes_per_upload"]


def test_sync_grid_mixed_tiers():
    """Mixed tiers in the synchronous cohort engine: per-row tier masks
    keep frozen-for-this-tier leaves still when no capable client is
    sampled, and the wire bills tier-sliced uploads."""
    ds = make_ds(n_clients=9)
    # explicit census: clients 0-2 full, 3-5 mid (bias frozen), 6-8 lite
    assign = [0, 0, 0, 1, 1, 1, 2, 2, 2]
    gc = simgrid.GridConfig(plan=TIER_PLAN, tier_assignment=assign)
    res = simgrid.run_grid(init_fn, loss_fn, ds, RC, 4, grid=gc, seed=1)
    assert res.history[-1]["loss"] < res.history[0]["loss"]
    st = res.tier_stats
    assert [st[k]["clients"] for k in ("full", "mid", "lite")] == [3, 3, 3]
    assert sum(r["up_bytes"] for r in st.values()) \
        == res.comm.measured_up_bytes
    assert res.comm.measured_up_bytes > 0
    # lite uploads cost the bias bytes only
    if st["lite"]["uploads"]:
        y_lite, _ = res.plan.split(res.y, res.plan.tiers[2])
        assert st["lite"]["up_bytes"] == wire.uplink_bytes(y_lite) \
            * st["lite"]["uploads"]


def test_sync_grid_lite_only_cohort_freezes_masked_leaves():
    """A cohort made entirely of kernel-frozen clients must leave every
    kernel untouched — exact freezing, not just down-weighting."""
    ds = make_ds(n_clients=6)
    gc = simgrid.GridConfig(plan={"full": (), "lite": (r"/kernel$",)},
                            tier_assignment=[1] * 6)
    res = simgrid.run_grid(init_fn, loss_fn, ds, RC, 3, grid=gc, seed=0)
    y0, _ = part.partition(init_fn(0), ())
    assert bool(jnp.all(res.y["dense"]["kernel"] == y0["dense"]["kernel"]))
    assert not bool(jnp.all(res.y["dense"]["bias"] == y0["dense"]["bias"]))


def test_async_grid_mixed_tiers_dp():
    """Tiers compose with per-flush DP: the masked, clipped row keeps
    sensitivity clip/goal_count, so sigma and the accountant are
    tier-independent."""
    ds = make_ds(n_clients=10)
    rc = fedpt.RoundConfig(4, 2, 8, "sgd", 0.1, "sgd", 1.0,
                           dp_clip_norm=0.5, dp_noise_multiplier=0.4)
    gc = simgrid.GridConfig(mode="async", concurrency=5, goal_count=3,
                            plan=TIER_PLAN,
                            tier_assignment=[0, 0, 0, 1, 1, 1, 2, 2, 2, 2])
    a = simgrid.run_grid(init_fn, loss_fn, ds, rc, 6, grid=gc, seed=4)
    b = simgrid.run_grid(init_fn, loss_fn, ds, rc, 6, grid=gc, seed=4)
    assert [h["loss"] for h in a.history] == [h["loss"] for h in b.history]
    assert a.dp == b.dp
    assert a.dp["sigma"] == pytest.approx(0.4 * 0.5 / 3)
    assert a.dp["flushes"] == 6


# ---------------------------------------------------------------------------
# FlushAccountant satellites: repeated clients, multiplicity, and the
# staleness-weight rejection path, end to end through the grid


def test_async_grid_dp_repeated_clients_raise_multiplicity():
    """With-replacement dispatch over a 2-client dataset guarantees one
    client owns several rows of a 3-deep flush: the accountant must see
    multiplicity > 1 and charge more epsilon than a distinct-client
    composition of the same length."""
    ds = make_ds(n_clients=2)
    rc = fedpt.RoundConfig(2, 2, 8, "sgd", 0.1, "sgd", 1.0,
                           dp_clip_norm=0.5, dp_noise_multiplier=1.0)
    gc = simgrid.GridConfig(mode="async", concurrency=4, goal_count=3)
    res = simgrid.run_grid(init_fn, loss_fn, ds, rc, 5, grid=gc, seed=7)
    assert res.dp["max_multiplicity"] >= 2
    from repro.core import dp as dp_lib
    distinct = dp_lib.FlushAccountant(dp_lib.FlushDPConfig(
        clip_norm=0.5, noise_multiplier=1.0, goal_count=3))
    for _ in range(res.dp["flushes"]):
        distinct.record_flush(3, multiplicity=1)
    assert res.dp["epsilon"] > distinct.epsilon(res.dp["delta"])


def test_async_grid_dp_rejects_amplifying_staleness_weight():
    """Per-flush DP calibrates sigma for weights <= 1; a staleness fn
    that amplifies must be rejected, not silently under-noised."""
    ds = make_ds(n_clients=8)
    rc = fedpt.RoundConfig(4, 2, 8, "sgd", 0.1, "sgd", 1.0,
                           dp_clip_norm=0.5, dp_noise_multiplier=0.4)
    gc = simgrid.GridConfig(mode="async", concurrency=4, goal_count=3,
                            staleness=lambda s: 1.0 + s)
    with pytest.raises(ValueError, match=r"\[0, 1\]"):
        simgrid.run_grid(init_fn, loss_fn, ds, rc, 3, grid=gc, seed=1)
    # the same amplifying weighting is fine WITHOUT DP
    rc0 = fedpt.RoundConfig(4, 2, 8, "sgd", 0.1, "sgd", 1.0)
    res = simgrid.run_grid(init_fn, loss_fn, ds, rc0, 3, grid=gc, seed=1)
    assert len(res.history) == 3


def test_grid_rejects_oversized_cohort():
    ds = make_ds(n_clients=3)
    with pytest.raises(ValueError, match="clients_per_round"):
        simgrid.run_grid(init_fn, loss_fn, ds, RC, 1)


def test_fleet_presets():
    uni = dev_lib.make_fleet(8, "uniform")
    mb = 1024.0 * 1024.0
    for p in uni.profiles:
        assert p.downlink_bps == comm.DOWNLINK_MBPS * mb
        assert p.uplink_bps == comm.UPLINK_MBPS * mb
        assert p.availability == 1.0 and p.dropout == 0.0
    par = dev_lib.make_fleet(64, "pareto-mobile", seed=1)
    dls = {p.downlink_bps for p in par.profiles}
    assert len(dls) > 32                     # heterogeneous
    assert max(dls) <= comm.DOWNLINK_MBPS * mb
    silo = dev_lib.make_fleet(4, "cross-silo")
    assert all(p.availability == 1.0 for p in silo.profiles)
    assert silo.profiles[0].downlink_bps > 100 * mb
    with pytest.raises(ValueError):
        dev_lib.make_fleet(4, "galaxy-brain")
    # round-trip time composes download + compute + upload
    p = uni.profiles[0]
    t = p.round_trip_seconds(mb, mb, 2.0)
    assert t == pytest.approx(1 / comm.DOWNLINK_MBPS + 2.0
                              + 1 / comm.UPLINK_MBPS)


def test_summarize_delegates_to_comm_report():
    params = init_fn(0)
    spec = (r"bias",)
    s = part.summarize(params, spec)
    y, z = part.partition(params, spec)
    assert s["comm_reduction"] == comm.report_for(y, z).reduction
    assert s["trainable_bytes"] == basic.tree_bytes(y)
