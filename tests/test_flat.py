"""Flat-buffer aggregation subsystem: layout round-trips over every
freeze spec the core fixtures use, fused flat aggregation vs the old
tree-path reference, kernel-vs-ref parity (interpret mode), and async
client lanes reproducing the sequential scheduler's history.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.partition as part
from repro.core import compress, fedpt
from repro.core import flat as flat_lib
from repro.data import synthetic as syn
from repro.kernels import ref
from repro.kernels.dp_clip import clip_flat
from repro.kernels.quantize import fake_quantize_flat
from repro.models import paper_models as pm
from repro.nn import basic
from repro.optim import optimizers as opt_lib
from repro.sim import devices as dev_lib
from repro.sim import grid as simgrid
from repro.sim import scheduler as sched_lib


# ---------------------------------------------------------------------------
# FlatLayout round-trip, across the freeze specs used by the core tests


FREEZE_SPECS = {
    "none": (),
    "emnist_paper": pm.EMNIST_FREEZE,
    "conv1": (r"^conv1/",),
    "dense_gn": (r"^dense1/", r"^gn/"),
    "bias_only": (r"bias",),
}


@pytest.mark.parametrize("name,spec", sorted(FREEZE_SPECS.items()))
def test_flat_layout_roundtrip(name, spec):
    y, z = part.partition(pm.init_emnist_cnn(3), spec)
    layout = flat_lib.FlatLayout.of(y)
    assert layout.size % layout.align == 0
    assert layout.size >= sum(layout.sizes)
    vec = layout.flatten(y)
    assert vec.shape == (layout.size,) and vec.dtype == jnp.float32
    # tree -> vec -> tree is exact (dtype and bits)
    y2 = layout.unflatten(vec)
    for (ka, va), (kb, vb) in zip(basic.flatten_params(y),
                                  basic.flatten_params(y2)):
        assert ka == kb and va.dtype == vb.dtype
        assert bool((va == vb).all()), ka
    # vec -> tree -> vec is exact, including pad slots
    vec2 = layout.flatten(layout.unflatten(vec))
    assert bool((vec == vec2).all())


def test_flat_layout_blocks_partition_leaves():
    y, _ = part.partition(pm.init_emnist_cnn(0), pm.EMNIST_FREEZE)
    layout = flat_lib.FlatLayout.of(y)
    bl = layout.block_leaf()
    assert len(bl) == layout.num_blocks
    # each leaf owns a contiguous run of whole blocks covering its
    # padded span
    for lid, pad in enumerate(layout.padded):
        assert int(np.sum(bl == lid)) * layout.align == pad
    assert list(bl) == sorted(bl)


def test_flat_layout_empty_tree():
    layout = flat_lib.FlatLayout.of({})
    assert layout.size == 0
    assert layout.flatten({}).shape == (0,)
    assert layout.unflatten(jnp.zeros((0,))) == {}


# ---------------------------------------------------------------------------
# Fused flat aggregation tail vs the old per-leaf tree reference


def _client_deltas(seed, clients, spec=pm.EMNIST_FREEZE):
    y, _ = part.partition(pm.init_emnist_cnn(seed), spec)
    ks = jax.random.split(jax.random.key(seed), clients)
    deltas = [jax.tree_util.tree_map(
        lambda a, k=k: 0.1 * jax.random.normal(k, a.shape, jnp.float32),
        y) for k in ks]
    return y, deltas


def _tree_aggregate(deltas, w, clip_norm=0.0, bits=0, wsum=None):
    """The pre-flat aggregation tail, leaf by leaf (the old engine)."""
    if bits:
        deltas = [compress.fake_quantize_tree(d, bits) for d in deltas]
    if clip_norm > 0:
        clipped = []
        for d in deltas:
            nrm = opt_lib.tree_global_norm(d)
            s = jnp.minimum(1.0, clip_norm / jnp.maximum(nrm, 1e-12))
            clipped.append(jax.tree_util.tree_map(lambda x: x * s, d))
        deltas = clipped
    wsum = jnp.sum(w) if wsum is None else wsum
    return jax.tree_util.tree_map(
        lambda *ds: sum(wi * d for wi, d in zip(w, ds)) / wsum, *deltas)


@pytest.mark.parametrize("clip_norm,bits", [(0.0, 0), (0.5, 0), (0.0, 8),
                                            (0.5, 8)])
def test_flat_aggregation_matches_tree_reference(clip_norm, bits):
    C = 5
    y, deltas = _client_deltas(0, C)
    layout = flat_lib.FlatLayout.of(y)
    w = jnp.asarray([1.0, 2.0, 0.5, 1.5, 3.0])

    mat = jnp.stack([layout.flatten(d) for d in deltas])
    if bits:
        mat = flat_lib.fake_quantize(mat, layout, bits)
    weff = w
    if clip_norm > 0:
        norms = flat_lib.row_norms(mat, layout.align)
        weff = w * jnp.minimum(1.0, clip_norm / jnp.maximum(norms, 1e-12))
    flat_delta = flat_lib.weighted_mean(mat, weff, jnp.sum(w))
    got = layout.unflatten(flat_delta, dtype=jnp.float32)

    want = _tree_aggregate(deltas, w, clip_norm=clip_norm, bits=bits)
    for (ka, va), (kb, vb) in zip(basic.flatten_params(got),
                                  basic.flatten_params(want)):
        assert ka == kb
        np.testing.assert_allclose(np.asarray(va), np.asarray(vb),
                                   rtol=1e-5, atol=1e-7, err_msg=ka)


def test_flat_quantize_matches_tree_bitwise():
    y, deltas = _client_deltas(1, 1)
    layout = flat_lib.FlatLayout.of(y)
    got = flat_lib.fake_quantize(layout.flatten(deltas[0]), layout, 8)
    want = layout.flatten(compress.fake_quantize_tree(deltas[0], 8))
    assert bool((got == want).all())


def test_clip_delta_flat_path_matches_tree():
    y, deltas = _client_deltas(2, 1)
    d = deltas[0]
    clipped, nrm = fedpt.clip_delta(d, 0.25)
    ref_norm = opt_lib.tree_global_norm(d)
    np.testing.assert_allclose(float(nrm), float(ref_norm), rtol=1e-6)
    s = min(1.0, 0.25 / max(float(ref_norm), 1e-12))
    for (ka, va), (kb, vb) in zip(basic.flatten_params(clipped),
                                  basic.flatten_params(d)):
        np.testing.assert_allclose(np.asarray(va), np.asarray(vb) * s,
                                   rtol=1e-5, atol=1e-8)
    n2 = opt_lib.tree_global_norm(clipped)
    assert float(n2) <= 0.25 * (1 + 1e-5)


# ---------------------------------------------------------------------------
# Pallas kernels (interpret mode) vs the pure-JAX fallbacks


@pytest.mark.interpret
def test_quantize_kernel_matches_ref():
    y, deltas = _client_deltas(3, 1)
    layout = flat_lib.FlatLayout.of(y)
    x = layout.flatten(deltas[0])
    bl = layout.block_leaf()
    got = fake_quantize_flat(x, bl, len(layout.sizes), block=layout.align,
                             interpret=True)
    want = ref.fake_quantize_flat_ref(x, bl, bits=8, block=layout.align)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6,
                               atol=1e-8)


@pytest.mark.interpret
def test_clip_flat_kernel_matches_ref():
    x = jax.random.normal(jax.random.key(0), (5000,), jnp.float32)
    got, gn = clip_flat(x, 1.5, block=1024, interpret=True)
    want, wn = ref.flat_clip_ref(x, 1.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-6,
                               atol=2e-7)
    np.testing.assert_allclose(float(gn), float(wn), rtol=1e-6)


def test_row_sumsq_ref_matches_dense():
    x = jax.random.normal(jax.random.key(1), (3, 4096), jnp.float32)
    got = ref.row_sumsq_ref(x, chunk=1024)
    want = jnp.sum(x * x, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)
    # non-multiple falls back to a single chunk
    got2 = ref.flat_sumsq_ref(x[0, :4097 - 1024], chunk=1024)
    np.testing.assert_allclose(
        float(got2), float(jnp.sum(x[0, :4097 - 1024] ** 2)), rtol=1e-5)


# ---------------------------------------------------------------------------
# Async client lanes == the sequential scheduler, event for event


def _tiny_ds(n_clients=10):
    return syn.make_federated_images(n_clients, 24, (8, 8, 1), 4, seed=0,
                                     test_examples=16)


def _tiny_init(seed):
    return {"dense": basic.init_dense(seed, "dense", 64, 4, jnp.float32,
                                      bias=True)}


def _tiny_loss(params, b):
    x = b["images"].reshape(b["images"].shape[0], -1)
    logits = basic.dense(x, params["dense"])
    lp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(lp, b["labels"][:, None], 1)), {}


RC = fedpt.RoundConfig(4, 2, 8, "sgd", 0.1, "sgd", 1.0)


@pytest.mark.parametrize("fleet", ["uniform", "pareto-mobile"])
def test_async_lanes_match_sequential_scheduler(fleet):
    ds = _tiny_ds()
    runs = {}
    for lanes in (0, None, 2):
        gc = simgrid.GridConfig(mode="async", fleet=fleet, concurrency=5,
                                goal_count=3, lanes=lanes)
        runs[lanes] = simgrid.run_grid(_tiny_init, _tiny_loss, ds, RC, 6,
                                       grid=gc, seed=2)
    seq = runs[0]
    for lanes in (None, 2):
        lane = runs[lanes]
        # the virtual clock and staleness bookkeeping are EXACTLY the
        # sequential scheduler's — lanes only change device dispatch
        for hs, hl in zip(seq.history, lane.history):
            assert hs["virtual_seconds"] == hl["virtual_seconds"]
            assert hs["staleness_mean"] == hl["staleness_mean"]
            assert hs["staleness_max"] == hl["staleness_max"]
            assert hs["loss"] == pytest.approx(hl["loss"], rel=1e-5)
        assert seq.scheduler_stats == lane.scheduler_stats
        assert seq.comm.measured_up_bytes == lane.comm.measured_up_bytes
        for (ka, va), (kb, vb) in zip(basic.flatten_params(seq.y),
                                      basic.flatten_params(lane.y)):
            np.testing.assert_allclose(np.asarray(va), np.asarray(vb),
                                       rtol=1e-5, atol=1e-7, err_msg=ka)


def test_async_deadline_drains_partial_buffer():
    """A virtual-time budget ends the run with one short final flush,
    which the grid pads to goal_count (zero weights) — exercising the
    fixed-shape apply on a genuinely partial buffer."""
    ds = _tiny_ds()
    full = simgrid.run_grid(_tiny_init, _tiny_loss, ds, RC, 6,
                            grid=simgrid.GridConfig(
                                mode="async", concurrency=4, goal_count=3),
                            seed=2)
    # cut the budget between the 2nd and 3rd updates of the full run
    cut = (full.history[1]["virtual_seconds"]
           + full.history[2]["virtual_seconds"]) / 2.0
    gc = simgrid.GridConfig(mode="async", concurrency=4, goal_count=3,
                            async_deadline=cut)
    res = simgrid.run_grid(_tiny_init, _tiny_loss, ds, RC, 6, grid=gc,
                           seed=2)
    assert len(res.history) == 3            # 2 full flushes + the drain
    assert res.history[-1]["virtual_seconds"] == cut
    assert np.isfinite(res.history[-1]["loss"])
    # the un-cut prefix is identical to the unconstrained run
    for a, b in zip(full.history[:2], res.history[:2]):
        assert a["virtual_seconds"] == b["virtual_seconds"]
        assert a["loss"] == b["loss"]


def test_scheduler_deadline_partial_flush_unit():
    """Scheduler-level: the drain flush hands apply_update FEWER than
    goal_count entries, at exactly the deadline time."""
    fleet = dev_lib.Fleet(name="t", profiles=[dev_lib.DeviceProfile(
        downlink_bps=1e6, uplink_bps=1e6, compute_multiplier=1.0)] * 2)
    applied = []

    def run_client(cid, version):
        return {"weight": 1.0, "up_bytes": 0, "loss": 0.0}

    def apply_update(entries, now, version):
        applied.append((len(entries), now))
        return {}

    sched = sched_lib.BufferedAsyncScheduler(
        fleet=fleet, concurrency=2, goal_count=4,
        staleness_fn=lambda s: 1.0, sample_cid=lambda rng: 0,
        run_client=run_client, apply_update=apply_update, down_bytes=0,
        compute_seconds=1.0, rng=np.random.default_rng(0))
    # completions land pairwise at t=1, 2, 3...; goal_count 4 would
    # first fill at t=2, so a 1.5s budget forces a 2-entry drain
    records = sched.run(10, deadline=1.5)
    assert applied == [(2, 1.5)]            # partial final flush only
    assert records[-1]["virtual_seconds"] == 1.5
    assert len(records) == 1


def test_buffered_apply_padded_flush_does_not_retrace():
    """A short (drained) final buffer is padded to goal_count with zero
    weights: same trace, same result as an explicit short-shape apply."""
    y, _ = part.partition(_tiny_init(0), ())
    layout = flat_lib.FlatLayout.of(y)
    sopt = opt_lib.sgd(1.0)
    traces = {"n": 0}

    def counting_apply(y, ss, deltas, weights):
        traces["n"] += 1
        return fedpt.make_buffered_apply(sopt)(y, ss, deltas, weights)

    apply_fn = jax.jit(counting_apply)
    K = 4
    ks = jax.random.split(jax.random.key(0), K)
    rows = jnp.stack([0.01 * jax.random.normal(k, (layout.size,)) for k in ks])
    w = jnp.asarray([1.0, 2.0, 1.0, 0.5])

    y1, ss1, _ = apply_fn(y, sopt.init(y), rows, w)
    # "partial" flush of 2 entries padded to K with zero weight
    rows_pad = rows.at[2:].set(0.0)
    w_pad = jnp.asarray([1.0, 2.0, 0.0, 0.0])
    y2, ss2, _ = apply_fn(y, sopt.init(y), rows_pad, w_pad)
    assert traces["n"] == 1, "fixed goal_count shape must not re-trace"

    # zero-weight padding is inert: equals the true 2-entry mean
    flat_ref = flat_lib.weighted_mean(rows[:2], w_pad[:2], jnp.sum(w_pad[:2]))
    want = jax.tree_util.tree_map(
        lambda a, d: a + d, y, layout.unflatten(flat_ref, jnp.float32))
    for (ka, va), (kb, vb) in zip(basic.flatten_params(y2),
                                  basic.flatten_params(want)):
        np.testing.assert_allclose(np.asarray(va), np.asarray(vb),
                                   rtol=1e-6, atol=1e-8, err_msg=ka)


def test_sync_round_engine_unchanged_with_flat_tail():
    """Flat tail == old tree tail on the jitted round engine (weighted
    mean bit-for-bit; clip/quant within fp tolerance)."""
    ds = _tiny_ds()
    rc = fedpt.RoundConfig(4, 2, 8, "sgd", 0.1, "sgd", 1.0)
    res = simgrid.run_grid(_tiny_init, _tiny_loss, ds, rc, 3, seed=5)
    # reference: explicit per-leaf sequential aggregation of round 0
    y, frozen = part.partition(_tiny_init(5), ())
    rng = np.random.default_rng(5 + 77)
    cids = syn.sample_cohort(rng, ds.num_clients, 4)
    batch, w = syn.cohort_batch(ds, cids, 2, 8, rng)
    cu = fedpt.make_client_update(_tiny_loss, opt_lib.sgd(0.1), 2)
    deltas = [cu(y, frozen, {k: v[i] for k, v in batch.items()})[0]
              for i in range(4)]
    agg = _tree_aggregate(deltas, jnp.asarray(w))
    y1 = jax.tree_util.tree_map(lambda a, d: a + d, y, agg)
    round_fn, sopt = fedpt.make_round_fn(_tiny_loss, rc)
    y1_grid, _, _ = jax.jit(round_fn)(y, sopt.init(y), frozen, batch,
                                      jnp.asarray(w), jax.random.key(0))
    for (ka, va), (kb, vb) in zip(basic.flatten_params(y1_grid),
                                  basic.flatten_params(y1)):
        np.testing.assert_allclose(np.asarray(va), np.asarray(vb),
                                   rtol=2e-5, atol=2e-6, err_msg=ka)
