"""Mesh-sharded grid execution (CI `multidevice` job).

These tests need >= 8 visible host devices; the CI job provides them via
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (see
.github/workflows/ci.yml). They assert the tentpole contract: an async
grid run sharded over a ``launch/mesh.py`` debug mesh reproduces the
single-device lane run — the virtual clock and staleness bookkeeping
exactly, losses/params to fp32 round-off — and the per-flush DP path
keeps its fixed ``goal_count`` denominator and noise scale under
sharding, zero-weight padding rows included.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.partition as part
from repro.core import dp as dp_lib
from repro.core import fedpt
from repro.core import flat as flat_lib
from repro.data import synthetic as syn
from repro.launch import mesh as mesh_lib
from repro.launch import sharding as shard_lib
from repro.nn import basic
from repro.optim import optimizers as opt_lib
from repro.sim import grid as simgrid

pytestmark = [
    pytest.mark.multidevice,
    pytest.mark.skipif(
        jax.device_count() < 8,
        reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8"),
]


def init_fn(seed):
    return {"dense": basic.init_dense(seed, "dense", 64, 4, jnp.float32,
                                      bias=True)}


def loss_fn(params, b):
    x = b["images"].reshape(b["images"].shape[0], -1)
    logits = basic.dense(x, params["dense"])
    lp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(lp, b["labels"][:, None], 1)), {}


def make_ds(n_clients=12):
    return syn.make_federated_images(n_clients, 30, (8, 8, 1), 4, seed=0,
                                     test_examples=32)


RC = fedpt.RoundConfig(4, 2, 8, "sgd", 0.1, "sgd", 1.0)
RC_DP = fedpt.RoundConfig(4, 2, 8, "sgd", 0.1, "sgd", 1.0,
                          dp_clip_norm=0.5, dp_noise_multiplier=0.4)


def assert_histories_match(ref, got, keys_exact=("virtual_seconds",
                                                "buffer_fill",
                                                "staleness_mean",
                                                "staleness_max")):
    assert len(ref.history) == len(got.history)
    for ha, hb in zip(ref.history, got.history):
        for k in keys_exact:
            assert ha[k] == hb[k], k          # clock/bookkeeping: exact
        assert ha["loss"] == pytest.approx(hb["loss"], rel=1e-5, abs=1e-6)
    assert ref.scheduler_stats == got.scheduler_stats
    assert ref.comm.measured_up_bytes == got.comm.measured_up_bytes
    for (ka, va), (kb, vb) in zip(basic.flatten_params(ref.y),
                                  basic.flatten_params(got.y)):
        np.testing.assert_allclose(np.asarray(va), np.asarray(vb),
                                   rtol=1e-5, atol=1e-6, err_msg=ka)


# ---------------------------------------------------------------------------
# Tentpole acceptance: mesh run == single-device lane run, fp32 round-off


@pytest.mark.parametrize("mesh_name", ["debug", "debug-pod"])
def test_async_grid_mesh_matches_single_device(mesh_name):
    ds = make_ds()
    runs = {}
    for mesh in (None, mesh_name):
        gc = simgrid.GridConfig(mode="async", fleet="pareto-mobile",
                                concurrency=6, goal_count=3, mesh=mesh)
        runs[mesh] = simgrid.run_grid(init_fn, loss_fn, ds, RC, 8,
                                      grid=gc, seed=2)
    assert_histories_match(runs[None], runs[mesh_name])


def test_sync_grid_mesh_matches_single_device():
    ds = make_ds()
    runs = {}
    for mesh in (None, "debug"):
        gc = simgrid.GridConfig(mode="sync", mesh=mesh)
        runs[mesh] = simgrid.run_grid(init_fn, loss_fn, ds, RC, 4,
                                      grid=gc, seed=1)
    for ha, hb in zip(runs[None].history, runs["debug"].history):
        assert ha["virtual_seconds"] == hb["virtual_seconds"]
        assert ha["loss"] == pytest.approx(hb["loss"], rel=1e-5)


def test_async_grid_mesh_dp_matches_single_device():
    """Per-flush DP under sharding: sharding-invariant noise (the repo
    forces partitionable threefry) + fixed-denominator mean => histories
    agree to fp32 round-off, and the accountants agree exactly."""
    ds = make_ds()
    runs = {}
    for mesh in (None, "debug"):
        gc = simgrid.GridConfig(mode="async", concurrency=5, goal_count=3,
                                mesh=mesh)
        runs[mesh] = simgrid.run_grid(init_fn, loss_fn, ds, RC_DP, 6,
                                      grid=gc, seed=3)
    assert_histories_match(runs[None], runs["debug"])
    assert runs[None].dp == runs["debug"].dp
    assert runs["debug"].dp["flushes"] == 6
    assert runs["debug"].dp["sigma"] == pytest.approx(0.4 * 0.5 / 3)


def test_mesh_resolution_and_flat_shardings():
    mesh = mesh_lib.resolve_mesh("debug")
    assert mesh is mesh_lib.resolve_mesh(mesh)      # objects pass through
    with pytest.raises(ValueError, match="mesh preset"):
        mesh_lib.resolve_mesh("galaxy-brain")
    constrain = shard_lib.flat_constrainer(mesh)
    mat = jnp.zeros((4, 4096), jnp.float32)
    out = jax.jit(lambda m: constrain(m, clients=True))(mat)
    assert out.sharding.spec == jax.sharding.PartitionSpec("data", "model")
    vec = jax.jit(lambda v: constrain(v, clients=False))(mat[0])
    assert vec.sharding.spec == jax.sharding.PartitionSpec("model")
    pod = mesh_lib.resolve_mesh("debug-pod")
    out3 = jax.jit(
        lambda m: shard_lib.flat_constrainer(pod)(m, clients=True))(mat)
    assert out3.sharding.spec == jax.sharding.PartitionSpec(
        ("pod", "data"), "model")


# ---------------------------------------------------------------------------
# Padded partial flush on a (2,2) debug mesh: zero-weight padding rows
# must perturb neither the sharded weighted mean nor the per-flush sigma


def _apply_pair(flush_dp=None):
    """(sharded apply on the debug mesh, unsharded reference apply)."""
    mesh = mesh_lib.resolve_mesh("debug")
    sopt = opt_lib.sgd(1.0)
    sharded = jax.jit(fedpt.make_buffered_apply(
        sopt, flush_dp=flush_dp,
        constrain_flat_fn=shard_lib.flat_constrainer(mesh)))
    plain = jax.jit(fedpt.make_buffered_apply(sopt, flush_dp=flush_dp))
    return sharded, plain


def test_padded_flush_mean_unperturbed_on_mesh():
    y, _ = part.partition(init_fn(0), ())
    layout = flat_lib.FlatLayout.of(y)
    sopt = opt_lib.sgd(1.0)
    sharded, plain = _apply_pair()
    K = 4
    ks = jax.random.split(jax.random.key(0), K)
    rows = jnp.stack([0.01 * jax.random.normal(k, (layout.size,))
                      for k in ks])
    w = jnp.asarray([1.0, 0.5, 0.0, 0.0])
    # padding rows are inert even when they hold garbage: zero weight
    rows_garbage = rows.at[2:].set(7.7)
    for padded in (flat_lib.pad_rows(rows[:2], K), rows_garbage):
        ym, _, mm = sharded(y, sopt.init(y), padded, w)
        yr, _, mr = plain(y, sopt.init(y), rows.at[2:].set(0.0), w)
        assert mm["delta_norm"] == pytest.approx(float(mr["delta_norm"]),
                                                 rel=1e-5)
        for (ka, va), (kb, vb) in zip(basic.flatten_params(ym),
                                      basic.flatten_params(yr)):
            np.testing.assert_allclose(np.asarray(va), np.asarray(vb),
                                       rtol=1e-5, atol=1e-7, err_msg=ka)


def test_padded_flush_dp_fixed_denominator_on_mesh():
    """With per-flush DP the mean divides by goal_count regardless of
    fill, sigma never changes, and the sharded apply reproduces the
    manually-composed single-device mechanism."""
    y, _ = part.partition(init_fn(0), ())
    layout = flat_lib.FlatLayout.of(y)
    sopt = opt_lib.sgd(1.0)
    K = 4
    flush_dp = dp_lib.FlushDPConfig(clip_norm=1.0, noise_multiplier=0.5,
                                    goal_count=K)
    sharded, _ = _apply_pair(flush_dp)
    ks = jax.random.split(jax.random.key(1), K)
    rows = jnp.stack([0.01 * jax.random.normal(k, (layout.size,))
                      for k in ks])
    w_full = jnp.asarray([1.0, 0.8, 0.6, 0.4])
    w_pad = jnp.asarray([1.0, 0.8, 0.0, 0.0])
    rng = jax.random.key(9)

    def manual(mat, w):
        flat = flat_lib.weighted_mean(mat, w, jnp.asarray(float(K)))
        flat = flat_lib.add_noise(flat, flush_dp.sigma, rng)
        return jax.tree_util.tree_map(
            lambda a, d: a + d, y, layout.unflatten(flat, jnp.float32))

    for mat, w in ((rows, w_full), (flat_lib.pad_rows(rows[:2], K), w_pad)):
        ym, _, _ = sharded(y, sopt.init(y), mat, w, rng)
        want = manual(mat, w)
        for (ka, va), (kb, vb) in zip(basic.flatten_params(ym),
                                      basic.flatten_params(want)):
            np.testing.assert_allclose(np.asarray(va), np.asarray(vb),
                                       rtol=1e-5, atol=1e-6, err_msg=ka)
    # same rng, different data: outputs differ by exactly the mean gap —
    # i.e. the noise term is identical for a full and a padded flush
    yf, _, _ = sharded(y, sopt.init(y), rows, w_full, rng)
    yp, _, _ = sharded(y, sopt.init(y), flat_lib.pad_rows(rows[:2], K),
                       w_pad, rng)
    gap = flat_lib.weighted_mean(rows, w_full, jnp.asarray(float(K))) \
        - flat_lib.weighted_mean(flat_lib.pad_rows(rows[:2], K), w_pad,
                                 jnp.asarray(float(K)))
    gap_tree = flat_lib.FlatLayout.of(y).unflatten(gap, jnp.float32)
    for (ka, vf), (_, vp), (_, vg) in zip(basic.flatten_params(yf),
                                          basic.flatten_params(yp),
                                          basic.flatten_params(gap_tree)):
        np.testing.assert_allclose(np.asarray(vf - vp), np.asarray(vg),
                                   rtol=1e-4, atol=1e-6, err_msg=ka)


def test_async_grid_mixed_tier_mesh_matches_single_device():
    """Trainability tiers under mesh sharding: tier-grouped lanes run at
    tier width and scatter into the sharded (K, size) buffer; the mixed
    fleet's history matches single-device to fp32 round-off, and the
    per-tier wire ledger is mesh-independent (exact)."""
    ds = make_ds()
    plan = {"full": (), "mid": (r"/bias$",), "lite": (r"/kernel$",)}
    assign = [0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2]
    runs = {}
    for mesh in (None, "debug"):
        gc = simgrid.GridConfig(mode="async", fleet="pareto-mobile",
                                concurrency=6, goal_count=3, mesh=mesh,
                                plan=plan, tier_assignment=assign)
        runs[mesh] = simgrid.run_grid(init_fn, loss_fn, ds, RC, 8,
                                      grid=gc, seed=2)
    assert_histories_match(runs[None], runs["debug"])
    assert runs[None].comm.tier_traffic == runs["debug"].comm.tier_traffic
    st = runs["debug"].tier_stats
    assert set(st) == {"full", "mid", "lite"}
    assert sum(r["up_bytes"] for r in st.values()) \
        == runs["debug"].comm.measured_up_bytes


def test_sync_grid_mixed_tier_mesh_matches_single_device():
    """Mixed-tier SYNC cohorts on the debug mesh (per-row tier masks in
    the round engine + the cohort-input batch constrainer) reproduce the
    single-device run to fp32 round-off."""
    ds = make_ds()
    plan = {"full": (), "lite": (r"/bias$",)}
    assign = [0, 1] * 6
    runs = {}
    for mesh in (None, "debug"):
        gc = simgrid.GridConfig(mode="sync", mesh=mesh, plan=plan,
                                tier_assignment=assign)
        runs[mesh] = simgrid.run_grid(init_fn, loss_fn, ds, RC, 4,
                                      grid=gc, seed=1)
    for ha, hb in zip(runs[None].history, runs["debug"].history):
        assert ha["virtual_seconds"] == hb["virtual_seconds"]
        assert ha["loss"] == pytest.approx(hb["loss"], rel=1e-5)
    assert runs[None].comm.tier_traffic == runs["debug"].comm.tier_traffic
    for (ka, va), (kb, vb) in zip(basic.flatten_params(runs[None].y),
                                  basic.flatten_params(runs["debug"].y)):
        np.testing.assert_allclose(np.asarray(va), np.asarray(vb),
                                   rtol=1e-5, atol=1e-6, err_msg=ka)


def test_async_grid_mesh_dp_deadline_drain():
    """End-to-end: a deadline-drained DP run on the (2,2) debug mesh
    matches the single-device drain, padded flush and all."""
    ds = make_ds()
    base = simgrid.GridConfig(mode="async", concurrency=4, goal_count=3)
    full = simgrid.run_grid(init_fn, loss_fn, ds, RC_DP, 6,
                            grid=base, seed=2)
    cut = (full.history[1]["virtual_seconds"]
           + full.history[2]["virtual_seconds"]) / 2.0
    runs = {}
    for mesh in (None, "debug"):
        gc = dataclasses.replace(base, async_deadline=cut, mesh=mesh)
        runs[mesh] = simgrid.run_grid(init_fn, loss_fn, ds, RC_DP, 6,
                                      grid=gc, seed=2)
    assert runs["debug"].history[-1]["buffer_fill"] < base.goal_count
    assert runs["debug"].dp["padded_flushes"] == 1
    assert runs[None].dp == runs["debug"].dp
    assert_histories_match(runs[None], runs["debug"])
