"""Adaptive quantile clipping + tuning grid."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import adaptive_clip as ac
from repro.fl import tuning


def test_clip_converges_to_target_quantile():
    cfg = ac.AdaptiveClipConfig(initial_clip=10.0, target_quantile=0.5,
                                lr=0.3)
    state = ac.init_state(cfg)
    rng = np.random.default_rng(0)
    for t in range(300):
        norms = jnp.asarray(rng.lognormal(0.0, 0.5, 32), jnp.float32)
        state, clip = ac.update_state(cfg, state, norms)
    # median of lognormal(0, .5) is 1.0
    assert 0.7 < float(state["clip"]) < 1.4, float(state["clip"])


def test_clipped_mean_bounds_contributions():
    deltas = {"w": jnp.stack([jnp.full((4,), 10.0), jnp.full((4,), 0.1)])}
    norms = jnp.asarray([20.0, 0.2])
    avg = ac.clipped_mean(deltas, norms, clip=1.0)
    # client 0 scaled by 1/20 -> contributes 0.5 per coord; client 1 intact
    np.testing.assert_allclose(np.asarray(avg["w"]), (0.5 + 0.1) / 2,
                               rtol=1e-5)


def test_paper_grid_matches_appendix():
    assert len(tuning.PAPER_DP_GRID) == 15  # 3 client x 5 server LRs
    best, score, hist = tuning.search(
        lambda p: -abs(p["client_lr"] - 0.1) - abs(p["server_lr"] - 1.0),
        tuning.PAPER_DP_GRID)
    assert abs(best["client_lr"] - 0.1) < 1e-9
    assert abs(best["server_lr"] - 1.0) < 1e-9
    assert len(hist) == 15
