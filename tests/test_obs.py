"""Grid telemetry (src/repro/obs/): the metrics registry, the structured
event tracer and its exporters, and the acceptance guarantees — telemetry
off is bit-identical (sync) / lane-exact (async), and telemetry on emits
a schema-valid stream whose virtual timestamps cross-check against
GridResult's own totals."""
import dataclasses
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.partition as part
from repro.core import comm, fedpt
from repro.data import synthetic as syn
from repro.nn import basic
from repro.obs import export as export_lib
from repro.obs import metrics as metrics_lib
from repro.obs import profiling as prof_lib
from repro.obs import schema as schema_lib
from repro.obs import trace as trace_lib
from repro.sim import devices as dev_lib
from repro.sim import dynamics as dyn_lib
from repro.sim import grid as simgrid


def init_fn(seed):
    return {"dense": basic.init_dense(seed, "dense", 64, 4, jnp.float32,
                                      bias=True)}


def loss_fn(params, b):
    x = b["images"].reshape(b["images"].shape[0], -1)
    logits = basic.dense(x, params["dense"])
    lp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(lp, b["labels"][:, None], 1)), {}


def make_ds(n_clients=10, seed=0):
    return syn.make_federated_images(n_clients, 30, (8, 8, 1), 4, seed=seed,
                                     test_examples=64)


RC = fedpt.RoundConfig(4, 2, 8, "sgd", 0.1, "sgd", 1.0)

TIER_PLAN = {"full": (), "mid": (r"/bias$",), "lite": (r"/kernel$",)}


def _fleet(mults, **kw):
    mb = 1024.0 * 1024.0
    return dev_lib.Fleet(name="test", profiles=[
        dev_lib.DeviceProfile(downlink_bps=mb, uplink_bps=mb,
                              compute_multiplier=m, **kw) for m in mults])


def _assert_same_run(a, b):
    assert [h["loss"] for h in a.history] == [h["loss"] for h in b.history]
    for ha, hb in zip(a.history, b.history):
        assert ha["virtual_seconds"] == hb["virtual_seconds"]
    for (pa, la), (pb, lb) in zip(basic.flatten_params(a.y),
                                  basic.flatten_params(b.y)):
        assert pa == pb and bool(jnp.all(la == lb)), pa
    assert a.scheduler_stats == b.scheduler_stats
    assert a.comm.measured_down_bytes == b.comm.measured_down_bytes
    assert a.comm.measured_up_bytes == b.comm.measured_up_bytes


# ---------------------------------------------------------------------------
# Metrics registry


def test_metrics_counter_gauge_histogram():
    reg = metrics_lib.MetricsRegistry()
    c = reg.counter("uploads")
    c.inc()
    c.inc(3, label=0)
    c.inc(2, label=1)
    assert c.value == 6
    assert c.get(0) == 3 and c.get(1) == 2 and c.get(9, -1) == -1
    assert reg.counter("uploads") is c       # create-on-demand, cached
    g = reg.gauge("compute")
    assert g.value is None
    g.set(2.5)
    g.set(0.5, label=1)
    assert g.value == 0.5 and g.get(1) == 0.5 and g.get(0) is None
    h = reg.histogram("rtt")
    assert h.summary() == {"count": 0, "sum": 0.0, "mean": 0.0,
                           "min": 0.0, "max": 0.0}
    for v in (1.0, 3.0, 2.0):
        h.observe(v)
    assert h.summary() == {"count": 3, "sum": 6.0, "mean": 2.0,
                           "min": 1.0, "max": 3.0}


def test_metrics_snapshot_json_roundtrip():
    reg = metrics_lib.MetricsRegistry()
    reg.counter("tier_up_bytes").inc(100, label=2)
    reg.gauge("sigma").set(0.4)
    reg.histogram("round_seconds").observe(1.5)
    snap = json.loads(json.dumps(reg.snapshot()))
    assert snap["v"] == metrics_lib.SNAPSHOT_VERSION
    # labels stringify so the snapshot survives json round-trips
    assert snap["counters"]["tier_up_bytes"]["labels"] == {"2": 100}
    assert snap["gauges"]["sigma"]["value"] == 0.4
    assert snap["histograms"]["round_seconds"]["count"] == 1


# ---------------------------------------------------------------------------
# Schema validation


def test_schema_accepts_valid_records():
    good = [
        {"v": 1, "kind": "dispatch", "t": 0.0, "dur": 2.5, "cid": 3,
         "tier": None, "down_bytes": 100, "up_bytes": 50, "outcome": "ok"},
        {"v": 1, "kind": "upload", "t": 2.5, "cid": 3, "up_bytes": 50,
         "rtt": 2.5, "staleness": 0},
        {"v": 1, "kind": "retry", "t": 1.0, "backoff": 30.0},
        {"v": 1, "kind": "flush", "t": 9.0, "version": 2,
         "buffer_fill": 3.0, "staleness_mean": 0.5, "staleness_max": 2.0},
        {"v": 1, "kind": "round", "t": 0.0, "dur": 4.0, "round": 0,
         "participants": 4.0, "cohort": 5, "loss": 1.38},
        {"v": 1, "kind": "dp_flush", "t": 9.0, "flush": 0, "n_real": 3,
         "multiplicity": 1, "sigma": 0.066, "epsilon": 1.2,
         "delta": 1e-5, "padded": False},
        {"v": 1, "kind": "tier_upload", "t": 30.0, "tier_name": "lite",
         "down_bytes": 1000, "up_bytes": 400, "transfers": 5, "uploads": 4},
    ]
    assert schema_lib.validate_records(good) == []


def test_schema_rejects_malformed_records():
    assert schema_lib.validate_record([1, 2]) != []          # not an object
    assert any("unknown kind" in e for e in schema_lib.validate_record(
        {"v": 1, "kind": "teleport", "t": 0.0}))
    assert any("missing required" in e for e in schema_lib.validate_record(
        {"v": 1, "kind": "upload", "t": 0.0, "cid": 1}))     # no up_bytes
    assert any("wrong type" in e for e in schema_lib.validate_record(
        {"v": 1, "kind": "dispatch", "t": 0.0, "cid": True}))  # bool != int
    assert any("unexpected field" in e for e in schema_lib.validate_record(
        {"v": 1, "kind": "retry", "t": 0.0, "speed": 9}))
    assert any("v=" in e for e in schema_lib.validate_record(
        {"v": 99, "kind": "retry", "t": 0.0}))
    assert any("t=" in e for e in schema_lib.validate_record(
        {"v": 1, "kind": "retry", "t": -1.0}))
    assert any("dur=" in e for e in schema_lib.validate_record(
        {"v": 1, "kind": "round", "t": 0.0, "dur": math.inf, "round": 0}))


def test_resolve_telemetry_variants():
    assert trace_lib.resolve_telemetry(None) is None
    cfg = trace_lib.TelemetryConfig(jsonl_path="x.jsonl")
    assert trace_lib.resolve_telemetry(cfg) is cfg
    for spec in (True, "on", "memory"):
        got = trace_lib.resolve_telemetry(spec)
        assert isinstance(got, trace_lib.TelemetryConfig)
        assert got.jsonl_path is None and not got.profile
    got = trace_lib.resolve_telemetry({"perfetto_path": "t.json"})
    assert got.perfetto_path == "t.json"
    with pytest.raises(ValueError, match="telemetry"):
        trace_lib.resolve_telemetry(42)


def test_null_tracer_is_noop():
    nt = trace_lib.NULL_TRACER
    assert nt.enabled is False and nt.events == ()
    assert nt.span("dispatch", 0.0, 1.0, cid=1) is None
    assert nt.instant("flush", 0.0) is None
    assert nt.events == ()


# ---------------------------------------------------------------------------
# Exporters


def test_perfetto_track_layout():
    recs = [
        trace_lib.TraceRecord("dispatch", 1.0, 2.0, {"cid": 7,
                                                     "tier": None}),
        trace_lib.TraceRecord("upload", 3.0, None, {"cid": 7,
                                                    "up_bytes": 10}),
        trace_lib.TraceRecord("flush", 3.0, None, {"version": 0,
                                                   "buffer_fill": 1.0}),
        trace_lib.TraceRecord("dp_flush", 3.0, None,
                              {"flush": 0, "n_real": 1, "multiplicity": 1}),
    ]
    doc = export_lib.perfetto_trace(recs)
    ev = {e["name"]: e for e in doc["traceEvents"] if e.get("ph") != "M"}
    # client events on the clients process, one thread per cid
    assert ev["dispatch"]["pid"] == 1 and ev["dispatch"]["tid"] == 7
    assert ev["dispatch"]["ph"] == "X"
    assert ev["dispatch"]["ts"] == 1.0e6 and ev["dispatch"]["dur"] == 2.0e6
    # None payload values are dropped from args, never serialized
    assert "tier" not in ev["dispatch"]["args"]
    assert ev["upload"]["ph"] == "i" and ev["upload"]["s"] == "t"
    # server events on pid 0: flushes with the rounds, dp on "privacy"
    assert ev["flush"]["pid"] == 0 and ev["flush"]["tid"] == 0
    assert ev["dp_flush"]["tid"] == 1
    meta = [e for e in doc["traceEvents"] if e.get("ph") == "M"]
    names = {(m["name"], m.get("pid"), m.get("tid")): m["args"]["name"]
             for m in meta}
    assert names[("process_name", 0, None)] == "server"
    assert names[("process_name", 1, None)] == "clients"
    assert names[("thread_name", 1, 7)] == "client 7"


def test_profiling_annotation_wrappers():
    calls = []

    def fn(x):
        calls.append(x)
        return x + 1

    assert prof_lib.annotate(fn, "test", enabled=False) is fn
    wrapped = prof_lib.annotate(fn, "test", enabled=True)
    assert wrapped(2) == 3 and calls == [2]
    m = prof_lib.annotate_map({"a": fn}, "test", enabled=False)
    assert m["a"] is fn
    m = prof_lib.annotate_map({"a": fn}, "test", enabled=True)
    assert m["a"](5) == 6


# ---------------------------------------------------------------------------
# Acceptance: telemetry off is exactly free


def test_sync_telemetry_off_bit_identical():
    """GridConfig.telemetry=None and telemetry='memory' must produce
    bit-for-bit the same sync run — tracing consumes no PRNG draws."""
    ds = make_ds()
    gc = simgrid.GridConfig(fleet="pareto-mobile", over_selection=1.3,
                            straggler_deadline=120.0)
    off = simgrid.run_grid(init_fn, loss_fn, ds, RC, 4, grid=gc, seed=3)
    on = simgrid.run_grid(
        init_fn, loss_fn, ds, RC, 4, seed=3,
        grid=dataclasses.replace(gc, telemetry="memory"))
    _assert_same_run(off, on)
    assert off.telemetry is None and on.telemetry is not None


def test_async_telemetry_off_lane_exact():
    ds = make_ds(n_clients=16)
    gc = simgrid.GridConfig(mode="async", fleet="pareto-mobile",
                            concurrency=6, goal_count=3,
                            staleness="polynomial")
    off = simgrid.run_grid(init_fn, loss_fn, ds, RC, 8, grid=gc, seed=2)
    on = simgrid.run_grid(
        init_fn, loss_fn, ds, RC, 8, seed=2,
        grid=dataclasses.replace(gc, telemetry="memory"))
    _assert_same_run(off, on)
    for ha, hb in zip(off.history, on.history):
        assert ha["staleness_mean"] == hb["staleness_mean"]


def test_async_profile_annotations_run():
    """TelemetryConfig(profile=True) wraps the jitted lane step and the
    server apply in jax.profiler annotations — the run must behave
    identically (same history), just with named profiler scopes."""
    ds = make_ds()
    gc = simgrid.GridConfig(mode="async", concurrency=4, goal_count=2)
    ref = simgrid.run_grid(init_fn, loss_fn, ds, RC, 3, grid=gc, seed=1)
    prof = simgrid.run_grid(
        init_fn, loss_fn, ds, RC, 3, seed=1,
        grid=dataclasses.replace(
            gc, telemetry=trace_lib.TelemetryConfig(profile=True)))
    _assert_same_run(ref, prof)


# ---------------------------------------------------------------------------
# Satellite: one normalized stats schema across scheduling modes


def test_stats_schema_normalized_across_modes():
    """Both modes emit every STAT_KEYS key, with explicit zeros for
    counters that cannot fire in that mode — no more async-only retries
    or sync-only offline."""
    ds = make_ds()
    sync = simgrid.run_grid(init_fn, loss_fn, ds, RC, 2, seed=0)
    asyn = simgrid.run_grid(
        init_fn, loss_fn, ds, RC, 2, seed=0,
        grid=simgrid.GridConfig(mode="async", concurrency=4, goal_count=2))
    assert tuple(sync.scheduler_stats) == simgrid.STAT_KEYS
    assert tuple(asyn.scheduler_stats) == simgrid.STAT_KEYS
    # uniform always-on fleet, no dynamics: nothing can retry/drop
    assert sync.scheduler_stats["retries"] == 0
    for k in ("offline", "deadline_drops", "excess"):
        assert asyn.scheduler_stats[k] == 0
    assert asyn.scheduler_stats["uploads"] > 0


def test_scheduler_stats_is_registry_view():
    ds = make_ds()
    res = simgrid.run_grid(
        init_fn, loss_fn, ds, RC, 3, seed=1,
        grid=simgrid.GridConfig(mode="async", concurrency=4, goal_count=2))
    snap = res.metrics.snapshot()
    for k, v in res.scheduler_stats.items():
        assert snap["counters"][k]["value"] == v, k
    assert snap["gauges"]["payload_up_bytes"]["value"] \
        == res.comm.measured_up_bytes // max(res.scheduler_stats["uploads"], 1)


@pytest.mark.dynamics
def test_sync_dark_window_repoll_counts_as_retry():
    """The sync dark-window backoff advance is the retry analogue of the
    async parked dispatch — it must land in the same normalized key."""
    ds = make_ds(n_clients=4)
    cfg = dyn_lib.DynamicsConfig(
        availability=dyn_lib.StepTrace([0.0, 100.0], [0.0, 1.0]),
        redispatch_backoff=30.0)
    gc = simgrid.GridConfig(fleet=_fleet([1.0] * 4), dynamics=cfg,
                            telemetry="memory")
    res = simgrid.run_grid(init_fn, loss_fn, ds, RC, 6, grid=gc, seed=0)
    # ceil(100/30) = 4 dark re-polls before the window opens
    assert res.scheduler_stats["retries"] == 4
    retries = res.telemetry.of_kind("retry")
    assert len(retries) == 4
    assert all(r.payload["backoff"] == 30.0 for r in retries)


# ---------------------------------------------------------------------------
# Acceptance: traced runs export valid streams whose timestamps
# cross-check against GridResult's own totals


def test_sync_traced_events_cross_check():
    ds = make_ds()
    gc = simgrid.GridConfig(fleet="pareto-mobile", over_selection=1.3,
                            straggler_deadline=120.0, telemetry="memory")
    res = simgrid.run_grid(init_fn, loss_fn, ds, RC, 4, grid=gc, seed=3)
    tr = res.telemetry
    st = res.scheduler_stats
    assert len(tr.of_kind("dispatch")) == st["dispatches"]
    uploads = tr.of_kind("upload")
    # the stats "uploads" counter includes late arrivals (the server
    # still pays their uplink); upload *instants* are only emitted for
    # deltas that made the deadline
    assert len(uploads) == st["uploads"] - st["deadline_drops"]
    assert sum(u.payload["participant"] for u in uploads) \
        == int(sum(h["participants"] for h in res.history))
    rounds = tr.of_kind("round")
    assert len(rounds) == len(res.history)
    for span, rec in zip(rounds, res.history):
        # the round span ends exactly at the history's virtual timestamp
        assert span.t + span.dur == pytest.approx(rec["virtual_seconds"])
        assert span.payload["loss"] == rec["loss"]
    # dropouts are dispatch spans with a null duration and no upload
    drops = [d for d in tr.of_kind("dispatch")
             if d.payload["outcome"] == "dropout"]
    assert len(drops) == st["dropouts"]
    assert all(d.dur is None for d in drops)
    assert schema_lib.validate_records(
        [r.to_json() for r in tr.events]) == []


def test_async_traced_run_exports_and_cross_checks(tmp_path):
    """The ISSUE's acceptance run: traced async DP grid -> schema-valid
    JSONL + loadable Perfetto containing dispatch/upload/flush/dp_flush,
    with virtual timestamps matching GridResult.stats totals."""
    jsonl = str(tmp_path / "trace.jsonl")
    pft = str(tmp_path / "trace.json")
    ds = make_ds()
    rc = fedpt.RoundConfig(4, 2, 8, "sgd", 0.1, "sgd", 1.0,
                           dp_clip_norm=0.5, dp_noise_multiplier=0.4)
    gc = simgrid.GridConfig(
        mode="async", concurrency=5, goal_count=3,
        telemetry=trace_lib.TelemetryConfig(jsonl_path=jsonl,
                                            perfetto_path=pft))
    res = simgrid.run_grid(init_fn, loss_fn, ds, rc, 6, grid=gc, seed=4)
    tr = res.telemetry
    st = res.scheduler_stats
    assert len(tr.of_kind("dispatch")) == st["dispatches"]
    assert len(tr.of_kind("upload")) == st["uploads"]
    flushes = tr.of_kind("flush")
    assert len(flushes) == len(res.history)
    for f, rec in zip(flushes, res.history):
        assert f.t == rec["virtual_seconds"]
        assert f.payload["staleness_mean"] == rec["staleness_mean"]
    # the dp_flush stream is the accountant's composition, step by step:
    # monotone epsilon, final value = the reported budget
    dps = tr.of_kind("dp_flush")
    assert len(dps) == res.dp["flushes"] == 6
    eps = [d.payload["epsilon"] for d in dps]
    assert eps == sorted(eps)
    assert eps[-1] == pytest.approx(res.dp["epsilon"])
    assert all(d.payload["sigma"] == res.dp["sigma"] for d in dps)
    for d, f in zip(dps, flushes):
        assert d.t == f.t                 # accounted at flush time
    # every completed dispatch carries its realized round trip as a span
    spans = [d for d in tr.of_kind("dispatch")
             if d.payload["outcome"] == "ok"]
    assert spans and all(d.dur is not None for d in spans)
    assert sum(u.payload["up_bytes"] for u in tr.of_kind("upload")) \
        == res.comm.measured_up_bytes
    # exports were written by flush_outputs and validate cleanly
    n, errs = schema_lib.validate_jsonl(jsonl)
    assert errs == [] and n == len(tr.events)
    pn, perrs = schema_lib.validate_perfetto(
        pft, require=["dispatch", "upload", "flush", "dp_flush"])
    assert perrs == [] and pn == n
    # the Perfetto timeline uses microseconds of virtual time
    with open(pft) as f:
        doc = json.load(f)
    fl = [e for e in doc["traceEvents"] if e.get("name") == "flush"]
    assert sorted(e["ts"] for e in fl) \
        == [pytest.approx(h["virtual_seconds"] * 1e6) for h in res.history]
    assert schema_lib.main([jsonl, "--perfetto", pft,
                            "--require", "dispatch", "flush"]) == 0


def test_async_tiered_traced_tier_billing(tmp_path):
    """tier_upload events from the comm ledger: one instant per tier's
    end-of-run billing batch, bytes summing to the ledger totals, and
    tier_stats' rtt_mean fed by the registry's labeled accumulators."""
    ds = make_ds(n_clients=12)
    gc = simgrid.GridConfig(mode="async", fleet="pareto-mobile",
                            concurrency=6, goal_count=3, plan=TIER_PLAN,
                            telemetry="memory")
    res = simgrid.run_grid(init_fn, loss_fn, ds, RC, 8, grid=gc, seed=5)
    tus = res.telemetry.of_kind("tier_upload")
    assert tus and {t.payload["tier_name"] for t in tus} \
        <= set(TIER_PLAN)
    assert sum(t.payload["up_bytes"] for t in tus) \
        == res.comm.measured_up_bytes
    assert sum(t.payload["down_bytes"] for t in tus) \
        == res.comm.measured_down_bytes
    assert all(t.t == res.virtual_seconds for t in tus)
    # dispatch spans carry the tier the payload was sliced for
    tiers_seen = {d.payload["tier"] for d in
                  res.telemetry.of_kind("dispatch")}
    assert tiers_seen <= {0, 1, 2}
    # rtt_mean comes from tier_rtt_sum / tier_rtt_n in the registry
    for name, rec in res.tier_stats.items():
        if rec["uploads"]:
            assert rec["rtt_mean"] > 0.0, name
    assert schema_lib.validate_records(
        [r.to_json() for r in res.telemetry.events]) == []


# ---------------------------------------------------------------------------
# Satellite: CommReport edge cases (tier_table / transfer_seconds /
# per_client_round_mb)


def test_comm_tier_table_empty_and_zero_uploads():
    rep = comm.CommReport(full_bytes=1000, trainable_bytes=100)
    assert rep.tier_table() == {}            # nothing metered yet
    # a tier that dispatched but never uploaded (all dropouts): the
    # per-upload figure must be an explicit 0.0, not a ZeroDivisionError
    rep.add_tier_measured("lite", down_bytes=400, up_bytes=0, transfers=4,
                          uploads=0)
    tab = rep.tier_table()
    assert tab["lite"]["up_bytes_per_upload"] == 0.0
    assert tab["lite"]["down_mb"] == pytest.approx(400 / 2 ** 20)
    assert tab["lite"]["up_mb"] == 0.0
    # ... and the zero-byte batch still counts its transfers globally
    assert rep.transfers == 4 and rep.measured_up_bytes == 0
    assert rep.measured_total_bytes == 400


def test_comm_transfer_seconds_full_vs_fedpt():
    mb = 2 ** 20
    rep = comm.CommReport(full_bytes=4 * mb, trainable_bytes=1 * mb,
                          rounds=2)
    # fedpt=True: (trainable + seed) down, trainable up, per round
    want_fedpt = ((1 * mb + comm.SEED_BYTES) * 2 / mb / comm.DOWNLINK_MBPS
                  + 1 * 2 / comm.UPLINK_MBPS)
    assert rep.transfer_seconds() == pytest.approx(want_fedpt)
    # fedpt=False bills the full model both ways
    want_full = 4 * 2 / comm.DOWNLINK_MBPS + 4 * 2 / comm.UPLINK_MBPS
    assert rep.transfer_seconds(fedpt=False) == pytest.approx(want_full)
    assert rep.transfer_seconds(fedpt=False) > rep.transfer_seconds()
    # analytic columns are independent of wire metering
    before = rep.transfer_seconds()
    rep.add_measured(0, 0, transfers=1)      # zero measured bytes
    assert rep.transfer_seconds() == before


def test_comm_per_client_round_mb_quantized():
    mb = 2 ** 20
    rep = comm.CommReport(full_bytes=4 * mb, trainable_bytes=1 * mb,
                          rounds=3, uplink_bits=8,
                          quantized_trainable_bytes=mb // 4)
    out = rep.per_client_round_mb()
    assert out["full_down_mb"] == out["full_up_mb"] == 4.0
    assert out["fedpt_down_mb"] == pytest.approx(
        (mb + comm.SEED_BYTES) / mb)
    # quantized uplink: per-round upload is the int8 payload
    assert out["fedpt_up_mb"] == pytest.approx(0.25)
    assert rep.upload_fedpt == (mb // 4) * 3
    # zero quantized bytes falls back to fp32 (the fedpt=False-ish path)
    rep0 = comm.CommReport(full_bytes=4 * mb, trainable_bytes=1 * mb,
                           uplink_bits=8, quantized_trainable_bytes=0)
    assert rep0.per_client_round_mb()["fedpt_up_mb"] == 1.0


def test_comm_add_tier_measured_emits_traced_instant():
    rep = comm.CommReport(full_bytes=1000, trainable_bytes=100,
                          tracer=trace_lib.Tracer())
    rep.add_tier_measured("mid", down_bytes=300, up_bytes=120, transfers=3,
                          uploads=2, now=7.5)
    (rec,) = rep.tracer.of_kind("tier_upload")
    assert rec.t == 7.5
    assert rec.payload == {"tier_name": "mid", "down_bytes": 300,
                           "up_bytes": 120, "transfers": 3, "uploads": 2}
    assert schema_lib.validate_record(rec.to_json()) == []
    # the tracer is plumbing, never ledger state: equality ignores it
    assert rep == dataclasses.replace(rep, tracer=trace_lib.NULL_TRACER)
