"""Device dynamics (sim/dynamics.py): stochastic links, trace-driven
availability, RNG-stream hygiene, and the trivial-case bit-for-bit
contract with the pre-dynamics grid."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.partition as part
from repro.core import fedpt
from repro.data import synthetic as syn
from repro.nn import basic
from repro.sim import devices as dev_lib
from repro.sim import dynamics as dyn_lib
from repro.sim import grid as simgrid
from repro.sim import scheduler as sched_lib


def init_fn(seed):
    return {"dense": basic.init_dense(seed, "dense", 64, 4, jnp.float32,
                                      bias=True)}


def loss_fn(params, b):
    x = b["images"].reshape(b["images"].shape[0], -1)
    logits = basic.dense(x, params["dense"])
    lp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(lp, b["labels"][:, None], 1)), {}


def make_ds(n_clients=12, seed=0):
    return syn.make_federated_images(n_clients, 30, (8, 8, 1), 4, seed=seed,
                                     test_examples=64)


RC = fedpt.RoundConfig(4, 2, 8, "sgd", 0.1, "sgd", 1.0)

MB = 1024.0 * 1024.0


def _fleet(mults, **kw):
    return dev_lib.Fleet(name="test", profiles=[
        dev_lib.DeviceProfile(downlink_bps=MB, uplink_bps=MB,
                              compute_multiplier=m, **kw) for m in mults])


def _assert_same_run(a, b):
    assert [h["loss"] for h in a.history] == [h["loss"] for h in b.history]
    for ha, hb in zip(a.history, b.history):
        assert ha["virtual_seconds"] == hb["virtual_seconds"]
    for (pa, la), (pb, lb) in zip(basic.flatten_params(a.y),
                                  basic.flatten_params(b.y)):
        assert pa == pb and bool(jnp.all(la == lb)), pa
    assert a.comm.measured_up_bytes == b.comm.measured_up_bytes
    assert a.scheduler_stats == b.scheduler_stats


# ---------------------------------------------------------------------------
# LinkModel


def test_link_model_trivial_is_exact():
    lm = dyn_lib.LinkModel()
    assert lm.trivial
    # sigma=0 maps any z to factor exactly 1.0: static bytes/bps
    for z in (-3.0, 0.0, 2.5):
        assert lm.jitter(z) == 1.0
        assert lm.transfer_seconds(MB, MB, z) == 1.0


def test_link_model_jitter_mean_preserving():
    lm = dyn_lib.LinkModel(jitter_sigma=0.5, rtt_seconds=0.25)
    assert not lm.trivial
    rng = np.random.default_rng(0)
    z = rng.standard_normal(200_000)
    factors = np.exp(0.5 * z - 0.125)
    # E[exp(sigma z - sigma^2/2)] = 1 — jitter changes variance, not
    # the expected transfer time
    assert np.mean(factors) == pytest.approx(1.0, rel=0.02)
    t = lm.transfer_seconds(2 * MB, MB, 0.0)
    assert t == pytest.approx(0.25 + 2.0 * math.exp(-0.125))
    # the RTT floor holds even for zero-byte transfers
    assert lm.transfer_seconds(0, MB, 1.0) == 0.25


# ---------------------------------------------------------------------------
# Availability traces


def test_diurnal_trace_bounds_and_period():
    tr = dyn_lib.DiurnalTrace(period=100.0, low=0.2, high=0.8,
                              phase_spread=0.0)
    tr = tr.bind(4, np.random.default_rng(0))
    vals = [tr.prob(0, t) for t in np.linspace(0, 100, 201)]
    assert min(vals) == pytest.approx(0.2, abs=1e-6)
    assert max(vals) == pytest.approx(0.8, abs=1e-6)
    # periodic: one full cycle returns to the start
    assert tr.prob(2, 0.0) == pytest.approx(tr.prob(2, 100.0))
    # phase_spread=0: the whole fleet shares one clock
    assert tr.prob(0, 37.0) == tr.prob(3, 37.0)
    # per-client phases desynchronize the fleet
    tr2 = dyn_lib.DiurnalTrace(period=100.0).bind(8, np.random.default_rng(1))
    assert len({round(tr2.prob(c, 10.0), 9) for c in range(8)}) > 1
    with pytest.raises(ValueError):
        dyn_lib.DiurnalTrace(low=0.9, high=0.1)


def test_step_trace_shared_and_per_client():
    tr = dyn_lib.StepTrace([0.0, 10.0, 20.0], [1.0, 0.0, 0.5]).bind(
        3, np.random.default_rng(0))
    assert tr.prob(0, 0.0) == 1.0
    assert tr.prob(0, 9.999) == 1.0
    assert tr.prob(0, 10.0) == 0.0     # right-continuous steps
    assert tr.prob(0, 19.0) == 0.0
    assert tr.prob(0, 1e9) == 0.5      # last value holds forever
    per = dyn_lib.StepTrace([0.0, 5.0], [[1.0, 0.0], [0.0, 1.0]]).bind(
        2, np.random.default_rng(0))
    assert per.prob(0, 1.0) == 1.0 and per.prob(1, 1.0) == 0.0
    assert per.prob(0, 6.0) == 0.0 and per.prob(1, 6.0) == 1.0
    with pytest.raises(ValueError):
        dyn_lib.StepTrace([1.0, 2.0], [1.0, 1.0])      # must start at 0
    with pytest.raises(ValueError):
        dyn_lib.StepTrace([0.0, 1.0], [0.5, 1.5])      # out of [0, 1]
    with pytest.raises(ValueError):
        dyn_lib.StepTrace([0.0, 5.0], [[1.0, 0.0]]).bind(
            2, np.random.default_rng(0))               # row/fleet mismatch


# ---------------------------------------------------------------------------
# Resolution: trivial configs route to None (the pre-dynamics paths)


def test_resolve_dynamics():
    uni = dev_lib.make_fleet(4, "uniform")
    assert dyn_lib.resolve_dynamics(None, uni) is None
    assert dyn_lib.resolve_dynamics("static", uni) is None
    assert dyn_lib.resolve_dynamics(dyn_lib.DynamicsConfig(), uni) is None
    got = dyn_lib.resolve_dynamics("jitter", uni)
    assert got is not None and not got.trivial
    with pytest.raises(ValueError, match="unknown dynamics preset"):
        dyn_lib.resolve_dynamics("galaxy-brain", uni)
    with pytest.raises(TypeError):
        dyn_lib.resolve_dynamics(42, uni)
    # the diurnal fleet preset implies the diurnal dynamics preset...
    diurnal = dev_lib.make_fleet(4, "pareto-mobile-diurnal", seed=1)
    assert all(p.link_model is not None for p in diurnal.profiles)
    assert dyn_lib.resolve_dynamics(None, diurnal) is not None
    # ... "static" is the hard off-switch (the A/B control), overriding
    # even the profiles' own link models ...
    assert dyn_lib.resolve_dynamics("static", diurnal) is None
    # ... while an explicit (even trivial) config honors profile links
    assert dyn_lib.resolve_dynamics(dyn_lib.DynamicsConfig(),
                                    diurnal) is not None
    # explicit per-client phases must match the fleet, never be
    # silently redrawn
    with pytest.raises(ValueError, match="phases"):
        dyn_lib.DiurnalTrace(phases=np.zeros(3)).bind(
            5, np.random.default_rng(0))


def test_bound_dynamics_prefers_profile_link():
    fleet = _fleet([1.0, 1.0])
    slow = dataclasses.replace(fleet.profiles[1],
                               link_model=dyn_lib.LinkModel(rtt_seconds=5.0))
    fleet = dev_lib.Fleet(name="t", profiles=[fleet.profiles[0], slow])
    cfg = dyn_lib.DynamicsConfig(link=dyn_lib.LinkModel(rtt_seconds=1.0))
    bound = cfg.bind(fleet, np.random.default_rng(0))
    assert bound.link_for(0).rtt_seconds == 1.0   # fleet default
    assert bound.link_for(1).rtt_seconds == 5.0   # profile override


# ---------------------------------------------------------------------------
# RNG hygiene: the dynamics stream is independent of the device stream


def test_spawned_dynamics_stream_leaves_parent_untouched():
    """The grid spawns the dynamics child off [seed, device_seed];
    spawning must not advance the parent's draw stream — this is what
    keeps plan_sync_round's fixed-count availability/dropout draws
    byte-identical with dynamics on or off."""
    a = np.random.default_rng([7, 13])
    b = np.random.default_rng([7, 13])
    child = b.spawn(1)[0]
    np.testing.assert_array_equal(a.random(16), b.random(16))
    # and the child is genuinely a different stream
    assert not np.array_equal(np.random.default_rng([7, 13]).random(8),
                              child.random(8))


@pytest.mark.dynamics
def test_plan_sync_round_jitter_preserves_outcome_streams():
    """Jitter moves arrival times but must not move the fixed-count
    availability/dropout draws: the same members dispatch and drop with
    dynamics on and off."""
    fleet = _fleet([1.0, 2.0, 3.0, 4.0], availability=0.6, dropout=0.3)
    cfg = dyn_lib.DynamicsConfig(link=dyn_lib.LinkModel(jitter_sigma=0.5))
    bound = cfg.bind(fleet, np.random.default_rng(0))
    base = sched_lib.plan_sync_round(
        fleet, [0, 1, 2, 3], int(MB), int(MB), 1.0, 4,
        np.random.default_rng(42))
    jit = sched_lib.plan_sync_round(
        fleet, [0, 1, 2, 3], int(MB), int(MB), 1.0, 4,
        np.random.default_rng(42),
        dynamics=bound, dyn_rng=np.random.default_rng(9))
    np.testing.assert_array_equal(base.dispatched, jit.dispatched)
    assert base.offline == jit.offline and base.dropouts == jit.dropouts
    # ... while the completing members' times actually moved
    done = np.isfinite(base.arrival)
    assert done.any()
    assert not np.allclose(base.arrival[done], jit.arrival[done])


@pytest.mark.dynamics
def test_grid_trivial_dynamics_bit_for_bit():
    """Acceptance: static links + always-on trace + uniform selection
    reproduce the pre-dynamics grid exactly in both modes."""
    ds = make_ds()
    ref = simgrid.run_grid(init_fn, loss_fn, ds, RC, 4, seed=3)
    got = simgrid.run_grid(
        init_fn, loss_fn, ds, RC, 4, seed=3,
        grid=simgrid.GridConfig(dynamics="static", selection="uniform"))
    _assert_same_run(ref, got)
    gc = simgrid.GridConfig(mode="async", fleet="pareto-mobile",
                            concurrency=6, goal_count=3)
    ra = simgrid.run_grid(init_fn, loss_fn, ds, RC, 8, grid=gc, seed=2)
    rb = simgrid.run_grid(
        init_fn, loss_fn, ds, RC, 8, seed=2,
        grid=dataclasses.replace(gc, dynamics="static",
                                 selection="uniform"))
    _assert_same_run(ra, rb)
    assert ra.dynamics is None and rb.dynamics is None


@pytest.mark.dynamics
def test_grid_jitter_only_moves_the_clock_not_the_outcome_streams():
    """End to end: enabling jitter-only dynamics on the sync grid keeps
    every availability/dropout outcome (the dev-stream draws) while the
    virtual clock moves."""
    ds = make_ds()
    gc = simgrid.GridConfig(fleet="pareto-mobile")
    a = simgrid.run_grid(init_fn, loss_fn, ds, RC, 4, grid=gc, seed=5)
    b = simgrid.run_grid(
        init_fn, loss_fn, ds, RC, 4, seed=5,
        grid=dataclasses.replace(gc, dynamics=dyn_lib.DynamicsConfig(
            link=dyn_lib.LinkModel(jitter_sigma=0.3))))
    for k in ("offline", "dropouts", "dispatches"):
        assert a.scheduler_stats[k] == b.scheduler_stats[k], k
    assert a.virtual_seconds != b.virtual_seconds


# ---------------------------------------------------------------------------
# Scheduler edge cases under availability windows


@pytest.mark.dynamics
def test_sync_all_offline_window_closes_at_deadline():
    """A zero-availability trace window: nobody dispatches, the round
    closes at its deadline with an empty update (y unchanged)."""
    ds = make_ds(n_clients=4)
    dark = dyn_lib.DynamicsConfig(
        availability=dyn_lib.StepTrace([0.0, 1e9], [0.0, 1.0]))
    gc = simgrid.GridConfig(fleet=_fleet([1.0] * 4), dynamics=dark,
                            straggler_deadline=10.0)
    res = simgrid.run_grid(init_fn, loss_fn, ds, RC, 2, grid=gc, seed=0)
    assert res.scheduler_stats["dispatches"] == 0
    assert res.scheduler_stats["offline"] == 2 * RC.clients_per_round
    assert all(h["participants"] == 0.0 for h in res.history)
    assert res.virtual_seconds == pytest.approx(20.0)  # 2 deadline closes
    y0, _ = part.partition(init_fn(0), ())
    for (p, l0), (_, l1) in zip(basic.flatten_params(y0),
                                basic.flatten_params(res.y)):
        assert bool(jnp.all(l0 == l1)), p   # empty updates moved nothing
    assert res.comm.measured_down_bytes == 0


@pytest.mark.dynamics
def test_sync_dark_window_without_deadline_advances_the_clock():
    """A deadline-less sync server under a dark window must not freeze
    the virtual clock at the same trace query forever: empty rounds
    advance by the redispatch backoff until the trace opens."""
    ds = make_ds(n_clients=4)
    cfg = dyn_lib.DynamicsConfig(
        availability=dyn_lib.StepTrace([0.0, 100.0], [0.0, 1.0]),
        redispatch_backoff=30.0)
    gc = simgrid.GridConfig(fleet=_fleet([1.0] * 4), dynamics=cfg)
    res = simgrid.run_grid(init_fn, loss_fn, ds, RC, 8, grid=gc, seed=0)
    # first ceil(100/30)=4 rounds are empty backoff advances, then the
    # window opens and cohorts actually train
    assert [h["participants"] for h in res.history[:4]] == [0.0] * 4
    assert all(h["participants"] > 0 for h in res.history[4:])
    assert res.history[3]["virtual_seconds"] == pytest.approx(120.0)
    assert res.virtual_seconds > 120.0


@pytest.mark.dynamics
def test_async_dark_window_does_not_deadlock():
    """Async under a dark availability window must park dispatches and
    resume when the trace opens — not starve, not spin forever."""
    ds = make_ds(n_clients=6)
    # fleet dark until t=200, then fully online
    cfg = dyn_lib.DynamicsConfig(
        availability=dyn_lib.StepTrace([0.0, 200.0], [0.0, 1.0]),
        redispatch_backoff=25.0)
    gc = simgrid.GridConfig(mode="async", fleet=_fleet([1.0] * 6),
                            dynamics=cfg, concurrency=3, goal_count=2)
    res = simgrid.run_grid(init_fn, loss_fn, ds, RC, 3, grid=gc, seed=1)
    assert len(res.history) == 3
    assert res.scheduler_stats["retries"] >= 3    # parked during the window
    # nothing could complete before the window opened
    assert res.history[0]["virtual_seconds"] >= 200.0


@pytest.mark.dynamics
def test_async_deadline_inside_dark_window_terminates():
    """A run whose whole budget sits inside the dark window must end at
    the deadline with however little it buffered — never deadlock."""
    ds = make_ds(n_clients=6)
    cfg = dyn_lib.DynamicsConfig(
        availability=dyn_lib.StepTrace([0.0, 1e9], [0.0, 1.0]),
        redispatch_backoff=10.0)
    gc = simgrid.GridConfig(mode="async", fleet=_fleet([1.0] * 6),
                            dynamics=cfg, concurrency=3, goal_count=2,
                            async_deadline=100.0)
    res = simgrid.run_grid(init_fn, loss_fn, ds, RC, 5, grid=gc, seed=1)
    assert res.history == []                       # nothing ever completed
    assert res.scheduler_stats["uploads"] == 0
    assert res.scheduler_stats["retries"] > 0


@pytest.mark.dynamics
def test_straggler_deadline_interacts_with_jittered_uplinks():
    """With static links every member beats the deadline; jitter pushes
    some uploads past it — deadline drops appear and the round closes
    with fewer participants."""
    fleet = _fleet([1.0] * 8)
    cohort = list(range(8))
    # static: every round trip is exactly 1.0s of compute, deadline 1.5
    base = sched_lib.plan_sync_round(fleet, cohort, 0, int(MB), 1.0, 8,
                                     np.random.default_rng(0), deadline=2.2)
    assert base.deadline_drops == 0 and base.participant.all()
    cfg = dyn_lib.DynamicsConfig(link=dyn_lib.LinkModel(jitter_sigma=1.0))
    bound = cfg.bind(fleet, np.random.default_rng(0))
    jit = sched_lib.plan_sync_round(fleet, cohort, 0, int(MB), 1.0, 8,
                                    np.random.default_rng(0), deadline=2.2,
                                    dynamics=bound,
                                    dyn_rng=np.random.default_rng(7))
    assert jit.deadline_drops > 0
    assert jit.participant.sum() < 8
    assert jit.round_seconds == 2.2   # the server waited the deadline out


# ---------------------------------------------------------------------------
# The diurnal fleet preset, end to end


@pytest.mark.dynamics
def test_pareto_mobile_diurnal_preset_end_to_end():
    ds = make_ds(n_clients=16)
    fleet = dev_lib.make_fleet(16, "pareto-mobile-diurnal", seed=1)
    assert all(p.link_model is not None and not p.link_model.trivial
               for p in fleet.profiles)
    gc = simgrid.GridConfig(mode="async", fleet="pareto-mobile-diurnal",
                            concurrency=6, goal_count=3)
    res = simgrid.run_grid(init_fn, loss_fn, ds, RC, 8, grid=gc, seed=2)
    assert res.dynamics is not None            # auto-resolved "diurnal"
    assert len(res.history) == 8
    assert res.history[-1]["loss"] < res.history[0]["loss"]
    # replay-deterministic: same seeds, same trajectory
    res2 = simgrid.run_grid(init_fn, loss_fn, ds, RC, 8, grid=gc, seed=2)
    assert [h["loss"] for h in res.history] \
        == [h["loss"] for h in res2.history]
    assert res.virtual_seconds == res2.virtual_seconds
