"""MoE dispatch: sort-based capacity dispatch vs the dense oracle,
capacity-drop semantics, and router invariants.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.nn import moe as moe_lib

CFG = ModelConfig(name="m", family="moe", num_layers=1, d_model=32,
                  num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=8,
                  num_experts=4, num_experts_per_tok=2,
                  moe_capacity_factor=8.0,  # high capacity: no drops
                  compute_dtype="float32")


def test_moe_matches_dense_oracle_when_no_drops():
    p = moe_lib.init_moe(0, "moe", CFG, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (64, 32))
    got, aux1 = moe_lib.moe_ffn(x, p, CFG)
    want, aux2 = moe_lib.moe_ffn_dense_fallback(x, p, CFG)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-5)


def test_moe_with_shared_experts():
    cfg = CFG.with_(num_shared_experts=1, moe_d_ff=32)
    p = moe_lib.init_moe(0, "moe", cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(2), (32, 32))
    got, _ = moe_lib.moe_ffn(x, p, cfg)
    want, _ = moe_lib.moe_ffn_dense_fallback(x, p, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_capacity_drop_reduces_output_not_crashes():
    cfg = CFG.with_(moe_capacity_factor=0.25)  # force heavy dropping
    p = moe_lib.init_moe(0, "moe", cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(3), (64, 32))
    got, _ = moe_lib.moe_ffn(x, p, cfg)
    assert got.shape == x.shape
    assert bool(jnp.isfinite(got).all())
    # dropped tokens -> some outputs exactly zero (no expert contribution)
    norms = jnp.linalg.norm(np.asarray(got), axis=-1)
    assert float(jnp.min(norms)) == 0.0


def test_router_weights_normalized_topk():
    p = moe_lib.init_moe(0, "moe", CFG, jnp.float32)
    x = jax.random.normal(jax.random.key(4), (16, 32))
    w, idx, aux = moe_lib.router_topk(x, p, CFG)
    assert w.shape == (16, 2) and idx.shape == (16, 2)
    np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), 1.0, rtol=1e-5)
    assert int(idx.min()) >= 0 and int(idx.max()) < 4
    # top-k indices are distinct per token
    assert bool((idx[:, 0] != idx[:, 1]).all())
    assert float(aux) > 0
