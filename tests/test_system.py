"""End-to-end system behaviour: federated FedPT training actually learns,
decode matches prefill for every family, and the serving path generates.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.partition as part
from repro.core import fedpt
from repro.data import synthetic as syn
from repro.configs.base import ModelConfig
from repro.models import decoder_lm as dlm
from repro.models import paper_models as pm


def test_fedpt_learns_synthetic_emnist():
    ds = syn.make_federated_images(16, 40, (28, 28, 1), 62, seed=0,
                                   test_examples=200)

    def loss_fn(params, b):
        logits = pm.emnist_cnn_forward(params, b["images"])
        lp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(lp, b["labels"][:, None], 1)), {}

    y, z = part.partition(pm.init_emnist_cnn(0), pm.EMNIST_FREEZE)
    rc = fedpt.RoundConfig(6, 2, 16, "sgd", 0.05, "sgd", 0.5)
    round_fn, sopt = fedpt.make_round_fn(loss_fn, rc)
    round_fn = jax.jit(round_fn)
    ss = sopt.init(y)
    rng = np.random.default_rng(0)
    losses = []
    for r in range(8):
        cids = syn.sample_cohort(rng, 16, 6)
        batch, w = syn.cohort_batch(ds, cids, 2, 16, rng)
        y, ss, m = round_fn(y, ss, z, batch, jnp.asarray(w), jax.random.key(r))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.95, losses
    acc = float(jnp.mean(jnp.argmax(pm.emnist_cnn_forward(
        part.merge(y, z), ds.test_images), -1) == ds.test_labels))
    assert acc > 0.10  # >6x chance after 8 rounds


@pytest.mark.parametrize("family_cfg", [
    dict(name="t-dense", family="dense"),
    dict(name="t-swa", family="dense", sliding_window=4),
    dict(name="t-moe", family="moe", num_experts=4, num_experts_per_tok=2,
         moe_capacity_factor=8.0),
    dict(name="t-mla", family="dense", use_mla=True, kv_lora_rank=32,
         q_lora_rank=48, qk_nope_head_dim=16, qk_rope_head_dim=8,
         v_head_dim=16),
    dict(name="t-hybrid", family="hybrid", num_layers=4, attn_period=4,
         use_rope=False),
    dict(name="t-ssm", family="ssm", num_layers=4, d_ff=0, slstm_every=4,
         use_rope=False, tie_embeddings=True),
])
def test_decode_matches_prefill(family_cfg):
    """The strongest serving invariant: token-by-token decode with caches
    reproduces the teacher-forced forward pass."""
    kw = dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
              d_ff=128, vocab_size=64, compute_dtype="float32")
    kw.update(family_cfg)
    cfg = ModelConfig(**kw)
    p = dlm.init_model(cfg, 0)
    B, T = 2, 8
    toks = jax.random.randint(jax.random.key(1), (B, T), 0, cfg.vocab_size)
    cache = dlm.init_cache(cfg, B, 16)
    outs = []
    for t in range(T):
        lg, cache = dlm.decode_step(p, cfg, cache, toks[:, t:t + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    full, _ = dlm.forward(p, cfg, toks)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-4, rtol=2e-4)


def test_generation_runs_and_is_deterministic():
    from repro.launch.serve import generate
    cfg = ModelConfig(name="g", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                      compute_dtype="float32")
    p = dlm.init_model(cfg, 0)
    prompt = jnp.ones((2, 4), jnp.int32)
    a = generate(p, cfg, prompt, steps=8, max_len=16)
    b = generate(p, cfg, prompt, steps=8, max_len=16)
    assert a.shape == (2, 12)
    assert bool((a == b).all())
