"""Dry-run integration: the lowering path works end-to-end on a small
forced-device mesh in a subprocess (the 512-device production matrices
are exercised offline; their JSON results are validated here when
present).
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
from repro.configs import load_all
from repro.launch import mesh as mesh_lib, dryrun
load_all()
mesh = mesh_lib.make_debug_mesh((2, 4), ("data", "model"))
out = [dryrun.run_one(a, s, mesh=mesh, verbose=False)
       for a, s in [("stablelm-1.6b", "decode_32k"),
                    ("mixtral-8x7b", "train_4k")]]
print(json.dumps(out))
"""


@pytest.mark.slow
def test_dryrun_small_mesh_subprocess():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-2000:]
    res = json.loads(proc.stdout.strip().splitlines()[-1])
    for r in res:
        assert r["status"] == "ok", r
        assert r["cost"].get("flops", 0) > 0
        assert r["memory"]["peak_bytes"] > 0


@pytest.mark.parametrize("path,mesh_shape", [
    ("results/dryrun_single_pod.json", [16, 16]),
    ("results/dryrun_multi_pod.json", [2, 16, 16]),
])
def test_production_matrix_results(path, mesh_shape):
    """Validates the recorded production dry-run matrices: every non-skip
    pair lowered + compiled, skips match the documented rule."""
    full = os.path.join(ROOT, path)
    if not os.path.exists(full):
        pytest.skip(f"{path} not generated in this checkout")
    res = json.load(open(full))
    assert len(res) == 40
    from repro.launch.specs import skip_reason
    for r in res:
        expected_skip = skip_reason(r["arch"], r["shape"])
        if expected_skip:
            assert r["status"] == "skip"
        else:
            assert r["status"] == "ok", (r["arch"], r["shape"],
                                         r.get("error"))
            assert r["mesh"] == mesh_shape
            assert r["memory"]["peak_bytes"] > 0
