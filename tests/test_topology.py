"""Hierarchical aggregation (sim/topology.py): region partitions, edge
pre-reduce, per-hop wire billing, correlated region shocks, and the
one-region bit-for-bit contract with the flat grid."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fedpt
from repro.data import synthetic as syn
from repro.nn import basic
from repro.sim import devices as dev_lib
from repro.sim import dynamics as dyn_lib
from repro.sim import grid as simgrid
from repro.sim import scheduler as sched_lib
from repro.sim import topology as topo_lib
from repro.sim import wire


def init_fn(seed):
    return {"dense": basic.init_dense(seed, "dense", 64, 4, jnp.float32,
                                      bias=True)}


def loss_fn(params, b):
    x = b["images"].reshape(b["images"].shape[0], -1)
    lp = jax.nn.log_softmax(basic.dense(x, params["dense"]))
    return -jnp.mean(jnp.take_along_axis(lp, b["labels"][:, None], 1)), {}


def make_ds(n_clients=12, seed=0):
    return syn.make_federated_images(n_clients, 30, (8, 8, 1), 4, seed=seed,
                                     test_examples=64)


RC = fedpt.RoundConfig(4, 2, 8, "sgd", 0.1, "sgd", 1.0)


def _assert_same_run(a, b):
    assert [h["loss"] for h in a.history] == [h["loss"] for h in b.history]
    for ha, hb in zip(a.history, b.history):
        assert ha["virtual_seconds"] == hb["virtual_seconds"]
    for (pa, la), (pb, lb) in zip(basic.flatten_params(a.y),
                                  basic.flatten_params(b.y)):
        assert pa == pb and bool(jnp.all(la == lb)), pa
    assert a.scheduler_stats == b.scheduler_stats
    # the legacy single-hop ledger is topology-independent
    assert a.comm.measured_down_bytes == b.comm.measured_down_bytes
    assert a.comm.measured_up_bytes == b.comm.measured_up_bytes
    assert a.comm.transfers == b.comm.transfers


# ---------------------------------------------------------------------------
# partition schemes


def test_contiguous_partition_blocks():
    t = topo_lib.Topology.build(12, topo_lib.TopologyConfig(regions=3))
    assert t.num_regions == 3
    np.testing.assert_array_equal(t.region_of, [0] * 4 + [1] * 4 + [2] * 4)
    np.testing.assert_array_equal(t.members(1), [4, 5, 6, 7])


def test_contiguous_partition_uneven_sizes_differ_by_one():
    t = topo_lib.Topology.build(10, topo_lib.TopologyConfig(regions=3))
    sizes = np.bincount(t.region_of, minlength=3)
    assert sizes.sum() == 10 and sizes.max() - sizes.min() <= 1


def test_strided_partition_interleaves():
    t = topo_lib.Topology.build(
        8, topo_lib.TopologyConfig(regions=3, assignment="strided"))
    np.testing.assert_array_equal(t.region_of, [0, 1, 2, 0, 1, 2, 0, 1])
    np.testing.assert_array_equal(t.members(2), [2, 5])


def test_explicit_partition_array():
    t = topo_lib.Topology.build(
        4, topo_lib.TopologyConfig(regions=2,
                                   assignment=np.array([1, 0, 1, 1])))
    np.testing.assert_array_equal(t.members(0), [1])
    np.testing.assert_array_equal(t.members(1), [0, 2, 3])
    assert t.summary()["region_size_max"] == 3.0


def test_partition_errors():
    with pytest.raises(ValueError, match=">= 1 region"):
        topo_lib.TopologyConfig(regions=0)
    with pytest.raises(ValueError, match="at least one client"):
        topo_lib.Topology.build(3, topo_lib.TopologyConfig(regions=5))
    with pytest.raises(ValueError, match="unknown region assignment"):
        topo_lib.Topology.build(
            4, topo_lib.TopologyConfig(regions=2, assignment="hexagons"))
    with pytest.raises(ValueError, match="uses region"):
        topo_lib.Topology.build(
            4, topo_lib.TopologyConfig(regions=2,
                                       assignment=np.array([0, 1, 2, 0])))
    with pytest.raises(ValueError, match="has shape"):
        topo_lib.Topology(4, np.zeros(3, np.int32))
    assert topo_lib.resolve_topology(None, 10) is None
    assert topo_lib.resolve_topology(3, 10).num_regions == 3


# ---------------------------------------------------------------------------
# FleetState struct-of-arrays vs the per-profile scalar paths


def test_fleet_state_matches_per_profile_views():
    fleet = dev_lib.make_fleet(64, "pareto-mobile", seed=3)
    st = fleet.state
    for i in (0, 17, 63):
        p = fleet.profile(i)
        assert p.downlink_bps == st.downlink_bps[i]
        assert p.uplink_bps == st.uplink_bps[i]
        assert p.compute_multiplier == st.compute_multiplier[i]
        assert p.availability == st.availability[i]
        assert p.dropout == st.dropout[i]


def test_round_trip_seconds_batch_matches_scalar_bitwise():
    fleet = dev_lib.make_fleet(50, "pareto-mobile", seed=1)
    cids = np.array([3, 3, 49, 0, 21])
    up = np.array([1000, 2000, 500, 1, 0], np.int64)
    comp = np.array([0.1, 0.0, 2.5, 0.3, 1.0])
    batch = fleet.state.round_trip_seconds(4096, up, comp, cids=cids)
    for k, c in enumerate(cids):
        assert batch[k] == fleet.profile(int(c)).round_trip_seconds(
            4096, int(up[k]), float(comp[k]))


def test_capability_scores_batch_matches_scalar():
    fleet = dev_lib.make_fleet(40, "pareto-mobile", seed=2)
    scores = fleet.state.capability_scores()
    for i in range(0, 40, 7):
        assert scores[i] == dev_lib.capability_score(fleet.profile(i))


def test_from_profiles_round_trips_through_arrays():
    profiles = [dev_lib.DeviceProfile(downlink_bps=1e6 * (i + 1),
                                      uplink_bps=5e5,
                                      compute_multiplier=1.0,
                                      availability=0.9, dropout=0.05)
                for i in range(5)]
    fleet = dev_lib.Fleet(name="hand", profiles=profiles)
    assert len(fleet) == 5
    assert [p.downlink_bps for p in fleet.profiles] \
        == [p.downlink_bps for p in profiles]


# ---------------------------------------------------------------------------
# edge pre-reduce


def test_edge_reduce_reassociates_the_flat_reduce():
    rng = np.random.default_rng(0)
    rows = rng.standard_normal((9, 33)).astype(np.float32)
    wts = rng.random(9).astype(np.float32)
    regions = np.array([0, 2, 1, 0, 2, 2, 1, 0, 0])
    buffers = topo_lib.edge_reduce(rows, wts, regions, 3)
    assert buffers.shape == (3, 33)
    # each edge buffer is its members' weighted sum...
    for k in range(3):
        np.testing.assert_allclose(
            buffers[k], (rows[regions == k] * wts[regions == k, None]).sum(0),
            rtol=1e-6)
    # ...and the buffers re-associate the server's flat weighted reduce
    np.testing.assert_allclose(buffers.sum(0), (rows * wts[:, None]).sum(0),
                               rtol=1e-5)


def test_edge_reduce_empty_region_forwards_zeros():
    buffers = topo_lib.edge_reduce(np.ones((2, 4), np.float32),
                                   np.ones(2, np.float32),
                                   np.array([0, 0]), 3)
    assert np.all(buffers[1:] == 0.0)


def test_edge_reduce_shape_mismatch():
    with pytest.raises(ValueError, match="shape mismatch"):
        topo_lib.edge_reduce(np.ones((2, 4)), np.ones(3), np.zeros(2), 1)


# ---------------------------------------------------------------------------
# hierarchical grid runs: hop billing and the one-region contract


def _run(mode, topology=None, dynamics=None, seed=0, rounds=3, **kw):
    gc = simgrid.GridConfig(mode=mode, fleet="pareto-mobile",
                            topology=topology, dynamics=dynamics, **kw)
    return simgrid.run_grid(init_fn, loss_fn, make_ds(), RC, rounds, gc,
                            seed=seed)


def test_sync_hop_billing_sums_to_legacy_ledger():
    res = _run("sync", topology=3)
    ce = res.comm.hop_traffic["client_edge"]
    # the client->edge hop IS the legacy single-hop ledger
    assert ce["down_bytes"] == res.comm.measured_down_bytes
    assert ce["up_bytes"] == res.comm.measured_up_bytes
    assert ce["transfers"] == res.comm.transfers
    es = res.comm.hop_traffic["edge_server"]
    # each round, every active region forwards ONE pre-reduced buffer
    # and fetches ONE model copy: upstream traffic is bounded by
    # rounds * regions, not by cohort size
    assert 0 < es["uploads"] <= 3 * 3
    assert es["up_bytes"] == es["uploads"] * wire.edge_flush_bytes(res.y)
    assert es["transfers"] <= 3 * 3
    assert "edge_server" in res.comm.hop_table()


def test_async_hop_billing_sums_to_legacy_ledger():
    res = _run("async", topology=4, rounds=6, goal_count=4, concurrency=6)
    ce = res.comm.hop_traffic["client_edge"]
    assert ce["down_bytes"] == res.comm.measured_down_bytes
    assert ce["up_bytes"] == res.comm.measured_up_bytes
    es = res.comm.hop_traffic["edge_server"]
    assert es["uploads"] > 0
    assert es["up_bytes"] == es["uploads"] * wire.edge_flush_bytes(res.y)


def test_flat_run_has_no_edge_hop():
    # the flat grid never bills hops at all: no hierarchical machinery
    res = _run("sync")
    assert res.topology is None
    assert res.comm.hop_traffic == {}


def test_one_region_sync_is_bit_identical_to_flat():
    flat = _run("sync")
    one = _run("sync", topology=1)
    assert one.topology is not None and one.topology.num_regions == 1
    _assert_same_run(flat, one)
    # and the hierarchy actually ran: the edge hop is billed
    assert one.comm.hop_traffic["edge_server"]["uploads"] > 0


def test_one_region_async_is_bit_identical_to_flat():
    flat = _run("async", rounds=6, goal_count=4, concurrency=6)
    one = _run("async", topology=1, rounds=6, goal_count=4, concurrency=6)
    _assert_same_run(flat, one)
    assert one.comm.hop_traffic["edge_server"]["uploads"] > 0


def test_multi_region_changes_billing_not_the_model():
    flat = _run("sync", over_selection=1.5)
    multi = _run("sync", topology=4, over_selection=1.5)
    _assert_same_run(flat, multi)   # billing view only — same model path


def test_region_dispatch_upload_counters_cover_cohort():
    res = _run("sync", topology=3)
    reg_up = res.metrics.counter("region_uploads")
    assert sum(reg_up.labels.values()) == res.scheduler_stats["uploads"]
    reg_disp = res.metrics.counter("region_dispatches")
    assert sum(reg_disp.labels.values()) == res.scheduler_stats["dispatches"]


# ---------------------------------------------------------------------------
# correlated region shocks


@pytest.mark.dynamics
def test_shock_zeroes_exactly_its_region():
    shocks = dyn_lib.RegionShocks(every=10.0, duration=5.0,
                                  residual=0.0).bind(
        3, np.random.default_rng(0))
    # force-fire one outage by advancing past the first arrival
    t = shocks.next_t + 1e-9
    f = shocks.factor(np.array([0, 1, 2]), t)
    assert shocks.fired == 1
    region = int(shocks.outages[0][0])
    expected = np.ones(3)
    expected[region] = 0.0
    np.testing.assert_array_equal(f, expected)
    assert shocks.factor_one(region, t) == 0.0
    # the outage expires after `duration`
    t_end = shocks.outages[0][2]
    assert shocks.factor_one(region, t_end) in (1.0, 0.0)  # may re-fire
    if shocks.fired == 1:
        assert shocks.factor_one(region, t_end) == 1.0


@pytest.mark.dynamics
def test_shock_state_dict_round_trips():
    a = dyn_lib.RegionShocks(every=0.5, duration=0.3).bind(
        4, np.random.default_rng(7))
    a.factor(np.arange(4), 2.0)     # fire a few, prune some
    b = dyn_lib.RegionShocks(every=0.5, duration=0.3).bind(
        4, np.random.default_rng(1))
    b.load_state(a.state_dict())
    for t in (2.1, 2.7, 3.4):
        np.testing.assert_array_equal(a.factor(np.arange(4), t),
                                      b.factor(np.arange(4), t))
    assert a.fired == b.fired and a.next_t == b.next_t


@pytest.mark.dynamics
def test_sync_shock_zeroes_exactly_its_regions_dispatches():
    # full-residual outages (residual=0) make every covered region's
    # availability exactly zero: NO member of a shocked region may
    # dispatch while its outage window is live. every=0.005 makes shocks
    # fire well inside the toy run's sub-second virtual span.
    res = _run("sync", topology=3, rounds=4, over_selection=1.5,
               telemetry=True,
               dynamics=dyn_lib.DynamicsConfig(shocks=dyn_lib.RegionShocks(
                   every=0.005, duration=0.05, residual=0.0)))
    events = res.telemetry.events
    outages = [(int(e.payload["region"]), e.t, float(e.payload["until"]))
               for e in events if e.kind == "shock"]
    assert outages, "no shock fired despite every=0.005"
    dispatches = [(int(res.topology.region_of[e.payload["cid"]]), e.t)
                  for e in events if e.kind == "dispatch"]
    assert dispatches
    for region, start, end in outages:
        hits = [t for r, t in dispatches if r == region and start <= t < end]
        assert not hits, (f"region {region} dispatched at {hits[:3]} "
                          f"inside its outage [{start}, {end})")
    # the run as a whole still made progress under the shock schedule
    assert res.scheduler_stats["uploads"] > 0
    assert len(res.history) == 4


@pytest.mark.dynamics
def test_sync_shocks_reduce_uploads_vs_unshocked_run():
    base = _run("sync", topology=3, rounds=4, over_selection=1.5)
    shocked = _run("sync", topology=3, rounds=4, over_selection=1.5,
                   dynamics=dyn_lib.DynamicsConfig(
                       shocks=dyn_lib.RegionShocks(every=0.002,
                                                   duration=0.2,
                                                   residual=0.0)))
    assert shocked.scheduler_stats["offline"] \
        > base.scheduler_stats["offline"]


def test_shocks_without_topology_is_an_error():
    with pytest.raises(ValueError, match="needs a topology"):
        _run("sync", dynamics=dyn_lib.DynamicsConfig(
            shocks=dyn_lib.RegionShocks()))
