"""Pallas kernel validation (interpret mode on CPU): shape/dtype sweeps
asserting allclose against the pure-jnp oracles in kernels/ref.py, plus
hypothesis property sweeps.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import sanitize as sanitize_lib
from repro.kernels import agg_tail
from repro.kernels import ref
from repro.kernels.dp_clip import clip_accumulate, sumsq
from repro.kernels.seed_reconstruct import seed_reconstruct
from repro.kernels.swa_attention import swa_attention


# ---------------------------------------------------------------------------
# sliding-window flash attention


@pytest.mark.interpret
@pytest.mark.parametrize("B,H,S,D,window,dtype", [
    (1, 1, 128, 128, 0, jnp.float32),
    (2, 2, 256, 128, 64, jnp.float32),
    (1, 2, 384, 128, 128, jnp.float32),
    (1, 1, 256, 256, 96, jnp.float32),
    (1, 1, 200, 128, 64, jnp.float32),   # non-multiple seq (padding path)
    (1, 1, 256, 128, 0, jnp.bfloat16),
])
def test_swa_attention_matches_oracle(B, H, S, D, window, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, H, S, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, H, S, D), jnp.float32).astype(dtype)
    out = swa_attention(q, k, v, window=window, interpret=True)
    want = ref.swa_attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                                 v.astype(jnp.float32), window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), atol=tol, rtol=tol)


@pytest.mark.interpret
@given(st.integers(1, 3), st.integers(1, 2),
       st.sampled_from([128, 192, 256]), st.sampled_from([0, 32, 100]))
@settings(max_examples=6, deadline=None)
def test_swa_attention_property_sweep(B, H, S, window):
    D = 128
    ks = jax.random.split(jax.random.key(B * 100 + H * 10 + S + window), 3)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    out = swa_attention(q, k, v, window=window, bq=64, bk=64, interpret=True)
    want = ref.swa_attention_ref(q, k, v, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5,
                               rtol=3e-5)


@pytest.mark.interpret
def test_swa_window_actually_windows():
    """Row S-1 must ignore keys older than the window."""
    B, H, S, D, W = 1, 1, 256, 128, 64
    ks = jax.random.split(jax.random.key(5), 3)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    out1 = swa_attention(q, k, v, window=W, interpret=True)
    # perturb keys/values far outside the window of the last query
    k2 = k.at[:, :, :S - W - 8].set(0.0)
    v2 = v.at[:, :, :S - W - 8].set(0.0)
    out2 = swa_attention(q, k2, v2, window=W, interpret=True)
    np.testing.assert_allclose(np.asarray(out1[:, :, -1]),
                               np.asarray(out2[:, :, -1]), atol=1e-6)


# ---------------------------------------------------------------------------
# DP clip-accumulate


@pytest.mark.interpret
@pytest.mark.parametrize("n,clip", [(1000, 0.5), (32768, 3.0),
                                    (100_001, 1.0), (5, 10.0)])
def test_dp_clip_matches_oracle(n, clip):
    x = jax.random.normal(jax.random.key(n), (n,)) * 2.0
    acc = jnp.linspace(0, 1, n)
    got, nrm = clip_accumulate(acc, x, clip, block=4096, interpret=True)
    want, wn = ref.dp_clip_accumulate_ref(acc, x, clip)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-6,
                               atol=2e-6)
    np.testing.assert_allclose(float(nrm), float(wn), rtol=1e-6)


@pytest.mark.interpret
@given(st.integers(1, 50_000), st.floats(0.1, 20.0))
@settings(max_examples=8, deadline=None)
def test_sumsq_property(n, scale):
    x = jax.random.normal(jax.random.key(n), (n,)) * scale
    got = sumsq(x, block=2048, interpret=True)
    np.testing.assert_allclose(float(got), float(jnp.sum(x * x)), rtol=2e-5)


# ---------------------------------------------------------------------------
# fused aggregation tail (agg_tail.py): per-stage Pallas kernels vs the
# ref.py oracles, then the whole fused composition vs the staged
# reference on every row pathology the server screen handles


_AT_BL = np.asarray([0, 0, 1, 1, 2, 2, 2, 3], np.int32)   # 4 leaves
_AT_NB = len(_AT_BL)
_AT_BLOCK = 256
_AT_SIZE = _AT_NB * _AT_BLOCK


def _at_mat(seed=0, k=5, nan_row=None, outlier_row=None):
    m = np.random.default_rng(seed).normal(0, 0.5, (k, _AT_SIZE))
    m = m.astype(np.float32)
    if nan_row is not None:
        m[nan_row, 33] = np.nan
    if outlier_row is not None:
        m[outlier_row] *= 1e6
    return jnp.asarray(m)


@pytest.mark.interpret
@pytest.mark.parametrize("nan_row", [None, 2])
def test_agg_stats_kernel_matches_ref(nan_row):
    mat = _at_mat(seed=1, nan_row=nan_row)
    bmax, bsumsq = agg_tail.block_stats(mat, block=_AT_BLOCK,
                                        interpret=True)
    rmax, rsumsq = ref.agg_block_stats_ref(mat, block=_AT_BLOCK,
                                           with_sumsq=True)
    np.testing.assert_array_equal(np.asarray(bmax), np.asarray(rmax))
    np.testing.assert_allclose(np.asarray(bsumsq), np.asarray(rsumsq),
                               rtol=1e-6)
    if nan_row is not None:
        assert np.isnan(np.asarray(bmax)[nan_row, 0])


@pytest.mark.interpret
def test_agg_pack_kernel_matches_ref():
    mat = _at_mat(seed=2)
    bmax, _ = ref.agg_block_stats_ref(mat, block=_AT_BLOCK)
    sblock = ref.agg_scales_ref(bmax, _AT_BL, 8, 4)
    q, qss = agg_tail.pack(mat, sblock, bits=8, block=_AT_BLOCK,
                           interpret=True)
    want_q = ref.agg_pack_ref(mat, sblock, 8, block=_AT_BLOCK)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(want_q))
    want_qss = ref.agg_quant_sumsq_ref(want_q, sblock)
    np.testing.assert_allclose(np.asarray(qss), np.asarray(want_qss),
                               rtol=1e-5)


@pytest.mark.interpret
def test_agg_apply_kernel_matches_ref():
    mat = _at_mat(seed=3)
    k = mat.shape[0]
    bmax, _ = ref.agg_block_stats_ref(mat, block=_AT_BLOCK)
    sblock = ref.agg_scales_ref(bmax, _AT_BL, 8, 4)
    q = ref.agg_pack_ref(mat, sblock, 8, block=_AT_BLOCK)
    w = jnp.linspace(0.2, 1.4, k)
    coeff = (w / jnp.sum(w))[:, None] * sblock
    noise = jnp.asarray(np.random.default_rng(9).normal(
        0, 0.01, (_AT_SIZE,)), jnp.float32)
    got = agg_tail.apply_coeff(q, coeff, noise, block=_AT_BLOCK,
                               interpret=True)
    want = ref.agg_apply_ref(q, coeff, noise=noise, block=_AT_BLOCK)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-7)


_AT_SCREEN = sanitize_lib.SanitizeConfig(nonfinite=True, norm_mult=10.0)


@pytest.mark.interpret
@pytest.mark.parametrize("scenario", [
    "clean", "nan_rows", "outlier_rows", "tier_sliced", "zero_weight_pad"])
def test_agg_tail_fused_kernels_match_staged_composition(scenario):
    """The full fused tail with the Pallas 'tpu' engine (interpret mode)
    vs the inline ref composition — which tests/test_agg_tail.py pins to
    the staged op sequence — on every row pathology: clean rows, NaN
    rows, outlier-norm rows, tier-sliced widths, zero-weight padding."""
    k = 5
    kw = dict(block_leaf=_AT_BL, n_leaves=4, align=_AT_BLOCK, bits=8,
              clip_norm=0.5, uniform=True, wsum_fixed=float(k),
              sigma=0.01, screen=_AT_SCREEN)
    mat = _at_mat(seed=4, k=k)
    w = jnp.linspace(0.5, 1.5, k)
    if scenario == "nan_rows":
        mat = _at_mat(seed=4, k=k, nan_row=1)
    elif scenario == "outlier_rows":
        mat = _at_mat(seed=4, k=k, outlier_row=3)
    elif scenario == "tier_sliced":
        # rows as tier lanes emit them: zero outside the tier's
        # contiguous block sub-layout — partial-width rows through the
        # stats/pack/apply kernels
        masks = np.ones((k, _AT_NB), np.float32)
        masks[::2] = (_AT_BL == 0) | (_AT_BL == 2)
        mat = mat * jnp.repeat(jnp.asarray(masks), _AT_BLOCK, axis=1)
    elif scenario == "zero_weight_pad":
        w = w.at[0].set(0.0).at[4].set(0.0)
    rng = jax.random.key(11)
    tpu_out, tpu_info = agg_tail.compose(mat, w, rng=rng, engine="tpu",
                                         interpret=True, **kw)
    ref_out, ref_info = agg_tail.compose(mat, w, rng=rng, engine="ref",
                                         **kw)
    assert tpu_info["route"] == "fused/tpu/coeff"
    np.testing.assert_allclose(np.asarray(tpu_out), np.asarray(ref_out),
                               rtol=1e-4, atol=1e-6)
    for key in ("nonfinite", "outlier"):
        if key in tpu_info:
            np.testing.assert_array_equal(np.asarray(tpu_info[key]),
                                          np.asarray(ref_info[key]))
    if scenario == "nan_rows":
        assert bool(np.asarray(tpu_info["nonfinite"])[1])
    if scenario == "outlier_rows":
        assert bool(np.asarray(tpu_info["outlier"])[3])


# ---------------------------------------------------------------------------
# seed_reconstruct


@pytest.mark.interpret
def test_seed_reconstruct_deterministic_and_invariant():
    a = seed_reconstruct(42, 7, (300, 200), 0.05, interpret=True)
    b = seed_reconstruct(42, 7, (300, 200), 0.05, interpret=True)
    c = seed_reconstruct(43, 7, (300, 200), 0.05, interpret=True)
    d = seed_reconstruct(42, 8, (300, 200), 0.05, interpret=True)
    e = seed_reconstruct(42, 7, (300, 200), 0.05, block_rows=64,
                         interpret=True)
    assert bool((a == b).all())
    assert bool((a != c).any()) and bool((a != d).any())
    assert bool((a == e).all()), "blocking must not change the stream"


@pytest.mark.interpret
@pytest.mark.parametrize("shape,std", [((1024, 256), 0.02), ((17, 130), 1.0),
                                       ((4096,), 0.5)])
def test_seed_reconstruct_moments(shape, std):
    x = np.asarray(seed_reconstruct(1, 2, shape, std, interpret=True)).ravel()
    n = x.size
    assert abs(x.mean()) < 5 * std / np.sqrt(n)
    assert abs(x.std() - std) < 0.05 * std + 1e-3
    # distribution sanity vs the jnp reference (moment match, not bitwise)
    r = np.asarray(ref.seed_reconstruct_ref(1, shape, std)).ravel()
    assert abs(np.abs(x).mean() - np.abs(r).mean()) < 0.1 * std
