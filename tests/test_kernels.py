"""Pallas kernel validation (interpret mode on CPU): shape/dtype sweeps
asserting allclose against the pure-jnp oracles in kernels/ref.py, plus
hypothesis property sweeps.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.dp_clip import clip_accumulate, sumsq
from repro.kernels.seed_reconstruct import seed_reconstruct
from repro.kernels.swa_attention import swa_attention


# ---------------------------------------------------------------------------
# sliding-window flash attention


@pytest.mark.interpret
@pytest.mark.parametrize("B,H,S,D,window,dtype", [
    (1, 1, 128, 128, 0, jnp.float32),
    (2, 2, 256, 128, 64, jnp.float32),
    (1, 2, 384, 128, 128, jnp.float32),
    (1, 1, 256, 256, 96, jnp.float32),
    (1, 1, 200, 128, 64, jnp.float32),   # non-multiple seq (padding path)
    (1, 1, 256, 128, 0, jnp.bfloat16),
])
def test_swa_attention_matches_oracle(B, H, S, D, window, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, H, S, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, H, S, D), jnp.float32).astype(dtype)
    out = swa_attention(q, k, v, window=window, interpret=True)
    want = ref.swa_attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                                 v.astype(jnp.float32), window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), atol=tol, rtol=tol)


@pytest.mark.interpret
@given(st.integers(1, 3), st.integers(1, 2),
       st.sampled_from([128, 192, 256]), st.sampled_from([0, 32, 100]))
@settings(max_examples=6, deadline=None)
def test_swa_attention_property_sweep(B, H, S, window):
    D = 128
    ks = jax.random.split(jax.random.key(B * 100 + H * 10 + S + window), 3)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    out = swa_attention(q, k, v, window=window, bq=64, bk=64, interpret=True)
    want = ref.swa_attention_ref(q, k, v, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5,
                               rtol=3e-5)


@pytest.mark.interpret
def test_swa_window_actually_windows():
    """Row S-1 must ignore keys older than the window."""
    B, H, S, D, W = 1, 1, 256, 128, 64
    ks = jax.random.split(jax.random.key(5), 3)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    out1 = swa_attention(q, k, v, window=W, interpret=True)
    # perturb keys/values far outside the window of the last query
    k2 = k.at[:, :, :S - W - 8].set(0.0)
    v2 = v.at[:, :, :S - W - 8].set(0.0)
    out2 = swa_attention(q, k2, v2, window=W, interpret=True)
    np.testing.assert_allclose(np.asarray(out1[:, :, -1]),
                               np.asarray(out2[:, :, -1]), atol=1e-6)


# ---------------------------------------------------------------------------
# DP clip-accumulate


@pytest.mark.interpret
@pytest.mark.parametrize("n,clip", [(1000, 0.5), (32768, 3.0),
                                    (100_001, 1.0), (5, 10.0)])
def test_dp_clip_matches_oracle(n, clip):
    x = jax.random.normal(jax.random.key(n), (n,)) * 2.0
    acc = jnp.linspace(0, 1, n)
    got, nrm = clip_accumulate(acc, x, clip, block=4096, interpret=True)
    want, wn = ref.dp_clip_accumulate_ref(acc, x, clip)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-6,
                               atol=2e-6)
    np.testing.assert_allclose(float(nrm), float(wn), rtol=1e-6)


@pytest.mark.interpret
@given(st.integers(1, 50_000), st.floats(0.1, 20.0))
@settings(max_examples=8, deadline=None)
def test_sumsq_property(n, scale):
    x = jax.random.normal(jax.random.key(n), (n,)) * scale
    got = sumsq(x, block=2048, interpret=True)
    np.testing.assert_allclose(float(got), float(jnp.sum(x * x)), rtol=2e-5)


# ---------------------------------------------------------------------------
# seed_reconstruct


@pytest.mark.interpret
def test_seed_reconstruct_deterministic_and_invariant():
    a = seed_reconstruct(42, 7, (300, 200), 0.05, interpret=True)
    b = seed_reconstruct(42, 7, (300, 200), 0.05, interpret=True)
    c = seed_reconstruct(43, 7, (300, 200), 0.05, interpret=True)
    d = seed_reconstruct(42, 8, (300, 200), 0.05, interpret=True)
    e = seed_reconstruct(42, 7, (300, 200), 0.05, block_rows=64,
                         interpret=True)
    assert bool((a == b).all())
    assert bool((a != c).any()) and bool((a != d).any())
    assert bool((a == e).all()), "blocking must not change the stream"


@pytest.mark.interpret
@pytest.mark.parametrize("shape,std", [((1024, 256), 0.02), ((17, 130), 1.0),
                                       ((4096,), 0.5)])
def test_seed_reconstruct_moments(shape, std):
    x = np.asarray(seed_reconstruct(1, 2, shape, std, interpret=True)).ravel()
    n = x.size
    assert abs(x.mean()) < 5 * std / np.sqrt(n)
    assert abs(x.std() - std) < 0.05 * std + 1e-3
    # distribution sanity vs the jnp reference (moment match, not bitwise)
    r = np.asarray(ref.seed_reconstruct_ref(1, shape, std)).ravel()
    assert abs(np.abs(x).mean() - np.abs(r).mean()) < 0.1 * std
