"""SSM-layer properties: Mamba scan-vs-step consistency, mLSTM chunk-size
invariance (the chunkwise-parallel form must not depend on the chunking),
sLSTM stabilizer behaviour, causal conv identities.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.nn import ssm

CFG = ModelConfig(name="s", family="ssm", num_layers=1, d_model=32,
                  num_heads=2, num_kv_heads=2, d_ff=0, vocab_size=8,
                  compute_dtype="float32", mamba_d_state=4, mamba_expand=2)


def test_causal_conv_matches_step():
    B, S, C, K = 2, 7, 6, 4
    x = jax.random.normal(jax.random.key(0), (B, S, C))
    w = jax.random.normal(jax.random.key(1), (K, C)) * 0.3
    b = jax.random.normal(jax.random.key(2), (C,)) * 0.1
    full = ssm.causal_conv1d(x, w, b)
    state = jnp.zeros((B, K - 1, C))
    outs = []
    for t in range(S):
        y, state = ssm.conv1d_step(x[:, t], state, w, b)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), atol=1e-5, rtol=1e-5)


def test_mamba_forward_matches_stepwise():
    p = ssm.init_mamba(0, "m", CFG, jnp.float32)
    B, S = 2, 6
    x = jax.random.normal(jax.random.key(3), (B, S, CFG.d_model)) * 0.5
    full, (h_fin, _) = ssm.mamba_forward(x, p, CFG)
    di, _ = ssm.mamba_dims(CFG)
    state = (jnp.zeros((B, di, CFG.mamba_d_state)),
             jnp.zeros((B, CFG.mamba_d_conv - 1, di)))
    outs = []
    for t in range(S):
        y, state = ssm.mamba_step(x[:, t], p, CFG, state)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(state[0]), np.asarray(h_fin),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("chunks", [(4, 16), (8, 64)])
def test_mlstm_chunk_size_invariance(chunks):
    """The chunkwise-parallel mLSTM is exact: results must be identical
    (to fp tolerance) for any chunk size."""
    p = ssm.init_mlstm(0, "m", CFG, jnp.float32)
    x = jax.random.normal(jax.random.key(4), (2, 24, CFG.d_model)) * 0.5
    a, (Ca, na) = ssm.mlstm_forward(x, p, CFG, chunk=chunks[0])
    b, (Cb, nb) = ssm.mlstm_forward(x, p, CFG, chunk=chunks[1])
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5,
                               rtol=3e-5)
    np.testing.assert_allclose(np.asarray(Ca), np.asarray(Cb), atol=3e-4,
                               rtol=3e-4)


def test_mlstm_forward_matches_stepwise():
    p = ssm.init_mlstm(0, "m", CFG, jnp.float32)
    B, S = 1, 9
    x = jax.random.normal(jax.random.key(5), (B, S, CFG.d_model)) * 0.5
    full, _ = ssm.mlstm_forward(x, p, CFG, chunk=4)
    d_in, nh, dh = ssm.xlstm_dims(CFG)
    state = (jnp.zeros((B, nh, dh, dh)), jnp.zeros((B, nh, dh)),
             jnp.zeros((B, 3, d_in)))
    outs = []
    for t in range(S):
        y, state = ssm.mlstm_step(x[:, t], p, CFG, state)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), atol=3e-5, rtol=3e-5)


def test_slstm_forward_matches_stepwise_and_is_stable():
    p = ssm.init_slstm(0, "s", CFG, jnp.float32)
    B, S = 2, 8
    x = jax.random.normal(jax.random.key(6), (B, S, CFG.d_model)) * 3.0
    full, fin = ssm.slstm_forward(x, p, CFG)
    assert bool(jnp.isfinite(full).all())  # exp-gating stabilized by m
    nh, dh = CFG.num_heads, CFG.d_model // CFG.num_heads
    zeros = jnp.zeros((B, nh, dh))
    state = ((zeros, zeros, zeros, zeros - 30.0),
             jnp.zeros((B, 3, CFG.d_model)))
    outs = []
    for t in range(S):
        y, state = ssm.slstm_step(x[:, t], p, CFG, state)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), atol=2e-5, rtol=2e-5)
