"""Checkpoint-resume equivalence: training R rounds straight equals
training r rounds, checkpointing (trainable + seed + server state only),
restoring, and training R-r more — with identical client sampling.
"""
import jax
import jax.numpy as jnp
import numpy as np

import repro.core.partition as part
from repro.checkpoint import checkpoint as ckpt
from repro.core import fedpt
from repro.data import synthetic as syn
from repro.models import paper_models as pm
from repro.nn import basic


def _loss(params, b):
    logits = pm.emnist_cnn_forward(params, b["images"])
    lp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(lp, b["labels"][:, None], 1)), {}


def _train(y, ss, frozen, round_fn, ds, rounds, start=0):
    rng = np.random.default_rng(42)
    # regenerate the deterministic cohort stream and skip `start` rounds
    cohorts = []
    for r in range(start + rounds):
        cids = syn.sample_cohort(rng, 8, 4)
        batch, w = syn.cohort_batch(ds, cids, 1, 8, rng)
        cohorts.append((batch, w))
    for r in range(start, start + rounds):
        batch, w = cohorts[r]
        y, ss, _ = round_fn(y, ss, frozen, batch, jnp.asarray(w),
                            jax.random.key(r))
    return y, ss


def test_resume_equals_straight_run(tmp_path):
    ds = syn.make_federated_images(8, 24, (28, 28, 1), 62, seed=9)
    SEED = 5
    init_fn = lambda s: pm.init_emnist_cnn(s)
    y0, frozen = part.partition(init_fn(SEED), pm.EMNIST_FREEZE)
    rc = fedpt.RoundConfig(4, 1, 8, "sgd", 0.05, "sgdm", 0.5)
    round_fn, sopt = fedpt.make_round_fn(_loss, rc)
    round_fn = jax.jit(round_fn)

    # straight: 4 rounds
    yA, ssA = _train(y0, sopt.init(y0), frozen, round_fn, ds, 4)

    # split: 2 rounds -> checkpoint -> restore -> 2 rounds
    y1, ss1 = _train(y0, sopt.init(y0), frozen, round_fn, ds, 2)
    path = str(tmp_path / "mid.npz")
    ckpt.save(path, y1, seed=SEED, freeze_spec=pm.EMNIST_FREEZE,
              server_state=ss1, round_num=2)
    y2, seed2, spec2, ss2, rnd, _ = ckpt.load(path, server_state_template=ss1)
    frozen2 = part.partition(init_fn(seed2), tuple(spec2))[1]
    yB, ssB = _train(
        jax.tree_util.tree_map(jnp.asarray, y2), ss2, frozen2, round_fn, ds,
        2, start=2)

    for (ka, va), (kb, vb) in zip(basic.flatten_params(yA),
                                  basic.flatten_params(yB)):
        assert ka == kb
        np.testing.assert_allclose(np.asarray(va), np.asarray(vb),
                                   rtol=1e-6, atol=1e-7)
