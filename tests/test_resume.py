"""Checkpoint-resume equivalence.

Two layers: (1) model checkpoints (``checkpoint.checkpoint``) — training
R rounds straight equals training r rounds, checkpointing (trainable +
seed + server state only), restoring, and training R-r more, with
identical client sampling; (2) grid-state snapshots
(``checkpoint.grid_state``) — kill a fault-injected grid run at virtual
time T, restore its latest mid-run snapshot, continue, and the resumed
run reproduces the uninterrupted run's history, final ``y`` (bitwise on
CPU), privacy ledger and wire billing exactly.
"""
import dataclasses as dc
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.partition as part
from repro.checkpoint import checkpoint as ckpt
from repro.checkpoint import grid_state as gstate
from repro.core import dp as dp_lib
from repro.core import fedpt
from repro.data import synthetic as syn
from repro.models import paper_models as pm
from repro.nn import basic
from repro.sim import faults as faults_lib
from repro.sim import grid as simgrid


def _loss(params, b):
    logits = pm.emnist_cnn_forward(params, b["images"])
    lp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(lp, b["labels"][:, None], 1)), {}


def _train(y, ss, frozen, round_fn, ds, rounds, start=0):
    rng = np.random.default_rng(42)
    # regenerate the deterministic cohort stream and skip `start` rounds
    cohorts = []
    for r in range(start + rounds):
        cids = syn.sample_cohort(rng, 8, 4)
        batch, w = syn.cohort_batch(ds, cids, 1, 8, rng)
        cohorts.append((batch, w))
    for r in range(start, start + rounds):
        batch, w = cohorts[r]
        y, ss, _ = round_fn(y, ss, frozen, batch, jnp.asarray(w),
                            jax.random.key(r))
    return y, ss


def test_resume_equals_straight_run(tmp_path):
    ds = syn.make_federated_images(8, 24, (28, 28, 1), 62, seed=9)
    SEED = 5
    init_fn = lambda s: pm.init_emnist_cnn(s)
    y0, frozen = part.partition(init_fn(SEED), pm.EMNIST_FREEZE)
    rc = fedpt.RoundConfig(4, 1, 8, "sgd", 0.05, "sgdm", 0.5)
    round_fn, sopt = fedpt.make_round_fn(_loss, rc)
    round_fn = jax.jit(round_fn)

    # straight: 4 rounds
    yA, ssA = _train(y0, sopt.init(y0), frozen, round_fn, ds, 4)

    # split: 2 rounds -> checkpoint -> restore -> 2 rounds
    y1, ss1 = _train(y0, sopt.init(y0), frozen, round_fn, ds, 2)
    path = str(tmp_path / "mid.npz")
    ckpt.save(path, y1, seed=SEED, freeze_spec=pm.EMNIST_FREEZE,
              server_state=ss1, round_num=2)
    y2, seed2, spec2, ss2, rnd, _ = ckpt.load(path, server_state_template=ss1)
    frozen2 = part.partition(init_fn(seed2), tuple(spec2))[1]
    yB, ssB = _train(
        jax.tree_util.tree_map(jnp.asarray, y2), ss2, frozen2, round_fn, ds,
        2, start=2)

    for (ka, va), (kb, vb) in zip(basic.flatten_params(yA),
                                  basic.flatten_params(yB)):
        assert ka == kb
        np.testing.assert_allclose(np.asarray(va), np.asarray(vb),
                                   rtol=1e-6, atol=1e-7)


def test_checkpoint_save_load_appends_npz_suffix(tmp_path):
    """save()/load() agree on the on-disk name even when the caller
    omits ``.npz`` (np.savez appends it on write; load used to miss)."""
    y = {"dense": basic.init_dense(0, "dense", 8, 4, jnp.float32,
                                   bias=True)}
    bare = str(tmp_path / "model")            # no suffix
    ckpt.save(bare, y, seed=0, freeze_spec=(), round_num=3)
    assert (tmp_path / "model.npz").exists()
    y2, seed, spec, ss, rnd, meta = ckpt.load(bare)
    assert seed == 0 and rnd == 3
    for (ka, va), (kb, vb) in zip(basic.flatten_params(y),
                                  basic.flatten_params(y2)):
        assert ka == kb
        np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))


# ---------------------------------------------------------------------------
# grid-state snapshots: kill -> restore -> continue (chaos marker: these
# exercise the fault model end to end)

pytest_grid = pytest.mark.chaos


def _g_init(seed):
    return {"dense": basic.init_dense(seed, "dense", 64, 4, jnp.float32,
                                      bias=True)}


def _g_loss(params, b):
    x = b["images"].reshape(b["images"].shape[0], -1)
    lp = jax.nn.log_softmax(basic.dense(x, params["dense"]))
    return -jnp.mean(jnp.take_along_axis(lp, b["labels"][:, None], 1)), {}


def _g_ds():
    return syn.make_federated_images(12, 30, (8, 8, 1), 4, seed=0,
                                     test_examples=64)


G_RC = fedpt.RoundConfig(4, 2, 8, "sgd", 0.1, "sgd", 1.0)
CHAOS = dict(crash_compute=0.05, truncate_upload=0.05, corrupt_nan=0.08,
             corrupt_bitflip=0.08, duplicate_upload=0.05)


def _assert_same_run(a, b):
    assert [h["loss"] for h in a.history] == [h["loss"] for h in b.history]
    for ha, hb in zip(a.history, b.history):
        assert ha["virtual_seconds"] == hb["virtual_seconds"]
    for (ka, va), (kb, vb) in zip(basic.flatten_params(a.y),
                                  basic.flatten_params(b.y)):
        assert ka == kb
        assert bool(jnp.all(va == vb)), f"{ka} differs after resume"
    assert a.scheduler_stats == b.scheduler_stats
    assert a.comm.measured_up_bytes == b.comm.measured_up_bytes
    assert a.comm.measured_down_bytes == b.comm.measured_down_bytes
    assert a.dp == b.dp


def _kill_then_resume(gbase, killed_cfg, rc, n, seed=3):
    """Run ``killed_cfg`` until ServerKilled, then resume ``gbase`` from
    the checkpoint the kill left behind."""
    ds = _g_ds()
    with pytest.raises(faults_lib.ServerKilled) as ei:
        simgrid.run_grid(_g_init, _g_loss, ds, rc, n, grid=killed_cfg,
                         seed=seed)
    assert ei.value.checkpoint is not None
    return simgrid.run_grid(
        _g_init, _g_loss, ds, rc, n,
        grid=dc.replace(gbase, resume_from=ei.value.checkpoint), seed=seed)


@pytest_grid
def test_async_kill_resume_bitwise(tmp_path):
    """The flagship acceptance: chaos faults + sanitize + per-flush DP +
    jittered dynamics, killed mid-run, resumed from the latest snapshot —
    history, y, epsilon ledger and wire billing all match the
    uninterrupted run."""
    ds = _g_ds()
    rc = dc.replace(G_RC, dp_clip_norm=1.0, dp_noise_multiplier=0.6)
    gbase = simgrid.GridConfig(mode="async", faults=CHAOS, sanitize=True,
                               dynamics="jitter")
    straight = simgrid.run_grid(_g_init, _g_loss, ds, rc, 8, grid=gbase,
                                seed=3)
    # kill between the 5th and 6th flush: checkpoints at applied 2 and 4
    # exist, and the run still has work to redo after restore
    T = 0.5 * (straight.history[4]["virtual_seconds"]
               + straight.history[5]["virtual_seconds"])
    killed = dc.replace(gbase, faults=dict(CHAOS, server_kill_at=T),
                        checkpoint_every=2,
                        checkpoint_dir=str(tmp_path / "ckpt"))
    resumed = _kill_then_resume(gbase, killed, rc, 8)
    _assert_same_run(straight, resumed)
    assert resumed.dp["epsilon"] == straight.dp["epsilon"]


@pytest_grid
def test_sync_kill_resume_bitwise(tmp_path):
    ds = _g_ds()
    gbase = simgrid.GridConfig(mode="sync",
                               faults={"crash_compute": 0.1})
    straight = simgrid.run_grid(_g_init, _g_loss, ds, G_RC, 8, grid=gbase,
                                seed=3)
    T = 0.5 * (straight.history[4]["virtual_seconds"]
               + straight.history[5]["virtual_seconds"])
    killed = dc.replace(gbase,
                        faults={"crash_compute": 0.1, "server_kill_at": T},
                        checkpoint_every=2,
                        checkpoint_dir=str(tmp_path / "ckpt"))
    resumed = _kill_then_resume(gbase, killed, G_RC, 8)
    _assert_same_run(straight, resumed)


@pytest_grid
def test_async_hierarchical_shock_kill_resume_bitwise(tmp_path):
    """Hierarchical + correlated-shock resume: the topology meta, the
    shock process (its RNG stream, pending arrival and outage history)
    and the per-region edge counters all ride the snapshot — the
    resumed run matches the uninterrupted one bitwise, down to the
    edge_server hop ledger."""
    from repro.sim import dynamics as dyn_lib
    ds = _g_ds()
    gbase = simgrid.GridConfig(
        mode="async", faults=CHAOS, sanitize=True, topology=3,
        dynamics=dyn_lib.DynamicsConfig(shocks=dyn_lib.RegionShocks(
            every=0.01, duration=0.05, residual=0.0)))
    straight = simgrid.run_grid(_g_init, _g_loss, ds, G_RC, 8, grid=gbase,
                                seed=3)
    T = 0.5 * (straight.history[4]["virtual_seconds"]
               + straight.history[5]["virtual_seconds"])
    killed = dc.replace(gbase, faults=dict(CHAOS, server_kill_at=T),
                        checkpoint_every=2,
                        checkpoint_dir=str(tmp_path / "ckpt"))
    resumed = _kill_then_resume(gbase, killed, G_RC, 8)
    _assert_same_run(straight, resumed)
    assert straight.comm.hop_traffic == resumed.comm.hop_traffic
    assert straight.comm.hop_traffic["edge_server"]["uploads"] > 0


@pytest_grid
def test_sync_hierarchical_shock_kill_resume_bitwise(tmp_path):
    from repro.sim import dynamics as dyn_lib
    ds = _g_ds()
    gbase = simgrid.GridConfig(
        mode="sync", faults={"crash_compute": 0.1}, topology=3,
        over_selection=1.5,
        dynamics=dyn_lib.DynamicsConfig(shocks=dyn_lib.RegionShocks(
            every=0.01, duration=0.05, residual=0.0)))
    straight = simgrid.run_grid(_g_init, _g_loss, ds, G_RC, 8, grid=gbase,
                                seed=3)
    T = 0.5 * (straight.history[4]["virtual_seconds"]
               + straight.history[5]["virtual_seconds"])
    killed = dc.replace(gbase,
                        faults={"crash_compute": 0.1, "server_kill_at": T},
                        checkpoint_every=2,
                        checkpoint_dir=str(tmp_path / "ckpt"))
    resumed = _kill_then_resume(gbase, killed, G_RC, 8)
    _assert_same_run(straight, resumed)
    assert straight.comm.hop_traffic == resumed.comm.hop_traffic


@pytest_grid
def test_resume_topology_mismatch_rejected(tmp_path):
    """A snapshot from a 3-region run must not silently resume onto a
    different (or flat) topology."""
    ds = _g_ds()
    gtopo = simgrid.GridConfig(mode="sync", topology=3, checkpoint_every=2,
                               checkpoint_dir=str(tmp_path / "ckpt"))
    simgrid.run_grid(_g_init, _g_loss, ds, G_RC, 4, grid=gtopo, seed=3)
    snap = gstate.latest(str(tmp_path / "ckpt"))
    assert snap is not None
    with pytest.raises(ValueError):
        simgrid.run_grid(
            _g_init, _g_loss, ds, G_RC, 4,
            grid=simgrid.GridConfig(mode="sync", resume_from=snap), seed=3)
    with pytest.raises(ValueError):
        simgrid.run_grid(
            _g_init, _g_loss, ds, G_RC, 4,
            grid=simgrid.GridConfig(mode="sync", topology=5,
                                    resume_from=snap), seed=3)


@pytest_grid
def test_async_resume_multitier_adaptive_policy(tmp_path):
    """Resume carries the whole policy/plan state: a two-tier TrainPlan
    with the adaptive-capability policy (observed-RTT EMAs, refit maps)
    continues exactly — kill-only fault config, resumed without faults."""
    ds = _g_ds()
    gbase = simgrid.GridConfig(mode="async",
                               plan={"full": (), "lite": (r"/kernel$",)},
                               selection="adaptive-capability",
                               fleet="pareto-mobile", dynamics="jitter")
    straight = simgrid.run_grid(_g_init, _g_loss, ds, G_RC, 8, grid=gbase,
                                seed=3)
    T = 0.5 * (straight.history[5]["virtual_seconds"]
               + straight.history[6]["virtual_seconds"])
    killed = dc.replace(gbase, faults={"server_kill_at": T},
                        checkpoint_every=2,
                        checkpoint_dir=str(tmp_path / "ckpt"))
    resumed = _kill_then_resume(gbase, killed, G_RC, 8)
    _assert_same_run(straight, resumed)
    assert straight.tier_stats == resumed.tier_stats


@pytest_grid
def test_resume_mode_mismatch_rejected(tmp_path):
    ds = _g_ds()
    gsync = simgrid.GridConfig(mode="sync", checkpoint_every=2,
                               checkpoint_dir=str(tmp_path / "ckpt"))
    simgrid.run_grid(_g_init, _g_loss, ds, G_RC, 4, grid=gsync, seed=3)
    snap = gstate.latest(str(tmp_path / "ckpt"))
    assert snap is not None
    with pytest.raises(ValueError, match="mode must match"):
        simgrid.run_grid(
            _g_init, _g_loss, ds, G_RC, 4,
            grid=simgrid.GridConfig(mode="async", resume_from=snap),
            seed=3)


@pytest_grid
def test_grid_state_rejects_legacy_model_checkpoint(tmp_path):
    """A model checkpoint is not a grid-state snapshot: load_state fails
    with a pointer to checkpoint.load, which still reads it fine."""
    y = {"dense": _g_init(0)["dense"]}
    path = str(tmp_path / "model.npz")
    ckpt.save(path, y, seed=0, freeze_spec=(), round_num=1)
    with pytest.raises(ValueError, match="checkpoint.load"):
        gstate.load_state(path)
    y2, *_ = ckpt.load(path)
    np.testing.assert_array_equal(np.asarray(y["dense"]["kernel"]),
                                  np.asarray(y2["dense"]["kernel"]))


@pytest_grid
def test_grid_state_version_gate(tmp_path):
    path = gstate.save_state(str(tmp_path / "future"),
                             {"grid_state_version": 999, "mode": "async"},
                             {})
    with pytest.raises(ValueError, match="version 999"):
        gstate.load_state(path)


@pytest_grid
def test_accountant_ledger_roundtrip():
    cfg = dp_lib.FlushDPConfig(clip_norm=1.0, noise_multiplier=0.8,
                               goal_count=5)
    a = dp_lib.FlushAccountant(cfg)
    a.record_flush(5, multiplicity=1, now=1.0)
    a.record_flush(3, multiplicity=2, now=2.0)   # padded, duplicated
    b = dp_lib.FlushAccountant(cfg)
    b.load_state(a.state_dict())
    assert b.summary() == a.summary()
    assert b.epsilon(1e-5) == a.epsilon(1e-5)
    # continuing the restored ledger composes identically
    a.record_flush(5, now=3.0)
    b.record_flush(5, now=3.0)
    assert math.isclose(a.epsilon(1e-5), b.epsilon(1e-5), rel_tol=0.0)
    # a different calibration must refuse the ledger
    other = dp_lib.FlushAccountant(
        dp_lib.FlushDPConfig(clip_norm=1.0, noise_multiplier=0.4,
                             goal_count=5))
    with pytest.raises(ValueError, match="calibration|sigma|match"):
        other.load_state(a.state_dict())


@pytest_grid
def test_rng_state_json_roundtrip_exact():
    import json
    g = np.random.default_rng(1234)
    g.standard_normal(17)
    state = json.loads(json.dumps(gstate.rng_state(g)))
    h = np.random.default_rng(0)
    gstate.set_rng_state(h, state)
    np.testing.assert_array_equal(g.standard_normal(32),
                                  h.standard_normal(32))
